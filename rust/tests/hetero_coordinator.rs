//! Integration tests for the host/offload coordinator (HeteroRun):
//! split-consistency across worker threads, the exchange-schedule
//! ablation, and failure handling.

use repro::coordinator::node::WorkerBackend;
use repro::coordinator::HeteroRun;
use repro::mesh::{build_local_blocks, geometry::unit_cube_geometry, geometry::two_tree_geometry};
use repro::partition::{nested_partition, splice, DeviceKind};
use repro::solver::analytic::standing_wave;
use repro::solver::driver::{Driver, RustRefBackend, StageBackend};
use repro::solver::{BlockState, LglBasis};

fn build_states(
    mesh: &repro::mesh::Mesh,
    owners: &[usize],
    n_owners: usize,
    order: usize,
) -> (Vec<repro::mesh::LocalBlock>, Vec<BlockState>, repro::mesh::ExchangePlan, Vec<DeviceKind>) {
    let (lblocks, plan) = build_local_blocks(mesh, owners, n_owners);
    let basis = LglBasis::new(order);
    let w = std::f64::consts::PI * 3f64.sqrt();
    let mut states = Vec::new();
    let mut devices = Vec::new();
    for lb in &lblocks {
        let mut st =
            BlockState::from_local_block(lb, order, lb.len().max(1), lb.halo_len.max(1));
        st.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
        states.push(st);
        devices.push(if lb.owner % 2 == 0 { DeviceKind::Cpu } else { DeviceKind::Mic });
    }
    (lblocks, states, plan, devices)
}

/// The two-worker threaded coordinator must reproduce the single-threaded
/// Driver exactly (same backend, same schedule).
#[test]
fn hetero_run_matches_driver() {
    let order = 2;
    let mesh = unit_cube_geometry(2);
    let node_part = splice(&mesh, 1);
    let np = nested_partition(&mesh, &node_part, 0.5);
    let owners = np.owners();

    // single-threaded driver
    let (lblocks, states, plan, _) = build_states(&mesh, &owners, np.n_owners(), order);
    let backends: Vec<Box<dyn StageBackend>> = (0..np.n_owners())
        .map(|_| Box::new(RustRefBackend::new(order)) as Box<dyn StageBackend>)
        .collect();
    let mut drv = Driver::new(states.clone(), plan.clone(), backends, order);
    drv.prime();
    drv.run(1e-3, 8).unwrap();

    // threaded coordinator
    let (lblocks2, states2, plan2, devices) = build_states(&mesh, &owners, np.n_owners(), order);
    assert_eq!(lblocks.len(), lblocks2.len());
    let mut run = HeteroRun::launch(
        &lblocks2, states2, plan2, &devices, WorkerBackend::RustRef, order,
    )
    .unwrap();
    run.run(1e-3, 8).unwrap();

    for (o, _) in lblocks.iter().enumerate() {
        let st = run.read_block(o).unwrap();
        let max_diff = drv.blocks[o]
            .q
            .iter()
            .zip(&st.q)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-6, "owner {o}: threaded vs driver diff {max_diff}");
    }
}

/// Exchange-schedule ablation: once-per-step sync (the paper's §5.5
/// schedule) must stay stable but differ measurably from per-stage.
#[test]
fn once_per_step_sync_is_stable_but_approximate() {
    let order = 2;
    // 4^3 so the MIC partition is non-empty (2^3 = 8 has no interior)
    let mesh = unit_cube_geometry(4);
    let node_part = splice(&mesh, 1);
    let np = nested_partition(&mesh, &node_part, 0.12);
    assert!(np.node_counts[0].1 > 0, "MIC partition must be non-empty");
    let owners = np.owners();
    let basis = LglBasis::new(order);

    let run_mode = |every_stage: bool| -> (f64, Vec<f32>) {
        let (lblocks, states, plan, devices) =
            build_states(&mesh, &owners, np.n_owners(), order);
        let mut run = HeteroRun::launch(
            &lblocks, states, plan, &devices, WorkerBackend::RustRef, order,
        )
        .unwrap();
        run.exchange_every_stage = every_stage;
        run.run(1e-3, 10).unwrap();
        let e = run.energy().unwrap();
        let q = run.read_block(0).unwrap().q.clone();
        (e, q)
    };
    let (e_exact, q_exact) = run_mode(true);
    let (e_lazy, q_lazy) = run_mode(false);
    assert!(e_lazy.is_finite() && e_lazy > 0.0);
    // bounded: lazy sync cannot blow up over 10 steps
    assert!((e_lazy - e_exact).abs() < 0.05 * e_exact, "{e_exact} vs {e_lazy}");
    // ...but it is a genuinely different schedule
    let diff = q_exact
        .iter()
        .zip(&q_lazy)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(diff > 0.0, "schedules must differ");
    let _ = basis;
}

/// Two-tree geometry (paper Fig 6.1) through the full coordinator:
/// stable across the acoustic/elastic interface.
#[test]
fn two_tree_coupled_run_stable() {
    let order = 2;
    let mesh = two_tree_geometry(2);
    let node_part = splice(&mesh, 2); // two "nodes" across the interface
    let np = nested_partition(&mesh, &node_part, 0.4);
    let owners = np.owners();
    let (lblocks, mut states, plan, devices) =
        build_states(&mesh, &owners, np.n_owners(), order);
    // gaussian pulse in the acoustic tree instead of the standing wave
    let basis = LglBasis::new(order);
    for st in states.iter_mut() {
        st.set_initial_condition(&basis, |x| {
            repro::solver::analytic::gaussian_pulse(x, [0.5, 0.5, 0.5], 0.15, 1.0, 1.0)
        });
    }
    let mut run =
        HeteroRun::launch(&lblocks, states, plan, &devices, WorkerBackend::RustRef, order)
            .unwrap();
    let e0 = run.energy().unwrap();
    run.run(5e-4, 40).unwrap();
    let e1 = run.energy().unwrap();
    assert!(e1.is_finite());
    assert!(e1 <= e0 * (1.0 + 1e-6), "energy grew across the interface: {e0} -> {e1}");
    assert!(e1 > 0.3 * e0, "unphysical dissipation: {e0} -> {e1}");
}

/// Empty MIC partitions (fraction 0) still run: all work on the CPU worker.
#[test]
fn zero_mic_fraction_runs() {
    let order = 1;
    let mesh = unit_cube_geometry(2);
    let node_part = splice(&mesh, 1);
    let np = nested_partition(&mesh, &node_part, 0.0);
    let owners = np.owners();
    let (lblocks, states, plan, devices) = build_states(&mesh, &owners, np.n_owners(), order);
    let mut run =
        HeteroRun::launch(&lblocks, states, plan, &devices, WorkerBackend::RustRef, order)
            .unwrap();
    run.run(1e-3, 3).unwrap();
    assert!(run.energy().unwrap() > 0.0);
}

/// Kernel-time accounting flows back from both workers.
#[test]
fn take_times_reports_work() {
    let order = 2;
    let mesh = unit_cube_geometry(4);
    let node_part = splice(&mesh, 1);
    let np = nested_partition(&mesh, &node_part, 0.12);
    assert!(np.node_counts[0].1 > 0, "MIC partition must be non-empty");
    let owners = np.owners();
    let (lblocks, states, plan, devices) = build_states(&mesh, &owners, np.n_owners(), order);
    let mut run =
        HeteroRun::launch(&lblocks, states, plan, &devices, WorkerBackend::RustRef, order)
            .unwrap();
    run.run(1e-3, 2).unwrap();
    let (cpu_t, mic_t) = run.take_times().unwrap();
    assert!(cpu_t.total() > 0.0, "cpu worker did work");
    assert!(mic_t.total() > 0.0, "mic worker did work");
    assert!(cpu_t.volume_loop > 0.0 && mic_t.volume_loop > 0.0);
    // after take, counters reset
    let (cpu_t2, _) = run.take_times().unwrap();
    assert_eq!(cpu_t2.total(), 0.0);
}
