//! Fault-tolerant cluster runtime, end to end: a seeded `FaultPlan` kills
//! a chosen node mid-run on every transport, the coordinator detects the
//! death within bounded time (polling collection + stage deadline — no
//! run path may hang on a dead worker), recovery resplices the dead
//! node's elements across the survivors and rewinds to the last
//! q-snapshot, and the final field still matches the single-block scalar
//! oracle to 1e-6. Elastic join is the mirror image: a spare node comes
//! online mid-run and the splice sheds elements onto it, again without
//! leaving the oracle. Teardown under poison must leave no hung thread
//! and no leaked transport resources on any lane.

use std::thread;
use std::time::{Duration, Instant};

use repro::coordinator::cluster::{ClusterRun, ClusterSpec};
use repro::coordinator::rebalance::RebalanceCause;
use repro::coordinator::{ClusterError, FaultPlan, JoinSpec, KillMode, KillSpec, TransportKind};
use repro::mesh::{build_local_blocks, unit_cube_geometry, Mesh};
use repro::solver::analytic::standing_wave;
use repro::solver::driver::{Driver, RustRefBackend, StageBackend};
use repro::solver::{BlockState, LglBasis};

const KINDS: [TransportKind; 3] =
    [TransportKind::InProc, TransportKind::Shm, TransportKind::Socket];

fn ic(x: [f64; 3]) -> [f64; 9] {
    let w = std::f64::consts::PI * 3f64.sqrt();
    standing_wave(x, 0.0, 1.0, 1.0, w)
}

/// The oracle: one block, one scalar backend, the plain driver. Returns
/// per-element q in global Morton order.
fn scalar_reference(mesh: &Mesh, order: usize, dt: f64, steps: usize) -> Vec<Vec<f32>> {
    let owners = vec![0usize; mesh.len()];
    let (lblocks, plan) = build_local_blocks(mesh, &owners, 1);
    let basis = LglBasis::new(order);
    let mut st = BlockState::from_local_block(
        &lblocks[0],
        order,
        lblocks[0].len(),
        lblocks[0].halo_len.max(1),
    );
    st.set_initial_condition(&basis, ic);
    let backends: Vec<Box<dyn StageBackend>> = vec![Box::new(RustRefBackend::new(order))];
    let mut drv = Driver::new(vec![st], plan, backends, order);
    drv.prime();
    drv.run(dt, steps).unwrap();
    let m = order + 1;
    let esz = 9 * m * m * m;
    let st = &drv.blocks[0];
    (0..mesh.len()).map(|e| st.q[e * esz..(e + 1) * esz].to_vec()).collect()
}

fn max_diff(a: &[Vec<f32>], b: &[Vec<f32>]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut worst = 0.0f32;
    for (ea, eb) in a.iter().zip(b) {
        assert_eq!(ea.len(), eb.len());
        for (&x, &y) in ea.iter().zip(eb) {
            worst = worst.max((x - y).abs());
        }
    }
    worst
}

fn faulty_spec(nodes: usize, order: usize, kind: TransportKind, plan: FaultPlan) -> ClusterSpec {
    let mut spec = ClusterSpec::new(nodes, order);
    spec.mic_fraction = Some(0.2);
    spec.transport = kind;
    spec.faults = plan;
    spec
}

/// The tentpole path on every transport: node 1 crashes at step 5 of 8,
/// snapshots run every 2 steps, so recovery rewinds exactly 1 completed
/// step, resplices node 1's chunk over node 0, and the finished run still
/// matches the scalar oracle.
#[test]
fn crash_kill_recovers_on_every_transport() {
    let order = 2;
    let mesh = unit_cube_geometry(4); // 64 elements
    let dt = 1e-3;
    let steps = 8;
    let reference = scalar_reference(&mesh, order, dt, steps);
    for kind in KINDS {
        let plan = FaultPlan {
            seed: 7,
            kills: vec![KillSpec { node: 1, step: 5, mode: KillMode::Crash }],
            ..FaultPlan::default()
        };
        let mut spec = faulty_spec(2, order, kind, plan);
        spec.checkpoint_every = Some(2);
        let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
        run.run(dt, steps).unwrap();

        assert_eq!(run.node_active(), &[true, false], "{kind}: node 1 must be down");
        let counts = run.node_counts();
        assert_eq!(counts[1], (0, 0), "{kind}: dead node keeps no elements");
        assert_eq!(counts[0].0 + counts[0].1, mesh.len(), "{kind}: survivor owns everything");

        let rec: Vec<_> = run
            .rebalance_history
            .iter()
            .filter(|r| r.cause == RebalanceCause::Recovery)
            .collect();
        assert_eq!(rec.len(), 1, "{kind}: exactly one recovery");
        assert_eq!(rec[0].replayed_steps, 1, "{kind}: snapshots at 0/2/4 -> replay 1 step");
        assert!(rec[0].level1_migrated > 0, "{kind}: the dead chunk must move");
        assert!(run.last_error().is_none(), "{kind}: recovery clears the failure");

        let got = run.gather_elements().unwrap();
        let diff = max_diff(&reference, &got);
        assert!(diff <= 1e-6, "{kind}: recovered field vs scalar oracle diff {diff}");
    }
}

/// A silent kill (the worker thread vanishes without shipping or
/// replying) is detected through the hung-up reply channel and recovers
/// just like a crash.
#[test]
fn silent_kill_recovers() {
    let order = 2;
    let mesh = unit_cube_geometry(4);
    let dt = 1e-3;
    let steps = 8;
    let reference = scalar_reference(&mesh, order, dt, steps);
    let plan = FaultPlan {
        seed: 5,
        kills: vec![KillSpec { node: 0, step: 3, mode: KillMode::Silent }],
        ..FaultPlan::default()
    };
    let mut spec = faulty_spec(2, order, TransportKind::InProc, plan);
    spec.checkpoint_every = Some(2);
    let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
    run.run(dt, steps).unwrap();
    assert_eq!(run.node_active(), &[false, true]);
    let rec: Vec<_> = run
        .rebalance_history
        .iter()
        .filter(|r| r.cause == RebalanceCause::Recovery)
        .collect();
    assert_eq!(rec.len(), 1);
    assert_eq!(rec[0].replayed_steps, 1, "kill at 3, snapshots at 0/2 -> replay 1");
    let got = run.gather_elements().unwrap();
    let diff = max_diff(&reference, &got);
    assert!(diff <= 1e-6, "silent-kill recovery vs scalar oracle diff {diff}");
}

/// A worker that stalls (mute but alive) can only be caught by the stage
/// deadline; detection must be bounded, the failure typed, and — with no
/// checkpoint configured — the run must surface the error instead of
/// recovering, refuse further steps, and still tear down cleanly.
#[test]
fn stall_is_caught_by_the_stage_deadline() {
    let order = 2;
    let mesh = unit_cube_geometry(4);
    let dt = 1e-3;
    let plan = FaultPlan {
        seed: 1,
        kills: vec![KillSpec { node: 0, step: 2, mode: KillMode::Stall }],
        ..FaultPlan::default()
    };
    let mut spec = faulty_spec(2, order, TransportKind::InProc, plan);
    spec.stage_deadline = Some(Duration::from_millis(300));
    // no checkpoint_every: the failure is detected but not recoverable
    let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
    let t0 = Instant::now();
    let err = run.run(dt, 6).expect_err("a stalled node without checkpoints is fatal");
    let detected = t0.elapsed();
    // deadline 300ms + the fixed 5s post-halt grace, with slack for CI
    assert!(detected < Duration::from_secs(60), "detection took {detected:?}");
    assert!(err.to_string().contains("node failure"), "{err}");
    match run.last_error() {
        Some(ClusterError::NodeFailure { nodes, step, .. }) => {
            assert_eq!(nodes, &[0]);
            assert_eq!(*step, 2);
        }
        other => panic!("expected a typed NodeFailure, got {other:?}"),
    }
    assert!(!run.can_recover(), "no checkpoint -> not recoverable");
    let again = run.step(dt).expect_err("degraded run must refuse to step");
    assert!(again.to_string().contains("degraded"), "{again}");
    drop(run); // must join the stalled (but Shutdown-honoring) thread
}

/// Elastic membership: a spare node held back at launch joins at step 3
/// and the splice sheds elements onto it; the result still matches the
/// oracle because joins migrate live state at a step boundary.
#[test]
fn elastic_join_sheds_elements_onto_the_spare() {
    let order = 2;
    let mesh = unit_cube_geometry(4);
    let dt = 1e-3;
    let steps = 6;
    let reference = scalar_reference(&mesh, order, dt, steps);
    for kind in [TransportKind::InProc, TransportKind::Socket] {
        let plan = FaultPlan {
            seed: 2,
            joins: vec![JoinSpec { node: None, step: 3 }],
            ..FaultPlan::default()
        };
        let mut spec = faulty_spec(2, order, kind, plan);
        spec.spare_nodes = 1;
        let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
        assert_eq!(run.node_active(), &[true, true, false], "{kind}: spare starts inactive");
        run.run(dt, steps).unwrap();
        assert_eq!(run.node_active(), &[true, true, true], "{kind}: spare joined");
        let counts = run.node_counts();
        assert!(counts[2].0 + counts[2].1 > 0, "{kind}: join must shed elements: {counts:?}");
        let joins: Vec<_> = run
            .rebalance_history
            .iter()
            .filter(|r| r.cause == RebalanceCause::Join)
            .collect();
        assert_eq!(joins.len(), 1, "{kind}");
        assert!(joins[0].level1_migrated > 0, "{kind}");
        let got = run.gather_elements().unwrap();
        let diff = max_diff(&reference, &got);
        assert!(diff <= 1e-6, "{kind}: post-join field vs scalar oracle diff {diff}");
    }
}

/// A crash with no checkpoint surfaces a typed, recoverable=false path
/// fast (sentinel reply, no deadline involved) and never hangs.
#[test]
fn crash_without_checkpoint_is_fatal_but_fast() {
    let order = 2;
    let mesh = unit_cube_geometry(4);
    let dt = 1e-3;
    let plan = FaultPlan {
        seed: 3,
        kills: vec![KillSpec { node: 1, step: 2, mode: KillMode::Crash }],
        ..FaultPlan::default()
    };
    let spec = faulty_spec(2, order, TransportKind::Shm, plan);
    let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
    let t0 = Instant::now();
    run.run(dt, 6).expect_err("no checkpoint -> the kill is fatal");
    assert!(t0.elapsed() < Duration::from_secs(30));
    assert!(matches!(run.last_error(), Some(ClusterError::NodeFailure { .. })));
    assert!(!run.can_recover());
}

/// Teardown under poison across the transport matrix: a side thread
/// poisons the fabric mid-run (the permanent control flag, distinct from
/// the clearable recovery halt), the run surfaces an error instead of
/// hanging, further steps are refused, Drop joins every thread, and the
/// transport's resources are released (a fresh cluster on the same lane
/// kind must launch and run).
#[test]
fn teardown_under_poison_never_hangs() {
    let order = 2;
    let mesh = unit_cube_geometry(4);
    let dt = 1e-3;
    for kind in KINDS {
        for nodes in [2usize, 4] {
            let mut spec = ClusterSpec::new(nodes, order);
            spec.mic_fraction = Some(0.2);
            spec.transport = kind;
            let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
            let ctl = run.fabric_ctl();
            let killer = thread::spawn(move || {
                thread::sleep(Duration::from_millis(30));
                ctl.poison();
            });
            let res = run.run(dt, 500_000);
            killer.join().unwrap();
            assert!(res.is_err(), "{kind} P={nodes}: poisoned run must error");
            assert!(run.step(dt).is_err(), "{kind} P={nodes}: refuse to step when poisoned");
            drop(run); // joins all worker threads or the test times out

            // lane resources must be back: relaunch and take real steps
            let mut spec2 = ClusterSpec::new(nodes, order);
            spec2.mic_fraction = Some(0.2);
            spec2.transport = kind;
            let mut again = ClusterRun::launch(&mesh, &spec2, ic).unwrap();
            again.run(dt, 2).unwrap();
        }
    }
}

/// Seeded determinism: the same plan (message drops armed) produces a
/// bitwise-identical field; the drop pattern is a pure function of the
/// seed, never of thread timing.
#[test]
fn same_seed_same_field_under_message_drops() {
    let order = 2;
    let mesh = unit_cube_geometry(4);
    let dt = 1e-3;
    let steps = 4;
    let field = |seed: u64| {
        let plan = FaultPlan { seed, drop_prob: 0.3, ..FaultPlan::default() };
        let spec = faulty_spec(2, order, TransportKind::InProc, plan);
        let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
        run.run(dt, steps).unwrap();
        run.gather_elements().unwrap()
    };
    let a = field(9);
    let b = field(9);
    assert_eq!(a.len(), b.len());
    for (ea, eb) in a.iter().zip(&b) {
        for (&x, &y) in ea.iter().zip(eb) {
            assert_eq!(x.to_bits(), y.to_bits(), "same seed must be bitwise identical");
        }
    }
}

/// The spec parser behind `--kill-node` / `--join-node`.
#[test]
fn fault_specs_parse_from_cli_syntax() {
    let k: KillSpec = "1@5".parse().unwrap();
    assert_eq!(k, KillSpec { node: 1, step: 5, mode: KillMode::Crash });
    let k: KillSpec = "0@9:silent".parse().unwrap();
    assert_eq!(k.mode, KillMode::Silent);
    let k: KillSpec = "2@4:stall".parse().unwrap();
    assert_eq!(k.mode, KillMode::Stall);
    assert!("nope".parse::<KillSpec>().is_err());
    let j: JoinSpec = "@3".parse().unwrap();
    assert_eq!(j, JoinSpec { node: None, step: 3 });
    let j: JoinSpec = "4@3".parse().unwrap();
    assert_eq!(j, JoinSpec { node: Some(4), step: 3 });
}
