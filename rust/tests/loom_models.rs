//! Loom model suite: exhaustively explores thread interleavings of the
//! crate's hand-rolled synchronization under the `loom` stand-in crate
//! (bounded-preemption DFS over real threads; see CORRECTNESS.md).
//!
//! Built ONLY when the `loom` cfg is active:
//!
//! ```sh
//! RUSTFLAGS="--cfg loom" cargo test --test loom_models --release
//! ```
//!
//! Under a plain `cargo test` this file compiles to an empty (passing)
//! test binary, so the tier-1 suite is unaffected.
//!
//! Every model uses *bounded* loops only: the explorer's default schedule
//! keeps running the current thread, so an unbounded spin would never
//! terminate. Blocking primitives (`Mutex`, `Condvar`) are fine — the
//! scheduler parks and reschedules them, and a lost wakeup surfaces as a
//! detected deadlock.
#![cfg(loom)]

use loom::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use loom::sync::{Arc, Condvar, Mutex};
use loom::thread;

use repro::util::pool::{PhaseBarrier, SlotLedger};
use repro::util::shm::slot_ring;

/// SPSC ring: a producer pushes two records while a consumer races to
/// pop them. Checks FIFO order, no duplication, no loss, across every
/// interleaving of the Release/Acquire head/tail protocol (modeled as
/// SeqCst by the stand-in — see CORRECTNESS.md for what that proves).
#[test]
fn spsc_ring_push_pop_pair() {
    loom::model(|| {
        // Capacity floor is 4 slots, we push 2: try_push can never
        // report full, so the producer needs no retry loop.
        let (mut tx, mut rx) = slot_ring(2, 2);

        let producer = thread::spawn(move || {
            assert_eq!(tx.try_push(1, 10, &[1.0]), Ok(true));
            assert_eq!(tx.try_push(2, 20, &[2.0]), Ok(true));
            tx // keep the producer alive until joined (Drop closes)
        });

        let consumer = thread::spawn(move || {
            let mut got: Vec<(u32, u32, f32)> = Vec::new();
            // Bounded attempts; whatever is left is drained after join.
            for _ in 0..4 {
                if let Some(rec) = rx.try_pop_with(|w0, w1, p| (w0, w1, p[0])) {
                    got.push(rec);
                }
                thread::yield_now();
            }
            (got, rx)
        });

        let _tx = producer.join().unwrap();
        let (mut got, mut rx) = consumer.join().unwrap();
        // Producer finished and is joined: both records are published,
        // so a final drain must observe everything not yet popped.
        while let Some(rec) = rx.try_pop_with(|w0, w1, p| (w0, w1, p[0])) {
            got.push(rec);
        }
        assert_eq!(
            got,
            vec![(1, 10, 1.0), (2, 20, 2.0)],
            "SPSC ring lost, duplicated, or reordered a record"
        );
    });
}

/// PhaseBarrier sense reversal: two participants cross the barrier for
/// two consecutive generations. The explorer covers the late-arrival
/// case — one participant re-enters `wait()` for generation g+1 while
/// the other has not yet woken from generation g — which is exactly the
/// state a naive `arrived == 0` barrier corrupts.
#[test]
fn phase_barrier_sense_reversal() {
    loom::model(|| {
        let barrier = Arc::new(PhaseBarrier::new(2));
        let a = Arc::new(AtomicUsize::new(0));
        let b = Arc::new(AtomicUsize::new(0));

        let t = {
            let barrier = Arc::clone(&barrier);
            let a = Arc::clone(&a);
            let b = Arc::clone(&b);
            thread::spawn(move || {
                a.store(1, Ordering::SeqCst);
                barrier.wait(); // generation 0
                barrier.wait(); // generation 1 (possibly arriving early)
                assert_eq!(b.load(Ordering::SeqCst), 1, "gen-1 publication lost");
            })
        };

        barrier.wait(); // generation 0
        assert_eq!(a.load(Ordering::SeqCst), 1, "gen-0 publication lost");
        b.store(1, Ordering::SeqCst);
        barrier.wait(); // generation 1
        t.join().unwrap();
    });
}

/// SlotLedger: disjoint slices may be held concurrently; overlapping
/// claims are mutually exclusive; every slot is free once all holders
/// release. Mirrors two `PoolSlice` dispatchers racing a full-pool
/// dispatcher for the same OS workers.
#[test]
fn slot_ledger_disjoint_dispatch() {
    loom::model(|| {
        let ledger = Arc::new(SlotLedger::new(2));
        let in0 = Arc::new(AtomicBool::new(false));
        let in1 = Arc::new(AtomicBool::new(false));

        // Dispatcher A: slice [0, 1).
        let ta = {
            let ledger = Arc::clone(&ledger);
            let in0 = Arc::clone(&in0);
            thread::spawn(move || {
                ledger.acquire(0, 1);
                assert!(!in0.swap(true, Ordering::SeqCst), "slot 0 double-claimed");
                in0.store(false, Ordering::SeqCst);
                ledger.release(0);
            })
        };
        // Dispatcher B: slice [1, 2) — disjoint from A, may overlap in time.
        let tb = {
            let ledger = Arc::clone(&ledger);
            let in1 = Arc::clone(&in1);
            thread::spawn(move || {
                ledger.acquire(1, 1);
                assert!(!in1.swap(true, Ordering::SeqCst), "slot 1 double-claimed");
                in1.store(false, Ordering::SeqCst);
                ledger.release(1);
            })
        };

        // Full-pool dispatcher: claims both slots all-or-nothing, so it
        // must be mutually exclusive with A and B individually.
        ledger.acquire(0, 2);
        assert!(!in0.swap(true, Ordering::SeqCst), "slot 0 claimed while held");
        assert!(!in1.swap(true, Ordering::SeqCst), "slot 1 claimed while held");
        in0.store(false, Ordering::SeqCst);
        in1.store(false, Ordering::SeqCst);
        ledger.release(0);
        ledger.release(1);

        ta.join().unwrap();
        tb.join().unwrap();
        assert_eq!(
            ledger.busy_snapshot(),
            vec![false, false],
            "ledger leaked a busy flag"
        );
    });
}

/// Poison vs blocked recv: a consumer parked in `Condvar::wait` on an
/// empty queue must be woken by a poisoner that sets the halt flag and
/// notifies — the FabricCtl teardown shape. A lost wakeup here is a
/// hung worker at shutdown; the explorer reports it as a deadlock.
#[test]
fn poison_wakes_blocked_recv() {
    loom::model(|| {
        let queue = Arc::new(Mutex::new(Vec::<u32>::new()));
        let ready = Arc::new(Condvar::new());
        let poison = Arc::new(AtomicBool::new(false));

        #[derive(Debug, PartialEq)]
        enum Outcome {
            Got(u32),
            Poisoned,
        }

        let consumer = {
            let queue = Arc::clone(&queue);
            let ready = Arc::clone(&ready);
            let poison = Arc::clone(&poison);
            thread::spawn(move || {
                let mut q = queue.lock().unwrap();
                loop {
                    if let Some(v) = q.pop() {
                        return Outcome::Got(v);
                    }
                    // Check poison only after draining: published records
                    // stay deliverable through teardown (fabric contract).
                    if poison.load(Ordering::SeqCst) {
                        return Outcome::Poisoned;
                    }
                    // Bounded: each iteration consumes one notification,
                    // and the two peers below notify finitely often.
                    q = ready.wait(q).unwrap();
                }
            })
        };

        let sender = {
            let queue = Arc::clone(&queue);
            let ready = Arc::clone(&ready);
            thread::spawn(move || {
                queue.lock().unwrap().push(7);
                ready.notify_all();
            })
        };

        // Poisoner (the main model thread): set the flag, then lock and
        // notify so the store cannot land between the consumer's empty
        // check and its wait (the classic lost-wakeup window).
        poison.store(true, Ordering::SeqCst);
        drop(queue.lock().unwrap());
        ready.notify_all();

        sender.join().unwrap();
        let out = consumer.join().unwrap();
        assert!(
            out == Outcome::Got(7) || out == Outcome::Poisoned,
            "recv terminated with neither a record nor the poison marker: {out:?}"
        );
    });
}

/// Polling-teardown variant: the Unix-lane receiver polls with a
/// timeout instead of blocking, re-checking the halt flag between
/// polls. Models that a bounded polling loop (a) never deadlocks and
/// (b) the halt flag published by the poisoner is visible to a poll
/// that happens-after the poisoner finished.
#[test]
fn poison_visible_to_polling_recv() {
    loom::model(|| {
        let poison = Arc::new(AtomicBool::new(false));

        let poller = {
            let poison = Arc::clone(&poison);
            thread::spawn(move || {
                let mut saw = false;
                for _ in 0..3 {
                    if poison.load(Ordering::SeqCst) {
                        saw = true;
                        break;
                    }
                    thread::yield_now(); // recv_timeout elapsed, poll again
                }
                saw
            })
        };

        poison.store(true, Ordering::SeqCst);
        let saw_inside = poller.join().unwrap();
        // The bounded poll may or may not have observed the store while
        // racing, but after the join edge it must be visible here.
        assert!(poison.load(Ordering::SeqCst));
        let _ = saw_inside;
    });
}
