//! Integration: the AOT artifact executed through PJRT must match the
//! pure-rust reference stage to f32 tolerance, block by block, through
//! full multi-step heterogeneous runs. Skips (with a notice) when
//! artifacts are not built.

use repro::coordinator::node::WorkerBackend;
use repro::coordinator::HeteroRun;
use repro::mesh::{build_local_blocks, geometry::unit_cube_geometry};
use repro::partition::{nested_partition, splice, DeviceKind};
use repro::runtime::{ArtifactManifest, PjrtRuntime};
use repro::solver::analytic::standing_wave;
use repro::solver::driver::RustRefBackend;
use repro::solver::reference::RefScratch;
use repro::solver::rk::{LSRK_A, LSRK_B, N_STAGES};
use repro::solver::{BlockState, LglBasis, StageBackend};

fn artifacts_available() -> Option<std::path::PathBuf> {
    let dir = ArtifactManifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

/// A single all-mirror block: stage through PJRT vs the rust reference,
/// for EVERY order shipped in the artifact set.
#[test]
fn single_block_stage_matches_reference() {
    let Some(dir) = artifacts_available() else { return };
    let mut rt = PjrtRuntime::new(&dir).unwrap();
    for order in rt.manifest.orders() {
        let basis = LglBasis::new(order);

        let mesh = unit_cube_geometry(2);
        let owners = vec![0usize; mesh.len()];
        let (lblocks, _) = build_local_blocks(&mesh, &owners, 1);
        let meta = rt.manifest.pick_stage(order, 8, 1).unwrap();
        let (kb, hb) = (meta.k, meta.halo);

        let mut st_pjrt = BlockState::from_local_block(&lblocks[0], order, kb, hb);
        let w = std::f64::consts::PI * 3f64.sqrt();
        st_pjrt.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
        let mut st_ref = st_pjrt.clone();

        let mut pjrt = rt.stage_backend(&st_pjrt).unwrap();
        let mut rref = RustRefBackend::new(order);
        let dt = 1e-3f32;
        for s in 0..N_STAGES {
            pjrt.stage(&mut st_pjrt, dt, LSRK_A[s] as f32, LSRK_B[s] as f32).unwrap();
            rref.stage(&mut st_ref, dt, LSRK_A[s] as f32, LSRK_B[s] as f32).unwrap();
        }
        let max_q = max_diff(&st_pjrt.q[..live(&st_pjrt)], &st_ref.q[..live(&st_ref)]);
        assert!(max_q < 5e-5, "order {order}: q diff after 5 stages: {max_q}");
        let max_tr = max_diff(&st_pjrt.traces, &st_ref.traces);
        assert!(max_tr < 5e-5, "order {order}: trace diff: {max_tr}");
    }
}

fn live(st: &BlockState) -> usize {
    st.k_real * 9 * st.m * st.m * st.m
}

fn max_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Full heterogeneous run (CPU worker + MIC worker, PJRT backend) vs the
/// same run on the rust reference backend: identical physics.
#[test]
fn hetero_run_pjrt_matches_rust_ref() {
    let Some(dir) = artifacts_available() else { return };
    let rt = PjrtRuntime::new(&dir).unwrap();
    let order = *rt.manifest.orders().first().unwrap();
    drop(rt);

    let energies: Vec<(f64, f64)> = [
        WorkerBackend::Pjrt { artifact_dir: dir.clone() },
        WorkerBackend::RustRef,
    ]
    .into_iter()
    .map(|backend| run_once(order, backend, &dir))
    .collect();
    let (e0_p, e1_p) = energies[0];
    let (e0_r, e1_r) = energies[1];
    assert!((e0_p - e0_r).abs() < 1e-9 * e0_r.abs().max(1.0), "initial energies differ");
    let rel = (e1_p - e1_r).abs() / e1_r.abs().max(1e-12);
    assert!(rel < 1e-4, "final energies diverge: pjrt {e1_p} ref {e1_r}");
    // physics: dissipative but conservative to ~0.5%
    assert!(e1_p <= e0_p * (1.0 + 1e-6));
    assert!(e1_p > 0.99 * e0_p);
}

fn run_once(
    order: usize,
    backend: WorkerBackend,
    dir: &std::path::Path,
) -> (f64, f64) {
    let mesh = unit_cube_geometry(2);
    let node_part = splice(&mesh, 1);
    let np = nested_partition(&mesh, &node_part, 0.5);
    let owners = np.owners();
    let (lblocks, plan) = build_local_blocks(&mesh, &owners, np.n_owners());
    let manifest = ArtifactManifest::load(dir).unwrap();
    let basis = LglBasis::new(order);
    let w = std::f64::consts::PI * 3f64.sqrt();
    let mut states = Vec::new();
    let mut devices = Vec::new();
    for lb in &lblocks {
        let meta = manifest.pick_stage(order, lb.len().max(1), lb.halo_len.max(1)).unwrap();
        let mut st = BlockState::from_local_block(lb, order, meta.k, meta.halo);
        st.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
        states.push(st);
        devices.push(if lb.owner % 2 == 0 { DeviceKind::Cpu } else { DeviceKind::Mic });
    }
    let mut run =
        HeteroRun::launch(&lblocks, states, plan, &devices, backend, order).unwrap();
    let e0 = run.energy().unwrap();
    run.run(2e-3, 10).unwrap();
    let e1 = run.energy().unwrap();
    (e0, e1)
}

/// The RefScratch shape-bucket reuse must not leak state across blocks.
#[test]
fn reference_scratch_isolated_between_blocks() {
    let order = 2;
    let basis = LglBasis::new(order);
    let mesh = unit_cube_geometry(2);
    let owners = vec![0usize; mesh.len()];
    let (lblocks, _) = build_local_blocks(&mesh, &owners, 1);
    let mut st1 = BlockState::from_local_block(&lblocks[0], order, 8, 8);
    let w = std::f64::consts::PI * 3f64.sqrt();
    st1.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
    let mut st2 = st1.clone();
    let mut scratch = RefScratch::new(&st1);
    // interleave two identical blocks through one scratch: identical results
    repro::solver::reference::stage(&mut st1, &basis, &mut scratch, 1e-3, 0.0, 1.0);
    let mut scratch2 = RefScratch::new(&st2);
    repro::solver::reference::stage(&mut st2, &basis, &mut scratch2, 1e-3, 0.0, 1.0);
    assert_eq!(st1.q, st2.q);
}
