//! Integration tests on the simulator + experiment drivers: the paper's
//! quantitative *shapes* must hold (speedup bands, crossover location,
//! profile ordering, traffic asymptotics).

use repro::coordinator::experiments;
use repro::costmodel::calib::{
    stampede_node, PAPER_ELEMS_PER_NODE, PAPER_MIC_RATIO, PAPER_ORDER,
};
use repro::partition::solve_mic_fraction;
use repro::sim::{simulate, Cluster, Scheme};

/// Table 6.1's headline: single-node speedup in the 6-7x band.
#[test]
fn single_node_speedup_band() {
    let mesh = experiments::paper_mesh(1, PAPER_ELEMS_PER_NODE);
    let c = Cluster::stampede(1);
    let base = simulate(&c, &mesh, PAPER_ORDER, 10, Scheme::BaselineMpi { ranks_per_node: 8 });
    let nest = simulate(&c, &mesh, PAPER_ORDER, 10, Scheme::Nested { mic_fraction: None });
    let speedup = base.wall_s / nest.wall_s;
    assert!(
        (5.3..7.5).contains(&speedup),
        "paper: 6.3x; simulated {speedup:.2}x"
    );
}

/// Scale-up shape: the speedup *drops* from 1 to 64 nodes (6.3 -> 5.6).
#[test]
fn speedup_drops_at_scale() {
    let c1 = Cluster::stampede(1);
    let m1 = experiments::paper_mesh(1, PAPER_ELEMS_PER_NODE);
    let base1 = simulate(&c1, &m1, PAPER_ORDER, 5, Scheme::BaselineMpi { ranks_per_node: 8 });
    let nest1 = simulate(&c1, &m1, PAPER_ORDER, 5, Scheme::Nested { mic_fraction: None });
    let s1 = base1.wall_s / nest1.wall_s;

    let c64 = Cluster::stampede(64);
    let m64 = experiments::paper_mesh(64, PAPER_ELEMS_PER_NODE);
    let base64 = simulate(&c64, &m64, PAPER_ORDER, 5, Scheme::BaselineMpi { ranks_per_node: 8 });
    let nest64 = simulate(&c64, &m64, PAPER_ORDER, 5, Scheme::Nested { mic_fraction: None });
    let s64 = base64.wall_s / nest64.wall_s;

    assert!(s64 < s1, "speedup must drop at scale: {s1:.2} -> {s64:.2}");
    assert!((4.8..6.6).contains(&s64), "paper: 5.6x at 64 nodes; got {s64:.2}");
    // absolute walls in the right neighborhood at paper steps (118):
    let scale = 118.0 / 5.0;
    let b64 = base64.wall_s * scale;
    assert!((300.0..550.0).contains(&b64), "baseline 64-node ~413 s, got {b64:.0}");
}

/// The balance solve lands near the paper's 1.6 ratio.
#[test]
fn mic_ratio_matches_paper() {
    let sol = solve_mic_fraction(&stampede_node(), PAPER_ORDER, PAPER_ELEMS_PER_NODE);
    assert!(
        (sol.ratio - PAPER_MIC_RATIO).abs() < 0.25,
        "K_MIC/K_CPU {:.2} vs paper {PAPER_MIC_RATIO}",
        sol.ratio
    );
}

/// Task-offload loses to nested at the paper's size — and the gap is the
/// PCI traffic asymmetry (paper §5.5's core argument).
#[test]
fn task_offload_pci_dominated() {
    let mesh = experiments::paper_mesh(1, PAPER_ELEMS_PER_NODE);
    let c = Cluster::stampede(1);
    let off = simulate(&c, &mesh, PAPER_ORDER, 5, Scheme::TaskOffload);
    let nest = simulate(&c, &mesh, PAPER_ORDER, 5, Scheme::Nested { mic_fraction: None });
    assert!(off.wall_s > 1.15 * nest.wall_s, "off {} nest {}", off.wall_s, nest.wall_s);
}

/// Fig 4.1 ordering: volume_loop > int_flux > each of the others.
#[test]
fn baseline_profile_ordering() {
    let mesh = experiments::paper_mesh(1, PAPER_ELEMS_PER_NODE);
    let c = Cluster::stampede(1);
    let rep = simulate(&c, &mesh, PAPER_ORDER, 3, Scheme::BaselineMpi { ranks_per_node: 8 });
    let fr = rep.breakdown.fractions();
    assert_eq!(fr[0].0, "volume_loop");
    assert_eq!(fr[1].0, "int_flux");
    assert!(fr[0].1 > 0.4 && fr[0].1 < 0.75, "volume share {}", fr[0].1);
}

/// Fig 5.2: the sweep's crossover equals the solver's optimum.
#[test]
fn sweep_crossover_consistent_with_solver() {
    let node = stampede_node();
    let rows =
        repro::partition::balance::sweep_fractions(&node, PAPER_ORDER, PAPER_ELEMS_PER_NODE, 200);
    let sol = solve_mic_fraction(&node, PAPER_ORDER, PAPER_ELEMS_PER_NODE);
    // find the sweep crossing
    let mut crossing = None;
    for w in rows.windows(2) {
        let (f0, tc0, tm0) = w[0];
        let (f1, _, _) = w[1];
        let (_, tc1, tm1) = w[1];
        if (tm0 - tc0).signum() != (tm1 - tc1).signum() {
            crossing = Some(0.5 * (f0 + f1));
            break;
        }
    }
    let crossing = crossing.expect("sweep must cross");
    let sol_frac = sol.k_mic as f64 / PAPER_ELEMS_PER_NODE as f64;
    assert!(
        (crossing - sol_frac).abs() < 0.02,
        "sweep {crossing:.3} vs solver {sol_frac:.3}"
    );
}

/// Fig 5.3 shape: latency floor at small sizes, linear growth at large.
#[test]
fn pci_curve_shape() {
    let pci = repro::costmodel::calib::stampede_pci();
    use repro::costmodel::pci::Direction::ToDevice;
    let t1 = pci.transfer_time(1 << 20, ToDevice);
    let t4096 = pci.transfer_time(4096 << 20, ToDevice);
    // 4096x the bytes must NOT cost 4096x (latency floor) but must cost
    // >1000x (bandwidth regime reached)
    assert!(t4096 / t1 > 1000.0);
    assert!(t4096 / t1 < 4096.0);
}

/// Every experiment driver runs end to end and emits its CSV.
#[test]
fn experiment_drivers_produce_output() {
    let dir = std::env::temp_dir().join(format!("repro_exp_{}", std::process::id()));
    let csv = |n: &str| dir.join(n).to_str().unwrap().to_string();
    let t = experiments::fig5_2(Some(&csv("f52.csv"))).unwrap();
    assert!(t.contains("crossover"));
    let t = experiments::fig5_3(Some(&csv("f53.csv")), 8).unwrap();
    assert!(t.contains("to_mic"));
    let t = experiments::fig5_4(Some(&csv("f54.csv"))).unwrap();
    assert!(t.contains("mid-plane"));
    let t = experiments::fig6_2(Some(&csv("f62.csv"))).unwrap();
    assert!(t.contains("volume_loop"));
    let t = experiments::table6_1(Some(&csv("t61.csv")), 4).unwrap();
    assert!(t.contains("speedup"));
    for f in ["f52.csv", "f53.csv", "f54.csv", "f62.csv", "t61.csv"] {
        assert!(dir.join(f).exists(), "{f} missing");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Nested wall time is monotone in the MIC fraction error: the balanced
/// fraction beats both 0 (idle MIC) and the max-interior fraction when
/// over-committed... at minimum it must beat fraction 0.
#[test]
fn balanced_fraction_beats_cpu_only() {
    let mesh = experiments::paper_mesh(1, PAPER_ELEMS_PER_NODE);
    let c = Cluster::stampede(1);
    let balanced = simulate(&c, &mesh, PAPER_ORDER, 3, Scheme::Nested { mic_fraction: None });
    let cpu_only = simulate(&c, &mesh, PAPER_ORDER, 3, Scheme::Nested { mic_fraction: Some(0.0) });
    assert!(balanced.wall_s < 0.6 * cpu_only.wall_s);
}
