//! SIMD/scalar kernel-equivalence property tests.
//!
//! The `solver::simd` vector paths are contracted to reproduce the scalar
//! kernels *bitwise* (identical operand association, no FMA; the only
//! permitted difference is the sign of zero, which `f32::eq` ignores).
//! One deliberate exception: on `simd-fma` builds whose host reports FMA,
//! the W8 kernels may contract multiply-adds, and the gate widens from
//! bitwise to 1e-6 relative on exactly that leg
//! (`simd::fma_possible`) — SSE2 and scalar stay bitwise everywhere.
//! These tests enforce the contract end to end at the stage level and
//! directly on the Riemann face kernels, sweeping
//!
//! * orders {2, 3, 7} (m = 3 / 4 / 8 — covers unpadded SIMD tails: face
//!   sizes 9 and vol sizes 27 are not lane multiples),
//! * lane widths {scalar, 4, 8} via `simd::set_forced` (widths the host
//!   cannot execute are skipped — `set_forced` clamps and reports),
//! * block sizes {27, 64, 512} elements.
//!
//! The forced lane width is process-global, so every test serializes on
//! one lock and restores auto-detection before returning.

use std::sync::Mutex;

use repro::mesh::geometry::{discontinuous_brick, unit_cube_geometry};
use repro::mesh::{build_local_blocks, Mesh};
use repro::solver::analytic::standing_wave;
use repro::solver::driver::{Driver, StageBackend};
use repro::solver::reference::{riemann_face, riemann_face_mirror, stage, RefScratch};
use repro::solver::simd::{self, Lanes};
use repro::solver::{BlockState, LglBasis, ParallelRefBackend, LSRK_A, LSRK_B, N_STAGES};

/// Serializes the tests of this binary (the forced lane width is global).
static LANE_LOCK: Mutex<()> = Mutex::new(());

const LANE_SWEEP: [Lanes; 3] = [Lanes::Scalar, Lanes::W4, Lanes::W8];

/// Restores lane auto-detection when dropped (also on assertion panic, so
/// one failing test doesn't poison the rest of the binary).
struct LaneGuard;

impl Drop for LaneGuard {
    fn drop(&mut self) {
        simd::set_forced(None);
    }
}

/// Force `lanes`; `None` if this host cannot execute that width.
fn force(lanes: Lanes) -> Option<Lanes> {
    (simd::set_forced(Some(lanes)) == lanes).then_some(lanes)
}

/// Bitwise, unless `lanes` may FMA-contract in this build on this host —
/// then a 1e-6 relative gate (the `simd-fma` exception above).
fn assert_lane_eq(got: &[f32], want: &[f32], lanes: Lanes, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}");
    if simd::fma_possible(lanes) {
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            assert!(
                (g - w).abs() <= 1e-6 * w.abs().max(1.0),
                "{ctx}: [{i}] {g} vs {w}"
            );
        }
    } else {
        assert!(got == want, "{ctx}");
    }
}

/// Deterministic non-trivial filler in [-1, 1), varied per slot.
fn filler(i: usize, salt: usize) -> f32 {
    (((i * 31 + salt * 97 + 7) % 256) as f32) / 128.0 - 1.0
}

fn single_block_state(order: usize, n: usize) -> BlockState {
    let mesh = unit_cube_geometry(n);
    let owners = vec![0usize; mesh.len()];
    let (blocks, _) = build_local_blocks(&mesh, &owners, 1);
    let k = blocks[0].len();
    let mut st = BlockState::from_local_block(&blocks[0], order, k, 8);
    let basis = LglBasis::new(order);
    let w = std::f64::consts::PI * 3f64.sqrt();
    st.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
    st
}

/// Run `stages` low-storage RK stages of the scalar reference backend on
/// a fresh copy of `st0` under the given forced lane width.
fn run_ref_stages(st0: &BlockState, basis: &LglBasis, stages: usize, lanes: Lanes) -> BlockState {
    let eff = simd::set_forced(Some(lanes));
    assert_eq!(eff, lanes, "caller checked capability");
    let mut st = st0.clone();
    let mut scratch = RefScratch::new(&st);
    for s in 0..stages {
        let (a, b) = (LSRK_A[s % N_STAGES] as f32, LSRK_B[s % N_STAGES] as f32);
        stage(&mut st, basis, &mut scratch, 1e-3, a, b);
    }
    st
}

#[test]
fn reference_stage_equal_across_lane_widths() {
    let _lock = LANE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _guard = LaneGuard;
    for order in [2usize, 3, 7] {
        for n in [3usize, 4, 8] {
            // full RK sweep on the small grids, one stage on the big ones
            let stages = if n >= 8 || order >= 7 { 1 } else { N_STAGES };
            let st0 = single_block_state(order, n);
            assert_eq!(st0.k_real, n * n * n);
            let basis = LglBasis::new(order);
            let base = run_ref_stages(&st0, &basis, stages, Lanes::Scalar);
            for lanes in [Lanes::W4, Lanes::W8] {
                let Some(lanes) = force(lanes) else { continue };
                let got = run_ref_stages(&st0, &basis, stages, lanes);
                let ctx = format!("order {order} k {} {lanes:?}", st0.k_real);
                assert_lane_eq(&got.q, &base.q, lanes, &format!("q: {ctx}"));
                assert_lane_eq(&got.res, &base.res, lanes, &format!("res: {ctx}"));
                assert_lane_eq(&got.traces, &base.traces, lanes, &format!("traces: {ctx}"));
            }
        }
    }
}

fn overlap_driver(mesh: &Mesh, owners: &[usize], order: usize) -> Driver {
    let (lblocks, plan) = build_local_blocks(mesh, owners, 2);
    let basis = LglBasis::new(order);
    let w = std::f64::consts::PI * 3f64.sqrt();
    let mut blocks: Vec<BlockState> = lblocks
        .iter()
        .map(|lb| BlockState::from_local_block(lb, order, lb.len().max(1), lb.halo_len.max(1)))
        .collect();
    for blk in blocks.iter_mut() {
        blk.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
    }
    let backends: Vec<Box<dyn StageBackend>> = (0..2)
        .map(|_| Box::new(ParallelRefBackend::with_threads(order, 2)) as Box<dyn StageBackend>)
        .collect();
    let mut drv = Driver::new(blocks, plan, backends, order);
    drv.overlap = true;
    drv.prime();
    drv
}

#[test]
fn parallel_overlap_stage_equal_across_lane_widths() {
    let _lock = LANE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _guard = LaneGuard;
    // mixed elastic/acoustic brick, two owners: exercises neighbor, halo
    // and mirror flux paths plus the masked interior trace refresh
    let mesh = discontinuous_brick([4, 4, 2], [1.0, 1.0, 0.5]);
    let owners: Vec<usize> = (0..mesh.len()).map(|e| usize::from(e >= 16)).collect();
    for order in [2usize, 3] {
        simd::set_forced(Some(Lanes::Scalar));
        let mut base = overlap_driver(&mesh, &owners, order);
        base.run(1e-3, 2).unwrap();
        for lanes in [Lanes::W4, Lanes::W8] {
            let Some(lanes) = force(lanes) else { continue };
            let mut got = overlap_driver(&mesh, &owners, order);
            got.run(1e-3, 2).unwrap();
            for (ba, bg) in base.blocks.iter().zip(&got.blocks) {
                let ctx = format!("order {order} {lanes:?}");
                assert_lane_eq(&bg.q, &ba.q, lanes, &ctx);
                let live = ba.k_real * 6 * repro::solver::state::NFIELDS * ba.m * ba.m;
                assert_lane_eq(&bg.traces[..live], &ba.traces[..live], lanes, &ctx);
            }
        }
    }
}

#[test]
fn riemann_face_kernels_equal_across_lane_widths() {
    let _lock = LANE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _guard = LaneGuard;
    let elastic = [1.0f32, 2.0, 1.0];
    let acoustic = [1.2f32, 3.0, 0.0];
    for m in [3usize, 4, 8] {
        let face = m * m;
        let tr_m: Vec<f32> = (0..9 * face).map(|i| filler(i, m)).collect();
        let tr_p: Vec<f32> = (0..9 * face).map(|i| filler(i, m + 13)).collect();
        for (matm, matp) in [(elastic, elastic), (elastic, acoustic), (acoustic, elastic)] {
            for axis in 0..3 {
                for sign in [1.0f32, -1.0] {
                    let mut want = vec![0.0f32; 9 * face];
                    let mut want_mir = vec![0.0f32; 9 * face];
                    simd::set_forced(Some(Lanes::Scalar));
                    riemann_face(&tr_m, &tr_p, matm, matp, axis, sign, face, &mut want);
                    riemann_face_mirror(&tr_m, matm, axis, sign, face, &mut want_mir);
                    for lanes in [Lanes::W4, Lanes::W8] {
                        let Some(lanes) = force(lanes) else { continue };
                        let mut got = vec![0.0f32; 9 * face];
                        riemann_face(&tr_m, &tr_p, matm, matp, axis, sign, face, &mut got);
                        assert_lane_eq(
                            &got,
                            &want,
                            lanes,
                            &format!("riemann_face m {m} axis {axis} sign {sign} {lanes:?}"),
                        );
                        let mut got_mir = vec![0.0f32; 9 * face];
                        riemann_face_mirror(&tr_m, matm, axis, sign, face, &mut got_mir);
                        assert_lane_eq(
                            &got_mir,
                            &want_mir,
                            lanes,
                            &format!("mirror m {m} axis {axis} {lanes:?}"),
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn lane_sweep_covers_detected_width() {
    let _lock = LANE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _guard = LaneGuard;
    // the sweep above must include the width this host actually runs at
    let cap = simd::detect();
    assert!(
        LANE_SWEEP.contains(&cap),
        "detected lane width {cap:?} missing from the sweep"
    );
}
