//! Cross-language regression: execute the AOT artifact on python-recorded
//! inputs and compare against the python jit outputs (artifacts/testvec_*).
//! This pins the HLO-text round trip + rust runtime against python truth,
//! independently of the rust reference implementation. Also cross-checks
//! the rust reference against the same vectors.

use repro::runtime::{ArtifactManifest, PjrtRuntime};
use repro::solver::driver::RustRefBackend;
use repro::solver::state::BlockState;
use repro::solver::StageBackend;
use repro::util::Json;

struct TestVec {
    order: usize,
    k: usize,
    halo: usize,
    arrays: Vec<(String, Vec<usize>, Vec<u8>)>,
}

fn load_testvec(dir: &std::path::Path, order: usize) -> Option<TestVec> {
    let base = dir.join(format!("testvec_n{order}"));
    let meta = std::fs::read_to_string(base.with_extension("json")).ok()?;
    let blob = std::fs::read(base.with_extension("bin")).ok()?;
    let j = Json::parse(&meta).ok()?;
    let mut arrays = Vec::new();
    for a in j.get("arrays")?.as_arr()? {
        let name = a.get("name")?.as_str()?.to_string();
        let shape: Vec<usize> =
            a.get("shape")?.as_arr()?.iter().filter_map(|x| x.as_usize()).collect();
        let off = a.get("offset")?.as_usize()?;
        let nb = a.get("nbytes")?.as_usize()?;
        arrays.push((name, shape, blob[off..off + nb].to_vec()));
    }
    Some(TestVec {
        order: j.get("order")?.as_usize()?,
        k: j.get("k")?.as_usize()?,
        halo: j.get("halo")?.as_usize()?,
        arrays,
    })
}

fn f32s(bytes: &[u8]) -> Vec<f32> {
    bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

fn i32s(bytes: &[u8]) -> Vec<i32> {
    bytes.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

fn state_from_vec(tv: &TestVec) -> BlockState {
    let get = |n: &str| &tv.arrays.iter().find(|(name, _, _)| name == n).unwrap().2;
    let m = tv.order + 1;
    BlockState {
        uid: BlockState::fresh_uid(),
        order: tv.order,
        m,
        k_real: tv.k,
        k_pad: tv.k,
        halo_real: tv.halo,
        halo_pad: tv.halo,
        q: f32s(get("q")),
        res: f32s(get("res")),
        traces: vec![0.0; tv.k * 6 * 9 * m * m],
        halo: f32s(get("halo")),
        conn: i32s(get("conn")),
        halo_idx: i32s(get("halo_idx")),
        mats: f32s(get("mats")),
        halo_mats: f32s(get("halo_mats")),
        h: f32s(get("h")),
        centers: vec![[0.0; 3]; tv.k],
    }
}

fn max_rel(a: &[f32], b: &[f32]) -> f32 {
    let scale = b.iter().map(|v| v.abs()).fold(0.0f32, f32::max).max(1e-6);
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max) / scale
}

#[test]
fn artifact_matches_python_jit_outputs() {
    let dir = ArtifactManifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let mut rt = PjrtRuntime::new(&dir).unwrap();
    let mut tested = 0;
    for order in rt.manifest.orders() {
        let Some(tv) = load_testvec(&dir, order) else { continue };
        let mut st = state_from_vec(&tv);
        let scal = f32s(&tv.arrays.iter().find(|(n, _, _)| n == "scal").unwrap().2);
        let mut backend = rt.stage_backend(&st).unwrap();
        backend.stage(&mut st, scal[0], scal[1], scal[2]).unwrap();
        for (out_name, field) in
            [("out_q", &st.q), ("out_res", &st.res), ("out_traces", &st.traces)]
        {
            let want = f32s(&tv.arrays.iter().find(|(n, _, _)| n == out_name).unwrap().2);
            let rel = max_rel(field, &want);
            assert!(
                rel < 2e-6,
                "order {order} {out_name}: max rel diff {rel} (HLO round trip broke)"
            );
        }
        tested += 1;
    }
    assert!(tested >= 3, "expected test vectors for at least 3 orders, ran {tested}");
}

#[test]
fn rust_reference_matches_python_jit_outputs() {
    let dir = ArtifactManifest::default_dir();
    let mut tested = 0;
    for order in [1usize, 2, 3, 7] {
        let Some(tv) = load_testvec(&dir, order) else { continue };
        let mut st = state_from_vec(&tv);
        st.refresh_traces(); // reference reads traces of the current q
        let scal = f32s(&tv.arrays.iter().find(|(n, _, _)| n == "scal").unwrap().2);
        let mut backend = RustRefBackend::new(order);
        backend.stage(&mut st, scal[0], scal[1], scal[2]).unwrap();
        for (out_name, field) in
            [("out_q", &st.q), ("out_res", &st.res), ("out_traces", &st.traces)]
        {
            let want = f32s(&tv.arrays.iter().find(|(n, _, _)| n == out_name).unwrap().2);
            let rel = max_rel(field, &want);
            assert!(
                rel < 5e-5,
                "order {order} {out_name}: max rel diff {rel} (rust reference diverges)"
            );
        }
        tested += 1;
    }
    if tested == 0 {
        eprintln!("SKIP: no test vectors present");
    }
}
