//! Transport-equivalence matrix for the cluster message fabric: the same
//! nested two-level run must produce bit-identical (≤1e-6) element state
//! on the in-process channel, shared-memory ring, and Unix-socket
//! transports for P ∈ {2, 4} virtual nodes — including adaptive mid-run
//! rebalancing, whose routing-table swap and element migration must work
//! across a live socket lane. The §5.5 refusal (no accelerator on the
//! inter-node lane) is classification, not mechanism, so every transport
//! must reject the same hand-built bad plan.

use repro::coordinator::cluster::{ClusterRun, ClusterSpec, WorkerSpec};
use repro::coordinator::{TransportKind, WorkerBackend};
use repro::mesh::{build_local_blocks, two_tree_geometry, unit_cube_geometry, Mesh};
use repro::partition::DeviceKind;
use repro::solver::analytic::standing_wave;
use repro::solver::driver::{Driver, RustRefBackend, StageBackend};
use repro::solver::{BlockState, LglBasis};

const KINDS: [TransportKind; 3] =
    [TransportKind::InProc, TransportKind::Shm, TransportKind::Socket];

fn ic(x: [f64; 3]) -> [f64; 9] {
    let w = std::f64::consts::PI * 3f64.sqrt();
    standing_wave(x, 0.0, 1.0, 1.0, w)
}

/// The oracle: one block, one scalar backend, the plain driver. Returns
/// per-element q in global Morton order.
fn scalar_reference(mesh: &Mesh, order: usize, dt: f64, steps: usize) -> Vec<Vec<f32>> {
    let owners = vec![0usize; mesh.len()];
    let (lblocks, plan) = build_local_blocks(mesh, &owners, 1);
    let basis = LglBasis::new(order);
    let mut st = BlockState::from_local_block(
        &lblocks[0],
        order,
        lblocks[0].len(),
        lblocks[0].halo_len.max(1),
    );
    st.set_initial_condition(&basis, ic);
    let backends: Vec<Box<dyn StageBackend>> = vec![Box::new(RustRefBackend::new(order))];
    let mut drv = Driver::new(vec![st], plan, backends, order);
    drv.prime();
    drv.run(dt, steps).unwrap();
    let m = order + 1;
    let esz = 9 * m * m * m;
    let st = &drv.blocks[0];
    (0..mesh.len()).map(|e| st.q[e * esz..(e + 1) * esz].to_vec()).collect()
}

fn max_diff(a: &[Vec<f32>], b: &[Vec<f32>]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut worst = 0.0f32;
    for (ea, eb) in a.iter().zip(b) {
        assert_eq!(ea.len(), eb.len());
        for (&x, &y) in ea.iter().zip(eb) {
            worst = worst.max((x - y).abs());
        }
    }
    worst
}

/// The matrix itself: P ∈ {2, 4} × {inproc, shm, socket} on the mixed
/// elastic/acoustic mesh, every cell within 1e-6 of the scalar oracle,
/// with identical lane classification on every transport.
#[test]
fn transport_matrix_matches_scalar_p_2_4() {
    let order = 2;
    let mesh = two_tree_geometry(3); // 54 elements, acoustic + elastic trees
    let dt = 2.5e-4;
    let steps = 4;
    let reference = scalar_reference(&mesh, order, dt, steps);
    for nodes in [2usize, 4] {
        let mut classified = None;
        for kind in KINDS {
            let mut spec = ClusterSpec::new(nodes, order);
            spec.mic_fraction = Some(0.3);
            spec.transport = kind;
            let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
            assert_eq!(run.transport(), kind);
            run.run(dt, steps).unwrap();
            let got = run.gather_elements().unwrap();
            let diff = max_diff(&reference, &got);
            assert!(diff <= 1e-6, "P={nodes} {kind}: cluster vs scalar diff {diff}");
            // classification comes from the routing tables, not the
            // mechanism: identical counts on every transport, §5.5 upheld
            let f = run.fabric();
            assert!(f.inter_node_faces > 0, "P={nodes} {kind}: {f:?}");
            assert_eq!(f.mic_inter_node_faces, 0, "P={nodes} {kind}: {f:?}");
            let lanes = (f.self_faces, f.intra_node_faces, f.inter_node_faces);
            match classified {
                None => classified = Some(lanes),
                Some(c) => assert_eq!(c, lanes, "P={nodes} {kind}: lane classes diverged"),
            }
        }
    }
}

/// Adaptive mid-run rebalancing on every transport: elements must migrate
/// (the split starts deliberately starved) and the final state must still
/// match the oracle — on the socket transport the migrated blocks and the
/// swapped routing tables cross a live kernel socket.
#[test]
fn adaptive_rebalance_matches_on_every_transport() {
    let order = 2;
    let mesh = unit_cube_geometry(4); // 64 elements
    let dt = 1e-3;
    let steps = 6;
    let reference = scalar_reference(&mesh, order, dt, steps);
    for kind in KINDS {
        let mut spec = ClusterSpec::new(2, order);
        spec.mic_fraction = Some(0.1);
        spec.rebalance_every = Some(2);
        spec.transport = kind;
        let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
        run.run(dt, steps).unwrap();
        let migrated: usize = run.rebalance_history.iter().map(|r| r.migrated_elems()).sum();
        assert!(migrated > 0, "{kind}: the starved split must trigger migration");
        let got = run.gather_elements().unwrap();
        let diff = max_diff(&reference, &got);
        assert!(diff <= 1e-6, "{kind} adaptive: cluster vs scalar diff {diff}");
    }
}

/// Level-1 (across-node) migration over the socket lane: a throttled node
/// sheds elements to its peer across the inter-node socket, the kept
/// workers keep their connections through the routing-table swap, and the
/// run stays bit-compatible afterwards.
#[test]
fn level1_migration_crosses_the_socket_lane() {
    let order = 2;
    let mesh = unit_cube_geometry(6); // 216 elements
    let dt = 1e-3;
    let mut spec = ClusterSpec::new(2, order);
    spec.mic_fraction = Some(0.2);
    let mut backends = vec![(WorkerBackend::RustRef, WorkerBackend::RustRef); 2];
    backends[1] = (
        WorkerBackend::Throttled { spin_us_per_elem: 30 },
        WorkerBackend::Throttled { spin_us_per_elem: 30 },
    );
    spec.node_backends = Some(backends);
    spec.transport = TransportKind::Socket;
    let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
    run.run(dt, 2).unwrap();
    for _ in 0..2 {
        run.rebalance().unwrap();
        run.run(dt, 2).unwrap();
    }
    let l1: usize = run.rebalance_history.iter().map(|r| r.level1_migrated).sum();
    assert!(l1 > 0, "level-1 elements must cross the node boundary");
    let sizes = run.node_partition().unwrap().sizes();
    assert!(sizes[1] < mesh.len() / 2, "throttled node must shed: {sizes:?}");
    // 2 static + 2x2 rebalanced = 6 steps, all priced through the socket
    let reference = scalar_reference(&mesh, order, dt, 6);
    let got = run.gather_elements().unwrap();
    let diff = max_diff(&reference, &got);
    assert!(diff <= 1e-6, "post-socket-migration diff {diff}");
}

/// §5.5 enforcement is transport-independent: the hand-built plan that
/// puts two accelerator workers of different nodes in contact is refused
/// at launch with the same error on all three transports.
#[test]
fn inter_node_mic_traffic_refused_on_every_transport() {
    let order = 1;
    let mesh = unit_cube_geometry(2); // 8 elements, morton halves touch
    for kind in KINDS {
        let owners: Vec<usize> = (0..mesh.len()).map(|e| if e < 4 { 1 } else { 3 }).collect();
        let (lblocks, plan) = build_local_blocks(&mesh, &owners, 4);
        let basis = LglBasis::new(order);
        let states: Vec<BlockState> = lblocks
            .iter()
            .map(|lb| {
                let mut st =
                    BlockState::from_local_block(lb, order, lb.len().max(1), lb.halo_len.max(1));
                st.set_initial_condition(&basis, ic);
                st
            })
            .collect();
        let specs: Vec<WorkerSpec> = (0..4)
            .map(|w| WorkerSpec {
                node: w / 2,
                device: if w % 2 == 0 { DeviceKind::Cpu } else { DeviceKind::Mic },
                backend: WorkerBackend::RustRef,
                name: format!("w{w}"),
                pin_base: None,
            })
            .collect();
        let worker_of_owner: Vec<usize> = (0..4).collect();
        let res = ClusterRun::launch_parts_with(
            &lblocks,
            states,
            plan,
            &worker_of_owner,
            &specs,
            order,
            kind,
        );
        let err = match res {
            Ok(_) => panic!("{kind}: mic<->mic inter-node plan must be refused"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("inter-node"), "{kind}: {err}");
    }
}
