//! Property-style tests on partitioner invariants (the offline build has
//! no proptest; cases are generated with the in-tree deterministic RNG —
//! shrinking is traded for a printed failing seed).

use repro::mesh::element::Material;
use repro::mesh::{build_local_blocks, Mesh};
use repro::partition::nested::{check_interior_only, pci_faces};
use repro::partition::{nested_partition, partition_stats, splice, splice_weighted, DeviceKind};
use repro::util::Rng;

fn random_mesh(rng: &mut Rng) -> Mesh {
    let nx = 2 + rng.below(7);
    let ny = 2 + rng.below(7);
    let nz = 2 + rng.below(7);
    Mesh::structured_brick([nx, ny, nz], [0.0; 3], [1.0, 1.5, 0.7], |c| {
        if c[0] < 0.5 {
            Material::acoustic(1.0, 1.0)
        } else {
            Material::elastic(1.0, 3.0, 2.0)
        }
    })
}

/// Every element is owned exactly once, by a valid part.
#[test]
fn prop_splice_is_partition() {
    for seed in 0..40u64 {
        let mut rng = Rng::seed_from_u64(seed);
        let mesh = random_mesh(&mut rng);
        let nparts = 1 + rng.below(mesh.len().min(9));
        let p = splice(&mesh, nparts);
        assert_eq!(p.assignment.len(), mesh.len(), "seed {seed}");
        assert!(p.assignment.iter().all(|&a| a < nparts), "seed {seed}");
        let sizes = p.sizes();
        let max = sizes.iter().max().unwrap();
        let min = sizes.iter().min().unwrap();
        assert!(max - min <= 1, "splice must be balanced to 1: seed {seed} {sizes:?}");
    }
}

/// Weighted splice: per-part weight within one max-element-weight of target.
#[test]
fn prop_weighted_splice_bounded_imbalance() {
    for seed in 0..30u64 {
        let mut rng = Rng::seed_from_u64(1000 + seed);
        let n = 20 + rng.below(200);
        let weights: Vec<f64> = (0..n).map(|_| rng.range(0.5, 4.0)).collect();
        let nparts = 2 + rng.below(6.min(n - 1));
        let p = splice_weighted(&weights, nparts);
        assert_eq!(p.nparts, nparts);
        let mut wsum = vec![0.0; nparts];
        for (e, &part) in p.assignment.iter().enumerate() {
            wsum[part] += weights[e];
        }
        let target: f64 = weights.iter().sum::<f64>() / nparts as f64;
        let wmax = weights.iter().cloned().fold(0.0, f64::max);
        for (i, w) in wsum.iter().enumerate() {
            assert!(
                (w - target).abs() <= target + wmax,
                "seed {seed} part {i}: weight {w} target {target}"
            );
        }
        // contiguity
        for w in p.assignment.windows(2) {
            assert!(w[1] == w[0] || w[1] == w[0] + 1, "seed {seed}");
        }
    }
}

/// Weighted splice, degenerate inputs: all-zero weights (fall back to the
/// equal splice), a single huge weight at either end (every part still
/// non-empty), non-finite weights (ignored), nparts > elements (one
/// element per leading part, empty tail).
#[test]
fn prop_weighted_splice_degenerate_weights() {
    // all zeros carry no information: equal-count fallback
    let p = splice_weighted(&vec![0.0; 30], 4);
    let sizes = p.sizes();
    assert_eq!(sizes.iter().sum::<usize>(), 30);
    assert!(sizes.iter().all(|&s| s >= 7), "{sizes:?}");
    // one huge weight must not starve the other parts
    for pos in [0usize, 15, 29] {
        let mut w = vec![1.0; 30];
        w[pos] = 1e12;
        let p = splice_weighted(&w, 4);
        assert!(p.sizes().iter().all(|&s| s >= 1), "pos {pos}: {:?}", p.sizes());
        for win in p.assignment.windows(2) {
            assert!(win[1] == win[0] || win[1] == win[0] + 1, "pos {pos}");
        }
    }
    // non-finite / negative weights are treated as zero, not propagated
    let w = [f64::NAN, 1.0, f64::INFINITY, -3.0, 1.0, 1.0];
    let p = splice_weighted(&w, 2);
    assert_eq!(p.assignment.len(), 6);
    assert!(p.sizes().iter().all(|&s| s >= 1), "{:?}", p.sizes());
    // more parts than elements: one element each for the first len parts
    let p = splice_weighted(&[1.0, 2.0, 3.0], 5);
    assert_eq!(p.nparts, 5);
    assert_eq!(p.assignment, vec![0, 1, 2]);
    let sizes = p.sizes();
    assert_eq!(&sizes[..3], &[1, 1, 1]);
    assert_eq!(&sizes[3..], &[0, 0]);
}

/// The rebalancer's monotonicity contract: with per-node rate weights, a
/// node measured 2x faster than every other never receives fewer elements
/// than any slower node — and halving one node's rate (it got faster)
/// never shrinks its chunk.
#[test]
fn prop_weighted_splice_faster_node_never_shrinks() {
    for seed in 0..30u64 {
        let mut rng = Rng::seed_from_u64(4000 + seed);
        let nparts = 2 + rng.below(5);
        let k_per = 15 + rng.below(20);
        let n = nparts * k_per;
        // one node at least 2x faster than every (equal-rate) other
        let slow_rate = rng.range(2.0, 8.0);
        let fast = rng.below(nparts);
        let rate_a: Vec<f64> = (0..nparts)
            .map(|nd| if nd == fast { slow_rate / 2.0 } else { slow_rate })
            .collect();
        let weights_of = |rates: &[f64]| -> Vec<f64> {
            (0..n).map(|e| rates[e / k_per]).collect()
        };
        let sizes_a = splice_weighted(&weights_of(&rate_a), nparts).sizes();
        assert!(
            (0..nparts).all(|nd| sizes_a[fast] >= sizes_a[nd]),
            "seed {seed}: 2x-faster node {fast} got fewer elements: {sizes_a:?}"
        );
        assert!(
            sizes_a[fast] >= k_per,
            "seed {seed}: faster node fell below its equal share: {sizes_a:?}"
        );
        // comparative form on arbitrary rates: speeding node i up 2x never
        // shrinks its chunk, everything else held fixed
        let rates: Vec<f64> = (0..nparts).map(|_| rng.range(1.0, 4.0)).collect();
        let i = rng.below(nparts);
        let mut faster = rates.clone();
        faster[i] /= 2.0;
        let before = splice_weighted(&weights_of(&rates), nparts).sizes();
        let after = splice_weighted(&weights_of(&faster), nparts).sizes();
        // the greedy boundary quantizes to whole elements, so allow one
        // element of rounding on the comparative form; the 2x-vs-equal
        // form above is exact
        assert!(
            after[i] + 1 >= before[i],
            "seed {seed}: node {i} sped up 2x but shrank {before:?} -> {after:?}"
        );
    }
}

/// Nested partition invariants for random meshes/parts/fractions.
#[test]
fn prop_nested_invariants() {
    for seed in 0..40u64 {
        let mut rng = Rng::seed_from_u64(2000 + seed);
        let mesh = random_mesh(&mut rng);
        let nparts = 1 + rng.below(5.min(mesh.len()));
        let frac = rng.uniform();
        let node = splice(&mesh, nparts);
        let np = nested_partition(&mesh, &node, frac);
        // 1. interior-only
        assert!(check_interior_only(&mesh, &np), "seed {seed}");
        // 2. counts consistent
        let total: usize = np.node_counts.iter().map(|&(c, m)| c + m).sum();
        assert_eq!(total, mesh.len(), "seed {seed}");
        // 3. pci faces match the assignment
        let pci = pci_faces(&mesh, &np);
        let st = partition_stats(&mesh, &np);
        for nd in 0..nparts {
            assert_eq!(pci[nd], st.per_node[nd].pci_faces, "seed {seed} node {nd}");
        }
        // 4. owners encode (node, device)
        for (e, &o) in np.owners().iter().enumerate() {
            assert_eq!(o / 2, np.node.assignment[e], "seed {seed}");
            assert_eq!(o % 2 == 1, np.device[e] == DeviceKind::Mic, "seed {seed}");
        }
    }
}

/// Local block extraction: halo plumbing is globally consistent.
#[test]
fn prop_local_blocks_consistent() {
    for seed in 0..30u64 {
        let mut rng = Rng::seed_from_u64(3000 + seed);
        let mesh = random_mesh(&mut rng);
        let nparts = 1 + rng.below(4.min(mesh.len()));
        let frac = rng.uniform();
        let node = splice(&mesh, nparts);
        let np = nested_partition(&mesh, &node, frac);
        let owners = np.owners();
        let (blocks, plan) = build_local_blocks(&mesh, &owners, np.n_owners());
        // every element appears exactly once
        let total: usize = blocks.iter().map(|b| b.len()).sum();
        assert_eq!(total, mesh.len(), "seed {seed}");
        // every halo slot is fed exactly once per stage
        for (o, blk) in blocks.iter().enumerate() {
            let mut fed = vec![0usize; blk.halo_len];
            for &(_, _, _, slot) in &plan.copies[o] {
                fed[slot] += 1;
            }
            assert!(fed.iter().all(|&f| f == 1), "seed {seed} owner {o}: {fed:?}");
        }
        // local conn values are in range
        for blk in &blocks {
            for (k, c) in blk.conn.iter().enumerate() {
                for f in 0..6 {
                    let v = c[f];
                    assert!(v >= -2 && (v < blk.len() as i32), "seed {seed}: conn[{k}][{f}] = {v}");
                    if v == -1 {
                        assert!((blk.halo_idx[k][f] as usize) < blk.halo_len, "seed {seed}");
                    }
                }
            }
        }
        // cross-owner face symmetry: the plan copies each shared face once
        // in each direction
        let mut shared = 0usize;
        for (e, c) in mesh.conn.iter().enumerate() {
            for &v in c {
                if v >= 0 && owners[v as usize] != owners[e] {
                    shared += 1;
                }
            }
        }
        assert_eq!(plan.total_faces(), shared, "seed {seed}");
    }
}

/// Balance solver: monotone in K, conserves elements, bounded ratio.
#[test]
fn prop_balance_solver() {
    use repro::costmodel::calib::stampede_node;
    use repro::partition::solve_mic_fraction;
    let node = stampede_node();
    let mut prev_kmic = 0usize;
    for k in [256usize, 512, 1024, 2048, 4096, 8192, 16384] {
        for order in [1usize, 3, 7] {
            let sol = solve_mic_fraction(&node, order, k);
            assert_eq!(sol.k_mic + sol.k_cpu, k, "k {k} order {order}");
            assert!(
                sol.ratio > 0.3 && sol.ratio < 4.0,
                "ratio {} k {k} order {order}",
                sol.ratio
            );
        }
        let sol7 = solve_mic_fraction(&node, 7, k);
        assert!(sol7.k_mic >= prev_kmic, "k_mic monotone in k");
        prev_kmic = sol7.k_mic;
    }
}

/// Morton keys of a mesh are strictly increasing (the level-1 premise).
#[test]
fn prop_mesh_morton_sorted() {
    for seed in 0..20u64 {
        let mut rng = Rng::seed_from_u64(4000 + seed);
        let mesh = random_mesh(&mut rng);
        assert!(mesh.check_consistency(), "seed {seed}");
        for w in mesh.elements.windows(2) {
            assert!(w[0].key <= w[1].key, "seed {seed}");
        }
    }
}
