//! Integration tests for the N-node cluster runtime: equivalence of the
//! full two-level nested execution against the scalar single-driver
//! reference for P ∈ {1, 2, 4} nodes (mixed elastic/acoustic mesh,
//! homogeneous and heterogeneous worker backends), the §5.5 fabric
//! constraint (accelerators never touch the inter-node lane), and the
//! adaptive rebalancer (element counts migrate toward the solved MIC
//! fraction without perturbing the solution).

use repro::coordinator::cluster::{ClusterRun, ClusterSpec, WorkerSpec};
use repro::coordinator::WorkerBackend;
use repro::mesh::{build_local_blocks, two_tree_geometry, unit_cube_geometry, Mesh};
use repro::partition::DeviceKind;
use repro::solver::analytic::standing_wave;
use repro::solver::driver::{Driver, RustRefBackend, StageBackend};
use repro::solver::{BlockState, LglBasis};

fn ic(x: [f64; 3]) -> [f64; 9] {
    let w = std::f64::consts::PI * 3f64.sqrt();
    standing_wave(x, 0.0, 1.0, 1.0, w)
}

/// The oracle: one block, one scalar backend, the plain driver. Returns
/// per-element q in global Morton order.
fn scalar_reference(mesh: &Mesh, order: usize, dt: f64, steps: usize) -> Vec<Vec<f32>> {
    let owners = vec![0usize; mesh.len()];
    let (lblocks, plan) = build_local_blocks(mesh, &owners, 1);
    let basis = LglBasis::new(order);
    let mut st = BlockState::from_local_block(
        &lblocks[0],
        order,
        lblocks[0].len(),
        lblocks[0].halo_len.max(1),
    );
    st.set_initial_condition(&basis, ic);
    let backends: Vec<Box<dyn StageBackend>> = vec![Box::new(RustRefBackend::new(order))];
    let mut drv = Driver::new(vec![st], plan, backends, order);
    drv.prime();
    drv.run(dt, steps).unwrap();
    let m = order + 1;
    let esz = 9 * m * m * m;
    let st = &drv.blocks[0];
    (0..mesh.len()).map(|e| st.q[e * esz..(e + 1) * esz].to_vec()).collect()
}

fn max_diff(a: &[Vec<f32>], b: &[Vec<f32>]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut worst = 0.0f32;
    for (ea, eb) in a.iter().zip(b) {
        assert_eq!(ea.len(), eb.len());
        for (&x, &y) in ea.iter().zip(eb) {
            worst = worst.max((x - y).abs());
        }
    }
    worst
}

/// P-node cluster equivalence against the scalar single-driver run on the
/// mixed elastic/acoustic two-tree mesh, for P in {1, 2, 4}.
#[test]
fn cluster_matches_scalar_p_1_2_4() {
    let order = 2;
    let mesh = two_tree_geometry(3); // 54 elements, acoustic + elastic trees
    let dt = 2.5e-4;
    let steps = 4;
    let reference = scalar_reference(&mesh, order, dt, steps);
    for nodes in [1usize, 2, 4] {
        let mut spec = ClusterSpec::new(nodes, order);
        spec.mic_fraction = Some(0.3);
        let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
        run.run(dt, steps).unwrap();
        let got = run.gather_elements().unwrap();
        let diff = max_diff(&reference, &got);
        assert!(diff <= 1e-6, "P={nodes}: cluster vs scalar diff {diff}");
    }
}

/// Heterogeneous worker backends (multithreaded CPU workers, scalar
/// accelerator stand-ins) must still match the scalar reference — the
/// backends share per-element kernels, so the cluster schedule is the only
/// variable under test.
#[test]
fn heterogeneous_backends_match_scalar() {
    let order = 2;
    let mesh = two_tree_geometry(3);
    let dt = 2.5e-4;
    let steps = 3;
    let reference = scalar_reference(&mesh, order, dt, steps);
    let mut spec = ClusterSpec::new(4, order);
    spec.mic_fraction = Some(0.3);
    spec.cpu_backend = WorkerBackend::RustParallel { threads: 2 };
    spec.mic_backend = WorkerBackend::RustRef;
    let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
    run.run(dt, steps).unwrap();
    let got = run.gather_elements().unwrap();
    let diff = max_diff(&reference, &got);
    assert!(diff <= 1e-6, "heterogeneous cluster vs scalar diff {diff}");
    // P=4 nodes exchange over the inter-node lane — but only CPU workers do
    let f = run.fabric();
    assert!(f.inter_node_faces > 0, "{f:?}");
    assert_eq!(f.mic_inter_node_faces, 0, "{f:?}");
}

/// Adaptive rebalancing: from a deliberately bad static split, measured
/// times must move the element counts toward the solved MIC fraction
/// (clipped at the interior-only constraint), migrate state between the
/// node's workers, and leave the solution within 1e-6 of the scalar run.
#[test]
fn rebalance_migrates_toward_solved_fraction() {
    let order = 2;
    let mesh = unit_cube_geometry(6); // 216 elements, 64 interior
    let dt = 1e-3;
    let mut spec = ClusterSpec::new(1, order);
    spec.mic_fraction = Some(0.05); // starve the accelerator worker
    let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
    run.run(dt, 2).unwrap();
    let before = run.node_counts()[0];
    assert!(before.1 <= 12, "static split should starve the MIC: {before:?}");
    let report = run.rebalance().unwrap();
    assert!(report.migrated_elems() > 0, "{report:?}");
    // single node: a level-2-only move that rebuilds both of its workers
    assert_eq!(report.level1_migrated, 0);
    assert!(report.level2_migrated > 0);
    assert_eq!(report.rebuilt_workers, 2);
    let after = run.node_counts()[0];
    assert!(
        after.1 > before.1,
        "k_mic must grow toward the solved split: {before:?} -> {after:?}"
    );
    assert_eq!(after.0 + after.1, mesh.len());
    assert_eq!(report.per_node[0].new_k_mic, after.1);
    // both in-process workers run the same kernels, so the solved target is
    // near half the node — well above the interior-only clip of 64
    assert!(
        report.per_node[0].target_fraction > 0.25,
        "measured-equal workers should target a large share: {report:?}"
    );
    assert!(after.1 <= 64, "interior-only constraint caps the migration");
    // the run continues bit-compatibly after migration
    run.run(dt, 2).unwrap();
    let reference = scalar_reference(&mesh, order, dt, 4);
    let got = run.gather_elements().unwrap();
    let diff = max_diff(&reference, &got);
    assert!(diff <= 1e-6, "post-migration cluster vs scalar diff {diff}");
}

/// The closed loop end to end: running with `rebalance_every` migrates
/// mid-run and the final state still matches the scalar reference.
#[test]
fn adaptive_run_matches_scalar() {
    let order = 2;
    let mesh = unit_cube_geometry(4); // 64 elements
    let dt = 1e-3;
    let steps = 6;
    let reference = scalar_reference(&mesh, order, dt, steps);
    let mut spec = ClusterSpec::new(2, order);
    spec.mic_fraction = Some(0.1);
    spec.rebalance_every = Some(2);
    let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
    run.run(dt, steps).unwrap();
    let got = run.gather_elements().unwrap();
    let diff = max_diff(&reference, &got);
    assert!(diff <= 1e-6, "adaptive cluster vs scalar diff {diff}");
}

/// Level-1 across-node rebalancing: one deliberately slow node (throttled
/// backends) must shed elements to the fast nodes over a few measured
/// rebalances, shrinking the node busy-time imbalance — and the migrated
/// state must stay within 1e-6 of the scalar driver for P in {2, 4}.
#[test]
fn level1_rebalance_converges_and_matches_scalar() {
    let order = 2;
    let mesh = unit_cube_geometry(6); // 216 elements
    let dt = 1e-3;
    for nodes in [2usize, 4] {
        let mut spec = ClusterSpec::new(nodes, order);
        spec.mic_fraction = Some(0.2);
        let mut backends =
            vec![(WorkerBackend::RustRef, WorkerBackend::RustRef); nodes];
        backends[nodes - 1] = (
            WorkerBackend::Throttled { spin_us_per_elem: 30 },
            WorkerBackend::Throttled { spin_us_per_elem: 30 },
        );
        spec.node_backends = Some(backends);
        let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
        // static window: the throttled node dominates the step
        run.run(dt, 2).unwrap();
        let imb_static =
            repro::coordinator::profile::node_busy_imbalance(&run.worker_times().unwrap());
        // three measured rebalance rounds (the weighted re-splice is a
        // damped iteration; each round moves toward the equal-time point)
        for _ in 0..3 {
            run.rebalance().unwrap();
            run.run(dt, 2).unwrap();
        }
        let sizes = run.node_partition().unwrap().sizes();
        let slow = nodes - 1;
        assert!(
            sizes[slow] < mesh.len() / nodes,
            "P={nodes}: throttled node must shed elements: {sizes:?}"
        );
        assert!(
            sizes.iter().take(nodes - 1).all(|&k| k > sizes[slow]),
            "P={nodes}: every fast node outweighs the slow one: {sizes:?}"
        );
        let l1: usize =
            run.rebalance_history.iter().map(|r| r.level1_migrated).sum();
        assert!(l1 > 0, "P={nodes}: level-1 migration must have happened");
        // steady-state imbalance shrank
        let _ = run.take_worker_times().unwrap();
        run.run(dt, 2).unwrap();
        let imb_adaptive =
            repro::coordinator::profile::node_busy_imbalance(&run.worker_times().unwrap());
        assert!(
            imb_adaptive < imb_static,
            "P={nodes}: imbalance must shrink: {imb_static:.3} -> {imb_adaptive:.3}"
        );
        // 2 static + 3x2 rebalanced + 2 measured = 10 steps, bit-compatible
        let reference = scalar_reference(&mesh, order, dt, 10);
        let got = run.gather_elements().unwrap();
        let diff = max_diff(&reference, &got);
        assert!(diff <= 1e-6, "P={nodes}: post-level-1-migration diff {diff}");
    }
}

/// Incremental migration: a hand-picked move of a *single* node's level-2
/// split must rebuild exactly that node's two workers — every other
/// worker keeps its blocks and backends — and the run must continue
/// bit-compatibly.
#[test]
fn single_node_move_rebuilds_only_that_node() {
    let order = 2;
    let mesh = unit_cube_geometry(6);
    let dt = 1e-3;
    let mut spec = ClusterSpec::new(2, order);
    spec.mic_fraction = Some(0.2);
    let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
    run.run(dt, 2).unwrap();
    let part = run.node_partition().unwrap();
    let fracs = run.mic_fractions().unwrap();
    // identical fractions: provably zero-migration (the planner's no-op)
    let rep0 = run.apply_two_level(part.clone(), fracs.clone()).unwrap();
    assert_eq!(rep0.migrated_elems(), 0, "{rep0:?}");
    assert_eq!(rep0.rebuilt_workers, 0);
    assert_eq!(rep0.kept_workers, 4);
    // move only node 1's split: node 0 keeps its exact element set
    let rep = run.apply_two_level(part, vec![fracs[0], 0.45]).unwrap();
    assert_eq!(rep.level1_migrated, 0, "{rep:?}");
    assert!(rep.level2_migrated > 0, "{rep:?}");
    assert_eq!(rep.rebuilt_workers, 2, "only node 1's workers rebuild: {rep:?}");
    assert_eq!(rep.kept_workers, 2, "{rep:?}");
    assert_eq!(rep.per_node[0].new_k_mic, rep.per_node[0].old_k_mic);
    assert!(rep.per_node[1].new_k_mic > rep.per_node[1].old_k_mic);
    run.run(dt, 2).unwrap();
    let reference = scalar_reference(&mesh, order, dt, 4);
    let got = run.gather_elements().unwrap();
    let diff = max_diff(&reference, &got);
    assert!(diff <= 1e-6, "post-incremental-migration diff {diff}");
}

/// Pool and classification survival: a rebalance that migrates only one
/// node's split must leave the kept workers' persistent pools (same
/// generation) *and* their memoized boundary/interior classification
/// (same compute count, flat across further stages) alive, while the
/// rebuilt workers show a fresh pool generation — the backend-preserving
/// contract of the incremental migration, extended from blocks to the
/// execution substrate.
#[test]
fn pool_and_classification_survive_rebalance() {
    let order = 2;
    let mesh = unit_cube_geometry(6);
    let dt = 1e-3;
    let mut spec = ClusterSpec::new(2, order);
    spec.mic_fraction = Some(0.2);
    // parallel backends everywhere so every worker owns a pool
    spec.cpu_backend = WorkerBackend::RustParallel { threads: 2 };
    spec.mic_backend = WorkerBackend::RustParallel { threads: 1 };
    let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
    run.run(dt, 2).unwrap();
    let before = run.worker_times().unwrap();
    assert!(
        before.iter().all(|t| t.pool_generation != 0),
        "parallel workers report a live pool: {before:?}"
    );
    assert!(
        before.iter().all(|t| t.classify_computes == 1),
        "one block per worker classifies exactly once: {before:?}"
    );
    // move only node 1's level-2 split (same shape as
    // single_node_move_rebuilds_only_that_node)
    let part = run.node_partition().unwrap();
    let fracs = run.mic_fractions().unwrap();
    let rep = run.apply_two_level(part, vec![fracs[0], 0.45]).unwrap();
    assert_eq!(rep.rebuilt_workers, 2, "{rep:?}");
    run.run(dt, 2).unwrap();
    let after = run.worker_times().unwrap();
    for w in [0usize, 1] {
        assert_eq!(
            after[w].pool_generation, before[w].pool_generation,
            "kept worker {w} must keep its pool"
        );
        assert_eq!(
            after[w].classify_computes, before[w].classify_computes,
            "kept worker {w} must keep its memoized classification"
        );
    }
    for w in [2usize, 3] {
        assert_ne!(
            after[w].pool_generation, before[w].pool_generation,
            "rebuilt worker {w} must get a fresh pool"
        );
        assert_eq!(
            after[w].classify_computes, 1,
            "rebuilt worker {w} reclassified its new block exactly once"
        );
    }
    // the run stays bit-compatible through pool-preserving migration
    let reference = scalar_reference(&mesh, order, dt, 4);
    let got = run.gather_elements().unwrap();
    let diff = max_diff(&reference, &got);
    assert!(diff <= 1e-6, "post-migration diff {diff}");
}

/// Core pinning is best-effort and must not perturb the numerics: a
/// pinned cluster (disjoint core ranges per parallel worker) matches the
/// scalar reference whether or not the sandbox honors the affinity call.
#[test]
fn pinned_cluster_matches_scalar() {
    let order = 2;
    let mesh = unit_cube_geometry(4);
    let dt = 1e-3;
    let steps = 2;
    let reference = scalar_reference(&mesh, order, dt, steps);
    let mut spec = ClusterSpec::new(2, order);
    spec.mic_fraction = Some(0.2);
    spec.cpu_backend = WorkerBackend::RustParallel { threads: 2 };
    spec.mic_backend = WorkerBackend::RustParallel { threads: 1 };
    spec.pin_cores = true;
    let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
    run.run(dt, steps).unwrap();
    let got = run.gather_elements().unwrap();
    let diff = max_diff(&reference, &got);
    assert!(diff <= 1e-6, "pinned cluster vs scalar diff {diff}");
}

/// Thread budgeting: explicit budgets pass through to `WorkerTimes`, and
/// the `threads: 0` auto budget divides the machine across the *parallel*
/// workers only (scalar workers report 1).
#[test]
fn thread_budget_exposed_and_divided() {
    let order = 2;
    let mesh = unit_cube_geometry(4);
    let mut spec = ClusterSpec::new(1, order);
    spec.mic_fraction = Some(0.3);
    spec.cpu_backend = WorkerBackend::RustParallel { threads: 2 };
    spec.mic_backend = WorkerBackend::RustRef;
    let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
    run.run(1e-3, 1).unwrap();
    let t = run.worker_times().unwrap();
    assert_eq!(t[0].threads, 2, "explicit budget passes through");
    assert_eq!(t[1].threads, 1, "scalar worker occupies one thread");

    // auto budget: 2 nodes x 2 parallel workers share the machine
    let mut spec = ClusterSpec::new(2, order);
    spec.mic_fraction = Some(0.3);
    spec.cpu_backend = WorkerBackend::RustParallel { threads: 0 };
    spec.mic_backend = WorkerBackend::RustParallel { threads: 0 };
    let run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let expected = (hw / 4).max(1);
    let t = run.worker_times().unwrap();
    assert!(t.iter().all(|wt| wt.threads == expected), "{t:?} vs {expected}");
}

/// A hand-built layout that puts accelerator workers of different nodes in
/// contact must be refused at launch — the fabric enforces §5.5.
#[test]
fn inter_node_mic_traffic_is_refused() {
    let order = 1;
    let mesh = unit_cube_geometry(2); // 8 elements, morton halves touch
    let owners: Vec<usize> = (0..mesh.len()).map(|e| if e < 4 { 1 } else { 3 }).collect();
    let (lblocks, plan) = build_local_blocks(&mesh, &owners, 4);
    let basis = LglBasis::new(order);
    let states: Vec<BlockState> = lblocks
        .iter()
        .map(|lb| {
            let mut st =
                BlockState::from_local_block(lb, order, lb.len().max(1), lb.halo_len.max(1));
            st.set_initial_condition(&basis, ic);
            st
        })
        .collect();
    let specs: Vec<WorkerSpec> = (0..4)
        .map(|w| WorkerSpec {
            node: w / 2,
            device: if w % 2 == 0 { DeviceKind::Cpu } else { DeviceKind::Mic },
            backend: WorkerBackend::RustRef,
            name: format!("w{w}"),
            pin_base: None,
        })
        .collect();
    let worker_of_owner: Vec<usize> = (0..4).collect();
    let res = ClusterRun::launch_parts(&lblocks, states, plan, &worker_of_owner, &specs, order);
    let err = match res {
        Ok(_) => panic!("mic<->mic inter-node plan must be refused"),
        Err(e) => format!("{e}"),
    };
    assert!(err.contains("inter-node"), "{err}");
}
