//! The persistent-pool contract of the stage hot path: after one warmup
//! step, stepping the driver creates **zero** OS threads — the backends'
//! worker pools and the driver's comm thread are created once and reused
//! every stage. (Own test binary with a single test: the assertions
//! snapshot process-wide counters, so nothing else may spawn pools
//! concurrently.)

use repro::mesh::{build_local_blocks, geometry::unit_cube_geometry};
use repro::solver::analytic::standing_wave;
use repro::solver::driver::{Driver, StageBackend};
use repro::solver::{BlockState, LglBasis, ParallelRefBackend};
use repro::util::pool::os_threads_spawned;

/// Live OS threads of this process (Linux); 0 elsewhere.
fn live_os_threads() -> usize {
    std::fs::read_dir("/proc/self/task").map(|d| d.count()).unwrap_or(0)
}

fn build_driver(order: usize, owners: &[usize], n_owners: usize, threads: usize) -> Driver {
    let mesh = unit_cube_geometry(2);
    let (lblocks, plan) = build_local_blocks(&mesh, owners, n_owners);
    let basis = LglBasis::new(order);
    let w = std::f64::consts::PI * 3f64.sqrt();
    let mut blocks: Vec<BlockState> = lblocks
        .iter()
        .map(|b| BlockState::from_local_block(b, order, b.len(), b.halo_len.max(1)))
        .collect();
    for b in blocks.iter_mut() {
        b.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
    }
    let backends: Vec<Box<dyn StageBackend>> = (0..n_owners)
        .map(|_| {
            Box::new(ParallelRefBackend::with_threads(order, threads)) as Box<dyn StageBackend>
        })
        .collect();
    Driver::new(blocks, plan, backends, order)
}

#[test]
fn warm_stage_loop_spawns_no_threads() {
    let order = 2;

    // ---- serial schedule: warm from the first stage ---------------------
    // (the fused pipeline dispatches to pools created with the backends)
    let mut serial = build_driver(order, &[0usize; 8], 1, 3);
    serial.prime();
    let spawned_before = os_threads_spawned();
    serial.run(1e-3, 4).unwrap();
    assert_eq!(
        os_threads_spawned(),
        spawned_before,
        "the fused serial schedule dispatches to the persistent pool only"
    );

    // ---- overlapped schedule: warm after one step -----------------------
    // (the first overlapped step creates the driver's comm thread)
    let owners: Vec<usize> = (0..8).map(|e| e / 4).collect();
    let mut drv = build_driver(order, &owners, 2, 2);
    drv.overlap = true;
    drv.prime();
    drv.step(1e-3).unwrap(); // warmup
    let spawned_before = os_threads_spawned();
    let live_before = live_os_threads();
    drv.run(1e-3, 5).unwrap();
    assert_eq!(
        os_threads_spawned(),
        spawned_before,
        "a warm overlapped stage loop must not create pool/comm threads"
    );
    if cfg!(target_os = "linux") {
        assert_eq!(
            live_os_threads(),
            live_before,
            "OS thread count must be flat across warm steps"
        );
    }
    // sanity: warmup did create persistent threads — 2 backends x 1 extra
    // pool worker each + the comm thread (plus the serial driver's pool)
    assert!(spawned_before >= 3, "expected persistent threads, saw {spawned_before}");
}
