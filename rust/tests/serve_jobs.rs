//! Acceptance tests for `coordinator::serve` — the multi-scenario job
//! scheduler over the shared pool/cluster substrate.
//!
//! * **Isolation**: every job served out of a concurrent mixed batch
//!   (pool-slice jobs at several orders plus a cluster-backed job) must
//!   finish within 1e-6 of its own *solo* scalar run — same mesh, same
//!   `job_dt` timestep, same standing-wave IC, single block, one scalar
//!   backend. Co-scheduling must not leak state across jobs.
//! * **Throughput**: the headline claim — N >= 4 mixed-size jobs
//!   co-scheduled on disjoint 1-lane slices must beat the same jobs run
//!   back-to-back on one slice owning the whole lane budget. The >= 1.3x
//!   assertion only arms on hosts with >= 4 hardware threads (the spec's
//!   "multi-core host" proviso); narrower machines still run both legs
//!   and check accounting.
//! * **Cancellation**: cancelling one in-flight cluster job (which
//!   poisons that job's own fabric) must neither hang the batch nor
//!   perturb the surviving jobs' fields.

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use repro::coordinator::serve::{
    job_dt, job_ic, serve, serve_with_ctls, JobCtl, JobSpec, JobStatus, ServeOptions, ServeSpec,
};
use repro::mesh::build_local_blocks;
use repro::mesh::geometry::unit_cube_geometry;
use repro::solver::driver::{Driver, RustRefBackend, StageBackend};
use repro::solver::{BlockState, LglBasis};

/// The solo oracle: the job's mesh, `job_dt` and `job_ic`, one block, one
/// scalar backend — exactly the trajectory `serve` integrates for it.
fn solo_scalar(job: &JobSpec) -> Vec<Vec<f32>> {
    let mesh = unit_cube_geometry(job.n);
    let dt = job_dt(&mesh, job.order);
    let owners = vec![0usize; mesh.len()];
    let (lblocks, plan) = build_local_blocks(&mesh, &owners, 1);
    let basis = LglBasis::new(job.order);
    let mut st = BlockState::from_local_block(
        &lblocks[0],
        job.order,
        lblocks[0].len(),
        lblocks[0].halo_len.max(1),
    );
    st.set_initial_condition(&basis, job_ic);
    let backends: Vec<Box<dyn StageBackend>> = vec![Box::new(RustRefBackend::new(job.order))];
    let mut drv = Driver::new(vec![st], plan, backends, job.order);
    drv.prime();
    drv.run(dt, job.steps).unwrap();
    let m = job.order + 1;
    let esz = 9 * m * m * m;
    let st = &drv.blocks[0];
    (0..mesh.len()).map(|e| st.q[e * esz..(e + 1) * esz].to_vec()).collect()
}

fn max_diff(a: &[Vec<f32>], b: &[Vec<f32>]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut worst = 0.0f32;
    for (ea, eb) in a.iter().zip(b) {
        assert_eq!(ea.len(), eb.len());
        for (&x, &y) in ea.iter().zip(eb) {
            worst = worst.max((x - y).abs());
        }
    }
    worst
}

fn job(name: &str, n: usize, order: usize, steps: usize, nodes: usize) -> JobSpec {
    JobSpec { name: name.into(), n, order, steps, nodes }
}

#[test]
fn served_jobs_match_their_solo_scalar_runs() {
    // mixed orders, mixed sizes, one cluster-backed job — all in flight
    // at once over two slices of one shared pool
    let jobs = vec![
        job("small_p2", 3, 2, 4, 1),
        job("tall_p4", 2, 4, 3, 1),
        job("wide_p3", 3, 3, 3, 1),
        job("cluster_p2", 3, 2, 3, 2),
    ];
    let mut spec = ServeSpec::new(jobs);
    spec.slices = vec![1, 2];
    spec.queue_cap = 3; // admission must block at least once
    let report = serve(&spec, &ServeOptions { keep_fields: true, ..Default::default() }).unwrap();

    assert_eq!(report.jobs.len(), spec.jobs.len());
    assert_eq!(report.evicted_reports, 0);
    for j in &report.jobs {
        assert_eq!(j.status, JobStatus::Done, "{}: {:?}", j.name, j.status);
        assert_eq!(j.steps_done, j.steps, "{}", j.name);
    }
    assert_eq!(report.fields.len(), spec.jobs.len());
    for (idx, job) in spec.jobs.iter().enumerate() {
        let got = report.fields[idx].as_ref().expect("keep_fields retained the final state");
        let want = solo_scalar(job);
        let d = max_diff(got, &want);
        assert!(d <= 1e-6, "{}: served fields differ from solo run by {d:e}", job.name);
    }
}

#[test]
fn concurrent_serve_beats_serial_on_multicore() {
    // four mixed-size jobs, sized so each is long enough to measure but
    // small enough that a 4-lane gang is sync-bound — the regime where
    // co-scheduling (4 jobs x 1 lane) beats width (1 job x 4 lanes)
    let jobs = vec![
        job("small_a", 3, 2, 600, 1),
        job("med_a", 4, 3, 240, 1),
        job("small_b", 3, 2, 600, 1),
        job("med_b", 4, 3, 240, 1),
    ];
    let mut spec = ServeSpec::new(jobs);
    spec.slices = vec![1, 1, 1, 1];
    let opts = ServeOptions::default();
    let concurrent = serve(&spec, &opts).unwrap();
    let serial = serve(&spec.serial(), &opts).unwrap();

    for j in concurrent.jobs.iter().chain(&serial.jobs) {
        assert_eq!(j.status, JobStatus::Done, "{}: {:?}", j.name, j.status);
    }
    // greedy makespan placement must actually spread the batch
    let used: std::collections::HashSet<usize> =
        concurrent.jobs.iter().map(|j| j.slice).collect();
    assert!(used.len() >= 2, "all jobs landed on one slice: {used:?}");
    assert!(serial.jobs.iter().all(|j| j.slice == 0 && j.lanes == 4));

    let speedup = serial.wall_s / concurrent.wall_s.max(1e-12);
    let hw = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "serve aggregate over serial: {speedup:.2}x \
         (concurrent {:.3}s, serial {:.3}s, {hw} hw threads)",
        concurrent.wall_s, serial.wall_s
    );
    if hw >= 4 {
        assert!(
            speedup >= 1.3,
            "expected >= 1.3x aggregate speedup on a {hw}-thread host, got {speedup:.2}x"
        );
    } else {
        println!("(host has {hw} hw threads < 4 — speedup floor not armed)");
    }
}

#[test]
fn cancelling_one_inflight_job_leaves_survivors_intact() {
    // the victim is a cluster job far too long to ever finish; a side
    // thread cancels it mid-flight, which poisons that job's own fabric.
    // The batch must still drain and the survivors must match their solo
    // runs exactly as if the victim had never existed.
    let jobs = vec![
        job("victim", 3, 2, 200_000, 2),
        job("surv_p2", 3, 2, 4, 1),
        job("surv_p3", 2, 3, 4, 1),
    ];
    let mut spec = ServeSpec::new(jobs);
    spec.slices = vec![1, 1];
    let ctls: Vec<Arc<JobCtl>> = (0..3).map(|_| Arc::new(JobCtl::default())).collect();
    let victim_ctl = ctls[0].clone();
    let killer = thread::spawn(move || {
        thread::sleep(Duration::from_millis(150));
        victim_ctl.cancel();
    });
    let report = serve_with_ctls(
        &spec,
        &ServeOptions { keep_fields: true, ..Default::default() },
        Some(&ctls),
    )
    .unwrap();
    killer.join().unwrap();

    assert_eq!(report.jobs.len(), 3);
    let victim = report.jobs.iter().find(|j| j.name == "victim").unwrap();
    assert_eq!(victim.status, JobStatus::Cancelled, "victim must report cancelled");
    assert!(victim.steps_done < victim.steps, "victim cannot have finished");
    assert!(report.fields[0].is_none(), "cancelled job keeps no fields");
    for (idx, job) in spec.jobs.iter().enumerate().skip(1) {
        let r = report.jobs.iter().find(|j| j.name == job.name).unwrap();
        assert_eq!(r.status, JobStatus::Done, "{}: {:?}", job.name, r.status);
        let got = report.fields[idx].as_ref().expect("survivor fields kept");
        let want = solo_scalar(job);
        let d = max_diff(got, &want);
        assert!(d <= 1e-6, "{}: survivor corrupted by cancellation ({d:e})", job.name);
    }
}
