//! Property tests for the multithreaded boundary/interior CPU backend:
//! `ParallelRefBackend` must reproduce the scalar `RustRefBackend`
//! field-by-field on a mixed elastic/acoustic two-block mesh across
//! orders {2, 3, 7} and thread counts {1, 2, 4}, under both the serial
//! and the overlapped (compute/exchange) driver schedules; and its
//! boundary/interior classification must agree with the partition
//! machinery (`boundary_depths` depth-0 set, `partition_stats` MPI faces).

use repro::mesh::{build_local_blocks, geometry::discontinuous_brick};
use repro::partition::nested::boundary_depths;
use repro::partition::{nested_partition, partition_stats, splice};
use repro::solver::analytic::standing_wave;
use repro::solver::driver::{Driver, RustRefBackend, StageBackend};
use repro::solver::parallel::classify_elements;
use repro::solver::state::NFIELDS;
use repro::solver::{BlockState, LglBasis, ParallelRefBackend};

/// The mixed elastic/acoustic workload: a brick whose material jumps at
/// the half plane, spliced into two node chunks.
fn mixed_mesh() -> (repro::mesh::Mesh, Vec<usize>) {
    let mesh = discontinuous_brick([4, 4, 2], [1.0, 1.0, 0.5]);
    let owners = splice(&mesh, 2).assignment.clone();
    (mesh, owners)
}

fn build_driver(
    mesh: &repro::mesh::Mesh,
    owners: &[usize],
    order: usize,
    threads: Option<usize>,
    overlap: bool,
) -> Driver {
    let (lblocks, plan) = build_local_blocks(mesh, owners, 2);
    let basis = LglBasis::new(order);
    let w = std::f64::consts::PI * 3f64.sqrt();
    let mut blocks: Vec<BlockState> = lblocks
        .iter()
        .map(|lb| BlockState::from_local_block(lb, order, lb.len().max(1), lb.halo_len.max(1)))
        .collect();
    for blk in blocks.iter_mut() {
        blk.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
    }
    let backends: Vec<Box<dyn StageBackend>> = (0..2)
        .map(|_| -> Box<dyn StageBackend> {
            match threads {
                Some(t) => Box::new(ParallelRefBackend::with_threads(order, t)),
                None => Box::new(RustRefBackend::new(order)),
            }
        })
        .collect();
    let mut drv = Driver::new(blocks, plan, backends, order);
    drv.overlap = overlap;
    drv.prime();
    drv
}

/// Max relative L2 difference over the 9 fields between two runs.
fn max_field_rel_diff(a: &Driver, b: &Driver) -> f64 {
    let mut worst = 0.0f64;
    for fld in 0..NFIELDS {
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for (ba, bb) in a.blocks.iter().zip(&b.blocks) {
            let vol = ba.m * ba.m * ba.m;
            for e in 0..ba.k_real {
                let base = (e * NFIELDS + fld) * vol;
                for n in 0..vol {
                    let x = ba.q[base + n] as f64;
                    let y = bb.q[base + n] as f64;
                    num += (x - y) * (x - y);
                    den += x * x;
                }
            }
        }
        worst = worst.max((num / den.max(1e-30)).sqrt());
    }
    worst
}

#[test]
fn parallel_matches_scalar_across_orders_and_threads() {
    let (mesh, owners) = mixed_mesh();
    for order in [2usize, 3, 7] {
        let steps = if order >= 7 { 2 } else { 3 };
        let dt = 5e-4;
        let mut scalar = build_driver(&mesh, &owners, order, None, false);
        scalar.run(dt, steps).unwrap();
        for threads in [1usize, 2, 4] {
            for overlap in [false, true] {
                let mut par = build_driver(&mesh, &owners, order, Some(threads), overlap);
                par.run(dt, steps).unwrap();
                let diff = max_field_rel_diff(&scalar, &par);
                assert!(
                    diff <= 1e-6,
                    "order {order}, {threads} thread(s), overlap {overlap}: \
                     field rel diff {diff:e}"
                );
            }
        }
    }
}

#[test]
fn energy_consistent_between_backends() {
    let (mesh, owners) = mixed_mesh();
    let order = 3;
    let mut scalar = build_driver(&mesh, &owners, order, None, false);
    let mut par = build_driver(&mesh, &owners, order, Some(4), true);
    let e0 = scalar.energy();
    scalar.run(1e-3, 5).unwrap();
    par.run(1e-3, 5).unwrap();
    let es = scalar.energy();
    let ep = par.energy();
    assert!(es > 0.0 && es <= e0 * (1.0 + 1e-6));
    assert!((es - ep).abs() <= 1e-9 * es.abs().max(1.0), "{es} vs {ep}");
}

#[test]
fn hetero_workers_parallel_matches_rustref() {
    use repro::coordinator::{node::WorkerBackend, HeteroRun};
    use repro::partition::DeviceKind;
    let (mesh, owners) = mixed_mesh();
    let order = 2;
    let run = |backend: WorkerBackend| -> Vec<f32> {
        let (lblocks, plan) = build_local_blocks(&mesh, &owners, 2);
        let basis = LglBasis::new(order);
        let w = std::f64::consts::PI * 3f64.sqrt();
        let mut states: Vec<BlockState> = lblocks
            .iter()
            .map(|lb| BlockState::from_local_block(lb, order, lb.len().max(1), lb.halo_len.max(1)))
            .collect();
        for st in states.iter_mut() {
            st.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
        }
        let devices = vec![DeviceKind::Cpu, DeviceKind::Mic];
        let mut hr = HeteroRun::launch(&lblocks, states, plan, &devices, backend, order).unwrap();
        hr.run(1e-3, 3).unwrap();
        let mut out = Vec::new();
        for &o in &hr.owners() {
            out.extend(hr.read_block(o).unwrap().q);
        }
        out
    };
    let scalar = run(WorkerBackend::RustRef);
    let parallel = run(WorkerBackend::RustParallel { threads: 2 });
    assert_eq!(scalar.len(), parallel.len());
    for (x, y) in scalar.iter().zip(&parallel) {
        assert!((x - y).abs() <= 1e-6 * x.abs().max(1.0), "{x} vs {y}");
    }
}

#[test]
fn classification_agrees_with_partition_machinery() {
    let (mesh, owners) = mixed_mesh();
    let node = splice(&mesh, 2);
    assert_eq!(&node.assignment, &owners);
    let (lblocks, _) = build_local_blocks(&mesh, &owners, 2);
    let np = nested_partition(&mesh, &node, 0.0);
    let stats = partition_stats(&mesh, &np);
    for (nd, lb) in lblocks.iter().enumerate() {
        let st = BlockState::from_local_block(lb, 2, lb.len().max(1), lb.halo_len.max(1));
        let split = classify_elements(&st.conn, st.k_real);
        // every real element is classified exactly once
        assert_eq!(split.boundary.len() + split.interior.len(), st.k_real);
        // halo-facing faces are exactly this node's MPI faces
        assert_eq!(
            split.halo_faces.len(),
            stats.per_node[nd].mpi_faces,
            "node {nd}: halo faces vs partition stats"
        );
        assert_eq!(split.halo_faces.len(), lb.halo_len);
        // boundary elements are exactly the depth-0 set of the node split
        let depths: std::collections::HashMap<usize, usize> =
            boundary_depths(&mesh, &owners, nd).into_iter().collect();
        for &e in &split.boundary {
            assert_eq!(depths[&lb.global_ids[e]], 0);
        }
        for &e in &split.interior {
            assert!(depths[&lb.global_ids[e]] >= 1);
        }
        // interior elements must not touch the halo (the invariant the
        // overlapped schedule relies on)
        for &e in &split.interior {
            for f in 0..6 {
                assert!(st.conn[e * 6 + f] != -1);
            }
        }
    }
}
