//! Level-2 partitioning: the asymmetric CPU/MIC split inside each node.
//!
//! Paper §5.5: "we only allow interior elements [...] to be offloaded to
//! the MIC", "minimizing communication over the PCI bus [...] by minimizing
//! the surface area of the partition offloaded to the MIC", and the count
//! comes from the load-balance solve (§5.6).
//!
//! The selection is an onion-peeling heuristic: BFS layers inward from the
//! node-subdomain boundary (any element with a face shared with another
//! node or with depth-0 neighbors), then offload the K_mic *deepest*
//! elements, breaking depth ties in Morton order so the MIC set stays
//! contiguous along the curve. Deepest-first growth keeps the exposed
//! CPU<->MIC interface close to the minimal enclosing surface.

use std::collections::VecDeque;

use super::splice::Partition;
use crate::mesh::Mesh;

/// Which device of the owning node executes an element.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    Cpu,
    Mic,
}

/// The full two-level assignment.
#[derive(Debug, Clone)]
pub struct NestedPartition {
    pub node: Partition,
    pub device: Vec<DeviceKind>,
    /// Per node: (k_cpu, k_mic).
    pub node_counts: Vec<(usize, usize)>,
}

impl NestedPartition {
    /// Owner id for block extraction: node*2 (CPU) / node*2+1 (MIC).
    pub fn owners(&self) -> Vec<usize> {
        self.node
            .assignment
            .iter()
            .zip(&self.device)
            .map(|(&n, &d)| n * 2 + usize::from(d == DeviceKind::Mic))
            .collect()
    }

    pub fn n_owners(&self) -> usize {
        self.node.nparts * 2
    }
}

/// Distance-to-boundary layers within one node's element set.
///
/// Depth 0 = element with at least one face owned by another node (an MPI
/// boundary element, pinned to the CPU); physical-boundary faces do NOT
/// count (paper: interior means "faces not shared with other compute
/// nodes"). Returns `usize::MAX` for nodes whose subdomain has no MPI
/// boundary at all (single-node runs) — callers treat every element as
/// offloadable then, with depth measured from the physical hull instead so
/// surface minimization still has a gradient.
pub fn boundary_depths(mesh: &Mesh, node_of: &[usize], node: usize) -> Vec<(usize, usize)> {
    // collect this node's elements
    let elems: Vec<usize> =
        (0..mesh.len()).filter(|&e| node_of[e] == node).collect();
    let mut depth = vec![usize::MAX; mesh.len()];
    let mut queue = VecDeque::new();
    for &e in &elems {
        let mpi_boundary = mesh.conn[e]
            .iter()
            .any(|&v| v >= 0 && node_of[v as usize] != node);
        if mpi_boundary {
            depth[e] = 0;
            queue.push_back(e);
        }
    }
    if queue.is_empty() {
        // single-node case: seed from the physical hull instead
        for &e in &elems {
            if mesh.conn[e].iter().any(|&v| v < 0) {
                depth[e] = 0;
                queue.push_back(e);
            }
        }
    }
    while let Some(e) = queue.pop_front() {
        for &v in &mesh.conn[e] {
            if v >= 0 {
                let v = v as usize;
                if node_of[v] == node && depth[v] == usize::MAX {
                    depth[v] = depth[e] + 1;
                    queue.push_back(v);
                }
            }
        }
    }
    elems.into_iter().map(|e| (e, depth[e])).collect()
}

/// Build the nested partition: per node, offload the `mic_fraction` share
/// of elements (deepest-first) to the MIC, subject to the interior-only
/// constraint. Returns the assignment plus realized per-node counts (the
/// realized MIC count can fall short of the request if a node has too few
/// interior elements — exactly the regime where the paper's scheme degrades
/// to CPU-only).
pub fn nested_partition(mesh: &Mesh, node: &Partition, mic_fraction: f64) -> NestedPartition {
    nested_partition_fractions(mesh, node, &vec![mic_fraction; node.nparts])
}

/// [`nested_partition`] with one MIC fraction *per node* — the entry point
/// of the adaptive rebalancer ([`crate::coordinator::cluster`]), which
/// re-solves each node's split from its measured kernel times and re-splits
/// only the nodes whose target moved.
pub fn nested_partition_fractions(
    mesh: &Mesh,
    node: &Partition,
    fractions: &[f64],
) -> NestedPartition {
    assert_eq!(fractions.len(), node.nparts, "one MIC fraction per node");
    let node_of = &node.assignment;
    let mut device = vec![DeviceKind::Cpu; mesh.len()];
    let mut node_counts = vec![(0usize, 0usize); node.nparts];
    for nd in 0..node.nparts {
        let mic_fraction = fractions[nd];
        assert!((0.0..=1.0).contains(&mic_fraction), "node {nd} fraction {mic_fraction}");
        let depths = boundary_depths(mesh, node_of, nd);
        let k = depths.len();
        let want = (k as f64 * mic_fraction).round() as usize;
        // offloadable = strictly interior (depth >= 1); in the single-node
        // case there is no MPI boundary, so depth-0 (hull) elements remain
        // on the CPU too — they still carry bound_flux work.
        let mut cand: Vec<(usize, usize)> =
            depths.iter().copied().filter(|&(_, d)| d >= 1).collect();
        // deepest first; ties by Morton position (= global index order)
        cand.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let take = want.min(cand.len());
        for &(e, _) in cand.iter().take(take) {
            device[e] = DeviceKind::Mic;
        }
        node_counts[nd] = (k - take, take);
    }
    NestedPartition { node: node.clone(), device, node_counts }
}

/// The elements that change device between two nested partitions of the
/// same node assignment: `(element, old device, new device)` rows. This is
/// exactly the state the cluster runtime migrates between a node's two
/// workers when the rebalancer moves the split.
pub fn migration_diff(
    old: &NestedPartition,
    new: &NestedPartition,
) -> Vec<(usize, DeviceKind, DeviceKind)> {
    assert_eq!(old.device.len(), new.device.len());
    old.device
        .iter()
        .zip(&new.device)
        .enumerate()
        .filter(|(_, (a, b))| a != b)
        .map(|(e, (&a, &b))| (e, a, b))
        .collect()
}

/// An owner-vector change classified by partition level — the shape of a
/// two-level rebalance, computed before any state moves.
#[derive(Debug, Clone, Default)]
pub struct OwnerMigration {
    /// Elements whose *node* changed (level-1 splice boundary moved).
    pub level1: usize,
    /// Elements that stayed on their node but switched device (level 2).
    pub level2: usize,
    /// Owners that lose or gain at least one element, ascending — exactly
    /// the workers an incremental migration must rebuild; every other
    /// worker keeps its blocks *and* its backends.
    pub changed_owners: Vec<usize>,
}

impl OwnerMigration {
    pub fn total(&self) -> usize {
        self.level1 + self.level2
    }
}

/// Classify the move between two owner vectors (owner = `node*2 + device`,
/// the [`NestedPartition::owners`] encoding) into level-1 and level-2
/// migrations plus the set of owners whose element set changed at all.
pub fn owner_migration(old_owners: &[usize], new_owners: &[usize]) -> OwnerMigration {
    assert_eq!(old_owners.len(), new_owners.len());
    let mut m = OwnerMigration::default();
    let mut changed = std::collections::BTreeSet::new();
    for (&o, &n) in old_owners.iter().zip(new_owners) {
        if o == n {
            continue;
        }
        if o / 2 != n / 2 {
            m.level1 += 1;
        } else {
            m.level2 += 1;
        }
        changed.insert(o);
        changed.insert(n);
    }
    m.changed_owners = changed.into_iter().collect();
    m
}

/// The level-2 split applied *inside one extracted block*: partition the
/// block's real elements into **boundary** (any face is a halo face, i.e.
/// touches an element owned by someone else — exactly the elements that
/// own communication) and **interior** (all faces local or physical
/// boundary). This is the same depth-0 / depth>=1 distinction as
/// [`boundary_depths`], but computed from the block-local `(K, 6)`
/// connectivity (`LOCAL_HALO` faces) so the in-node parallel backend can
/// classify without the global mesh. Both vectors preserve Morton order.
///
/// The result is a pure function of the block's immutable connectivity, so
/// callers on the stage hot path memoize it per block
/// (`solver::parallel::ParallelRefBackend` caches the split keyed on the
/// connectivity storage identity and reuses it every stage; the cache dies
/// exactly when a rebalance migration rebuilds the block).
pub fn split_block_elements(conn: &[i32], k_real: usize) -> (Vec<usize>, Vec<usize>) {
    let mut boundary = Vec::new();
    let mut interior = Vec::new();
    for e in 0..k_real {
        let faces = &conn[e * 6..e * 6 + 6];
        if faces.iter().any(|&c| c == crate::mesh::halo::LOCAL_HALO) {
            boundary.push(e);
        } else {
            interior.push(e);
        }
    }
    (boundary, interior)
}

/// Count faces between CPU- and MIC-owned elements of the same node — the
/// per-step PCI surface (each shared face transfers one trace each way).
pub fn pci_faces(mesh: &Mesh, np: &NestedPartition) -> Vec<usize> {
    let mut out = vec![0usize; np.node.nparts];
    for (e, c) in mesh.conn.iter().enumerate() {
        for &v in c {
            if v >= 0 {
                let v = v as usize;
                if np.node.assignment[e] == np.node.assignment[v]
                    && np.device[e] == DeviceKind::Mic
                    && np.device[v] == DeviceKind::Cpu
                {
                    out[np.node.assignment[e]] += 1;
                }
            }
        }
    }
    out
}

/// Verify the interior-only invariant: no MIC element touches another node.
pub fn check_interior_only(mesh: &Mesh, np: &NestedPartition) -> bool {
    for (e, c) in mesh.conn.iter().enumerate() {
        if np.device[e] == DeviceKind::Mic {
            for &v in c {
                if v >= 0 && np.node.assignment[v as usize] != np.node.assignment[e] {
                    return false;
                }
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::element::Material;
    use crate::partition::splice::splice;

    fn mesh(n: usize) -> Mesh {
        Mesh::structured_brick([n, n, n], [0.0; 3], [1.0; 3], |_| Material::acoustic(1.0, 1.0))
    }

    #[test]
    fn interior_only_invariant() {
        let m = mesh(8);
        let node = splice(&m, 4);
        for frac in [0.1, 0.3, 0.6, 0.9] {
            let np = nested_partition(&m, &node, frac);
            assert!(check_interior_only(&m, &np), "frac {frac}");
        }
    }

    #[test]
    fn counts_match_assignment() {
        let m = mesh(8);
        let node = splice(&m, 4);
        let np = nested_partition(&m, &node, 0.5);
        for nd in 0..4 {
            let cpu = (0..m.len())
                .filter(|&e| node.assignment[e] == nd && np.device[e] == DeviceKind::Cpu)
                .count();
            let mic = (0..m.len())
                .filter(|&e| node.assignment[e] == nd && np.device[e] == DeviceKind::Mic)
                .count();
            assert_eq!((cpu, mic), np.node_counts[nd]);
        }
    }

    #[test]
    fn requested_fraction_realized_when_feasible() {
        // single node: offloadable = strict interior (6^3 = 216 of 8^3)
        let m = mesh(8);
        let node = splice(&m, 1);
        let np = nested_partition(&m, &node, 0.25);
        assert_eq!(np.node_counts[0].1, 128, "feasible request fully realized");
        // an infeasible request clips to the interior count
        let np2 = nested_partition(&m, &node, 0.9);
        assert_eq!(np2.node_counts[0].1, 216, "clipped to interior elements");
    }

    #[test]
    fn zero_and_full_fraction() {
        let m = mesh(4);
        let node = splice(&m, 2);
        let np0 = nested_partition(&m, &node, 0.0);
        assert!(np0.device.iter().all(|&d| d == DeviceKind::Cpu));
        let np1 = nested_partition(&m, &node, 1.0);
        // full request: every interior element offloaded, boundary stays
        assert!(check_interior_only(&m, &np1));
        for nd in 0..2 {
            let (cpu, _) = np1.node_counts[nd];
            assert!(cpu > 0, "MPI-boundary elements must stay on the CPU");
        }
    }

    #[test]
    fn mic_surface_smaller_than_random_selection() {
        // onion peeling must beat random interior selection on PCI faces;
        // needs a mesh large enough that the choice matters (interior 1000,
        // selecting 518)
        let m = mesh(12);
        let node = splice(&m, 1);
        let np = nested_partition(&m, &node, 0.3);
        let pci = pci_faces(&m, &np)[0];
        // random baseline
        let mut rng = crate::util::Rng::seed_from_u64(7);
        let depths = boundary_depths(&m, &node.assignment, 0);
        let mut interior: Vec<usize> =
            depths.iter().filter(|&&(_, d)| d >= 1).map(|&(e, _)| e).collect();
        rng.shuffle(&mut interior);
        let k_mic = np.node_counts[0].1;
        let mut device = vec![DeviceKind::Cpu; m.len()];
        for &e in interior.iter().take(k_mic) {
            device[e] = DeviceKind::Mic;
        }
        let rand_np = NestedPartition {
            node: node.clone(),
            device,
            node_counts: vec![(m.len() - k_mic, k_mic)],
        };
        let pci_rand = pci_faces(&m, &rand_np)[0];
        assert!(
            (pci as f64) < 0.7 * pci_rand as f64,
            "onion {pci} vs random {pci_rand}"
        );
    }

    #[test]
    fn owners_encoding() {
        let m = mesh(4);
        let node = splice(&m, 2);
        let np = nested_partition(&m, &node, 0.3);
        let owners = np.owners();
        for (e, &o) in owners.iter().enumerate() {
            assert_eq!(o / 2, node.assignment[e]);
            assert_eq!(o % 2 == 1, np.device[e] == DeviceKind::Mic);
        }
    }

    #[test]
    fn block_split_matches_depth_zero() {
        // block-local classification must agree with the global depth-0 set
        let m = mesh(4);
        let node = splice(&m, 2);
        let (blocks, _) = crate::mesh::build_local_blocks(&m, &node.assignment, 2);
        for (nd, blk) in blocks.iter().enumerate() {
            let flat: Vec<i32> = blk.conn.iter().flatten().copied().collect();
            let (boundary, interior) = split_block_elements(&flat, blk.len());
            assert_eq!(boundary.len() + interior.len(), blk.len());
            let depths = boundary_depths(&m, &node.assignment, nd);
            let depth_of: std::collections::HashMap<usize, usize> = depths.into_iter().collect();
            for &e in &boundary {
                assert_eq!(depth_of[&blk.global_ids[e]], 0, "boundary elements sit at depth 0");
            }
            for &e in &interior {
                assert!(depth_of[&blk.global_ids[e]] >= 1, "interior elements sit deeper");
            }
        }
    }

    #[test]
    fn per_node_fractions_respected() {
        let m = mesh(8);
        let node = splice(&m, 2);
        let np = nested_partition_fractions(&m, &node, &[0.0, 0.3]);
        assert_eq!(np.node_counts[0].1, 0, "node 0 requested no MIC share");
        assert!(np.node_counts[1].1 > 0, "node 1 requested 30%");
        assert!(check_interior_only(&m, &np));
        // uniform fractions reduce to the single-fraction entry point
        let a = nested_partition(&m, &node, 0.25);
        let b = nested_partition_fractions(&m, &node, &[0.25, 0.25]);
        assert_eq!(a.node_counts, b.node_counts);
    }

    #[test]
    fn migration_diff_counts_moves() {
        let m = mesh(8);
        let node = splice(&m, 2);
        let old = nested_partition(&m, &node, 0.1);
        let new = nested_partition(&m, &node, 0.3);
        let diff = migration_diff(&old, &new);
        assert!(!diff.is_empty());
        // deepest-first selection is monotone: growing the fraction only
        // moves elements CPU -> MIC, never back
        assert!(diff.iter().all(|&(_, a, b)| a == DeviceKind::Cpu && b == DeviceKind::Mic));
        let moved: usize = diff.len();
        let grew: usize =
            (0..2).map(|nd| new.node_counts[nd].1 - old.node_counts[nd].1).sum();
        assert_eq!(moved, grew);
        assert!(migration_diff(&old, &old).is_empty());
    }

    #[test]
    fn owner_migration_classifies_levels() {
        let m = mesh(8);
        let node = splice(&m, 2);
        let old = nested_partition(&m, &node, 0.1);
        // pure level-2 move: same node partition, bigger MIC share
        let new = nested_partition(&m, &node, 0.3);
        let mig = owner_migration(&old.owners(), &new.owners());
        assert_eq!(mig.level1, 0);
        assert!(mig.level2 > 0);
        assert_eq!(mig.total(), migration_diff(&old, &new).len());
        // changed owners are exactly the movers' endpoints
        for &(e, _, _) in &migration_diff(&old, &new) {
            assert!(mig.changed_owners.contains(&old.owners()[e]));
            assert!(mig.changed_owners.contains(&new.owners()[e]));
        }
        // level-1 move: shift the splice boundary by a few elements
        let mut shifted = node.clone();
        for a in shifted.assignment.iter_mut().take(m.len() / 2 + 5) {
            *a = 0;
        }
        let new1 = nested_partition(&m, &shifted, 0.1);
        let mig1 = owner_migration(&old.owners(), &new1.owners());
        assert!(mig1.level1 >= 5, "{mig1:?}");
        // identity is a no-op
        let noop = owner_migration(&old.owners(), &old.owners());
        assert_eq!(noop.total(), 0);
        assert!(noop.changed_owners.is_empty());
    }

    #[test]
    fn depths_zero_on_mpi_boundary() {
        let m = mesh(4);
        let node = splice(&m, 2);
        let depths = boundary_depths(&m, &node.assignment, 0);
        for (e, d) in depths {
            let mpi = m.conn[e]
                .iter()
                .any(|&v| v >= 0 && node.assignment[v as usize] != 0);
            if mpi {
                assert_eq!(d, 0);
            }
        }
    }
}
