//! Level-1 partitioning: splice the Morton-ordered element array.
//!
//! "The elements can be ordered according to a global Morton ordering, in
//! effect producing a one-dimensional array of elements which is then
//! spliced into roughly equally-sized sub-arrays. [...] This procedure is
//! approximately optimal with respect to minimizing communication between
//! subdomains." (paper §5.1)

use crate::mesh::Mesh;

/// An element -> part assignment.
#[derive(Debug, Clone)]
pub struct Partition {
    pub assignment: Vec<usize>,
    pub nparts: usize,
}

impl Partition {
    /// Element count per part.
    pub fn sizes(&self) -> Vec<usize> {
        let mut s = vec![0usize; self.nparts];
        for &p in &self.assignment {
            s[p] += 1;
        }
        s
    }

    /// Max/min size imbalance ratio (1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        let s = self.sizes();
        let max = *s.iter().max().unwrap_or(&0) as f64;
        let min = *s.iter().min().unwrap_or(&0) as f64;
        if min == 0.0 {
            f64::INFINITY
        } else {
            max / min
        }
    }
}

/// Equal-count contiguous splice of `n` Morton-ordered elements (the pure
/// count form, shared by [`splice`] and the degenerate-weight fallback of
/// [`splice_weighted`]).
pub fn splice_counts(n: usize, nparts: usize) -> Partition {
    assert!(nparts >= 1, "need at least one part");
    let mut assignment = vec![0usize; n];
    // distribute the remainder one extra element to the first (n % p) parts,
    // exactly like an MPI block distribution
    let live = nparts.min(n.max(1));
    let base = n / live;
    let extra = n % live;
    let mut e = 0;
    for p in 0..live {
        let count = base + usize::from(p < extra);
        for _ in 0..count {
            assignment[e] = p;
            e += 1;
        }
    }
    Partition { assignment, nparts }
}

/// Equal-count contiguous splice of the (already Morton-sorted) mesh.
pub fn splice(mesh: &Mesh, nparts: usize) -> Partition {
    let n = mesh.len();
    assert!(nparts >= 1 && nparts <= n, "need 1 <= nparts ({nparts}) <= n ({n})");
    splice_counts(n, nparts)
}

/// Weighted splice: chunk boundaries chosen so per-part weight is balanced.
/// Used when element cost varies (mixed polynomial orders in hp), and by
/// the two-level rebalancer ([`crate::coordinator::rebalance`]), where each
/// element carries the measured per-element rate of the node currently
/// owning it — re-splicing every R steps then walks the level-1 boundaries
/// toward the equal-time point.
///
/// Robustness contract (this sees live measured data):
/// * non-finite or non-positive weights are treated as zero;
/// * an all-zero weight vector carries no balance information and falls
///   back to the equal-count splice;
/// * `nparts > weights.len()` assigns one element to each of the first
///   `len` parts and leaves the tail parts empty;
/// * otherwise every part receives at least one element (the cluster
///   runtime owns one chunk per live node), so a single huge weight
///   cannot starve the remaining parts.
pub fn splice_weighted(weights: &[f64], nparts: usize) -> Partition {
    let n = weights.len();
    assert!(nparts >= 1, "need at least one part");
    if nparts > n {
        return Partition { assignment: (0..n).collect(), nparts };
    }
    let clean = |w: f64| if w.is_finite() && w > 0.0 { w } else { 0.0 };
    let total: f64 = weights.iter().map(|&w| clean(w)).sum();
    if total <= 0.0 {
        return splice_counts(n, nparts);
    }
    let target = total / nparts as f64;
    let mut assignment = vec![0usize; n];
    let mut part = 0usize;
    let mut acc = 0.0;
    let mut in_part = 0usize;
    for (e, &w) in weights.iter().enumerate() {
        let w = clean(w);
        if part + 1 < nparts && in_part > 0 {
            // close the chunk when adding this element would overshoot the
            // running target more than it undershoots — or when exactly one
            // element per remaining part is left (feasibility floor)
            let must = n - e == nparts - part - 1;
            let want = acc + w / 2.0 > target * (part + 1) as f64;
            if must || want {
                part += 1;
                in_part = 0;
            }
        }
        assignment[e] = part;
        in_part += 1;
        acc += w;
    }
    Partition { assignment, nparts }
}

/// Weighted splice over the *active* subset of a degraded membership:
/// splice across the live parts only, then remap chunk indices back to the
/// caller's part ids so inactive (dead or not-yet-joined spare) parts end
/// up with zero elements. This is the recovery/elastic form of
/// [`splice_weighted`] — that function guarantees every part at least one
/// element, which would re-feed a dead node.
pub fn splice_weighted_excluding(weights: &[f64], nparts: usize, active: &[bool]) -> Partition {
    assert_eq!(active.len(), nparts, "active mask must cover all parts");
    let live: Vec<usize> = (0..nparts).filter(|&p| active[p]).collect();
    assert!(!live.is_empty(), "cannot splice with zero active parts");
    let inner = splice_weighted(weights, live.len());
    Partition {
        assignment: inner.assignment.iter().map(|&p| live[p]).collect(),
        nparts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::element::Material;
    use crate::mesh::Mesh;

    fn mesh(n: usize) -> Mesh {
        Mesh::structured_brick([n, n, n], [0.0; 3], [1.0; 3], |_| Material::acoustic(1.0, 1.0))
    }

    #[test]
    fn splice_equal_sizes() {
        let m = mesh(4);
        let p = splice(&m, 8);
        assert_eq!(p.sizes(), vec![8; 8]);
        assert!((p.imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn splice_remainder_distribution() {
        let m = mesh(3); // 27 elements
        let p = splice(&m, 4);
        let mut sizes = p.sizes();
        sizes.sort();
        assert_eq!(sizes, vec![6, 7, 7, 7]);
    }

    #[test]
    fn splice_is_contiguous() {
        let m = mesh(4);
        let p = splice(&m, 5);
        for w in p.assignment.windows(2) {
            assert!(w[1] == w[0] || w[1] == w[0] + 1);
        }
    }

    #[test]
    fn splice_locality_beats_random() {
        // morton splice should expose far fewer cross-part faces than a
        // random assignment — the property the paper relies on
        let m = mesh(8);
        let p = splice(&m, 8);
        let cross_splice = cross_faces(&m, &p.assignment);
        let mut rng = crate::util::Rng::seed_from_u64(42);
        let mut shuffled = p.assignment.clone();
        rng.shuffle(&mut shuffled);
        let cross_rand = cross_faces(&m, &shuffled);
        assert!(
            (cross_splice as f64) < 0.5 * cross_rand as f64,
            "splice {cross_splice} vs random {cross_rand}"
        );
    }

    fn cross_faces(m: &Mesh, owners: &[usize]) -> usize {
        let mut n = 0;
        for (e, c) in m.conn.iter().enumerate() {
            for &v in c {
                if v >= 0 && owners[v as usize] != owners[e] {
                    n += 1;
                }
            }
        }
        n / 2
    }

    #[test]
    fn excluding_splice_starves_inactive_parts() {
        let weights = vec![1.0; 30];
        let p = splice_weighted_excluding(&weights, 4, &[true, false, true, true]);
        let sizes = p.sizes();
        assert_eq!(p.nparts, 4);
        assert_eq!(sizes[1], 0, "dead part must receive nothing: {sizes:?}");
        assert_eq!(sizes.iter().sum::<usize>(), 30);
        for &p in [0usize, 2, 3].iter() {
            assert!(sizes[p] >= 9, "live parts share evenly: {sizes:?}");
        }
        // contiguity is preserved over live parts
        for w in p.assignment.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn weighted_splice_balances_weight() {
        let weights: Vec<f64> = (0..100).map(|i| 1.0 + (i % 7) as f64).collect();
        let p = splice_weighted(&weights, 4);
        let mut wsum = vec![0.0; 4];
        for (e, &part) in p.assignment.iter().enumerate() {
            wsum[part] += weights[e];
        }
        let total: f64 = weights.iter().sum();
        for w in &wsum {
            assert!((w - total / 4.0).abs() < total * 0.05, "{wsum:?}");
        }
    }
}
