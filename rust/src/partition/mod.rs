//! The paper's nested two-level partitioning scheme (§5.5).
//!
//! Level 1 ([`splice`]): the Morton-ordered global element array is spliced
//! into one contiguous, (weight-)balanced chunk per compute node — mangll's
//! existing homogeneous load balancing, reused unchanged.
//!
//! Level 2 ([`nested`]): each node's chunk is split asymmetrically between
//! its CPU and its accelerator under three constraints (paper §5.5):
//!   1. only *interior* elements (no face shared with another node) may be
//!      offloaded to the MIC — the accelerator never talks to the network;
//!   2. the CPU<->MIC shared surface (PCI traffic) is minimized;
//!   3. the element-count ratio comes from the heterogeneous load balance
//!      solve T_MIC(N, K_mic) = T_CPU(N, K_cpu) + T_PCI(K_mic)
//!      ([`balance`], paper §5.6).

pub mod balance;
pub mod nested;
pub mod splice;
pub mod stats;

pub use balance::{solve_equal_finish, solve_mic_fraction};
pub use nested::{
    migration_diff, nested_partition, nested_partition_fractions, owner_migration, DeviceKind,
    NestedPartition, OwnerMigration,
};
pub use splice::{
    splice, splice_counts, splice_weighted, splice_weighted_excluding, Partition,
};
pub use stats::{partition_stats, PartitionStats};
