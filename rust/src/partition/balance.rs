//! Heterogeneous CPU/MIC load balancing (paper §5.6).
//!
//! Solve, for a node owning K elements at order N:
//!
//! ```text
//!   T_MIC(N, K_mic)  =  T_CPU(N, K - K_mic) + PCI_time(K_mic)
//! ```
//!
//! where PCI_time assumes the MIC partition's surface is minimal (a cube:
//! 6 K_mic^{2/3} shared faces). The computation on the MIC runs
//! asynchronously, so the optimum is the equal-finish point — the crossing
//! of the two curves in Fig 5.2. Solved by bisection on K_mic (both sides
//! are monotone in K_mic).

use crate::costmodel::pci::PciModel;
use crate::costmodel::{DeviceModel, NodeModel};

/// Estimated per-step time of the CPU side: its elements' volume work,
/// its share of interior faces (~3 per element), boundary faces, the
/// PCI-adjacent faces it co-computes, plus the PCI exchange itself
/// (the host drives the bus, paper §5.6 puts PCI_time in T_CPU).
pub fn t_cpu(dev: &DeviceModel, pci: &PciModel, n: usize, k_cpu: f64, k_mic: f64) -> f64 {
    let shared = mic_surface_faces(k_mic);
    let int_faces = 3.0 * k_cpu;
    let bound_faces = 6.0 * k_cpu.powf(2.0 / 3.0);
    dev.step_time(n, k_cpu.round() as usize, int_faces as usize, bound_faces as usize, shared as usize)
        + pci.step_exchange_time(shared as usize, n)
}

/// Estimated per-step time of the MIC side.
pub fn t_mic(dev: &DeviceModel, n: usize, k_mic: f64) -> f64 {
    let shared = mic_surface_faces(k_mic);
    let int_faces = 3.0 * k_mic;
    dev.step_time(n, k_mic.round() as usize, int_faces as usize, 0, shared as usize)
}

/// Minimal-surface face count of a K-element partition (cube ansatz).
pub fn mic_surface_faces(k_mic: f64) -> f64 {
    if k_mic <= 0.0 {
        0.0
    } else {
        6.0 * k_mic.powf(2.0 / 3.0)
    }
}

/// Result of the balance solve.
#[derive(Debug, Clone, Copy)]
pub struct BalanceSolution {
    pub k_mic: usize,
    pub k_cpu: usize,
    /// K_MIC / K_CPU — the paper reports 1.6 at N=7, K=8192.
    pub ratio: f64,
    /// Predicted per-step times at the optimum.
    pub t_cpu_s: f64,
    pub t_mic_s: f64,
}

/// Generic bisection solve of the equal-finish point
/// `t_mic_of(K_mic) = t_cpu_of(K_mic)` over K_mic in [0, K]. Both cost
/// curves take the *MIC* element count (a CPU curve internally works on
/// K - K_mic). Shared by the calibrated solve below and the measured-rate
/// adaptive rebalancer ([`crate::coordinator::cluster`]), which feeds live
/// [`crate::solver::reference::KernelTimes`] back through
/// [`solve_mic_fraction`] via a refitted node model.
pub fn solve_equal_finish(
    k: usize,
    t_mic_of: impl Fn(f64) -> f64,
    t_cpu_of: impl Fn(f64) -> f64,
) -> BalanceSolution {
    // f(0) < 0 (idle MIC), f(K) > 0 (idle CPU): bisect the sign change
    let f = |k_mic: f64| t_mic_of(k_mic) - t_cpu_of(k_mic);
    let (mut lo, mut hi) = (0.0, k as f64);
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if f(mid) < 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let k_mic = (0.5 * (lo + hi)).round() as usize;
    let k_cpu = k - k_mic;
    BalanceSolution {
        k_mic,
        k_cpu,
        ratio: k_mic as f64 / k_cpu.max(1) as f64,
        t_cpu_s: t_cpu_of(k_mic as f64),
        t_mic_s: t_mic_of(k_mic as f64),
    }
}

/// Bisection solve of T_MIC(K_mic) = T_CPU(K - K_mic) over K_mic in [0, K].
pub fn solve_mic_fraction(node: &NodeModel, n: usize, k: usize) -> BalanceSolution {
    let kf = k as f64;
    solve_equal_finish(
        k,
        |k_mic| t_mic(&node.mic, n, k_mic),
        |k_mic| t_cpu(&node.cpu_vec, &node.pci, n, kf - k_mic, k_mic),
    )
}

/// Sweep the MIC load fraction (Fig 5.2): returns (fraction, t_cpu, t_mic)
/// rows for plotting/printing the crossover.
pub fn sweep_fractions(
    node: &NodeModel,
    n: usize,
    k: usize,
    points: usize,
) -> Vec<(f64, f64, f64)> {
    (0..=points)
        .map(|i| {
            let frac = i as f64 / points as f64;
            let k_mic = frac * k as f64;
            (
                frac,
                t_cpu(&node.cpu_vec, &node.pci, n, k as f64 - k_mic, k_mic),
                t_mic(&node.mic, n, k_mic),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::calib::{stampede_node, PAPER_ELEMS_PER_NODE, PAPER_ORDER};

    #[test]
    fn paper_operating_point_ratio() {
        let node = stampede_node();
        let sol = solve_mic_fraction(&node, PAPER_ORDER, PAPER_ELEMS_PER_NODE);
        assert!(
            (1.35..=1.85).contains(&sol.ratio),
            "K_MIC/K_CPU = {:.2}, paper says 1.6",
            sol.ratio
        );
        // equal finish within 2%
        let rel = (sol.t_cpu_s - sol.t_mic_s).abs() / sol.t_cpu_s;
        assert!(rel < 0.02, "imbalance {rel}");
    }

    #[test]
    fn balance_conserves_elements() {
        let node = stampede_node();
        for k in [512, 4096, 8192, 32768] {
            let sol = solve_mic_fraction(&node, 7, k);
            assert_eq!(sol.k_mic + sol.k_cpu, k);
        }
    }

    #[test]
    fn curves_cross_once() {
        let node = stampede_node();
        let rows = sweep_fractions(&node, 7, 8192, 64);
        let mut signs = Vec::new();
        for (_, tc, tm) in &rows {
            signs.push(tm > tc);
        }
        let flips = signs.windows(2).filter(|w| w[0] != w[1]).count();
        assert_eq!(flips, 1, "exactly one crossover (Fig 5.2)");
    }

    #[test]
    fn mic_fraction_shrinks_at_low_order() {
        // at low N the flux/PCI overheads weigh more; the MIC should get
        // a smaller relative share than at N=7
        let node = stampede_node();
        let hi = solve_mic_fraction(&node, 7, 8192);
        let lo = solve_mic_fraction(&node, 1, 8192);
        assert!(lo.ratio < hi.ratio, "lo {} hi {}", lo.ratio, hi.ratio);
    }

    #[test]
    fn equal_finish_generic_crossing() {
        // t_mic = 2 k_mic, t_cpu = (K - k_mic): crossing at K/3
        let sol = solve_equal_finish(1000, |km| 2.0 * km, |km| 1000.0 - km);
        assert!((sol.k_mic as i64 - 333).abs() <= 1, "{:?}", sol.k_mic);
        assert_eq!(sol.k_mic + sol.k_cpu, 1000);
        // returned times are evaluated at the crossing: nearly equal
        assert!((sol.t_cpu_s - sol.t_mic_s).abs() < 3.0);
    }

    #[test]
    fn t_cpu_monotone_in_k() {
        let node = stampede_node();
        let t1 = t_cpu(&node.cpu_vec, &node.pci, 7, 1000.0, 500.0);
        let t2 = t_cpu(&node.cpu_vec, &node.pci, 7, 2000.0, 500.0);
        assert!(t2 > t1);
    }
}
