//! Partition quality metrics: the quantities the cost models consume.

use super::nested::{DeviceKind, NestedPartition};
use crate::mesh::Mesh;

/// Per-node face/element counts for one nested partition.
#[derive(Debug, Clone, Default)]
pub struct NodeStats {
    pub k_cpu: usize,
    pub k_mic: usize,
    /// CPU-side element faces against same-node CPU elements (counted once).
    pub cpu_int_faces: usize,
    /// MIC-side element faces against same-node MIC elements (counted once).
    pub mic_int_faces: usize,
    /// CPU<->MIC faces inside the node (PCI traffic, counted once).
    pub pci_faces: usize,
    /// Faces against other nodes (MPI traffic, counted once per node side).
    pub mpi_faces: usize,
    /// Physical boundary faces handled by the CPU partition.
    pub bound_faces_cpu: usize,
    /// Physical boundary faces handled by the MIC partition (possible in
    /// multi-node runs: "interior" excludes only MPI faces).
    pub bound_faces_mic: usize,
}

impl NodeStats {
    pub fn bound_faces(&self) -> usize {
        self.bound_faces_cpu + self.bound_faces_mic
    }
}

/// Aggregate stats for the whole cluster partition.
#[derive(Debug, Clone)]
pub struct PartitionStats {
    pub per_node: Vec<NodeStats>,
}

impl PartitionStats {
    pub fn total_pci_faces(&self) -> usize {
        self.per_node.iter().map(|s| s.pci_faces).sum()
    }

    pub fn total_mpi_faces(&self) -> usize {
        self.per_node.iter().map(|s| s.mpi_faces).sum()
    }

    pub fn max_mpi_faces(&self) -> usize {
        self.per_node.iter().map(|s| s.mpi_faces).max().unwrap_or(0)
    }
}

/// Count every face class of a nested partition.
pub fn partition_stats(mesh: &Mesh, np: &NestedPartition) -> PartitionStats {
    let mut per_node = vec![NodeStats::default(); np.node.nparts];
    for nd in 0..np.node.nparts {
        per_node[nd].k_cpu = np.node_counts[nd].0;
        per_node[nd].k_mic = np.node_counts[nd].1;
    }
    for (e, c) in mesh.conn.iter().enumerate() {
        let nd = np.node.assignment[e];
        let dev = np.device[e];
        let s = &mut per_node[nd];
        for &v in c {
            if v < 0 {
                match dev {
                    DeviceKind::Cpu => s.bound_faces_cpu += 1,
                    DeviceKind::Mic => s.bound_faces_mic += 1,
                }
                continue;
            }
            let v = v as usize;
            let nd2 = np.node.assignment[v];
            if nd2 != nd {
                s.mpi_faces += 1; // counted from this node's side
                continue;
            }
            // same node: count each interior pair once (e < v)
            match (dev, np.device[v]) {
                (DeviceKind::Cpu, DeviceKind::Cpu) => {
                    if e < v {
                        s.cpu_int_faces += 1;
                    }
                }
                (DeviceKind::Mic, DeviceKind::Mic) => {
                    if e < v {
                        s.mic_int_faces += 1;
                    }
                }
                (DeviceKind::Mic, DeviceKind::Cpu) => s.pci_faces += 1,
                (DeviceKind::Cpu, DeviceKind::Mic) => {} // counted from MIC side
            }
        }
    }
    PartitionStats { per_node }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::element::Material;
    use crate::partition::nested::nested_partition;
    use crate::partition::splice::splice;

    fn mesh(n: usize) -> Mesh {
        Mesh::structured_brick([n, n, n], [0.0; 3], [1.0; 3], |_| Material::acoustic(1.0, 1.0))
    }

    #[test]
    fn face_classes_partition_all_faces() {
        let m = mesh(8);
        let node = splice(&m, 4);
        let np = nested_partition(&m, &node, 0.5);
        let st = partition_stats(&m, &np);
        let (int_total, bound_total) = m.face_counts();
        let counted: usize = st
            .per_node
            .iter()
            .map(|s| s.cpu_int_faces + s.mic_int_faces + s.pci_faces)
            .sum::<usize>()
            + st.total_mpi_faces() / 2; // mpi faces counted from both sides
        assert_eq!(counted, int_total);
        let bounds: usize = st.per_node.iter().map(|s| s.bound_faces()).sum();
        assert_eq!(bounds, bound_total);
    }

    #[test]
    fn elements_match_counts() {
        let m = mesh(8);
        let node = splice(&m, 2);
        let np = nested_partition(&m, &node, 0.4);
        let st = partition_stats(&m, &np);
        let k: usize = st.per_node.iter().map(|s| s.k_cpu + s.k_mic).sum();
        assert_eq!(k, m.len());
    }

    #[test]
    fn mic_surface_close_to_cube_ansatz() {
        // the onion-peeled MIC set should expose a surface within ~2.5x of
        // the ideal cube (it is constrained inside the node's chunk shape)
        let m = mesh(8);
        let node = splice(&m, 1);
        let np = nested_partition(&m, &node, 0.4);
        let st = partition_stats(&m, &np);
        let k_mic = st.per_node[0].k_mic as f64;
        let ideal = 6.0 * k_mic.powf(2.0 / 3.0);
        let actual = st.per_node[0].pci_faces as f64;
        assert!(
            actual < 2.5 * ideal,
            "pci faces {actual} vs ideal cube {ideal}"
        );
    }

    #[test]
    fn no_mic_no_pci() {
        let m = mesh(4);
        let node = splice(&m, 2);
        let np = nested_partition(&m, &node, 0.0);
        let st = partition_stats(&m, &np);
        assert_eq!(st.total_pci_faces(), 0);
    }
}
