//! A small criterion-style bench harness (the offline build has no
//! criterion). `cargo bench` runs the `benches/*.rs` binaries, which use
//! [`Bench`] for warmup + timed sampling and print mean / p50 / p95 /
//! throughput lines that the perf log in EXPERIMENTS.md quotes directly.
//! [`JsonSink`] additionally writes the samples in machine-readable form
//! (e.g. `BENCH_rhs.json`) so the perf trajectory can be tracked across
//! PRs; see PERF.md for the schema.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::json::Json;

pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn percentile(&self, p: f64) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() - 1) as f64 * p).round() as usize;
        s[idx]
    }

    pub fn report(&self) {
        println!(
            "{:<44} mean {:>11} p50 {:>11} p95 {:>11} ({} samples)",
            self.name,
            fmt_t(self.mean()),
            fmt_t(self.percentile(0.5)),
            fmt_t(self.percentile(0.95)),
            self.samples.len(),
        );
    }

    /// Report with an items/sec throughput line.
    pub fn report_throughput(&self, items: usize, unit: &str) {
        self.report();
        println!(
            "{:<44} {:>10.0} {unit}/s",
            "",
            items as f64 / self.mean()
        );
    }
}

fn fmt_t(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

pub struct Bench {
    pub warmup_iters: usize,
    pub sample_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 3, sample_iters: 10 }
    }
}

/// Collects bench entries and writes them as a JSON array, one object per
/// benchmark: `{"name": ..., "ns_per_iter": ..., "items_per_s": ...,
/// "unit": ..., "p50_ns": ..., "p95_ns": ..., "samples": N}`.
/// `items_per_s` is null when the bench has no throughput notion.
#[derive(Debug, Default)]
pub struct JsonSink {
    entries: Vec<Json>,
}

impl JsonSink {
    pub fn new() -> Self {
        JsonSink::default()
    }

    /// Record one result; `items` per iteration (with its unit name, e.g.
    /// "elem-stages") yields the throughput field.
    pub fn push(&mut self, r: &BenchResult, items: Option<(usize, &str)>) {
        let mut obj = BTreeMap::new();
        obj.insert("name".to_string(), Json::Str(r.name.clone()));
        obj.insert("ns_per_iter".to_string(), Json::Num(r.mean() * 1e9));
        obj.insert("p50_ns".to_string(), Json::Num(r.percentile(0.5) * 1e9));
        obj.insert("p95_ns".to_string(), Json::Num(r.percentile(0.95) * 1e9));
        obj.insert("samples".to_string(), Json::Num(r.samples.len() as f64));
        match items {
            Some((n, unit)) => {
                obj.insert("items_per_s".to_string(), Json::Num(n as f64 / r.mean()));
                obj.insert("unit".to_string(), Json::Str(unit.to_string()));
            }
            None => {
                obj.insert("items_per_s".to_string(), Json::Null);
                obj.insert("unit".to_string(), Json::Null);
            }
        }
        self.entries.push(Json::Obj(obj));
    }

    /// Record one dimensionless metric (e.g. a parallel efficiency or a
    /// max/mean imbalance): `{"name": ..., "value": ..., "unit": ...}`.
    /// Scalar entries sit alongside timing entries in the same array;
    /// consumers distinguish them by the presence of the `value` key.
    pub fn push_scalar(&mut self, name: &str, value: f64, unit: &str) {
        let mut obj = BTreeMap::new();
        obj.insert("name".to_string(), Json::Str(name.to_string()));
        obj.insert("value".to_string(), Json::Num(value));
        obj.insert("unit".to_string(), Json::Str(unit.to_string()));
        self.entries.push(Json::Obj(obj));
    }

    /// Record one pre-built entry (e.g. a serving-layer `JobReport`
    /// record). The sink stays a flat array; consumers distinguish entry
    /// kinds by their keys, so record objects ride alongside timing and
    /// scalar entries.
    pub fn push_entry(&mut self, entry: Json) {
        self.entries.push(entry);
    }

    /// Serialize all entries as a JSON array.
    pub fn dump(&self) -> String {
        Json::Arr(self.entries.clone()).dump()
    }

    /// Write to `path`, replacing any previous run's file.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.dump())
    }
}

impl Bench {
    pub fn new(warmup: usize, samples: usize) -> Self {
        Bench { warmup_iters: warmup, sample_iters: samples }
    }

    /// Time `f` (one sample per call) after warmup.
    pub fn run(&self, name: &str, mut f: impl FnMut()) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let r = BenchResult { name: name.to_string(), samples };
        r.report();
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let r = BenchResult {
            name: "x".into(),
            samples: vec![1.0, 2.0, 3.0, 4.0, 5.0],
        };
        assert_eq!(r.percentile(0.0), 1.0);
        assert_eq!(r.percentile(1.0), 5.0);
        assert_eq!(r.percentile(0.5), 3.0);
        assert!((r.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn json_sink_schema() {
        let r = BenchResult { name: "stage_n7".into(), samples: vec![0.5, 0.5] };
        let mut sink = JsonSink::new();
        sink.push(&r, Some((64, "elem-stages")));
        sink.push(&r, None);
        let j = Json::parse(&sink.dump()).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(), "stage_n7");
        let ns = arr[0].get("ns_per_iter").unwrap().as_f64().unwrap();
        assert!((ns - 0.5e9).abs() < 1.0);
        let tput = arr[0].get("items_per_s").unwrap().as_f64().unwrap();
        assert!((tput - 128.0).abs() < 1e-9);
        assert_eq!(arr[0].get("unit").unwrap().as_str().unwrap(), "elem-stages");
        assert!(matches!(arr[1].get("items_per_s").unwrap(), Json::Null));
    }

    #[test]
    fn scalar_entries_roundtrip() {
        let mut sink = JsonSink::new();
        sink.push_scalar("cluster_imbalance_static", 1.85, "max_over_mean");
        let j = Json::parse(&sink.dump()).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(), "cluster_imbalance_static");
        let v = arr[0].get("value").unwrap().as_f64().unwrap();
        assert!((v - 1.85).abs() < 1e-12);
        assert_eq!(arr[0].get("unit").unwrap().as_str().unwrap(), "max_over_mean");
    }

    #[test]
    fn run_collects_samples() {
        let b = Bench::new(1, 5);
        let mut n = 0u64;
        let r = b.run("noop", || n += 1);
        assert_eq!(r.samples.len(), 5);
        assert_eq!(n, 6);
    }
}
