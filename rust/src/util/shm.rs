//! Lock-free SPSC slot ring for the shared-memory fabric lane
//! ([`crate::coordinator::transport`]).
//!
//! One ring per directed worker pair. Records are fixed-stride slots of
//! `u32` words — two header words, a payload length, then up to
//! `payload_words` of f32 bit patterns — so a halo trace is written once
//! by the producer into the slot and read once by the consumer straight
//! into the destination block's halo storage: no intermediate
//! serialization, no queue-node allocation, no locks.
//!
//! Single-producer / single-consumer is enforced by construction:
//! [`slot_ring`] returns a ([`RingProducer`], [`RingConsumer`]) handle
//! pair and neither is `Clone`. Head/tail are `AtomicUsize` on separate
//! cache lines with release/acquire publication — the classic
//! Lamport-style SPSC queue, specialized to fixed slots.

use std::cell::UnsafeCell;

// Atomics come through the util::sync shim so the loom suite can
// model-check the push/pop pair (`rust/tests/loom_models.rs`).
use crate::util::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::util::sync::Arc;

/// Pad to a cache line so the producer's tail and the consumer's head
/// never false-share.
#[repr(align(64))]
struct CachePadded(AtomicUsize);

struct RingShared {
    /// Slot storage: `slots * stride` u32 words.
    buf: Box<[UnsafeCell<u32>]>,
    /// Words per slot: 3 header words + payload capacity.
    stride: usize,
    /// Max payload f32 words per record.
    payload_words: usize,
    /// Slot count, power of two (mask = slots - 1).
    mask: usize,
    /// Next slot the consumer will read. Written by consumer only.
    head: CachePadded,
    /// Next slot the producer will write. Written by producer only.
    tail: CachePadded,
    /// Either side can close; the other observes it on its next op.
    closed: AtomicBool,
}

// SAFETY: the UnsafeCell storage is partitioned by the head/tail
// indices — slots in [head, tail) are owned by the consumer, the rest
// by the producer — with release/acquire handoff on tail/head (the same
// argument as std's mpsc internals), so moving the shared state to
// another thread is sound.
unsafe impl Send for RingShared {}
// SAFETY: shared access is the whole point — exactly one producer and
// one consumer exist by construction (`slot_ring` returns one
// non-Clone handle each) and they touch disjoint slots per the
// ownership argument above.
unsafe impl Sync for RingShared {}

/// Producer half: `try_push` is wait-free (fails fast when full).
pub struct RingProducer {
    ring: Arc<RingShared>,
}

/// Consumer half: `try_pop_with` hands the slot payload to a closure by
/// reference, so the caller can copy it straight to its destination.
pub struct RingConsumer {
    ring: Arc<RingShared>,
}

/// Build an SPSC slot ring with at least `min_slots` slots (rounded up
/// to a power of two, minimum 4) of `payload_words` f32 capacity each.
pub fn slot_ring(min_slots: usize, payload_words: usize) -> (RingProducer, RingConsumer) {
    let slots = min_slots.max(4).next_power_of_two();
    let stride = 3 + payload_words;
    let buf: Box<[UnsafeCell<u32>]> = (0..slots * stride).map(|_| UnsafeCell::new(0)).collect();
    let ring = Arc::new(RingShared {
        buf,
        stride,
        payload_words,
        mask: slots - 1,
        head: CachePadded(AtomicUsize::new(0)),
        tail: CachePadded(AtomicUsize::new(0)),
        closed: AtomicBool::new(false),
    });
    (RingProducer { ring: ring.clone() }, RingConsumer { ring })
}

impl RingProducer {
    /// Try to publish one record. Returns `Ok(true)` if published,
    /// `Ok(false)` if the ring is full (caller should drain its own
    /// inbound lanes and retry), `Err` if the consumer closed.
    pub fn try_push(&mut self, w0: u32, w1: u32, payload: &[f32]) -> Result<bool, RingClosed> {
        let r = &*self.ring;
        assert!(
            payload.len() <= r.payload_words,
            "ring record payload {} exceeds slot capacity {}",
            payload.len(),
            r.payload_words
        );
        if r.closed.load(Ordering::Acquire) {
            return Err(RingClosed);
        }
        // Relaxed: tail is producer-owned — only this thread stores it,
        // so its own last value is always visible; no data rides on it.
        let tail = r.tail.0.load(Ordering::Relaxed);
        // Acquire: pairs with the consumer's Release store of head, so
        // the consumer's reads of a recycled slot happen-before our
        // writes into it.
        let head = r.head.0.load(Ordering::Acquire);
        if tail.wrapping_sub(head) > r.mask {
            return Ok(false); // full
        }
        let base = (tail & r.mask) * r.stride;
        // SAFETY: slot `tail & mask` is producer-owned (not in
        // [head, tail), per the full check above), so no concurrent
        // reader exists; `base + 3 + payload.len()` stays within the
        // slot because `payload.len() <= payload_words` was asserted and
        // stride = 3 + payload_words. The u32/f32 cast is a bit copy of
        // equal-size Pod types.
        unsafe {
            *r.buf[base].get() = w0;
            *r.buf[base + 1].get() = w1;
            *r.buf[base + 2].get() = payload.len() as u32;
            let dst = r.buf[base + 3].get();
            std::ptr::copy_nonoverlapping(payload.as_ptr() as *const u32, dst, payload.len());
        }
        // Release: publishes the slot writes above to the consumer's
        // Acquire load of tail.
        r.tail.0.store(tail.wrapping_add(1), Ordering::Release);
        Ok(true)
    }

    /// Signal the consumer that no more records will come.
    pub fn close(&mut self) {
        self.ring.closed.store(true, Ordering::Release);
    }
}

impl Drop for RingProducer {
    fn drop(&mut self) {
        self.close();
    }
}

/// The other side of the ring is gone.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RingClosed;

impl std::fmt::Display for RingClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "shm ring closed by peer")
    }
}

impl std::error::Error for RingClosed {}

impl RingConsumer {
    /// Pop one record if available, handing `(w0, w1, payload)` to `f`
    /// while the slot is still owned by the consumer; the slot is
    /// released after `f` returns. `None` means the ring is currently
    /// empty (check [`RingConsumer::is_closed`] to distinguish
    /// drained-and-closed from momentarily-empty).
    pub fn try_pop_with<T>(&mut self, f: impl FnOnce(u32, u32, &[f32]) -> T) -> Option<T> {
        let r = &*self.ring;
        // Relaxed: head is consumer-owned — only this thread stores it,
        // so its own last value is always visible; no data rides on it.
        let head = r.head.0.load(Ordering::Relaxed);
        // Acquire: pairs with the producer's Release store of tail, so
        // the producer's slot writes happen-before our reads below.
        let tail = r.tail.0.load(Ordering::Acquire);
        if head == tail {
            return None;
        }
        let base = (head & r.mask) * r.stride;
        // SAFETY: slot `head & mask` is consumer-owned (in [head, tail),
        // per the non-empty check above) and the producer's writes to it
        // are published by the tail Acquire; `len <= payload_words`
        // (enforced at push) keeps the borrowed slice inside the slot,
        // and the slice dies with `f` before head is advanced.
        let out = unsafe {
            let w0 = *r.buf[base].get();
            let w1 = *r.buf[base + 1].get();
            let len = (*r.buf[base + 2].get()) as usize;
            debug_assert!(len <= r.payload_words);
            let payload = std::slice::from_raw_parts(r.buf[base + 3].get() as *const f32, len);
            f(w0, w1, payload)
        };
        // Release: returns the slot to the producer; pairs with its
        // Acquire load of head before reusing the slot.
        r.head.0.store(head.wrapping_add(1), Ordering::Release);
        Some(out)
    }

    /// True once the producer closed; records already published remain
    /// poppable, so drain until `try_pop_with` returns `None` first.
    pub fn is_closed(&self) -> bool {
        self.ring.closed.load(Ordering::Acquire)
    }

    /// Close from the consumer side (producer's next push errors).
    pub fn close(&mut self) {
        self.ring.closed.store(true, Ordering::Release);
    }
}

impl Drop for RingConsumer {
    fn drop(&mut self) {
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_preserves_bits_in_order() {
        let (mut tx, mut rx) = slot_ring(4, 8);
        for i in 0..3u32 {
            let payload: Vec<f32> = (0..5).map(|j| (i * 10 + j) as f32 * 0.5 - 1.25).collect();
            assert_eq!(tx.try_push(i, i + 100, &payload), Ok(true));
        }
        for i in 0..3u32 {
            let got = rx
                .try_pop_with(|w0, w1, p| (w0, w1, p.to_vec()))
                .expect("record available");
            assert_eq!(got.0, i);
            assert_eq!(got.1, i + 100);
            let want: Vec<f32> = (0..5).map(|j| (i * 10 + j) as f32 * 0.5 - 1.25).collect();
            assert_eq!(got.2, want);
        }
        assert!(rx.try_pop_with(|_, _, _| ()).is_none());
    }

    #[test]
    fn full_ring_reports_false_then_recovers() {
        let (mut tx, mut rx) = slot_ring(4, 2);
        for i in 0..4 {
            assert_eq!(tx.try_push(i, 0, &[1.0]), Ok(true));
        }
        assert_eq!(tx.try_push(99, 0, &[1.0]), Ok(false), "5th push must report full");
        assert!(rx.try_pop_with(|w0, _, _| assert_eq!(w0, 0)).is_some());
        assert_eq!(tx.try_push(99, 0, &[1.0]), Ok(true), "freed slot is reusable");
    }

    #[test]
    fn close_is_observed_both_ways() {
        let (mut tx, rx) = slot_ring(4, 2);
        drop(rx);
        assert_eq!(tx.try_push(0, 0, &[]), Err(RingClosed));

        let (mut tx, mut rx) = slot_ring(4, 2);
        assert_eq!(tx.try_push(7, 8, &[0.5]), Ok(true));
        drop(tx);
        // already-published records still drain after producer close
        assert!(rx.is_closed());
        let got = rx.try_pop_with(|w0, w1, p| (w0, w1, p.to_vec())).unwrap();
        assert_eq!(got, (7, 8, vec![0.5]));
        assert!(rx.try_pop_with(|_, _, _| ()).is_none());
    }

    #[test]
    fn cross_thread_spsc_stream() {
        let (mut tx, mut rx) = slot_ring(8, 4);
        const N: u32 = 10_000;
        let producer = std::thread::spawn(move || {
            for i in 0..N {
                let payload = [i as f32, (i as f32) * -0.5];
                loop {
                    match tx.try_push(i, !i, &payload) {
                        Ok(true) => break,
                        Ok(false) => std::thread::yield_now(),
                        Err(_) => panic!("consumer closed early"),
                    }
                }
            }
        });
        let mut next = 0u32;
        while next < N {
            let popped = rx.try_pop_with(|w0, w1, p| {
                assert_eq!(w0, next);
                assert_eq!(w1, !next);
                assert_eq!(p, [next as f32, (next as f32) * -0.5]);
            });
            if popped.is_some() {
                next += 1;
            } else {
                std::thread::yield_now();
            }
        }
        producer.join().unwrap();
    }
}
