//! Deterministic pseudo-random numbers: xoshiro256** seeded via SplitMix64.
//!
//! Used for PCI-jitter sampling (Fig 5.3 error bars), randomized test
//! baselines and property-style test case generation. Deterministic in the
//! seed, so every experiment and test is reproducible.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform usize in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_and_centered() {
        let mut r = Rng::seed_from_u64(7);
        let n = 20000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 50000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, sorted, "astronomically unlikely to be identity");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::seed_from_u64(11);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
