//! Synchronization shim: `std::sync` in real builds, `loom` under
//! `--cfg loom`.
//!
//! Every hand-rolled concurrent structure in this crate — the
//! [`crate::util::pool`] barrier/ledger, the [`crate::util::shm`] SPSC
//! ring, the `FabricCtl` poison/halt flags in
//! [`crate::coordinator::transport`] — imports its primitives from here
//! instead of `std::sync` directly. A normal build re-exports the std
//! types unchanged (zero behavior and zero cost difference); building
//! with `RUSTFLAGS="--cfg loom"` swaps in the model-checked equivalents
//! from the in-tree `loom` shim so `rust/tests/loom_models.rs` can
//! exhaustively explore their interleavings. See CORRECTNESS.md for
//! what the model checker does and does not prove.
//!
//! Types loom does not model (`mpsc` channels, `Once`) come from std
//! under both cfgs: the loom suite drives only the primitives above and
//! models channel-shaped protocols with `Mutex` + `Condvar` + flags.

#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

#[cfg(not(loom))]
pub mod atomic {
    pub use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
}

#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub mod atomic {
    pub use loom::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
}

// Not modeled: always std, under either cfg.
pub use std::sync::{mpsc, LockResult, Once, PoisonError};
