//! Persistent execution resources for the stage hot path.
//!
//! Two primitives replace the per-stage `std::thread` traffic the solver
//! used to pay (spawn/join per sweep, a scatter thread per stage):
//!
//! * [`WorkerPool`] — a fork-join pool whose OS threads live as long as
//!   the pool (one per hardware-thread of the worker's budget, minus the
//!   caller, which participates as worker 0). A dispatch is a
//!   *rendezvous*: publish one shared closure, run it as
//!   `f(worker, phase)` on every worker, meet at a per-dispatch barrier.
//!   A multi-phase dispatch reuses the same wake-up: phases are separated
//!   by dispatch-internal barriers (a few atomic ops), not by fresh
//!   spawn/join cycles, so a fused RHS + RK + trace-refresh stage costs
//!   one wake-up instead of three thread-spawn sweeps.
//! * [`PoolSlice`] — a contiguous sub-range of one pool's OS workers
//!   behaving like a smaller pool. Dispatch is *participant-scoped*:
//!   each dispatch engages exactly the OS workers of its slice (claimed
//!   all-or-nothing from a slot ledger, so overlapping slices serialize
//!   and disjoint slices run **concurrently** — the serving layer
//!   co-schedules independent simulations onto disjoint core ranges of
//!   one pool this way). Idle workers are never woken at all.
//! * [`TaskThread`] — a single persistent thread for overlap work (the
//!   driver's halo scatter), replacing a `std::thread::spawn` per stage.
//!
//! **Core pinning.** A pool built with a `pin_base` pins worker `w` to
//! the `(pin_base + w)`-th CPU this process is *allowed* to run on (the
//! `sched_getaffinity` mask — so cgroup-restricted containers pin onto
//! real cores; spawned workers at startup, the first dispatching thread
//! on its first dispatch), turning the cluster's divided thread budget
//! (`RustParallel { threads: 0 }`) into a real affinity assignment
//! instead of an honor system. Pinning uses raw `sched_{get,set}affinity`
//! syscalls (the offline build carries no libc crate) and degrades to a
//! no-op on unsupported targets or when the kernel refuses.
//!
//! Every pool carries a process-unique **generation id**
//! ([`WorkerPool::generation`]): backends expose it so the cluster tests
//! can assert that a rebalance which keeps a worker's blocks also keeps
//! its pool (same generation), while rebuilt workers show a fresh one.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::thread::JoinHandle;

// All blocking/atomic primitives come through the util::sync shim so the
// loom suite can model-check this module's barrier and ledger.
use crate::util::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::util::sync::mpsc::{channel, Receiver, Sender};
use crate::util::sync::{Arc, Condvar, Mutex, Once};

/// Process-wide pool id source (1-based so 0 can mean "no pool").
static NEXT_GENERATION: AtomicU64 = AtomicU64::new(1);

/// Process-wide count of OS threads this module ever spawned (pool
/// workers + task threads). Monotonic; tests snapshot it around a warm
/// hot loop to prove the loop spawns nothing.
static SPAWNED: AtomicU64 = AtomicU64::new(0);

/// Total OS threads spawned by pools and task threads so far (monotonic).
pub fn os_threads_spawned() -> u64 {
    SPAWNED.load(Ordering::SeqCst)
}

/// Pin the calling OS thread to one core. Returns whether the affinity
/// call succeeded; `false` on unsupported targets or kernel refusal
/// (sandboxes commonly deny affinity changes) — callers treat pinning as
/// best-effort.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
pub fn pin_current_thread(core: usize) -> bool {
    // cpu_set_t-sized mask: 1024 cpus
    let mut mask = [0u64; 16];
    if core >= 16 * 64 {
        return false;
    }
    mask[core / 64] |= 1u64 << (core % 64);
    let ret: isize;
    // SAFETY: raw sched_setaffinity(0, sizeof(mask), &mask) — syscall 203
    // on x86_64. Reads `mask`, writes no caller memory; rcx/r11 are the
    // syscall-clobbered registers. The offline build has no libc crate,
    // hence the direct syscall.
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") 203isize => ret,
            in("rdi") 0usize,
            in("rsi") std::mem::size_of_val(&mask),
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    ret == 0
}

#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
pub fn pin_current_thread(_core: usize) -> bool {
    false
}

/// CPU ids this process is actually allowed to run on, from the
/// `sched_getaffinity` mask (Linux x86_64). Pinned core ranges index into
/// this list, so cgroup/affinity-restricted environments (CI containers
/// confined to, say, CPUs 8–15) pin onto *allowed* cores instead of
/// silently failing every affinity call. Falls back to
/// `0..available_parallelism` when the mask can't be read.
fn allowed_cpus() -> Vec<usize> {
    #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
    {
        let mut mask = [0u64; 16];
        let ret: isize;
        // SAFETY: raw sched_getaffinity(0, sizeof(mask), &mut mask) —
        // syscall 204 on x86_64; writes at most sizeof(mask) bytes into
        // `mask`, which outlives the call.
        unsafe {
            std::arch::asm!(
                "syscall",
                inlateout("rax") 204isize => ret,
                in("rdi") 0usize,
                in("rsi") std::mem::size_of_val(&mask),
                in("rdx") mask.as_mut_ptr(),
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack),
            );
        }
        if ret > 0 {
            let cpus: Vec<usize> = (0..16 * 64)
                .filter(|&c| mask[c / 64] & (1u64 << (c % 64)) != 0)
                .collect();
            if !cpus.is_empty() {
                return cpus;
            }
        }
    }
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    (0..hw).collect()
}

/// Type- and lifetime-erased dispatch closure. The pointee is only ever
/// dereferenced between the epoch publish and the final barrier of one
/// `run_phased` call, during which the caller is blocked and the closure
/// is alive on its stack.
#[derive(Clone, Copy)]
struct Job(*const (dyn Fn(usize, usize) + Sync + 'static));

// SAFETY: the pointee is Sync (shared calls from many threads are fine)
// and outlives every dereference (see Job docs); sending the pointer
// value itself between threads carries no extra obligation.
unsafe impl Send for Job {}
// SAFETY: Sync is needed because a Job rides inside an `Arc<Dispatch>`
// shared with every engaged worker; `&Job` only exposes the pointer
// value, dereferencing stays unsafe (argued at each deref site).
unsafe impl Sync for Job {}

fn erase_job<'a>(f: &'a (dyn Fn(usize, usize) + Sync + 'a)) -> Job {
    // SAFETY: pure lifetime erasure of a fat pointer (layout-identical
    // types); validity is argued on `Job`.
    Job(unsafe {
        std::mem::transmute::<
            *const (dyn Fn(usize, usize) + Sync + 'a),
            *const (dyn Fn(usize, usize) + Sync + 'static),
        >(f)
    })
}

/// Sense-reversing barrier sized for one dispatch's participants
/// (`std::sync::Barrier` would work here too, but this one tolerates a
/// poisoned mutex after a participant panicked mid-phase).
///
/// Public so the loom suite (`rust/tests/loom_models.rs`) can drive the
/// sense reversal — including a participant arriving late into the next
/// generation — under the model checker; the pool itself constructs one
/// per dispatch and never exposes it.
pub struct PhaseBarrier {
    state: Mutex<BarrierState>,
    cv: Condvar,
}

struct BarrierState {
    arrived: usize,
    generation: u64,
    participants: usize,
}

impl PhaseBarrier {
    /// Barrier for exactly `participants` waiters per generation.
    pub fn new(participants: usize) -> PhaseBarrier {
        PhaseBarrier {
            state: Mutex::new(BarrierState { arrived: 0, generation: 0, participants }),
            cv: Condvar::new(),
        }
    }

    /// Block until all participants of the current generation arrived.
    /// The last arrival resets the count and bumps the generation, so
    /// the barrier is immediately reusable (sense reversal: waiters key
    /// on the generation they entered with, never on `arrived == 0`).
    pub fn wait(&self) {
        let mut s = self.state.lock().unwrap_or_else(|e| e.into_inner());
        s.arrived += 1;
        if s.arrived >= s.participants {
            s.arrived = 0;
            s.generation = s.generation.wrapping_add(1);
            self.cv.notify_all();
        } else {
            let gen = s.generation;
            while s.generation == gen {
                s = self.cv.wait(s).unwrap_or_else(|e| e.into_inner());
            }
        }
    }
}

/// One rendezvous, fully self-contained: the job, the phase count, the
/// phase barrier, and the panic flag all live here, so two dispatches on
/// disjoint worker slices share *nothing* and proceed concurrently. The
/// dispatcher allocates one `Arc<Dispatch>` per rendezvous; the engaged
/// workers hold it alive through their final barrier wait.
struct Dispatch {
    job: Job,
    phases: usize,
    /// Participants = the slice's OS workers + the dispatching caller.
    barrier: PhaseBarrier,
    panicked: AtomicBool,
}

/// One OS worker's mailbox: a dispatch is delivered by the thread that
/// holds this worker's ledger slot, so publications never race.
struct Slot {
    ctl: Mutex<SlotCtl>,
    work: Condvar,
}

struct SlotCtl {
    /// The pending rendezvous (taken by the worker) and the worker's
    /// slice-local lane for it (`global - slice_start + 1`; lane 0 is the
    /// dispatching caller).
    dispatch: Option<Arc<Dispatch>>,
    local: usize,
    shutdown: bool,
}

/// Which OS workers are currently engaged by a dispatch. A dispatcher
/// claims its whole slice all-or-nothing under one mutex (no
/// hold-and-wait, hence no deadlock between overlapping slices) and each
/// worker frees its own flag when done.
///
/// Public so the loom suite (`rust/tests/loom_models.rs`) can model two
/// dispatchers racing for overlapping and disjoint slices; the pool
/// itself keeps its ledger private inside `Shared`.
pub struct SlotLedger {
    busy: Mutex<Vec<bool>>,
    freed: Condvar,
}

impl SlotLedger {
    /// Ledger over `slots` initially-free slots.
    pub fn new(slots: usize) -> SlotLedger {
        SlotLedger { busy: Mutex::new(vec![false; slots]), freed: Condvar::new() }
    }

    /// Block until every slot in `[start, start+count)` is free, then
    /// claim them all atomically.
    pub fn acquire(&self, start: usize, count: usize) {
        let mut busy = self.busy.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if busy[start..start + count].iter().all(|b| !*b) {
                for b in &mut busy[start..start + count] {
                    *b = true;
                }
                return;
            }
            busy = self.freed.wait(busy).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Free one slot and wake blocked acquirers.
    pub fn release(&self, slot: usize) {
        let mut busy = self.busy.lock().unwrap_or_else(|e| e.into_inner());
        busy[slot] = false;
        drop(busy);
        self.freed.notify_all();
    }

    /// Copy of the busy flags (loom models assert on it; not used on
    /// the hot path).
    pub fn busy_snapshot(&self) -> Vec<bool> {
        self.busy.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }
}

struct Shared {
    /// One mailbox per OS worker (`threads - 1` of them).
    slots: Vec<Slot>,
    ledger: SlotLedger,
}

/// The persistent fork-join pool (see module docs).
pub struct WorkerPool {
    /// `None` when `threads == 1`: dispatches run inline on the caller.
    shared: Option<Arc<Shared>>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
    generation: u64,
    /// The allowed CPU worker 0 (the caller) pins to, when pinning.
    caller_core: Option<usize>,
    caller_pin: Once,
}

impl WorkerPool {
    /// Pool with `threads` total workers (floor 1). `threads - 1` OS
    /// threads are spawned here and live until drop; the thread calling
    /// [`WorkerPool::run`] acts as worker 0. With `pin_base`, worker `w`
    /// is pinned to the `(pin_base + w)`-th *allowed* CPU of this process
    /// (the `sched_getaffinity` mask, wrapping), so restricted
    /// environments pin onto real cores; still best-effort when the
    /// kernel refuses.
    pub fn new(threads: usize, pin_base: Option<usize>) -> WorkerPool {
        let threads = threads.max(1);
        // Relaxed: pure id allocation — only atomicity matters, no data
        // is published under this counter.
        let generation = NEXT_GENERATION.fetch_add(1, Ordering::Relaxed);
        // resolve the whole pinned range up front: logical pool worker w
        // -> allowed_cpus[(pin_base + w) % n_allowed]; ranges straddling
        // the machine edge wrap instead of letting the tail silently float
        let pin_cores: Option<Vec<usize>> = pin_base.map(|b| {
            let cpus = allowed_cpus();
            (0..threads).map(|w| cpus[(b + w) % cpus.len()]).collect()
        });
        let mut handles = Vec::new();
        let shared = if threads > 1 {
            let os_workers = threads - 1;
            let shared = Arc::new(Shared {
                slots: (0..os_workers)
                    .map(|_| Slot {
                        ctl: Mutex::new(SlotCtl { dispatch: None, local: 0, shutdown: false }),
                        work: Condvar::new(),
                    })
                    .collect(),
                ledger: SlotLedger::new(os_workers),
            });
            for g in 0..os_workers {
                let sh = shared.clone();
                let pin = pin_cores.as_ref().map(|c| c[g + 1]);
                SPAWNED.fetch_add(1, Ordering::SeqCst);
                handles.push(
                    std::thread::Builder::new()
                        .name(format!("pool{generation}-w{}", g + 1))
                        .spawn(move || worker_main(sh, g, pin))
                        .expect("spawning pool worker"),
                );
            }
            Some(shared)
        } else {
            None
        };
        WorkerPool {
            shared,
            handles,
            threads,
            generation,
            caller_core: pin_cores.map(|c| c[0]),
            caller_pin: Once::new(),
        }
    }

    /// Total workers (caller included).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Process-unique id of this pool (nonzero). Stable for the pool's
    /// lifetime; a rebuilt backend gets a fresh one.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// One rendezvous: run `f(worker)` once per worker (0..threads), the
    /// caller participating as worker 0. Returns after every worker
    /// finished.
    pub fn run(&self, f: impl Fn(usize) + Sync) {
        self.run_phased(1, |w, _| f(w));
    }

    /// One rendezvous, `phases` internally-barriered passes: every worker
    /// runs `f(worker, 0)`, meets at the pool barrier, runs
    /// `f(worker, 1)`, ... The barrier gives each phase a happens-before
    /// edge over all of the previous one — writes of phase p are visible
    /// to every worker in phase p+1 — at a cost of a few atomic ops
    /// instead of a spawn/join sweep.
    pub fn run_phased(&self, phases: usize, f: impl Fn(usize, usize) + Sync) {
        self.run_phased_limit(self.threads, phases, f);
    }

    /// [`WorkerPool::run_phased`] dispatched to at most `limit` workers
    /// (clamped to `1..=threads`): `f(w, phase)` runs for workers
    /// `0..limit` only, and only those wake and meet at the phase
    /// barriers — a block with fewer work chunks than pool workers pays
    /// wake-ups proportional to the work, not the pool size. With
    /// `limit == 1` the whole dispatch runs inline on the caller (no
    /// rendezvous at all).
    pub fn run_phased_limit(&self, limit: usize, phases: usize, f: impl Fn(usize, usize) + Sync) {
        let active = limit.clamp(1, self.threads);
        self.run_phased_slice(0, active - 1, phases, f);
    }

    /// Participant-scoped rendezvous: engage OS workers
    /// `[os_start, os_start + os_count)` plus the caller. The caller runs
    /// as lane 0 and OS worker `g` as lane `g - os_start + 1`, so `f`
    /// always sees dense lanes `0..=os_count` regardless of where the
    /// slice sits. The slice's slots are claimed all-or-nothing from the
    /// ledger: dispatches on overlapping slices serialize, dispatches on
    /// disjoint slices run concurrently, and workers outside the slice
    /// are neither woken nor barriered. With `os_count == 0` the whole
    /// dispatch runs inline on the caller.
    pub fn run_phased_slice(
        &self,
        os_start: usize,
        os_count: usize,
        phases: usize,
        f: impl Fn(usize, usize) + Sync,
    ) {
        if phases == 0 {
            return;
        }
        if let Some(core) = self.caller_core {
            self.caller_pin.call_once(|| {
                pin_current_thread(core);
            });
        }
        let shared = match &self.shared {
            Some(s) if os_count > 0 => s,
            _ => {
                for phase in 0..phases {
                    f(0, phase);
                }
                return;
            }
        };
        assert!(
            os_start + os_count <= shared.slots.len(),
            "slice [{os_start}, {}) exceeds the pool's {} OS workers",
            os_start + os_count,
            shared.slots.len(),
        );
        let d = Arc::new(Dispatch {
            job: erase_job(&f),
            phases,
            barrier: PhaseBarrier::new(os_count + 1),
            panicked: AtomicBool::new(false),
        });
        shared.ledger.acquire(os_start, os_count);
        for g in os_start..os_start + os_count {
            let slot = &shared.slots[g];
            let mut ctl = slot.ctl.lock().unwrap_or_else(|e| e.into_inner());
            ctl.local = g - os_start + 1;
            ctl.dispatch = Some(d.clone());
            drop(ctl);
            slot.work.notify_one();
        }
        let mut caller_panic: Option<Box<dyn std::any::Any + Send>> = None;
        for phase in 0..phases {
            // Relaxed: best-effort skip of further phases after a worker
            // panic; the phase barrier supplies the happens-before edge,
            // and the authoritative post-dispatch check is SeqCst below.
            if caller_panic.is_none() && !d.panicked.load(Ordering::Relaxed) {
                if let Err(p) = catch_unwind(AssertUnwindSafe(|| f(0, phase))) {
                    caller_panic = Some(p);
                }
            }
            d.barrier.wait();
        }
        // every engaged worker is past its last call into `f` once the
        // final barrier released, so returning (and dropping f) is safe;
        // the Arc keeps the barrier itself alive for late leavers
        if let Some(p) = caller_panic {
            resume_unwind(p);
        }
        if d.panicked.load(Ordering::SeqCst) {
            panic!("pool worker panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if let Some(shared) = &self.shared {
            for slot in &shared.slots {
                slot.ctl.lock().unwrap_or_else(|e| e.into_inner()).shutdown = true;
                slot.work.notify_one();
            }
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_main(shared: Arc<Shared>, g: usize, pin: Option<usize>) {
    if let Some(core) = pin {
        pin_current_thread(core);
    }
    let slot = &shared.slots[g];
    loop {
        let (d, local) = {
            let mut ctl = slot.ctl.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if ctl.shutdown {
                    return;
                }
                if let Some(d) = ctl.dispatch.take() {
                    break (d, ctl.local);
                }
                ctl = slot.work.wait(ctl).unwrap_or_else(|e| e.into_inner());
            }
        };
        for phase in 0..d.phases {
            // Relaxed: same best-effort skip as the caller's loop — the
            // barrier orders phases, so a stale false only costs one
            // extra (harmless) phase of work.
            if !d.panicked.load(Ordering::Relaxed) {
                // SAFETY: see Job — the dispatcher blocks in
                // run_phased_slice until the final barrier, which this
                // worker only reaches after its last call into the job.
                let f = unsafe { &*d.job.0 };
                if catch_unwind(AssertUnwindSafe(|| f(local, phase))).is_err() {
                    d.panicked.store(true, Ordering::SeqCst);
                }
            }
            d.barrier.wait();
        }
        drop(d);
        // only after dropping the dispatch: a freed slot may be re-claimed
        // and re-published immediately
        shared.ledger.release(g);
    }
}

// ---------------------------------------------------------------------------
// slices: a sub-pool view for co-scheduled callers
// ---------------------------------------------------------------------------

/// A contiguous slice of one [`WorkerPool`]'s OS workers, plus the
/// dispatching caller — a smaller pool carved out of a bigger one. The
/// serving layer hands each concurrent job a disjoint slice: their
/// dispatches touch disjoint ledger slots, so they proceed fully in
/// parallel, while two owners of *overlapping* slices are safe (the
/// ledger serializes them). Cloning is cheap (an `Arc` bump).
#[derive(Clone)]
pub struct PoolSlice {
    pool: Arc<WorkerPool>,
    os_start: usize,
    os_count: usize,
}

impl PoolSlice {
    /// The whole pool as one slice (lane count = `pool.threads()`).
    pub fn full(pool: Arc<WorkerPool>) -> PoolSlice {
        let os_count = pool.threads() - 1;
        PoolSlice { pool, os_start: 0, os_count }
    }

    /// A slice of `lanes` total workers (the caller plus `lanes - 1` OS
    /// workers starting at OS-worker index `os_start`). Panics if the
    /// range falls outside the pool.
    pub fn range(pool: Arc<WorkerPool>, os_start: usize, lanes: usize) -> PoolSlice {
        let os_count = lanes.max(1) - 1;
        assert!(
            os_start + os_count <= pool.threads() - 1,
            "slice [{os_start}, {}) exceeds the pool's {} OS workers",
            os_start + os_count,
            pool.threads() - 1,
        );
        PoolSlice { pool, os_start, os_count }
    }

    /// Total lanes of this slice (caller included) — the slice-local
    /// analogue of [`WorkerPool::threads`].
    pub fn threads(&self) -> usize {
        self.os_count + 1
    }

    /// Generation id of the underlying pool.
    pub fn generation(&self) -> u64 {
        self.pool.generation()
    }

    /// The pool this slice draws workers from.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// Slice-scoped [`WorkerPool::run`].
    pub fn run(&self, f: impl Fn(usize) + Sync) {
        self.run_phased(1, |w, _| f(w));
    }

    /// Slice-scoped [`WorkerPool::run_phased`].
    pub fn run_phased(&self, phases: usize, f: impl Fn(usize, usize) + Sync) {
        self.run_phased_limit(self.threads(), phases, f);
    }

    /// Slice-scoped [`WorkerPool::run_phased_limit`]: lanes are always
    /// `0..limit` with 0 the caller, whatever `os_start` is.
    pub fn run_phased_limit(&self, limit: usize, phases: usize, f: impl Fn(usize, usize) + Sync) {
        let active = limit.clamp(1, self.threads());
        self.pool.run_phased_slice(self.os_start, active - 1, phases, f);
    }
}

// ---------------------------------------------------------------------------
// the persistent overlap thread
// ---------------------------------------------------------------------------

type Task = Box<dyn FnOnce() + Send + 'static>;

/// A single persistent thread executing one submitted task at a time —
/// the replacement for the driver's per-stage scatter `thread::spawn`.
pub struct TaskThread {
    tx: Option<Sender<Task>>,
    done: Receiver<std::thread::Result<()>>,
    handle: Option<JoinHandle<()>>,
}

impl TaskThread {
    pub fn new(name: &str) -> TaskThread {
        let (tx, rx) = channel::<Task>();
        let (dtx, done) = channel();
        SPAWNED.fetch_add(1, Ordering::SeqCst);
        let handle = std::thread::Builder::new()
            .name(name.to_string())
            .spawn(move || {
                while let Ok(task) = rx.recv() {
                    let r = catch_unwind(AssertUnwindSafe(task));
                    if dtx.send(r).is_err() {
                        break;
                    }
                }
            })
            .expect("spawning task thread");
        TaskThread { tx: Some(tx), done, handle: Some(handle) }
    }

    /// Run `f` on the persistent thread, concurrently with the caller.
    /// The returned guard joins the task on [`TaskGuard::join`] or drop;
    /// while the guard is alive, everything `f` borrows stays borrowed
    /// (the guard carries `'env`) **and** this `TaskThread` stays
    /// mutably borrowed — so safe code can neither touch the data nor
    /// submit a second task before the first finished (a second
    /// outstanding task would cross-match completion signals).
    ///
    /// # Safety
    ///
    /// The guard must actually run its drop (or `join`): leaking it with
    /// `std::mem::forget` would let the task outlive the borrows it
    /// captured. Callers keep the guard on the stack of the dispatching
    /// frame.
    pub unsafe fn run_scoped<'env>(
        &'env mut self,
        f: impl FnOnce() + Send + 'env,
    ) -> TaskGuard<'env> {
        let boxed: Box<dyn FnOnce() + Send + 'env> = Box::new(f);
        // SAFETY: lifetime erasure only; the guard's drop blocks until the
        // task completed, so the captures outlive every use (see above).
        let boxed = std::mem::transmute::<
            Box<dyn FnOnce() + Send + 'env>,
            Box<dyn FnOnce() + Send + 'static>,
        >(boxed);
        self.tx
            .as_ref()
            .expect("task thread alive")
            .send(boxed)
            .expect("task thread alive");
        TaskGuard { owner: self, pending: true }
    }
}

impl Drop for TaskThread {
    fn drop(&mut self) {
        self.tx = None; // closes the channel; the thread exits its loop
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Join handle of one [`TaskThread::run_scoped`] task (joins on drop).
pub struct TaskGuard<'env> {
    owner: &'env TaskThread,
    pending: bool,
}

impl TaskGuard<'_> {
    /// Block until the task finished; re-raises a task panic.
    pub fn join(self) {
        drop(self);
    }
}

impl Drop for TaskGuard<'_> {
    fn drop(&mut self) {
        if !self.pending {
            return;
        }
        self.pending = false;
        let r = self.owner.done.recv();
        // never double-panic while already unwinding
        if !std::thread::panicking() {
            match r {
                Ok(Ok(())) => {}
                Ok(Err(p)) => resume_unwind(p),
                Err(_) => panic!("task thread died"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn every_worker_runs_exactly_once() {
        let pool = WorkerPool::new(4, None);
        let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        pool.run(|w| {
            hits[w].fetch_add(1, Ordering::SeqCst);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn dispatches_are_reusable() {
        // the rendezvous must survive many cycles (per-stage usage)
        let pool = WorkerPool::new(3, None);
        let count = AtomicUsize::new(0);
        for _ in 0..200 {
            pool.run(|_| {
                count.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(count.load(Ordering::SeqCst), 200 * 3);
    }

    #[test]
    fn phase_barrier_publishes_previous_phase() {
        // phase 0 writes per-worker slots; every worker must see all of
        // them in phase 1 (the barrier's happens-before edge)
        let nw = 4;
        let pool = WorkerPool::new(nw, None);
        let slots: Vec<AtomicUsize> = (0..nw).map(|_| AtomicUsize::new(0)).collect();
        let sums: Vec<AtomicUsize> = (0..nw).map(|_| AtomicUsize::new(0)).collect();
        pool.run_phased(2, |w, phase| {
            if phase == 0 {
                slots[w].store(w + 1, Ordering::SeqCst);
            } else {
                let s: usize = slots.iter().map(|x| x.load(Ordering::SeqCst)).sum();
                sums[w].store(s, Ordering::SeqCst);
            }
        });
        for s in &sums {
            assert_eq!(s.load(Ordering::SeqCst), (1..=nw).sum::<usize>());
        }
    }

    #[test]
    fn limited_dispatch_wakes_only_requested_workers() {
        let pool = WorkerPool::new(4, None);
        let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        pool.run_phased_limit(2, 2, |w, _| {
            assert!(w < 2, "idle workers must not run the job");
            hits[w].fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits[0].load(Ordering::SeqCst), 2);
        assert_eq!(hits[1].load(Ordering::SeqCst), 2);
        assert_eq!(hits[2].load(Ordering::SeqCst), 0);
        assert_eq!(hits[3].load(Ordering::SeqCst), 0);
        // limit 1 runs inline on the caller, no rendezvous
        pool.run_phased_limit(1, 3, |w, _| {
            assert_eq!(w, 0);
            hits[w].fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits[0].load(Ordering::SeqCst), 5);
        // and full dispatches still engage every worker afterwards
        // (the skipped workers acknowledged the limited epochs)
        for _ in 0..50 {
            pool.run(|w| {
                hits[w].fetch_add(1, Ordering::SeqCst);
            });
        }
        for h in &hits[2..] {
            assert_eq!(h.load(Ordering::SeqCst), 50);
        }
        // an out-of-range limit clamps to the pool size
        pool.run_phased_limit(99, 1, |w, _| {
            hits[w].fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits[3].load(Ordering::SeqCst), 51);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = WorkerPool::new(1, None);
        let count = AtomicUsize::new(0);
        pool.run_phased(3, |w, _| {
            assert_eq!(w, 0);
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 3);
        assert_eq!(pool.threads(), 1);
    }

    #[test]
    fn generations_are_unique_and_nonzero() {
        let a = WorkerPool::new(1, None);
        let b = WorkerPool::new(2, None);
        assert_ne!(a.generation(), 0);
        assert_ne!(b.generation(), 0);
        assert_ne!(a.generation(), b.generation());
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2, None);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(|w| {
                if w == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "worker panic must surface on the caller");
        // the pool stays usable after a panicked dispatch
        let count = AtomicUsize::new(0);
        pool.run(|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn pinning_is_best_effort() {
        // sandboxes may refuse affinity changes; both outcomes are legal,
        // and a pinned pool must work either way
        let _ = pin_current_thread(0);
        let pool = WorkerPool::new(2, Some(0));
        let count = AtomicUsize::new(0);
        pool.run(|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn slice_lanes_are_dense_and_local() {
        // a slice in the middle of the pool still sees lanes 0..threads
        let pool = Arc::new(WorkerPool::new(5, None));
        let slice = PoolSlice::range(pool.clone(), 2, 3);
        assert_eq!(slice.threads(), 3);
        assert_eq!(slice.generation(), pool.generation());
        let hits: Vec<AtomicUsize> = (0..5).map(|_| AtomicUsize::new(0)).collect();
        slice.run(|w| {
            assert!(w < 3, "slice lanes must be slice-local");
            hits[w].fetch_add(1, Ordering::SeqCst);
        });
        for h in &hits[..3] {
            assert_eq!(h.load(Ordering::SeqCst), 1);
        }
        // the full-slice view behaves like the pool itself
        let full = PoolSlice::full(pool);
        assert_eq!(full.threads(), 5);
        let count = AtomicUsize::new(0);
        full.run_phased(2, |_, _| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn disjoint_slices_dispatch_concurrently() {
        // slice A's job spins until slice B's job has run: this deadlocks
        // unless dispatches on disjoint slices genuinely overlap
        let pool = Arc::new(WorkerPool::new(5, None));
        let a = PoolSlice::range(pool.clone(), 0, 2);
        let b = PoolSlice::range(pool.clone(), 2, 2);
        let b_ran = Arc::new(AtomicBool::new(false));
        std::thread::scope(|s| {
            let flag = b_ran.clone();
            s.spawn(move || {
                a.run(|_| {
                    while !flag.load(Ordering::SeqCst) {
                        std::thread::yield_now();
                    }
                });
            });
            let flag = b_ran.clone();
            s.spawn(move || {
                b.run(|_| {}); // rendezvous completes while A is parked
                flag.store(true, Ordering::SeqCst);
            });
        });
        assert!(b_ran.load(Ordering::SeqCst));
    }

    #[test]
    fn overlapping_slices_serialize_on_the_ledger() {
        let pool = Arc::new(WorkerPool::new(3, None));
        let a = PoolSlice::range(pool.clone(), 0, 2);
        let b = PoolSlice::range(pool.clone(), 1, 2);
        let count = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for slice in [a, b] {
                let count = count.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        slice.run(|_| {
                            count.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                });
            }
        });
        assert_eq!(count.load(Ordering::SeqCst), 2 * 100 * 2);
    }

    #[test]
    fn slice_panic_stays_on_its_slice() {
        let pool = Arc::new(WorkerPool::new(5, None));
        let a = PoolSlice::range(pool.clone(), 0, 2);
        let b = PoolSlice::range(pool.clone(), 2, 3);
        let r = catch_unwind(AssertUnwindSafe(|| {
            a.run(|w| {
                if w == 1 {
                    panic!("slice boom");
                }
            });
        }));
        assert!(r.is_err());
        // the sibling slice and the panicked slice both stay usable
        let count = AtomicUsize::new(0);
        b.run(|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        a.run(|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn task_thread_runs_scoped_borrows() {
        let mut t = TaskThread::new("test-task");
        let mut data = vec![0usize; 8];
        // SAFETY: the guard is joined on this frame
        let guard = unsafe {
            t.run_scoped(|| {
                for (i, v) in data.iter_mut().enumerate() {
                    *v = i * i;
                }
            })
        };
        guard.join();
        assert_eq!(data[7], 49);
        // reusable across submissions
        // SAFETY: the guard is joined on this frame
        let guard = unsafe {
            t.run_scoped(|| {
                data[0] = 1;
            })
        };
        guard.join();
        assert_eq!(data[0], 1);
    }

    #[test]
    fn task_thread_propagates_panics() {
        let mut t = TaskThread::new("test-panic");
        let r = catch_unwind(AssertUnwindSafe(|| {
            // SAFETY: the guard is joined on this frame
            let guard = unsafe { t.run_scoped(|| panic!("task boom")) };
            guard.join();
        }));
        assert!(r.is_err());
        // the thread survives a panicked task
        let flag = AtomicBool::new(false);
        // SAFETY: the guard is joined on this frame
        let guard = unsafe {
            t.run_scoped(|| {
                flag.store(true, Ordering::SeqCst);
            })
        };
        guard.join();
        assert!(flag.load(Ordering::SeqCst));
    }
}
