//! Length-prefixed `Deliver` frames for the socket transport
//! ([`crate::coordinator::transport`]).
//!
//! One frame carries one delivery *group* — every halo trace a worker
//! ships to one peer in one routed stage. The wire layout is a flat
//! little-endian `u32` stream (the payload f32s travel as their bit
//! patterns), self-describing enough that a reader can resynchronize
//! detection of a corrupt stream via the leading magic:
//!
//! ```text
//! [MAGIC][src][n_items]            group header
//!   ( [dst_block][halo_slot][len_words][len_words x f32-bits] ) x n_items
//! ```
//!
//! `n_items == 0` is a valid frame: a failed worker ships empty groups so
//! every peer's per-stage delivery count stays intact (the cluster
//! lockstep never counts bytes, only groups).

use std::io::{Read, Write};

use anyhow::{anyhow, bail};

use crate::Result;

/// Leading word of every group frame ("FABR").
pub const GROUP_MAGIC: u32 = 0x4641_4252;

/// One decoded halo installment: (dst local block, halo slot, trace data).
pub type FrameItem = (usize, usize, Vec<f32>);

/// Reusable group-frame encoder: one heap buffer per endpoint, reused
/// across stages so the socket lane never allocates in steady state.
#[derive(Debug, Default)]
pub struct FrameWriter {
    buf: Vec<u8>,
    items: u32,
}

impl FrameWriter {
    pub fn new() -> Self {
        FrameWriter::default()
    }

    /// Start a group frame from `src`; the item count is patched at
    /// [`FrameWriter::finish`] so callers can stream items in.
    pub fn begin_group(&mut self, src: usize) {
        self.buf.clear();
        self.items = 0;
        self.push_u32(GROUP_MAGIC);
        self.push_u32(src as u32);
        self.push_u32(0); // n_items, patched in finish()
    }

    /// Append one halo trace destined for (`dst_block`, `halo_slot`).
    pub fn push_item(&mut self, dst_block: usize, halo_slot: usize, data: &[f32]) {
        self.push_u32(dst_block as u32);
        self.push_u32(halo_slot as u32);
        self.push_u32(data.len() as u32);
        for &v in data {
            self.push_u32(v.to_bits());
        }
        self.items += 1;
    }

    /// Patch the item count in; returns the wire bytes of the frame.
    pub fn finish(&mut self) -> &[u8] {
        let n = self.items.to_le_bytes();
        self.buf[8..12].copy_from_slice(&n);
        &self.buf
    }

    fn push_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Write one whole group frame to `w` (encode + `write_all`).
pub fn write_group(
    w: &mut impl Write,
    enc: &mut FrameWriter,
    src: usize,
    items: impl Iterator<Item = FrameItem>,
) -> Result<usize> {
    enc.begin_group(src);
    let mut payload_bytes = 0usize;
    for (bi, slot, data) in items {
        payload_bytes += data.len() * 4;
        enc.push_item(bi, slot, &data);
    }
    let frame = enc.finish();
    w.write_all(frame).map_err(|e| anyhow!("socket lane write: {e}"))?;
    Ok(payload_bytes)
}

fn read_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Read one group frame; `Ok(None)` on a clean EOF at a frame boundary
/// (the peer shut the socket down). Returns `(src, items)`.
pub fn read_group(r: &mut impl Read) -> Result<Option<(usize, Vec<FrameItem>)>> {
    let magic = match read_u32(r) {
        Ok(m) => m,
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => bail!("socket lane read: {e}"),
    };
    if magic != GROUP_MAGIC {
        bail!("socket lane lost frame sync (got {magic:#x}, want {GROUP_MAGIC:#x})");
    }
    let src = read_u32(r)? as usize;
    let n = read_u32(r)? as usize;
    let mut items = Vec::with_capacity(n);
    for _ in 0..n {
        let bi = read_u32(r)? as usize;
        let slot = read_u32(r)? as usize;
        let len = read_u32(r)? as usize;
        let mut data = Vec::with_capacity(len);
        for _ in 0..len {
            data.push(f32::from_bits(read_u32(r)?));
        }
        items.push((bi, slot, data));
    }
    Ok(Some((src, items)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_two_groups() {
        let mut wire = Vec::new();
        let mut enc = FrameWriter::new();
        let items = vec![(3usize, 7usize, vec![1.0f32, -2.5, 3.25]), (0, 1, vec![0.5])];
        let bytes = write_group(&mut wire, &mut enc, 5, items.clone().into_iter()).unwrap();
        assert_eq!(bytes, 4 * 4);
        // an empty (failure) group rides the same stream
        write_group(&mut wire, &mut enc, 2, std::iter::empty()).unwrap();
        let mut r = wire.as_slice();
        let (src, got) = read_group(&mut r).unwrap().unwrap();
        assert_eq!(src, 5);
        assert_eq!(got, items);
        let (src2, got2) = read_group(&mut r).unwrap().unwrap();
        assert_eq!(src2, 2);
        assert!(got2.is_empty());
        // clean EOF at the frame boundary
        assert!(read_group(&mut r).unwrap().is_none());
    }

    #[test]
    fn bad_magic_is_an_error() {
        let mut wire = vec![0u8; 12];
        wire[0] = 0xde;
        let err = read_group(&mut wire.as_slice()).unwrap_err();
        assert!(err.to_string().contains("frame sync"), "{err}");
    }

    #[test]
    fn truncated_frame_is_an_error_not_eof() {
        let mut wire = Vec::new();
        let mut enc = FrameWriter::new();
        write_group(&mut wire, &mut enc, 0, std::iter::once((1, 2, vec![1.0f32; 8]))).unwrap();
        wire.truncate(wire.len() - 3); // mid-payload cut
        let res = read_group(&mut wire.as_slice());
        assert!(res.is_err(), "torn frame must not read as clean EOF");
    }
}
