//! Length-prefixed `Deliver` frames for the socket transport
//! ([`crate::coordinator::transport`]).
//!
//! One frame carries one delivery *group* — every halo trace a worker
//! ships to one peer in one routed stage. The wire layout is a flat
//! little-endian `u32` stream (the payload f32s travel as their bit
//! patterns), self-describing enough that a reader can resynchronize
//! detection of a corrupt stream via the leading magic:
//!
//! ```text
//! [MAGIC][src][n_items]            group header
//!   ( [dst_block][halo_slot][len_words][len_words x f32-bits] ) x n_items
//! ```
//!
//! `n_items == 0` is a valid frame: a failed worker ships empty groups so
//! every peer's per-stage delivery count stays intact (the cluster
//! lockstep never counts bytes, only groups).
//!
//! The decoder treats the wire as hostile: every malformed input —
//! truncated header, oversized length prefix, EOF mid-frame — surfaces as
//! a typed [`FrameError`], never a panic or an unbounded allocation
//! (length prefixes are capped and never trusted for pre-allocation).

use std::io::{Read, Write};

use anyhow::anyhow;

use crate::Result;

/// Leading word of every group frame ("FABR").
pub const GROUP_MAGIC: u32 = 0x4641_4252;

/// Cap on `n_items` in one group. A group carries at most one item per
/// halo face between two workers; a prefix beyond this is corruption,
/// not a big mesh.
pub const MAX_GROUP_ITEMS: usize = 1 << 24;

/// Cap on one item's payload length in f32 words (16 MiB). A trace is
/// `NFIELDS * (order+1)^2` words — orders of magnitude below this.
pub const MAX_ITEM_WORDS: usize = 1 << 22;

/// One decoded halo installment: (dst local block, halo slot, trace data).
pub type FrameItem = (usize, usize, Vec<f32>);

/// Why a frame failed to decode, as a typed value (the vendored `anyhow`
/// carries strings only, so branch on this *before* the `?` conversion —
/// [`read_group_typed`] returns it directly).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// Leading word was not [`GROUP_MAGIC`]: the stream lost framing.
    BadMagic(u32),
    /// EOF inside the group header (magic arrived, src/n_items did not).
    TruncatedHeader,
    /// EOF inside an item header or payload.
    MidFrameEof,
    /// A length prefix exceeds the wire caps — corrupt or hostile frame,
    /// refused before any allocation happens.
    OversizedLength { what: &'static str, got: usize, max: usize },
    /// Underlying transport error, rendered.
    Io(String),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            // "frame sync" is load-bearing: the transport tests key on it
            FrameError::BadMagic(got) => write!(
                f,
                "socket lane lost frame sync (got {got:#x}, want {GROUP_MAGIC:#x})"
            ),
            FrameError::TruncatedHeader => {
                write!(f, "socket lane group header truncated (EOF mid-header)")
            }
            FrameError::MidFrameEof => {
                write!(f, "socket lane frame truncated (EOF mid-frame)")
            }
            FrameError::OversizedLength { what, got, max } => write!(
                f,
                "socket lane {what} length prefix {got} exceeds cap {max} (corrupt frame)"
            ),
            FrameError::Io(msg) => write!(f, "socket lane read: {msg}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// Reusable group-frame encoder: one heap buffer per endpoint, reused
/// across stages so the socket lane never allocates in steady state.
#[derive(Debug, Default)]
pub struct FrameWriter {
    buf: Vec<u8>,
    items: u32,
}

impl FrameWriter {
    pub fn new() -> Self {
        FrameWriter::default()
    }

    /// Start a group frame from `src`; the item count is patched at
    /// [`FrameWriter::finish`] so callers can stream items in.
    pub fn begin_group(&mut self, src: usize) {
        self.buf.clear();
        self.items = 0;
        self.push_u32(GROUP_MAGIC);
        self.push_u32(src as u32);
        self.push_u32(0); // n_items, patched in finish()
    }

    /// Append one halo trace destined for (`dst_block`, `halo_slot`).
    pub fn push_item(&mut self, dst_block: usize, halo_slot: usize, data: &[f32]) {
        self.push_u32(dst_block as u32);
        self.push_u32(halo_slot as u32);
        self.push_u32(data.len() as u32);
        for &v in data {
            self.push_u32(v.to_bits());
        }
        self.items += 1;
    }

    /// Patch the item count in; returns the wire bytes of the frame.
    pub fn finish(&mut self) -> &[u8] {
        let n = self.items.to_le_bytes();
        self.buf[8..12].copy_from_slice(&n);
        &self.buf
    }

    fn push_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Write one whole group frame to `w` (encode + `write_all`).
pub fn write_group(
    w: &mut impl Write,
    enc: &mut FrameWriter,
    src: usize,
    items: impl Iterator<Item = FrameItem>,
) -> Result<usize> {
    enc.begin_group(src);
    let mut payload_bytes = 0usize;
    for (bi, slot, data) in items {
        payload_bytes += data.len() * 4;
        enc.push_item(bi, slot, &data);
    }
    let frame = enc.finish();
    w.write_all(frame).map_err(|e| anyhow!("socket lane write: {e}"))?;
    Ok(payload_bytes)
}

fn read_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// `read_u32` with EOF mapped to the given typed error (a cut inside a
/// frame is corruption, not a clean shutdown).
fn read_u32_in_frame(
    r: &mut impl Read,
    on_eof: FrameError,
) -> std::result::Result<u32, FrameError> {
    read_u32(r).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            on_eof
        } else {
            FrameError::Io(e.to_string())
        }
    })
}

/// Read one group frame; `Ok(None)` on a clean EOF at a frame boundary
/// (the peer shut the socket down). Returns `(src, items)`.
///
/// Typed-error twin of [`read_group`] — callers that need to branch on
/// the failure mode use this; the transport uses the `anyhow` wrapper.
pub fn read_group_typed(
    r: &mut impl Read,
) -> std::result::Result<Option<(usize, Vec<FrameItem>)>, FrameError> {
    let magic = match read_u32(r) {
        Ok(m) => m,
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(FrameError::Io(e.to_string())),
    };
    if magic != GROUP_MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let src = read_u32_in_frame(r, FrameError::TruncatedHeader)? as usize;
    let n = read_u32_in_frame(r, FrameError::TruncatedHeader)? as usize;
    if n > MAX_GROUP_ITEMS {
        return Err(FrameError::OversizedLength {
            what: "group item-count",
            got: n,
            max: MAX_GROUP_ITEMS,
        });
    }
    // Never trust a wire prefix for allocation: reserve a small floor and
    // let the vec grow as items actually arrive, so a lying prefix costs
    // a decode error, not an OOM.
    let mut items = Vec::with_capacity(n.min(1024));
    for _ in 0..n {
        let bi = read_u32_in_frame(r, FrameError::MidFrameEof)? as usize;
        let slot = read_u32_in_frame(r, FrameError::MidFrameEof)? as usize;
        let len = read_u32_in_frame(r, FrameError::MidFrameEof)? as usize;
        if len > MAX_ITEM_WORDS {
            return Err(FrameError::OversizedLength {
                what: "item payload",
                got: len,
                max: MAX_ITEM_WORDS,
            });
        }
        let mut data = Vec::with_capacity(len.min(4096));
        for _ in 0..len {
            data.push(f32::from_bits(read_u32_in_frame(r, FrameError::MidFrameEof)?));
        }
        items.push((bi, slot, data));
    }
    Ok(Some((src, items)))
}

/// [`read_group_typed`] with the error hoisted into `anyhow` (the
/// transport's error plumbing); the typed message text is preserved.
pub fn read_group(r: &mut impl Read) -> Result<Option<(usize, Vec<FrameItem>)>> {
    Ok(read_group_typed(r)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_two_groups() {
        let mut wire = Vec::new();
        let mut enc = FrameWriter::new();
        let items = vec![(3usize, 7usize, vec![1.0f32, -2.5, 3.25]), (0, 1, vec![0.5])];
        let bytes = write_group(&mut wire, &mut enc, 5, items.clone().into_iter()).unwrap();
        assert_eq!(bytes, 4 * 4);
        // an empty (failure) group rides the same stream
        write_group(&mut wire, &mut enc, 2, std::iter::empty()).unwrap();
        let mut r = wire.as_slice();
        let (src, got) = read_group(&mut r).unwrap().unwrap();
        assert_eq!(src, 5);
        assert_eq!(got, items);
        let (src2, got2) = read_group(&mut r).unwrap().unwrap();
        assert_eq!(src2, 2);
        assert!(got2.is_empty());
        // clean EOF at the frame boundary
        assert!(read_group(&mut r).unwrap().is_none());
    }

    #[test]
    fn bad_magic_is_an_error() {
        let mut wire = vec![0u8; 12];
        wire[0] = 0xde;
        let err = read_group_typed(&mut wire.as_slice()).unwrap_err();
        assert!(matches!(err, FrameError::BadMagic(_)), "{err:?}");
        // the rendered form keeps the historical wording
        assert!(err.to_string().contains("frame sync"), "{err}");
        let err = read_group(&mut wire.as_slice()).unwrap_err();
        assert!(err.to_string().contains("frame sync"), "{err}");
    }

    #[test]
    fn truncated_header_is_typed() {
        // magic alone, then EOF: the header (src, n_items) never arrives
        let wire = GROUP_MAGIC.to_le_bytes();
        let err = read_group_typed(&mut wire.as_slice()).unwrap_err();
        assert_eq!(err, FrameError::TruncatedHeader);
        // magic + src, still no n_items
        let mut wire = Vec::new();
        wire.extend_from_slice(&GROUP_MAGIC.to_le_bytes());
        wire.extend_from_slice(&7u32.to_le_bytes());
        let err = read_group_typed(&mut wire.as_slice()).unwrap_err();
        assert_eq!(err, FrameError::TruncatedHeader);
    }

    #[test]
    fn mid_frame_eof_is_typed_not_clean() {
        let mut wire = Vec::new();
        let mut enc = FrameWriter::new();
        write_group(&mut wire, &mut enc, 0, std::iter::once((1, 2, vec![1.0f32; 8]))).unwrap();
        // cut at every possible offset inside the frame: each must be a
        // typed truncation error, never Ok(None) and never a panic
        for cut in 4..wire.len() {
            let torn = &wire[..cut];
            match read_group_typed(&mut &torn[..]) {
                Err(FrameError::TruncatedHeader) | Err(FrameError::MidFrameEof) => {}
                other => panic!("cut at {cut}: want typed truncation, got {other:?}"),
            }
        }
    }

    #[test]
    fn oversized_length_prefix_is_refused_without_allocating() {
        // group claims u32::MAX items
        let mut wire = Vec::new();
        wire.extend_from_slice(&GROUP_MAGIC.to_le_bytes());
        wire.extend_from_slice(&0u32.to_le_bytes());
        wire.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = read_group_typed(&mut wire.as_slice()).unwrap_err();
        assert!(
            matches!(err, FrameError::OversizedLength { what: "group item-count", .. }),
            "{err:?}"
        );

        // one item claims a u32::MAX-word payload
        let mut wire = Vec::new();
        wire.extend_from_slice(&GROUP_MAGIC.to_le_bytes());
        wire.extend_from_slice(&0u32.to_le_bytes());
        wire.extend_from_slice(&1u32.to_le_bytes()); // n_items = 1
        wire.extend_from_slice(&0u32.to_le_bytes()); // dst_block
        wire.extend_from_slice(&0u32.to_le_bytes()); // halo_slot
        wire.extend_from_slice(&u32::MAX.to_le_bytes()); // len_words
        let err = read_group_typed(&mut wire.as_slice()).unwrap_err();
        assert!(
            matches!(err, FrameError::OversizedLength { what: "item payload", .. }),
            "{err:?}"
        );
    }

    /// Fuzz-style sweep: seeded random byte soup, plus random *valid*
    /// frames with random corruption (bit flips, truncation, garbage
    /// injection). The decoder must always return — Ok or a typed error —
    /// and never panic or over-allocate.
    #[test]
    fn fuzzed_garbage_never_panics() {
        let mut rng = Rng::seed_from_u64(0x46_41_42_52);
        for case in 0..500 {
            let wire: Vec<u8> = match case % 3 {
                // pure garbage of random length
                0 => {
                    let len = rng.below(257);
                    (0..len).map(|_| rng.next_u64() as u8).collect()
                }
                // a valid frame, then a random truncation
                1 => {
                    let mut wire = Vec::new();
                    let mut enc = FrameWriter::new();
                    let n_items = rng.below(4);
                    let items: Vec<FrameItem> = (0..n_items)
                        .map(|i| {
                            let words = rng.below(16);
                            (i, rng.below(8), vec![0.25f32; words])
                        })
                        .collect();
                    let src = rng.below(32);
                    write_group(&mut wire, &mut enc, src, items.into_iter()).unwrap();
                    let keep = rng.below(wire.len() + 1);
                    wire.truncate(keep);
                    wire
                }
                // a valid frame with random bit flips
                _ => {
                    let mut wire = Vec::new();
                    let mut enc = FrameWriter::new();
                    write_group(
                        &mut wire,
                        &mut enc,
                        1,
                        std::iter::once((0, 0, vec![1.5f32; 1 + rng.below(8)])),
                    )
                    .unwrap();
                    for _ in 0..1 + rng.below(4) {
                        let byte = rng.below(wire.len());
                        wire[byte] ^= 1 << rng.below(8);
                    }
                    wire
                }
            };
            // decode until the stream errors or drains; must terminate
            let mut r = wire.as_slice();
            for _ in 0..8 {
                match read_group_typed(&mut r) {
                    Ok(Some(_)) => continue,
                    Ok(None) | Err(_) => break,
                }
            }
        }
    }
}
