//! Bounded report history — a fixed-capacity ring over `VecDeque`.
//!
//! Long serving runs accumulate per-rebalance and per-job reports
//! indefinitely; [`History`] keeps the most recent `cap` of them and
//! counts what it evicted, so memory stays bounded while the totals a
//! summary needs (how much history scrolled away) remain honest.

use std::collections::VecDeque;

/// The most recent `cap` pushed values, oldest first.
#[derive(Debug, Clone)]
pub struct History<T> {
    buf: VecDeque<T>,
    cap: usize,
    evicted: usize,
}

impl<T> History<T> {
    /// An empty history keeping at most `cap` entries (floor 1).
    pub fn new(cap: usize) -> History<T> {
        let cap = cap.max(1);
        History { buf: VecDeque::with_capacity(cap.min(64)), cap, evicted: 0 }
    }

    /// Append, evicting the oldest entry once full.
    pub fn push(&mut self, value: T) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.evicted += 1;
        }
        self.buf.push_back(value);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Maximum entries retained.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Entries dropped off the front so far (total pushes = `len +
    /// evicted`).
    pub fn evicted(&self) -> usize {
        self.evicted
    }

    pub fn last(&self) -> Option<&T> {
        self.buf.back()
    }

    /// Oldest-first iteration over the retained entries.
    pub fn iter(&self) -> std::collections::vec_deque::Iter<'_, T> {
        self.buf.iter()
    }
}

impl<'a, T> IntoIterator for &'a History<T> {
    type Item = &'a T;
    type IntoIter = std::collections::vec_deque::Iter<'a, T>;

    fn into_iter(self) -> Self::IntoIter {
        self.buf.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_the_most_recent_cap_entries() {
        let mut h = History::new(3);
        for i in 0..7 {
            h.push(i);
        }
        assert_eq!(h.len(), 3);
        assert_eq!(h.evicted(), 4);
        assert_eq!(h.iter().copied().collect::<Vec<_>>(), vec![4, 5, 6]);
        assert_eq!(h.last(), Some(&6));
    }

    #[test]
    fn under_capacity_is_lossless() {
        let mut h = History::new(8);
        h.push("a");
        h.push("b");
        assert_eq!(h.len(), 2);
        assert_eq!(h.evicted(), 0);
        assert!(!h.is_empty());
        assert_eq!((&h).into_iter().count(), 2);
    }

    #[test]
    fn zero_cap_is_clamped() {
        let mut h = History::new(0);
        h.push(1);
        h.push(2);
        assert_eq!(h.cap(), 1);
        assert_eq!(h.last(), Some(&2));
        assert_eq!(h.len(), 1);
    }
}
