//! A minimal JSON parser/serializer (the offline build has no serde_json).
//!
//! Supports the full JSON value grammar with the escapes the artifact
//! manifest can contain; numbers parse as f64 (the manifest's integers are
//! all well below 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => bail!("unexpected byte at {}", self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let text = r#"{"format": "hlo-text", "artifacts": [
            {"name": "stage_n2_k8_h64", "k": 8, "halo": 64,
             "inputs": [{"shape": [8, 9, 3, 3, 3], "dtype": "float32"}]}],
            "lsrk_a": [0.0, -0.41789047449985195]}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("format").unwrap().as_str().unwrap(), "hlo-text");
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("k").unwrap().as_usize().unwrap(), 8);
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.len(), 5);
        let a = j.get("lsrk_a").unwrap().as_arr().unwrap();
        assert!((a[1].as_f64().unwrap() + 0.41789047449985195).abs() < 1e-15);
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a":[1,2.5,-3],"b":"x\"y\n","c":true,"d":null,"e":{}}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "Aé");
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64().unwrap(), 1000.0);
        assert_eq!(Json::parse("-2.5").unwrap().as_f64().unwrap(), -2.5);
        assert_eq!(Json::parse("0").unwrap().as_usize().unwrap(), 0);
    }
}
