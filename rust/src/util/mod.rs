//! Self-contained utilities (the build is offline; everything beyond
//! xla + anyhow is implemented here).

pub mod bench;
pub mod json;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
