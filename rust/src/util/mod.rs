//! Self-contained utilities (the build is offline; everything beyond
//! xla + anyhow is implemented here).

pub mod bench;
pub mod framing;
pub mod json;
pub mod pool;
pub mod ring;
pub mod rng;
pub mod shm;
pub mod sync;

pub use json::Json;
pub use pool::{PoolSlice, TaskThread, WorkerPool};
pub use ring::History;
pub use rng::Rng;
