//! A minimal discrete-event queue.
//!
//! The per-step schedule of Fig 5.1 is a small static DAG, but modeling it
//! through an explicit event queue keeps the engine extensible (overlapped
//! PCI transfers, pipelined exchanges — the paper's future-work items) and
//! makes device busy-intervals available for utilization accounting.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What completes at a point in simulated time.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// A device finished its compute phase for the step.
    ComputeDone { node: usize, device: &'static str },
    /// A PCI transfer finished on a node.
    PciDone { node: usize },
    /// The inter-node exchange finished for a node.
    MpiDone { node: usize },
    /// A node died (fault injection): its chunk must be respliced across
    /// the survivors and the run replayed from the last checkpoint.
    NodeFailed { node: usize },
    /// A spare node came online (elastic join): the next rebalance sheds
    /// elements onto it.
    NodeJoined { node: usize },
    /// Generic marker.
    Marker(&'static str),
}

#[derive(Debug, Clone)]
pub struct Event {
    pub time: f64,
    pub kind: EventKind,
    /// Monotone sequence number: deterministic FIFO tie-breaking.
    pub seq: u64,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap by (time, seq): reverse for BinaryHeap
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue with deterministic ordering.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    seq: u64,
    pub now: f64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn schedule(&mut self, at: f64, kind: EventKind) {
        debug_assert!(at >= self.now, "cannot schedule in the past");
        self.heap.push(Event { time: at, kind, seq: self.seq });
        self.seq += 1;
    }

    pub fn schedule_after(&mut self, delay: f64, kind: EventKind) {
        self.schedule(self.now + delay, kind);
    }

    /// Pop the next event, advancing simulated time.
    pub fn next(&mut self) -> Option<Event> {
        let ev = self.heap.pop()?;
        self.now = ev.time;
        Some(ev)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, EventKind::Marker("c"));
        q.schedule(1.0, EventKind::Marker("a"));
        q.schedule(2.0, EventKind::Marker("b"));
        let order: Vec<f64> = std::iter::from_fn(|| q.next().map(|e| e.time)).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        q.schedule(1.0, EventKind::Marker("first"));
        q.schedule(1.0, EventKind::Marker("second"));
        assert_eq!(q.next().unwrap().kind, EventKind::Marker("first"));
        assert_eq!(q.next().unwrap().kind, EventKind::Marker("second"));
    }

    #[test]
    fn now_advances() {
        let mut q = EventQueue::new();
        q.schedule(5.0, EventKind::Marker("x"));
        assert_eq!(q.now, 0.0);
        q.next();
        assert_eq!(q.now, 5.0);
        q.schedule_after(2.0, EventKind::Marker("y"));
        q.next();
        assert_eq!(q.now, 7.0);
    }
}
