//! Discrete-event simulator for heterogeneous clusters.
//!
//! Replaces the Stampede testbed (see DESIGN.md §Hardware substitution):
//! virtual nodes with a CPU device, a MIC device and a PCI link, connected
//! by an InfiniBand-like network, all clocked by the calibrated cost
//! models. The engine executes the paper's per-timestep flow (Fig 5.1):
//! host and offload processes compute concurrently, exchange shared faces
//! once per step over PCI, then the hosts run the MPI neighbor exchange.
//!
//! Three execution schemes are modeled, matching the paper's comparisons:
//! the pure-MPI baseline (8 scalar ranks/node), the task-offload strawman
//! (§5.5's "common paradigm"), and the nested partitioning contribution.

pub mod engine;
pub mod events;
pub mod topology;

pub use engine::{
    simulate, simulate_elastic, simulate_parts, ElasticSimReport, KernelBreakdown, Scheme,
    SimReport,
};
pub use events::{Event, EventKind, EventQueue};
pub use topology::Cluster;
