//! Virtual cluster topology.

use crate::costmodel::{calib, NetworkModel, NodeModel};

/// A simulated cluster: homogeneous nodes (the Stampede assumption) plus
/// an interconnect model.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub nodes: usize,
    pub node_model: NodeModel,
    pub network: NetworkModel,
}

impl Cluster {
    /// Stampede-calibrated cluster of `nodes` nodes.
    pub fn stampede(nodes: usize) -> Self {
        Cluster {
            nodes,
            node_model: calib::stampede_node(),
            network: calib::stampede_node_network(),
        }
    }

    /// A cluster with an explicit node/network model — used by the
    /// live-vs-simulated cross-check, which refits the node model from a
    /// real cluster run's measured kernel times
    /// ([`calib::measured_node`]).
    pub fn custom(nodes: usize, node_model: NodeModel, network: NetworkModel) -> Self {
        Cluster { nodes, node_model, network }
    }

    /// Aggregate theoretical peak in GFLOPs (paper §6: 1173 GF/node).
    pub fn peak_gflops(&self) -> f64 {
        self.nodes as f64 * calib::NODE_PEAK_GFLOPS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stampede_peak_per_node() {
        let c = Cluster::stampede(1);
        assert!((c.peak_gflops() - 1173.0).abs() < 1.0);
    }

    #[test]
    fn peak_scales_with_nodes() {
        assert_eq!(Cluster::stampede(64).peak_gflops(), 64.0 * Cluster::stampede(1).peak_gflops());
    }
}
