//! The simulation engine: run a workload under one of the three execution
//! schemes and report wall time + per-kernel breakdown.
//!
//! Per-timestep flow (paper Fig 5.1, nested scheme):
//!
//! ```text
//!   host: CPU kernels  ---\                       /--> MPI exchange --> next
//!                          >-- PCI face exchange -
//!   mic:  MIC kernels  ---/                       \--> (idle)
//! ```
//!
//! Baseline: 8 scalar ranks per node compute, then exchange (intra-node
//! via shared memory — compute cost only; inter-node over the network).
//! Task-offload: volume_loop ships to the MIC each step with the full
//! element state over PCI; everything else stays on the CPU (serialized —
//! exactly the "common paradigm" of §5.5 the paper argues against).

use std::collections::HashMap;

use crate::coordinator::fault::{FaultPlan, KillMode};
use crate::costmodel::kernels::{element_state_bytes, PaperKernel, ALL_KERNELS};
use crate::costmodel::pci::Direction;
use crate::costmodel::DeviceModel;
use crate::mesh::Mesh;
use crate::partition::{
    nested_partition, nested_partition_fractions, partition_stats, splice, Partition,
};
use crate::sim::events::{EventKind, EventQueue};
use crate::sim::topology::Cluster;
use crate::util::Rng;

/// Execution scheme under simulation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scheme {
    /// Paper baseline: pure MPI, `ranks_per_node` scalar ranks.
    BaselineMpi { ranks_per_node: usize },
    /// §5.5 strawman: offload volume_loop wholesale each step.
    TaskOffload,
    /// The paper's contribution. `mic_fraction` overrides the balance
    /// solve when Some (used by the Fig 5.2 sweep).
    Nested { mic_fraction: Option<f64> },
    /// Extension (paper's implicit future work): the PCI face exchange is
    /// overlapped with interior compute — each device orders its boundary
    /// elements first, ships their traces asynchronously, and computes its
    /// interior while the bus drains; only the invocation latency remains
    /// on the critical path.
    NestedOverlap { mic_fraction: Option<f64> },
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::BaselineMpi { .. } => "baseline-mpi",
            Scheme::TaskOffload => "task-offload",
            Scheme::Nested { .. } => "nested",
            Scheme::NestedOverlap { .. } => "nested-overlap",
        }
    }
}

/// Busy-seconds per (device label, kernel) over the whole run.
#[derive(Debug, Clone, Default)]
pub struct KernelBreakdown {
    pub entries: HashMap<(&'static str, &'static str), f64>,
}

impl KernelBreakdown {
    fn add(&mut self, dev: &'static str, k: PaperKernel, secs: f64) {
        *self.entries.entry((dev, k.name())).or_default() += secs;
    }

    /// Fraction of total busy time per kernel (summed over devices) —
    /// the Fig 4.1 "Average" series.
    pub fn fractions(&self) -> Vec<(&'static str, f64)> {
        let total: f64 = self.entries.values().sum();
        let mut per_kernel: HashMap<&'static str, f64> = HashMap::new();
        for ((_, k), &v) in &self.entries {
            *per_kernel.entry(k).or_default() += v;
        }
        let mut out: Vec<_> = ALL_KERNELS
            .iter()
            .map(|k| (k.name(), per_kernel.get(k.name()).copied().unwrap_or(0.0) / total))
            .collect();
        out.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        out
    }

    pub fn device_kernel_seconds(&self, dev: &str, kernel: &str) -> f64 {
        self.entries
            .iter()
            .filter(|((d, k), _)| *d == dev && *k == kernel)
            .map(|(_, v)| *v)
            .sum()
    }

    /// Busy seconds of one kernel summed over all devices — the
    /// denominator of the per-kernel live-over-sim drift series
    /// (`coordinator::experiments::cross_check`).
    pub fn kernel_seconds(&self, kernel: &str) -> f64 {
        self.entries.iter().filter(|((_, k), _)| *k == kernel).map(|(_, v)| *v).sum()
    }
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub scheme: &'static str,
    pub nodes: usize,
    pub steps: usize,
    pub order: usize,
    pub elems: usize,
    pub wall_s: f64,
    pub breakdown: KernelBreakdown,
    /// Per-node realized (k_cpu, k_mic) for the nested scheme.
    pub node_counts: Vec<(usize, usize)>,
    /// Mean per-step PCI and MPI time on the critical path.
    pub pci_step_s: f64,
    pub mpi_step_s: f64,
    /// Mean utilization of both devices during compute (nested only).
    pub cpu_busy_frac: f64,
    pub mic_busy_frac: f64,
}

impl SimReport {
    /// Cross-check hook: the ratio of a *live* measured wall time to this
    /// report's prediction (1.0 = the simulator nailed it). The experiment
    /// driver `coordinator::experiments::cross_check` runs the same
    /// configuration through the in-process cluster runtime and the
    /// simulator (with the node model refitted from the live run's
    /// measured kernel times) and reports this number per configuration.
    pub fn discrepancy(&self, live_wall_s: f64) -> f64 {
        live_wall_s / self.wall_s.max(1e-300)
    }

    /// Predicted wall seconds per timestep.
    pub fn per_step_s(&self) -> f64 {
        self.wall_s / self.steps.max(1) as f64
    }
}

/// Per-node precomputed step times for the event engine.
struct NodeStep {
    cpu_compute: f64,
    mic_compute: f64,
    pci: f64,
    mpi: f64,
}

/// Simulate `steps` timesteps of the DG solver on `mesh` across the
/// cluster under `scheme` (equal-count level-1 splice).
pub fn simulate(
    cluster: &Cluster,
    mesh: &Mesh,
    order: usize,
    steps: usize,
    scheme: Scheme,
) -> SimReport {
    simulate_parts(cluster, mesh, &splice(mesh, cluster.nodes), None, order, steps, scheme)
}

/// [`simulate`] with an explicit level-1 partition and optional per-node
/// MIC fractions — the two-level hook of the live-vs-sim cross-check: the
/// simulator prices exactly the (possibly rebalanced, weighted) partition
/// the cluster runtime executes, so live-over-sim drift stays comparable
/// across adaptive moves. The baseline scheme re-splices per rank and
/// ignores custom chunk boundaries (it models the homogeneous code).
pub fn simulate_parts(
    cluster: &Cluster,
    mesh: &Mesh,
    node_part: &Partition,
    fractions: Option<&[f64]>,
    order: usize,
    steps: usize,
    scheme: Scheme,
) -> SimReport {
    let nodes = cluster.nodes;
    assert_eq!(node_part.nparts, nodes, "one level-1 chunk per node");
    let mut breakdown = KernelBreakdown::default();
    let mut node_counts = Vec::new();
    let mut per_node: Vec<NodeStep> = Vec::with_capacity(nodes);
    let hetero = !matches!(scheme, Scheme::BaselineMpi { .. });

    match scheme {
        Scheme::BaselineMpi { ranks_per_node } => {
            let ranks = splice(mesh, nodes * ranks_per_node);
            let per_rank = rank_face_stats(mesh, &ranks, ranks_per_node);
            let dev = &cluster.node_model.cpu_scalar;
            for nd in 0..nodes {
                let mut k = 0usize;
                let (mut intf, mut bndf, mut parf, mut mpif) = (0usize, 0, 0, 0);
                for r in nd * ranks_per_node..(nd + 1) * ranks_per_node {
                    let s = &per_rank[r];
                    k += s.k;
                    intf += s.int_faces;
                    bndf += s.bound_faces;
                    parf += s.cross_faces; // inter-rank faces = parallel_flux
                    mpif += s.cross_node;
                }
                let t = add_volume_kernels(&mut breakdown, dev, order, k, steps)
                    + add_face_kernels(&mut breakdown, dev, order, intf, bndf, parf, steps);
                per_node.push(NodeStep {
                    cpu_compute: t / steps as f64,
                    mic_compute: 0.0,
                    pci: 0.0,
                    mpi: cluster.network.exchange_time(mpif, order),
                });
                node_counts.push((k, 0));
            }
        }
        Scheme::TaskOffload => {
            let np = nested_partition(mesh, node_part, 0.0); // all CPU, stats only
            let st = partition_stats(mesh, &np);
            let cpu = &cluster.node_model.cpu_vec;
            let micd = &cluster.node_model.mic;
            for nd in 0..nodes {
                let s = &st.per_node[nd];
                let k = s.k_cpu + s.k_mic;
                // volume on the MIC, everything else on the CPU, serialized
                let t_vol = micd.time(PaperKernel::VolumeLoop, order, k);
                breakdown.add(micd.name, PaperKernel::VolumeLoop, t_vol * steps as f64);
                let mut t_cpu = 0.0;
                for kern in [PaperKernel::InterpQ, PaperKernel::Lift, PaperKernel::Rk] {
                    let t = cpu.time(kern, order, k);
                    breakdown.add(cpu.name, kern, t * steps as f64);
                    t_cpu += t;
                }
                let intf = s.cpu_int_faces + s.mic_int_faces + s.pci_faces;
                t_cpu += add_face_kernels(
                    &mut breakdown, cpu, order, intf, s.bound_faces(), s.mpi_faces, steps,
                ) / steps as f64;
                // full state both ways, every step (the O(K (N+1)^3) cost)
                let state = k * element_state_bytes(order);
                let pci = cluster.node_model.pci.transfer_time(state, Direction::ToDevice)
                    + cluster.node_model.pci.transfer_time(state, Direction::FromDevice);
                per_node.push(NodeStep {
                    // serialized: CPU waits for transfer + MIC volume
                    cpu_compute: t_cpu + t_vol + pci,
                    mic_compute: 0.0,
                    pci: 0.0,
                    mpi: cluster.network.exchange_time(s.mpi_faces, order),
                });
                node_counts.push((k, 0));
            }
        }
        Scheme::Nested { mic_fraction } | Scheme::NestedOverlap { mic_fraction } => {
            let overlap = matches!(scheme, Scheme::NestedOverlap { .. });
            // explicit per-node fractions (the cross-check's live split)
            // beat the scheme's uniform fraction beat the balance solve
            // (run per node: weighted chunks differ in size)
            let fracs: Vec<f64> = match fractions {
                Some(f) => {
                    assert_eq!(f.len(), nodes, "one MIC fraction per node");
                    f.to_vec()
                }
                None => match mic_fraction {
                    Some(fr) => vec![fr; nodes],
                    None => node_part
                        .sizes()
                        .iter()
                        .map(|&k_node| {
                            let k_node = k_node.max(1);
                            let sol = crate::partition::solve_mic_fraction(
                                &cluster.node_model,
                                order,
                                k_node,
                            );
                            sol.k_mic as f64 / k_node as f64
                        })
                        .collect(),
                },
            };
            let np = nested_partition_fractions(mesh, node_part, &fracs);
            let st = partition_stats(mesh, &np);
            let cpu = &cluster.node_model.cpu_vec;
            let micd = &cluster.node_model.mic;
            for nd in 0..nodes {
                let s = &st.per_node[nd];
                let t_cpu = add_volume_kernels(&mut breakdown, cpu, order, s.k_cpu, steps)
                    + add_face_kernels(
                        &mut breakdown, cpu, order, s.cpu_int_faces, s.bound_faces_cpu,
                        s.mpi_faces + s.pci_faces, steps,
                    );
                let t_mic = add_volume_kernels(&mut breakdown, micd, order, s.k_mic, steps)
                    + add_face_kernels(
                        &mut breakdown, micd, order, s.mic_int_faces, s.bound_faces_mic,
                        s.pci_faces, steps,
                    );
                let pci_full = cluster.node_model.pci.step_exchange_time(s.pci_faces, order);
                // overlapped: the transfer hides under interior compute as
                // long as it is shorter than the smaller device's interior
                // work; the invocation latency cannot be hidden.
                let pci = if overlap {
                    let hideable = (t_cpu / steps as f64).min(t_mic / steps as f64) * 0.5;
                    (pci_full - hideable).max(2.0 * cluster.node_model.pci.latency_s)
                } else {
                    pci_full
                };
                per_node.push(NodeStep {
                    cpu_compute: t_cpu / steps as f64,
                    mic_compute: t_mic / steps as f64,
                    pci,
                    mpi: cluster.network.exchange_time(s.mpi_faces, order),
                });
                node_counts.push((s.k_cpu, s.k_mic));
            }
        }
    }

    // ---- event-driven per-step schedule --------------------------------
    let straggler = cluster.network.straggler_factor(nodes, hetero);
    let mut wall = 0.0;
    let mut pci_total = 0.0;
    let mut mpi_total = 0.0;
    let mut cpu_busy = 0.0;
    let mut mic_busy = 0.0;
    for _ in 0..steps {
        let (step, pci_s, mpi_s) = simulate_one_step(&per_node);
        wall += step * straggler;
        pci_total += pci_s;
        mpi_total += mpi_s;
        let compute_span: f64 = per_node
            .iter()
            .map(|s| s.cpu_compute.max(s.mic_compute))
            .fold(0.0, f64::max);
        if compute_span > 0.0 {
            cpu_busy += per_node.iter().map(|s| s.cpu_compute).sum::<f64>()
                / (per_node.len() as f64 * compute_span);
            mic_busy += per_node.iter().map(|s| s.mic_compute).sum::<f64>()
                / (per_node.len() as f64 * compute_span);
        }
    }

    SimReport {
        scheme: scheme.name(),
        nodes,
        steps,
        order,
        elems: mesh.len(),
        wall_s: wall,
        breakdown,
        node_counts,
        pci_step_s: pci_total / steps as f64,
        mpi_step_s: mpi_total / steps as f64,
        cpu_busy_frac: cpu_busy / steps as f64,
        mic_busy_frac: mic_busy / steps as f64,
    }
}

/// Outcome of an elastic-membership simulation ([`simulate_elastic`]).
#[derive(Debug, Clone)]
pub struct ElasticSimReport {
    pub scheme: &'static str,
    pub steps: usize,
    /// Wall seconds including degraded epochs, detection and recovery.
    pub wall_s: f64,
    /// The same workload on the initial membership with no faults — the
    /// denominator for fault-tolerance overhead.
    pub baseline_wall_s: f64,
    /// Seconds between each node death and the coordinator noticing
    /// (deadline-bounded, kill-mode dependent).
    pub detect_s: f64,
    /// Seconds spent resplicing state and replaying checkpointed steps.
    pub recover_s: f64,
    /// Timesteps re-executed from the last q-snapshot across all failures.
    pub replayed_steps: usize,
    pub failures: usize,
    pub joins: usize,
    /// Live nodes when the run finished (0 = every node died).
    pub final_nodes: usize,
}

/// Per-step wall for a healthy epoch over `live` nodes, memoized —
/// epochs before/after membership changes revisit the same sizes, and
/// [`simulate`] is linear in steps (`wall_time_linear_in_steps`).
fn epoch_step_s(
    cache: &mut HashMap<usize, f64>,
    cluster: &Cluster,
    mesh: &Mesh,
    order: usize,
    scheme: Scheme,
    live: usize,
) -> f64 {
    *cache.entry(live).or_insert_with(|| {
        let sub = Cluster::custom(live, cluster.node_model.clone(), cluster.network.clone());
        simulate(&sub, mesh, order, 1, scheme).wall_s
    })
}

/// Simulate a run whose membership changes mid-flight: nodes die at the
/// steps a [`FaultPlan`] dictates and spares join where it says, with the
/// coordinator's detect/checkpoint/recover cycle priced on the critical
/// path. The mirror of the live runtime's recovery-as-rebalance story:
///
/// * each join in the plan holds one node of `cluster` back as a spare,
///   so the initial membership is `cluster.nodes - joins`;
/// * a kill removes a node's chunk: detection costs about one step
///   (bounded by the stage deadline — fast for a `Silent` kill, deadline +
///   grace for a `Stall`), then its elements resplice across survivors
///   over the network and the run replays from the last q-snapshot
///   (every `checkpoint_every` steps) at the degraded rate;
/// * a join sheds elements onto the newcomer at a step boundary —
///   migration cost only, no replay.
///
/// Deterministic in `faults.seed`: the only randomness is the detection
/// jitter, drawn from the plan's own RNG.
pub fn simulate_elastic(
    cluster: &Cluster,
    mesh: &Mesh,
    order: usize,
    steps: usize,
    scheme: Scheme,
    faults: &FaultPlan,
    checkpoint_every: usize,
) -> ElasticSimReport {
    let total = cluster.nodes;
    let spares = faults.joins.len().min(total.saturating_sub(1));
    let initial = total - spares;
    let every = checkpoint_every.max(1);
    let mut rng = Rng::seed_from_u64(faults.seed);
    let mut cache: HashMap<usize, f64> = HashMap::new();

    // Membership timeline through the event queue for deterministic
    // ordering; joins first on ties — the live runtime admits pending
    // joins at the step boundary before a mid-step failure can fire.
    let mut q = EventQueue::new();
    for j in &faults.joins {
        if j.step < steps {
            q.schedule(
                j.step as f64,
                EventKind::NodeJoined { node: j.node.unwrap_or(usize::MAX) },
            );
        }
    }
    let mut mode_of: HashMap<usize, KillMode> = HashMap::new();
    for k in &faults.kills {
        if k.step < steps && k.node < total {
            q.schedule(k.step as f64, EventKind::NodeFailed { node: k.node });
            mode_of.insert(k.node, k.mode);
        }
    }

    let mut active: Vec<bool> = (0..total).map(|nd| nd < initial).collect();
    let mut wall = 0.0;
    let mut detect_s = 0.0;
    let mut recover_s = 0.0;
    let mut replayed = 0usize;
    let mut failures = 0usize;
    let mut joins = 0usize;
    let mut cur = 0usize; // next step to price

    while let Some(ev) = q.next() {
        let at = (ev.time as usize).min(steps);
        let live = active.iter().filter(|&&a| a).count();
        if at > cur {
            wall += (at - cur) as f64
                * epoch_step_s(&mut cache, cluster, mesh, order, scheme, live);
            cur = at;
        }
        match ev.kind {
            EventKind::NodeFailed { node } => {
                if !active[node] {
                    continue; // already down (or was never admitted)
                }
                active[node] = false;
                failures += 1;
                let survivors = live - 1;
                // a silent drop trips the disconnect path within a recv
                // tick; a crash surfaces its sentinel at stage end; a
                // stall only expires the stage deadline plus grace
                let factor = match mode_of.get(&node) {
                    Some(KillMode::Silent) => 0.25,
                    Some(KillMode::Stall) => 1.5,
                    _ => 1.0,
                };
                detect_s += epoch_step_s(&mut cache, cluster, mesh, order, scheme, live)
                    * factor
                    * (1.0 + 0.5 * rng.uniform());
                if survivors == 0 {
                    break; // nobody left to recover onto
                }
                // recovery = resplice the dead chunk over the network +
                // replay from the last q-snapshot at the degraded rate
                let k_moved = mesh.len().div_ceil(live);
                let bytes = k_moved * element_state_bytes(order);
                let replay = cur % every;
                recover_s += cluster.network.alpha_s
                    + bytes as f64 / cluster.network.beta_bytes_per_s
                    + replay as f64
                        * epoch_step_s(&mut cache, cluster, mesh, order, scheme, survivors);
                replayed += replay;
            }
            EventKind::NodeJoined { node } => {
                let nd = if node == usize::MAX {
                    active.iter().position(|&a| !a)
                } else if node < total && !active[node] {
                    Some(node)
                } else {
                    None
                };
                let Some(nd) = nd else { continue };
                active[nd] = true;
                joins += 1;
                // step-boundary migration: the newcomer's share of live
                // state crosses the network once
                let k_moved = mesh.len() / (live + 1);
                let bytes = k_moved * element_state_bytes(order);
                recover_s += cluster.network.alpha_s
                    + bytes as f64 / cluster.network.beta_bytes_per_s;
            }
            _ => {}
        }
    }

    let live = active.iter().filter(|&&a| a).count();
    if live > 0 && cur < steps {
        wall += (steps - cur) as f64
            * epoch_step_s(&mut cache, cluster, mesh, order, scheme, live);
    }
    let baseline = steps as f64
        * epoch_step_s(&mut cache, cluster, mesh, order, scheme, initial.max(1));

    ElasticSimReport {
        scheme: scheme.name(),
        steps,
        wall_s: wall + detect_s + recover_s,
        baseline_wall_s: baseline,
        detect_s,
        recover_s,
        replayed_steps: replayed,
        failures,
        joins,
        final_nodes: live,
    }
}

/// Event-driven execution of one step: device compute in parallel per
/// node, then PCI sync, then the network exchange; the step completes when
/// every node is done (bulk-synchronous neighbor exchange).
fn simulate_one_step(per_node: &[NodeStep]) -> (f64, f64, f64) {
    let mut q = EventQueue::new();
    let n = per_node.len();
    let mut devices_pending: Vec<u8> = per_node
        .iter()
        .map(|s| if s.mic_compute > 0.0 { 2 } else { 1 })
        .collect();
    for (nd, s) in per_node.iter().enumerate() {
        q.schedule(s.cpu_compute, EventKind::ComputeDone { node: nd, device: "cpu" });
        if s.mic_compute > 0.0 {
            q.schedule(s.mic_compute, EventKind::ComputeDone { node: nd, device: "mic" });
        }
    }
    let mut remaining = n;
    let mut max_pci = 0.0f64;
    while let Some(ev) = q.next() {
        match ev.kind {
            EventKind::ComputeDone { node, .. } => {
                devices_pending[node] -= 1;
                if devices_pending[node] == 0 {
                    q.schedule_after(per_node[node].pci, EventKind::PciDone { node });
                    max_pci = max_pci.max(per_node[node].pci);
                }
            }
            EventKind::PciDone { node } => {
                q.schedule_after(per_node[node].mpi, EventKind::MpiDone { node });
            }
            EventKind::MpiDone { .. } => {
                remaining -= 1;
                if remaining == 0 {
                    break;
                }
            }
            EventKind::NodeFailed { .. } | EventKind::NodeJoined { .. } => {}
            EventKind::Marker(_) => {}
        }
    }
    let step = q.now;
    let mpi_max = per_node.iter().map(|s| s.mpi).fold(0.0, f64::max);
    (step, max_pci, mpi_max)
}

fn add_volume_kernels(
    breakdown: &mut KernelBreakdown,
    dev: &DeviceModel,
    order: usize,
    k: usize,
    steps: usize,
) -> f64 {
    let mut total = 0.0;
    for kern in [
        PaperKernel::VolumeLoop,
        PaperKernel::InterpQ,
        PaperKernel::Lift,
        PaperKernel::Rk,
    ] {
        let t = dev.time(kern, order, k) * steps as f64;
        breakdown.add(dev.name, kern, t);
        total += t;
    }
    total
}

fn add_face_kernels(
    breakdown: &mut KernelBreakdown,
    dev: &DeviceModel,
    order: usize,
    int_faces: usize,
    bound_faces: usize,
    parallel_faces: usize,
    steps: usize,
) -> f64 {
    let mut total = 0.0;
    for (kern, count) in [
        (PaperKernel::IntFlux, int_faces),
        (PaperKernel::BoundFlux, bound_faces),
        (PaperKernel::ParallelFlux, parallel_faces),
    ] {
        let t = dev.time(kern, order, count) * steps as f64;
        breakdown.add(dev.name, kern, t);
        total += t;
    }
    total
}

/// Per-rank stats for the baseline scheme; ranks `nd*rpn..(nd+1)*rpn`
/// belong to node `nd`.
struct RankStats {
    k: usize,
    int_faces: usize,
    bound_faces: usize,
    /// Faces against any other rank (intra- or inter-node).
    cross_faces: usize,
    /// Of those, faces whose neighbor rank lives on another node.
    cross_node: usize,
}

fn rank_face_stats(mesh: &Mesh, ranks: &Partition, rpn: usize) -> Vec<RankStats> {
    let mut out: Vec<RankStats> = (0..ranks.nparts)
        .map(|_| RankStats { k: 0, int_faces: 0, bound_faces: 0, cross_faces: 0, cross_node: 0 })
        .collect();
    for (e, c) in mesh.conn.iter().enumerate() {
        let r = ranks.assignment[e];
        out[r].k += 1;
        for &v in c {
            if v < 0 {
                out[r].bound_faces += 1;
            } else {
                let r2 = ranks.assignment[v as usize];
                if r2 == r {
                    if (v as usize) > e {
                        out[r].int_faces += 1;
                    }
                } else {
                    out[r].cross_faces += 1;
                    if r2 / rpn != r / rpn {
                        out[r].cross_node += 1;
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::geometry::discontinuous_brick;

    fn small_mesh() -> Mesh {
        discontinuous_brick([8, 8, 8], [2.0, 1.0, 1.0])
    }

    #[test]
    fn nested_beats_baseline_single_node() {
        let c = Cluster::stampede(1);
        let m = small_mesh();
        let base = simulate(&c, &m, 7, 10, Scheme::BaselineMpi { ranks_per_node: 8 });
        let nest = simulate(&c, &m, 7, 10, Scheme::Nested { mic_fraction: None });
        let speedup = base.wall_s / nest.wall_s;
        assert!(speedup > 3.0, "speedup {speedup}");
    }

    #[test]
    fn nested_beats_task_offload() {
        let c = Cluster::stampede(1);
        let m = small_mesh();
        let off = simulate(&c, &m, 7, 10, Scheme::TaskOffload);
        let nest = simulate(&c, &m, 7, 10, Scheme::Nested { mic_fraction: None });
        assert!(nest.wall_s < off.wall_s, "nested {} offload {}", nest.wall_s, off.wall_s);
    }

    #[test]
    fn simulate_parts_prices_custom_partition() {
        let c = Cluster::stampede(2);
        let m = small_mesh();
        // skewed level-1 chunks (~3/4 vs ~1/4 of the elements)
        let weights: Vec<f64> =
            (0..m.len()).map(|e| if e < m.len() * 3 / 4 { 1.0 } else { 3.0 }).collect();
        let part = crate::partition::splice_weighted(&weights, 2);
        let sizes = part.sizes();
        assert!(sizes[0] > sizes[1], "{sizes:?}");
        let rep = simulate_parts(
            &c, &m, &part, Some(&[0.3, 0.3]), 7, 3,
            Scheme::Nested { mic_fraction: None },
        );
        for (nd, &(kc, km)) in rep.node_counts.iter().enumerate() {
            assert_eq!(kc + km, sizes[nd], "node {nd}");
        }
        // the equal splice predicts a faster step than the skewed one on a
        // homogeneous cluster — the imbalance the level-1 rebalancer sees
        let eq = simulate(&c, &m, 7, 3, Scheme::Nested { mic_fraction: Some(0.3) });
        assert!(eq.wall_s < rep.wall_s, "eq {} skew {}", eq.wall_s, rep.wall_s);
    }

    #[test]
    fn wall_time_linear_in_steps() {
        let c = Cluster::stampede(1);
        let m = small_mesh();
        let t10 = simulate(&c, &m, 7, 10, Scheme::Nested { mic_fraction: None }).wall_s;
        let t20 = simulate(&c, &m, 7, 20, Scheme::Nested { mic_fraction: None }).wall_s;
        assert!((t20 / t10 - 2.0).abs() < 0.01);
    }

    #[test]
    fn discrepancy_is_live_over_predicted() {
        let c = Cluster::stampede(1);
        let m = small_mesh();
        let rep = simulate(&c, &m, 7, 10, Scheme::Nested { mic_fraction: None });
        assert!((rep.discrepancy(rep.wall_s) - 1.0).abs() < 1e-12);
        assert!((rep.discrepancy(2.0 * rep.wall_s) - 2.0).abs() < 1e-12);
        assert!((rep.per_step_s() * 10.0 - rep.wall_s).abs() < 1e-12);
    }

    #[test]
    fn breakdown_fractions_sum_to_one() {
        let c = Cluster::stampede(1);
        let m = small_mesh();
        let rep = simulate(&c, &m, 7, 5, Scheme::BaselineMpi { ranks_per_node: 8 });
        let total: f64 = rep.breakdown.fractions().iter().map(|(_, f)| f).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn volume_loop_dominates_baseline_profile() {
        let c = Cluster::stampede(1);
        let m = small_mesh();
        let rep = simulate(&c, &m, 7, 5, Scheme::BaselineMpi { ranks_per_node: 8 });
        let fracs = rep.breakdown.fractions();
        assert_eq!(fracs[0].0, "volume_loop", "{fracs:?}");
        assert!(fracs[0].1 > 0.4);
    }

    #[test]
    fn zero_mic_fraction_equals_cpu_only() {
        let c = Cluster::stampede(1);
        let m = small_mesh();
        let rep = simulate(&c, &m, 7, 5, Scheme::Nested { mic_fraction: Some(0.0) });
        assert!(rep.node_counts.iter().all(|&(_, mic)| mic == 0));
        assert_eq!(rep.pci_step_s, stampede_pci_floor());
    }

    fn stampede_pci_floor() -> f64 {
        // zero faces still pay two latency hits in step_exchange_time
        2.0 * crate::costmodel::calib::stampede_pci().latency_s
    }

    #[test]
    fn elastic_kill_costs_wall_and_replays() {
        let c = Cluster::stampede(2);
        let m = small_mesh();
        let plan = FaultPlan {
            seed: 11,
            kills: vec![crate::coordinator::fault::KillSpec {
                node: 1,
                step: 5,
                mode: KillMode::Crash,
            }],
            ..FaultPlan::default()
        };
        let rep =
            simulate_elastic(&c, &m, 7, 10, Scheme::Nested { mic_fraction: Some(0.2) }, &plan, 2);
        assert_eq!(rep.failures, 1);
        assert_eq!(rep.final_nodes, 1);
        // kill at step 5, snapshots every 2 steps -> replay 1 step
        assert_eq!(rep.replayed_steps, 1);
        assert!(rep.detect_s > 0.0 && rep.recover_s > 0.0);
        assert!(
            rep.wall_s > rep.baseline_wall_s,
            "faulty {} baseline {}",
            rep.wall_s,
            rep.baseline_wall_s
        );
    }

    #[test]
    fn elastic_join_beats_staying_degraded() {
        let c = Cluster::stampede(2);
        let m = small_mesh();
        let plan = FaultPlan {
            seed: 3,
            joins: vec![crate::coordinator::fault::JoinSpec { node: None, step: 2 }],
            ..FaultPlan::default()
        };
        let rep =
            simulate_elastic(&c, &m, 7, 10, Scheme::Nested { mic_fraction: Some(0.2) }, &plan, 2);
        assert_eq!(rep.joins, 1);
        assert_eq!(rep.final_nodes, 2);
        assert_eq!(rep.replayed_steps, 0);
        // the spare is held back, so the baseline is the 1-node run; the
        // join sheds half the elements after 2 steps and wins
        assert!(
            rep.wall_s < rep.baseline_wall_s,
            "joined {} degraded {}",
            rep.wall_s,
            rep.baseline_wall_s
        );
    }

    #[test]
    fn elastic_is_deterministic_in_seed() {
        let c = Cluster::stampede(4);
        let m = small_mesh();
        let mk = |seed| FaultPlan {
            seed,
            kills: vec![crate::coordinator::fault::KillSpec {
                node: 2,
                step: 3,
                mode: KillMode::Silent,
            }],
            ..FaultPlan::default()
        };
        let s = Scheme::Nested { mic_fraction: Some(0.2) };
        let a = simulate_elastic(&c, &m, 7, 8, s, &mk(42), 2);
        let b = simulate_elastic(&c, &m, 7, 8, s, &mk(42), 2);
        assert_eq!(a.wall_s.to_bits(), b.wall_s.to_bits(), "same seed, same wall");
        let d = simulate_elastic(&c, &m, 7, 8, s, &mk(43), 2);
        assert_ne!(a.detect_s.to_bits(), d.detect_s.to_bits(), "seed moves the jitter");
    }

    #[test]
    fn elastic_without_faults_matches_plain_run() {
        let c = Cluster::stampede(2);
        let m = small_mesh();
        let s = Scheme::Nested { mic_fraction: Some(0.2) };
        let rep = simulate_elastic(&c, &m, 7, 6, s, &FaultPlan::default(), 2);
        assert_eq!(rep.failures + rep.joins, 0);
        assert_eq!(rep.wall_s.to_bits(), rep.baseline_wall_s.to_bits());
    }

    #[test]
    fn utilization_high_for_nested() {
        // needs a workload big enough that the interior-only constraint
        // doesn't cap the MIC share (16^3: interior 2744 >> balanced need)
        let c = Cluster::stampede(1);
        let m = discontinuous_brick([16, 16, 16], [2.0, 1.0, 1.0]);
        let rep = simulate(&c, &m, 7, 5, Scheme::Nested { mic_fraction: None });
        assert!(rep.cpu_busy_frac > 0.85, "cpu busy {}", rep.cpu_busy_frac);
        assert!(rep.mic_busy_frac > 0.85, "mic busy {}", rep.mic_busy_frac);
    }
}
