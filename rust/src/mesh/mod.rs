//! Hexahedral octree meshes in Morton order.
//!
//! The baseline dgae/mangll pipeline discretizes the domain with octrees of
//! hexahedral elements, orders the leaves along the global Morton (Z-order)
//! curve, and splices that 1-D array into contiguous chunks — "approximately
//! optimal with respect to minimizing communication" (paper §5.1, [6]).
//! This module provides the same substrate: Morton codes, octree leaf
//! enumeration, multi-tree forests with per-tree materials (the paper's
//! Fig 6.1 two-tree geometry), conforming face connectivity, and the local
//! block/halo extraction the solver consumes.

pub mod element;
pub mod geometry;
pub mod halo;
pub mod morton;
pub mod octree;

pub use element::{Material, Mesh};
pub use geometry::{two_tree_geometry, unit_cube_geometry};
pub use halo::{build_local_blocks, ExchangePlan, LocalBlock};
pub use morton::MortonKey;
