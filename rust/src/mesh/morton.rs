//! 3-D Morton (Z-order) codes.
//!
//! The global element order of the baseline code is the Morton order of the
//! octree leaves (paper §5.1, citing Sundar et al. [6]); splicing that order
//! yields compact subdomains with near-minimal shared surface. 21 bits per
//! dimension (max octree level 21) fit a u64.

/// Maximum supported refinement level (bits per coordinate).
pub const MAX_LEVEL: u32 = 21;

/// A Morton key: interleaved (x, y, z) anchor coordinates of an octant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct MortonKey(pub u64);

/// Spread the low 21 bits of `v` so consecutive bits land 3 apart.
#[inline]
fn split3(v: u32) -> u64 {
    let mut x = (v as u64) & 0x1f_ffff; // 21 bits
    x = (x | (x << 32)) & 0x1f00000000ffff;
    x = (x | (x << 16)) & 0x1f0000ff0000ff;
    x = (x | (x << 8)) & 0x100f00f00f00f00f;
    x = (x | (x << 4)) & 0x10c30c30c30c30c3;
    x = (x | (x << 2)) & 0x1249249249249249;
    x
}

/// Inverse of [`split3`]: gather every third bit.
#[inline]
fn compact3(v: u64) -> u32 {
    let mut x = v & 0x1249249249249249;
    x = (x | (x >> 2)) & 0x10c30c30c30c30c3;
    x = (x | (x >> 4)) & 0x100f00f00f00f00f;
    x = (x | (x >> 8)) & 0x1f0000ff0000ff;
    x = (x | (x >> 16)) & 0x1f00000000ffff;
    x = (x | (x >> 32)) & 0x1f_ffff;
    x as u32
}

impl MortonKey {
    /// Interleave integer coordinates (x fastest-varying bit).
    pub fn encode(x: u32, y: u32, z: u32) -> Self {
        debug_assert!(x < (1 << MAX_LEVEL) && y < (1 << MAX_LEVEL) && z < (1 << MAX_LEVEL));
        MortonKey(split3(x) | (split3(y) << 1) | (split3(z) << 2))
    }

    /// Recover the (x, y, z) integer coordinates.
    pub fn decode(self) -> (u32, u32, u32) {
        (compact3(self.0), compact3(self.0 >> 1), compact3(self.0 >> 2))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_small() {
        for x in 0..8 {
            for y in 0..8 {
                for z in 0..8 {
                    assert_eq!(MortonKey::encode(x, y, z).decode(), (x, y, z));
                }
            }
        }
    }

    #[test]
    fn roundtrip_large_coords() {
        let max = (1 << MAX_LEVEL) - 1;
        for &(x, y, z) in &[(0, 0, 0), (max, max, max), (123_456, 1, max), (max / 3, max / 5, max / 7)] {
            assert_eq!(MortonKey::encode(x, y, z).decode(), (x, y, z));
        }
    }

    #[test]
    fn order_matches_interleaved_magnitude() {
        // unit cube of 8 octants: morton order is the standard Z traversal
        let keys: Vec<_> = (0..8)
            .map(|i| MortonKey::encode(i & 1, (i >> 1) & 1, (i >> 2) & 1))
            .collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
    }

    #[test]
    fn locality_of_consecutive_keys() {
        // consecutive morton codes in a 2^3 block differ by at most the
        // block diagonal — crude locality check over a 16^3 grid
        let mut keys = Vec::new();
        for z in 0..16u32 {
            for y in 0..16 {
                for x in 0..16 {
                    keys.push(MortonKey::encode(x, y, z));
                }
            }
        }
        keys.sort();
        let mut maxd = 0i64;
        for w in keys.windows(2) {
            let (ax, ay, az) = w[0].decode();
            let (bx, by, bz) = w[1].decode();
            let d = (ax as i64 - bx as i64).abs().max((ay as i64 - by as i64).abs()).max(
                (az as i64 - bz as i64).abs(),
            );
            maxd = maxd.max(d);
        }
        assert!(maxd <= 15, "morton jumps should stay inside the grid: {maxd}");
    }
}
