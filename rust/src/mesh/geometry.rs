//! Canned geometries used by the paper's experiments.

use super::element::{Material, Mesh};

/// Unit cube, uniform acoustic material, `n^3` elements.
pub fn unit_cube_geometry(n: usize) -> Mesh {
    Mesh::structured_brick([n, n, n], [0.0; 3], [1.0; 3], |_| Material::acoustic(1.0, 1.0))
}

/// Unit cube with an arbitrary material field.
pub fn unit_cube_with(n: usize, material: impl Fn([f64; 3]) -> Material) -> Mesh {
    Mesh::structured_brick([n, n, n], [0.0; 3], [1.0; 3], material)
}

/// The paper's Fig 6.1 geometry: a brick-like domain built from two glued
/// trees with a material discontinuity at the interface. First tree is
/// acoustic (c_p = 1, c_s = 0), second elastic (c_p = 3, c_s = 2).
pub fn two_tree_geometry(n_per_tree: usize) -> Mesh {
    let n = n_per_tree;
    let acoustic = Mesh::structured_brick([n, n, n], [0.0; 3], [1.0; 3], |_| {
        Material::acoustic(1.0, 1.0)
    });
    let elastic = Mesh::structured_brick([n, n, n], [1.0, 0.0, 0.0], [1.0; 3], |_| {
        Material::elastic(1.0, 3.0, 2.0)
    });
    Mesh::glue_x(acoustic, elastic)
}

/// Brick with a centered material discontinuity (Table 6.1's workload):
/// acoustic on the left half, elastic on the right.
pub fn discontinuous_brick(dims: [usize; 3], extent: [f64; 3]) -> Mesh {
    let half = extent[0] / 2.0;
    Mesh::structured_brick(dims, [0.0; 3], extent, move |c| {
        if c[0] < half {
            Material::acoustic(1.0, 1.0)
        } else {
            Material::elastic(1.0, 3.0, 2.0)
        }
    })
}

/// Near-cubic factorization of `n` into three factors (a >= b >= c),
/// greedily peeling powers of two then distributing odd remainders.
pub fn near_cube_dims(n: usize) -> [usize; 3] {
    let mut dims = [1usize; 3];
    let mut rem = n;
    // peel small prime factors, assigning each to the smallest dim
    let mut f = 2;
    while rem > 1 {
        while rem % f == 0 {
            let i = (0..3).min_by_key(|&i| dims[i]).unwrap();
            dims[i] *= f;
            rem /= f;
        }
        f += if f == 2 { 1 } else { 2 };
        if f * f > rem && rem > 1 {
            let i = (0..3).min_by_key(|&i| dims[i]).unwrap();
            dims[i] *= rem;
            rem = 1;
        }
    }
    dims.sort_unstable_by(|a, b| b.cmp(a));
    dims
}

/// Global brick dimensions + extent for a `nodes`-node run with
/// `elems_per_node` elements each: per-node near-cube chunks arranged on a
/// near-cube node grid, unit-sized elements.
pub fn sweep_dims(nodes: usize, elems_per_node: usize) -> ([usize; 3], [f64; 3]) {
    let nd = near_cube_dims(elems_per_node);
    let pg = near_cube_dims(nodes);
    let dims = [nd[0] * pg[0], nd[1] * pg[1], nd[2] * pg[2]];
    let extent = [dims[0] as f64, dims[1] as f64, dims[2] as f64];
    (dims, extent)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn near_cube_products() {
        for n in [1usize, 8, 64, 100, 8192, 1024, 27, 30] {
            let d = near_cube_dims(n);
            assert_eq!(d[0] * d[1] * d[2], n, "{n} -> {d:?}");
            assert!(d[0] >= d[1] && d[1] >= d[2]);
        }
    }

    #[test]
    fn near_cube_is_cubic_for_8192() {
        let d = near_cube_dims(8192);
        assert_eq!(d, [32, 16, 16]);
    }

    #[test]
    fn sweep_dims_scale() {
        let (d, _) = sweep_dims(64, 8192);
        assert_eq!(d[0] * d[1] * d[2], 64 * 8192);
    }

    #[test]
    fn two_tree_has_discontinuity() {
        let m = two_tree_geometry(2);
        assert_eq!(m.len(), 16);
        assert!(m.check_consistency());
        let mus: Vec<f32> = m.elements.iter().map(|e| e.material.mu).collect();
        assert!(mus.iter().any(|&x| x == 0.0));
        assert!(mus.iter().any(|&x| x > 0.0));
    }

    #[test]
    fn discontinuous_brick_split_along_x() {
        let m = discontinuous_brick([4, 2, 2], [2.0, 1.0, 1.0]);
        for e in &m.elements {
            if e.center[0] < 1.0 {
                assert_eq!(e.material.mu, 0.0);
            } else {
                assert!(e.material.mu > 0.0);
            }
        }
    }
}
