//! Local block extraction and halo exchange plans.
//!
//! Given a global mesh and an owner id per element (node rank for the
//! baseline, `node*2 + device` for the nested scheme), build for every
//! owner the local element block the L2 stage function consumes — local
//! connectivity with `-1` halo faces and `-2` physical-boundary faces —
//! plus the [`ExchangePlan`] the coordinator applies between RK stages:
//! for every halo slot, which (owner, local element, face) trace fills it.

use super::element::{Mesh, BOUNDARY};

/// Face-local connectivity codes for the L2 model (see model.py docstring).
pub const LOCAL_HALO: i32 = -1;
pub const LOCAL_BOUNDARY: i32 = -2;

/// One owner's element block, in the exact layout the stage artifact takes.
#[derive(Debug, Clone)]
pub struct LocalBlock {
    pub owner: usize,
    /// local index -> global element index (ascending == Morton order).
    pub global_ids: Vec<usize>,
    /// (K, 6) local connectivity: local neighbor / LOCAL_HALO / LOCAL_BOUNDARY.
    pub conn: Vec<[i32; 6]>,
    /// (K, 6) halo slot per LOCAL_HALO face (0 elsewhere).
    pub halo_idx: Vec<[i32; 6]>,
    /// Number of live halo slots.
    pub halo_len: usize,
    /// Per slot: (source owner, source local element, source face) — the
    /// face is on the *source* element, i.e. the opposite of the consumer's.
    pub halo_src: Vec<(usize, usize, usize)>,
    /// Material on the far side of each halo slot (rho, lambda, mu).
    pub halo_mats: Vec<[f32; 3]>,
    /// (K, 3) per-element material.
    pub mats: Vec<[f32; 3]>,
    /// (K, 3) per-element extents, f32 for the artifact.
    pub h: Vec<[f32; 3]>,
    /// (K, 3) element centers (f64, for initial conditions / errors).
    pub centers: Vec<[f64; 3]>,
}

impl LocalBlock {
    pub fn len(&self) -> usize {
        self.global_ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.global_ids.is_empty()
    }
}

/// The per-stage trace routing between blocks:
/// `copies[dst_owner]` = list of (src_owner, src_local, src_face, dst_slot).
#[derive(Debug, Clone, Default)]
pub struct ExchangePlan {
    pub copies: Vec<Vec<(usize, usize, usize, usize)>>,
}

impl ExchangePlan {
    /// Total number of face copies per stage (both directions).
    pub fn total_faces(&self) -> usize {
        self.copies.iter().map(|c| c.len()).sum()
    }

    /// Faces crossing between a pair of owners (either direction).
    pub fn faces_between(&self, a: usize, b: usize) -> usize {
        let mut n = 0;
        if b < self.copies.len() {
            n += self.copies[b].iter().filter(|c| c.0 == a).count();
        }
        if a < self.copies.len() {
            n += self.copies[a].iter().filter(|c| c.0 == b).count();
        }
        n
    }
}

/// Build one [`LocalBlock`] per owner plus the global [`ExchangePlan`].
///
/// `owners[e]` assigns every global element to exactly one owner in
/// `0..n_owners`. Empty owners produce empty blocks (legal; skipped by the
/// coordinator).
pub fn build_local_blocks(
    mesh: &Mesh,
    owners: &[usize],
    n_owners: usize,
) -> (Vec<LocalBlock>, ExchangePlan) {
    assert_eq!(owners.len(), mesh.len());
    // local index of each global element within its owner, preserving order
    let mut local_of = vec![usize::MAX; mesh.len()];
    let mut counts = vec![0usize; n_owners];
    for (g, &o) in owners.iter().enumerate() {
        local_of[g] = counts[o];
        counts[o] += 1;
    }
    let mut blocks: Vec<LocalBlock> = (0..n_owners)
        .map(|owner| LocalBlock {
            owner,
            global_ids: Vec::with_capacity(counts[owner]),
            conn: Vec::with_capacity(counts[owner]),
            halo_idx: Vec::with_capacity(counts[owner]),
            halo_len: 0,
            halo_src: Vec::new(),
            halo_mats: Vec::new(),
            mats: Vec::with_capacity(counts[owner]),
            h: Vec::with_capacity(counts[owner]),
            centers: Vec::with_capacity(counts[owner]),
        })
        .collect();
    let mut plan = ExchangePlan { copies: vec![Vec::new(); n_owners] };

    for (g, elem) in mesh.elements.iter().enumerate() {
        let o = owners[g];
        let blk = &mut blocks[o];
        blk.global_ids.push(g);
        blk.mats.push(elem.material.as_array());
        blk.h.push([elem.h[0] as f32, elem.h[1] as f32, elem.h[2] as f32]);
        blk.centers.push(elem.center);
        let mut lc = [LOCAL_BOUNDARY; 6];
        let mut hi = [0i32; 6];
        for f in 0..6 {
            match mesh.conn[g][f] {
                BOUNDARY => {}
                nb => {
                    let nb = nb as usize;
                    if owners[nb] == o {
                        lc[f] = local_of[nb] as i32;
                    } else {
                        // halo face: allocate a slot, fed by the neighbor's
                        // opposite-face trace each stage
                        lc[f] = LOCAL_HALO;
                        let slot = blk.halo_len;
                        hi[f] = slot as i32;
                        blk.halo_len += 1;
                        blk.halo_src.push((owners[nb], local_of[nb], f ^ 1));
                        blk.halo_mats.push(mesh.elements[nb].material.as_array());
                        plan.copies[o].push((owners[nb], local_of[nb], f ^ 1, slot));
                    }
                }
            }
        }
        blk.conn.push(lc);
        blk.halo_idx.push(hi);
    }
    (blocks, plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::element::Material;
    use crate::mesh::Mesh;

    fn mesh4() -> Mesh {
        Mesh::structured_brick([4, 4, 4], [0.0; 3], [1.0; 3], |_| Material::acoustic(1.0, 1.0))
    }

    #[test]
    fn single_owner_no_halo() {
        let m = mesh4();
        let owners = vec![0usize; m.len()];
        let (blocks, plan) = build_local_blocks(&m, &owners, 1);
        assert_eq!(blocks.len(), 1);
        assert_eq!(blocks[0].len(), 64);
        assert_eq!(blocks[0].halo_len, 0);
        assert_eq!(plan.total_faces(), 0);
        // local conn must mirror global conn exactly (identity mapping,
        // since a single owner preserves order)
        for (g, c) in m.conn.iter().enumerate() {
            for f in 0..6 {
                let expect = match c[f] {
                    BOUNDARY => LOCAL_BOUNDARY,
                    v => v as i32,
                };
                assert_eq!(blocks[0].conn[g][f], expect);
            }
        }
    }

    #[test]
    fn two_owner_split_halo_symmetry() {
        let m = mesh4();
        // split by morton half (the level-1 splice)
        let owners: Vec<usize> = (0..m.len()).map(|e| if e < 32 { 0 } else { 1 }).collect();
        let (blocks, plan) = build_local_blocks(&m, &owners, 2);
        assert_eq!(blocks[0].len() + blocks[1].len(), 64);
        // every halo face in block 0 has a matching copy directive
        assert_eq!(plan.copies[0].len(), blocks[0].halo_len);
        assert_eq!(plan.copies[1].len(), blocks[1].halo_len);
        // cross-owner faces are symmetric
        assert_eq!(
            plan.copies[0].len(),
            plan.copies[1].len(),
            "conforming mesh: same number of halo faces each way"
        );
        // each copy's source face is the opposite of some consumer face
        for &(src_owner, src_local, src_face, slot) in &plan.copies[0] {
            assert_eq!(src_owner, 1);
            assert!(src_local < blocks[1].len());
            assert!(src_face < 6);
            assert!(slot < blocks[0].halo_len);
        }
    }

    #[test]
    fn halo_src_points_back_to_consumer() {
        let m = mesh4();
        let owners: Vec<usize> = (0..m.len()).map(|e| e % 2).collect(); // pathological split
        let (blocks, _) = build_local_blocks(&m, &owners, 2);
        for blk in &blocks {
            for (k, c) in blk.conn.iter().enumerate() {
                for f in 0..6 {
                    if c[f] == LOCAL_HALO {
                        let slot = blk.halo_idx[k][f] as usize;
                        let (src_o, src_l, src_f) = blk.halo_src[slot];
                        // the source element's global neighbor across src_f
                        // must be this very element
                        let src_g = blocks[src_o].global_ids[src_l];
                        assert_eq!(m.conn[src_g][src_f], blk.global_ids[k] as i64);
                    }
                }
            }
        }
    }

    #[test]
    fn owners_partition_elements() {
        let m = mesh4();
        let owners: Vec<usize> = (0..m.len()).map(|e| e / 16).collect();
        let (blocks, _) = build_local_blocks(&m, &owners, 4);
        let total: usize = blocks.iter().map(|b| b.len()).sum();
        assert_eq!(total, m.len());
        let mut seen = vec![false; m.len()];
        for b in &blocks {
            for &g in &b.global_ids {
                assert!(!seen[g]);
                seen[g] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}
