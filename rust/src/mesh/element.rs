//! Element arrays and conforming face connectivity.
//!
//! A [`Mesh`] is the Morton-ordered element array the partitioner consumes:
//! geometry (center, extents), material, and 6-face conforming neighbor
//! connectivity. Faces are ordered `[-x, +x, -y, +y, -z, +z]`, matching the
//! L2 model's `conn` encoding.

use std::collections::HashMap;

use super::morton::MortonKey;

/// Isotropic linear material: density and the two Lame constants.
/// `mu = 0` marks an acoustic region (c_s = 0, paper §2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Material {
    pub rho: f32,
    pub lambda: f32,
    pub mu: f32,
}

impl Material {
    pub fn acoustic(rho: f32, cp: f32) -> Self {
        Material { rho, lambda: rho * cp * cp, mu: 0.0 }
    }

    /// Elastic material from wave speeds: lambda = rho(cp^2 - 2 cs^2).
    pub fn elastic(rho: f32, cp: f32, cs: f32) -> Self {
        assert!(cp * cp >= 2.0 * cs * cs, "cp^2 must exceed 2 cs^2 for lambda >= 0");
        Material { rho, lambda: rho * (cp * cp - 2.0 * cs * cs), mu: rho * cs * cs }
    }

    pub fn cp(&self) -> f32 {
        ((self.lambda + 2.0 * self.mu) / self.rho).sqrt()
    }

    pub fn cs(&self) -> f32 {
        (self.mu / self.rho).sqrt()
    }

    pub fn as_array(&self) -> [f32; 3] {
        [self.rho, self.lambda, self.mu]
    }
}

/// Neighbor encoding in the global mesh: index, or `BOUNDARY` for the
/// physical (traction) boundary.
pub const BOUNDARY: i64 = -2;

/// One hexahedral element (axis-aligned, affine map).
#[derive(Debug, Clone)]
pub struct Element {
    /// Physical center.
    pub center: [f64; 3],
    /// Physical extents (hx, hy, hz).
    pub h: [f64; 3],
    pub material: Material,
    /// Morton key for ordering/partition locality.
    pub key: MortonKey,
}

/// A conforming hexahedral mesh in Morton order.
#[derive(Debug, Clone)]
pub struct Mesh {
    pub elements: Vec<Element>,
    /// `conn[e][f]` = neighbor element index or [`BOUNDARY`].
    pub conn: Vec<[i64; 6]>,
}

impl Mesh {
    pub fn len(&self) -> usize {
        self.elements.len()
    }

    pub fn is_empty(&self) -> bool {
        self.elements.is_empty()
    }

    /// Structured brick of `nx x ny x nz` equal elements over `extent`,
    /// material assigned per element center, elements sorted in Morton
    /// order (grid indices used as integer coordinates).
    pub fn structured_brick(
        dims: [usize; 3],
        origin: [f64; 3],
        extent: [f64; 3],
        material: impl Fn([f64; 3]) -> Material,
    ) -> Mesh {
        let [nx, ny, nz] = dims;
        let h = [extent[0] / nx as f64, extent[1] / ny as f64, extent[2] / nz as f64];
        // enumerate ix,iy,iz; sort by morton of the grid indices
        let mut cells: Vec<(MortonKey, [usize; 3])> = Vec::with_capacity(nx * ny * nz);
        for iz in 0..nz {
            for iy in 0..ny {
                for ix in 0..nx {
                    cells.push((MortonKey::encode(ix as u32, iy as u32, iz as u32), [ix, iy, iz]));
                }
            }
        }
        cells.sort_by_key(|c| c.0);
        let mut grid_to_elem: HashMap<[usize; 3], usize> = HashMap::with_capacity(cells.len());
        for (e, (_, idx)) in cells.iter().enumerate() {
            grid_to_elem.insert(*idx, e);
        }
        let mut elements = Vec::with_capacity(cells.len());
        let mut conn = Vec::with_capacity(cells.len());
        for (key, [ix, iy, iz]) in &cells {
            let center = [
                origin[0] + (*ix as f64 + 0.5) * h[0],
                origin[1] + (*iy as f64 + 0.5) * h[1],
                origin[2] + (*iz as f64 + 0.5) * h[2],
            ];
            elements.push(Element { center, h, material: material(center), key: *key });
            let mut c = [BOUNDARY; 6];
            let idx = [*ix as i64, *iy as i64, *iz as i64];
            let lims = [nx as i64, ny as i64, nz as i64];
            for (f, (axis, delta)) in
                [(0usize, -1i64), (0, 1), (1, -1), (1, 1), (2, -1), (2, 1)].iter().enumerate()
            {
                let mut j = idx;
                j[*axis] += delta;
                if j[*axis] >= 0 && j[*axis] < lims[*axis] {
                    let g = [j[0] as usize, j[1] as usize, j[2] as usize];
                    c[f] = grid_to_elem[&g] as i64;
                }
            }
            conn.push(c);
        }
        Mesh { elements, conn }
    }

    /// Glue two meshes along the +x face of `a` / -x face of `b`.
    /// `b` must sit exactly to the right of `a` with matching (ny, nz)
    /// layer structure; faces are matched geometrically by center.
    pub fn glue_x(a: Mesh, b: Mesh) -> Mesh {
        let na = a.len();
        let mut elements = a.elements;
        elements.extend(b.elements);
        let mut conn = a.conn;
        conn.extend(b.conn.iter().map(|c| {
            let mut c2 = *c;
            for v in c2.iter_mut() {
                if *v >= 0 {
                    *v += na as i64;
                }
            }
            c2
        }));
        // geometric matching of the interface: +x boundary faces of a
        // against -x boundary faces of b by (y, z) center and size
        let keyf = |c: &[f64; 3], h: &[f64; 3]| {
            (
                (c[1] / h[1] * 2.0).round() as i64,
                (c[2] / h[2] * 2.0).round() as i64,
            )
        };
        let xmax = elements[..na]
            .iter()
            .map(|e| e.center[0] + e.h[0] / 2.0)
            .fold(f64::MIN, f64::max);
        let mut right_faces: HashMap<(i64, i64), usize> = HashMap::new();
        for (i, e) in elements.iter().enumerate().skip(na) {
            if conn[i][0] == BOUNDARY && (e.center[0] - e.h[0] / 2.0 - xmax).abs() < 1e-9 {
                right_faces.insert(keyf(&e.center, &e.h), i);
            }
        }
        for i in 0..na {
            if conn[i][1] == BOUNDARY
                && (elements[i].center[0] + elements[i].h[0] / 2.0 - xmax).abs() < 1e-9
            {
                if let Some(&j) = right_faces.get(&keyf(&elements[i].center, &elements[i].h)) {
                    conn[i][1] = j as i64;
                    conn[j][0] = i as i64;
                }
            }
        }
        Mesh { elements, conn }
    }

    /// Count interior faces (each counted once) and boundary faces.
    pub fn face_counts(&self) -> (usize, usize) {
        let mut interior = 0;
        let mut boundary = 0;
        for c in &self.conn {
            for &v in c {
                if v == BOUNDARY {
                    boundary += 1;
                } else {
                    interior += 1;
                }
            }
        }
        (interior / 2, boundary)
    }

    /// Validate symmetry of the connectivity: if e lists j across face f,
    /// j must list e across the opposite face f^1.
    pub fn check_consistency(&self) -> bool {
        for (e, c) in self.conn.iter().enumerate() {
            for (f, &v) in c.iter().enumerate() {
                if v >= 0 {
                    let back = self.conn[v as usize][f ^ 1];
                    if back != e as i64 {
                        return false;
                    }
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(_c: [f64; 3]) -> Material {
        Material::acoustic(1.0, 1.0)
    }

    #[test]
    fn brick_counts_and_consistency() {
        let m = Mesh::structured_brick([4, 4, 4], [0.0; 3], [1.0; 3], mat);
        assert_eq!(m.len(), 64);
        assert!(m.check_consistency());
        let (int, bnd) = m.face_counts();
        assert_eq!(int, 3 * 4 * 4 * 3); // 3 directions x 3 planes x 16
        assert_eq!(bnd, 6 * 16);
    }

    #[test]
    fn brick_morton_sorted() {
        let m = Mesh::structured_brick([4, 4, 4], [0.0; 3], [1.0; 3], mat);
        for w in m.elements.windows(2) {
            assert!(w[0].key < w[1].key);
        }
    }

    #[test]
    fn non_pow2_brick_still_consistent() {
        let m = Mesh::structured_brick([3, 5, 2], [0.0; 3], [1.5, 2.5, 1.0], mat);
        assert_eq!(m.len(), 30);
        assert!(m.check_consistency());
    }

    #[test]
    fn glue_two_bricks() {
        let a = Mesh::structured_brick([2, 2, 2], [0.0; 3], [1.0; 3], mat);
        let b = Mesh::structured_brick([2, 2, 2], [1.0, 0.0, 0.0], [1.0; 3], |_| {
            Material::elastic(1.0, 3.0, 2.0)
        });
        let g = Mesh::glue_x(a, b);
        assert_eq!(g.len(), 16);
        assert!(g.check_consistency());
        let (int, bnd) = g.face_counts();
        assert_eq!(int, 2 * (3 * 2 * 2) + 4); // two bricks' interiors + 4 glued
        assert_eq!(bnd, 2 * 24 - 8);
    }

    #[test]
    fn material_constructors() {
        let a = Material::acoustic(2.0, 3.0);
        assert!((a.cp() - 3.0).abs() < 1e-6);
        assert_eq!(a.cs(), 0.0);
        let e = Material::elastic(1.0, 3.0, 2.0);
        assert!((e.cp() - 3.0).abs() < 1e-6);
        assert!((e.cs() - 2.0).abs() < 1e-6);
    }
}
