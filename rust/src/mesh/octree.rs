//! Linear octrees: octant arithmetic, uniform & adaptive leaf enumeration,
//! and 2:1 balance checking.
//!
//! The compute path of this reproduction uses conforming (same-level)
//! leaves, matching the paper's uniform-brick experiments; adaptive
//! refinement is provided for partition-quality studies (the partitioner
//! operates on any Morton-sorted leaf array).

use super::morton::{MortonKey, MAX_LEVEL};

/// An octant: anchor (integer coords at `MAX_LEVEL` resolution) + level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Octant {
    pub x: u32,
    pub y: u32,
    pub z: u32,
    pub level: u32,
}

impl Octant {
    /// Root octant covering the whole tree domain.
    pub fn root() -> Self {
        Octant { x: 0, y: 0, z: 0, level: 0 }
    }

    /// Edge length in integer units at `MAX_LEVEL` resolution.
    pub fn extent(&self) -> u32 {
        1 << (MAX_LEVEL - self.level)
    }

    /// The eight children in Morton order.
    pub fn children(&self) -> [Octant; 8] {
        let h = self.extent() / 2;
        let mut out = [*self; 8];
        for (i, c) in out.iter_mut().enumerate() {
            c.level = self.level + 1;
            c.x = self.x + if i & 1 != 0 { h } else { 0 };
            c.y = self.y + if i & 2 != 0 { h } else { 0 };
            c.z = self.z + if i & 4 != 0 { h } else { 0 };
        }
        out
    }

    /// Morton key of the anchor (ties broken by level elsewhere).
    pub fn key(&self) -> MortonKey {
        MortonKey::encode(self.x, self.y, self.z)
    }

    /// Face-neighbor anchor in direction `dir` (0..6: -x,+x,-y,+y,-z,+z),
    /// or None if it would leave the unit tree.
    pub fn face_neighbor(&self, dir: usize) -> Option<Octant> {
        let e = self.extent() as i64;
        let lim = 1i64 << MAX_LEVEL;
        let (mut x, mut y, mut z) = (self.x as i64, self.y as i64, self.z as i64);
        match dir {
            0 => x -= e,
            1 => x += e,
            2 => y -= e,
            3 => y += e,
            4 => z -= e,
            5 => z += e,
            _ => unreachable!(),
        }
        if x < 0 || y < 0 || z < 0 || x >= lim || y >= lim || z >= lim {
            return None;
        }
        Some(Octant { x: x as u32, y: y as u32, z: z as u32, level: self.level })
    }
}

/// Uniformly refine the root to `level`, returning leaves in Morton order.
pub fn uniform_leaves(level: u32) -> Vec<Octant> {
    assert!(level <= 10, "uniform refinement beyond 2^30 leaves is a mistake");
    let n = 1u32 << level;
    let e = 1u32 << (MAX_LEVEL - level);
    let mut leaves = Vec::with_capacity((n as usize).pow(3));
    for z in 0..n {
        for y in 0..n {
            for x in 0..n {
                leaves.push(Octant { x: x * e, y: y * e, z: z * e, level });
            }
        }
    }
    leaves.sort_by_key(|o| (o.key(), o.level));
    leaves
}

/// Adaptively refine: split every leaf for which `pred` returns true,
/// starting from the root, up to `max_level`. Leaves in Morton order.
pub fn adaptive_leaves(max_level: u32, pred: impl Fn(&Octant) -> bool) -> Vec<Octant> {
    let mut stack = vec![Octant::root()];
    let mut leaves = Vec::new();
    while let Some(o) = stack.pop() {
        if o.level < max_level && pred(&o) {
            stack.extend_from_slice(&o.children());
        } else {
            leaves.push(o);
        }
    }
    leaves.sort_by_key(|o| (o.key(), o.level));
    leaves
}

/// Check the 2:1 balance condition: face-adjacent leaves differ by at most
/// one level. (mangll guarantees this by construction [6]; we verify.)
pub fn is_two_to_one_balanced(leaves: &[Octant]) -> bool {
    use std::collections::HashMap;
    // map anchor -> level for quick containment queries
    let by_anchor: HashMap<(u32, u32, u32), u32> =
        leaves.iter().map(|o| ((o.x, o.y, o.z), o.level)).collect();
    for o in leaves {
        for dir in 0..6 {
            if let Some(nb) = o.face_neighbor(dir) {
                // find the leaf containing nb's anchor at any coarser level
                let mut found = None;
                for lvl in (0..=MAX_LEVEL).rev() {
                    let mask = !((1u32 << (MAX_LEVEL - lvl)) - 1);
                    let key = (nb.x & mask, nb.y & mask, nb.z & mask);
                    if let Some(&l) = by_anchor.get(&key) {
                        if l == lvl {
                            found = Some(l);
                            break;
                        }
                    }
                }
                if let Some(l) = found {
                    if (l as i64 - o.level as i64).abs() > 1 {
                        return false;
                    }
                }
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_counts() {
        assert_eq!(uniform_leaves(0).len(), 1);
        assert_eq!(uniform_leaves(1).len(), 8);
        assert_eq!(uniform_leaves(3).len(), 512);
    }

    #[test]
    fn uniform_leaves_are_morton_sorted() {
        let leaves = uniform_leaves(2);
        for w in leaves.windows(2) {
            assert!(w[0].key() < w[1].key());
        }
    }

    #[test]
    fn children_partition_parent() {
        let root = Octant::root();
        let kids = root.children();
        let e = root.extent();
        // each child has half extent, anchors tile the corners
        for k in &kids {
            assert_eq!(k.extent(), e / 2);
        }
        let anchors: std::collections::HashSet<_> =
            kids.iter().map(|k| (k.x, k.y, k.z)).collect();
        assert_eq!(anchors.len(), 8);
    }

    #[test]
    fn face_neighbor_boundary() {
        let leaves = uniform_leaves(1);
        // first leaf (corner) has no -x neighbor
        assert!(leaves[0].face_neighbor(0).is_none());
        assert!(leaves[0].face_neighbor(1).is_some());
    }

    #[test]
    fn uniform_is_balanced() {
        assert!(is_two_to_one_balanced(&uniform_leaves(2)));
    }

    #[test]
    fn adaptive_refinement_respects_predicate() {
        // refine only the first octant chain: leaves at mixed levels
        let leaves = adaptive_leaves(3, |o| o.x == 0 && o.y == 0 && o.z == 0);
        assert!(leaves.len() > 8);
        let levels: std::collections::HashSet<_> = leaves.iter().map(|o| o.level).collect();
        assert!(levels.len() > 1, "expected mixed levels, got {levels:?}");
    }
}
