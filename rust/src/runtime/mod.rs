//! PJRT runtime: load the AOT artifacts and execute them.
//!
//! The execution half ([`client`]) depends on the `xla` crate (a git-only
//! dependency the offline build cannot fetch) and is therefore gated
//! behind the off-by-default `pjrt` cargo feature; manifest parsing and
//! bucket selection ([`artifacts`]) are always available.
//!
//! Python (jax + pallas) runs once at build time (`make artifacts`),
//! lowering the L2 stage function to HLO **text** (xla_extension 0.5.1
//! rejects jax>=0.5 serialized protos — 64-bit instruction ids; the text
//! parser reassigns them). This module loads those files, compiles them on
//! the PJRT CPU client and exposes a [`crate::solver::StageBackend`] so
//! the coordinator's hot path never touches python.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod client;

pub use artifacts::{ArtifactManifest, ArtifactMeta};
#[cfg(feature = "pjrt")]
pub use client::{PjrtBackend, PjrtRuntime};
