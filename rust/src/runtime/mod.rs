//! PJRT runtime: load the AOT artifacts and execute them.
//!
//! Python (jax + pallas) runs once at build time (`make artifacts`),
//! lowering the L2 stage function to HLO **text** (xla_extension 0.5.1
//! rejects jax>=0.5 serialized protos — 64-bit instruction ids; the text
//! parser reassigns them). This module loads those files, compiles them on
//! the PJRT CPU client and exposes a [`crate::solver::StageBackend`] so
//! the coordinator's hot path never touches python.

pub mod artifacts;
pub mod client;

pub use artifacts::{ArtifactManifest, ArtifactMeta};
pub use client::{PjrtBackend, PjrtRuntime};
