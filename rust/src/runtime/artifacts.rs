//! artifacts/manifest.json parsing and shape-bucket selection.
//!
//! Parsed with the in-tree JSON parser (`util::json`) — the offline build
//! carries no serde.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context};

use crate::util::Json;
use crate::Result;

#[derive(Debug, Clone)]
pub struct ShapeSig {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String,
    pub path: String,
    pub order: usize,
    pub k: usize,
    pub halo: usize,
    pub inputs: Vec<ShapeSig>,
    pub outputs: Vec<ShapeSig>,
    pub sha256: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub format: String,
    pub artifacts: Vec<ArtifactMeta>,
    pub lsrk_a: Vec<f64>,
    pub lsrk_b: Vec<f64>,
    pub dir: PathBuf,
}

fn shape_sigs(j: Option<&Json>) -> Result<Vec<ShapeSig>> {
    let mut out = Vec::new();
    if let Some(arr) = j.and_then(|v| v.as_arr()) {
        for s in arr {
            out.push(ShapeSig {
                shape: s
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                    .unwrap_or_default(),
                dtype: s
                    .get("dtype")
                    .and_then(|v| v.as_str())
                    .unwrap_or_default()
                    .to_string(),
            });
        }
    }
    Ok(out)
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        let format = j
            .get("format")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("manifest missing format"))?
            .to_string();
        if format != "hlo-text" {
            bail!("unsupported artifact format {format}");
        }
        let mut artifacts = Vec::new();
        for a in j.get("artifacts").and_then(|v| v.as_arr()).unwrap_or(&[]) {
            let gets = |k: &str| {
                a.get(k)
                    .and_then(|v| v.as_str())
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("artifact missing {k}"))
            };
            let getn = |k: &str| {
                a.get(k).and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("artifact missing {k}"))
            };
            artifacts.push(ArtifactMeta {
                name: gets("name")?,
                kind: gets("kind")?,
                path: gets("path")?,
                order: getn("order")?,
                k: getn("k")?,
                halo: getn("halo")?,
                inputs: shape_sigs(a.get("inputs"))?,
                outputs: shape_sigs(a.get("outputs"))?,
                sha256: a
                    .get("sha256")
                    .and_then(|v| v.as_str())
                    .unwrap_or_default()
                    .to_string(),
            });
        }
        let nums = |k: &str| -> Vec<f64> {
            j.get(k)
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
                .unwrap_or_default()
        };
        let m = ArtifactManifest {
            format,
            artifacts,
            lsrk_a: nums("lsrk_a"),
            lsrk_b: nums("lsrk_b"),
            dir,
        };
        // the rust LSRK tableau must agree with what the artifacts embed
        for (a, b) in m.lsrk_a.iter().zip(crate::solver::LSRK_A.iter()) {
            if (a - b).abs() > 1e-12 {
                bail!("LSRK 'a' tableau mismatch between python and rust");
            }
        }
        for (a, b) in m.lsrk_b.iter().zip(crate::solver::LSRK_B.iter()) {
            if (a - b).abs() > 1e-12 {
                bail!("LSRK 'b' tableau mismatch between python and rust");
            }
        }
        Ok(m)
    }

    /// Default artifact directory: $REPRO_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("REPRO_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Smallest stage artifact bucket fitting (order, k_real, halo_real).
    pub fn pick_stage(&self, order: usize, k: usize, halo: usize) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == "stage" && a.order == order && a.k >= k && a.halo >= halo)
            .min_by_key(|a| (a.k, a.halo))
            .ok_or_else(|| {
                anyhow!(
                    "no stage artifact for order {order}, k >= {k}, halo >= {halo}; \
                     regenerate with `python -m compile.aot --orders ... --buckets ...`"
                )
            })
    }

    /// Smallest energy artifact fitting (order, k_real).
    pub fn pick_energy(&self, order: usize, k: usize) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == "energy" && a.order == order && a.k >= k)
            .min_by_key(|a| a.k)
            .ok_or_else(|| anyhow!("no energy artifact for order {order}, k >= {k}"))
    }

    pub fn file_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.path)
    }

    /// Orders available in this artifact set.
    pub fn orders(&self) -> Vec<usize> {
        let mut v: Vec<usize> =
            self.artifacts.iter().filter(|a| a.kind == "stage").map(|a| a.order).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> Option<PathBuf> {
        let d = ArtifactManifest::default_dir();
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn load_and_pick() {
        let Some(dir) = manifest_dir() else {
            eprintln!("skipping: no artifacts built");
            return;
        };
        let m = ArtifactManifest::load(dir).unwrap();
        assert!(!m.artifacts.is_empty());
        let orders = m.orders();
        assert!(!orders.is_empty());
        let o = orders[0];
        let smallest_k = m
            .artifacts
            .iter()
            .filter(|a| a.kind == "stage" && a.order == o)
            .map(|a| a.k)
            .min()
            .unwrap();
        let a = m.pick_stage(o, 1, 1).unwrap();
        assert_eq!(a.k, smallest_k, "must pick the smallest fitting bucket");
        assert!(m.pick_stage(o, usize::MAX / 2, 1).is_err());
        // input signature sanity: stage artifacts carry 9 inputs, f32/i32
        assert_eq!(a.inputs.len(), 9);
        assert_eq!(a.inputs[3].dtype, "int32");
    }

    #[test]
    fn synthetic_manifest_parses() {
        let dir = std::env::temp_dir().join(format!("repro_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let text = format!(
            r#"{{"format":"hlo-text","artifacts":[
                {{"name":"stage_n1_k8_h32","kind":"stage","path":"x.hlo.txt",
                  "order":1,"k":8,"halo":32,
                  "inputs":[{{"shape":[8,9,2,2,2],"dtype":"float32"}}],
                  "outputs":[{{"shape":[8,9,2,2,2],"dtype":"float32"}}]}}],
               "lsrk_a":{:?},"lsrk_b":{:?}}}"#,
            crate::solver::LSRK_A.to_vec(),
            crate::solver::LSRK_B.to_vec()
        );
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        assert_eq!(m.pick_stage(1, 5, 10).unwrap().name, "stage_n1_k8_h32");
        assert!(m.pick_stage(1, 9, 10).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_tableau_rejected() {
        let dir = std::env::temp_dir().join(format!("repro_manifest_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let text = r#"{"format":"hlo-text","artifacts":[],
                       "lsrk_a":[0.5,0,0,0,0],"lsrk_b":[0,0,0,0,0]}"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        assert!(ArtifactManifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
