//! The PJRT execution wrapper: compile-once per artifact, execute per stage.
//!
//! One [`PjrtRuntime`] owns a PJRT CPU client plus a compile cache keyed by
//! artifact name; [`PjrtBackend`] binds one compiled stage executable to a
//! block's shape bucket and implements [`StageBackend`]. The underlying
//! `xla::PjRtClient` is `Rc`-based (not `Send`), so each device worker
//! thread owns its own runtime — matching the paper's process model, where
//! the host and the offloaded MIC process are separate executors.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{anyhow, Context};
use xla::{ElementType, Literal, PjRtLoadedExecutable};

use super::artifacts::{ArtifactManifest, ArtifactMeta};
use crate::solver::reference::KernelTimes;
use crate::solver::state::{BlockState, NFIELDS};
use crate::solver::StageBackend;
use crate::Result;

/// A PJRT CPU client + artifact registry + compile cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub manifest: ArtifactManifest,
    cache: HashMap<String, Rc<PjRtLoadedExecutable>>,
}

impl PjrtRuntime {
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = ArtifactManifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client, manifest, cache: HashMap::new() })
    }

    /// Load with the default artifact directory.
    pub fn from_env() -> Result<Self> {
        Self::new(ArtifactManifest::default_dir())
    }

    fn compile(&mut self, meta: &ArtifactMeta) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.get(&meta.name) {
            return Ok(exe.clone());
        }
        let path = self.manifest.file_path(meta);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp).context("PJRT compile")?);
        self.cache.insert(meta.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Build a stage backend for a block (the block must already be padded
    /// to the chosen artifact's buckets — use [`Self::buckets_for`]).
    pub fn stage_backend(&mut self, st: &BlockState) -> Result<PjrtBackend> {
        let meta = self
            .manifest
            .pick_stage(st.order, st.k_real, st.halo_real)?
            .clone();
        if meta.k != st.k_pad || meta.halo != st.halo_pad {
            return Err(anyhow!(
                "block padded to (k={}, h={}) but artifact {} expects (k={}, h={})",
                st.k_pad, st.halo_pad, meta.name, meta.k, meta.halo
            ));
        }
        let exe = self.compile(&meta)?;
        PjrtBackend::new(exe, meta, self.client.clone(), st)
    }

    /// The (k, halo) bucket a block of this size will be padded to.
    pub fn buckets_for(&self, order: usize, k: usize, halo: usize) -> Result<(usize, usize)> {
        let meta = self.manifest.pick_stage(order, k, halo)?;
        Ok((meta.k, meta.halo))
    }

    /// Evaluate the energy artifact on a block.
    pub fn energy(&mut self, st: &BlockState) -> Result<f64> {
        let meta = self.manifest.pick_energy(st.order, st.k_pad)?.clone();
        if meta.k != st.k_pad {
            return Err(anyhow!(
                "energy artifact bucket {} != block padding {}",
                meta.k, st.k_pad
            ));
        }
        let exe = self.compile(&meta)?;
        let m = st.m;
        let q = lit_f32(&st.q, &[st.k_pad, NFIELDS, m, m, m])?;
        let mats = lit_f32(&st.mats, &[st.k_pad, 3])?;
        let h = lit_f32(&st.h, &[st.k_pad, 3])?;
        let result = exe.execute::<Literal>(&[q, mats, h])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let v = out.to_vec::<f32>()?;
        Ok(v[0] as f64)
    }
}

fn lit_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    debug_assert_eq!(n, data.len());
    // SAFETY: viewing an f32 slice as bytes — same allocation, exact
    // byte length (4 per element), u8 has no alignment or validity
    // requirements, and the borrow ends with this statement.
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::F32, dims, bytes)?)
}

fn lit_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    debug_assert_eq!(n, data.len());
    // SAFETY: viewing an i32 slice as bytes — same allocation, exact
    // byte length (4 per element), u8 has no alignment or validity
    // requirements, and the borrow ends with this statement.
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::S32, dims, bytes)?)
}

/// One compiled stage executable bound to a shape bucket.
///
/// The five inputs that never change across a run (connectivity, materials,
/// extents) are built as literals once at construction and reused —
/// `execute` clones them internally; rebuilding them every stage cost ~10%
/// at k=64 (EXPERIMENTS.md §Perf). NOTE a pure-device path via
/// `execute_b` + persistent `PjRtBuffer`s was attempted and reverted: the
/// crate's `execute_b` segfaults on this 9-parameter executable (works on
/// 2-parameter toys) — see DESIGN.md §Perf. q/res round-trip through the
/// host each stage regardless, since PJRT returns the output tuple as one
/// host-fetchable buffer.
pub struct PjrtBackend {
    exe: Rc<PjRtLoadedExecutable>,
    pub meta: ArtifactMeta,
    pub calls: usize,
    /// (conn, halo_idx, mats, halo_mats, h) literals, fixed per run.
    static_lits: Vec<Literal>,
}

impl PjrtBackend {
    fn new(
        exe: Rc<PjRtLoadedExecutable>,
        meta: ArtifactMeta,
        _client: xla::PjRtClient,
        st: &BlockState,
    ) -> Result<Self> {
        let k = st.k_pad;
        let hs = st.halo_pad;
        let static_lits = vec![
            lit_i32(&st.conn, &[k, 6])?,
            lit_i32(&st.halo_idx, &[k, 6])?,
            lit_f32(&st.mats, &[k, 3])?,
            lit_f32(&st.halo_mats, &[hs, 3])?,
            lit_f32(&st.h, &[k, 3])?,
        ];
        Ok(PjrtBackend { exe, meta, calls: 0, static_lits })
    }

    /// Execute one LSRK stage on the block through the artifact:
    /// inputs (q, res, halo, conn, halo_idx, mats, halo_mats, h, scal),
    /// outputs (q', res', traces').
    fn run_stage(&mut self, st: &mut BlockState, dt: f32, a: f32, b: f32) -> Result<()> {
        let m = st.m;
        let k = st.k_pad;
        let hs = st.halo_pad;
        let q = lit_f32(&st.q, &[k, NFIELDS, m, m, m])?;
        let res = lit_f32(&st.res, &[k, NFIELDS, m, m, m])?;
        let halo = lit_f32(&st.halo, &[hs, NFIELDS, m, m])?;
        let scal = lit_f32(&[dt, a, b], &[3])?;
        let args: Vec<&Literal> = vec![
            &q,
            &res,
            &halo,
            &self.static_lits[0],
            &self.static_lits[1],
            &self.static_lits[2],
            &self.static_lits[3],
            &self.static_lits[4],
            &scal,
        ];
        let result = self.exe.execute::<&Literal>(&args)?[0][0].to_literal_sync()?;
        let mut outs = result.to_tuple()?;
        if outs.len() != 3 {
            return Err(anyhow!("stage artifact returned {} outputs, want 3", outs.len()));
        }
        let traces = outs.pop().unwrap();
        let res = outs.pop().unwrap();
        let q = outs.pop().unwrap();
        q.copy_raw_to(&mut st.q)?;
        res.copy_raw_to(&mut st.res)?;
        traces.copy_raw_to(&mut st.traces)?;
        self.calls += 1;
        Ok(())
    }
}

impl StageBackend for PjrtBackend {
    fn stage(&mut self, st: &mut BlockState, dt: f32, a: f32, b: f32) -> Result<KernelTimes> {
        let t0 = std::time::Instant::now();
        self.run_stage(st, dt, a, b)?;
        // the artifact fuses all kernels into one executable: attribute the
        // wall time to volume_loop (dominant) for coarse accounting; the
        // fine-grained split comes from the cost models / reference path.
        let mut t = KernelTimes::default();
        t.volume_loop = t0.elapsed().as_secs_f64();
        Ok(t)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
