//! Plain-text table / CSV rendering for experiment outputs.

use std::fmt::Write as _;
use std::path::Path;

use crate::Result;

/// Render an aligned text table.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        let mut first = true;
        for (i, c) in cells.iter().enumerate().take(ncol) {
            if !first {
                out.push_str("  ");
            }
            let _ = write!(out, "{:>w$}", c, w = widths[i]);
            first = false;
        }
        out.push('\n');
    };
    line(&mut out, &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
    out.push_str(&"-".repeat(total));
    out.push('\n');
    for row in rows {
        line(&mut out, row);
    }
    out
}

/// Write rows as CSV (headers first).
pub fn write_csv(path: impl AsRef<Path>, headers: &[&str], rows: &[Vec<String>]) -> Result<()> {
    let mut s = String::new();
    s.push_str(&headers.join(","));
    s.push('\n');
    for row in rows {
        s.push_str(&row.join(","));
        s.push('\n');
    }
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, s)?;
    Ok(())
}

/// Format seconds in a human scale.
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 1.0 {
        format!("{s:.2}")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let t = render_table(
            &["name", "value"],
            &[
                vec!["a".into(), "1".into()],
                vec!["long-name".into(), "12345".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[3].contains("12345"));
    }

    #[test]
    fn csv_roundtrip(){
        let dir = std::env::temp_dir().join("repro_test_csv");
        let p = dir.join("x.csv");
        write_csv(&p, &["a", "b"], &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }

    #[test]
    fn fmt_scales() {
        assert_eq!(fmt_secs(250.0), "250");
        assert_eq!(fmt_secs(2.5), "2.50");
        assert!(fmt_secs(0.0025).ends_with("ms"));
        assert!(fmt_secs(2.5e-5).ends_with("us"));
    }
}
