//! Pluggable message-fabric transports for the cluster runtime.
//!
//! [`super::cluster`] routes halo traces worker-to-worker on three lanes
//! (self / intra-node / inter-node). The routing tables and the §5.5 lane
//! classification are transport-independent; this module owns *how* a
//! delivery group actually crosses between two workers:
//!
//! * [`TransportKind::InProc`] — the original std `mpsc` channels on
//!   every cross-worker lane (the baseline the equivalence tests pin
//!   everything else to).
//! * [`TransportKind::Shm`] — serialization-free shared-memory lanes:
//!   one lock-free SPSC slot ring ([`crate::util::shm`]) per directed
//!   worker pair. A trace is written once by the producer into a ring
//!   slot and copied once by the consumer straight into the destination
//!   block's halo storage — no queue-node allocation, no locks, no
//!   intermediate framing.
//! * [`TransportKind::Socket`] — the honest lane split: intra-node
//!   (PCI stand-in) pairs keep the shared-memory rings, while every
//!   inter-node (MPI stand-in) pair crosses a real kernel socket
//!   (`UnixStream` pair) carrying length-prefixed Deliver frames
//!   ([`crate::util::framing`]). Workers are still thread-hosted — the
//!   bytes, syscalls and wakeups are the real inter-process cost, the
//!   address-space split is the remaining step (see ROADMAP).
//!
//! Every worker holds one [`MixedEndpoint`]; `ship`/`recv_group` hide
//! which mechanism each peer lane uses. Delivery is *grouped*: one group
//! per (src, dst) pair per routed stage, empty groups on stage failure,
//! so the cluster lockstep counts groups identically on all transports.
//!
//! [`measure_fabric_links`] probes the latency/bandwidth of the actual
//! mechanisms (`mpsc` hop, ring hop, socket hop) so
//! [`crate::costmodel::network`] / [`crate::costmodel::pci`] can be
//! calibrated against measured links instead of guessed constants.

use std::io::BufReader;
use std::net::Shutdown;
use std::os::unix::net::UnixStream;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

// The FabricCtl poison/halt flags come through the util::sync shim so
// the loom suite can model the poison-vs-blocked-recv teardown.
use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::Arc;

use anyhow::{anyhow, bail};

use super::fault::FaultInjector;
use crate::solver::state::BlockState;
use crate::util::framing::{self, FrameItem, FrameWriter};
use crate::util::shm::{slot_ring, RingConsumer, RingProducer};
use crate::Result;

/// One halo installment: (destination local block, halo slot, trace data).
pub type Delivery = (usize, usize, Vec<f32>);

/// One delivery group — everything one peer ships this worker in one
/// routed stage.
pub type Deliveries = Vec<Delivery>;

/// One routed copy:
/// (src local block, src elem, src face, dst local block, dst halo slot).
pub type CopyRoute = (usize, usize, usize, usize, usize);

// ---------------------------------------------------------------------------
// transport selection
// ---------------------------------------------------------------------------

/// Which mechanism carries cross-worker delivery groups.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// In-process `mpsc` channels on every lane (baseline).
    #[default]
    InProc,
    /// Lock-free shared-memory slot rings on every lane.
    Shm,
    /// Rings intra-node, Unix-domain sockets inter-node.
    Socket,
}

impl TransportKind {
    pub fn label(self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Shm => "shm",
            TransportKind::Socket => "socket",
        }
    }
}

impl std::str::FromStr for TransportKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "inproc" => Ok(TransportKind::InProc),
            "shm" => Ok(TransportKind::Shm),
            "socket" => Ok(TransportKind::Socket),
            other => Err(anyhow!("unknown transport {other:?} (inproc|shm|socket)")),
        }
    }
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

// ---------------------------------------------------------------------------
// fabric control plane
// ---------------------------------------------------------------------------

/// Shared fabric stop flags, split by failure domain:
///
/// * **poison** — permanent. Set on teardown, job cancellation, or a
///   genuine unrecoverable failure; every endpoint blocked in a ship/recv
///   wait bails out instead of spinning on deliveries that will never
///   come, and the run refuses further steps.
/// * **halt** — clearable. Set when a worker dies mid-stage so the
///   *survivors* unblock from the broken exchange, then cleared once
///   recovery has restored a consistent membership. Survivors stay
///   schedulable; only the interrupted stage is lost.
#[derive(Debug, Clone, Default)]
pub struct FabricCtl {
    poison: Arc<AtomicBool>,
    halt: Arc<AtomicBool>,
}

impl FabricCtl {
    pub fn new() -> Self {
        FabricCtl::default()
    }

    pub fn poison(&self) {
        self.poison.store(true, Ordering::Release);
    }

    pub fn is_poisoned(&self) -> bool {
        self.poison.load(Ordering::Acquire)
    }

    /// Stop the current exchange without condemning the fabric: blocked
    /// endpoints bail, but [`FabricCtl::clear_halt`] re-arms them.
    pub fn halt(&self) {
        self.halt.store(true, Ordering::Release);
    }

    pub fn clear_halt(&self) {
        self.halt.store(false, Ordering::Release);
    }

    pub fn is_halted(&self) -> bool {
        self.halt.load(Ordering::Acquire)
    }

    /// Whether endpoints should stop waiting right now (either flag).
    pub fn is_stopped(&self) -> bool {
        self.is_poisoned() || self.is_halted()
    }

    /// Human label for error messages; "poisoned" is pinned by tests and
    /// by the serve layer's cancellation path.
    pub fn stop_reason(&self) -> &'static str {
        if self.is_poisoned() {
            "poisoned"
        } else {
            "halted for recovery"
        }
    }
}

// ---------------------------------------------------------------------------
// the endpoint
// ---------------------------------------------------------------------------

/// What one worker uses to talk to the fabric: ship one outbound group
/// per peer per routed stage, receive one group per sending peer.
///
/// Both calls return the *payload* f32 bytes moved (headers/framing
/// excluded) so the worker can account per-lane traffic honestly.
pub trait FabricEndpoint: Send {
    /// Ship one delivery group to `dst`. `items` are this worker's
    /// routed copies for that peer; traces are read from `blocks`. When
    /// `failed`, an empty group is shipped instead so the peer's
    /// per-stage group count stays intact.
    fn ship(
        &mut self,
        dst: usize,
        items: &[CopyRoute],
        blocks: &[BlockState],
        failed: bool,
    ) -> Result<usize>;

    /// Block until one more inbound delivery group has been fully
    /// installed into `blocks` (plus whatever else arrived while
    /// waiting). Fails when the fabric is poisoned or a lane closed.
    fn recv_group(&mut self, blocks: &mut [BlockState]) -> Result<usize>;

    /// Drop any buffered/in-flight deliveries (rebalance swaps routing
    /// tables between stages on empty lanes; a failed stage may leave
    /// stragglers).
    fn clear_pending(&mut self);
}

/// Per-destination send lane of a [`MixedEndpoint`].
enum LaneTx {
    /// No lane (self, or the worker itself).
    None,
    Mpsc(Sender<(usize, Deliveries)>),
    Ring(RingProducer),
    Stream(UnixStream),
}

/// One worker's fabric endpoint; mixes mechanisms per peer lane.
///
/// Ring protocol: a group is one *header* record (`w0 = n_items`,
/// empty payload) followed by `n_items` face records (`w0 = dst block`,
/// `w1 = halo slot`, payload = trace). Records of one group never
/// interleave with another's on the same ring (SPSC, one group per
/// stage), so the consumer tracks a (started, remaining) state machine
/// per source ring.
pub struct MixedEndpoint {
    me: usize,
    ctl: FabricCtl,
    /// Send lanes by destination worker.
    tx: Vec<LaneTx>,
    /// Inbound rings by source worker.
    rings_in: Vec<Option<RingConsumer>>,
    /// Inbound channel: mpsc peers send whole groups here; socket reader
    /// threads decode frames into it too.
    chan_rx: Receiver<(usize, Deliveries)>,
    /// Keeps `chan_rx` connected even when no peer holds a sender (a
    /// worker whose peers are all ring-connected must still be able to
    /// block on the channel with a timeout, not die on Disconnected).
    _chan_keepalive: Sender<(usize, Deliveries)>,
    /// Reusable socket frame encoder.
    enc: FrameWriter,
    /// Ring consumer state machine: mid-group flag per source…
    ring_started: Vec<bool>,
    /// …and face records remaining in the current group.
    ring_remaining: Vec<usize>,
    /// Ring groups fully consumed but not yet credited to a
    /// `recv_group` call.
    ring_groups_done: usize,
    /// Halo installs drained from inbound rings while *shipping* blocked
    /// on a full ring (breaks ship/ship deadlocks); flushed to blocks at
    /// the next `recv_group`.
    stash: Vec<Delivery>,
    /// Socket reader threads (joined on drop; they exit once the socket
    /// is shut down from either side).
    readers: Vec<JoinHandle<()>>,
    /// Optional fault saboteur: consulted once per outbound group (all
    /// lane mechanisms funnel through `ship`), may delay the ship or
    /// force the group empty (a dropped message).
    injector: Option<FaultInjector>,
}

/// How long `recv_group` blocks on the channel between poison checks.
const RECV_TICK: Duration = Duration::from_millis(20);

impl MixedEndpoint {
    /// Install (or remove) the per-worker fault saboteur.
    pub fn set_injector(&mut self, injector: Option<FaultInjector>) {
        self.injector = injector;
    }

    fn has_rings(&self) -> bool {
        self.rings_in.iter().any(|r| r.is_some())
    }

    /// Drain whatever is immediately available on the inbound rings,
    /// installing via `install` and crediting completed groups. Returns
    /// newly-installed payload bytes. An associated fn over disjoint
    /// field borrows so both `ship` (stashing) and `recv_group`
    /// (installing into blocks) can pump.
    fn pump_rings(
        rings: &mut [Option<RingConsumer>],
        started: &mut [bool],
        remaining: &mut [usize],
        groups_done: &mut usize,
        install: &mut dyn FnMut(usize, usize, &[f32]),
    ) -> Result<usize> {
        enum Ev {
            Header(usize),
            Face(usize),
        }
        let mut bytes = 0usize;
        for (src, lane) in rings.iter_mut().enumerate() {
            let Some(rc) = lane else { continue };
            loop {
                let ev = if !started[src] {
                    rc.try_pop_with(|w0, _, _| Ev::Header(w0 as usize))
                } else {
                    rc.try_pop_with(|w0, w1, p| {
                        install(w0 as usize, w1 as usize, p);
                        Ev::Face(p.len() * 4)
                    })
                };
                match ev {
                    None => {
                        if rc.is_closed() && started[src] {
                            bail!("shm ring from worker {src} closed mid-group");
                        }
                        break;
                    }
                    Some(Ev::Header(0)) => *groups_done += 1, // empty (failed-stage) group
                    Some(Ev::Header(n)) => {
                        started[src] = true;
                        remaining[src] = n;
                    }
                    Some(Ev::Face(b)) => {
                        bytes += b;
                        remaining[src] -= 1;
                        if remaining[src] == 0 {
                            started[src] = false;
                            *groups_done += 1;
                        }
                    }
                }
            }
        }
        Ok(bytes)
    }

    /// Push one record to `dst`'s ring, draining our own inbound rings
    /// into the stash while the peer's ring is full (the peer may be
    /// blocked shipping to *us* — mutual drain breaks the cycle).
    fn ring_send(&mut self, dst: usize, w0: u32, w1: u32, payload: &[f32]) -> Result<()> {
        loop {
            let LaneTx::Ring(p) = &mut self.tx[dst] else {
                bail!("lane to worker {dst} is not a ring");
            };
            match p.try_push(w0, w1, payload) {
                Ok(true) => return Ok(()),
                Ok(false) => {}
                Err(_) => bail!("shm ring to worker {dst} closed"),
            }
            if self.ctl.is_stopped() {
                bail!("fabric {} while shipping to worker {dst}", self.ctl.stop_reason());
            }
            let stash = &mut self.stash;
            Self::pump_rings(
                &mut self.rings_in,
                &mut self.ring_started,
                &mut self.ring_remaining,
                &mut self.ring_groups_done,
                &mut |bi, slot, p| stash.push((bi, slot, p.to_vec())),
            )?;
            std::thread::yield_now();
        }
    }

    fn install_group(blocks: &mut [BlockState], group: Deliveries) -> usize {
        let mut bytes = 0usize;
        for (bi, slot, data) in group {
            bytes += data.len() * 4;
            blocks[bi].set_halo_slot(slot, &data);
        }
        bytes
    }
}

impl FabricEndpoint for MixedEndpoint {
    fn ship(
        &mut self,
        dst: usize,
        items: &[CopyRoute],
        blocks: &[BlockState],
        failed: bool,
    ) -> Result<usize> {
        // injected sabotage: a dropped group ships exactly like a failed
        // stage's (empty, still counted), so lockstep survives the loss
        let failed = failed
            || self.injector.as_mut().is_some_and(|i| i.sabotage_ship());
        // dispatch on a copied discriminant so the lane borrow doesn't
        // outlive the match arm (ring_send re-borrows per record)
        enum K {
            Mpsc,
            Ring,
            Stream,
        }
        let kind = match &self.tx[dst] {
            LaneTx::Mpsc(_) => K::Mpsc,
            LaneTx::Ring(_) => K::Ring,
            LaneTx::Stream(_) => K::Stream,
            LaneTx::None => bail!("no fabric lane from worker {} to {dst}", self.me),
        };
        let mut bytes = 0usize;
        match kind {
            K::Mpsc => {
                let payload: Deliveries = if failed {
                    Vec::new()
                } else {
                    items
                        .iter()
                        .map(|&(bi, e, f, dbi, slot)| {
                            let data = blocks[bi].trace_slice(e, f).to_vec();
                            bytes += data.len() * 4;
                            (dbi, slot, data)
                        })
                        .collect()
                };
                let LaneTx::Mpsc(tx) = &self.tx[dst] else { unreachable!() };
                tx.send((self.me, payload))
                    .map_err(|_| anyhow!("mpsc lane to worker {dst} closed"))?;
            }
            K::Ring => {
                let n = if failed { 0 } else { items.len() };
                self.ring_send(dst, n as u32, 0, &[])?;
                if !failed {
                    for &(bi, e, f, dbi, slot) in items {
                        let data = blocks[bi].trace_slice(e, f);
                        bytes += data.len() * 4;
                        // the trace is copied once: source trace -> ring
                        // slot; the consumer copies slot -> halo storage
                        self.ring_send(dst, dbi as u32, slot as u32, data)?;
                    }
                }
            }
            K::Stream => {
                let frame_items: Vec<FrameItem> = if failed {
                    Vec::new()
                } else {
                    items
                        .iter()
                        .map(|&(bi, e, f, dbi, slot)| {
                            (dbi, slot, blocks[bi].trace_slice(e, f).to_vec())
                        })
                        .collect()
                };
                let me = self.me;
                let LaneTx::Stream(s) = &mut self.tx[dst] else { unreachable!() };
                // write_all can't deadlock: the peer's dedicated reader
                // thread always drains its end of the socket
                bytes = framing::write_group(s, &mut self.enc, me, frame_items.into_iter())?;
            }
        }
        Ok(bytes)
    }

    fn recv_group(&mut self, blocks: &mut [BlockState]) -> Result<usize> {
        // installs drained during a blocked ship belong to this stage's
        // inbound traffic — land them (and count them) now
        let mut bytes = 0usize;
        for (bi, slot, data) in self.stash.drain(..) {
            bytes += data.len() * 4;
            blocks[bi].set_halo_slot(slot, &data);
        }
        let spin = self.has_rings();
        loop {
            if self.ring_groups_done > 0 {
                self.ring_groups_done -= 1;
                return Ok(bytes);
            }
            match self.chan_rx.try_recv() {
                Ok((_, group)) => {
                    bytes += Self::install_group(blocks, group);
                    return Ok(bytes);
                }
                Err(TryRecvError::Empty) => {}
                Err(TryRecvError::Disconnected) => bail!("fabric channel closed"),
            }
            bytes += Self::pump_rings(
                &mut self.rings_in,
                &mut self.ring_started,
                &mut self.ring_remaining,
                &mut self.ring_groups_done,
                &mut |bi, slot, p| blocks[bi].set_halo_slot(slot, p),
            )?;
            if self.ring_groups_done > 0 {
                continue;
            }
            if self.ctl.is_stopped() {
                bail!("fabric {} during exchange", self.ctl.stop_reason());
            }
            if spin {
                // ring lanes need polling; stay hot but yield the core
                std::thread::yield_now();
            } else {
                // channel-only endpoint: block properly between checks
                match self.chan_rx.recv_timeout(RECV_TICK) {
                    Ok((_, group)) => {
                        bytes += Self::install_group(blocks, group);
                        return Ok(bytes);
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => bail!("fabric channel closed"),
                }
            }
        }
    }

    fn clear_pending(&mut self) {
        self.stash.clear();
        while self.chan_rx.try_recv().is_ok() {}
        // rings are empty between stages by protocol (every shipped group
        // is consumed in the same stage's exchange window); the state
        // machine reset below covers a failed stage's stragglers
        let _ = Self::pump_rings(
            &mut self.rings_in,
            &mut self.ring_started,
            &mut self.ring_remaining,
            &mut self.ring_groups_done,
            &mut |_, _, _| {},
        );
        for s in self.ring_started.iter_mut() {
            *s = false;
        }
        for r in self.ring_remaining.iter_mut() {
            *r = 0;
        }
        self.ring_groups_done = 0;
    }
}

impl Drop for MixedEndpoint {
    fn drop(&mut self) {
        // socket shutdown affects every clone of the fd, so this both
        // signals EOF to the peer and unblocks our own reader thread
        for lane in &self.tx {
            if let LaneTx::Stream(s) = lane {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// fabric construction
// ---------------------------------------------------------------------------

/// Slots per ring: enough that a full stage group (header + a typical
/// outbound face count) streams through without the producer stalling.
const RING_SLOTS: usize = 64;

fn spawn_reader(
    name: String,
    stream: UnixStream,
    out: Sender<(usize, Deliveries)>,
) -> Result<JoinHandle<()>> {
    std::thread::Builder::new()
        .name(name)
        .spawn(move || {
            let mut r = BufReader::new(stream);
            // EOF/error/closed-channel all mean the run is over
            while let Ok(Some((src, items))) = framing::read_group(&mut r) {
                if out.send((src, items)).is_err() {
                    break;
                }
            }
        })
        .map_err(|e| anyhow!("spawning socket reader: {e}"))
}

/// Build one endpoint per worker. `node_of_worker[w]` gives each
/// worker's virtual node — the lane class of a pair (intra vs inter) is
/// derived from it exactly as [`super::cluster`]'s `fabric_stats`
/// classifies traffic, so the §5.5 story is the same on every transport.
/// `face_words` bounds one trace's f32 payload (ring slot capacity).
///
/// Lanes are built for every cross-worker pair regardless of the current
/// routing tables: rebalancing reshapes *routes*, never node membership,
/// so kept workers keep their live lanes (including open sockets) across
/// a routing-table swap.
pub fn build_endpoints(
    kind: TransportKind,
    node_of_worker: &[usize],
    face_words: usize,
    ctl: &FabricCtl,
) -> Result<Vec<MixedEndpoint>> {
    let nw = node_of_worker.len();
    let mut chan_txs = Vec::with_capacity(nw);
    let mut endpoints: Vec<MixedEndpoint> = Vec::with_capacity(nw);
    for me in 0..nw {
        let (ctx, crx) = channel::<(usize, Deliveries)>();
        chan_txs.push(ctx.clone());
        endpoints.push(MixedEndpoint {
            me,
            ctl: ctl.clone(),
            tx: (0..nw).map(|_| LaneTx::None).collect(),
            rings_in: (0..nw).map(|_| None).collect(),
            chan_rx: crx,
            _chan_keepalive: ctx,
            enc: FrameWriter::new(),
            ring_started: vec![false; nw],
            ring_remaining: vec![0; nw],
            ring_groups_done: 0,
            stash: Vec::new(),
            readers: Vec::new(),
            injector: None,
        });
    }
    for a in 0..nw {
        for b in (a + 1)..nw {
            let intra = node_of_worker[a] == node_of_worker[b];
            let ring_lane = match kind {
                TransportKind::InProc => false,
                TransportKind::Shm => true,
                TransportKind::Socket => intra,
            };
            if ring_lane {
                let (pa, ca) = slot_ring(RING_SLOTS, face_words); // a -> b
                let (pb, cb) = slot_ring(RING_SLOTS, face_words); // b -> a
                endpoints[a].tx[b] = LaneTx::Ring(pa);
                endpoints[b].rings_in[a] = Some(ca);
                endpoints[b].tx[a] = LaneTx::Ring(pb);
                endpoints[a].rings_in[b] = Some(cb);
            } else if kind == TransportKind::Socket {
                // one socketpair carries both directions of the pair
                let (sa, sb) =
                    UnixStream::pair().map_err(|e| anyhow!("socketpair({a},{b}): {e}"))?;
                let ra = sa.try_clone().map_err(|e| anyhow!("cloning socket: {e}"))?;
                let rb = sb.try_clone().map_err(|e| anyhow!("cloning socket: {e}"))?;
                endpoints[a]
                    .readers
                    .push(spawn_reader(format!("fab-r{a}-{b}"), ra, chan_txs[a].clone())?);
                endpoints[b]
                    .readers
                    .push(spawn_reader(format!("fab-r{b}-{a}"), rb, chan_txs[b].clone())?);
                endpoints[a].tx[b] = LaneTx::Stream(sa);
                endpoints[b].tx[a] = LaneTx::Stream(sb);
            } else {
                endpoints[a].tx[b] = LaneTx::Mpsc(chan_txs[b].clone());
                endpoints[b].tx[a] = LaneTx::Mpsc(chan_txs[a].clone());
            }
        }
    }
    Ok(endpoints)
}

// ---------------------------------------------------------------------------
// link measurement
// ---------------------------------------------------------------------------

/// Measured point-to-point link characteristics of one fabric mechanism.
#[derive(Debug, Clone, Copy)]
pub struct LinkMeasurement {
    /// One-way small-message latency (seconds).
    pub latency_s: f64,
    /// Sustained one-way bandwidth (bytes/second).
    pub bw_bytes_per_s: f64,
}

/// The two cross-worker link classes of a transport, measured.
#[derive(Debug, Clone, Copy)]
pub struct FabricLinks {
    /// Intra-node lane (the PCI stand-in).
    pub pci: LinkMeasurement,
    /// Inter-node lane (the MPI stand-in).
    pub net: LinkMeasurement,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LinkMech {
    Mpsc,
    Ring,
    Uds,
}

const PING_ROUNDS: usize = 64;
const BULK_CHUNK_F32: usize = 64 * 1024; // 256 KiB per message
const BULK_CHUNKS: usize = 24; // 6 MiB total

fn measure_mpsc() -> LinkMeasurement {
    let (atx, arx) = channel::<Vec<f32>>();
    let (btx, brx) = channel::<Vec<f32>>();
    let echo = std::thread::spawn(move || {
        while let Ok(v) = arx.recv() {
            if v.is_empty() {
                break;
            }
            btx.send(v).ok();
        }
        // bulk phase: drain until the empty sentinel, then ack once
        let mut got = 0usize;
        while let Ok(v) = arx.recv() {
            if v.is_empty() {
                break;
            }
            got += v.len();
        }
        btx.send(vec![got as f32]).ok();
    });
    let ping = vec![1.0f32; 16];
    let t0 = Instant::now();
    for _ in 0..PING_ROUNDS {
        atx.send(ping.clone()).unwrap();
        brx.recv().unwrap();
    }
    let latency_s = t0.elapsed().as_secs_f64() / (PING_ROUNDS as f64 * 2.0);
    atx.send(Vec::new()).unwrap(); // end ping phase
    let chunk = vec![0.5f32; BULK_CHUNK_F32];
    let t1 = Instant::now();
    for _ in 0..BULK_CHUNKS {
        atx.send(chunk.clone()).unwrap();
    }
    atx.send(Vec::new()).unwrap();
    brx.recv().unwrap();
    let bulk_s = t1.elapsed().as_secs_f64();
    echo.join().ok();
    let bytes = (BULK_CHUNK_F32 * BULK_CHUNKS * 4) as f64;
    LinkMeasurement { latency_s, bw_bytes_per_s: bytes / bulk_s.max(1e-9) }
}

fn measure_ring() -> LinkMeasurement {
    let (mut fwd_tx, mut fwd_rx) = slot_ring(RING_SLOTS, BULK_CHUNK_F32.min(4096));
    let (mut rev_tx, mut rev_rx) = slot_ring(RING_SLOTS, 16);
    let payload_words = BULK_CHUNK_F32.min(4096);
    let echo = std::thread::spawn(move || {
        // ping phase: echo PING_ROUNDS records
        for _ in 0..PING_ROUNDS {
            while fwd_rx.try_pop_with(|_, _, _| ()).is_none() {
                std::hint::spin_loop();
            }
            while let Ok(false) = rev_tx.try_push(0, 0, &[]) {
                std::hint::spin_loop();
            }
        }
        // bulk phase: drain records until the w0=1 sentinel, ack once
        loop {
            let done = loop {
                if let Some(d) = fwd_rx.try_pop_with(|w0, _, _| w0 == 1) {
                    break d;
                }
                std::hint::spin_loop();
            };
            if done {
                break;
            }
        }
        while let Ok(false) = rev_tx.try_push(0, 0, &[]) {
            std::hint::spin_loop();
        }
    });
    let ping = vec![1.0f32; 16];
    let t0 = Instant::now();
    for _ in 0..PING_ROUNDS {
        while let Ok(false) = fwd_tx.try_push(0, 0, &ping) {
            std::hint::spin_loop();
        }
        while rev_rx.try_pop_with(|_, _, _| ()).is_none() {
            std::hint::spin_loop();
        }
    }
    let latency_s = t0.elapsed().as_secs_f64() / (PING_ROUNDS as f64 * 2.0);
    let chunk = vec![0.5f32; payload_words];
    // push enough records to match the bulk volume of the other probes
    let records = (BULK_CHUNK_F32 * BULK_CHUNKS) / payload_words;
    let t1 = Instant::now();
    for _ in 0..records {
        while let Ok(false) = fwd_tx.try_push(0, 0, &chunk) {
            std::hint::spin_loop();
        }
    }
    while let Ok(false) = fwd_tx.try_push(1, 0, &[]) {
        std::hint::spin_loop();
    }
    while rev_rx.try_pop_with(|_, _, _| ()).is_none() {
        std::hint::spin_loop();
    }
    let bulk_s = t1.elapsed().as_secs_f64();
    echo.join().ok();
    let bytes = (records * payload_words * 4) as f64;
    LinkMeasurement { latency_s, bw_bytes_per_s: bytes / bulk_s.max(1e-9) }
}

fn measure_uds() -> Result<LinkMeasurement> {
    use std::io::{Read, Write};
    let (mut a, mut b) = UnixStream::pair().map_err(|e| anyhow!("socketpair: {e}"))?;
    let bulk_bytes = BULK_CHUNK_F32 * BULK_CHUNKS * 4;
    let echo = std::thread::spawn(move || {
        let mut byte = [0u8; 64];
        for _ in 0..PING_ROUNDS {
            if b.read_exact(&mut byte).is_err() {
                return;
            }
            if b.write_all(&byte).is_err() {
                return;
            }
        }
        let mut buf = vec![0u8; 1 << 20];
        let mut got = 0usize;
        while got < bulk_bytes {
            match b.read(&mut buf) {
                Ok(0) | Err(_) => return,
                Ok(n) => got += n,
            }
        }
        b.write_all(&byte[..1]).ok();
    });
    let msg = [7u8; 64];
    let mut back = [0u8; 64];
    let t0 = Instant::now();
    for _ in 0..PING_ROUNDS {
        a.write_all(&msg).map_err(|e| anyhow!("uds probe: {e}"))?;
        a.read_exact(&mut back).map_err(|e| anyhow!("uds probe: {e}"))?;
    }
    let latency_s = t0.elapsed().as_secs_f64() / (PING_ROUNDS as f64 * 2.0);
    let chunk = vec![3u8; 1 << 20];
    let mut sent = 0usize;
    let t1 = Instant::now();
    while sent < bulk_bytes {
        let n = chunk.len().min(bulk_bytes - sent);
        a.write_all(&chunk[..n]).map_err(|e| anyhow!("uds probe: {e}"))?;
        sent += n;
    }
    a.read_exact(&mut back[..1]).map_err(|e| anyhow!("uds probe: {e}"))?;
    let bulk_s = t1.elapsed().as_secs_f64();
    echo.join().ok();
    Ok(LinkMeasurement { latency_s, bw_bytes_per_s: bulk_bytes as f64 / bulk_s.max(1e-9) })
}

fn measure_mech(mech: LinkMech) -> Result<LinkMeasurement> {
    match mech {
        LinkMech::Mpsc => Ok(measure_mpsc()),
        LinkMech::Ring => Ok(measure_ring()),
        LinkMech::Uds => measure_uds(),
    }
}

/// Probe the latency/bandwidth of the mechanisms `kind` actually puts on
/// each lane class (a few milliseconds per mechanism). Feeds
/// [`crate::costmodel::pci::PciModel::from_link`] and
/// [`crate::costmodel::network::NetworkModel::from_link`] so pricing uses
/// the measured fabric instead of hardcoded Stampede-era guesses.
pub fn measure_fabric_links(kind: TransportKind) -> Result<FabricLinks> {
    let (pci_mech, net_mech) = match kind {
        TransportKind::InProc => (LinkMech::Mpsc, LinkMech::Mpsc),
        TransportKind::Shm => (LinkMech::Ring, LinkMech::Ring),
        TransportKind::Socket => (LinkMech::Ring, LinkMech::Uds),
    };
    let pci = measure_mech(pci_mech)?;
    let net = if net_mech == pci_mech { pci } else { measure_mech(net_mech)? };
    Ok(FabricLinks { pci, net })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::state::NFIELDS;

    /// Tiny hand-built block: 1 boundary-only element with distinctive
    /// trace data, `halo` halo slots.
    fn test_block(order: usize, halo: usize) -> BlockState {
        let m = order + 1;
        let (vol, face) = (m * m * m, m * m);
        let hp = halo.max(1);
        BlockState {
            uid: BlockState::fresh_uid(),
            order,
            m,
            k_real: 1,
            k_pad: 1,
            halo_real: halo,
            halo_pad: hp,
            q: vec![0.0; NFIELDS * vol],
            res: vec![0.0; NFIELDS * vol],
            traces: (0..6 * NFIELDS * face).map(|i| i as f32 * 0.25 - 7.0).collect(),
            halo: vec![0.0; hp * NFIELDS * face],
            conn: vec![-2; 6],
            halo_idx: vec![0; 6],
            mats: vec![1.0; 3],
            halo_mats: vec![1.0; 3 * hp],
            h: vec![1.0; 3],
            centers: vec![[0.0; 3]],
        }
    }

    /// Read back halo slot contents (the field is plain storage).
    fn halo_slot(st: &BlockState, slot: usize) -> &[f32] {
        let sz = NFIELDS * st.m * st.m;
        &st.halo[slot * sz..(slot + 1) * sz]
    }

    fn endpoints_pair(kind: TransportKind) -> (MixedEndpoint, MixedEndpoint) {
        let ctl = FabricCtl::new();
        let order = 2;
        let m = order + 1;
        let mut eps = build_endpoints(kind, &[0, 1], NFIELDS * m * m, &ctl).unwrap();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        (a, b)
    }

    /// One group ships across and installs into the right halo slot on
    /// every transport mechanism.
    #[test]
    fn ship_and_recv_roundtrip_all_kinds() {
        for kind in [TransportKind::InProc, TransportKind::Shm, TransportKind::Socket] {
            let (mut a, mut b) = endpoints_pair(kind);
            let order = 2;
            let src = vec![test_block(order, 1)];
            let mut dst = vec![test_block(order, 2)];
            // route: a's block 0, elem 0, face 3 -> b's block 0, slot 1
            let items: Vec<CopyRoute> = vec![(0, 0, 3, 0, 1)];
            let sent = a.ship(1, &items, &src, false).unwrap();
            let m = order + 1;
            assert_eq!(sent, NFIELDS * m * m * 4, "{kind}");
            let got = b.recv_group(&mut dst).unwrap();
            assert_eq!(got, sent, "{kind}");
            let want = src[0].trace_slice(0, 3);
            assert_eq!(halo_slot(&dst[0], 1), want, "{kind}: payload must install bit-exactly");
        }
    }

    /// A failed stage ships an empty group that still counts.
    #[test]
    fn failed_stage_group_keeps_lockstep() {
        for kind in [TransportKind::InProc, TransportKind::Shm, TransportKind::Socket] {
            let (mut a, mut b) = endpoints_pair(kind);
            let src = vec![test_block(2, 1)];
            let mut dst = vec![test_block(2, 2)];
            let items: Vec<CopyRoute> = vec![(0, 0, 3, 0, 1)];
            let sent = a.ship(1, &items, &src, true).unwrap();
            assert_eq!(sent, 0, "{kind}");
            let got = b.recv_group(&mut dst).unwrap();
            assert_eq!(got, 0, "{kind}: empty group must still complete recv");
        }
    }

    /// Poisoning unblocks a receiver waiting on a group that never comes.
    #[test]
    fn poison_unblocks_recv() {
        for kind in [TransportKind::InProc, TransportKind::Shm, TransportKind::Socket] {
            let ctl = FabricCtl::new();
            let mut eps = build_endpoints(kind, &[0, 1], 128, &ctl).unwrap();
            let mut b = eps.pop().unwrap();
            let _a = eps.pop().unwrap();
            let h = std::thread::spawn(move || {
                let mut dst = vec![test_block(2, 1)];
                b.recv_group(&mut dst).unwrap_err()
            });
            std::thread::sleep(Duration::from_millis(10));
            ctl.poison();
            let err = h.join().unwrap();
            assert!(err.to_string().contains("poisoned"), "{kind}: {err}");
        }
    }

    /// Socket mode puts rings on intra-node pairs and sockets on
    /// inter-node pairs (the lane-class split is derived from node ids).
    #[test]
    fn socket_mode_lane_classes() {
        let ctl = FabricCtl::new();
        let eps = build_endpoints(TransportKind::Socket, &[0, 0, 1, 1], 128, &ctl).unwrap();
        let lane = |e: &MixedEndpoint, d: usize| match &e.tx[d] {
            LaneTx::None => "none",
            LaneTx::Mpsc(_) => "mpsc",
            LaneTx::Ring(_) => "ring",
            LaneTx::Stream(_) => "stream",
        };
        assert_eq!(lane(&eps[0], 1), "ring"); // same node
        assert_eq!(lane(&eps[2], 3), "ring");
        assert_eq!(lane(&eps[0], 2), "stream"); // across nodes
        assert_eq!(lane(&eps[1], 3), "stream");
        assert_eq!(lane(&eps[0], 0), "none");
    }

    /// Mutual full-ring ship must not deadlock: both endpoints ship a
    /// group far larger than the ring capacity to each other at the same
    /// time (drain-while-blocked breaks the cycle).
    #[test]
    fn mutual_large_ship_does_not_deadlock() {
        let ctl = FabricCtl::new();
        let order = 2;
        let m = order + 1;
        let n_items = RING_SLOTS * 3;
        let mut eps = build_endpoints(TransportKind::Shm, &[0, 0], NFIELDS * m * m, &ctl).unwrap();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let items: Vec<CopyRoute> = (0..n_items).map(|i| (0, 0, i % 6, 0, i)).collect();
        let run = |mut ep: MixedEndpoint, dst: usize, items: Vec<CopyRoute>| {
            std::thread::spawn(move || {
                let src = vec![test_block(2, 1)];
                let mut blocks = vec![test_block(2, n_items)];
                ep.ship(dst, &items, &src, false).unwrap();
                let bytes = ep.recv_group(&mut blocks).unwrap();
                assert_eq!(bytes, n_items * NFIELDS * (2 + 1) * (2 + 1) * 4);
            })
        };
        let ha = run(a, 1, items.clone());
        let hb = run(b, 0, items);
        ha.join().unwrap();
        hb.join().unwrap();
    }

    /// The probes return sane numbers for every transport kind.
    #[test]
    fn link_probes_are_sane() {
        for kind in [TransportKind::InProc, TransportKind::Shm, TransportKind::Socket] {
            let links = measure_fabric_links(kind).unwrap();
            for l in [links.pci, links.net] {
                assert!(l.latency_s > 0.0 && l.latency_s < 0.1, "{kind}: {l:?}");
                assert!(l.bw_bytes_per_s > 1e6, "{kind}: {l:?}");
            }
        }
    }

    /// Halting unblocks a waiting receiver like poison does, but the
    /// fabric comes back after `clear_halt` — the recovery-domain split.
    #[test]
    fn halt_unblocks_recv_and_clears() {
        for kind in [TransportKind::InProc, TransportKind::Shm, TransportKind::Socket] {
            let ctl = FabricCtl::new();
            let mut eps = build_endpoints(kind, &[0, 1], 128, &ctl).unwrap();
            let mut b = eps.pop().unwrap();
            let mut a = eps.pop().unwrap();
            let h = std::thread::spawn(move || {
                let mut dst = vec![test_block(2, 1)];
                let err = b.recv_group(&mut dst).unwrap_err();
                (b, err)
            });
            std::thread::sleep(Duration::from_millis(10));
            ctl.halt();
            let (mut b, err) = h.join().unwrap();
            assert!(err.to_string().contains("halted"), "{kind}: {err}");
            assert!(!err.to_string().contains("poisoned"), "{kind}: {err}");
            // after clearing, the same endpoints exchange again
            ctl.clear_halt();
            a.clear_pending();
            b.clear_pending();
            let src = vec![test_block(2, 1)];
            let mut dst = vec![test_block(2, 2)];
            a.ship(1, &[(0, 0, 3, 0, 1)], &src, false).unwrap();
            let got = b.recv_group(&mut dst).unwrap();
            assert!(got > 0, "{kind}: fabric must revive after clear_halt");
        }
    }

    /// An injector with drop_prob=1 turns every shipped group empty while
    /// keeping the group count intact (the receiver still completes).
    #[test]
    fn injector_drops_ship_as_empty_groups() {
        use crate::coordinator::fault::FaultPlan;
        for kind in [TransportKind::InProc, TransportKind::Shm, TransportKind::Socket] {
            let (mut a, mut b) = endpoints_pair(kind);
            let plan = FaultPlan { seed: 1, drop_prob: 1.0, ..Default::default() };
            a.set_injector(plan.injector_for(0));
            let src = vec![test_block(2, 1)];
            let mut dst = vec![test_block(2, 2)];
            let sent = a.ship(1, &[(0, 0, 3, 0, 1)], &src, false).unwrap();
            assert_eq!(sent, 0, "{kind}: dropped group ships no payload");
            let got = b.recv_group(&mut dst).unwrap();
            assert_eq!(got, 0, "{kind}: dropped group still counts for lockstep");
        }
    }

    #[test]
    fn kind_parses_and_prints() {
        for kind in [TransportKind::InProc, TransportKind::Shm, TransportKind::Socket] {
            let s = kind.label();
            assert_eq!(s.parse::<TransportKind>().unwrap(), kind);
        }
        assert!("tcp".parse::<TransportKind>().is_err());
    }
}
