//! `coordinator::serve` — many concurrent simulations over one substrate.
//!
//! The nested partition keeps one simulation's CPU and accelerator busy;
//! this layer keeps the *whole machine* busy under a fleet of independent
//! wave-propagation scenarios. It is the level-1 idea played one level
//! up: where the weighted splice places *elements across nodes* by
//! measured per-element rates, the job scheduler places *jobs across
//! pool slices* by predicted wall time
//! ([`crate::costmodel::placement::PlacementModel`] — calibrated
//! bootstrap, measured EWMA closed loop).
//!
//! Mechanics:
//!
//! * **One shared [`WorkerPool`]**, carved into disjoint [`PoolSlice`]s
//!   (one runner thread per slice = that slice's lane 0). Small jobs gang
//!   co-schedule onto disjoint core ranges: an order-2 smoke job's stage
//!   rendezvous wakes only its own slice's workers — dispatches on
//!   disjoint slices proceed fully concurrently (`util::pool`'s
//!   participant-scoped ledger).
//! * **Bounded admission queue with a batch front end**: jobs stream in
//!   (admission blocks while `queue_cap` jobs are pending) and are placed
//!   on admission — each job goes to the slice minimizing the fleet
//!   makespan contribution `eta(slice) + predicted(job, slice)`.
//! * **Work-conserving backfill**: a runner whose queue drains steals the
//!   tail of the most-loaded slice's queue, so an early-finishing slice
//!   never idles while work is waiting elsewhere.
//! * **Per-job accounting** mirrors `RebalanceReport`: a [`JobReport`]
//!   (queue wait, placement decision, wall time, elements·steps/s)
//!   per job, retained in a bounded [`History`] ring and serialized
//!   through `util::bench::JsonSink` by the `repro serve` driver into
//!   BENCH_serve.json.
//! * **Cancellation**: each job carries a [`JobCtl`]; cancelling poisons
//!   the job's own cluster fabric (if it runs on one) and trips a
//!   between-steps check, so one abandoned job neither hangs nor touches
//!   its neighbours.
//!
//! Jobs with `nodes >= 2` run on their own in-process [`ClusterRun`]
//! (two fabric workers per virtual node); jobs with `nodes <= 1` run as
//! a single-block [`Driver`] solve on the job's pool slice. Everything
//! shares one address space — see PERF.md "Serving" for what that does
//! and doesn't prove.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::anyhow;

use crate::coordinator::cluster::{ClusterRun, ClusterSpec};
use crate::coordinator::transport::FabricCtl;
use crate::costmodel::placement::PlacementModel;
use crate::mesh::{build_local_blocks, unit_cube_geometry, Mesh};
use crate::solver::analytic::standing_wave;
use crate::solver::driver::{Driver, StageBackend};
use crate::solver::parallel::ParallelRefBackend;
use crate::solver::rk::stable_dt;
use crate::solver::state::NFIELDS;
use crate::solver::{BlockState, LglBasis};
use crate::util::pool::{PoolSlice, WorkerPool};
use crate::util::ring::History;
use crate::util::Json;
use crate::Result;

/// One scenario: its own mesh size, order and step count.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    /// `unit_cube_geometry(n)` — `n^3` elements.
    pub n: usize,
    pub order: usize,
    pub steps: usize,
    /// `>= 2` runs the job on its own in-process cluster (two fabric
    /// workers per virtual node); `<= 1` runs it as a single-block solve
    /// on the job's pool slice.
    pub nodes: usize,
}

impl JobSpec {
    pub fn elems(&self) -> usize {
        self.n * self.n * self.n
    }

    /// Parse one job object: `{"name"?, "n", "order", "steps", "nodes"?}`.
    pub fn from_json(j: &Json) -> Result<JobSpec> {
        let n = j.get("n").and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("job needs \"n\""))?;
        let order =
            j.get("order").and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("job needs \"order\""))?;
        let steps =
            j.get("steps").and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("job needs \"steps\""))?;
        let nodes = j.get("nodes").and_then(|v| v.as_usize()).unwrap_or(1);
        anyhow::ensure!(n >= 1 && order >= 1 && steps >= 1, "job n/order/steps must be >= 1");
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .unwrap_or_else(|| format!("n{n}_p{order}_s{steps}"));
        Ok(JobSpec { name, n, order, steps, nodes })
    }
}

/// A batch of jobs plus the scheduler's shape.
#[derive(Debug, Clone)]
pub struct ServeSpec {
    pub jobs: Vec<JobSpec>,
    /// Bounded admission queue: at most this many jobs pending (queued on
    /// slices, waiting or running) at once; the batch front end blocks
    /// admission beyond it.
    pub queue_cap: usize,
    /// Lane count per pool slice (each slice = one runner thread + its
    /// `lanes - 1` OS workers of the shared pool).
    pub slices: Vec<usize>,
}

/// Default slicing: four slices splitting the hardware threads (floor one
/// lane each) — four concurrent jobs on an idle machine.
pub fn default_slices() -> Vec<usize> {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    vec![(hw / 4).max(1); 4]
}

impl ServeSpec {
    pub fn new(jobs: Vec<JobSpec>) -> ServeSpec {
        ServeSpec { jobs, queue_cap: 8, slices: default_slices() }
    }

    /// Parse a spec file: either a bare array of job objects, or
    /// `{"jobs": [...], "queue_cap"?: N, "slices"?: [lanes, ...]}`.
    pub fn parse(text: &str) -> Result<ServeSpec> {
        let j = Json::parse(text)?;
        let jobs_json = match &j {
            Json::Arr(a) => a.as_slice(),
            _ => j
                .get("jobs")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("serve spec needs a \"jobs\" array"))?,
        };
        let jobs = jobs_json.iter().map(JobSpec::from_json).collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(!jobs.is_empty(), "serve spec has no jobs");
        let mut spec = ServeSpec::new(jobs);
        if let Some(c) = j.get("queue_cap").and_then(|v| v.as_usize()) {
            spec.queue_cap = c.max(1);
        }
        if let Some(arr) = j.get("slices").and_then(|v| v.as_arr()) {
            let lanes = arr
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| anyhow!("\"slices\" must be lane counts")))
                .collect::<Result<Vec<_>>>()?;
            anyhow::ensure!(!lanes.is_empty(), "\"slices\" must not be empty");
            spec.slices = lanes;
        }
        Ok(spec)
    }

    /// The baseline the headline scalar compares against: the same jobs
    /// through the same scheduler, but a single slice owning the whole
    /// lane budget — back-to-back execution at full width.
    pub fn serial(&self) -> ServeSpec {
        let total: usize = self.slices.iter().map(|&l| l.max(1)).sum();
        ServeSpec { jobs: self.jobs.clone(), queue_cap: self.queue_cap, slices: vec![total] }
    }
}

/// Per-job cancellation handle. [`JobCtl::cancel`] trips the job's
/// between-steps check and poisons its cluster fabric (once armed), so an
/// in-flight job unblocks promptly without corrupting its neighbours.
#[derive(Debug, Default)]
pub struct JobCtl {
    cancel: AtomicBool,
    fabric: Mutex<Option<FabricCtl>>,
}

impl JobCtl {
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::SeqCst);
        if let Some(ctl) = self.fabric.lock().unwrap_or_else(|e| e.into_inner()).as_ref() {
            ctl.poison();
        }
    }

    pub fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::SeqCst)
    }

    /// Point the handle at a live cluster fabric. A cancel that already
    /// happened poisons it immediately (no lost-wakeup window).
    fn arm(&self, ctl: FabricCtl) {
        *self.fabric.lock().unwrap_or_else(|e| e.into_inner()) = Some(ctl.clone());
        if self.cancelled() {
            ctl.poison();
        }
    }

    fn disarm(&self) {
        *self.fabric.lock().unwrap_or_else(|e| e.into_inner()) = None;
    }
}

/// How a job ended.
#[derive(Debug, Clone, PartialEq)]
pub enum JobStatus {
    Done,
    Cancelled,
    Failed(String),
}

/// What one job did — the serving analogue of `RebalanceReport`.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub name: String,
    pub n: usize,
    pub order: usize,
    pub steps: usize,
    pub nodes: usize,
    /// Placement decision: which slice ran it, at how many lanes, and
    /// whether backfill stole it from its originally chosen slice.
    pub slice: usize,
    pub lanes: usize,
    pub stolen: bool,
    /// Admission-to-start latency.
    pub queue_wait_s: f64,
    /// The placement model's prediction at admission (for the slice that
    /// ran it).
    pub predicted_s: f64,
    pub wall_s: f64,
    pub steps_done: usize,
    /// Realized throughput, `elems * steps_done / wall_s`.
    pub elem_steps_per_s: f64,
    pub energy: f64,
    pub status: JobStatus,
}

impl JobReport {
    /// One flat record for the JSON sink (`"kind": "job"` marks it).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("kind".into(), Json::Str("job".into()));
        o.insert("name".into(), Json::Str(self.name.clone()));
        o.insert("n".into(), Json::Num(self.n as f64));
        o.insert("order".into(), Json::Num(self.order as f64));
        o.insert("steps".into(), Json::Num(self.steps as f64));
        o.insert("nodes".into(), Json::Num(self.nodes as f64));
        o.insert("slice".into(), Json::Num(self.slice as f64));
        o.insert("lanes".into(), Json::Num(self.lanes as f64));
        o.insert("stolen".into(), Json::Bool(self.stolen));
        o.insert("queue_wait_s".into(), Json::Num(self.queue_wait_s));
        o.insert("predicted_s".into(), Json::Num(self.predicted_s));
        o.insert("wall_s".into(), Json::Num(self.wall_s));
        o.insert("steps_done".into(), Json::Num(self.steps_done as f64));
        o.insert("elem_steps_per_s".into(), Json::Num(self.elem_steps_per_s));
        o.insert("energy".into(), Json::Num(self.energy));
        let status = match &self.status {
            JobStatus::Done => "done".to_string(),
            JobStatus::Cancelled => "cancelled".to_string(),
            JobStatus::Failed(m) => format!("failed: {m}"),
        };
        o.insert("status".into(), Json::Str(status));
        Json::Obj(o)
    }
}

/// What one [`serve`] call did.
#[derive(Debug)]
pub struct ServeReport {
    /// Completed jobs in completion order — the retained window of the
    /// bounded report ring (see `evicted_reports`).
    pub jobs: Vec<JobReport>,
    /// Wall seconds from first admission to last completion.
    pub wall_s: f64,
    /// Aggregate completed work over the wall: `sum(elems * steps) /
    /// wall_s` over jobs that ran to completion.
    pub elem_steps_per_s: f64,
    /// Per admitted job (submission order): its final per-element fields,
    /// kept only with [`ServeOptions::keep_fields`] (validation runs).
    pub fields: Vec<Option<Vec<Vec<f32>>>>,
    /// Reports that scrolled off the bounded ring.
    pub evicted_reports: usize,
}

/// Serving knobs that aren't part of the job spec.
#[derive(Debug, Clone, Default)]
pub struct ServeOptions {
    /// Retain each job's final per-element field (memory-heavy; tests and
    /// validation only).
    pub keep_fields: bool,
    /// Cap of the per-job report ring (0 = default 1024).
    pub report_cap: usize,
}

/// The initial condition every scenario solves (the standing wave the
/// whole repo validates against).
pub fn job_ic(x: [f64; 3]) -> [f64; NFIELDS] {
    let w = std::f64::consts::PI * 3f64.sqrt();
    standing_wave(x, 0.0, 1.0, 1.0, w)
}

/// The stable timestep a job runs at — shared with the solo-oracle tests
/// so serve-vs-solo comparisons integrate the same trajectory.
pub fn job_dt(mesh: &Mesh, order: usize) -> f64 {
    let cmax = mesh.elements.iter().map(|e| e.material.cp()).fold(0.0f32, f32::max);
    let hmin =
        mesh.elements.iter().map(|e| e.h[0].min(e.h[1]).min(e.h[2])).fold(f64::MAX, f64::min);
    stable_dt(0.3, hmin, cmax as f64, order)
}

/// Run a batch to completion. See the module docs for the scheduling
/// discipline.
pub fn serve(spec: &ServeSpec, opts: &ServeOptions) -> Result<ServeReport> {
    serve_with_ctls(spec, opts, None)
}

/// [`serve`] with caller-owned cancellation handles (one per job, aligned
/// with `spec.jobs`) — the cancellation tests drive mid-flight
/// [`JobCtl::cancel`] through these.
pub fn serve_with_ctls(
    spec: &ServeSpec,
    opts: &ServeOptions,
    ctls: Option<&[Arc<JobCtl>]>,
) -> Result<ServeReport> {
    anyhow::ensure!(!spec.jobs.is_empty(), "no jobs to serve");
    anyhow::ensure!(!spec.slices.is_empty(), "serve needs at least one slice");
    if let Some(c) = ctls {
        anyhow::ensure!(c.len() == spec.jobs.len(), "need one JobCtl per job");
    }
    let lanes: Vec<usize> = spec.slices.iter().map(|&l| l.max(1)).collect();
    // one OS worker per non-runner lane; every slice's runner thread is
    // that slice's lane 0, so no pool thread idles behind a runner
    let os_workers: usize = lanes.iter().map(|l| l - 1).sum();
    let pool = Arc::new(WorkerPool::new(os_workers + 1, None));
    let mut slices = Vec::with_capacity(lanes.len());
    let mut start = 0;
    for &l in &lanes {
        slices.push(PoolSlice::range(pool.clone(), start, l));
        start += l - 1;
    }
    let queue_cap = spec.queue_cap.max(1);
    let report_cap = if opts.report_cap == 0 { 1024 } else { opts.report_cap };
    let sched = Sched {
        state: Mutex::new(SchedState {
            fifos: vec![VecDeque::new(); lanes.len()],
            etas: vec![0.0; lanes.len()],
            queued: 0,
            all_submitted: false,
            model: PlacementModel::new(),
            reports: History::new(report_cap),
            fields: vec![None; spec.jobs.len()],
            completed_elem_steps: 0.0,
        }),
        cv: Condvar::new(),
    };
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for (s, slice) in slices.iter().enumerate() {
            let sched = &sched;
            let lanes = &lanes;
            let slice = slice.clone();
            let keep_fields = opts.keep_fields;
            scope.spawn(move || runner(s, slice, sched, lanes, keep_fields));
        }
        // batch admission through the bounded queue; placement happens at
        // admission so a queued job already has a slice and an eta
        for (idx, job) in spec.jobs.iter().enumerate() {
            let ctl = match ctls {
                Some(c) => c[idx].clone(),
                None => Arc::new(JobCtl::default()),
            };
            let mut st = sched.state.lock().unwrap();
            while st.queued >= queue_cap {
                st = sched.cv.wait(st).unwrap();
            }
            let mut best = 0usize;
            let mut best_score = f64::INFINITY;
            let mut best_pred = 0.0;
            for (s, &l) in lanes.iter().enumerate() {
                let pred = st.model.predict_wall_s(job.order, job.elems(), job.steps, l);
                let score = st.etas[s] + pred;
                if score < best_score {
                    best_score = score;
                    best = s;
                    best_pred = pred;
                }
            }
            st.etas[best] += best_pred;
            st.queued += 1;
            st.fifos[best].push_back(Admitted {
                idx,
                job: job.clone(),
                ctl,
                admitted_at: Instant::now(),
                predicted_s: best_pred,
                stolen: false,
            });
            drop(st);
            sched.cv.notify_all();
        }
        sched.state.lock().unwrap().all_submitted = true;
        sched.cv.notify_all();
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let st = sched.state.into_inner().unwrap_or_else(|e| e.into_inner());
    let evicted_reports = st.reports.evicted();
    let jobs: Vec<JobReport> = st.reports.iter().cloned().collect();
    Ok(ServeReport {
        jobs,
        wall_s,
        elem_steps_per_s: st.completed_elem_steps / wall_s.max(1e-12),
        fields: st.fields,
        evicted_reports,
    })
}

/// A job sitting in (or popped from) a slice queue.
struct Admitted {
    idx: usize,
    job: JobSpec,
    ctl: Arc<JobCtl>,
    admitted_at: Instant,
    predicted_s: f64,
    stolen: bool,
}

struct SchedState {
    fifos: Vec<VecDeque<Admitted>>,
    /// Predicted seconds of queued + running work per slice.
    etas: Vec<f64>,
    /// Jobs admitted but not yet completed (bounds the admission queue).
    queued: usize,
    all_submitted: bool,
    model: PlacementModel,
    reports: History<JobReport>,
    fields: Vec<Option<Vec<Vec<f32>>>>,
    completed_elem_steps: f64,
}

struct Sched {
    state: Mutex<SchedState>,
    cv: Condvar,
}

/// The queue a backfilling runner steals from: the most-loaded (by eta)
/// slice with anything still queued.
fn steal_victim(st: &SchedState) -> Option<usize> {
    let mut best = None;
    let mut best_eta = f64::NEG_INFINITY;
    for (v, fifo) in st.fifos.iter().enumerate() {
        if !fifo.is_empty() && st.etas[v] > best_eta {
            best = Some(v);
            best_eta = st.etas[v];
        }
    }
    best
}

fn runner(s: usize, slice: PoolSlice, sched: &Sched, lanes: &[usize], keep_fields: bool) {
    loop {
        let next = {
            let mut st = sched.state.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if let Some(a) = st.fifos[s].pop_front() {
                    break Some(a);
                }
                // work-conserving backfill: steal the tail of the
                // most-loaded queue (the job that would wait longest)
                match steal_victim(&st) {
                    Some(v) if v != s => {
                        let mut a = st.fifos[v].pop_back().expect("victim has a queued job");
                        st.etas[v] = (st.etas[v] - a.predicted_s).max(0.0);
                        let pred = st.model.predict_wall_s(
                            a.job.order,
                            a.job.elems(),
                            a.job.steps,
                            lanes[s],
                        );
                        st.etas[s] += pred;
                        a.predicted_s = pred;
                        a.stolen = true;
                        break Some(a);
                    }
                    _ => {}
                }
                if st.all_submitted {
                    break None;
                }
                st = sched.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        let Some(a) = next else { return };
        let queue_wait_s = a.admitted_at.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let outcome = run_job(&a.job, &slice, &a.ctl, keep_fields);
        let wall_s = t0.elapsed().as_secs_f64();
        let mut st = sched.state.lock().unwrap_or_else(|e| e.into_inner());
        st.etas[s] = (st.etas[s] - a.predicted_s).max(0.0);
        st.queued -= 1;
        let (status, steps_done, energy) = match outcome {
            Ok(o) => {
                if o.status == JobStatus::Done {
                    // close the placement loop (pool jobs only: a cluster
                    // job's workers are its own, not this slice's lanes)
                    if a.job.nodes < 2 {
                        st.model.observe(a.job.order, a.job.elems(), a.job.steps, lanes[s], wall_s);
                    }
                    st.completed_elem_steps += (a.job.elems() * a.job.steps) as f64;
                }
                if let Some(f) = o.fields {
                    st.fields[a.idx] = Some(f);
                }
                (o.status, o.steps_done, o.energy)
            }
            Err(_) if a.ctl.cancelled() => (JobStatus::Cancelled, 0, 0.0),
            Err(e) => (JobStatus::Failed(e.to_string()), 0, 0.0),
        };
        let steps_done_f = steps_done as f64;
        st.reports.push(JobReport {
            name: a.job.name.clone(),
            n: a.job.n,
            order: a.job.order,
            steps: a.job.steps,
            nodes: a.job.nodes,
            slice: s,
            lanes: lanes[s],
            stolen: a.stolen,
            queue_wait_s,
            predicted_s: a.predicted_s,
            wall_s,
            steps_done,
            elem_steps_per_s: a.job.elems() as f64 * steps_done_f / wall_s.max(1e-12),
            energy,
            status,
        });
        drop(st);
        sched.cv.notify_all();
    }
}

struct JobOutcome {
    status: JobStatus,
    steps_done: usize,
    energy: f64,
    fields: Option<Vec<Vec<f32>>>,
}

fn run_job(job: &JobSpec, slice: &PoolSlice, ctl: &JobCtl, keep_fields: bool) -> Result<JobOutcome> {
    let mesh = unit_cube_geometry(job.n);
    let dt = job_dt(&mesh, job.order);
    if job.nodes >= 2 {
        run_cluster_job(job, &mesh, dt, ctl, keep_fields)
    } else {
        run_pool_job(job, &mesh, dt, slice, ctl, keep_fields)
    }
}

/// Single-block solve on the job's pool slice — the gang-scheduling path:
/// its stage dispatches engage only the slice's workers.
fn run_pool_job(
    job: &JobSpec,
    mesh: &Mesh,
    dt: f64,
    slice: &PoolSlice,
    ctl: &JobCtl,
    keep_fields: bool,
) -> Result<JobOutcome> {
    let owners = vec![0usize; mesh.len()];
    let (lblocks, plan) = build_local_blocks(mesh, &owners, 1);
    let basis = LglBasis::new(job.order);
    let mut st = BlockState::from_local_block(
        &lblocks[0],
        job.order,
        lblocks[0].len(),
        lblocks[0].halo_len.max(1),
    );
    st.set_initial_condition(&basis, job_ic);
    let backends: Vec<Box<dyn StageBackend>> =
        vec![Box::new(ParallelRefBackend::with_slice(job.order, slice.clone()))];
    let mut drv = Driver::new(vec![st], plan, backends, job.order);
    drv.prime();
    let mut steps_done = 0;
    for _ in 0..job.steps {
        if ctl.cancelled() {
            return Ok(JobOutcome {
                status: JobStatus::Cancelled,
                steps_done,
                energy: drv.energy(),
                fields: None,
            });
        }
        drv.step(dt)?;
        steps_done += 1;
    }
    let fields = if keep_fields {
        Some(gather_driver_fields(&drv, mesh.len(), job.order))
    } else {
        None
    };
    Ok(JobOutcome { status: JobStatus::Done, steps_done, energy: drv.energy(), fields })
}

/// Per-element final q of a single-block driver, global Morton order —
/// shape-compatible with `ClusterRun::gather_elements`.
fn gather_driver_fields(drv: &Driver, k: usize, order: usize) -> Vec<Vec<f32>> {
    let m = order + 1;
    let esz = NFIELDS * m * m * m;
    let st = &drv.blocks[0];
    (0..k).map(|e| st.q[e * esz..(e + 1) * esz].to_vec()).collect()
}

/// Cluster-backed job: its own virtual nodes, workers and fabric; the
/// job's `JobCtl` is armed with the fabric poison handle so a cancel
/// unblocks it promptly wherever it is in a step.
fn run_cluster_job(
    job: &JobSpec,
    mesh: &Mesh,
    dt: f64,
    ctl: &JobCtl,
    keep_fields: bool,
) -> Result<JobOutcome> {
    let spec = ClusterSpec::new(job.nodes, job.order);
    let mut run = ClusterRun::launch(mesh, &spec, job_ic)?;
    ctl.arm(run.fabric_ctl());
    let mut steps_done = 0;
    let stepped: Result<()> = loop {
        if steps_done >= job.steps || ctl.cancelled() {
            break Ok(());
        }
        if let Err(e) = run.run(dt, 1) {
            break Err(e);
        }
        steps_done += 1;
    };
    ctl.disarm();
    if let Err(e) = stepped {
        if ctl.cancelled() {
            return Ok(JobOutcome {
                status: JobStatus::Cancelled,
                steps_done,
                energy: 0.0,
                fields: None,
            });
        }
        return Err(e);
    }
    if ctl.cancelled() {
        return Ok(JobOutcome { status: JobStatus::Cancelled, steps_done, energy: 0.0, fields: None });
    }
    let energy = run.energy()?;
    let fields = if keep_fields { Some(run.gather_elements()?) } else { None };
    Ok(JobOutcome { status: JobStatus::Done, steps_done, energy, fields })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_jobs(k: usize) -> Vec<JobSpec> {
        (0..k)
            .map(|i| JobSpec {
                name: format!("tiny{i}"),
                n: 2,
                order: 2,
                steps: 2,
                nodes: 1,
            })
            .collect()
    }

    #[test]
    fn spec_parses_bare_array_and_object() {
        let bare = r#"[{"n": 2, "order": 2, "steps": 3}]"#;
        let s = ServeSpec::parse(bare).unwrap();
        assert_eq!(s.jobs.len(), 1);
        assert_eq!(s.jobs[0].steps, 3);
        assert_eq!(s.jobs[0].nodes, 1);
        assert_eq!(s.jobs[0].name, "n2_p2_s3");

        let obj = r#"{"jobs": [{"name": "a", "n": 3, "order": 3, "steps": 1, "nodes": 2}],
                      "queue_cap": 2, "slices": [2, 1]}"#;
        let s = ServeSpec::parse(obj).unwrap();
        assert_eq!(s.jobs[0].name, "a");
        assert_eq!(s.jobs[0].nodes, 2);
        assert_eq!(s.queue_cap, 2);
        assert_eq!(s.slices, vec![2, 1]);
        let serial = s.serial();
        assert_eq!(serial.slices, vec![3]);
        assert_eq!(serial.jobs.len(), 1);

        assert!(ServeSpec::parse("[]").is_err());
        assert!(ServeSpec::parse(r#"[{"order": 2, "steps": 1}]"#).is_err());
    }

    #[test]
    fn serves_a_batch_and_accounts_every_job() {
        let mut spec = ServeSpec::new(tiny_jobs(5));
        spec.queue_cap = 2; // exercise the bounded admission queue
        spec.slices = vec![1, 1];
        let report =
            serve(&spec, &ServeOptions { keep_fields: true, ..Default::default() }).unwrap();
        assert_eq!(report.jobs.len(), 5);
        assert_eq!(report.evicted_reports, 0);
        for j in &report.jobs {
            assert_eq!(j.status, JobStatus::Done, "{}: {:?}", j.name, j.status);
            assert_eq!(j.steps_done, j.steps);
            assert!(j.slice < 2);
            assert!(j.wall_s > 0.0 && j.elem_steps_per_s > 0.0);
            assert!(j.energy > 0.0);
        }
        assert!(report.wall_s > 0.0);
        assert!(report.elem_steps_per_s > 0.0);
        // keep_fields retained one field set per admitted job
        assert_eq!(report.fields.len(), 5);
        for f in &report.fields {
            let f = f.as_ref().expect("fields kept");
            assert_eq!(f.len(), 8); // 2^3 elements
            assert_eq!(f[0].len(), 9 * 27);
        }
    }

    #[test]
    fn report_ring_is_bounded() {
        let mut spec = ServeSpec::new(tiny_jobs(4));
        spec.slices = vec![1];
        let opts = ServeOptions { report_cap: 2, ..Default::default() };
        let report = serve(&spec, &opts).unwrap();
        assert_eq!(report.jobs.len(), 2, "ring retains the cap");
        assert_eq!(report.evicted_reports, 2);
    }

    #[test]
    fn pre_cancelled_job_skips_and_survivors_complete() {
        let spec = {
            let mut s = ServeSpec::new(tiny_jobs(3));
            s.slices = vec![1];
            s
        };
        let ctls: Vec<Arc<JobCtl>> = (0..3).map(|_| Arc::new(JobCtl::default())).collect();
        ctls[1].cancel();
        let report = serve_with_ctls(
            &spec,
            &ServeOptions { keep_fields: true, ..Default::default() },
            Some(&ctls),
        )
        .unwrap();
        assert_eq!(report.jobs.len(), 3);
        let cancelled: Vec<_> =
            report.jobs.iter().filter(|j| j.status == JobStatus::Cancelled).collect();
        assert_eq!(cancelled.len(), 1);
        assert_eq!(cancelled[0].name, "tiny1");
        assert_eq!(cancelled[0].steps_done, 0);
        assert_eq!(report.jobs.iter().filter(|j| j.status == JobStatus::Done).count(), 2);
        assert!(report.fields[0].is_some() && report.fields[2].is_some());
        assert!(report.fields[1].is_none(), "cancelled job keeps no fields");
    }

    #[test]
    fn placement_spreads_jobs_over_equal_slices() {
        let mut spec = ServeSpec::new(tiny_jobs(4));
        spec.slices = vec![1, 1];
        let report = serve(&spec, &ServeOptions::default()).unwrap();
        // with equal slices and equal jobs, greedy makespan placement
        // (plus backfill) must use both slices
        let used: std::collections::HashSet<usize> =
            report.jobs.iter().map(|j| j.slice).collect();
        assert_eq!(used.len(), 2, "{:?}", report.jobs.iter().map(|j| j.slice).collect::<Vec<_>>());
    }
}
