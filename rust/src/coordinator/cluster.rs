//! The N-node in-process cluster runtime: the full two-level nested
//! partition (paper §5), executable end to end.
//!
//! [`ClusterRun`] launches P virtual compute nodes. Each node owns one
//! contiguous level-1 splice chunk of the Morton-ordered mesh and runs the
//! level-2 boundary/interior split across **two workers** — a CPU worker
//! (owner `2n`, the boundary elements, owns all communication) and an
//! accelerator stand-in (owner `2n+1`, the interior elements). Workers are
//! long-lived threads connected by a **message fabric**: halo traces flow
//! directly worker-to-worker, routed by tables derived from the
//! [`ExchangePlan`]. The fabric distinguishes three lanes:
//!
//! * **self** — copies between blocks of one worker (applied in place),
//! * **intra-node** — CPU <-> MIC of the same node (the PCI stand-in),
//! * **inter-node** — CPU(n) <-> CPU(m) (the MPI stand-in).
//!
//! *How* a lane physically moves bytes is pluggable
//! ([`super::transport`], [`ClusterSpec::transport`]): in-process mpsc
//! channels, zero-copy shared-memory slot rings, or Unix-domain sockets
//! with length-prefixed frames on the inter-node class. Routing tables,
//! lane classification and the §5.5 refusal below are identical on every
//! transport; the equivalence is pinned by `rust/tests/
//! transport_equivalence.rs`.
//!
//! Exactly as in §5.5, accelerator workers never touch the inter-node
//! lane: the interior-only constraint of [`crate::partition::nested`]
//! guarantees it, and [`ClusterRun::launch_parts`] *refuses* any exchange
//! plan that would route a halo face between an accelerator and another
//! node ([`FabricStats::mic_inter_node_faces`] must be zero).
//!
//! Per stage every worker (a) advances its boundary elements, (b) ships
//! its outbound traces through the fabric, (c) advances its interior
//! elements while peers' traces queue behind the sweep, then (d) installs
//! incoming halos — the paper's compute/communication overlap. The
//! coordinator thread only orchestrates the stage lockstep; no trace data
//! passes through it.
//!
//! **Adaptive rebalancing** closes the loop with the cost model at *both*
//! levels: every R steps ([`ClusterRun::rebalance`]) the measured window
//! is planned by [`super::rebalance`] — level 1 re-splices the
//! across-node chunks from each node's measured per-element rate
//! ([`crate::partition::splice_weighted`] over
//! [`crate::costmodel::calib::measured_elem_rate`] weights), level 2
//! refits each node's [`KernelTimes`] into a node model
//! ([`crate::costmodel::calib::measured_node`]) and re-solves
//! [`crate::partition::solve_mic_fraction`] on the node's new chunk. The
//! affected elements **migrate** with their full state (q, res), traces
//! refreshed and halos re-primed — the run continues bit-exactly as if it
//! had been partitioned that way from the start. Migration is
//! *incremental*: only workers whose element set changed rebuild blocks
//! and backends (for PJRT a rebuild is a recompile); everyone else keeps
//! both and merely swaps routing tables.

use std::collections::{HashMap, HashSet};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::anyhow;

use super::fault::{kill_mode_of, ClusterError, FaultPlan, JoinSpec, KillMode};
use super::rebalance::{plan_two_level, RebalanceCause, TwoLevelPlan};
// historical home of the report types (they moved to the planner module)
pub use super::rebalance::{NodeRebalance, RebalanceReport};
use super::transport::{build_endpoints, CopyRoute, FabricCtl, FabricEndpoint, TransportKind};
use crate::analysis::plan_check;
use crate::costmodel::calib;
use crate::mesh::{build_local_blocks, ExchangePlan, LocalBlock, Mesh};
use crate::partition::nested::owner_migration;
use crate::partition::{
    nested_partition_fractions, solve_mic_fraction, splice, splice_weighted_excluding,
    DeviceKind, Partition,
};
#[cfg(feature = "pjrt")]
use crate::runtime::PjrtRuntime;
use crate::solver::driver::RustRefBackend;
use crate::solver::exchange::apply_exchange;
use crate::solver::parallel::ParallelRefBackend;
use crate::solver::reference::KernelTimes;
use crate::solver::rk::{LSRK_A, LSRK_B, N_STAGES};
use crate::solver::state::{BlockState, NFIELDS};
use crate::solver::{LglBasis, StageBackend};
use crate::util::pool::WorkerPool;
use crate::util::ring::History;
use crate::Result;

/// Rebalance reports kept per run (older entries are evicted; totals over
/// the retained window stay exact and [`History::evicted`] says how much
/// scrolled away).
pub const REBALANCE_HISTORY_CAP: usize = 512;

// ---------------------------------------------------------------------------
// backends
// ---------------------------------------------------------------------------

/// Constructs the per-block stage backends *inside* a worker thread.
///
/// The factory crosses the thread boundary (hence `Send + Sync`); its
/// products never do — PJRT runtimes are `Rc`-based and thread-local, and
/// the paper's offload process is a separate executor anyway.
pub trait WorkerBackendFactory: Send + Sync {
    /// One backend per block, built on the worker's own thread.
    fn build(&self, order: usize, blocks: &[BlockState]) -> Result<Vec<Box<dyn StageBackend>>>;
    fn label(&self) -> &'static str;

    /// Hardware threads one built backend will occupy (1 for scalar
    /// backends). Surfaces in [`WorkerTimes::threads`] and the phase
    /// tables so oversubscription is visible in every report.
    fn thread_budget(&self) -> usize {
        1
    }
}

/// Scalar pure-rust reference kernels (no artifacts needed).
pub struct ScalarWorker;

impl WorkerBackendFactory for ScalarWorker {
    fn build(&self, order: usize, blocks: &[BlockState]) -> Result<Vec<Box<dyn StageBackend>>> {
        Ok(blocks
            .iter()
            .map(|_| Box::new(RustRefBackend::new(order)) as Box<dyn StageBackend>)
            .collect())
    }

    fn label(&self) -> &'static str {
        "rust-ref"
    }
}

/// Multithreaded reference kernels with the in-block boundary/interior
/// split; `threads == 0` divides the hardware threads across the cluster's
/// concurrently-staging *parallel* workers (floor 1) instead of assuming a
/// whole machine per worker — P virtual nodes on one machine would
/// otherwise oversubscribe by P x.
///
/// Each `build` call creates **one persistent worker pool** shared by
/// every block backend it constructs — the pool (and its memoized
/// classifications) lives exactly as long as the backends, i.e. until the
/// worker's blocks are rebuilt by a migration. With `pin_base` set the
/// pool's workers are pinned to cores `pin_base..pin_base + threads`, so
/// the divided budget is a real affinity assignment.
pub struct ParallelWorker {
    pub threads: usize,
    /// Number of parallel workers staging concurrently (thread auto-sizing
    /// divides the machine across exactly these; scalar workers cost ~one
    /// thread each and are ignored by the budget).
    pub concurrent: usize,
    /// First core of this worker's pinned range (None = unpinned).
    pub pin_base: Option<usize>,
}

impl ParallelWorker {
    /// The per-worker thread budget this factory will build with.
    fn resolved_threads(&self) -> usize {
        if self.threads != 0 {
            return self.threads;
        }
        std::thread::available_parallelism()
            .map(|n| (n.get() / self.concurrent.max(1)).max(1))
            .unwrap_or(1)
    }
}

impl WorkerBackendFactory for ParallelWorker {
    fn build(&self, order: usize, blocks: &[BlockState]) -> Result<Vec<Box<dyn StageBackend>>> {
        if blocks.is_empty() {
            // nothing will take the pool; don't spawn threads just to
            // join them (migrations can empty a worker out)
            return Ok(Vec::new());
        }
        let t = self.resolved_threads();
        let pool = Arc::new(WorkerPool::new(t, self.pin_base));
        Ok(blocks
            .iter()
            .map(|_| {
                Box::new(ParallelRefBackend::with_pool(order, pool.clone()))
                    as Box<dyn StageBackend>
            })
            .collect())
    }

    fn label(&self) -> &'static str {
        "rust-parallel"
    }

    fn thread_budget(&self) -> usize {
        self.resolved_threads()
    }
}

/// Reference kernels slowed by a deterministic busy-wait per element and
/// stage — the stand-in for a slow node in the skew tests/benches. The
/// numerics are bit-identical to [`ScalarWorker`]; only the measured wall
/// times (and therefore the adaptive rebalancer's view of the node)
/// change.
pub struct ThrottledWorker {
    pub spin_us_per_elem: u64,
}

struct ThrottledBackend {
    inner: RustRefBackend,
    spin_us_per_elem: u64,
}

impl StageBackend for ThrottledBackend {
    fn stage(
        &mut self,
        st: &mut BlockState,
        dt: f32,
        a: f32,
        b: f32,
    ) -> Result<KernelTimes> {
        let times = self.inner.stage(st, dt, a, b)?;
        let spin =
            std::time::Duration::from_micros(self.spin_us_per_elem * st.k_real as u64);
        let t0 = Instant::now();
        while t0.elapsed() < spin {
            std::hint::spin_loop();
        }
        Ok(times)
    }

    fn name(&self) -> &'static str {
        "throttled-ref"
    }
}

impl WorkerBackendFactory for ThrottledWorker {
    fn build(&self, order: usize, blocks: &[BlockState]) -> Result<Vec<Box<dyn StageBackend>>> {
        Ok(blocks
            .iter()
            .map(|_| {
                Box::new(ThrottledBackend {
                    inner: RustRefBackend::new(order),
                    spin_us_per_elem: self.spin_us_per_elem,
                }) as Box<dyn StageBackend>
            })
            .collect())
    }

    fn label(&self) -> &'static str {
        "throttled-ref"
    }
}

/// Wraps another factory with an injected kill: after `kill_stage` LSRK
/// stages the produced backends raise the [`KillMode`] sentinel on every
/// call, and the worker loop turns that into the configured death (an
/// announced crash, a silent thread exit, or a hang). The numerics up to
/// the kill are exactly the inner backend's.
pub struct FaultyWorker {
    pub inner: Arc<dyn WorkerBackendFactory>,
    pub kill_stage: usize,
    pub mode: KillMode,
}

struct FaultyBackend {
    inner: Box<dyn StageBackend>,
    /// Boundary stages executed so far (one per stage in the worker loop:
    /// only `stage`/`stage_boundary` tick, and the delegated inner default
    /// path never re-enters this wrapper).
    done: usize,
    kill_stage: usize,
    mode: KillMode,
}

impl FaultyBackend {
    fn tick(&mut self) -> Result<()> {
        if self.done >= self.kill_stage {
            return Err(anyhow!("{}", self.mode.sentinel()));
        }
        self.done += 1;
        Ok(())
    }
}

impl StageBackend for FaultyBackend {
    fn stage(&mut self, st: &mut BlockState, dt: f32, a: f32, b: f32) -> Result<KernelTimes> {
        self.tick()?;
        self.inner.stage(st, dt, a, b)
    }

    fn stage_boundary(
        &mut self,
        st: &mut BlockState,
        dt: f32,
        a: f32,
        b: f32,
    ) -> Result<KernelTimes> {
        self.tick()?;
        self.inner.stage_boundary(st, dt, a, b)
    }

    fn stage_interior(
        &mut self,
        v: &mut crate::solver::state::InteriorView<'_>,
        dt: f32,
        a: f32,
        b: f32,
    ) -> Result<KernelTimes> {
        self.inner.stage_interior(v, dt, a, b)
    }

    fn supports_overlap(&self) -> bool {
        self.inner.supports_overlap()
    }

    fn pool_generation(&self) -> Option<u64> {
        self.inner.pool_generation()
    }

    fn classify_computes(&self) -> u64 {
        self.inner.classify_computes()
    }

    fn name(&self) -> &'static str {
        "faulty"
    }
}

impl WorkerBackendFactory for FaultyWorker {
    fn build(&self, order: usize, blocks: &[BlockState]) -> Result<Vec<Box<dyn StageBackend>>> {
        Ok(self
            .inner
            .build(order, blocks)?
            .into_iter()
            .map(|inner| {
                Box::new(FaultyBackend {
                    inner,
                    done: 0,
                    kill_stage: self.kill_stage,
                    mode: self.mode,
                }) as Box<dyn StageBackend>
            })
            .collect())
    }

    fn label(&self) -> &'static str {
        "faulty"
    }

    fn thread_budget(&self) -> usize {
        self.inner.thread_budget()
    }
}

/// AOT artifacts through PJRT (needs the `pjrt` cargo feature).
pub struct PjrtWorker {
    pub artifact_dir: std::path::PathBuf,
}

impl WorkerBackendFactory for PjrtWorker {
    #[cfg(feature = "pjrt")]
    fn build(&self, _order: usize, blocks: &[BlockState]) -> Result<Vec<Box<dyn StageBackend>>> {
        let mut rt = PjrtRuntime::new(&self.artifact_dir)?;
        let mut out: Vec<Box<dyn StageBackend>> = Vec::with_capacity(blocks.len());
        for b in blocks {
            out.push(Box::new(rt.stage_backend(b)?));
        }
        Ok(out)
    }

    #[cfg(not(feature = "pjrt"))]
    fn build(&self, _order: usize, _blocks: &[BlockState]) -> Result<Vec<Box<dyn StageBackend>>> {
        Err(anyhow!(
            "PJRT backend requested but the binary was built without the `pjrt` \
             feature; use --rust-ref/--parallel or rebuild with --features pjrt"
        ))
    }

    fn label(&self) -> &'static str {
        "pjrt"
    }
}

/// Which backend a worker executes stages with (sugar over the factories;
/// also the CLI-facing selection enum).
#[derive(Debug, Clone)]
pub enum WorkerBackend {
    /// Pure-rust reference kernels (no artifacts needed).
    RustRef,
    /// Multithreaded reference kernels with the in-node boundary/interior
    /// split; `threads == 0` auto-sizes to the hardware threads divided by
    /// the number of concurrently-staging *parallel* workers in the
    /// cluster (floor 1), so P virtual nodes on one machine share the
    /// machine instead of oversubscribing it P-fold.
    RustParallel { threads: usize },
    /// AOT artifacts through PJRT (the production path; needs the `pjrt`
    /// cargo feature).
    Pjrt { artifact_dir: std::path::PathBuf },
    /// [`ScalarWorker`] slowed by a deterministic busy-wait of
    /// `spin_us_per_elem` microseconds per element per stage — the skew
    /// injector for rebalancing tests and benches (identical numerics,
    /// inflated measured times).
    Throttled { spin_us_per_elem: u64 },
    /// Any backend wrapped with an injected kill at the start of step
    /// `kill_step` ([`FaultyWorker`]); how the death manifests is the
    /// [`KillMode`]. [`ClusterRun::launch`] wraps a node's workers in
    /// this when the [`ClusterSpec::faults`] plan schedules its death.
    Faulty { inner: Box<WorkerBackend>, kill_step: usize, mode: KillMode },
}

impl WorkerBackend {
    /// The factory realizing this backend in a cluster where
    /// `concurrent_parallel` parallel workers stage at once (the divisor
    /// of the `threads == 0` auto-budget; scalar backends ignore it).
    /// `pin_base` pins a parallel worker's pool to the core range
    /// starting there (other backends ignore it).
    pub fn factory(
        &self,
        concurrent_parallel: usize,
        pin_base: Option<usize>,
    ) -> Arc<dyn WorkerBackendFactory> {
        match self {
            WorkerBackend::RustRef => Arc::new(ScalarWorker),
            WorkerBackend::RustParallel { threads } => Arc::new(ParallelWorker {
                threads: *threads,
                concurrent: concurrent_parallel.max(1),
                pin_base,
            }),
            WorkerBackend::Pjrt { artifact_dir } => {
                Arc::new(PjrtWorker { artifact_dir: artifact_dir.clone() })
            }
            WorkerBackend::Throttled { spin_us_per_elem } => {
                Arc::new(ThrottledWorker { spin_us_per_elem: *spin_us_per_elem })
            }
            WorkerBackend::Faulty { inner, kill_step, mode } => Arc::new(FaultyWorker {
                inner: inner.factory(concurrent_parallel, pin_base),
                kill_stage: kill_step * N_STAGES,
                mode: *mode,
            }),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            WorkerBackend::RustRef => "rust-ref",
            WorkerBackend::RustParallel { .. } => "rust-parallel",
            WorkerBackend::Pjrt { .. } => "pjrt",
            WorkerBackend::Throttled { .. } => "throttled-ref",
            WorkerBackend::Faulty { .. } => "faulty",
        }
    }
}

// ---------------------------------------------------------------------------
// fabric protocol
// ---------------------------------------------------------------------------

/// Outbound copies of one worker destined to one peer (one delivery
/// group per routed stage; [`CopyRoute`] lives in [`super::transport`]).
struct OutboundGroup {
    dst: usize,
    items: Vec<CopyRoute>,
}

struct ReplaceMsg {
    /// `Some` = new blocks: rebuild the backends for them (for PJRT that
    /// is a recompile). `None` = the worker's element set is unchanged by
    /// this migration: keep blocks *and* backends alive, swap only the
    /// routing tables (peers' local indices / halo slots may have moved).
    blocks: Option<Vec<BlockState>>,
    outbound: Vec<OutboundGroup>,
    self_copies: Vec<CopyRoute>,
    expected_in: usize,
}

enum Cmd {
    /// Run one LSRK stage on every owned block; ship traces through the
    /// fabric and install incoming halos when `route`. (Trace data never
    /// rides this channel — deliveries travel the worker's
    /// [`FabricEndpoint`], so a peer racing ahead of our Stage command
    /// simply queues in the data plane.)
    Stage { dt: f32, a: f32, b: f32, route: bool },
    /// Reply with the sum of block energies.
    Energy,
    /// Reply with a full clone of local block `i`'s state.
    ReadBlock(usize),
    /// Reply with accumulated per-phase times (non-destructive).
    ReadTimes,
    /// Reply with accumulated per-phase times, then reset them.
    TakeTimes,
    /// Swap in migrated blocks + routing tables (adaptive rebalancing).
    Replace(Box<ReplaceMsg>),
    Shutdown,
}

enum Resp {
    /// Backends built; the worker is ready for its first stage.
    Ready,
    StageDone { exchange_s: f64 },
    Energy(f64),
    Block(Box<BlockState>),
    Times(WorkerTimes),
    Replaced,
    /// Recoverable failure: the worker stays alive and keeps answering.
    Err(String),
}

/// Per-worker accumulated timing: kernel CPU seconds plus the wall time of
/// each phase of the overlapped stage — the measurement the adaptive
/// rebalancer feeds back into the balance solve.
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerTimes {
    /// Per-kernel CPU seconds summed over both phases (can exceed wall).
    pub kernels: KernelTimes,
    /// Wall seconds in the boundary phase (includes shipping traces).
    pub boundary_s: f64,
    /// Wall seconds in the interior phase.
    pub interior_s: f64,
    /// Wall seconds waiting for + installing incoming halos.
    pub exchange_s: f64,
    /// LSRK stages processed since the last reset.
    pub stages: usize,
    /// Hardware-thread budget of this worker's backend (1 for scalar
    /// backends; the divided share for `RustParallel { threads: 0 }`) —
    /// surfaced so phase tables show how the machine was carved up.
    pub threads: usize,
    /// Generation id of the worker's persistent stage pool (0 = the
    /// backend has none, e.g. scalar workers). Stamped from the live
    /// backends at read time: stable across stages *and* across
    /// rebalances that keep this worker's blocks; changes exactly when
    /// the worker's backends were rebuilt.
    pub pool_generation: u64,
    /// Boundary/interior classifications computed by the worker's
    /// backends since they were built (memoized: flat across stages; a
    /// rebuild restarts the count).
    pub classify_computes: u64,
    /// Trace payload bytes this worker shipped through the fabric since
    /// the last reset (cross-worker lanes only; self copies never leave
    /// the worker). Counted at the endpoint, so it reflects what the
    /// active transport actually moved.
    pub fabric_sent_bytes: u64,
    /// Trace payload bytes received and installed from the fabric.
    pub fabric_recv_bytes: u64,
}

impl WorkerTimes {
    /// Compute wall time (boundary + interior phases).
    pub fn busy_s(&self) -> f64 {
        self.boundary_s + self.interior_s
    }

    /// Timesteps measured (stages / N_STAGES).
    pub fn steps(&self) -> f64 {
        self.stages as f64 / N_STAGES as f64
    }

    /// Compute wall per timestep (0 when nothing was measured).
    pub fn busy_per_step(&self) -> f64 {
        if self.stages == 0 {
            0.0
        } else {
            self.busy_s() / self.steps()
        }
    }

    /// The kernel profile rescaled from (possibly thread-summed) CPU
    /// seconds to this worker's measured compute *wall* time. Parallel
    /// backends report per-thread timer sums that exceed wall; fitting
    /// rates from those would model a T-thread worker ~T times slower
    /// than reality, so the rebalancer and cross-check fit from this.
    pub fn wall_kernels(&self) -> KernelTimes {
        let total = self.kernels.total();
        if total > 1e-12 {
            self.kernels.scaled(self.busy_s() / total)
        } else {
            self.kernels
        }
    }
}

/// Fabric traffic classification, in halo faces per routed stage.
/// `mic_inter_node_faces` must be zero — launch refuses plans that would
/// put an accelerator on the inter-node lane (paper §5.5).
#[derive(Debug, Clone, Copy, Default)]
pub struct FabricStats {
    /// Same-worker copies (applied in place, never cross a channel).
    pub self_faces: usize,
    /// CPU <-> MIC inside one node (the PCI stand-in).
    pub intra_node_faces: usize,
    /// CPU <-> CPU across nodes (the MPI stand-in).
    pub inter_node_faces: usize,
    /// Inter-node faces touching an accelerator worker (always 0).
    pub mic_inter_node_faces: usize,
    /// Delivery groups (= messages) per routed stage on the intra-node
    /// lane: one per directed worker pair that exchanges any face.
    pub intra_node_msgs: usize,
    /// Delivery groups per routed stage on the inter-node lane.
    pub inter_node_msgs: usize,
}

impl FabricStats {
    /// (intra-node bytes, inter-node bytes) crossing the fabric per routed
    /// stage at `order`.
    pub fn bytes_per_routed_stage(&self, order: usize) -> (usize, usize) {
        let m = order + 1;
        let sz = NFIELDS * m * m * 4;
        (self.intra_node_faces * sz, self.inter_node_faces * sz)
    }

    /// Trace bytes moved per routed stage at `order` on each lane class:
    /// (self, intra-node, inter-node). Self-lane bytes are copied in
    /// place; the other two cross the active transport.
    pub fn lane_bytes_per_stage(&self, order: usize) -> (usize, usize, usize) {
        let m = order + 1;
        let sz = NFIELDS * m * m * 4;
        (self.self_faces * sz, self.intra_node_faces * sz, self.inter_node_faces * sz)
    }
}

// ---------------------------------------------------------------------------
// worker thread
// ---------------------------------------------------------------------------

struct WorkerInit {
    rx: Receiver<Cmd>,
    tx: Sender<Resp>,
    /// This worker's data plane: one lane per peer, mechanism chosen by
    /// the cluster's [`TransportKind`].
    endpoint: Box<dyn FabricEndpoint>,
    /// Shared poison flag: set by the coordinator (or a failing peer) so
    /// a worker blocked in the fabric bails instead of waiting forever.
    ctl: FabricCtl,
    blocks: Vec<BlockState>,
    outbound: Vec<OutboundGroup>,
    self_copies: Vec<CopyRoute>,
    expected_in: usize,
    factory: Arc<dyn WorkerBackendFactory>,
    order: usize,
}

fn worker_main(init: WorkerInit) {
    let WorkerInit {
        rx,
        tx,
        mut endpoint,
        ctl,
        mut blocks,
        mut outbound,
        mut self_copies,
        mut expected_in,
        factory,
        order,
    } = init;
    let basis = LglBasis::new(order);
    let mut backends = match factory.build(order, &blocks) {
        Ok(b) => {
            tx.send(Resp::Ready).ok();
            b
        }
        Err(e) => {
            tx.send(Resp::Err(format!("building {} backends: {e}", factory.label()))).ok();
            return;
        }
    };
    let budget = factory.thread_budget();
    let fresh_times = || WorkerTimes { threads: budget, ..Default::default() };
    let mut times = fresh_times();
    loop {
        let cmd = match rx.recv() {
            Ok(c) => c,
            Err(_) => break,
        };
        match cmd {
            Cmd::Stage { dt, a, b, route } => {
                let mut fail: Option<String> = None;
                // set when this worker's own fabric lane died: skip the
                // exchange (its deliveries will never come) but keep
                // serving commands — the coordinator decides whether the
                // run is recoverable
                let mut aborted = false;
                // boundary phase (full stage for non-split backends): after
                // this every outbound trace of the exchange plan is final
                let t0 = Instant::now();
                for (i, blk) in blocks.iter_mut().enumerate() {
                    match backends[i].stage_boundary(blk, dt, a, b) {
                        Ok(t) => times.kernels.accumulate(&t),
                        Err(e) => {
                            fail = Some(format!("boundary stage: {e}"));
                            break;
                        }
                    }
                }
                // injected kills surface here as sentinel errors from the
                // FaultyBackend wrapper; how the death manifests depends on
                // the mode. Crash falls through: empty groups keep the
                // peers' lockstep intact and the sentinel reply announces
                // the death. Silent vanishes without a word — detection is
                // the coordinator noticing the hung-up reply channel. Stall
                // keeps the thread alive but mute — only the stage
                // deadline catches it (it still honors Shutdown so Drop
                // can join the thread).
                if let Some(mode) = fail.as_deref().and_then(kill_mode_of) {
                    match mode {
                        KillMode::Silent => return,
                        KillMode::Stall => loop {
                            match rx.recv() {
                                Ok(Cmd::Shutdown) | Err(_) => return,
                                Ok(_) => {}
                            }
                        },
                        KillMode::Crash => {}
                    }
                }
                if route {
                    // ship traces through the fabric *before* the interior
                    // sweep so peers route while this worker keeps
                    // computing; on failure ship empty groups so the
                    // cluster lockstep (and every peer's exchange count)
                    // stays intact
                    for grp in &outbound {
                        match endpoint.ship(grp.dst, &grp.items, &blocks, fail.is_some()) {
                            Ok(bytes) => times.fabric_sent_bytes += bytes as u64,
                            Err(e) => {
                                // a dead lane starves every peer waiting on
                                // our group — halt the fabric so their
                                // waits error out; the coordinator clears
                                // the halt if the run can be recovered
                                ctl.halt();
                                if fail.is_none() {
                                    fail = Some(format!("shipping to worker {}: {e}", grp.dst));
                                }
                                aborted = true;
                            }
                        }
                    }
                    if fail.is_none() {
                        // same-worker copies never touch the fabric; the
                        // halo is not read again until the next stage's
                        // boundary phase, so installing now is safe
                        for &(bi, e, f, dbi, slot) in &self_copies {
                            let data = blocks[bi].trace_slice(e, f).to_vec();
                            blocks[dbi].set_halo_slot(slot, &data);
                        }
                    }
                }
                times.boundary_s += t0.elapsed().as_secs_f64();
                let t1 = Instant::now();
                if fail.is_none() {
                    for (blk, backend) in blocks.iter_mut().zip(backends.iter_mut()) {
                        let (mut v, _halo) = blk.split_for_overlap();
                        match backend.stage_interior(&mut v, dt, a, b) {
                            Ok(t) => times.kernels.accumulate(&t),
                            Err(e) => {
                                fail = Some(format!("interior stage: {e}"));
                                break;
                            }
                        }
                    }
                }
                times.interior_s += t1.elapsed().as_secs_f64();
                let mut exchange_s = 0.0;
                if route && !aborted {
                    // drain one delivery group per sending peer; a local
                    // compute failure still drains (installs are harmless,
                    // the cluster is poisoned after this stage) so peers'
                    // lockstep never stalls on us
                    let t2 = Instant::now();
                    let mut got = 0usize;
                    while got < expected_in {
                        match endpoint.recv_group(&mut blocks) {
                            Ok(bytes) => {
                                got += 1;
                                times.fabric_recv_bytes += bytes as u64;
                            }
                            Err(e) => {
                                // stopped fabric or dead lane: this stage
                                // is lost — halt so peers unblock, report,
                                // and let the coordinator sort out whether
                                // the cluster can recover
                                ctl.halt();
                                if fail.is_none() {
                                    fail = Some(format!("exchange: {e}"));
                                }
                                break;
                            }
                        }
                    }
                    exchange_s = t2.elapsed().as_secs_f64();
                    times.exchange_s += exchange_s;
                }
                times.stages += 1;
                let resp = match fail {
                    None => Resp::StageDone { exchange_s },
                    Some(m) => Resp::Err(m),
                };
                tx.send(resp).ok();
            }
            Cmd::Energy => {
                let e: f64 = blocks.iter().map(|b| b.energy(&basis)).sum();
                tx.send(Resp::Energy(e)).ok();
            }
            Cmd::ReadBlock(i) => {
                if i < blocks.len() {
                    tx.send(Resp::Block(Box::new(blocks[i].clone()))).ok();
                } else {
                    tx.send(Resp::Err(format!("no local block {i}"))).ok();
                }
            }
            Cmd::ReadTimes => {
                tx.send(Resp::Times(stamp_backend_state(times, &backends))).ok();
            }
            Cmd::TakeTimes => {
                tx.send(Resp::Times(stamp_backend_state(times, &backends))).ok();
                times = fresh_times();
            }
            Cmd::Replace(msg) => {
                let ReplaceMsg { blocks: nb, outbound: no, self_copies: nsc, expected_in: nei } =
                    *msg;
                // routing always swaps; blocks + backends only when the
                // migration actually changed this worker's element set
                if let Some(nb) = nb {
                    match factory.build(order, &nb) {
                        Ok(bk) => {
                            blocks = nb;
                            backends = bk;
                        }
                        Err(e) => {
                            tx.send(Resp::Err(format!("rebuilding backends: {e}"))).ok();
                            continue;
                        }
                    }
                }
                outbound = no;
                self_copies = nsc;
                expected_in = nei;
                times = fresh_times();
                endpoint.clear_pending();
                tx.send(Resp::Replaced).ok();
            }
            Cmd::Shutdown => break,
        }
    }
}

/// Fill the backend-derived [`WorkerTimes`] fields at reply time: the
/// pool generation (first backend with a pool) and the summed
/// classification count — live views of the *current* backends, so a
/// migration that rebuilds them is visible immediately.
fn stamp_backend_state(mut t: WorkerTimes, backends: &[Box<dyn StageBackend>]) -> WorkerTimes {
    t.pool_generation = backends.iter().find_map(|b| b.pool_generation()).unwrap_or(0);
    t.classify_computes = backends.iter().map(|b| b.classify_computes()).sum();
    t
}

// ---------------------------------------------------------------------------
// routing tables
// ---------------------------------------------------------------------------

/// Hand each parallel worker a disjoint core range matching its thread
/// budget: ranges are laid out cumulatively in worker order (so mixed
/// explicit budgets stay disjoint too). Bases are *logical* offsets — the
/// pool maps them into the process's allowed-CPU list and wraps there
/// ([`crate::util::pool::WorkerPool::new`]), the single wrap point, so a
/// cgroup-restricted machine doesn't get two disagreeing moduli. Scalar
/// and throttled workers stay unpinned — they float like before.
fn assign_pin_bases(specs: &mut [WorkerSpec]) {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let parallel: Vec<usize> = specs
        .iter()
        .enumerate()
        .filter(|(_, s)| matches!(s.backend, WorkerBackend::RustParallel { .. }))
        .map(|(i, _)| i)
        .collect();
    let n_par = parallel.len().max(1);
    let mut next = 0usize;
    for &i in &parallel {
        let budget = match specs[i].backend {
            WorkerBackend::RustParallel { threads: 0 } => (hw / n_par).max(1),
            WorkerBackend::RustParallel { threads } => threads,
            _ => unreachable!("filtered to parallel backends"),
        };
        specs[i].pin_base = Some(next);
        next += budget;
    }
}

/// Distribute per-owner states to workers, preserving owner order; returns
/// (blocks per worker, owners per worker, owner -> (worker, local index)).
#[allow(clippy::type_complexity)]
fn distribute(
    states: Vec<BlockState>,
    worker_of_owner: &[usize],
    nw: usize,
) -> (Vec<Vec<BlockState>>, Vec<Vec<usize>>, HashMap<usize, (usize, usize)>) {
    let mut blocks: Vec<Vec<BlockState>> = (0..nw).map(|_| Vec::new()).collect();
    let mut owners: Vec<Vec<usize>> = (0..nw).map(|_| Vec::new()).collect();
    let mut map = HashMap::new();
    for (o, st) in states.into_iter().enumerate() {
        let w = worker_of_owner[o];
        map.insert(o, (w, blocks[w].len()));
        blocks[w].push(st);
        owners[w].push(o);
    }
    (blocks, owners, map)
}

/// Invert the exchange plan into per-worker routing tables: outbound copy
/// groups per destination worker, same-worker copies, and how many Deliver
/// messages each worker expects per routed stage (one per sending peer).
#[allow(clippy::type_complexity)]
fn route_tables(
    plan: &ExchangePlan,
    owner_map: &HashMap<usize, (usize, usize)>,
    nw: usize,
) -> (Vec<Vec<OutboundGroup>>, Vec<Vec<CopyRoute>>, Vec<usize>) {
    let mut outbound: Vec<Vec<OutboundGroup>> = (0..nw).map(|_| Vec::new()).collect();
    let mut self_copies: Vec<Vec<CopyRoute>> = (0..nw).map(|_| Vec::new()).collect();
    let mut sources: Vec<HashSet<usize>> = (0..nw).map(|_| HashSet::new()).collect();
    for (dst_owner, copies) in plan.copies.iter().enumerate() {
        let Some(&(wd, bd)) = owner_map.get(&dst_owner) else { continue };
        for &(src_owner, se, sf, slot) in copies {
            let (ws, bs) = owner_map[&src_owner];
            let route: CopyRoute = (bs, se, sf, bd, slot);
            if ws == wd {
                self_copies[ws].push(route);
            } else {
                match outbound[ws].iter_mut().find(|g| g.dst == wd) {
                    Some(g) => g.items.push(route),
                    None => outbound[ws].push(OutboundGroup { dst: wd, items: vec![route] }),
                }
                sources[wd].insert(ws);
            }
        }
    }
    let expected: Vec<usize> = sources.iter().map(|s| s.len()).collect();
    (outbound, self_copies, expected)
}

/// Classify every copy of the plan by fabric lane and enforce the §5.5
/// constraint: no inter-node face may touch an accelerator worker.
fn fabric_stats(
    plan: &ExchangePlan,
    owner_map: &HashMap<usize, (usize, usize)>,
    meta: &[(usize, DeviceKind)],
) -> Result<FabricStats> {
    let mut st = FabricStats::default();
    let mut intra_pairs: HashSet<(usize, usize)> = HashSet::new();
    let mut inter_pairs: HashSet<(usize, usize)> = HashSet::new();
    for (dst_owner, copies) in plan.copies.iter().enumerate() {
        let Some(&(wd, _)) = owner_map.get(&dst_owner) else { continue };
        for &(src_owner, _, _, _) in copies {
            let (ws, _) = owner_map[&src_owner];
            if ws == wd {
                st.self_faces += 1;
            } else if meta[ws].0 == meta[wd].0 {
                st.intra_node_faces += 1;
                intra_pairs.insert((ws, wd));
            } else {
                st.inter_node_faces += 1;
                inter_pairs.insert((ws, wd));
                if meta[ws].1 == DeviceKind::Mic || meta[wd].1 == DeviceKind::Mic {
                    st.mic_inter_node_faces += 1;
                }
            }
        }
    }
    st.intra_node_msgs = intra_pairs.len();
    st.inter_node_msgs = inter_pairs.len();
    if st.mic_inter_node_faces > 0 {
        // same typed diagnostic the static checker emits (the rendered
        // message keeps the "inter-node" wording tests key on)
        let d = plan_check::PlanDiag::error(
            plan_check::DiagCode::AcceleratorOnInterNodeLane,
            format!(
                "{} halo faces would route between an accelerator worker and another \
                 node; accelerators never touch the inter-node fabric (paper §5.5 \
                 interior-only constraint) — fix the nested partition",
                st.mic_inter_node_faces
            ),
        );
        return Err(plan_check::PlanCheckError { diags: vec![d] }.into());
    }
    Ok(st)
}

// ---------------------------------------------------------------------------
// the cluster runtime
// ---------------------------------------------------------------------------

/// One worker's placement + backend in [`ClusterRun::launch_parts`].
#[derive(Debug, Clone)]
pub struct WorkerSpec {
    /// Which virtual node the worker belongs to.
    pub node: usize,
    /// CPU (communication-owning) or accelerator stand-in.
    pub device: DeviceKind,
    pub backend: WorkerBackend,
    /// Thread name.
    pub name: String,
    /// First core of this worker's pinned range (parallel backends only;
    /// `None` = unpinned). [`ClusterRun::launch`] fills it from
    /// [`ClusterSpec::pin_cores`], handing each parallel worker a
    /// disjoint core range of its thread budget.
    pub pin_base: Option<usize>,
}

/// Read-only summary of one live worker.
#[derive(Debug, Clone)]
pub struct WorkerSummary {
    pub node: usize,
    pub device: DeviceKind,
    pub k_elems: usize,
    pub label: &'static str,
    /// False once the worker's node was declared failed (injected or
    /// detected); dead workers own no elements and receive no commands.
    pub alive: bool,
}

/// High-level cluster configuration for [`ClusterRun::launch`].
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    /// Number of virtual compute nodes (level-1 splice chunks).
    pub nodes: usize,
    pub order: usize,
    /// Level-2 MIC share per node; `None` solves it from the calibrated
    /// Stampede model (the paper's §5.6 static split).
    pub mic_fraction: Option<f64>,
    /// Backend of the CPU (boundary) workers.
    pub cpu_backend: WorkerBackend,
    /// Backend of the accelerator (interior) workers — may differ, which
    /// is the heterogeneous case the rebalancer equalizes.
    pub mic_backend: WorkerBackend,
    pub exchange_every_stage: bool,
    /// Re-solve the two-level split from measured times each R steps.
    pub rebalance_every: Option<usize>,
    /// Rebalancing adapts the *level-1* splice across nodes (weighted by
    /// measured node rates) in addition to each node's level-2 CPU/MIC
    /// split. Off = level-2-only (the pre-two-level behavior).
    pub level1_rebalance: bool,
    /// Per-node `(cpu, mic)` backend override (`len == nodes`); `None`
    /// uses `cpu_backend`/`mic_backend` uniformly. The skewed-cluster
    /// tests and benches throttle a single node through this.
    pub node_backends: Option<Vec<(WorkerBackend, WorkerBackend)>>,
    /// Pin each parallel worker's pool to a disjoint core range (making
    /// the divided `RustParallel { threads: 0 }` budget a real affinity
    /// assignment). Best-effort: refused affinity calls degrade to the
    /// unpinned behavior.
    pub pin_cores: bool,
    /// How fabric lanes physically move bytes ([`super::transport`]):
    /// in-process channels, shared-memory rings, or Unix-domain sockets
    /// on the inter-node lane. Routing, lane classification and the §5.5
    /// refusal are identical on all of them.
    pub transport: TransportKind,
    /// Seeded fault-injection plan: scheduled node kills, elastic joins
    /// and fabric sabotage ([`FaultPlan`]). Default = no faults.
    pub faults: FaultPlan,
    /// Extra nodes launched idle (zero elements, inactive) so an elastic
    /// join has somewhere to land. Spares run real worker threads on the
    /// fabric but own nothing until [`ClusterRun::join_node`].
    pub spare_nodes: usize,
    /// Snapshot q every C steps so a node failure rewinds at most C-1
    /// completed steps ([`ClusterRun::checkpoint_now`]). `None` = no
    /// checkpoints — a failure is then unrecoverable.
    pub checkpoint_every: Option<usize>,
    /// Upper bound on one stage's wall time before the coordinator halts
    /// the fabric and declares non-responding workers dead (the only way
    /// to catch a worker that stalls without crashing). `None` defaults
    /// to 10s when `faults` is armed, unbounded otherwise.
    pub stage_deadline: Option<Duration>,
}

impl ClusterSpec {
    pub fn new(nodes: usize, order: usize) -> Self {
        ClusterSpec {
            nodes,
            order,
            mic_fraction: None,
            cpu_backend: WorkerBackend::RustRef,
            mic_backend: WorkerBackend::RustRef,
            exchange_every_stage: true,
            rebalance_every: None,
            level1_rebalance: true,
            node_backends: None,
            pin_cores: false,
            transport: TransportKind::InProc,
            faults: FaultPlan::default(),
            spare_nodes: 0,
            checkpoint_every: None,
            stage_deadline: None,
        }
    }
}

struct WorkerHandle {
    tx: Sender<Cmd>,
    rx: Receiver<Resp>,
    handle: Option<JoinHandle<()>>,
    /// Owners handled by this worker, in block order.
    owners: Vec<usize>,
    node: usize,
    device: DeviceKind,
    k_elems: usize,
    label: &'static str,
    /// Cleared when the worker's node is declared failed: dead workers
    /// receive no further commands (their thread may still be parked in
    /// the command loop until shutdown, or already gone).
    alive: bool,
}

/// Everything the mesh-aware launch keeps for re-splitting + migration.
struct MeshCtx {
    mesh: Mesh,
    node_part: Partition,
    /// Current per-node MIC fraction.
    fractions: Vec<f64>,
    /// Current blocks (for global-id mapping during migration).
    lblocks: Vec<LocalBlock>,
    /// Current owner per global element.
    elem_owners: Vec<usize>,
}

/// A live N-node cluster: 2 workers per node plus the message fabric.
pub struct ClusterRun {
    workers: Vec<WorkerHandle>,
    /// owner -> (worker index, local block index)
    owner_map: HashMap<usize, (usize, usize)>,
    worker_of_owner: Vec<usize>,
    plan: ExchangePlan,
    fabric: FabricStats,
    pub order: usize,
    /// Exchange after every RK stage (numerically exact) vs once per step
    /// (the paper's §5.5 schedule, kept as an ablation).
    pub exchange_every_stage: bool,
    pub steps_taken: usize,
    /// Wall time of the compute part of all stages (boundary + interior).
    pub stage_wall_s: f64,
    /// Wall time of the exchange windows (max over workers per stage).
    pub exchange_wall_s: f64,
    /// When set, [`ClusterRun::run`] rebalances every R steps.
    pub rebalance_every: Option<usize>,
    /// Adapt the level-1 across-node splice during rebalancing (see
    /// [`ClusterSpec::level1_rebalance`]).
    pub level1_rebalance: bool,
    /// The most recent rebalances, in order — benches and the CLI
    /// aggregate level-1/level-2 migration counts and stall time from it.
    /// Bounded ([`REBALANCE_HISTORY_CAP`]) so a long-serving run that
    /// rebalances every R steps doesn't grow memory without limit.
    pub rebalance_history: History<RebalanceReport>,
    routed_stages: usize,
    poisoned: bool,
    /// Fabric poison flag shared with every worker endpoint: set before
    /// shutdown (and on any stage failure) so workers blocked in the
    /// data plane bail out instead of waiting forever.
    ctl: FabricCtl,
    transport: TransportKind,
    mesh_ctx: Option<MeshCtx>,
    /// Which nodes currently own part of the mesh: spares start false,
    /// a detected failure flips its node false, an elastic join flips a
    /// spare true. Indexed by node id.
    node_active: Vec<bool>,
    /// Snapshot q every C steps ([`ClusterSpec::checkpoint_every`]).
    checkpoint_every: Option<usize>,
    /// Most recent q snapshot (recovery rewinds to it).
    checkpoint: Option<Checkpoint>,
    /// The typed failure a stage surfaced; cleared by a successful
    /// [`ClusterRun::recover`].
    last_error: Option<ClusterError>,
    /// See [`ClusterSpec::stage_deadline`].
    stage_deadline: Option<Duration>,
    /// Scheduled elastic joins not yet executed, from the fault plan.
    pending_joins: Vec<JoinSpec>,
}

/// A q-only snapshot at a step boundary. Traces and halos are pure
/// functions of q (and res enters a step scaled by `LSRK_A[0] == 0`), so
/// restoring q and rebuilding traces reproduces the checkpointed step
/// boundary bit-for-bit. q is keyed by global element id, which makes the
/// snapshot membership-agnostic: it restores onto any node partition that
/// covers the mesh, not just the one it was taken under.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// `steps_taken` at snapshot time.
    pub step: usize,
    /// Per-global-element q block, Morton order.
    q: Vec<Vec<f32>>,
}

impl ClusterRun {
    /// Launch the full two-level scheme on `mesh`: level-1 splice into
    /// `spec.nodes` chunks, level-2 CPU/MIC split per node, two workers per
    /// node on the fabric. Initial conditions come from `ic`.
    pub fn launch(
        mesh: &Mesh,
        spec: &ClusterSpec,
        ic: impl Fn([f64; 3]) -> [f64; NFIELDS],
    ) -> Result<ClusterRun> {
        let nodes = spec.nodes.max(1);
        // Plan-shape refusals are typed diagnostics from the static
        // checker — the same pass `repro check` runs standalone (see
        // CORRECTNESS.md). Non-strict: feasibility findings (e.g. a kill
        // with checkpointing off) stay warnings so fault-injection runs
        // can observe the live typed failure.
        plan_check::check_spec(mesh.len(), spec, false).into_result()?;
        // spares are full fabric members with zero elements until a join
        let total = nodes + spec.spare_nodes;
        let node_part = Partition { assignment: splice(mesh, nodes).assignment, nparts: total };
        let k_node = (mesh.len() / nodes).max(1);
        let frac = spec.mic_fraction.unwrap_or_else(|| {
            let sol = solve_mic_fraction(&calib::stampede_node(), spec.order, k_node);
            sol.k_mic as f64 / k_node as f64
        });
        if let Some(d) = plan_check::fraction_diag(frac) {
            return Err(plan_check::PlanCheckError { diags: vec![d] }.into());
        }
        let fractions = vec![frac; total];
        let np = nested_partition_fractions(mesh, &node_part, &fractions);
        let elem_owners = np.owners();
        let (lblocks, plan) = build_local_blocks(mesh, &elem_owners, np.n_owners());
        // Deep preflight (debug builds): the structural invariants of
        // build_local_blocks — disjoint/exhaustive ownership, symmetric
        // routes, in-range copies. §5.5 silence is intentionally NOT
        // asserted here: a violating plan is a legal structure that
        // fabric_stats refuses with a typed error the tests observe.
        #[cfg(debug_assertions)]
        {
            let rep = plan_check::check_blocks(&lblocks, &plan, mesh.len());
            debug_assert!(!rep.has_errors(), "launch preflight: {}", rep.render_errors());
        }
        let basis = LglBasis::new(spec.order);
        let mut states = Vec::with_capacity(lblocks.len());
        for lb in &lblocks {
            let mut st =
                BlockState::from_local_block(lb, spec.order, lb.len().max(1), lb.halo_len.max(1));
            st.set_initial_condition(&basis, &ic);
            states.push(st);
        }
        let mut specs: Vec<WorkerSpec> = (0..2 * total)
            .map(|w| {
                let device = if w % 2 == 0 { DeviceKind::Cpu } else { DeviceKind::Mic };
                let backend = match spec.node_backends.as_ref().and_then(|nb| nb.get(w / 2)) {
                    Some(pair) => {
                        if device == DeviceKind::Cpu { pair.0.clone() } else { pair.1.clone() }
                    }
                    None => {
                        if device == DeviceKind::Cpu {
                            spec.cpu_backend.clone()
                        } else {
                            spec.mic_backend.clone()
                        }
                    }
                };
                WorkerSpec {
                    node: w / 2,
                    device,
                    backend,
                    name: format!(
                        "node{}-{}",
                        w / 2,
                        if device == DeviceKind::Cpu { "cpu" } else { "mic" }
                    ),
                    pin_base: None,
                }
            })
            .collect();
        if spec.pin_cores {
            assign_pin_bases(&mut specs);
        }
        // wrap scheduled-death nodes' backends after pinning so the pin
        // pass still sees the parallel backends underneath
        for k in &spec.faults.kills {
            for w in [2 * k.node, 2 * k.node + 1] {
                let inner = Box::new(specs[w].backend.clone());
                specs[w].backend =
                    WorkerBackend::Faulty { inner, kill_step: k.step, mode: k.mode };
            }
        }
        let worker_of_owner: Vec<usize> = (0..2 * total).collect();
        let mut run = ClusterRun::launch_parts_inner(
            &lblocks,
            states,
            plan,
            &worker_of_owner,
            &specs,
            spec.order,
            spec.transport,
            spec.faults.is_armed().then_some(&spec.faults),
        )?;
        run.exchange_every_stage = spec.exchange_every_stage;
        run.rebalance_every = spec.rebalance_every;
        run.level1_rebalance = spec.level1_rebalance;
        run.node_active = (0..total).map(|nd| nd < nodes).collect();
        run.checkpoint_every = spec.checkpoint_every;
        run.pending_joins = spec.faults.joins.clone();
        run.stage_deadline = spec
            .stage_deadline
            .or_else(|| spec.faults.is_armed().then(|| Duration::from_secs(10)));
        run.mesh_ctx =
            Some(MeshCtx { mesh: mesh.clone(), node_part, fractions, lblocks, elem_owners });
        Ok(run)
    }

    /// Launch from pre-built parts: `worker_of_owner[o]` assigns each owner's
    /// block to a worker in `0..specs.len()`. Initial conditions must already
    /// be set on the states; traces and halos are primed here. This entry
    /// point has no mesh, so [`ClusterRun::rebalance`] is unavailable — the
    /// mesh-aware [`ClusterRun::launch`] enables it.
    pub fn launch_parts(
        lblocks: &[LocalBlock],
        states: Vec<BlockState>,
        plan: ExchangePlan,
        worker_of_owner: &[usize],
        specs: &[WorkerSpec],
        order: usize,
    ) -> Result<ClusterRun> {
        ClusterRun::launch_parts_with(
            lblocks,
            states,
            plan,
            worker_of_owner,
            specs,
            order,
            TransportKind::InProc,
        )
    }

    /// [`ClusterRun::launch_parts`] with an explicit fabric transport
    /// ([`TransportKind`]); `launch_parts` keeps the historical in-process
    /// default.
    pub fn launch_parts_with(
        lblocks: &[LocalBlock],
        states: Vec<BlockState>,
        plan: ExchangePlan,
        worker_of_owner: &[usize],
        specs: &[WorkerSpec],
        order: usize,
        transport: TransportKind,
    ) -> Result<ClusterRun> {
        ClusterRun::launch_parts_inner(
            lblocks,
            states,
            plan,
            worker_of_owner,
            specs,
            order,
            transport,
            None,
        )
    }

    /// The real launcher: `faults`, when armed, hands each worker's fabric
    /// endpoint its seeded message-sabotage injector. Kill scheduling is
    /// *not* done here — [`ClusterRun::launch`] wraps doomed backends in
    /// [`WorkerBackend::Faulty`] before calling in.
    #[allow(clippy::too_many_arguments)]
    fn launch_parts_inner(
        lblocks: &[LocalBlock],
        mut states: Vec<BlockState>,
        plan: ExchangePlan,
        worker_of_owner: &[usize],
        specs: &[WorkerSpec],
        order: usize,
        transport: TransportKind,
        faults: Option<&FaultPlan>,
    ) -> Result<ClusterRun> {
        assert_eq!(lblocks.len(), states.len());
        assert_eq!(worker_of_owner.len(), states.len());
        let nw = specs.len();
        assert!(nw >= 1, "need at least one worker");
        assert!(worker_of_owner.iter().all(|&w| w < nw), "worker index out of range");
        // prime traces + halos in-process before distributing
        for s in states.iter_mut() {
            s.refresh_traces();
        }
        apply_exchange(&mut states, &plan);
        let (mut per_worker_blocks, per_worker_owners, owner_map) =
            distribute(states, worker_of_owner, nw);
        let meta: Vec<(usize, DeviceKind)> = specs.iter().map(|s| (s.node, s.device)).collect();
        let fabric = fabric_stats(&plan, &owner_map, &meta)?;
        let (mut outbound, mut self_copies, expected) = route_tables(&plan, &owner_map, nw);
        // thread auto-budget divisor: the workers that will actually claim
        // a thread pool, not every worker (a scalar accelerator stand-in
        // costs ~one thread and must not halve the parallel CPU workers'
        // share)
        let parallel_workers = specs
            .iter()
            .filter(|s| matches!(s.backend, WorkerBackend::RustParallel { .. }))
            .count()
            .max(1);
        let mut cmd_txs: Vec<Sender<Cmd>> = Vec::with_capacity(nw);
        let mut cmd_rxs: Vec<Option<Receiver<Cmd>>> = Vec::with_capacity(nw);
        for _ in 0..nw {
            let (t, r) = channel::<Cmd>();
            cmd_txs.push(t);
            cmd_rxs.push(Some(r));
        }
        // the data plane: one endpoint per worker, lane mechanism chosen
        // by `transport`; lanes exist for every cross-worker pair so a
        // rebalance can swap routing tables without re-plumbing (kept
        // workers keep live connections)
        let ctl = FabricCtl::new();
        let node_of_worker: Vec<usize> = specs.iter().map(|s| s.node).collect();
        let m = order + 1;
        let mut endpoints =
            build_endpoints(transport, &node_of_worker, NFIELDS * m * m, &ctl)?.into_iter();
        let mut workers = Vec::with_capacity(nw);
        for (w, spec) in specs.iter().enumerate() {
            let (rtx, rrx) = channel::<Resp>();
            let mut endpoint = endpoints.next().expect("one endpoint per worker");
            if let Some(plan) = faults {
                endpoint.set_injector(plan.injector_for(w));
            }
            let init = WorkerInit {
                rx: cmd_rxs[w].take().expect("receiver taken once"),
                tx: rtx,
                endpoint: Box::new(endpoint),
                ctl: ctl.clone(),
                blocks: std::mem::take(&mut per_worker_blocks[w]),
                outbound: std::mem::take(&mut outbound[w]),
                self_copies: std::mem::take(&mut self_copies[w]),
                expected_in: expected[w],
                factory: spec.backend.factory(parallel_workers, spec.pin_base),
                order,
            };
            let handle = std::thread::Builder::new()
                .name(spec.name.clone())
                .spawn(move || worker_main(init))
                .map_err(|e| anyhow!("spawning worker {w}: {e}"))?;
            let k_elems: usize = per_worker_owners[w].iter().map(|&o| lblocks[o].len()).sum();
            workers.push(WorkerHandle {
                tx: cmd_txs[w].clone(),
                rx: rrx,
                handle: Some(handle),
                owners: per_worker_owners[w].clone(),
                node: spec.node,
                device: spec.device,
                k_elems,
                label: spec.backend.label(),
                alive: true,
            });
        }
        let run = ClusterRun {
            workers,
            owner_map,
            worker_of_owner: worker_of_owner.to_vec(),
            plan,
            fabric,
            order,
            exchange_every_stage: true,
            steps_taken: 0,
            stage_wall_s: 0.0,
            exchange_wall_s: 0.0,
            rebalance_every: None,
            level1_rebalance: false,
            rebalance_history: History::new(REBALANCE_HISTORY_CAP),
            routed_stages: 0,
            poisoned: false,
            ctl,
            transport,
            mesh_ctx: None,
            node_active: {
                let n_nodes = specs.iter().map(|s| s.node).max().map_or(0, |m| m + 1);
                vec![true; n_nodes]
            },
            checkpoint_every: None,
            checkpoint: None,
            last_error: None,
            stage_deadline: None,
            pending_joins: Vec::new(),
        };
        // readiness handshake: backend construction can fail (e.g. PJRT
        // without the feature) — surface it now, not as a first-stage hang
        for (w, wk) in run.workers.iter().enumerate() {
            match wk.rx.recv() {
                Ok(Resp::Ready) => {}
                Ok(Resp::Err(m)) => return Err(anyhow!("worker {w} failed to start: {m}")),
                _ => return Err(anyhow!("worker {w} died during startup")),
            }
        }
        Ok(run)
    }

    /// Mark the run dead *and* poison the fabric, so any worker blocked
    /// in a data-plane wait errors out instead of hanging forever.
    fn poison(&mut self) {
        self.poisoned = true;
        self.ctl.poison();
    }

    /// Dispatch one stage to every live worker and collect the replies by
    /// *polling* — a dead or mute worker can therefore never hang the
    /// coordinator. The poll sleeps inside `recv_timeout`, so the wall
    /// cost over a blocking receive is at most ~1ms per live worker per
    /// sweep. Deaths are classified here: injected-kill sentinels and
    /// hung-up reply channels mark the whole node dead (nodes are the
    /// failure domain — the partner worker is marked dead too, its thread
    /// left parked until shutdown); fabric errors on *other* workers
    /// while a death is in flight are collateral, not failures of their
    /// own. A genuine failure with no death in flight still poisons the
    /// run exactly as before.
    fn stage_all(&mut self, dt: f32, a: f32, b: f32, route: bool) -> Result<()> {
        const POLL: Duration = Duration::from_millis(1);
        /// After the fabric halts, survivors unblock within one fabric
        /// tick — anything still silent this much later is stalled.
        const STAGE_GRACE: Duration = Duration::from_secs(5);
        let t0 = Instant::now();
        let mut newly_dead: Vec<usize> = Vec::new();
        let mut death_detail = String::new();
        for (w, wk) in self.workers.iter().enumerate() {
            if !wk.alive {
                continue;
            }
            if wk.tx.send(Cmd::Stage { dt, a, b, route }).is_err() {
                newly_dead.push(w);
                death_detail = format!("worker {w} hung up before the stage");
            }
        }
        let mut pending: Vec<usize> = (0..self.workers.len())
            .filter(|w| self.workers[*w].alive && !newly_dead.contains(w))
            .collect();
        let mut halt_time: Option<Instant> = None;
        if !newly_dead.is_empty() {
            self.ctl.halt();
            halt_time = Some(Instant::now());
        }
        let mut survivor_err: Option<String> = None;
        let mut collateral: Option<String> = None;
        let mut ex_max = 0.0f64;
        while !pending.is_empty() {
            let mut i = 0;
            while i < pending.len() {
                let w = pending[i];
                match self.workers[w].rx.recv_timeout(POLL) {
                    Ok(Resp::StageDone { exchange_s }) => {
                        ex_max = ex_max.max(exchange_s);
                        pending.swap_remove(i);
                    }
                    Ok(Resp::Err(m)) => {
                        if kill_mode_of(&m).is_some() {
                            // an injected death announcing itself
                            newly_dead.push(w);
                            death_detail = m;
                            self.ctl.halt();
                            halt_time.get_or_insert_with(Instant::now);
                        } else if halt_time.is_some() {
                            // a survivor tripping over the halted fabric
                            collateral.get_or_insert(m);
                        } else {
                            survivor_err.get_or_insert(m);
                        }
                        pending.swap_remove(i);
                    }
                    Ok(_) => {
                        survivor_err.get_or_insert_with(|| {
                            format!("worker {w} sent an unexpected reply during the stage")
                        });
                        pending.swap_remove(i);
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        // silent death: the thread is gone without a word
                        newly_dead.push(w);
                        death_detail = format!("worker {w} vanished mid-stage");
                        self.ctl.halt();
                        halt_time.get_or_insert_with(Instant::now);
                        pending.swap_remove(i);
                    }
                    Err(RecvTimeoutError::Timeout) => i += 1,
                }
            }
            // the stage deadline catches workers that neither reply nor
            // hang up (stalled); the grace window after a halt catches
            // workers that ignore even the halted fabric
            if let Some(t) = halt_time {
                if t.elapsed() > STAGE_GRACE && !pending.is_empty() {
                    for &w in &pending {
                        newly_dead.push(w);
                        death_detail = format!("worker {w}: no reply within deadline (stalled)");
                    }
                    pending.clear();
                }
            } else if self.stage_deadline.is_some_and(|dl| t0.elapsed() > dl) {
                self.ctl.halt();
                halt_time = Some(Instant::now());
            }
        }
        let full = t0.elapsed().as_secs_f64();
        self.stage_wall_s += (full - ex_max).max(0.0);
        self.exchange_wall_s += ex_max;
        if route {
            self.routed_stages += 1;
        }
        if !newly_dead.is_empty() {
            // nodes are the failure domain: losing either worker severs
            // the node's boundary/interior pairing, so both go
            let mut nodes: Vec<usize> =
                newly_dead.iter().map(|&w| self.workers[w].node).collect();
            nodes.sort_unstable();
            nodes.dedup();
            for wk in self.workers.iter_mut() {
                if nodes.contains(&wk.node) {
                    wk.alive = false;
                }
            }
            for &nd in &nodes {
                if nd < self.node_active.len() {
                    self.node_active[nd] = false;
                }
            }
            let err =
                ClusterError::NodeFailure { nodes, step: self.steps_taken, detail: death_detail };
            let msg = err.to_string();
            self.last_error = Some(err);
            return Err(anyhow!("{msg}"));
        }
        if let Some(m) = survivor_err {
            self.poison();
            self.last_error = Some(ClusterError::Poisoned { detail: m.clone() });
            return Err(anyhow!("stage failed: {m}"));
        }
        if halt_time.is_some() {
            if let Some(m) = collateral {
                // the deadline halted the fabric mid-exchange and broke
                // the stage, but nobody actually died: unrecoverable
                self.poison();
                self.last_error = Some(ClusterError::Poisoned { detail: m.clone() });
                return Err(anyhow!("stage deadline halted the fabric mid-stage: {m}"));
            }
            // spurious deadline — everyone finished anyway
            self.ctl.clear_halt();
        }
        Ok(())
    }

    /// Advance one LSRK timestep.
    pub fn step(&mut self, dt: f64) -> Result<()> {
        if self.poisoned {
            return Err(anyhow!("cluster poisoned by an earlier failure; relaunch"));
        }
        if let Some(e) = &self.last_error {
            return Err(anyhow!("cluster degraded ({e}); recover() or relaunch"));
        }
        for s in 0..N_STAGES {
            let route = self.exchange_every_stage || s == N_STAGES - 1;
            self.stage_all(dt as f32, LSRK_A[s] as f32, LSRK_B[s] as f32, route)?;
        }
        self.steps_taken += 1;
        Ok(())
    }

    /// Advance `steps` timesteps, rebalancing every `rebalance_every`
    /// steps when configured, snapshotting every `checkpoint_every` steps,
    /// executing scheduled elastic joins, and — when a node failure
    /// surfaces and a checkpoint exists — recovering in place: the dead
    /// node's elements are respliced across the survivors and the run
    /// rewinds to the last snapshot (mesh-aware launches only).
    pub fn run(&mut self, dt: f64, steps: usize) -> Result<()> {
        let target = self.steps_taken + steps;
        if self.checkpoint_every.is_some()
            && self.checkpoint.is_none()
            && self.mesh_ctx.is_some()
        {
            self.checkpoint_now()?;
        }
        while self.steps_taken < target {
            self.process_due_joins()?;
            match self.step(dt) {
                Ok(()) => {
                    if let Some(every) = self.checkpoint_every {
                        if every > 0 && self.steps_taken % every == 0 && self.mesh_ctx.is_some() {
                            self.checkpoint_now()?;
                        }
                    }
                    if let Some(every) = self.rebalance_every {
                        if every > 0 && self.steps_taken % every == 0 && self.mesh_ctx.is_some() {
                            self.rebalance()?;
                        }
                    }
                }
                Err(e) => {
                    if self.can_recover() {
                        self.recover()?;
                    } else {
                        return Err(e);
                    }
                }
            }
        }
        Ok(())
    }

    /// Run any fault-plan joins whose scheduled step has arrived.
    fn process_due_joins(&mut self) -> Result<()> {
        while let Some(pos) =
            self.pending_joins.iter().position(|j| j.step <= self.steps_taken)
        {
            let j = self.pending_joins.remove(pos);
            self.join_node(j.node)?;
        }
        Ok(())
    }

    /// The typed failure the last stage surfaced, if any.
    pub fn last_error(&self) -> Option<&ClusterError> {
        self.last_error.as_ref()
    }

    /// Per-node liveness: spares start inactive, a detected failure flips
    /// its node off, an elastic join flips a spare on.
    pub fn node_active(&self) -> &[bool] {
        &self.node_active
    }

    /// True when a recoverable node failure is pending *and* the run
    /// holds everything [`ClusterRun::recover`] needs.
    pub fn can_recover(&self) -> bool {
        !self.poisoned
            && self.last_error.as_ref().is_some_and(|e| e.recoverable())
            && self.checkpoint.is_some()
            && self.mesh_ctx.is_some()
    }

    /// Snapshot q at the current step boundary (mesh-aware launches
    /// only). [`ClusterRun::run`] calls this every
    /// [`ClusterSpec::checkpoint_every`] steps.
    pub fn checkpoint_now(&mut self) -> Result<()> {
        anyhow::ensure!(self.mesh_ctx.is_some(), "checkpoints need the mesh-aware launch");
        anyhow::ensure!(
            self.last_error.is_none() && !self.poisoned,
            "refusing to checkpoint a degraded run"
        );
        let q = self.gather_elements()?;
        self.checkpoint = Some(Checkpoint { step: self.steps_taken, q });
        Ok(())
    }

    /// Recover from a detected node failure: resplice the dead node's
    /// elements across the surviving nodes (weighted level-1 path,
    /// excluding inactive parts), restore every live worker's state from
    /// the last q snapshot, rewind `steps_taken` to it, and clear the
    /// failure. The returned report carries
    /// [`RebalanceReport::replayed_steps`] (completed steps the rewind
    /// discards) and, as `wall_s`, the recovery stall — both also land in
    /// [`ClusterRun::rebalance_history`] under
    /// [`RebalanceCause::Recovery`].
    pub fn recover(&mut self) -> Result<RebalanceReport> {
        anyhow::ensure!(!self.poisoned, "cluster poisoned; relaunch");
        match &self.last_error {
            Some(e) if e.recoverable() => {}
            Some(e) => return Err(anyhow!("failure is not recoverable: {e}")),
            None => return Err(anyhow!("nothing to recover from")),
        }
        let ckpt = self
            .checkpoint
            .clone()
            .ok_or_else(|| anyhow!("no checkpoint to recover from (set checkpoint_every)"))?;
        let t0 = Instant::now();
        let mut ctx = self
            .mesh_ctx
            .take()
            .ok_or_else(|| anyhow!("recovery needs the mesh-aware ClusterRun::launch"))?;
        let res = self.recover_inner(&mut ctx, &ckpt);
        self.mesh_ctx = Some(ctx);
        let mut report = res?;
        report.wall_s = t0.elapsed().as_secs_f64();
        self.rebalance_history.push(report.clone());
        Ok(report)
    }

    fn recover_inner(&mut self, ctx: &mut MeshCtx, ckpt: &Checkpoint) -> Result<RebalanceReport> {
        anyhow::ensure!(
            self.worker_of_owner.iter().enumerate().all(|(o, &w)| o == w),
            "recovery needs the standard one-owner-per-worker layout"
        );
        let total = ctx.node_part.nparts;
        anyhow::ensure!(
            self.node_active.iter().any(|&a| a),
            "no live nodes left to recover onto"
        );
        let failed_step = self.steps_taken;
        let old_counts = self.node_counts();
        let old_sizes = ctx.node_part.sizes();
        // survivors (and already-joined spares) inherit the dead node's
        // elements: uniform weighted splice over the live parts only —
        // the adaptive rebalancer re-tunes the weights from measurements
        // once the run is healthy again
        let node_part =
            splice_weighted_excluding(&vec![1.0; ctx.mesh.len()], total, &self.node_active);
        let np = nested_partition_fractions(&ctx.mesh, &node_part, &ctx.fractions);
        let new_owners = np.owners();
        let mig = owner_migration(&ctx.elem_owners, &new_owners);
        let nw = self.workers.len();
        let (new_lblocks, new_plan) = build_local_blocks(&ctx.mesh, &new_owners, nw);
        let order = self.order;
        let m = order + 1;
        let esz = NFIELDS * m * m * m;
        // the failure hit mid-step, so every live block is tainted:
        // rebuild ALL workers' blocks from the snapshot. Dead and spare
        // workers get padded empty blocks used only to index the central
        // halo priming — they are never shipped anywhere.
        let mut states: Vec<BlockState> = Vec::with_capacity(nw);
        for (w, lb) in new_lblocks.iter().enumerate() {
            if !self.workers[w].alive {
                anyhow::ensure!(
                    lb.global_ids.is_empty(),
                    "recovery plan assigns elements to dead worker {w}"
                );
            }
            let mut st =
                BlockState::from_local_block(lb, order, lb.len().max(1), lb.halo_len.max(1));
            for (li, &g) in lb.global_ids.iter().enumerate() {
                let q = ckpt
                    .q
                    .get(g)
                    .filter(|q| q.len() == esz)
                    .ok_or_else(|| anyhow!("checkpoint is missing element {g}"))?;
                st.q[li * esz..(li + 1) * esz].copy_from_slice(q);
            }
            // res is irrelevant at a step boundary (LSRK_A[0] == 0 wipes
            // it before first use) and traces are pure functions of q, so
            // this reproduces the checkpointed boundary bit-for-bit
            st.refresh_traces();
            states.push(st);
        }
        apply_exchange(&mut states, &new_plan);
        let meta: Vec<(usize, DeviceKind)> =
            self.workers.iter().map(|w| (w.node, w.device)).collect();
        let fabric = fabric_stats(&new_plan, &self.owner_map, &meta)?;
        let (mut outbound, mut self_copies, expected) =
            route_tables(&new_plan, &self.owner_map, nw);
        // nobody is blocked in the fabric any more — every live worker
        // replied to the failed stage before we got here — so the halt
        // can lift before the swap; Replace drains stale deliveries from
        // the failed stage via clear_pending
        self.ctl.clear_halt();
        let mut states: Vec<Option<BlockState>> = states.into_iter().map(Some).collect();
        let mut sent = vec![false; nw];
        for (w, wk) in self.workers.iter().enumerate() {
            if !wk.alive {
                continue;
            }
            let msg = ReplaceMsg {
                blocks: Some(vec![states[w].take().expect("state built for live worker")]),
                outbound: std::mem::take(&mut outbound[w]),
                self_copies: std::mem::take(&mut self_copies[w]),
                expected_in: expected[w],
            };
            if wk.tx.send(Cmd::Replace(Box::new(msg))).is_err() {
                self.poison();
                return Err(anyhow!("worker {w} died during recovery"));
            }
            sent[w] = true;
        }
        for (w, wk) in self.workers.iter().enumerate() {
            if !sent[w] {
                continue;
            }
            match wk.rx.recv() {
                Ok(Resp::Replaced) => {}
                Ok(Resp::Err(msg)) => {
                    self.poison();
                    return Err(anyhow!("worker {w} failed recovery: {msg}"));
                }
                _ => {
                    self.poison();
                    return Err(anyhow!("worker {w} died during recovery"));
                }
            }
        }
        for (w, wk) in self.workers.iter_mut().enumerate() {
            wk.k_elems = new_lblocks[w].len();
        }
        let new_sizes = node_part.sizes();
        let per_node = (0..total)
            .map(|nd| NodeRebalance {
                node: nd,
                old_k: old_sizes[nd],
                new_k: new_sizes[nd],
                old_k_mic: old_counts[nd].1,
                new_k_mic: np.node_counts[nd].1,
                target_fraction: ctx.fractions[nd],
                rate_s_per_elem: 0.0,
            })
            .collect();
        self.plan = new_plan;
        self.fabric = fabric;
        ctx.lblocks = new_lblocks;
        ctx.elem_owners = new_owners;
        ctx.node_part = node_part;
        let replayed = failed_step - ckpt.step;
        self.steps_taken = ckpt.step;
        self.last_error = None;
        Ok(RebalanceReport {
            level1_migrated: mig.level1,
            level2_migrated: mig.level2,
            rebuilt_workers: self.workers.iter().filter(|w| w.alive).count(),
            kept_workers: 0,
            wall_s: 0.0,
            cause: RebalanceCause::Recovery,
            replayed_steps: replayed,
            per_node,
        })
    }

    /// Bring an inactive node into the run (elastic join): a spare node
    /// announced at launch — or an explicit `Some(node)` — starts
    /// receiving elements via a fresh level-1 splice over the now-larger
    /// active set. Runs the normal live-state migration path, which is
    /// exact at step boundaries; the report lands in the history under
    /// [`RebalanceCause::Join`]. A crashed node cannot rejoin (its worker
    /// threads are gone); only never-activated spares and cleanly shed
    /// nodes qualify.
    pub fn join_node(&mut self, node: Option<usize>) -> Result<RebalanceReport> {
        anyhow::ensure!(!self.poisoned, "cluster poisoned; relaunch");
        anyhow::ensure!(self.last_error.is_none(), "recover() before joining a node");
        let nd = match node {
            Some(n) => {
                anyhow::ensure!(n < self.node_active.len(), "no such node {n}");
                anyhow::ensure!(!self.node_active[n], "node {n} is already active");
                n
            }
            None => self
                .node_active
                .iter()
                .position(|&a| !a)
                .ok_or_else(|| anyhow!("no inactive node available to join"))?,
        };
        anyhow::ensure!(
            self.workers.iter().filter(|w| w.node == nd).all(|w| w.alive),
            "node {nd}'s workers are dead; only live spares can join"
        );
        self.node_active[nd] = true;
        let res = self.rebalance_with(RebalanceCause::Join, |run, ctx| {
            let node_part = splice_weighted_excluding(
                &vec![1.0; ctx.mesh.len()],
                ctx.node_part.nparts,
                &run.node_active,
            );
            let fractions = ctx.fractions.clone();
            let old_sizes = ctx.node_part.sizes();
            let new_sizes = node_part.sizes();
            let np = nested_partition_fractions(&ctx.mesh, &node_part, &fractions);
            let per_node = (0..node_part.nparts)
                .map(|n| NodeRebalance {
                    node: n,
                    old_k: old_sizes[n],
                    new_k: new_sizes[n],
                    old_k_mic: run.workers[2 * n + 1].k_elems,
                    new_k_mic: np.node_counts[n].1,
                    target_fraction: fractions[n],
                    rate_s_per_elem: 0.0,
                })
                .collect();
            let level1_moved = node_part.assignment != ctx.node_part.assignment;
            Ok(TwoLevelPlan { node_part, fractions, np, level1_moved, per_node })
        });
        if res.is_err() {
            self.node_active[nd] = false;
        }
        res
    }

    /// Total energy across all blocks (live workers only — a dead node's
    /// elements either migrated to survivors or are lost with it).
    pub fn energy(&self) -> Result<f64> {
        for w in self.workers.iter().filter(|w| w.alive) {
            w.tx.send(Cmd::Energy).map_err(|_| anyhow!("worker died"))?;
        }
        let mut e = 0.0;
        for w in self.workers.iter().filter(|w| w.alive) {
            match w.rx.recv() {
                Ok(Resp::Energy(v)) => e += v,
                Ok(Resp::Err(m)) => return Err(anyhow!("energy failed: {m}")),
                _ => return Err(anyhow!("worker failed during energy")),
            }
        }
        Ok(e)
    }

    /// Pull back the state of one owner's block.
    pub fn read_block(&self, owner: usize) -> Result<BlockState> {
        let (w, bi) = *self
            .owner_map
            .get(&owner)
            .ok_or_else(|| anyhow!("unknown owner {owner}"))?;
        anyhow::ensure!(self.workers[w].alive, "owner {owner} lives on dead worker {w}");
        self.workers[w].tx.send(Cmd::ReadBlock(bi)).map_err(|_| anyhow!("worker died"))?;
        match self.workers[w].rx.recv() {
            Ok(Resp::Block(b)) => Ok(*b),
            Ok(Resp::Err(m)) => Err(anyhow!("read_block: {m}")),
            _ => Err(anyhow!("worker failed during read")),
        }
    }

    /// All owners, in worker order.
    pub fn owners(&self) -> Vec<usize> {
        self.workers.iter().flat_map(|w| w.owners.clone()).collect()
    }

    /// Per-worker placement summaries, in worker order.
    pub fn worker_summaries(&self) -> Vec<WorkerSummary> {
        self.workers
            .iter()
            .map(|w| WorkerSummary {
                node: w.node,
                device: w.device,
                k_elems: w.k_elems,
                label: w.label,
                alive: w.alive,
            })
            .collect()
    }

    /// Per-node realized (k_cpu, k_mic) for the standard two-workers-per-
    /// node layout of [`ClusterRun::launch`].
    pub fn node_counts(&self) -> Vec<(usize, usize)> {
        let nodes = self.workers.len() / 2;
        (0..nodes)
            .map(|nd| (self.workers[2 * nd].k_elems, self.workers[2 * nd + 1].k_elems))
            .collect()
    }

    /// Per-phase accumulated times per worker (non-destructive; safe to
    /// call repeatedly and after a failed step).
    pub fn worker_times(&self) -> Result<Vec<WorkerTimes>> {
        self.collect_times(false)
    }

    /// Per-phase accumulated times per worker, resetting the counters.
    pub fn take_worker_times(&self) -> Result<Vec<WorkerTimes>> {
        self.collect_times(true)
    }

    fn collect_times(&self, take: bool) -> Result<Vec<WorkerTimes>> {
        for w in self.workers.iter().filter(|w| w.alive) {
            let cmd = if take { Cmd::TakeTimes } else { Cmd::ReadTimes };
            w.tx.send(cmd).map_err(|_| anyhow!("worker died"))?;
        }
        let mut out = Vec::with_capacity(self.workers.len());
        // dead workers hold a zeroed slot so the 2-per-node layout every
        // consumer indexes by stays intact
        for w in &self.workers {
            if !w.alive {
                out.push(WorkerTimes::default());
                continue;
            }
            match w.rx.recv() {
                Ok(Resp::Times(t)) => out.push(t),
                Ok(Resp::Err(m)) => return Err(anyhow!("times: {m}")),
                _ => return Err(anyhow!("worker failed during times")),
            }
        }
        Ok(out)
    }

    /// Fabric traffic classification (faces per routed stage).
    pub fn fabric(&self) -> FabricStats {
        self.fabric
    }

    /// The transport every fabric lane of this run is built on.
    pub fn transport(&self) -> TransportKind {
        self.transport
    }

    /// A clone of the run's fabric poison handle. The serving layer arms
    /// per-job cancellation with it: poisoning unblocks every worker of
    /// *this* run's fabric (and only this run's) so an in-flight job can
    /// be abandoned without hanging or touching its neighbours.
    pub fn fabric_ctl(&self) -> FabricCtl {
        self.ctl.clone()
    }

    /// Routed stages so far (for cumulative traffic accounting).
    pub fn routed_stages(&self) -> usize {
        self.routed_stages
    }

    /// Bytes crossing the fabric per routed stage (all lanes).
    pub fn exchange_bytes_per_stage(&self) -> usize {
        let m = self.order + 1;
        self.plan.total_faces() * NFIELDS * m * m * 4
    }

    /// Read back element (q, res) keyed by global id — the one place that
    /// knows the per-element slicing, shared by state gathering and
    /// migration. `only` restricts the pull to a subset of owners (the
    /// incremental migration touches exactly the changed workers).
    fn pull_element_state(
        &self,
        ctx: &MeshCtx,
        only: Option<&HashSet<usize>>,
    ) -> Result<Vec<Option<(Vec<f32>, Vec<f32>)>>> {
        let m = self.order + 1;
        let esz = NFIELDS * m * m * m;
        let mut out: Vec<Option<(Vec<f32>, Vec<f32>)>> = vec![None; ctx.mesh.len()];
        for (owner, lb) in ctx.lblocks.iter().enumerate() {
            // empty owners (dead nodes, unjoined spares, zero-share MICs)
            // contribute nothing and may not even be readable
            if lb.global_ids.is_empty() || only.is_some_and(|f| !f.contains(&owner)) {
                continue;
            }
            let st = self.read_block(owner)?;
            for (li, &g) in lb.global_ids.iter().enumerate() {
                let q = st.q[li * esz..(li + 1) * esz].to_vec();
                let r = st.res[li * esz..(li + 1) * esz].to_vec();
                out[g] = Some((q, r));
            }
        }
        Ok(out)
    }

    /// Read back every element's solution in global Morton order
    /// (mesh-aware launches only): `out[g]` is element g's `(9, M, M, M)`
    /// block of q.
    pub fn gather_elements(&self) -> Result<Vec<Vec<f32>>> {
        let ctx = self
            .mesh_ctx
            .as_ref()
            .ok_or_else(|| anyhow!("gather_elements needs the mesh-aware ClusterRun::launch"))?;
        Ok(self
            .pull_element_state(ctx, None)?
            .into_iter()
            .map(|s| s.map(|(q, _)| q).unwrap_or_default())
            .collect())
    }

    /// The current level-1 node partition (mesh-aware launches only).
    pub fn node_partition(&self) -> Option<Partition> {
        self.mesh_ctx.as_ref().map(|c| c.node_part.clone())
    }

    /// The current per-node MIC fractions (mesh-aware launches only).
    pub fn mic_fractions(&self) -> Option<Vec<f64>> {
        self.mesh_ctx.as_ref().map(|c| c.fractions.clone())
    }

    /// Rebalance **both levels** of the nested partition from the window
    /// measured since the last `take_worker_times`/`rebalance` call
    /// (counters reset afterwards): level 1 re-splices the across-node
    /// chunks from measured per-element node rates (when
    /// [`ClusterRun::level1_rebalance`] is set), then level 2 re-solves
    /// each node's CPU/MIC split on its new chunk — one call settles the
    /// whole scheme ([`super::rebalance`] holds the planner).
    ///
    /// Migration is **incremental**: element state travels over the
    /// global-id path, but only workers whose element set actually changed
    /// get new blocks and backends (for the PJRT factory a rebuild is a
    /// recompile); every other worker keeps both and only its routing
    /// tables are swapped, since peers' local indices and halo slots may
    /// have moved. The run continues bit-exactly either way.
    pub fn rebalance(&mut self) -> Result<RebalanceReport> {
        self.rebalance_with(RebalanceCause::Adaptive, |run, ctx| {
            // standard layout: worker 2n = node n CPU, worker 2n+1 = node
            // n MIC (guaranteed by the mesh-aware launch)
            let times = run.take_worker_times()?;
            let counts = run.node_counts();
            Ok(plan_two_level(
                &ctx.mesh,
                &ctx.node_part,
                &ctx.fractions,
                &times,
                &counts,
                run.order,
                run.level1_rebalance,
                Some(&run.node_active),
            ))
        })
    }

    /// Apply an explicit two-level partition — `node_part` is the level-1
    /// splice, `fractions[nd]` node nd's MIC share — migrating state
    /// exactly as a measured [`ClusterRun::rebalance`] would (incremental
    /// rebuilds, history appended). Exposed so tests and tools can drive
    /// hand-picked moves.
    pub fn apply_two_level(
        &mut self,
        node_part: Partition,
        fractions: Vec<f64>,
    ) -> Result<RebalanceReport> {
        self.rebalance_with(RebalanceCause::Adaptive, move |run, ctx| {
            anyhow::ensure!(
                node_part.assignment.len() == ctx.mesh.len(),
                "partition covers {} elements, mesh has {}",
                node_part.assignment.len(),
                ctx.mesh.len()
            );
            anyhow::ensure!(
                2 * node_part.nparts == run.workers.len(),
                "partition has {} nodes, cluster runs {}",
                node_part.nparts,
                run.workers.len() / 2
            );
            anyhow::ensure!(
                fractions.len() == node_part.nparts,
                "need one MIC fraction per node"
            );
            let old_sizes = ctx.node_part.sizes();
            let new_sizes = node_part.sizes();
            let np = nested_partition_fractions(&ctx.mesh, &node_part, &fractions);
            let per_node = (0..node_part.nparts)
                .map(|nd| NodeRebalance {
                    node: nd,
                    old_k: old_sizes[nd],
                    new_k: new_sizes[nd],
                    old_k_mic: run.workers[2 * nd + 1].k_elems,
                    new_k_mic: np.node_counts[nd].1,
                    target_fraction: fractions[nd],
                    rate_s_per_elem: 0.0,
                })
                .collect();
            let level1_moved = node_part.assignment != ctx.node_part.assignment;
            Ok(TwoLevelPlan { node_part, fractions, np, level1_moved, per_node })
        })
    }

    /// Shared scaffolding of both rebalance entry points: take the mesh
    /// context, build a plan (measured or hand-picked), migrate, restore
    /// the context, stamp the wall time and append to the history.
    fn rebalance_with(
        &mut self,
        cause: RebalanceCause,
        build: impl FnOnce(&mut ClusterRun, &mut MeshCtx) -> Result<TwoLevelPlan>,
    ) -> Result<RebalanceReport> {
        let t0 = Instant::now();
        let mut ctx = self.mesh_ctx.take().ok_or_else(|| {
            anyhow!("two-level rebalancing needs the mesh-aware ClusterRun::launch")
        })?;
        let res = (|| {
            let plan = build(&mut *self, &mut ctx)?;
            self.migrate_two_level(&mut ctx, plan)
        })();
        self.mesh_ctx = Some(ctx);
        let mut report = res?;
        report.cause = cause;
        report.wall_s = t0.elapsed().as_secs_f64();
        self.rebalance_history.push(report.clone());
        Ok(report)
    }

    /// The migration executor under both rebalance entry points: pull
    /// state only from the workers whose element set changes, rebuild
    /// exactly their blocks (and backends), swap routing tables everywhere
    /// — peers' local indices and halo slots can move even when a worker's
    /// own blocks don't — and leave every unchanged worker's backend
    /// alive.
    fn migrate_two_level(
        &mut self,
        ctx: &mut MeshCtx,
        plan: TwoLevelPlan,
    ) -> Result<RebalanceReport> {
        let TwoLevelPlan { node_part, fractions, np, level1_moved: _, per_node } = plan;
        let new_owners = np.owners();
        let mig = owner_migration(&ctx.elem_owners, &new_owners);
        let nw = self.workers.len();
        let mut report = RebalanceReport {
            level1_migrated: mig.level1,
            level2_migrated: mig.level2,
            rebuilt_workers: 0,
            kept_workers: nw,
            wall_s: 0.0,
            cause: RebalanceCause::Adaptive,
            replayed_steps: 0,
            per_node,
        };
        if mig.changed_owners.is_empty() {
            ctx.node_part = node_part;
            ctx.fractions = fractions;
            return Ok(report);
        }
        // this path relies on the mesh-aware identity layout: owner o's
        // block lives alone on worker o
        anyhow::ensure!(
            self.worker_of_owner.iter().enumerate().all(|(o, &w)| o == w),
            "two-level migration needs the standard one-owner-per-worker layout"
        );
        let order = self.order;
        let m = order + 1;
        let esz = NFIELDS * m * m * m;
        let n_owners = self.worker_of_owner.len();
        let changed: HashSet<usize> = mig.changed_owners.iter().copied().collect();
        // ---- pull q/res only from the workers that lose/gain elements ---
        let mut elem_state = self.pull_element_state(ctx, Some(&changed))?;
        let (new_lblocks, new_plan) = build_local_blocks(&ctx.mesh, &new_owners, n_owners);
        // unchanged element set => bit-identical block layout: a face is a
        // halo face iff its neighbor is owned by *someone else*, so slot
        // numbering never depends on who that someone is. Kept workers'
        // correctness rests on this, so check it in release too (O(K)
        // once per rebalance, nothing on the stall path).
        anyhow::ensure!(
            (0..n_owners).filter(|o| !changed.contains(o)).all(|o| {
                new_lblocks[o].global_ids == ctx.lblocks[o].global_ids
                    && new_lblocks[o].halo_len == ctx.lblocks[o].halo_len
            }),
            "incremental migration invariant broken: an unchanged worker's \
             block layout differs under the new plan (halo-slot ordering \
             must depend only on the worker's own element set)"
        );
        // ---- rebuild blocks for the changed owners ----------------------
        let mut new_states: Vec<Option<BlockState>> = (0..n_owners).map(|_| None).collect();
        for &o in &mig.changed_owners {
            let lb = &new_lblocks[o];
            let mut st =
                BlockState::from_local_block(lb, order, lb.len().max(1), lb.halo_len.max(1));
            for (li, &g) in lb.global_ids.iter().enumerate() {
                let (q, r) = elem_state[g]
                    .take()
                    .ok_or_else(|| anyhow!("element {g} lost during migration"))?;
                st.q[li * esz..(li + 1) * esz].copy_from_slice(&q);
                st.res[li * esz..(li + 1) * esz].copy_from_slice(&r);
            }
            // traces are a pure function of q, so refreshed traces (and the
            // halos primed from them) reproduce the pre-migration values
            // bit-for-bit — the run continues exactly
            st.refresh_traces();
            new_states[o] = Some(st);
        }
        // ---- prime the rebuilt blocks' halos ----------------------------
        // sources on kept workers hold exactly the traces the last stage
        // computed (pure functions of their unmigrated q); pull those
        // blocks once each. This clones whole neighbor blocks to read a
        // few trace slices — acceptable at rebalance frequency; a
        // trace-only worker read would shrink the transfer if the stall
        // ever matters at scale.
        let mut kept_src: HashMap<usize, BlockState> = HashMap::new();
        for &o in &mig.changed_owners {
            for &(src, _, _, _) in &new_plan.copies[o] {
                if changed.contains(&src) || kept_src.contains_key(&src) {
                    continue;
                }
                let blk = self.read_block(src)?;
                kept_src.insert(src, blk);
            }
        }
        let mut installs: Vec<(usize, usize, Vec<f32>)> = Vec::new();
        for &o in &mig.changed_owners {
            for &(src, se, sf, slot) in &new_plan.copies[o] {
                let data = match new_states[src].as_ref() {
                    Some(st) => st.trace_slice(se, sf).to_vec(),
                    None => kept_src[&src].trace_slice(se, sf).to_vec(),
                };
                installs.push((o, slot, data));
            }
        }
        for (o, slot, data) in installs {
            new_states[o]
                .as_mut()
                .expect("changed owner has a rebuilt state")
                .set_halo_slot(slot, &data);
        }
        // ---- swap routing everywhere, blocks only where changed ---------
        let meta: Vec<(usize, DeviceKind)> =
            self.workers.iter().map(|w| (w.node, w.device)).collect();
        let fabric = fabric_stats(&new_plan, &self.owner_map, &meta)?;
        let (mut outbound, mut self_copies, expected) =
            route_tables(&new_plan, &self.owner_map, nw);
        report.rebuilt_workers = mig.changed_owners.len();
        report.kept_workers = nw - report.rebuilt_workers;
        let mut sent = vec![false; nw];
        for (w, wk) in self.workers.iter().enumerate() {
            if !wk.alive {
                // dead workers can't be re-plumbed; a valid plan never
                // routes anything to or from them
                anyhow::ensure!(
                    new_lblocks[w].global_ids.is_empty(),
                    "migration plan assigns elements to dead worker {w}"
                );
                continue;
            }
            let msg = ReplaceMsg {
                blocks: new_states[w].take().map(|st| vec![st]),
                outbound: std::mem::take(&mut outbound[w]),
                self_copies: std::mem::take(&mut self_copies[w]),
                expected_in: expected[w],
            };
            if wk.tx.send(Cmd::Replace(Box::new(msg))).is_err() {
                self.poison();
                return Err(anyhow!("worker {w} died during migration"));
            }
            sent[w] = true;
        }
        for (w, wk) in self.workers.iter().enumerate() {
            if !sent[w] {
                continue;
            }
            match wk.rx.recv() {
                Ok(Resp::Replaced) => {}
                Ok(Resp::Err(msg)) => {
                    self.poison();
                    return Err(anyhow!("worker {w} failed migration: {msg}"));
                }
                _ => {
                    self.poison();
                    return Err(anyhow!("worker {w} died during migration"));
                }
            }
        }
        for (w, wk) in self.workers.iter_mut().enumerate() {
            wk.k_elems = new_lblocks[w].len();
        }
        self.plan = new_plan;
        self.fabric = fabric;
        ctx.lblocks = new_lblocks;
        ctx.elem_owners = new_owners;
        ctx.node_part = node_part;
        ctx.fractions = fractions;
        Ok(report)
    }
}

impl Drop for ClusterRun {
    fn drop(&mut self) {
        // poison first: a worker blocked mid-exchange (peer died, its
        // group never came) must wake from the data plane before it can
        // see the Shutdown command
        self.ctl.poison();
        for w in &self.workers {
            let _ = w.tx.send(Cmd::Shutdown);
        }
        for w in self.workers.iter_mut() {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::unit_cube_geometry;
    use crate::solver::analytic::standing_wave;

    fn wave_ic(x: [f64; 3]) -> [f64; 9] {
        let w = std::f64::consts::PI * 3f64.sqrt();
        standing_wave(x, 0.0, 1.0, 1.0, w)
    }

    #[test]
    fn two_node_cluster_runs_and_decays() {
        let mesh = unit_cube_geometry(4);
        let mut spec = ClusterSpec::new(2, 2);
        spec.mic_fraction = Some(0.2);
        let mut run = ClusterRun::launch(&mesh, &spec, wave_ic).unwrap();
        let e0 = run.energy().unwrap();
        run.run(1e-3, 3).unwrap();
        let e1 = run.energy().unwrap();
        assert!(e1.is_finite() && e1 > 0.0);
        assert!(e1 <= e0 * (1.0 + 1e-6), "{e0} -> {e1}");
        // two nodes must exchange over the inter-node lane, CPU-only
        let f = run.fabric();
        assert!(f.inter_node_faces > 0, "{f:?}");
        assert_eq!(f.mic_inter_node_faces, 0);
        assert_eq!(run.routed_stages(), 3 * N_STAGES);
    }

    #[test]
    fn per_phase_times_accumulate_and_reset() {
        let mesh = unit_cube_geometry(4);
        let mut spec = ClusterSpec::new(1, 2);
        spec.mic_fraction = Some(0.3);
        let mut run = ClusterRun::launch(&mesh, &spec, wave_ic).unwrap();
        run.run(1e-3, 2).unwrap();
        let t = run.worker_times().unwrap();
        assert_eq!(t.len(), 2);
        assert!(t[0].busy_s() > 0.0 && t[1].busy_s() > 0.0);
        assert_eq!(t[0].stages, 2 * N_STAGES);
        // non-destructive read, then a destructive take, then empty
        let t2 = run.worker_times().unwrap();
        assert_eq!(t2[0].stages, 2 * N_STAGES);
        let t3 = run.take_worker_times().unwrap();
        assert_eq!(t3[0].stages, 2 * N_STAGES);
        let t4 = run.worker_times().unwrap();
        assert_eq!(t4[0].stages, 0);
        assert_eq!(t4[0].busy_s(), 0.0);
    }

    #[test]
    fn rebalance_without_measurement_is_noop() {
        let mesh = unit_cube_geometry(4);
        let mut spec = ClusterSpec::new(1, 1);
        spec.mic_fraction = Some(0.1);
        let mut run = ClusterRun::launch(&mesh, &spec, wave_ic).unwrap();
        // no steps taken: nothing measured, split must not move
        let rep = run.rebalance().unwrap();
        assert_eq!(rep.migrated_elems(), 0);
        assert_eq!(rep.rebuilt_workers, 0);
        assert_eq!(rep.kept_workers, 2);
        assert_eq!(run.rebalance_history.len(), 1);
    }

    #[test]
    fn node_counts_sum_to_mesh() {
        let mesh = unit_cube_geometry(4);
        let mut spec = ClusterSpec::new(2, 1);
        spec.mic_fraction = Some(0.25);
        let run = ClusterRun::launch(&mesh, &spec, wave_ic).unwrap();
        let total: usize = run.node_counts().iter().map(|&(c, m)| c + m).sum();
        assert_eq!(total, mesh.len());
    }

    /// The historical delivery race, forced deterministically: a fast
    /// peer's delivery group arrives *before* this worker's Stage
    /// command. The old fabric carried deliveries on the command channel
    /// (buffered in a `pending` vec whose draining was easy to get
    /// wrong); they now queue in the data plane, so an early group must
    /// simply be waiting when the exchange window opens. The "peer" here
    /// is the test thread holding worker 1's endpoint, which ships its
    /// group and only then sends Stage — on every transport.
    #[test]
    fn early_deliveries_queue_in_the_data_plane() {
        for kind in [TransportKind::InProc, TransportKind::Shm, TransportKind::Socket] {
            early_delivery_roundtrip(kind);
        }
    }

    fn early_delivery_roundtrip(kind: TransportKind) {
        let order = 1usize;
        let m = order + 1;
        let mesh = unit_cube_geometry(2);
        // two single-block workers on *different* nodes, so the socket
        // transport exercises its stream lane
        let half = mesh.len() / 2;
        let elem_owners: Vec<usize> = (0..mesh.len()).map(|e| usize::from(e >= half)).collect();
        let (lblocks, plan) = build_local_blocks(&mesh, &elem_owners, 2);
        let basis = LglBasis::new(order);
        let mut states: Vec<BlockState> = lblocks
            .iter()
            .map(|lb| {
                let mut st =
                    BlockState::from_local_block(lb, order, lb.len().max(1), lb.halo_len.max(1));
                st.set_initial_condition(&basis, &wave_ic);
                st
            })
            .collect();
        for s in states.iter_mut() {
            s.refresh_traces();
        }
        apply_exchange(&mut states, &plan);
        let owner_map: HashMap<usize, (usize, usize)> =
            [(0, (0, 0)), (1, (1, 0))].into_iter().collect();
        let (mut outbound, mut self_copies, expected) = route_tables(&plan, &owner_map, 2);
        assert_eq!(expected[0], 1, "worker 1 must feed worker 0");
        assert_eq!(outbound[1].len(), 1, "worker 1 has exactly one peer");
        let ctl = FabricCtl::new();
        let mut eps =
            build_endpoints(kind, &[0, 1], NFIELDS * m * m, &ctl).unwrap().into_iter();
        let ep0 = eps.next().unwrap();
        let mut ep1 = eps.next().unwrap();
        let peer_blocks = vec![states[1].clone()];
        let (ctx, crx) = channel::<Cmd>();
        let (rtx, rrx) = channel::<Resp>();
        let init = WorkerInit {
            rx: crx,
            tx: rtx,
            endpoint: Box::new(ep0),
            ctl: ctl.clone(),
            blocks: vec![states.swap_remove(0)],
            outbound: std::mem::take(&mut outbound[0]),
            self_copies: std::mem::take(&mut self_copies[0]),
            expected_in: expected[0],
            factory: WorkerBackend::RustRef.factory(1, None),
            order,
        };
        let handle = std::thread::spawn(move || worker_main(init));
        match rrx.recv().unwrap() {
            Resp::Ready => {}
            Resp::Err(e) => panic!("worker not ready on {kind}: {e}"),
            _ => panic!("unexpected startup response on {kind}"),
        }
        // the race, forced: the peer's group is in the data plane before
        // the worker has even been told to stage
        let grp = &outbound[1][0];
        assert_eq!(grp.dst, 0);
        ep1.ship(0, &grp.items, &peer_blocks, false).unwrap();
        ctx.send(Cmd::Stage { dt: 1e-3, a: LSRK_A[0] as f32, b: LSRK_B[0] as f32, route: true })
            .unwrap();
        match rrx.recv().unwrap() {
            Resp::StageDone { .. } => {}
            Resp::Err(e) => panic!("stage failed on {kind}: {e}"),
            _ => panic!("unexpected stage response on {kind}"),
        }
        // the staged worker must have installed the early group: its halo
        // slots hold exactly the traces the peer shipped
        ctx.send(Cmd::ReadBlock(0)).unwrap();
        let got = match rrx.recv().unwrap() {
            Resp::Block(b) => *b,
            _ => panic!("unexpected read response on {kind}"),
        };
        let sz = NFIELDS * m * m;
        for &(bs, se, sf, _bd, slot) in &grp.items {
            let want = peer_blocks[bs].trace_slice(se, sf);
            let have = &got.halo[slot * sz..(slot + 1) * sz];
            assert_eq!(have, want, "halo slot {slot} mismatch on {kind}");
        }
        ctx.send(Cmd::Shutdown).unwrap();
        handle.join().unwrap();
    }
}
