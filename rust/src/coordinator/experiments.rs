//! Experiment drivers: one per table/figure of the paper's evaluation.
//!
//! Each driver prints the same rows/series the paper reports and writes a
//! CSV under `results/`. Absolute times come from the calibrated simulator
//! (DESIGN.md §Hardware substitution); the *shapes* — who wins, by what
//! factor, where the crossover falls — are the reproduction targets, and
//! EXPERIMENTS.md records paper-vs-measured for each.

use crate::costmodel::calib::{
    self, PAPER_ELEMS_PER_NODE, PAPER_ORDER, PAPER_STEPS,
};
use crate::costmodel::pci::Direction;
use crate::mesh::geometry::{discontinuous_brick, sweep_dims};
use crate::mesh::Mesh;
use crate::partition::{nested_partition, partition_stats, solve_mic_fraction, splice};
use crate::sim::{simulate, Cluster, Scheme};
use crate::util::bench::JsonSink;
use crate::Result;

use super::report::{render_table, write_csv};

/// Global brick with `elems_per_node * nodes` elements, near-cubic chunks
/// of 8192 = 32x16x16 per node stacked along a 3-D node grid.
pub fn paper_mesh(nodes: usize, elems_per_node: usize) -> Mesh {
    let (dims, extent) = sweep_dims(nodes, elems_per_node);
    discontinuous_brick(dims, extent)
}

/// Fig 4.1 — baseline MPI-only kernel breakdown at 1, 8, 64 nodes.
pub fn fig4_1(out_csv: Option<&str>) -> Result<String> {
    let mut sections = String::new();
    let mut csv_rows = Vec::new();
    for nodes in [1usize, 8, 64] {
        let mesh = paper_mesh(nodes, PAPER_ELEMS_PER_NODE);
        let cluster = Cluster::stampede(nodes);
        let rep = simulate(
            &cluster, &mesh, PAPER_ORDER, PAPER_STEPS,
            Scheme::BaselineMpi { ranks_per_node: 8 },
        );
        let prof = super::profile::ProfileReport::from_breakdown(&rep.breakdown);
        sections.push_str(&prof.render(&format!(
            "Fig 4.1 — baseline profile, {nodes} node(s), {} MPI ranks, wall {:.0} s",
            nodes * 8,
            rep.wall_s
        )));
        sections.push('\n');
        for (k, s, f) in prof.fractions() {
            csv_rows.push(vec![
                nodes.to_string(),
                k.to_string(),
                format!("{s:.4}"),
                format!("{:.4}", f),
            ]);
        }
    }
    if let Some(p) = out_csv {
        write_csv(p, &["nodes", "kernel", "seconds", "fraction"], &csv_rows)?;
    }
    Ok(sections)
}

/// Fig 5.2 — estimated CPU and MIC runtimes vs MIC load fraction; the
/// crossover is the optimal work split.
pub fn fig5_2(out_csv: Option<&str>) -> Result<String> {
    let node = calib::stampede_node();
    let rows = crate::partition::balance::sweep_fractions(
        &node, PAPER_ORDER, PAPER_ELEMS_PER_NODE, 40,
    );
    let sol = solve_mic_fraction(&node, PAPER_ORDER, PAPER_ELEMS_PER_NODE);
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|(f, tc, tm)| {
            vec![format!("{f:.3}"), format!("{tc:.4}"), format!("{tm:.4}")]
        })
        .collect();
    if let Some(p) = out_csv {
        write_csv(p, &["mic_fraction", "t_cpu_s", "t_mic_s"], &table)?;
    }
    let mut s = render_table(&["mic_fraction", "t_cpu_s", "t_mic_s"], &table);
    s.push_str(&format!(
        "\ncrossover: K_MIC = {} K_CPU = {}  ->  K_MIC/K_CPU = {:.2}  (paper: 1.6)\n\
         predicted step times at optimum: cpu {:.4} s, mic {:.4} s\n",
        sol.k_mic, sol.k_cpu, sol.ratio, sol.t_cpu_s, sol.t_mic_s
    ));
    Ok(s)
}

/// Fig 5.3 — CPU<->MIC transfer time vs size (1..4096 MB), mean +/- sigma
/// from the jittered PCI model, both directions.
pub fn fig5_3(out_csv: Option<&str>, samples: usize) -> Result<String> {
    let pci = calib::stampede_pci();
    let mut rows = Vec::new();
    let mut mb = 1usize;
    while mb <= 4096 {
        for (dir, label) in
            [(Direction::ToDevice, "to_mic"), (Direction::FromDevice, "from_mic")]
        {
            let bytes = mb << 20;
            let vals: Vec<f64> =
                (0..samples as u64).map(|i| pci.sample(bytes, dir, i * 7919)).collect();
            let mean = vals.iter().sum::<f64>() / vals.len() as f64;
            let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
                / vals.len() as f64;
            rows.push(vec![
                mb.to_string(),
                label.to_string(),
                format!("{mean:.5}"),
                format!("{:.5}", var.sqrt()),
            ]);
        }
        mb *= 2;
    }
    if let Some(p) = out_csv {
        write_csv(p, &["mb", "direction", "mean_s", "sigma_s"], &rows)?;
    }
    Ok(render_table(&["MB", "direction", "mean_s", "sigma_s"], &rows))
}

/// Fig 5.4 — the nested partition itself: per-node interior (MIC)
/// subdomains + an ASCII slice. Runs on a reduced mesh for legibility.
pub fn fig5_4(out_csv: Option<&str>) -> Result<String> {
    let n = 16usize;
    let mesh = discontinuous_brick([n, n, n], [1.0, 1.0, 1.0]);
    let nodes = 4;
    let node_part = splice(&mesh, nodes);
    let node_model = calib::stampede_node();
    let sol = solve_mic_fraction(&node_model, PAPER_ORDER, mesh.len() / nodes);
    let frac = sol.k_mic as f64 / (mesh.len() / nodes) as f64;
    let np = nested_partition(&mesh, &node_part, frac);
    let st = partition_stats(&mesh, &np);

    let mut rows = Vec::new();
    for (nd, s) in st.per_node.iter().enumerate() {
        rows.push(vec![
            nd.to_string(),
            s.k_cpu.to_string(),
            s.k_mic.to_string(),
            format!("{:.2}", s.k_mic as f64 / s.k_cpu.max(1) as f64),
            s.pci_faces.to_string(),
            s.mpi_faces.to_string(),
        ]);
    }
    if let Some(p) = out_csv {
        write_csv(p, &["node", "k_cpu", "k_mic", "ratio", "pci_faces", "mpi_faces"], &rows)?;
    }
    let mut out = render_table(
        &["node", "k_cpu", "k_mic", "ratio", "pci_faces", "mpi_faces"],
        &rows,
    );
    // ASCII mid-slice: node digit for CPU elements, '#' for MIC elements
    out.push_str("\nmid-plane slice (z = n/2): digits = node id (CPU), '*' = offloaded to MIC\n");
    let mut grid = vec![vec![' '; n]; n];
    for (e, elem) in mesh.elements.iter().enumerate() {
        let ix = (elem.center[0] * n as f64).floor() as usize;
        let iy = (elem.center[1] * n as f64).floor() as usize;
        let iz = (elem.center[2] * n as f64).floor() as usize;
        if iz == n / 2 {
            grid[iy][ix] = if np.device[e] == crate::partition::DeviceKind::Mic {
                '*'
            } else {
                char::from_digit(np.node.assignment[e] as u32 % 10, 10).unwrap()
            };
        }
    }
    for row in grid.iter().rev() {
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    Ok(out)
}

/// Table 6.1 — end-to-end wall time, baseline vs optimized, 1 & 64 nodes
/// (plus the task-offload strawman as an extra row).
pub fn table6_1(out_csv: Option<&str>, steps: usize) -> Result<String> {
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for nodes in [1usize, 64] {
        let mesh = paper_mesh(nodes, PAPER_ELEMS_PER_NODE);
        let cluster = Cluster::stampede(nodes);
        let base = simulate(
            &cluster, &mesh, PAPER_ORDER, steps, Scheme::BaselineMpi { ranks_per_node: 8 },
        );
        let nest = simulate(&cluster, &mesh, PAPER_ORDER, steps, Scheme::Nested {
            mic_fraction: None,
        });
        let off = simulate(&cluster, &mesh, PAPER_ORDER, steps, Scheme::TaskOffload);
        let scale = PAPER_STEPS as f64 / steps as f64; // report at paper steps
        let speedup = base.wall_s / nest.wall_s;
        rows.push(vec![
            nodes.to_string(),
            format!("{:.0}", base.wall_s * scale),
            format!("{:.0}", nest.wall_s * scale),
            format!("{speedup:.1}x"),
            format!("{:.0}", off.wall_s * scale),
        ]);
        csv.push(vec![
            nodes.to_string(),
            format!("{}", base.wall_s * scale),
            format!("{}", nest.wall_s * scale),
            format!("{speedup}"),
            format!("{}", off.wall_s * scale),
        ]);
    }
    if let Some(p) = out_csv {
        write_csv(
            p,
            &["nodes", "baseline_s", "optimized_s", "speedup", "task_offload_s"],
            &csv,
        )?;
    }
    let mut s = render_table(
        &["nodes", "baseline (s)", "optimized (s)", "speedup", "task-offload (s)"],
        &rows,
    );
    s.push_str("\npaper: 1 node 408 -> 65 s (6.3x); 64 nodes 413 -> 74 s (5.6x)\n");
    Ok(s)
}

/// Fig 6.2 — single-node per-kernel comparison: baseline vs optimized-CPU
/// vs MIC (time per step for the device's share of the paper workload).
pub fn fig6_2(out_csv: Option<&str>) -> Result<String> {
    let node = calib::stampede_node();
    let n = PAPER_ORDER;
    let k = PAPER_ELEMS_PER_NODE;
    let sol = solve_mic_fraction(&node, n, k);
    // counts per device at the operating point
    let int_faces = 3 * k;
    let bound = (6.0 * (k as f64).powf(2.0 / 3.0)) as usize;
    let pci = crate::partition::balance::mic_surface_faces(sol.k_mic as f64) as usize;
    let mut rows = Vec::new();
    for kern in crate::costmodel::kernels::ALL_KERNELS {
        let count_of = |dev_k: usize, dev_int: usize, dev_bound: usize, dev_par: usize| {
            if kern.is_volume_kernel() {
                match kern {
                    crate::costmodel::PaperKernel::IntFlux => dev_int,
                    _ => dev_k,
                }
            } else {
                match kern {
                    crate::costmodel::PaperKernel::BoundFlux => dev_bound,
                    _ => dev_par,
                }
            }
        };
        let base_t = node.cpu_scalar.time(kern, n, count_of(k, int_faces, bound, 2500));
        let cpu_t = node.cpu_vec.time(
            kern, n,
            count_of(sol.k_cpu, 3 * sol.k_cpu, bound, pci),
        );
        let mic_t = node.mic.time(kern, n, count_of(sol.k_mic, 3 * sol.k_mic, 0, pci));
        // per-kernel speedup = achieved-rate ratio (the devices process
        // different element shares, so wall times are not comparable)
        let cpu_speedup = node.cpu_vec.rate(kern) / node.cpu_scalar.rate(kern);
        rows.push(vec![
            kern.name().to_string(),
            format!("{:.4}", base_t),
            format!("{:.4}", cpu_t),
            format!("{:.4}", mic_t),
            format!("{cpu_speedup:.1}x"),
        ]);
    }
    if let Some(p) = out_csv {
        write_csv(
            p,
            &["kernel", "baseline_s_per_step", "cpu_opt_s_per_step", "mic_s_per_step", "cpu_speedup"],
            &rows,
        )?;
    }
    let mut s = render_table(
        &["kernel", "baseline s/step", "CPU-opt s/step", "MIC s/step", "CPU speedup"],
        &rows,
    );
    s.push_str(
        "\npaper anchors: volume_loop 2x, int_flux 5x (CPU-opt vs baseline); \
         MIC faster than CPU-opt on all kernels except parallel_flux\n",
    );
    Ok(s)
}

/// Extension beyond the paper — the live-vs-simulated **cross-check**,
/// closing the loop between the two execution paths this repo has: run the
/// same nested configuration through the in-process N-node cluster runtime
/// ([`crate::coordinator::cluster`]) and through the discrete-event
/// simulator — the latter with its node model *refitted from the live
/// run's measured kernel times* (`Cluster::custom` +
/// `calib::measured_node`) and priced on the **same level-1 partition**
/// the live run executes ([`crate::sim::simulate_parts`]), so the
/// comparison survives adaptive two-level rebalancing
/// (`rebalance_every`: warm-up steps with the rebalancer live, then a
/// frozen measurement window). Reports the per-step discrepancy plus the
/// **per-kernel** live-over-sim drift — the series that localizes where
/// the calibrated functional forms break down — optionally emitted into a
/// [`JsonSink`] (`BENCH_cluster.json`). `transport` picks the live run's
/// message fabric ([`crate::coordinator::TransportKind`]); the simulator
/// side keeps the Stampede-calibrated network model, so the discrepancy
/// column also exposes how much a slower fabric costs.
#[allow(clippy::too_many_arguments)]
pub fn cross_check(
    nodes: usize,
    n: usize,
    order: usize,
    steps: usize,
    rebalance_every: Option<usize>,
    transport: crate::coordinator::TransportKind,
    out_csv: Option<&str>,
    mut sink: Option<&mut JsonSink>,
) -> Result<String> {
    use crate::coordinator::cluster::{ClusterRun, ClusterSpec};
    use crate::sim::simulate_parts;
    use crate::solver::analytic::standing_wave;
    use crate::solver::reference::KernelTimes;

    let nodes = nodes.max(1);
    let steps = steps.max(1);
    let mesh = discontinuous_brick([n, n, n], [1.0, 1.0, 1.0]);
    let mut spec = ClusterSpec::new(nodes, order);
    spec.mic_fraction = Some(0.3);
    spec.rebalance_every = rebalance_every;
    spec.transport = transport;
    let w = std::f64::consts::PI * 3f64.sqrt();
    let mut run = ClusterRun::launch(&mesh, &spec, |x| standing_wave(x, 0.0, 1.0, 1.0, w))?;
    if rebalance_every.is_some() {
        // warm-up: let the two-level rebalancer move the partition, then
        // freeze it so the measurement window prices one fixed partition —
        // the same one handed to the simulator below
        run.run(1e-3, steps)?;
        run.rebalance_every = None;
        let _ = run.take_worker_times()?;
    }
    let t0 = std::time::Instant::now();
    run.run(1e-3, steps)?;
    let live_wall = t0.elapsed().as_secs_f64();
    let times = run.take_worker_times()?;
    let counts = run.node_counts();
    // aggregate to one average node: summed kernel seconds over nodes with
    // per-node average counts and nodes x steps measured timesteps keeps
    // the refitted rates exact
    let mut cpu_k = KernelTimes::default();
    let mut mic_k = KernelTimes::default();
    let (mut k_cpu, mut k_mic) = (0usize, 0usize);
    let mut live_cpu_busy = 0.0;
    let mut live_mic_busy = 0.0;
    for (nd, &(kc, km)) in counts.iter().enumerate() {
        // wall-rescaled so thread-parallel backends fit correctly
        cpu_k.accumulate(&times[2 * nd].wall_kernels());
        mic_k.accumulate(&times[2 * nd + 1].wall_kernels());
        k_cpu += kc;
        k_mic += km;
        let bc = times[2 * nd].busy_per_step();
        let bm = times[2 * nd + 1].busy_per_step();
        let span = bc.max(bm).max(1e-300);
        live_cpu_busy += bc / span / nodes as f64;
        live_mic_busy += bm / span / nodes as f64;
    }
    let steps_meas = times[0].steps() * nodes as f64;
    let model = calib::measured_node(
        order,
        (k_cpu / nodes).max(1),
        k_mic / nodes,
        steps_meas,
        &cpu_k,
        &mic_k,
    );
    // the simulator prices the live run's actual two-level partition:
    // its (possibly re-spliced) level-1 chunks + per-node realized shares
    let node_part = run.node_partition().expect("mesh-aware launch");
    let fracs: Vec<f64> =
        counts.iter().map(|&(kc, km)| km as f64 / (kc + km).max(1) as f64).collect();
    let cluster_model = Cluster::custom(nodes, model, calib::fabric_network());
    let rep = simulate_parts(
        &cluster_model, &mesh, &node_part, Some(&fracs), order, steps,
        Scheme::Nested { mic_fraction: None },
    );
    let live_per_step = live_wall / steps as f64;
    let drift = rep.discrepancy(live_wall);
    let headers = [
        "nodes", "live_s_per_step", "sim_s_per_step", "live_over_sim",
        "live_cpu_busy", "sim_cpu_busy", "live_mic_busy", "sim_mic_busy",
    ];
    let rows = vec![vec![
        nodes.to_string(),
        format!("{live_per_step:.5}"),
        format!("{:.5}", rep.per_step_s()),
        format!("{drift:.2}"),
        format!("{live_cpu_busy:.2}"),
        format!("{:.2}", rep.cpu_busy_frac),
        format!("{live_mic_busy:.2}"),
        format!("{:.2}", rep.mic_busy_frac),
    ]];
    if let Some(s) = &mut sink {
        s.push_scalar("cross_check_live_over_sim", drift, "live_over_sim");
    }
    // per-kernel drift: live kernel seconds (wall-rescaled, summed over
    // workers) vs the simulator's breakdown, both per node-step
    let mut live_total = KernelTimes::default();
    live_total.accumulate(&cpu_k);
    live_total.accumulate(&mic_k);
    let mut krows = Vec::new();
    let mut kcsv = Vec::new();
    for (name, live_s) in live_total.rows() {
        let live_ps = live_s / steps_meas.max(1e-300);
        let sim_ps = rep.breakdown.kernel_seconds(name) / (steps * nodes) as f64;
        let ratio = if sim_ps > 1e-300 { live_ps / sim_ps } else { f64::INFINITY };
        if let Some(s) = &mut sink {
            // 0.0 = "no sim prediction to compare against" (keeps the JSON
            // finite; the text table still shows inf)
            let finite = if ratio.is_finite() { ratio } else { 0.0 };
            s.push_scalar(&format!("cross_check_drift_{name}"), finite, "live_over_sim");
        }
        krows.push(vec![
            name.to_string(),
            format!("{live_ps:.3e}"),
            format!("{sim_ps:.3e}"),
            format!("{ratio:.2}"),
        ]);
        kcsv.push(vec![
            name.to_string(),
            format!("{live_ps}"),
            format!("{sim_ps}"),
            format!("{ratio}"),
        ]);
    }
    let kheaders = ["kernel", "live_s_per_node_step", "sim_s_per_node_step", "live_over_sim"];
    if let Some(p) = out_csv {
        write_csv(p, &headers, &rows)?;
        let kpath = format!("{}_kernels.csv", p.trim_end_matches(".csv"));
        write_csv(&kpath, &kheaders, &kcsv)?;
    }
    let mut s = render_table(&headers, &rows);
    s.push('\n');
    s.push_str(&render_table(&kheaders, &krows));
    s.push_str(
        "\nlive = in-process cluster runtime; sim = event simulator with the node \
         model refitted from the live run's measured kernel times, priced on the \
         live run's level-1 partition\n",
    );
    Ok(s)
}

/// Extension beyond the paper: weak-scaling sweep 1..256 nodes for all
/// four schemes (baseline, task-offload, nested, nested+overlapped-PCI),
/// reporting parallel efficiency relative to each scheme's 1-node time.
pub fn weak_scaling(out_csv: Option<&str>, steps: usize) -> Result<String> {
    let mut rows = Vec::new();
    let mut t1: Vec<f64> = Vec::new();
    let schemes = [
        Scheme::BaselineMpi { ranks_per_node: 8 },
        Scheme::TaskOffload,
        Scheme::Nested { mic_fraction: None },
        Scheme::NestedOverlap { mic_fraction: None },
    ];
    for nodes in [1usize, 4, 16, 64, 256] {
        let mesh = paper_mesh(nodes, PAPER_ELEMS_PER_NODE);
        let cluster = Cluster::stampede(nodes);
        let mut row = vec![nodes.to_string()];
        for (i, sc) in schemes.iter().enumerate() {
            let rep = simulate(&cluster, &mesh, PAPER_ORDER, steps, *sc);
            if nodes == 1 {
                t1.push(rep.wall_s);
            }
            let eff = t1[i] / rep.wall_s;
            row.push(format!("{:.2}", rep.wall_s));
            row.push(format!("{:.2}", eff));
        }
        rows.push(row);
    }
    let headers = [
        "nodes",
        "baseline_s", "eff",
        "offload_s", "eff",
        "nested_s", "eff",
        "nested_overlap_s", "eff",
    ];
    if let Some(p) = out_csv {
        write_csv(p, &headers, &rows)?;
    }
    let mut s = render_table(&headers, &rows);
    s.push_str(
        "\nweak scaling at constant 8192 elem/node (eff = t(1)/t(P)); the\n\
         overlapped-PCI variant is this repo's extension of the paper's scheme\n",
    );
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weak_scaling_runs_and_overlap_wins() {
        let s = weak_scaling(None, 3).unwrap();
        assert!(s.contains("weak scaling"));
        // overlapped PCI must not be slower than plain nested at 1 node
        let first_row: Vec<&str> = s
            .lines()
            .nth(2)
            .unwrap()
            .split_whitespace()
            .collect();
        let nested: f64 = first_row[5].parse().unwrap();
        let overlap: f64 = first_row[7].parse().unwrap();
        assert!(overlap <= nested * 1.001, "overlap {overlap} nested {nested}");
    }

    #[test]
    fn fig5_2_crossover_near_paper() {
        let s = fig5_2(None).unwrap();
        assert!(s.contains("crossover"));
    }

    #[test]
    fn table6_1_speedups_in_band() {
        let s = table6_1(None, 6).unwrap();
        // extract speedups: both rows must be in the 5-8x band
        for line in s.lines().skip(2).take(2) {
            let sp: f64 = line
                .split_whitespace()
                .find(|t| t.ends_with('x'))
                .and_then(|t| t.trim_end_matches('x').parse().ok())
                .unwrap();
            assert!((4.5..8.5).contains(&sp), "speedup {sp} out of band: {line}");
        }
    }

    #[test]
    fn fig4_1_volume_dominates() {
        let s = fig4_1(None).unwrap();
        let first_data_line = s
            .lines()
            .find(|l| l.contains('%') && !l.contains("share"))
            .unwrap();
        assert!(first_data_line.contains("volume_loop"), "{first_data_line}");
    }

    #[test]
    fn cross_check_live_vs_sim_runs() {
        let s = cross_check(2, 4, 2, 3, None, Default::default(), None, None).unwrap();
        assert!(s.contains("live_over_sim"), "{s}");
        assert!(s.contains("refitted"), "{s}");
        // per-kernel drift rows are part of the report
        assert!(s.contains("volume_loop"), "{s}");
    }

    #[test]
    fn cross_check_adaptive_emits_kernel_drift() {
        let mut sink = JsonSink::new();
        let s =
            cross_check(2, 4, 2, 2, Some(2), Default::default(), None, Some(&mut sink)).unwrap();
        assert!(s.contains("live_over_sim"), "{s}");
        let dump = sink.dump();
        assert!(dump.contains("cross_check_live_over_sim"), "{dump}");
        assert!(dump.contains("cross_check_drift_volume_loop"), "{dump}");
        assert!(dump.contains("cross_check_drift_parallel_flux"), "{dump}");
    }

    #[test]
    fn fig5_4_renders_slice() {
        let s = fig5_4(None).unwrap();
        assert!(s.contains('*'), "MIC interior must appear in the slice:\n{s}");
    }
}
