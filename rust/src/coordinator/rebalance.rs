//! The two-level rebalancing planner (paper §5.5–§5.6, closed over
//! measured time).
//!
//! One [`plan_two_level`] call settles *both* levels of the nested
//! partition from a window of measured [`WorkerTimes`]:
//!
//! * **Level 1** — each node's measured per-element rate
//!   ([`calib::measured_elem_rate`] over the node's slower worker) becomes
//!   the weight its elements carry into
//!   [`crate::partition::splice_weighted`], so the re-splice moves the
//!   across-node chunk boundaries toward the equal-time point — mangll's
//!   weighted level-1 splice (§5.5), driven by live data instead of static
//!   element weights. Because the weight rides on the element while the
//!   cost lives on the node, one re-splice is a *damped* step; iterated
//!   every R steps it converges geometrically. The candidate splice is
//!   adopted only if it improves the predicted slowest-node time by >1%,
//!   which keeps measurement noise from ping-ponging the boundaries.
//! * **Level 2** — per node, the measured kernel profile is refit into a
//!   node model ([`calib::measured_node`]) and
//!   [`solve_mic_fraction`] re-solves the CPU/MIC split on the node's
//!   *new* chunk size. A ±1-element dead-band suppresses churn when the
//!   solve lands where the split already is (a rebuild can be a PJRT
//!   recompile — not worth one element).
//!
//! The planner is pure — mesh + partitions + times in, a [`TwoLevelPlan`]
//! out — so it unit-tests without worker threads; the migration executor
//! lives in [`crate::coordinator::cluster`] ([`ClusterRun::rebalance`]
//! measures, plans, then applies the plan incrementally).
//!
//! [`ClusterRun::rebalance`]: crate::coordinator::cluster::ClusterRun::rebalance

use crate::costmodel::calib;
use crate::mesh::Mesh;
use crate::partition::{
    nested_partition_fractions, solve_mic_fraction, splice_weighted, splice_weighted_excluding,
    NestedPartition, Partition,
};

use super::cluster::WorkerTimes;

/// Why a rebalance happened — adaptive load-chasing, or one of the
/// membership events of the fault-tolerant runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RebalanceCause {
    /// The periodic measured-window rebalance (the original path).
    #[default]
    Adaptive,
    /// A node died: its chunk was re-spliced across survivors and state
    /// was restored from the last checkpoint.
    Recovery,
    /// A node joined: the splice shed elements onto it from live state.
    Join,
}

impl RebalanceCause {
    pub fn label(self) -> &'static str {
        match self {
            RebalanceCause::Adaptive => "adaptive",
            RebalanceCause::Recovery => "recovery",
            RebalanceCause::Join => "join",
        }
    }
}

/// One node's row of a [`RebalanceReport`].
#[derive(Debug, Clone, Copy)]
pub struct NodeRebalance {
    pub node: usize,
    /// Level-1 chunk size before/after the re-splice.
    pub old_k: usize,
    pub new_k: usize,
    /// Level-2 accelerator share before/after.
    pub old_k_mic: usize,
    pub new_k_mic: usize,
    /// The solved (pre-clipping) MIC fraction of the new chunk.
    pub target_fraction: f64,
    /// Measured busy seconds per element per step (0.0 until measured).
    pub rate_s_per_elem: f64,
}

/// What one [`ClusterRun::rebalance`] (or explicit
/// [`ClusterRun::apply_two_level`]) call did, broken out by level.
///
/// [`ClusterRun::rebalance`]: crate::coordinator::cluster::ClusterRun::rebalance
/// [`ClusterRun::apply_two_level`]: crate::coordinator::cluster::ClusterRun::apply_two_level
#[derive(Debug, Clone, Default)]
pub struct RebalanceReport {
    /// Elements that moved between nodes (level-1 splice boundary).
    pub level1_migrated: usize,
    /// Elements that switched device within their node (level 2).
    pub level2_migrated: usize,
    /// Workers whose block shape changed: blocks *and* backends rebuilt.
    pub rebuilt_workers: usize,
    /// Workers untouched: blocks, backends (and any PJRT compilation)
    /// kept alive; only their routing tables were swapped.
    pub kept_workers: usize,
    /// Wall seconds of the whole rebalance call (plan + migration +
    /// rebuilds) — the stall the incremental path minimizes. For a
    /// `Recovery` this is the recovery wall time: detection handoff,
    /// re-splice, checkpoint restore and worker rebuilds.
    pub wall_s: f64,
    /// What triggered this rebalance.
    pub cause: RebalanceCause,
    /// Steps lost to the checkpoint rewind (`Recovery` only): the run
    /// re-executes `steps_at_failure - checkpoint_step` steps.
    pub replayed_steps: usize,
    pub per_node: Vec<NodeRebalance>,
}

impl RebalanceReport {
    /// Total elements that changed workers (0 = the split was optimal).
    pub fn migrated_elems(&self) -> usize {
        self.level1_migrated + self.level2_migrated
    }
}

/// Totals over a sequence of rebalance calls — the CLI summary line and
/// the bench's `cluster_rebalance_*` scalars both read these, so they can
/// never disagree on the aggregation.
#[derive(Debug, Clone, Copy, Default)]
pub struct RebalanceTotals {
    pub calls: usize,
    pub level1_migrated: usize,
    pub level2_migrated: usize,
    pub rebuilt_workers: usize,
    pub kept_workers: usize,
    pub wall_s: f64,
    /// Rebalances triggered by node death.
    pub recoveries: usize,
    /// Rebalances triggered by elastic join.
    pub joins: usize,
    /// Total steps re-executed after checkpoint rewinds.
    pub replayed_steps: usize,
    /// Wall seconds spent inside recovery rebalances only.
    pub recovery_wall_s: f64,
}

impl RebalanceTotals {
    /// Fold a rebalance history — a slice, or the bounded
    /// `ClusterRun::rebalance_history` ring (`crate::util::ring::History`
    /// iterates by reference).
    pub fn of<'a>(history: impl IntoIterator<Item = &'a RebalanceReport>) -> Self {
        let mut t = RebalanceTotals::default();
        for r in history {
            t.calls += 1;
            t.level1_migrated += r.level1_migrated;
            t.level2_migrated += r.level2_migrated;
            t.rebuilt_workers += r.rebuilt_workers;
            t.kept_workers += r.kept_workers;
            t.wall_s += r.wall_s;
            t.replayed_steps += r.replayed_steps;
            match r.cause {
                RebalanceCause::Adaptive => {}
                RebalanceCause::Recovery => {
                    t.recoveries += 1;
                    t.recovery_wall_s += r.wall_s;
                }
                RebalanceCause::Join => t.joins += 1,
            }
        }
        t
    }
}

/// A planned two-level partition, ready for the migration executor.
#[derive(Debug, Clone)]
pub struct TwoLevelPlan {
    pub node_part: Partition,
    pub fractions: Vec<f64>,
    pub np: NestedPartition,
    /// Whether level 1 adopted a re-splice (false = chunks unchanged).
    pub level1_moved: bool,
    pub per_node: Vec<NodeRebalance>,
}

/// Per-node measured rate (busy s / element / step): the node finishes a
/// step when its *slower* worker does, so the node rate takes the max of
/// the two workers' busy time. `None` for nodes with nothing measured.
pub fn node_rates(times: &[WorkerTimes], counts: &[(usize, usize)]) -> Vec<Option<f64>> {
    counts
        .iter()
        .enumerate()
        .map(|(nd, &(kc, km))| {
            let busy = times[2 * nd].busy_per_step().max(times[2 * nd + 1].busy_per_step());
            calib::measured_elem_rate(busy, kc + km)
        })
        .collect()
}

/// Level-1 re-splice decision: weight every element with its current
/// node's measured rate, re-splice, and adopt the candidate only if it
/// improves the predicted slowest-node time by more than `min_gain`
/// (relative). Nodes with nothing measured inherit the mean measured rate.
/// With an `active` mask, inactive nodes (dead, or provisioned spares not
/// yet joined) are excluded from the candidate splice so adaptive
/// rebalancing never re-feeds them. Returns `None` when level 1 should
/// stay put.
fn level1_resplice(
    node_part: &Partition,
    rates: &[Option<f64>],
    min_gain: f64,
    active: Option<&[bool]>,
) -> Option<(Partition, Vec<f64>)> {
    let nodes = node_part.nparts;
    let live = active.map_or(nodes, |a| a.iter().filter(|&&x| x).count());
    if live < 2 {
        return None;
    }
    let measured: Vec<f64> = rates.iter().flatten().copied().collect();
    if measured.is_empty() {
        return None;
    }
    let mean = measured.iter().sum::<f64>() / measured.len() as f64;
    let rate: Vec<f64> = rates.iter().map(|r| r.unwrap_or(mean)).collect();
    let weights: Vec<f64> =
        node_part.assignment.iter().map(|&nd| rate[nd]).collect();
    let cand = match active {
        Some(a) if live < nodes => splice_weighted_excluding(&weights, nodes, a),
        _ => splice_weighted(&weights, nodes),
    };
    if cand.assignment == node_part.assignment {
        return None;
    }
    // predicted step time = slowest node under its (node-bound) rate
    let predict = |p: &Partition| -> f64 {
        p.sizes().iter().zip(&rate).map(|(&k, r)| k as f64 * r).fold(0.0, f64::max)
    };
    let (old_t, new_t) = (predict(node_part), predict(&cand));
    if new_t < old_t * (1.0 - min_gain) {
        Some((cand, rate))
    } else {
        None
    }
}

/// Plan both levels from one measurement window.
///
/// * `node_part` / `fractions` — the partition currently executing.
/// * `times` — per-worker window times (standard layout: worker `2n` =
///   node n CPU, `2n+1` = node n accelerator).
/// * `counts` — current per-node realized `(k_cpu, k_mic)`.
/// * `level1` — whether the across-node re-splice is enabled (level 2
///   always re-solves).
/// * `active` — optional node liveness mask; inactive nodes never receive
///   elements from the re-splice (`None` = all nodes active).
#[allow(clippy::too_many_arguments)]
pub fn plan_two_level(
    mesh: &Mesh,
    node_part: &Partition,
    fractions: &[f64],
    times: &[WorkerTimes],
    counts: &[(usize, usize)],
    order: usize,
    level1: bool,
    active: Option<&[bool]>,
) -> TwoLevelPlan {
    let nodes = node_part.nparts;
    assert_eq!(times.len(), 2 * nodes, "two workers per node");
    assert_eq!(counts.len(), nodes);
    assert_eq!(fractions.len(), nodes);
    let rates = node_rates(times, counts);
    let respliced =
        if level1 { level1_resplice(node_part, &rates, 0.01, active) } else { None };
    let level1_moved = respliced.is_some();
    let new_part = respliced.map(|(p, _)| p).unwrap_or_else(|| node_part.clone());
    let old_sizes = node_part.sizes();
    let new_sizes = new_part.sizes();

    // level 2: re-solve every node's split on its (possibly new) chunk
    let mut new_fractions = Vec::with_capacity(nodes);
    let mut solved = vec![None; nodes];
    for nd in 0..nodes {
        let (kc, km) = counts[nd];
        let steps = times[2 * nd].steps();
        let k_new = new_sizes[nd];
        if kc + km == 0 || k_new == 0 || steps < 1.0 {
            // nothing measured (or nothing to split): keep the current split
            new_fractions.push(fractions[nd]);
            continue;
        }
        let model = calib::measured_node(
            order,
            kc,
            km,
            steps,
            &times[2 * nd].wall_kernels(),
            &times[2 * nd + 1].wall_kernels(),
        );
        let sol = solve_mic_fraction(&model, order, k_new);
        solved[nd] = Some(sol.k_mic as f64 / k_new as f64);
        if !level1_moved && (sol.k_mic as i64 - km as i64).abs() <= 1 {
            // dead-band: re-splitting for ±1 element churns a worker
            // rebuild (a PJRT recompile) for no measurable gain
            new_fractions.push(fractions[nd]);
        } else {
            new_fractions.push(sol.k_mic as f64 / k_new as f64);
        }
    }
    let np = nested_partition_fractions(mesh, &new_part, &new_fractions);
    let per_node = (0..nodes)
        .map(|nd| NodeRebalance {
            node: nd,
            old_k: old_sizes[nd],
            new_k: new_sizes[nd],
            old_k_mic: counts[nd].1,
            new_k_mic: np.node_counts[nd].1,
            target_fraction: solved[nd].unwrap_or(new_fractions[nd]),
            rate_s_per_elem: rates[nd].unwrap_or(0.0),
        })
        .collect();
    TwoLevelPlan { node_part: new_part, fractions: new_fractions, np, level1_moved, per_node }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::unit_cube_geometry;
    use crate::partition::splice;
    use crate::solver::reference::KernelTimes;
    use crate::solver::rk::N_STAGES;

    /// A worker that measured `busy_s_per_step` over two timesteps, with
    /// the whole profile booked as volume work (enough for the refit).
    fn worker(busy_s_per_step: f64) -> WorkerTimes {
        WorkerTimes {
            kernels: KernelTimes {
                volume_loop: 2.0 * busy_s_per_step,
                ..Default::default()
            },
            boundary_s: busy_s_per_step,
            interior_s: busy_s_per_step,
            exchange_s: 0.0,
            stages: 2 * N_STAGES,
            threads: 1,
            ..Default::default()
        }
    }

    #[test]
    fn slow_node_sheds_elements() {
        let mesh = unit_cube_geometry(6); // 216 elements
        let part = splice(&mesh, 2);
        let counts = vec![(88, 20), (88, 20)];
        // node 1 measured 3x slower than node 0
        let times =
            vec![worker(1e-3), worker(1e-3), worker(3e-3), worker(3e-3)];
        let plan =
            plan_two_level(&mesh, &part, &[0.2, 0.2], &times, &counts, 2, true, None);
        assert!(plan.level1_moved);
        let sizes = plan.node_part.sizes();
        assert!(sizes[0] > sizes[1], "fast node must grow: {sizes:?}");
        assert_eq!(sizes[0] + sizes[1], mesh.len());
        assert!(plan.per_node[1].new_k < plan.per_node[1].old_k);
        assert!(plan.per_node[0].rate_s_per_elem > 0.0);
        // the damped step moves toward (not past) the 3:1 equilibrium
        assert!(sizes[1] >= mesh.len() / 4, "{sizes:?}");
    }

    #[test]
    fn equal_nodes_hold_the_splice() {
        let mesh = unit_cube_geometry(6);
        let part = splice(&mesh, 2);
        let counts = vec![(88, 20), (88, 20)];
        let times =
            vec![worker(1e-3), worker(1e-3), worker(1e-3), worker(1e-3)];
        let plan =
            plan_two_level(&mesh, &part, &[0.2, 0.2], &times, &counts, 2, true, None);
        assert!(!plan.level1_moved, "equal rates must not move level 1");
        assert_eq!(plan.node_part.assignment, part.assignment);
    }

    #[test]
    fn level1_disabled_keeps_chunks() {
        let mesh = unit_cube_geometry(6);
        let part = splice(&mesh, 2);
        let counts = vec![(88, 20), (88, 20)];
        let times =
            vec![worker(1e-3), worker(1e-3), worker(5e-3), worker(5e-3)];
        let plan =
            plan_two_level(&mesh, &part, &[0.2, 0.2], &times, &counts, 2, false, None);
        assert!(!plan.level1_moved);
        assert_eq!(plan.node_part.sizes(), part.sizes());
        // level 2 still re-solves from the measured profile
        assert!(plan.per_node[0].target_fraction > 0.0);
    }

    #[test]
    fn degraded_mask_never_refeeds_dead_nodes() {
        let mesh = unit_cube_geometry(6); // 216 elements
        // node 1 is dead: its chunk already re-spliced away
        let active = [true, false, true];
        let part =
            crate::partition::splice_weighted_excluding(&vec![1.0; mesh.len()], 3, &active);
        let sizes0 = part.sizes();
        assert_eq!(sizes0[1], 0);
        let counts =
            vec![(sizes0[0] - 20, 20), (0, 0), (sizes0[2] - 20, 20)];
        // node 2 measured 3x slower than node 0; node 1 unmeasured (dead)
        let times = vec![
            worker(1e-3),
            worker(1e-3),
            WorkerTimes::default(),
            WorkerTimes::default(),
            worker(3e-3),
            worker(3e-3),
        ];
        let plan = plan_two_level(
            &mesh,
            &part,
            &[0.2, 0.2, 0.2],
            &times,
            &counts,
            2,
            true,
            Some(&active),
        );
        assert!(plan.level1_moved, "skewed survivors must re-splice");
        let sizes = plan.node_part.sizes();
        assert_eq!(sizes[1], 0, "dead node must stay empty: {sizes:?}");
        assert!(sizes[0] > sizes[2], "fast survivor grows: {sizes:?}");
        assert_eq!(sizes.iter().sum::<usize>(), mesh.len());
    }

    #[test]
    fn unmeasured_window_is_a_noop_plan() {
        let mesh = unit_cube_geometry(4);
        let part = splice(&mesh, 2);
        let counts = vec![(26, 6), (26, 6)];
        let times = vec![WorkerTimes::default(); 4];
        let plan =
            plan_two_level(&mesh, &part, &[0.19, 0.19], &times, &counts, 2, true, None);
        assert!(!plan.level1_moved);
        assert_eq!(plan.fractions, vec![0.19, 0.19]);
        assert_eq!(plan.node_part.assignment, part.assignment);
    }
}
