//! Per-kernel profiling reports (the Fig 4.1 / Fig 6.2 data shape).

use crate::costmodel::kernels::ALL_KERNELS;
use crate::sim::KernelBreakdown;
use crate::solver::reference::KernelTimes;

/// A kernel-time table with total + percentage columns.
#[derive(Debug, Clone)]
pub struct ProfileReport {
    /// (kernel, seconds) rows.
    pub rows: Vec<(&'static str, f64)>,
}

impl ProfileReport {
    pub fn from_kernel_times(t: &KernelTimes) -> Self {
        ProfileReport { rows: t.rows().to_vec() }
    }

    pub fn from_breakdown(b: &KernelBreakdown) -> Self {
        let rows = ALL_KERNELS
            .iter()
            .map(|k| {
                let secs: f64 = b
                    .entries
                    .iter()
                    .filter(|((_, kn), _)| *kn == k.name())
                    .map(|(_, v)| *v)
                    .sum();
                (k.name(), secs)
            })
            .collect();
        ProfileReport { rows }
    }

    pub fn total(&self) -> f64 {
        self.rows.iter().map(|(_, s)| s).sum()
    }

    /// Speedup of this profile over a baseline (baseline total / this
    /// total); `benches/end_to_end.rs` uses it to compare the scalar and
    /// parallel drivers' accumulated kernel times.
    pub fn speedup_over(&self, baseline: &ProfileReport) -> f64 {
        baseline.total() / self.total().max(1e-300)
    }

    /// (kernel, seconds, fraction) sorted by descending share.
    pub fn fractions(&self) -> Vec<(&'static str, f64, f64)> {
        let total = self.total().max(1e-300);
        let mut v: Vec<_> =
            self.rows.iter().map(|&(k, s)| (k, s, s / total)).collect();
        v.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        v
    }

    pub fn render(&self, title: &str) -> String {
        let rows: Vec<Vec<String>> = self
            .fractions()
            .iter()
            .map(|(k, s, f)| {
                vec![k.to_string(), super::report::fmt_secs(*s), format!("{:.1}%", f * 100.0)]
            })
            .collect();
        format!("{title}\n{}", super::report::render_table(&["kernel", "time", "share"], &rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_sorted_and_normalized() {
        let t = KernelTimes {
            volume_loop: 5.0,
            int_flux: 2.0,
            interp_q: 0.5,
            lift: 0.5,
            rk: 1.0,
            bound_flux: 0.25,
            parallel_flux: 0.75,
        };
        let p = ProfileReport::from_kernel_times(&t);
        let f = p.fractions();
        assert_eq!(f[0].0, "volume_loop");
        let sum: f64 = f.iter().map(|x| x.2).sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert!((p.total() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn speedup_ratio() {
        let slow = ProfileReport::from_kernel_times(&KernelTimes {
            volume_loop: 4.0,
            ..Default::default()
        });
        let fast = ProfileReport::from_kernel_times(&KernelTimes {
            volume_loop: 1.0,
            ..Default::default()
        });
        assert!((fast.speedup_over(&slow) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn render_contains_rows() {
        let t = KernelTimes { volume_loop: 1.0, ..Default::default() };
        let p = ProfileReport::from_kernel_times(&t);
        let s = p.render("test");
        assert!(s.contains("volume_loop"));
        assert!(s.contains("100.0%"));
    }
}
