//! Per-kernel profiling reports (the Fig 4.1 / Fig 6.2 data shape) and the
//! per-worker phase tables of the cluster runtime.

use crate::coordinator::cluster::{WorkerSummary, WorkerTimes};
use crate::costmodel::kernels::ALL_KERNELS;
use crate::partition::DeviceKind;
use crate::sim::KernelBreakdown;
use crate::solver::reference::KernelTimes;

/// A kernel-time table with total + percentage columns.
#[derive(Debug, Clone)]
pub struct ProfileReport {
    /// (kernel, seconds) rows.
    pub rows: Vec<(&'static str, f64)>,
}

impl ProfileReport {
    pub fn from_kernel_times(t: &KernelTimes) -> Self {
        ProfileReport { rows: t.rows().to_vec() }
    }

    pub fn from_breakdown(b: &KernelBreakdown) -> Self {
        let rows = ALL_KERNELS
            .iter()
            .map(|k| {
                let secs: f64 = b
                    .entries
                    .iter()
                    .filter(|((_, kn), _)| *kn == k.name())
                    .map(|(_, v)| *v)
                    .sum();
                (k.name(), secs)
            })
            .collect();
        ProfileReport { rows }
    }

    pub fn total(&self) -> f64 {
        self.rows.iter().map(|(_, s)| s).sum()
    }

    /// Speedup of this profile over a baseline (baseline total / this
    /// total); `benches/end_to_end.rs` uses it to compare the scalar and
    /// parallel drivers' accumulated kernel times.
    pub fn speedup_over(&self, baseline: &ProfileReport) -> f64 {
        baseline.total() / self.total().max(1e-300)
    }

    /// (kernel, seconds, fraction) sorted by descending share.
    pub fn fractions(&self) -> Vec<(&'static str, f64, f64)> {
        let total = self.total().max(1e-300);
        let mut v: Vec<_> =
            self.rows.iter().map(|&(k, s)| (k, s, s / total)).collect();
        v.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        v
    }

    pub fn render(&self, title: &str) -> String {
        let rows: Vec<Vec<String>> = self
            .fractions()
            .iter()
            .map(|(k, s, f)| {
                vec![k.to_string(), super::report::fmt_secs(*s), format!("{:.1}%", f * 100.0)]
            })
            .collect();
        format!("{title}\n{}", super::report::render_table(&["kernel", "time", "share"], &rows))
    }
}

/// Human-scale byte counts for the fabric traffic column.
pub fn fmt_bytes(b: f64) -> String {
    if b >= 1024.0 * 1024.0 {
        format!("{:.1}MiB", b / (1024.0 * 1024.0))
    } else if b >= 1024.0 {
        format!("{:.1}KiB", b / 1024.0)
    } else {
        format!("{b:.0}B")
    }
}

/// Render the per-worker phase breakdown of a cluster run: boundary /
/// interior / exchange wall seconds per step, fabric traffic (sent +
/// received payload bytes, as counted by the worker's transport
/// endpoint) per step, plus the busy imbalance — the measurement the
/// adaptive rebalancer drives to 1.0.
pub fn render_phase_table(summaries: &[WorkerSummary], times: &[WorkerTimes]) -> String {
    assert_eq!(summaries.len(), times.len());
    let mut rows = Vec::with_capacity(times.len());
    for (s, t) in summaries.iter().zip(times) {
        let steps = t.steps().max(1e-300);
        let fabric = (t.fabric_sent_bytes + t.fabric_recv_bytes) as f64;
        rows.push(vec![
            format!("node{}-{}", s.node, if s.device == DeviceKind::Cpu { "cpu" } else { "mic" }),
            s.label.to_string(),
            s.k_elems.to_string(),
            t.threads.to_string(),
            super::report::fmt_secs(t.boundary_s / steps),
            super::report::fmt_secs(t.interior_s / steps),
            super::report::fmt_secs(t.exchange_s / steps),
            fmt_bytes(fabric / steps),
            super::report::fmt_secs(t.busy_per_step()),
        ]);
    }
    let mut out = super::report::render_table(
        &[
            "worker",
            "backend",
            "elems",
            "threads",
            "boundary/step",
            "interior/step",
            "exchange/step",
            "fabric/step",
            "busy/step",
        ],
        &rows,
    );
    out.push_str(&format!(
        "busy imbalance (max/mean over workers): {:.3}\n",
        busy_imbalance(times)
    ));
    if times.len() >= 4 && times.len() % 2 == 0 {
        out.push_str(&format!(
            "node busy imbalance (max/mean over nodes): {:.3}\n",
            node_busy_imbalance(times)
        ));
    }
    out
}

/// Max-over-mean per-step busy time across workers (1.0 = perfectly
/// balanced). The quantity `BENCH_cluster.json` tracks static vs adaptive.
pub fn busy_imbalance(times: &[WorkerTimes]) -> f64 {
    max_over_mean(&times.iter().map(|t| t.busy_per_step()).collect::<Vec<_>>())
}

/// Max-over-mean per-step busy time across *nodes*, where a node's busy
/// time is the max of its two workers' (they run concurrently; the node
/// finishes a step when its slower worker does). Standard layout: worker
/// `2n` / `2n+1` belong to node n. This is the level-1 imbalance the
/// weighted across-node re-splice drives to 1.0, tracked static vs
/// adaptive in `BENCH_cluster.json`.
pub fn node_busy_imbalance(times: &[WorkerTimes]) -> f64 {
    assert_eq!(times.len() % 2, 0, "two workers per node (standard layout)");
    let busy: Vec<f64> = times
        .chunks_exact(2)
        .map(|pair| pair[0].busy_per_step().max(pair[1].busy_per_step()))
        .collect();
    max_over_mean(&busy)
}

fn max_over_mean(busy: &[f64]) -> f64 {
    let max = busy.iter().cloned().fold(0.0, f64::max);
    let mean = busy.iter().sum::<f64>() / busy.len().max(1) as f64;
    if mean <= 0.0 {
        1.0
    } else {
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_sorted_and_normalized() {
        let t = KernelTimes {
            volume_loop: 5.0,
            int_flux: 2.0,
            interp_q: 0.5,
            lift: 0.5,
            rk: 1.0,
            bound_flux: 0.25,
            parallel_flux: 0.75,
        };
        let p = ProfileReport::from_kernel_times(&t);
        let f = p.fractions();
        assert_eq!(f[0].0, "volume_loop");
        let sum: f64 = f.iter().map(|x| x.2).sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert!((p.total() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn speedup_ratio() {
        let slow = ProfileReport::from_kernel_times(&KernelTimes {
            volume_loop: 4.0,
            ..Default::default()
        });
        let fast = ProfileReport::from_kernel_times(&KernelTimes {
            volume_loop: 1.0,
            ..Default::default()
        });
        assert!((fast.speedup_over(&slow) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn render_contains_rows() {
        let t = KernelTimes { volume_loop: 1.0, ..Default::default() };
        let p = ProfileReport::from_kernel_times(&t);
        let s = p.render("test");
        assert!(s.contains("volume_loop"));
        assert!(s.contains("100.0%"));
    }

    #[test]
    fn busy_imbalance_bounds() {
        use crate::solver::rk::N_STAGES;
        let mk = |busy: f64| WorkerTimes {
            boundary_s: busy / 2.0,
            interior_s: busy / 2.0,
            stages: N_STAGES,
            ..Default::default()
        };
        // perfectly balanced pair
        assert!((busy_imbalance(&[mk(1.0), mk(1.0)]) - 1.0).abs() < 1e-12);
        // one idle worker: max/mean = 2
        assert!((busy_imbalance(&[mk(1.0), mk(0.0)]) - 2.0).abs() < 1e-12);
        // nothing measured: defined as balanced
        assert_eq!(busy_imbalance(&[mk(0.0), mk(0.0)]), 1.0);
    }

    #[test]
    fn node_busy_imbalance_takes_worker_max() {
        use crate::solver::rk::N_STAGES;
        let mk = |busy: f64| WorkerTimes {
            boundary_s: busy / 2.0,
            interior_s: busy / 2.0,
            stages: N_STAGES,
            ..Default::default()
        };
        // node 0: workers (1.0, 0.2) -> node busy 1.0; node 1: (1.0, 1.0)
        // -> 1.0: balanced at node level even though workers are not
        let t = [mk(1.0), mk(0.2), mk(1.0), mk(1.0)];
        assert!((node_busy_imbalance(&t) - 1.0).abs() < 1e-12);
        assert!(busy_imbalance(&t) > 1.0);
        // node 1 three times slower than node 0
        let t = [mk(1.0), mk(1.0), mk(3.0), mk(3.0)];
        assert!((node_busy_imbalance(&t) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn phase_table_renders() {
        use crate::partition::DeviceKind;
        use crate::solver::rk::N_STAGES;
        let summaries = vec![
            WorkerSummary { node: 0, device: DeviceKind::Cpu, k_elems: 10, label: "rust-ref" },
            WorkerSummary { node: 0, device: DeviceKind::Mic, k_elems: 6, label: "rust-ref" },
        ];
        let t = WorkerTimes {
            boundary_s: 0.1,
            interior_s: 0.2,
            exchange_s: 0.05,
            stages: 2 * N_STAGES,
            fabric_sent_bytes: 4096,
            fabric_recv_bytes: 4096,
            ..Default::default()
        };
        let s = render_phase_table(&summaries, &[t, t]);
        assert!(s.contains("node0-cpu") && s.contains("node0-mic"), "{s}");
        assert!(s.contains("busy imbalance"), "{s}");
        // 8192 bytes over 2 steps = 4 KiB per step in the fabric column
        assert!(s.contains("fabric/step") && s.contains("4.0KiB"), "{s}");
    }

    #[test]
    fn bytes_formatting_scales() {
        assert_eq!(fmt_bytes(512.0), "512B");
        assert_eq!(fmt_bytes(4.0 * 1024.0), "4.0KiB");
        assert_eq!(fmt_bytes(3.5 * 1024.0 * 1024.0), "3.5MiB");
    }
}
