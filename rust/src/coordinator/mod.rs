//! Execution coordination: the N-node cluster runtime and the experiment
//! drivers that regenerate every table and figure.
//!
//! The two-level execution story (paper §5), end to end:
//!
//! * **Level 1** — [`cluster`] launches P virtual compute nodes, one per
//!   contiguous splice chunk of the Morton-ordered mesh
//!   ([`crate::partition::splice`]). Nodes exchange halo traces over an
//!   in-process message fabric whose inter-node lane is the MPI stand-in.
//! * **Level 2** — inside each node, two long-lived worker threads realize
//!   the asymmetric CPU/accelerator split
//!   ([`crate::partition::nested`]): the CPU worker owns the boundary
//!   elements and *all* communication; the accelerator stand-in owns the
//!   interior and only ever talks to its own node's CPU over the
//!   intra-node (PCI stand-in) lane. Workers advance each stage in two
//!   phases (boundary, then interior — [`crate::solver::parallel`]) and
//!   ship traces *between* the phases, so the fabric routes while the
//!   interior sweep computes — the paper's compute/communication overlap.
//!
//! The loop closes through the cost model at both levels: every R steps
//! the [`rebalance`] planner turns the measured window into a
//! [`rebalance::TwoLevelPlan`] — a weighted level-1 re-splice across
//! nodes from measured per-element rates *and* a per-node level-2
//! CPU/MIC re-solve — and [`cluster::ClusterRun::rebalance`] applies it
//! incrementally: state migrates over the global-id path, but only
//! workers whose element set changed rebuild blocks/backends.
//!
//! [`node`] keeps the historical single-node two-worker API
//! ([`HeteroRun`]) as a wrapper over the cluster runtime; [`experiments`]
//! drives the paper's tables/figures plus the live-vs-simulated
//! cross-check; [`profile`]/[`report`] render the results. One level
//! above all of it, [`serve`] co-schedules *many independent
//! simulations* over the shared substrate — disjoint pool slices, a
//! bounded admission queue, cost-model placement and work-conserving
//! backfill.

pub mod cluster;
pub mod experiments;
pub mod fault;
pub mod node;
pub mod profile;
pub mod rebalance;
pub mod report;
pub mod serve;
pub mod transport;

pub use cluster::{ClusterRun, ClusterSpec, FabricStats, WorkerBackendFactory, WorkerTimes};
pub use fault::{ClusterError, FaultPlan, JoinSpec, KillMode, KillSpec};
pub use node::{HeteroRun, WorkerBackend};
pub use profile::ProfileReport;
pub use rebalance::{NodeRebalance, RebalanceReport};
pub use serve::{JobCtl, JobReport, JobSpec, JobStatus, ServeOptions, ServeReport, ServeSpec};
pub use transport::TransportKind;
