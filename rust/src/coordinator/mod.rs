//! Execution coordination: the N-node cluster runtime and the experiment
//! drivers that regenerate every table and figure.
//!
//! The two-level execution story (paper §5), end to end:
//!
//! * **Level 1** — [`cluster`] launches P virtual compute nodes, one per
//!   contiguous splice chunk of the Morton-ordered mesh
//!   ([`crate::partition::splice`]). Nodes exchange halo traces over an
//!   in-process message fabric whose inter-node lane is the MPI stand-in.
//! * **Level 2** — inside each node, two long-lived worker threads realize
//!   the asymmetric CPU/accelerator split
//!   ([`crate::partition::nested`]): the CPU worker owns the boundary
//!   elements and *all* communication; the accelerator stand-in owns the
//!   interior and only ever talks to its own node's CPU over the
//!   intra-node (PCI stand-in) lane. Workers advance each stage in two
//!   phases (boundary, then interior — [`crate::solver::parallel`]) and
//!   ship traces *between* the phases, so the fabric routes while the
//!   interior sweep computes — the paper's compute/communication overlap.
//!
//! The loop closes through the cost model: per-node measured kernel times
//! feed back into the §5.6 balance solve every R steps and elements
//! migrate between a node's workers ([`cluster::ClusterRun::rebalance`]).
//!
//! [`node`] keeps the historical single-node two-worker API
//! ([`HeteroRun`]) as a wrapper over the cluster runtime; [`experiments`]
//! drives the paper's tables/figures plus the live-vs-simulated
//! cross-check; [`profile`]/[`report`] render the results.

pub mod cluster;
pub mod experiments;
pub mod node;
pub mod profile;
pub mod report;

pub use cluster::{ClusterRun, ClusterSpec, FabricStats, WorkerBackendFactory, WorkerTimes};
pub use node::{HeteroRun, WorkerBackend};
pub use profile::ProfileReport;
