//! The per-node host/offload coordination (paper Fig 5.1) and the
//! experiment drivers that regenerate every table and figure.
//!
//! [`node`] implements the paper's execution flow in-process: the host
//! (CPU block) and the offload worker (MIC block) run concurrently on
//! dedicated threads, each owning its own PJRT runtime (the client is not
//! `Send`); they synchronize once per RK stage to exchange shared-face
//! traces, mirroring the host<->coprocessor dynamic the paper treats "in
//! much the same way as the dynamic between compute nodes".

pub mod experiments;
pub mod node;
pub mod profile;
pub mod report;

pub use node::{HeteroRun, WorkerBackend};
pub use profile::ProfileReport;
