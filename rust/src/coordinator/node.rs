//! In-process realization of the paper's host/offload execution flow.
//!
//! Two long-lived worker threads — "cpu" and "mic" — each own their
//! blocks' states and a private execution backend (PJRT runtimes are
//! thread-local: the client is `Rc`-based, and the paper's offload process
//! is a separate executor anyway). The coordinator thread owns the
//! exchange plan and routes boundary traces between workers after every
//! stage, playing the role of the PCI bus + MPI fabric; the simulator
//! charges modeled time for exactly these copies.
//!
//! `exchange_every_stage` selects between the numerically-exact schedule
//! (exchange after every RK stage) and the paper's once-per-timestep
//! synchronization (§5.5) — kept as an ablation; EXPERIMENTS.md quantifies
//! the accuracy difference.
//!
//! Workers advance each stage in two phases (boundary, then interior — see
//! [`crate::solver::parallel`]) and ship their outbound traces *between*
//! the phases, so the coordinator routes halo data while the interior
//! sweep is still computing; the halo install message simply queues behind
//! the sweep. Backends without a real split degrade to full-stage-first.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::anyhow;

use crate::mesh::{ExchangePlan, LocalBlock};
use crate::partition::DeviceKind;
#[cfg(feature = "pjrt")]
use crate::runtime::PjrtRuntime;
use crate::solver::driver::RustRefBackend;
use crate::solver::parallel::ParallelRefBackend;
use crate::solver::reference::KernelTimes;
use crate::solver::rk::{LSRK_A, LSRK_B, N_STAGES};
use crate::solver::state::BlockState;
use crate::solver::{LglBasis, StageBackend};
use crate::Result;

/// Which backend the workers execute stages with.
#[derive(Debug, Clone)]
pub enum WorkerBackend {
    /// Pure-rust reference kernels (no artifacts needed).
    RustRef,
    /// Multithreaded reference kernels with the in-node boundary/interior
    /// split; `threads == 0` auto-sizes to half the hardware threads per
    /// worker (the two workers stage concurrently).
    RustParallel { threads: usize },
    /// AOT artifacts through PJRT (the production path; needs the `pjrt`
    /// cargo feature).
    Pjrt { artifact_dir: std::path::PathBuf },
}

/// An outbound trace produced by a worker after a stage:
/// (destination owner, destination halo slot, trace data).
type OutTrace = (usize, usize, Vec<f32>);

enum Cmd {
    /// Run one LSRK stage on every owned block; reply Staged with
    /// outbound traces for the listed (block, elem, face, dst, slot).
    Stage { dt: f32, a: f32, b: f32 },
    /// Install halo updates: (local block index, slot, data).
    SetHalo(Vec<(usize, usize, Vec<f32>)>),
    /// Reply with the sum of block energies.
    Energy,
    /// Reply with a full clone of block `i`'s state.
    ReadBlock(usize),
    /// Reply with accumulated kernel times, then reset them.
    TakeTimes,
    Shutdown,
}

enum Resp {
    Staged(Vec<OutTrace>),
    HaloSet,
    Energy(f64),
    Block(Box<BlockState>),
    Times(KernelTimes),
}

struct Worker {
    tx: Sender<Cmd>,
    rx: Receiver<Resp>,
    handle: Option<JoinHandle<()>>,
    /// owners handled by this worker, in block order.
    owners: Vec<usize>,
}

/// What each worker must emit after every stage:
/// (local block idx, elem, face, dst owner, dst slot).
type OutboundPlan = Vec<(usize, usize, usize, usize, usize)>;

fn worker_main(
    rx: Receiver<Cmd>,
    tx: Sender<Resp>,
    mut blocks: Vec<BlockState>,
    outbound: OutboundPlan,
    backend_kind: WorkerBackend,
    order: usize,
) {
    let basis = LglBasis::new(order);
    // build one backend per block
    let mut backends: Vec<Box<dyn StageBackend>> = Vec::new();
    match &backend_kind {
        WorkerBackend::RustRef => {
            for _ in &blocks {
                backends.push(Box::new(RustRefBackend::new(order)));
            }
        }
        WorkerBackend::RustParallel { threads } => {
            // threads == 0: split the hardware budget between the two
            // concurrently-staging workers instead of oversubscribing 2x
            let auto = std::thread::available_parallelism()
                .map(|n| (n.get() / 2).max(1))
                .unwrap_or(1);
            let t = if *threads == 0 { auto } else { *threads };
            for _ in &blocks {
                backends.push(Box::new(ParallelRefBackend::with_threads(order, t)));
            }
        }
        WorkerBackend::Pjrt { artifact_dir } => {
            #[cfg(feature = "pjrt")]
            {
                let mut rt = PjrtRuntime::new(artifact_dir).expect("worker: loading artifacts");
                for b in &blocks {
                    backends.push(Box::new(
                        rt.stage_backend(b).expect("worker: compiling stage artifact"),
                    ));
                }
            }
            #[cfg(not(feature = "pjrt"))]
            {
                let _ = artifact_dir;
                panic!(
                    "worker: PJRT backend requested but the binary was built \
                     without the `pjrt` feature; use --rust-ref/--parallel or \
                     rebuild with --features pjrt"
                );
            }
        }
    }
    let mut times = KernelTimes::default();
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Stage { dt, a, b } => {
                // boundary phase (full stage for non-split backends): after
                // this every outbound trace is final
                for (i, blk) in blocks.iter_mut().enumerate() {
                    let t = backends[i].stage_boundary(blk, dt, a, b).expect("stage failed");
                    times.accumulate(&t);
                }
                // ship traces before the interior sweep so the coordinator
                // routes them while this worker keeps computing; the halo
                // install (Cmd::SetHalo) queues behind the sweep, exactly
                // the paper's compute/communication overlap
                let out: Vec<OutTrace> = outbound
                    .iter()
                    .map(|&(bi, elem, face, dst, slot)| {
                        (dst, slot, blocks[bi].trace_slice(elem, face).to_vec())
                    })
                    .collect();
                tx.send(Resp::Staged(out)).ok();
                for (blk, backend) in blocks.iter_mut().zip(backends.iter_mut()) {
                    let (mut v, _halo) = blk.split_for_overlap();
                    let t = backend
                        .stage_interior(&mut v, dt, a, b)
                        .expect("interior stage failed");
                    times.accumulate(&t);
                }
            }
            Cmd::SetHalo(updates) => {
                for (bi, slot, data) in updates {
                    blocks[bi].set_halo_slot(slot, &data);
                }
                tx.send(Resp::HaloSet).ok();
            }
            Cmd::Energy => {
                let e: f64 = blocks.iter().map(|b| b.energy(&basis)).sum();
                tx.send(Resp::Energy(e)).ok();
            }
            Cmd::ReadBlock(i) => {
                tx.send(Resp::Block(Box::new(blocks[i].clone()))).ok();
            }
            Cmd::TakeTimes => {
                tx.send(Resp::Times(times)).ok();
                times = KernelTimes::default();
            }
            Cmd::Shutdown => break,
        }
    }
}

/// A heterogeneous run: CPU worker + MIC worker + the routing fabric.
pub struct HeteroRun {
    workers: Vec<Worker>,
    /// owner -> (worker index, local block index)
    owner_map: HashMap<usize, (usize, usize)>,
    /// per destination owner: copies (src_owner, src_elem, src_face, slot)
    plan: ExchangePlan,
    pub order: usize,
    pub exchange_every_stage: bool,
    pub steps_taken: usize,
    /// wall time until every worker has shipped its outbound traces (the
    /// boundary phase; the full stage for non-split backends)
    pub stage_wall_s: f64,
    /// wall time to route traces and install halos — overlapped with the
    /// workers' interior sweeps, so this includes any wait for them
    pub exchange_wall_s: f64,
}

impl HeteroRun {
    /// Build from local blocks: `device_of_owner[o]` says which worker
    /// class owner `o` belongs to. Initial conditions must already be set
    /// on the block states; halos are primed here.
    pub fn launch(
        lblocks: &[LocalBlock],
        mut states: Vec<BlockState>,
        plan: ExchangePlan,
        device_of_owner: &[DeviceKind],
        backend: WorkerBackend,
        order: usize,
    ) -> Result<Self> {
        assert_eq!(lblocks.len(), states.len());
        // prime traces + halos in-process before distributing
        for s in states.iter_mut() {
            s.refresh_traces();
        }
        crate::solver::exchange::apply_exchange(&mut states, &plan);

        let mut owner_map = HashMap::new();
        let mut per_worker_blocks: Vec<Vec<BlockState>> = vec![Vec::new(), Vec::new()];
        let mut per_worker_owners: Vec<Vec<usize>> = vec![Vec::new(), Vec::new()];
        for (o, st) in states.into_iter().enumerate() {
            let w = match device_of_owner[o] {
                DeviceKind::Cpu => 0usize,
                DeviceKind::Mic => 1,
            };
            owner_map.insert(o, (w, per_worker_blocks[w].len()));
            per_worker_blocks[w].push(st);
            per_worker_owners[w].push(o);
        }
        // outbound plan per worker: invert the exchange plan
        let mut outbound: Vec<OutboundPlan> = vec![Vec::new(), Vec::new()];
        for (dst_owner, copies) in plan.copies.iter().enumerate() {
            for &(src_owner, src_elem, src_face, slot) in copies {
                let (w, bi) = owner_map[&src_owner];
                outbound[w].push((bi, src_elem, src_face, dst_owner, slot));
            }
        }
        let mut workers = Vec::new();
        for w in 0..2 {
            let (ctx, crx) = channel::<Cmd>();
            let (rtx, rrx) = channel::<Resp>();
            let blocks = std::mem::take(&mut per_worker_blocks[w]);
            let ob = std::mem::take(&mut outbound[w]);
            let bk = backend.clone();
            let handle = std::thread::Builder::new()
                .name(if w == 0 { "cpu-worker".into() } else { "mic-worker".into() })
                .spawn(move || worker_main(crx, rtx, blocks, ob, bk, order))
                .map_err(|e| anyhow!("spawning worker: {e}"))?;
            workers.push(Worker {
                tx: ctx,
                rx: rrx,
                handle: Some(handle),
                owners: std::mem::take(&mut per_worker_owners[w]),
            });
        }
        Ok(HeteroRun {
            workers,
            owner_map,
            plan,
            order,
            exchange_every_stage: true,
            steps_taken: 0,
            stage_wall_s: 0.0,
            exchange_wall_s: 0.0,
        })
    }

    fn stage_and_route(&mut self, dt: f32, a: f32, b: f32, route: bool) -> Result<()> {
        let t0 = std::time::Instant::now();
        for w in &self.workers {
            w.tx.send(Cmd::Stage { dt, a, b }).map_err(|_| anyhow!("worker died"))?;
        }
        let mut all_out: Vec<OutTrace> = Vec::new();
        for w in &self.workers {
            match w.rx.recv() {
                Ok(Resp::Staged(out)) => all_out.extend(out),
                _ => return Err(anyhow!("worker failed during stage")),
            }
        }
        self.stage_wall_s += t0.elapsed().as_secs_f64();
        if !route {
            return Ok(());
        }
        let t1 = std::time::Instant::now();
        // route: group by destination worker
        let mut per_worker: Vec<Vec<(usize, usize, Vec<f32>)>> = vec![Vec::new(), Vec::new()];
        for (dst_owner, slot, data) in all_out {
            let (w, bi) = self.owner_map[&dst_owner];
            per_worker[w].push((bi, slot, data));
        }
        for (w, updates) in per_worker.into_iter().enumerate() {
            self.workers[w].tx.send(Cmd::SetHalo(updates)).map_err(|_| anyhow!("worker died"))?;
        }
        for w in &self.workers {
            match w.rx.recv() {
                Ok(Resp::HaloSet) => {}
                _ => return Err(anyhow!("worker failed during halo set")),
            }
        }
        self.exchange_wall_s += t1.elapsed().as_secs_f64();
        Ok(())
    }

    /// Advance one LSRK timestep.
    pub fn step(&mut self, dt: f64) -> Result<()> {
        for s in 0..N_STAGES {
            let route = self.exchange_every_stage || s == N_STAGES - 1;
            self.stage_and_route(dt as f32, LSRK_A[s] as f32, LSRK_B[s] as f32, route)?;
        }
        self.steps_taken += 1;
        Ok(())
    }

    pub fn run(&mut self, dt: f64, steps: usize) -> Result<()> {
        for _ in 0..steps {
            self.step(dt)?;
        }
        Ok(())
    }

    /// Total energy across all blocks.
    pub fn energy(&self) -> Result<f64> {
        let mut e = 0.0;
        for w in &self.workers {
            w.tx.send(Cmd::Energy).map_err(|_| anyhow!("worker died"))?;
            match w.rx.recv() {
                Ok(Resp::Energy(v)) => e += v,
                _ => return Err(anyhow!("worker failed during energy")),
            }
        }
        Ok(e)
    }

    /// Pull back the state of one owner's block.
    pub fn read_block(&self, owner: usize) -> Result<BlockState> {
        let (w, bi) = *self
            .owner_map
            .get(&owner)
            .ok_or_else(|| anyhow!("unknown owner {owner}"))?;
        self.workers[w].tx.send(Cmd::ReadBlock(bi)).map_err(|_| anyhow!("worker died"))?;
        match self.workers[w].rx.recv() {
            Ok(Resp::Block(b)) => Ok(*b),
            _ => Err(anyhow!("worker failed during read")),
        }
    }

    /// All owners, in worker order (cpu owners then mic owners).
    pub fn owners(&self) -> Vec<usize> {
        self.workers.iter().flat_map(|w| w.owners.clone()).collect()
    }

    /// Accumulated per-kernel wall times per worker: (cpu, mic).
    pub fn take_times(&self) -> Result<(KernelTimes, KernelTimes)> {
        let mut out = Vec::new();
        for w in &self.workers {
            w.tx.send(Cmd::TakeTimes).map_err(|_| anyhow!("worker died"))?;
            match w.rx.recv() {
                Ok(Resp::Times(t)) => out.push(t),
                _ => return Err(anyhow!("worker failed during take_times")),
            }
        }
        Ok((out[0], out[1]))
    }

    /// Bytes crossing the fabric per exchange (the PCI/MPI traffic unit).
    pub fn exchange_bytes_per_stage(&self) -> usize {
        let m = self.order + 1;
        self.plan.total_faces() * 9 * m * m * 4
    }
}

impl Drop for HeteroRun {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(Cmd::Shutdown);
        }
        for w in self.workers.iter_mut() {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}
