//! The paper's per-node host/offload pair, as a single-node cluster.
//!
//! [`HeteroRun`] is the historical two-worker entry point — "cpu" and
//! "mic" workers on dedicated threads, synchronizing per RK stage — now a
//! thin wrapper over [`crate::coordinator::cluster::ClusterRun`] with
//! exactly one virtual node. All the machinery (worker threads, the
//! message fabric, per-phase timing, backend factories) lives in
//! [`super::cluster`]; this module keeps the established API surface:
//! arbitrary owner->device maps, `launch` from pre-built blocks, and the
//! `(cpu, mic)` kernel-time tuple.
//!
//! `exchange_every_stage` selects between the numerically-exact schedule
//! (exchange after every RK stage) and the paper's once-per-timestep
//! synchronization (§5.5) — kept as an ablation; EXPERIMENTS.md quantifies
//! the accuracy difference.

use std::ops::{Deref, DerefMut};

use super::cluster::{ClusterRun, WorkerSpec, WorkerTimes};
use crate::mesh::{ExchangePlan, LocalBlock};
use crate::partition::DeviceKind;
use crate::solver::reference::KernelTimes;
use crate::solver::state::BlockState;
use crate::Result;

pub use super::cluster::WorkerBackend;

/// A heterogeneous run: CPU worker + MIC worker + the routing fabric.
/// Dereferences to [`ClusterRun`] for stepping, energy, per-phase times
/// and traffic accounting.
pub struct HeteroRun {
    inner: ClusterRun,
}

impl HeteroRun {
    /// Build from local blocks: `device_of_owner[o]` says which worker
    /// class owner `o` belongs to. Initial conditions must already be set
    /// on the block states; halos are primed here.
    pub fn launch(
        lblocks: &[LocalBlock],
        states: Vec<BlockState>,
        plan: ExchangePlan,
        device_of_owner: &[DeviceKind],
        backend: WorkerBackend,
        order: usize,
    ) -> Result<Self> {
        assert_eq!(device_of_owner.len(), states.len());
        let specs = vec![
            WorkerSpec {
                node: 0,
                device: DeviceKind::Cpu,
                backend: backend.clone(),
                name: "cpu-worker".into(),
                pin_base: None,
            },
            WorkerSpec {
                node: 0,
                device: DeviceKind::Mic,
                backend,
                name: "mic-worker".into(),
                pin_base: None,
            },
        ];
        let worker_of_owner: Vec<usize> =
            device_of_owner.iter().map(|&d| usize::from(d == DeviceKind::Mic)).collect();
        let inner =
            ClusterRun::launch_parts(lblocks, states, plan, &worker_of_owner, &specs, order)?;
        Ok(HeteroRun { inner })
    }

    /// Accumulated per-kernel wall times per worker: (cpu, mic), resetting
    /// the counters. Safe to call repeatedly and after a failed step: the
    /// workers stay alive and answer with whatever they accumulated.
    pub fn take_times(&self) -> Result<(KernelTimes, KernelTimes)> {
        let t = self.inner.take_worker_times()?;
        anyhow::ensure!(t.len() == 2, "expected 2 workers, got {}", t.len());
        Ok((t[0].kernels, t[1].kernels))
    }

    /// Per-phase (boundary / interior / exchange) wall-time breakdown per
    /// worker, without resetting — what the adaptive rebalancer consumes.
    pub fn phase_times(&self) -> Result<Vec<WorkerTimes>> {
        self.inner.worker_times()
    }
}

impl Deref for HeteroRun {
    type Target = ClusterRun;

    fn deref(&self) -> &ClusterRun {
        &self.inner
    }
}

impl DerefMut for HeteroRun {
    fn deref_mut(&mut self) -> &mut ClusterRun {
        &mut self.inner
    }
}
