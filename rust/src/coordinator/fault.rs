//! Fault injection and typed failure classification for the cluster
//! runtime.
//!
//! A [`FaultPlan`] is a *seeded, deterministic* schedule of bad events:
//! kill a chosen node's workers at step S (three modes — announced crash,
//! silent thread death, or a hung stall), and optionally sabotage the
//! message fabric by dropping or delaying delivery groups. The same plan
//! drives both the live cluster ([`super::cluster::ClusterSpec::faults`])
//! and the simulator ([`crate::sim::simulate_elastic`]), so an observed
//! failure schedule reproduces exactly from `(plan, seed)`.
//!
//! Failures surface as a typed [`ClusterError`] kept on the run
//! (`ClusterRun::last_error`) *in addition* to the rendered `anyhow`
//! message — the vendored `anyhow` shim is string-only (no downcasting),
//! so callers that need to branch on the failure kind read the typed value
//! off the run instead of parsing strings.

use std::time::Duration;

use anyhow::anyhow;

use crate::util::Rng;
use crate::Result;

// ---------------------------------------------------------------------------
// kill specification
// ---------------------------------------------------------------------------

/// How an injected kill manifests to the rest of the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KillMode {
    /// The worker announces the failure (error reply + empty fabric
    /// groups) and stops staging — the polite death; nobody ever blocks.
    #[default]
    Crash,
    /// The worker thread exits without a word: no reply, no groups, lanes
    /// closed. Detected through the dropped reply channel.
    Silent,
    /// The worker hangs: alive but never replies nor ships. Only the
    /// coordinator's stage deadline can detect this one.
    Stall,
}

impl KillMode {
    pub fn label(self) -> &'static str {
        match self {
            KillMode::Crash => "crash",
            KillMode::Silent => "silent",
            KillMode::Stall => "stall",
        }
    }

    /// The sentinel error string the fault-injecting backend raises; the
    /// coordinator classifies replies containing it as injected deaths.
    pub fn sentinel(self) -> &'static str {
        match self {
            KillMode::Crash => "injected-kill:crash",
            KillMode::Silent => "injected-kill:silent",
            KillMode::Stall => "injected-kill:stall",
        }
    }
}

impl std::str::FromStr for KillMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "crash" => Ok(KillMode::Crash),
            "silent" => Ok(KillMode::Silent),
            "stall" => Ok(KillMode::Stall),
            other => Err(anyhow!("unknown kill mode {other:?} (crash|silent|stall)")),
        }
    }
}

/// Which injected kill (if any) an error message carries.
pub fn kill_mode_of(msg: &str) -> Option<KillMode> {
    for mode in [KillMode::Crash, KillMode::Silent, KillMode::Stall] {
        if msg.contains(mode.sentinel()) {
            return Some(mode);
        }
    }
    None
}

/// Kill node `node`'s workers at the start of step `step`.
///
/// Parses from `"N@S"` or `"N@S:mode"` (the `--kill-node` flag syntax).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KillSpec {
    pub node: usize,
    pub step: usize,
    pub mode: KillMode,
}

impl std::str::FromStr for KillSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let (head, mode) = match s.split_once(':') {
            Some((h, m)) => (h, m.parse::<KillMode>()?),
            None => (s, KillMode::Crash),
        };
        let (node, step) = head
            .split_once('@')
            .ok_or_else(|| anyhow!("kill spec {s:?} is not N@S[:crash|silent|stall]"))?;
        Ok(KillSpec {
            node: node.trim().parse().map_err(|_| anyhow!("bad node in kill spec {s:?}"))?,
            step: step.trim().parse().map_err(|_| anyhow!("bad step in kill spec {s:?}"))?,
            mode,
        })
    }
}

/// Bring a (provisioned-but-inactive) spare node into the cluster at the
/// start of step `step`. `node: None` picks the first idle spare.
///
/// Parses from `"@S"` (first spare) or `"N@S"`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JoinSpec {
    pub node: Option<usize>,
    pub step: usize,
}

impl std::str::FromStr for JoinSpec {
    fn from_str(s: &str) -> Result<Self> {
        let (node, step) = s
            .split_once('@')
            .ok_or_else(|| anyhow!("join spec {s:?} is not [N]@S"))?;
        let node = match node.trim() {
            "" => None,
            t => Some(t.parse().map_err(|_| anyhow!("bad node in join spec {s:?}"))?),
        };
        Ok(JoinSpec {
            node,
            step: step.trim().parse().map_err(|_| anyhow!("bad step in join spec {s:?}"))?,
        })
    }

    type Err = anyhow::Error;
}

// ---------------------------------------------------------------------------
// the plan
// ---------------------------------------------------------------------------

/// A deterministic schedule of injected faults and membership changes.
///
/// Everything random (message drops) derives from `seed`, and everything
/// scheduled (kills, joins) is pinned to a step — rerunning the same plan
/// on the same cluster reproduces the same failure history bit-for-bit.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Seed for every stochastic choice the plan makes (message drops in
    /// the live fabric, straggler jitter in the simulator).
    pub seed: u64,
    pub kills: Vec<KillSpec>,
    pub joins: Vec<JoinSpec>,
    /// Probability that any one fabric delivery group is silently dropped
    /// (shipped as an empty group, so the stage lockstep survives — the
    /// receiver just keeps its stale halo).
    pub drop_prob: f64,
    /// Fixed delay added before every fabric ship (a slow-link stand-in).
    pub delay_us: u64,
}

impl FaultPlan {
    /// Whether the plan does anything at all (armed plans turn on the
    /// coordinator's deadline-bounded stage detection by default).
    pub fn is_armed(&self) -> bool {
        !self.kills.is_empty()
            || !self.joins.is_empty()
            || self.drop_prob > 0.0
            || self.delay_us > 0
    }

    /// The kill scheduled for `node`, if any.
    pub fn kill_for_node(&self, node: usize) -> Option<KillSpec> {
        self.kills.iter().copied().find(|k| k.node == node)
    }

    /// The per-worker fabric saboteur, seeded as a pure function of
    /// `(plan seed, worker)` so every worker draws an independent but
    /// reproducible stream.
    pub fn injector_for(&self, worker: usize) -> Option<FaultInjector> {
        if self.drop_prob <= 0.0 && self.delay_us == 0 {
            return None;
        }
        Some(FaultInjector {
            rng: Rng::seed_from_u64(
                self.seed ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            ),
            drop_prob: self.drop_prob,
            delay: Duration::from_micros(self.delay_us),
        })
    }
}

/// Per-worker fabric saboteur installed into the worker's endpoint: called
/// once per outbound delivery group (every transport funnels through one
/// `ship` entry point), it may delay the ship and/or decide to drop the
/// group's payload.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    rng: Rng,
    drop_prob: f64,
    delay: Duration,
}

impl FaultInjector {
    /// Apply the configured delay, then decide whether this group's
    /// payload is dropped (`true` = ship an empty group instead).
    pub fn sabotage_ship(&mut self) -> bool {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        self.drop_prob > 0.0 && self.rng.uniform() < self.drop_prob
    }
}

// ---------------------------------------------------------------------------
// typed failure
// ---------------------------------------------------------------------------

/// What took the cluster down (or degraded it), as a typed value.
///
/// The vendored `anyhow` shim carries strings only, so the run keeps the
/// last `ClusterError` alongside the rendered message
/// (`ClusterRun::last_error`); tests and the serving layer branch on this
/// instead of string-matching.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ClusterError {
    /// One or more workers died or went silent; the listed *nodes* are now
    /// out of the membership. Recoverable via checkpoint restore + forced
    /// level-1 re-splice ([`ClusterRun::recover`]).
    ///
    /// [`ClusterRun::recover`]: super::cluster::ClusterRun::recover
    NodeFailure {
        /// Nodes lost in this failure event.
        nodes: Vec<usize>,
        /// Timestep the failure was detected in (not yet completed).
        step: usize,
        /// How the first dead worker manifested.
        detail: String,
    },
    /// A non-recoverable failure: the whole fabric is permanently
    /// poisoned and the run must be relaunched.
    Poisoned { detail: String },
}

impl ClusterError {
    /// Whether a checkpointed run can recover from this error.
    pub fn recoverable(&self) -> bool {
        matches!(self, ClusterError::NodeFailure { .. })
    }
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::NodeFailure { nodes, step, detail } => write!(
                f,
                "node failure at step {step}: node(s) {nodes:?} lost ({detail})"
            ),
            ClusterError::Poisoned { detail } => {
                write!(f, "cluster poisoned: {detail}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kill_spec_parses_all_forms() {
        let k: KillSpec = "1@5".parse().unwrap();
        assert_eq!(k, KillSpec { node: 1, step: 5, mode: KillMode::Crash });
        let k: KillSpec = "2@10:silent".parse().unwrap();
        assert_eq!(k.mode, KillMode::Silent);
        let k: KillSpec = "0@3:stall".parse().unwrap();
        assert_eq!(k.mode, KillMode::Stall);
        assert!("3".parse::<KillSpec>().is_err());
        assert!("a@b".parse::<KillSpec>().is_err());
        assert!("1@2:explode".parse::<KillSpec>().is_err());
    }

    #[test]
    fn join_spec_parses_both_forms() {
        let j: JoinSpec = "@4".parse().unwrap();
        assert_eq!(j, JoinSpec { node: None, step: 4 });
        let j: JoinSpec = "2@7".parse().unwrap();
        assert_eq!(j, JoinSpec { node: Some(2), step: 7 });
        assert!("7".parse::<JoinSpec>().is_err());
    }

    #[test]
    fn sentinels_classify() {
        assert_eq!(kill_mode_of("boundary stage: injected-kill:crash"), Some(KillMode::Crash));
        assert_eq!(kill_mode_of("injected-kill:stall"), Some(KillMode::Stall));
        assert_eq!(kill_mode_of("shipping to worker 3: lane closed"), None);
    }

    #[test]
    fn injector_is_deterministic_in_seed_and_worker() {
        let plan = FaultPlan { seed: 42, drop_prob: 0.5, ..Default::default() };
        let draws = |w: usize| -> Vec<bool> {
            let mut inj = plan.injector_for(w).unwrap();
            (0..64).map(|_| inj.sabotage_ship()).collect()
        };
        assert_eq!(draws(0), draws(0), "same worker, same stream");
        assert_ne!(draws(0), draws(1), "workers draw independent streams");
        assert!(plan.injector_for(0).is_some());
        assert!(FaultPlan::default().injector_for(0).is_none());
    }

    #[test]
    fn armed_plans_know_it() {
        assert!(!FaultPlan::default().is_armed());
        let k = FaultPlan {
            kills: vec!["0@1".parse().unwrap()],
            ..Default::default()
        };
        assert!(k.is_armed());
        assert!(FaultPlan { drop_prob: 0.1, ..Default::default() }.is_armed());
    }

    #[test]
    fn cluster_error_renders_and_classifies() {
        let e = ClusterError::NodeFailure {
            nodes: vec![1],
            step: 5,
            detail: "worker reply channel disconnected".into(),
        };
        assert!(e.recoverable());
        assert!(e.to_string().contains("step 5"));
        let p = ClusterError::Poisoned { detail: "backend exploded".into() };
        assert!(!p.recoverable());
    }
}
