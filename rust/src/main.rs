//! `repro` — the leader binary: solve runs, partition inspection, and the
//! paper's experiment drivers.
//!
//! ```text
//! repro run         solve a wave problem end to end (PJRT or rust-ref)
//! repro cluster     N-node cluster runtime with adaptive rebalancing
//! repro serve       co-schedule many independent simulations on one pool
//! repro check       static plan checker (no launch) with JSON diagnostics
//! repro partition   print nested-partition statistics for a workload
//! repro balance     solve the CPU/MIC load-balance split (paper §5.6)
//! repro experiment  regenerate a paper table/figure (fig4-1, fig5-2, ...)
//! repro validate    convergence study against the analytic solution
//! repro ablation    once-per-step vs per-stage exchange accuracy
//! ```
//!
//! Flag parsing is hand-rolled (the build is offline; no clap): every
//! subcommand takes `--key value` pairs and boolean `--flag`s.

// Match the library crate's unsafe-contract policy (this binary has no
// unsafe code; the deny keeps it that way or documented).
#![deny(clippy::undocumented_unsafe_blocks)]

use std::collections::HashMap;

use repro::coordinator::{experiments, node::WorkerBackend, FaultPlan, TransportKind};
use repro::costmodel::calib;
use repro::mesh::build_local_blocks;
use repro::mesh::geometry::{discontinuous_brick, two_tree_geometry, unit_cube_geometry};
use repro::partition::{nested_partition, partition_stats, solve_mic_fraction, splice};
use repro::runtime::ArtifactManifest;
use repro::solver::analytic::standing_wave;
use repro::solver::rk::stable_dt;
use repro::solver::{BlockState, LglBasis};

const USAGE: &str = "\
repro — nested partitioning for heterogeneous clusters (Kelly, Ghattas & Sundar 2013)

USAGE: repro <command> [--key value] [--flag]

COMMANDS
  run         end-to-end wave solve on the CPU+MIC worker pair
                --n 4  --order 2  --steps 20  --nodes 1  --artifacts artifacts
                --rust-ref  --parallel [--threads N]  --two-tree
                --sync-per-step
  cluster     N-node in-process cluster (two workers per node on the
              message fabric) with optional adaptive two-level rebalancing
                --n 6  --order 2  --steps 20  --nodes 2
                [--mic-fraction F]  [--rebalance-every R]  [--no-level1]
                [--transport inproc|shm|socket]
                --rust-ref | --parallel [--threads N]  [--pin-cores]
                --two-tree  --sync-per-step
                [--kill-node N@S[:crash|silent|stall][,...]]
                [--join-node [N]@S[,...]]  [--spare-nodes K]
                [--checkpoint-every C]  [--seed S]  [--drop-prob P]
                [--delay-us U]  [--stage-deadline-ms D]  [--verify-oracle]
              (--no-level1 restricts rebalancing to the in-node CPU/MIC
              split; default also re-splices the level-1 chunks across
              nodes from measured rates. --transport picks the message
              fabric: in-process channels, shared-memory rings, or Unix
              sockets on the inter-node lanes. --kill-node injects a
              deterministic node death at step S; recovery rewinds to the
              last --checkpoint-every q-snapshot and resplices the dead
              chunk across the survivors. --join-node brings a spare node
              online at step S — reserve spares with --spare-nodes
              (defaults to the number of joins). --verify-oracle checks
              the final field against the single-block scalar driver,
              max diff <= 1e-6)
  serve       co-schedule independent simulations (a scenario sweep) over
              one shared worker pool carved into slices
                --jobs examples/serve_smoke.json
                [--slices 1,1,1,1]  [--queue-cap 8]
                [--out BENCH_serve.json]  [--smoke]
              (runs the batch twice — concurrent on the sliced pool, then
              serial on one full-width slice — and writes per-job records
              plus the serve_aggregate_over_serial scalar to --out;
              --smoke caps every job at 4 steps for CI)
  check       static plan checker: validate a cluster plan — and, with
              --jobs, a serve spec — without launching a single worker
                takes the same shape flags as `cluster` (--n --order
                --nodes --mic-fraction --kill-node --join-node
                --spare-nodes --checkpoint-every --two-tree ...)
                [--jobs spec.json]
              (walks the exact launch construction — level-1 splice,
              MIC-fraction solve, nested level-2 split, exchange plan —
              and audits ownership disjointness/exhaustiveness, route
              symmetry, the paper's §5.5 accelerator-silence rule, and
              checkpoint-vs-kill feasibility; prints one JSON diagnostic
              per line and exits nonzero when any error-severity
              diagnostic fires. See CORRECTNESS.md)
  partition   nested-partition statistics
                --n 16  --nodes 4  --order 7  [--mic-fraction F]
  balance     CPU/MIC load-balance solve   --order 7  --elems 8192
  experiment  regenerate a paper artifact: fig4-1 fig5-2 fig5-3 fig5-4
              table6-1 fig6-2 weak-scaling cross-check | all
                                           [--out results] [--steps 118]
  validate    convergence vs the analytic wave
                --orders 2,3,4  --n 2  [--rust-ref | --parallel]
                [--artifacts artifacts]
  ablation    exchange-schedule ablation   --order 3 --n 2 [--artifacts ...]
";

/// Tiny argv parser: positional args + --key value + --flag.
struct Args {
    positional: Vec<String>,
    kv: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse(argv: &[String], flag_names: &[&str]) -> Self {
        let mut positional = Vec::new();
        let mut kv = HashMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if flag_names.contains(&name) {
                    flags.push(name.to_string());
                    i += 1;
                } else {
                    let val = argv.get(i + 1).cloned().unwrap_or_default();
                    kv.insert(name.to_string(), val);
                    i += 2;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, kv, flags }
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.kv.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn get_opt<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.kv.get(key).and_then(|v| v.parse().ok())
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.kv.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

fn main() -> repro::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "run" => {
            let a = Args::parse(rest, &["rust-ref", "parallel", "two-tree", "sync-per-step"]);
            run_solve(
                a.get("n", 4),
                a.get("order", 2),
                a.get("steps", 20),
                a.get("nodes", 1),
                worker_backend(&a),
                a.flag("two-tree"),
                !a.flag("sync-per-step"),
            )
        }
        "cluster" => {
            let a = Args::parse(
                rest,
                &[
                    "rust-ref",
                    "parallel",
                    "two-tree",
                    "sync-per-step",
                    "no-level1",
                    "pin-cores",
                    "verify-oracle",
                ],
            );
            let transport = match a.kv.get("transport") {
                Some(v) => v.parse::<TransportKind>()?,
                None => TransportKind::InProc,
            };
            let faults = fault_plan(&a)?;
            let spare_default = faults.joins.len();
            run_cluster(
                a.get("n", 6),
                a.get("order", 2),
                a.get("steps", 20),
                a.get("nodes", 2),
                a.get_opt::<f64>("mic-fraction"),
                a.get_opt::<usize>("rebalance-every"),
                !a.flag("no-level1"),
                transport,
                worker_backend(&a),
                a.flag("two-tree"),
                !a.flag("sync-per-step"),
                a.flag("pin-cores"),
                faults,
                a.get("spare-nodes", spare_default),
                a.get_opt::<usize>("checkpoint-every"),
                a.get_opt::<u64>("stage-deadline-ms"),
                a.flag("verify-oracle"),
            )
        }
        "serve" => {
            let a = Args::parse(rest, &["smoke"]);
            run_serve(
                &a.get_str("jobs", "examples/serve_smoke.json"),
                a.kv.get("slices").cloned(),
                a.get_opt::<usize>("queue-cap"),
                &a.get_str("out", "BENCH_serve.json"),
                a.flag("smoke"),
            )
        }
        "check" => {
            let a = Args::parse(
                rest,
                &["rust-ref", "parallel", "two-tree", "sync-per-step", "no-level1", "pin-cores"],
            );
            run_check(&a)
        }
        "partition" => {
            let a = Args::parse(rest, &[]);
            let n = a.get("n", 16usize);
            let nodes = a.get("nodes", 4usize);
            let order = a.get("order", 7usize);
            let mesh = discontinuous_brick([n, n, n], [1.0, 1.0, 1.0]);
            let node_part = splice(&mesh, nodes);
            let frac = a.get_opt::<f64>("mic-fraction").unwrap_or_else(|| {
                let sol = solve_mic_fraction(&calib::stampede_node(), order, mesh.len() / nodes);
                sol.k_mic as f64 / (mesh.len() / nodes) as f64
            });
            let np = nested_partition(&mesh, &node_part, frac);
            let st = partition_stats(&mesh, &np);
            println!("mesh: {} elements, {nodes} nodes, mic fraction {frac:.3}", mesh.len());
            for (nd, s) in st.per_node.iter().enumerate() {
                println!(
                    "node {nd}: k_cpu {} k_mic {} (ratio {:.2}) pci {} mpi {} bound {}",
                    s.k_cpu,
                    s.k_mic,
                    s.k_mic as f64 / s.k_cpu.max(1) as f64,
                    s.pci_faces,
                    s.mpi_faces,
                    s.bound_faces(),
                );
            }
            Ok(())
        }
        "balance" => {
            let a = Args::parse(rest, &[]);
            let order = a.get("order", calib::PAPER_ORDER);
            let elems = a.get("elems", calib::PAPER_ELEMS_PER_NODE);
            let sol = solve_mic_fraction(&calib::stampede_node(), order, elems);
            println!(
                "order {order}, K {elems}: K_MIC {} K_CPU {} ratio {:.2} \
                 (paper: 1.6 at N=7, K=8192)\n t_cpu {:.4} s/step, t_mic {:.4} s/step",
                sol.k_mic, sol.k_cpu, sol.ratio, sol.t_cpu_s, sol.t_mic_s
            );
            Ok(())
        }
        "experiment" => {
            let a = Args::parse(rest, &[]);
            let id = a.positional.first().cloned().unwrap_or_else(|| "all".into());
            let out = a.get_str("out", "results");
            let steps = a.get("steps", 118usize);
            let run_one = |id: &str| -> repro::Result<()> {
                let csv = |name: &str| format!("{out}/{name}.csv");
                let text = match id {
                    "fig4-1" => experiments::fig4_1(Some(&csv("fig4_1")))?,
                    "fig5-2" => experiments::fig5_2(Some(&csv("fig5_2")))?,
                    "fig5-3" => experiments::fig5_3(Some(&csv("fig5_3")), 64)?,
                    "fig5-4" => experiments::fig5_4(Some(&csv("fig5_4")))?,
                    "table6-1" => experiments::table6_1(Some(&csv("table6_1")), steps)?,
                    "fig6-2" => experiments::fig6_2(Some(&csv("fig6_2")))?,
                    "weak-scaling" => {
                        experiments::weak_scaling(Some(&csv("weak_scaling")), steps.min(20))?
                    }
                    "cross-check" => experiments::cross_check(
                        2,
                        6,
                        2,
                        steps.min(10),
                        Some(2),
                        TransportKind::InProc,
                        Some(&csv("cross_check")),
                        None,
                    )?,
                    other => anyhow::bail!("unknown experiment {other}\n{USAGE}"),
                };
                println!("{text}");
                Ok(())
            };
            if id == "all" {
                for id in [
                    "fig4-1", "fig5-2", "fig5-3", "fig5-4", "table6-1", "fig6-2",
                    "weak-scaling", "cross-check",
                ] {
                    println!("=== {id} ===");
                    run_one(id)?;
                }
            } else {
                run_one(&id)?;
            }
            Ok(())
        }
        "validate" => {
            let a = Args::parse(rest, &["rust-ref", "parallel"]);
            let orders = a.get_str("orders", "2,3,4");
            let n = a.get("n", 2usize);
            let mut prev: Option<f64> = None;
            for tok in orders.split(',') {
                let order: usize = tok.trim().parse()?;
                let err = validate_order(order, n, worker_backend(&a))?;
                let note = match prev {
                    Some(p) if err < p => " (converging)",
                    Some(_) => " (!! not converging)",
                    None => "",
                };
                println!("order {order}: rel L2 error {err:.3e}{note}");
                prev = Some(err);
            }
            Ok(())
        }
        "ablation" => {
            let a = Args::parse(rest, &["rust-ref", "parallel"]);
            let order = a.get("order", 3usize);
            let n = a.get("n", 2usize);
            for (label, every_stage) in
                [("exchange every stage", true), ("sync once per step (paper §5.5)", false)]
            {
                let err = validate_order_mode(order, n, worker_backend(&a), every_stage)?;
                println!("{label}: rel L2 error {err:.3e}");
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            anyhow::bail!("unknown command {other}\n{USAGE}");
        }
    }
}

/// The `--seed/--drop-prob/--delay-us/--kill-node/--join-node` flags as a
/// [`FaultPlan`], shared by `cluster` and `check`.
fn fault_plan(a: &Args) -> repro::Result<FaultPlan> {
    let mut faults = FaultPlan {
        seed: a.get("seed", 0u64),
        drop_prob: a.get("drop-prob", 0.0f64),
        delay_us: a.get("delay-us", 0u64),
        ..FaultPlan::default()
    };
    if let Some(spec) = a.kv.get("kill-node") {
        for tok in spec.split(',') {
            faults.kills.push(tok.trim().parse()?);
        }
    }
    if let Some(spec) = a.kv.get("join-node") {
        for tok in spec.split(',') {
            faults.joins.push(tok.trim().parse()?);
        }
    }
    Ok(faults)
}

/// `repro check` — the static plan checker: build the same ClusterSpec the
/// `cluster` subcommand would launch, run the full no-launch audit in
/// strict mode, print one JSON diagnostic per line, and fail on errors.
/// With `--jobs` the serve spec gets the slice-budget audit too.
fn run_check(a: &Args) -> repro::Result<()> {
    use repro::analysis::plan_check;
    use repro::coordinator::cluster::ClusterSpec;
    use repro::coordinator::serve::ServeSpec;

    let n = a.get("n", 6usize);
    let nodes = a.get("nodes", 2usize);
    let mesh = if a.flag("two-tree") { two_tree_geometry(n) } else { unit_cube_geometry(n) };
    let faults = fault_plan(a)?;
    let spare_default = faults.joins.len();
    let mut spec = ClusterSpec::new(nodes, a.get("order", 2usize));
    spec.mic_fraction = a.get_opt::<f64>("mic-fraction");
    spec.rebalance_every = a.get_opt::<usize>("rebalance-every");
    spec.level1_rebalance = !a.flag("no-level1");
    if let Some(t) = a.kv.get("transport") {
        spec.transport = t.parse::<TransportKind>()?;
    }
    let backend = worker_backend(a);
    spec.cpu_backend = backend.clone();
    spec.mic_backend = backend;
    spec.exchange_every_stage = !a.flag("sync-per-step");
    spec.pin_cores = a.flag("pin-cores");
    spec.faults = faults;
    spec.spare_nodes = a.get("spare-nodes", spare_default);
    spec.checkpoint_every = a.get_opt::<usize>("checkpoint-every");
    if let Some(ms) = a.get_opt::<u64>("stage-deadline-ms") {
        spec.stage_deadline = Some(std::time::Duration::from_millis(ms));
    }

    let mut rep = plan_check::check_cluster(&mesh, &spec, true);
    if let Some(path) = a.kv.get("jobs") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        let serve_spec = ServeSpec::parse(&text)?;
        rep.merge(plan_check::check_serve(&serve_spec, true));
    }
    for d in &rep.diags {
        println!("{}", d.to_json_line());
    }
    let errors = rep.errors().count();
    let warnings = rep.diags.len() - errors;
    eprintln!(
        "check: {} element(s), {} node(s): {errors} error(s), {warnings} warning(s)",
        mesh.len(),
        spec.nodes
    );
    anyhow::ensure!(errors == 0, "plan check failed with {errors} error(s)");
    Ok(())
}

/// Backend selection shared by run/validate/ablation:
/// --parallel beats --rust-ref beats the PJRT artifact path.
fn worker_backend(a: &Args) -> WorkerBackend {
    if a.flag("parallel") {
        WorkerBackend::RustParallel { threads: a.get("threads", 0usize) }
    } else if a.flag("rust-ref") {
        WorkerBackend::RustRef
    } else {
        WorkerBackend::Pjrt { artifact_dir: a.get_str("artifacts", "artifacts").into() }
    }
}

/// Load the artifact manifest when the backend needs one (PJRT only).
fn manifest_for(b: &WorkerBackend) -> repro::Result<Option<ArtifactManifest>> {
    match b {
        WorkerBackend::Pjrt { artifact_dir } => Ok(Some(ArtifactManifest::load(artifact_dir)?)),
        _ => Ok(None),
    }
}

/// End-to-end solve on the two-worker heterogeneous coordinator.
#[allow(clippy::too_many_arguments)]
fn run_solve(
    n: usize,
    order: usize,
    steps: usize,
    nodes: usize,
    backend: WorkerBackend,
    two_tree: bool,
    exchange_every_stage: bool,
) -> repro::Result<()> {
    use repro::coordinator::HeteroRun;
    let mesh = if two_tree { two_tree_geometry(n) } else { unit_cube_geometry(n) };
    let node_part = splice(&mesh, nodes);
    let k_node = mesh.len() / nodes;
    let sol = solve_mic_fraction(&calib::stampede_node(), order, k_node);
    let frac = sol.k_mic as f64 / k_node as f64;
    let np = nested_partition(&mesh, &node_part, frac);
    let owners = np.owners();
    let (lblocks, plan) = build_local_blocks(&mesh, &owners, np.n_owners());

    let manifest = manifest_for(&backend)?;
    let basis = LglBasis::new(order);
    let mut states = Vec::new();
    let mut device_of_owner = Vec::new();
    for lb in &lblocks {
        let (kb, hb) = match &manifest {
            Some(m) => {
                let meta = m.pick_stage(order, lb.len().max(1), lb.halo_len.max(1))?;
                (meta.k, meta.halo)
            }
            None => (lb.len().max(1), lb.halo_len.max(1)),
        };
        let mut st = BlockState::from_local_block(lb, order, kb, hb);
        let w = std::f64::consts::PI * 3f64.sqrt();
        st.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
        states.push(st);
        device_of_owner.push(if lb.owner % 2 == 0 {
            repro::partition::DeviceKind::Cpu
        } else {
            repro::partition::DeviceKind::Mic
        });
    }

    let cmax = mesh.elements.iter().map(|e| e.material.cp()).fold(0.0f32, f32::max);
    let hmin =
        mesh.elements.iter().map(|e| e.h[0].min(e.h[1]).min(e.h[2])).fold(f64::MAX, f64::min);
    let dt = stable_dt(0.3, hmin, cmax as f64, order);

    let label = backend.label();
    let mut run = HeteroRun::launch(&lblocks, states, plan, &device_of_owner, backend, order)?;
    run.exchange_every_stage = exchange_every_stage;
    let e0 = run.energy()?;
    println!(
        "run: {} elements, order {order}, {} owners, dt {dt:.2e}, backend {label}",
        mesh.len(),
        lblocks.len(),
    );
    let t0 = std::time::Instant::now();
    run.run(dt, steps)?;
    let wall = t0.elapsed().as_secs_f64();
    let e1 = run.energy()?;
    println!(
        "{steps} steps in {wall:.2} s ({:.1} ms/step); energy {e0:.6} -> {e1:.6} (ratio {:.6})",
        wall * 1e3 / steps as f64,
        e1 / e0
    );
    println!(
        "exchange: {} bytes/stage; stage wall {:.2} s, exchange wall {:.2} s",
        run.exchange_bytes_per_stage(),
        run.stage_wall_s,
        run.exchange_wall_s
    );
    Ok(())
}

/// The full two-level scheme live: P virtual nodes on the message fabric,
/// optional adaptive rebalancing and fault injection, per-worker phase
/// table at the end.
#[allow(clippy::too_many_arguments)]
fn run_cluster(
    n: usize,
    order: usize,
    steps: usize,
    nodes: usize,
    mic_fraction: Option<f64>,
    rebalance_every: Option<usize>,
    level1_rebalance: bool,
    transport: TransportKind,
    backend: WorkerBackend,
    two_tree: bool,
    exchange_every_stage: bool,
    pin_cores: bool,
    faults: FaultPlan,
    spare_nodes: usize,
    checkpoint_every: Option<usize>,
    stage_deadline_ms: Option<u64>,
    verify_oracle: bool,
) -> repro::Result<()> {
    use repro::coordinator::cluster::{ClusterRun, ClusterSpec};
    use repro::coordinator::profile::render_phase_table;

    let mesh = if two_tree { two_tree_geometry(n) } else { unit_cube_geometry(n) };
    let faults_armed = faults.is_armed();
    let drop_prob = faults.drop_prob;
    let mut spec = ClusterSpec::new(nodes, order);
    spec.mic_fraction = mic_fraction;
    spec.rebalance_every = rebalance_every;
    spec.level1_rebalance = level1_rebalance;
    spec.transport = transport;
    spec.cpu_backend = backend.clone();
    spec.mic_backend = backend;
    spec.exchange_every_stage = exchange_every_stage;
    spec.pin_cores = pin_cores;
    spec.faults = faults;
    spec.spare_nodes = spare_nodes;
    spec.checkpoint_every = checkpoint_every;
    if let Some(ms) = stage_deadline_ms {
        spec.stage_deadline = Some(std::time::Duration::from_millis(ms));
    }

    let cmax = mesh.elements.iter().map(|e| e.material.cp()).fold(0.0f32, f32::max);
    let hmin =
        mesh.elements.iter().map(|e| e.h[0].min(e.h[1]).min(e.h[2])).fold(f64::MAX, f64::min);
    let dt = stable_dt(0.3, hmin, cmax as f64, order);
    let w = std::f64::consts::PI * 3f64.sqrt();
    let mut run = ClusterRun::launch(&mesh, &spec, |x| standing_wave(x, 0.0, 1.0, 1.0, w))?;
    println!(
        "cluster: {} elements over {nodes} node(s) = {} workers{}, order {order}, \
         dt {dt:.2e}, transport {}",
        mesh.len(),
        2 * nodes,
        if spare_nodes > 0 {
            format!(" (+{spare_nodes} spare node(s))")
        } else {
            String::new()
        },
        run.transport().label()
    );
    for (nd, &(kc, km)) in run.node_counts().iter().enumerate() {
        println!("  node {nd}: k_cpu {kc} k_mic {km}");
    }
    let e0 = run.energy()?;
    let t0 = std::time::Instant::now();
    run.run(dt, steps)?;
    let wall = t0.elapsed().as_secs_f64();
    let e1 = run.energy()?;
    println!(
        "{steps} steps in {wall:.2} s ({:.1} ms/step); energy {e0:.6} -> {e1:.6} (ratio {:.6})",
        wall * 1e3 / steps as f64,
        e1 / e0
    );
    let t = repro::coordinator::rebalance::RebalanceTotals::of(&run.rebalance_history);
    if rebalance_every.is_some() {
        println!("after rebalancing:");
        for (nd, &(kc, km)) in run.node_counts().iter().enumerate() {
            println!("  node {nd}: k_cpu {kc} k_mic {km}");
        }
        println!(
            "rebalance: {} call(s), level-1 migrated {} elem(s), level-2 migrated \
             {} elem(s); rebuilt {} worker backend(s), kept {} alive; \
             total stall {:.1} ms (level-1 splice {})",
            t.calls,
            t.level1_migrated,
            t.level2_migrated,
            t.rebuilt_workers,
            t.kept_workers,
            t.wall_s * 1e3,
            if level1_rebalance { "on" } else { "off" },
        );
    }
    if faults_armed || t.recoveries + t.joins > 0 {
        println!(
            "fault tolerance: {} recovery(ies) replaying {} step(s) in {:.1} ms, {} join(s)",
            t.recoveries,
            t.replayed_steps,
            t.recovery_wall_s * 1e3,
            t.joins,
        );
        println!("final membership:");
        let counts = run.node_counts();
        for (nd, (&alive, &(kc, km))) in run.node_active().iter().zip(counts.iter()).enumerate() {
            println!("  node {nd}: k_cpu {kc} k_mic {km}{}", if alive { "" } else { " (down)" });
        }
    }
    if verify_oracle {
        anyhow::ensure!(
            drop_prob == 0.0,
            "--verify-oracle needs --drop-prob 0: message drops change the numerics"
        );
        let reference = scalar_oracle(&mesh, order, dt, steps)?;
        let got = run.gather_elements()?;
        let mut diff = 0.0f32;
        for (ea, eb) in reference.iter().zip(&got) {
            for (&x, &y) in ea.iter().zip(eb) {
                diff = diff.max((x - y).abs());
            }
        }
        anyhow::ensure!(diff <= 1e-6, "cluster vs scalar oracle diff {diff} > 1e-6");
        println!("oracle check: max |cluster - scalar| = {diff:.2e} (<= 1e-6)");
    }
    let f = run.fabric();
    let (self_b, intra, inter) = f.lane_bytes_per_stage(order);
    println!(
        "fabric per routed stage: {self_b} B self (in-place), {intra} B / {} msg(s) \
         intra-node (PCI lane), {inter} B / {} msg(s) inter-node (MPI lane); \
         accelerator faces on the inter-node lane: {} (always 0)",
        f.intra_node_msgs, f.inter_node_msgs, f.mic_inter_node_faces
    );
    print!("{}", render_phase_table(&run.worker_summaries(), &run.worker_times()?));
    Ok(())
}

/// The recovery oracle: one block, one scalar backend, the plain driver —
/// per-element final q in global Morton order, same IC as `run_cluster`.
fn scalar_oracle(
    mesh: &repro::mesh::Mesh,
    order: usize,
    dt: f64,
    steps: usize,
) -> repro::Result<Vec<Vec<f32>>> {
    use repro::solver::driver::{Driver, RustRefBackend, StageBackend};
    let owners = vec![0usize; mesh.len()];
    let (lblocks, plan) = build_local_blocks(mesh, &owners, 1);
    let basis = LglBasis::new(order);
    let mut st = BlockState::from_local_block(
        &lblocks[0],
        order,
        lblocks[0].len(),
        lblocks[0].halo_len.max(1),
    );
    let w = std::f64::consts::PI * 3f64.sqrt();
    st.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
    let backends: Vec<Box<dyn StageBackend>> = vec![Box::new(RustRefBackend::new(order))];
    let mut drv = Driver::new(vec![st], plan, backends, order);
    drv.prime();
    drv.run(dt, steps)?;
    let m = order + 1;
    let esz = 9 * m * m * m;
    let st = &drv.blocks[0];
    Ok((0..mesh.len()).map(|e| st.q[e * esz..(e + 1) * esz].to_vec()).collect())
}

/// The scenario-sweep driver: run the batch concurrently over the sliced
/// pool, then serially on one full-width slice (same scheduler, same
/// total lane budget), and write per-job records plus the
/// `serve_aggregate_over_serial` headline scalar to BENCH_serve.json.
fn run_serve(
    jobs_path: &str,
    slices: Option<String>,
    queue_cap: Option<usize>,
    out: &str,
    smoke: bool,
) -> repro::Result<()> {
    use repro::coordinator::serve::{serve, JobStatus, ServeOptions, ServeSpec};
    use repro::util::bench::JsonSink;

    let text = std::fs::read_to_string(jobs_path)
        .map_err(|e| anyhow::anyhow!("reading {jobs_path}: {e}"))?;
    let mut spec = ServeSpec::parse(&text)?;
    if let Some(s) = slices {
        spec.slices = s
            .split(',')
            .map(|t| t.trim().parse::<usize>())
            .collect::<Result<Vec<_>, _>>()
            .map_err(|e| anyhow::anyhow!("--slices: {e}"))?;
        anyhow::ensure!(!spec.slices.is_empty(), "--slices must not be empty");
    }
    if let Some(c) = queue_cap {
        spec.queue_cap = c.max(1);
    }
    if smoke {
        for j in &mut spec.jobs {
            j.steps = j.steps.min(4);
        }
    }
    let total_lanes: usize = spec.slices.iter().map(|&l| l.max(1)).sum();
    println!(
        "serve: {} job(s) over {} slice(s) ({total_lanes} lanes total), queue cap {}{}",
        spec.jobs.len(),
        spec.slices.len(),
        spec.queue_cap,
        if smoke { ", smoke (steps capped at 4)" } else { "" },
    );
    let opts = ServeOptions::default();
    let concurrent = serve(&spec, &opts)?;
    for j in &concurrent.jobs {
        println!(
            "  {:<18} slice {} x{} lane(s){} wait {:>7.3} s  wall {:>7.3} s  \
             {:>9.0} elem-steps/s  [{:?}]",
            j.name,
            j.slice,
            j.lanes,
            if j.stolen { " (stolen)" } else { "" },
            j.queue_wait_s,
            j.wall_s,
            j.elem_steps_per_s,
            j.status,
        );
    }
    println!("serial baseline (one {total_lanes}-lane slice):");
    let serial = serve(&spec.serial(), &opts)?;
    let speedup = serial.wall_s / concurrent.wall_s.max(1e-12);

    let mut sink = JsonSink::new();
    for j in &concurrent.jobs {
        sink.push_entry(j.to_json());
    }
    sink.push_scalar("serve_wall_s", concurrent.wall_s, "s");
    sink.push_scalar("serve_elem_steps_per_s", concurrent.elem_steps_per_s, "elem-steps/s");
    sink.push_scalar("serial_wall_s", serial.wall_s, "s");
    sink.push_scalar("serial_elem_steps_per_s", serial.elem_steps_per_s, "elem-steps/s");
    sink.push_scalar("serve_aggregate_over_serial", speedup, "x");
    sink.write(out)?;
    println!(
        "concurrent {:.2} s vs serial {:.2} s -> serve_aggregate_over_serial {speedup:.2}x; \
         wrote {out}",
        concurrent.wall_s, serial.wall_s,
    );
    let failed = concurrent
        .jobs
        .iter()
        .chain(&serial.jobs)
        .filter(|j| matches!(j.status, JobStatus::Failed(_)))
        .count();
    anyhow::ensure!(failed == 0, "{failed} job(s) failed");
    Ok(())
}

fn validate_order(order: usize, n: usize, backend: WorkerBackend) -> repro::Result<f64> {
    validate_order_mode(order, n, backend, true)
}

/// Convergence of the full in-process stack against the analytic solution.
fn validate_order_mode(
    order: usize,
    n: usize,
    backend: WorkerBackend,
    exchange_every_stage: bool,
) -> repro::Result<f64> {
    use repro::coordinator::HeteroRun;
    let mesh = unit_cube_geometry(n);
    let node_part = splice(&mesh, 1);
    let np = nested_partition(&mesh, &node_part, 0.5);
    let owners = np.owners();
    let (lblocks, plan) = build_local_blocks(&mesh, &owners, np.n_owners());
    let manifest = manifest_for(&backend)?;
    let basis = LglBasis::new(order);
    let w = std::f64::consts::PI * 3f64.sqrt();
    let mut states = Vec::new();
    let mut device_of_owner = Vec::new();
    for lb in &lblocks {
        let (kb, hb) = match &manifest {
            Some(m) => {
                let meta = m.pick_stage(order, lb.len().max(1), lb.halo_len.max(1))?;
                (meta.k, meta.halo)
            }
            None => (lb.len().max(1), lb.halo_len.max(1)),
        };
        let mut st = BlockState::from_local_block(lb, order, kb, hb);
        st.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
        states.push(st);
        device_of_owner.push(if lb.owner % 2 == 0 {
            repro::partition::DeviceKind::Cpu
        } else {
            repro::partition::DeviceKind::Mic
        });
    }
    let t_end = 0.25f64;
    let dt0 = stable_dt(0.3, 1.0 / n as f64, 1.0, order);
    let steps = (t_end / dt0).ceil() as usize;
    let dt = t_end / steps as f64;
    let mut run = HeteroRun::launch(&lblocks, states, plan, &device_of_owner, backend, order)?;
    run.exchange_every_stage = exchange_every_stage;
    run.run(dt, steps)?;
    // reassemble the global error over all owners
    let mut num = 0.0;
    let mut den = 0.0;
    for &o in &run.owners() {
        let st = run.read_block(o)?;
        let e = st.rel_l2_error(&basis, |x| standing_wave(x, t_end, 1.0, 1.0, w));
        let norm: f64 = (0..st.k_real)
            .map(|ei| {
                st.node_coords(ei, &basis)
                    .iter()
                    .map(|&x| {
                        standing_wave(x, t_end, 1.0, 1.0, w).iter().map(|v| v * v).sum::<f64>()
                    })
                    .sum::<f64>()
            })
            .sum();
        num += e * e * norm;
        den += norm;
    }
    Ok((num / den.max(1e-300)).sqrt())
}
