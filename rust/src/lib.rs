//! Nested partitioning for parallel heterogeneous clusters.
//!
//! Reproduction of Kelly, Ghattas & Sundar (2013): a two-level partitioning
//! scheme for clusters whose nodes pair a multicore CPU with an accelerator
//! (Xeon Phi / MIC on TACC Stampede). Level 1 splices the Morton-ordered
//! octree element array into one contiguous subdomain per *node*; level 2
//! splits each node's subdomain asymmetrically into **interior** elements
//! (offloaded to the accelerator with minimal exposed surface) and
//! **boundary** elements (kept on the CPU, which also owns all inter-node
//! communication). The CPU/accelerator work ratio is solved from calibrated
//! per-kernel cost models so both finish a timestep simultaneously.
//!
//! The evaluation vehicle is an hp-discontinuous-Galerkin spectral element
//! solver for coupled elastic-acoustic wave propagation. Its per-timestep
//! compute graph is authored in JAX (+ Pallas kernels) and AOT-compiled to
//! HLO at build time (`make artifacts`); this crate loads and executes the
//! artifacts through PJRT ([`runtime`], behind the off-by-default `pjrt`
//! cargo feature) so python is never on the run path. Without artifacts
//! the pure-rust kernels serve as both oracle and production CPU path.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`mesh`]       — Morton-ordered octree hexahedral meshes, connectivity
//! * [`partition`]  — level-1 splice (equal-count and weighted — the
//!   rebalancer feeds measured node rates into
//!   `partition::splice_weighted`), level-2 nested CPU/MIC split (also
//!   applied block-locally: `partition::nested::split_block_elements`,
//!   per-node for the rebalancer: `nested_partition_fractions`, and
//!   classified per level: `nested::owner_migration`), balance (generic
//!   equal-finish solve shared by the calibrated and measured-rate paths)
//! * [`costmodel`]  — calibrated Stampede kernel/PCI/network time models,
//!   plus `calib::measured_node` / `calib::measured_elem_rate`: node
//!   models and level-1 rates refitted from live times (the rebalancer's
//!   and cross-check's closed loop); `costmodel::placement` predicts
//!   whole-job wall time for the serve scheduler (calibrated bootstrap
//!   closed by a measured EWMA per completed job)
//! * [`sim`]        — discrete-event heterogeneous cluster simulator;
//!   `simulate_parts` prices an explicit (possibly rebalanced) two-level
//!   partition and `SimReport::discrepancy` cross-checks it live
//! * [`solver`]     — DGSEM state, LGL basis, pure-rust reference kernels
//!   (`solver::simd`: runtime-dispatched AVX2/SSE2 vector paths for the
//!   hot kernels, bitwise-equal to scalar, `simd` feature on by default;
//!   the opt-in `simd-fma` feature adds FMA-contracted W8 twins, ~1 ulp
//!   from scalar, behind a runtime `set_fma` toggle);
//!   `solver::parallel` is the multithreaded boundary/interior CPU backend
//!   (fused RHS+RK stage pipeline with memoized classification on a
//!   persistent worker pool) and `solver::driver` the multi-block driver
//!   with optional compute/exchange overlap on a persistent comm thread
//!   (see PERF.md)
//! * [`runtime`]    — PJRT artifact registry, compile cache, execution
//!   (`runtime::client` needs `--features pjrt`)
//! * [`coordinator`]— the execution core: `coordinator::cluster` runs the
//!   full two-level scheme as an N-node in-process cluster (two workers
//!   per node on a typed message fabric); `coordinator::transport` makes
//!   the fabric pluggable — in-process channels, lock-free shared-memory
//!   rings, or Unix-socket inter-node lanes (`TransportKind`), with
//!   measured link probes feeding the cost model; `coordinator::rebalance` plans
//!   the adaptive two-level rebalance (weighted level-1 re-splice across
//!   nodes + per-node level-2 re-solve) that `ClusterRun` applies with
//!   incremental, backend-preserving migration (kept workers keep blocks,
//!   backends, pools and memoized classification); `coordinator::node`
//!   keeps the single-node two-worker API; `coordinator::serve` is the
//!   multi-scenario job scheduler — N independent simulations admitted
//!   through a bounded queue onto disjoint slices of one shared pool,
//!   placed by predicted wall time, backfilled by work stealing, with
//!   per-job reports and fabric-poison cancellation (`repro serve`);
//!   experiments (incl. the live-vs-sim cross-check with per-kernel
//!   drift), reports
//! * [`analysis`]   — static plan checking: `analysis::plan_check` walks
//!   an `ExchangePlan`/`ClusterSpec` without launching anything and
//!   reports typed diagnostics (ownership disjointness/exhaustiveness,
//!   route symmetry, §5.5 accelerator silence, checkpoint-vs-kill
//!   feasibility, serve slice budgets) — surfaced as `repro check` and
//!   as the launch preflight; see CORRECTNESS.md for how it fits the
//!   loom / Miri / TSan layers
//! * [`util`]       — offline-build utilities: bench harness + JSON sink,
//!   json, rng, `util::pool` — the persistent execution substrate
//!   (`WorkerPool` fork-join pool with phased barriers, participant-
//!   scoped [`util::pool::PoolSlice`] ranges for concurrent disjoint
//!   dispatch, optional core pinning, generation ids; `TaskThread` for
//!   overlap work), `util::ring::History` — the bounded report ring —
//!   plus the transport building blocks `util::shm` (lock-free SPSC
//!   slot rings) and `util::framing` (length-prefixed delivery-group
//!   frames), and `util::sync` — the std/loom shim every hand-rolled
//!   concurrent structure imports its primitives through (CORRECTNESS.md)

// Every unsafe block must carry a `// SAFETY:` contract; CI enforces
// this via clippy (the attribute is inert under plain rustc).
#![deny(clippy::undocumented_unsafe_blocks)]

pub mod analysis;
pub mod coordinator;
pub mod costmodel;
pub mod mesh;
pub mod partition;
pub mod runtime;
pub mod sim;
pub mod solver;
pub mod util;

/// Crate-wide result type (anyhow for rich error context in the binaries).
pub type Result<T> = anyhow::Result<T>;
