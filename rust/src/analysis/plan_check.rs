//! Static plan checker: typed diagnostics over a cluster plan, computed
//! without launching a single thread.
//!
//! The checker mirrors [`ClusterRun::launch`]'s construction sequence
//! (level-1 splice → MIC-fraction solve → nested level-2 split → local
//! blocks + exchange plan) and then audits the result:
//!
//! * **ownership** — every mesh element is owned by exactly one block
//!   (disjoint and exhaustive), and every id is in range;
//! * **route symmetry** — each pair of owners exchanges the same number
//!   of halo faces in both directions (a shared face produces one trace
//!   copy each way), and every copy's indices are in range;
//! * **§5.5 accelerator silence** — no halo face may route between an
//!   accelerator worker and another node (the paper's interior-only
//!   constraint; accelerators talk only to their own node's CPU);
//! * **fault feasibility** — a [`FaultPlan`] kill is only recoverable if
//!   checkpointing is on (`run()` snapshots at step 0 and every
//!   `checkpoint_every` steps, so *any* armed checkpoint interval makes
//!   every kill step recoverable — the infeasible case is exactly a kill
//!   with `checkpoint_every: None`);
//! * **serve slice budgets** — slice lane counts and per-job node counts
//!   that the scheduler could actually place.
//!
//! Severity is two-level: `Error` is a plan the runtime would refuse (or
//! corrupt on), `Warning` is legal-but-lossy (e.g. an unrecoverable kill
//! — `rust/tests/fault_recovery.rs` launches one on purpose to observe
//! the typed failure). `strict` mode — what `repro check` uses —
//! escalates the feasibility warnings to errors.
//!
//! Diagnostics are machine-readable: [`PlanDiag::to_json_line`] emits one
//! JSON object per line (`{"severity":..,"code":..,"message":..}`), and
//! [`DiagCode`] is a closed enum tests can match on. See CORRECTNESS.md
//! for how this static layer complements the loom/Miri/TSan dynamic
//! layers.
//!
//! [`ClusterRun::launch`]: crate::coordinator::ClusterRun::launch
//! [`FaultPlan`]: crate::coordinator::FaultPlan

use crate::coordinator::cluster::ClusterSpec;
use crate::coordinator::serve::ServeSpec;
use crate::costmodel::calib;
use crate::mesh::{build_local_blocks, ExchangePlan, LocalBlock, Mesh};
use crate::partition::{nested_partition_fractions, solve_mic_fraction, splice, Partition};

// ---------------------------------------------------------------------------
// diagnostic types
// ---------------------------------------------------------------------------

/// How bad a finding is: `Error` = the runtime would refuse or misbehave,
/// `Warning` = legal but probably not what the operator meant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// Closed set of diagnostic codes — tests and tooling match on these
/// instead of message substrings.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DiagCode {
    /// Mesh has fewer elements than requested level-1 chunks.
    MeshSmallerThanNodes,
    /// A kill targets a node outside the initially-active range.
    KillTargetsUnknownNode,
    /// A pinned join targets a node that is not a provisioned spare.
    JoinTargetsNonSpare,
    /// An unpinned join exists but no spare nodes are provisioned.
    JoinNeedsSpare,
    /// Explicit MIC fraction outside `[0, 1]`.
    MicFractionOutOfRange,
    /// `node_backends` length matches neither `nodes` nor `nodes + spares`.
    NodeBackendsLengthMismatch,
    /// A kill is scheduled but `checkpoint_every` is unset, so the kill
    /// precedes any checkpoint and the failure is unrecoverable.
    KillWithoutCheckpoint,
    /// `checkpoint_every == 0`: only the step-0 snapshot is ever taken.
    CheckpointIntervalZero,
    /// A mesh element appears in more than one owner's block.
    OverlappingOwnership,
    /// A mesh element appears in no owner's block.
    UnownedElement,
    /// A block claims a global element id outside the mesh.
    ElementIdOutOfRange,
    /// Owner pair exchanging unequal face counts in the two directions.
    AsymmetricRoute,
    /// An exchange copy indexes outside its source block or halo buffer.
    RouteOutOfRange,
    /// A halo face routes between an accelerator worker and another node
    /// (violates the paper's §5.5 interior-only constraint).
    AcceleratorOnInterNodeLane,
    /// Serve spec has no slices (or a slice with zero lanes).
    EmptySliceBudget,
    /// Serve slices request more lanes than the machine has threads.
    SliceOversubscribed,
    /// A serve job's mesh has fewer elements than its cluster nodes.
    JobMeshSmallerThanNodes,
}

impl DiagCode {
    pub fn as_str(self) -> &'static str {
        match self {
            DiagCode::MeshSmallerThanNodes => "mesh-smaller-than-nodes",
            DiagCode::KillTargetsUnknownNode => "kill-targets-unknown-node",
            DiagCode::JoinTargetsNonSpare => "join-targets-non-spare",
            DiagCode::JoinNeedsSpare => "join-needs-spare",
            DiagCode::MicFractionOutOfRange => "mic-fraction-out-of-range",
            DiagCode::NodeBackendsLengthMismatch => "node-backends-length-mismatch",
            DiagCode::KillWithoutCheckpoint => "kill-without-checkpoint",
            DiagCode::CheckpointIntervalZero => "checkpoint-interval-zero",
            DiagCode::OverlappingOwnership => "overlapping-ownership",
            DiagCode::UnownedElement => "unowned-element",
            DiagCode::ElementIdOutOfRange => "element-id-out-of-range",
            DiagCode::AsymmetricRoute => "asymmetric-route",
            DiagCode::RouteOutOfRange => "route-out-of-range",
            DiagCode::AcceleratorOnInterNodeLane => "accelerator-on-inter-node-lane",
            DiagCode::EmptySliceBudget => "empty-slice-budget",
            DiagCode::SliceOversubscribed => "slice-oversubscribed",
            DiagCode::JobMeshSmallerThanNodes => "job-mesh-smaller-than-nodes",
        }
    }
}

/// One finding: severity + typed code + human-readable message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanDiag {
    pub severity: Severity,
    pub code: DiagCode,
    pub message: String,
}

impl PlanDiag {
    pub fn error(code: DiagCode, message: impl Into<String>) -> PlanDiag {
        PlanDiag { severity: Severity::Error, code, message: message.into() }
    }

    pub fn warning(code: DiagCode, message: impl Into<String>) -> PlanDiag {
        PlanDiag { severity: Severity::Warning, code, message: message.into() }
    }

    /// One JSON object per diagnostic — the `repro check` wire format.
    pub fn to_json_line(&self) -> String {
        format!(
            "{{\"severity\":\"{}\",\"code\":\"{}\",\"message\":\"{}\"}}",
            self.severity.as_str(),
            self.code.as_str(),
            json_escape(&self.message)
        )
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// All findings from one check pass.
#[derive(Debug, Clone, Default)]
pub struct PlanReport {
    pub diags: Vec<PlanDiag>,
}

impl PlanReport {
    pub fn has_errors(&self) -> bool {
        self.diags.iter().any(|d| d.severity == Severity::Error)
    }

    pub fn errors(&self) -> impl Iterator<Item = &PlanDiag> {
        self.diags.iter().filter(|d| d.severity == Severity::Error)
    }

    /// First diagnostic with the given code, if any.
    pub fn find(&self, code: DiagCode) -> Option<&PlanDiag> {
        self.diags.iter().find(|d| d.code == code)
    }

    pub fn merge(&mut self, other: PlanReport) {
        self.diags.extend(other.diags);
    }

    /// Error messages joined for a one-line refusal.
    pub fn render_errors(&self) -> String {
        self.errors().map(|d| d.message.as_str()).collect::<Vec<_>>().join("; ")
    }

    /// `Ok(self)` when clean of errors, else the typed refusal (which
    /// converts into `anyhow::Error` via `?`).
    pub fn into_result(self) -> Result<PlanReport, PlanCheckError> {
        if self.has_errors() {
            Err(PlanCheckError { diags: self.diags })
        } else {
            Ok(self)
        }
    }
}

/// A plan rejected by the checker. Carries every diagnostic (warnings
/// included) so callers can render or match; `Display` shows the errors.
#[derive(Debug, Clone)]
pub struct PlanCheckError {
    pub diags: Vec<PlanDiag>,
}

impl PlanCheckError {
    pub fn find(&self, code: DiagCode) -> Option<&PlanDiag> {
        self.diags.iter().find(|d| d.code == code)
    }
}

impl std::fmt::Display for PlanCheckError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let msgs: Vec<&str> = self
            .diags
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .map(|d| d.message.as_str())
            .collect();
        write!(f, "{}", msgs.join("; "))
    }
}

impl std::error::Error for PlanCheckError {}

// ---------------------------------------------------------------------------
// spec-shape checks (no mesh walk needed)
// ---------------------------------------------------------------------------

/// The out-of-range-fraction diagnostic, shared with `launch` (which
/// checks the *solved* fraction too, not just an explicit override).
pub fn fraction_diag(frac: f64) -> Option<PlanDiag> {
    if (0.0..=1.0).contains(&frac) {
        None
    } else {
        Some(PlanDiag::error(
            DiagCode::MicFractionOutOfRange,
            format!("MIC fraction {frac} outside [0, 1]"),
        ))
    }
}

/// Shape-check a [`ClusterSpec`] against a mesh of `mesh_len` elements:
/// everything [`ClusterRun::launch`] would refuse before building blocks,
/// plus checkpoint-vs-kill feasibility. `strict` escalates the
/// feasibility warnings to errors (`repro check` mode); `launch` itself
/// uses `strict = false` so an unrecoverable kill stays launchable (the
/// fault-injection tests observe exactly that typed failure).
///
/// [`ClusterRun::launch`]: crate::coordinator::ClusterRun::launch
pub fn check_spec(mesh_len: usize, spec: &ClusterSpec, strict: bool) -> PlanReport {
    let mut rep = PlanReport::default();
    let nodes = spec.nodes.max(1);
    let total = nodes + spec.spare_nodes;
    if mesh_len < nodes {
        rep.diags.push(PlanDiag::error(
            DiagCode::MeshSmallerThanNodes,
            format!("mesh has fewer elements than nodes ({mesh_len} < {nodes})"),
        ));
    }
    for k in &spec.faults.kills {
        if k.node >= nodes {
            rep.diags.push(PlanDiag::error(
                DiagCode::KillTargetsUnknownNode,
                format!(
                    "kill plan targets node {}, but only nodes 0..{nodes} start active",
                    k.node
                ),
            ));
        }
    }
    for j in &spec.faults.joins {
        match j.node {
            Some(n) if n < nodes || n >= total => {
                rep.diags.push(PlanDiag::error(
                    DiagCode::JoinTargetsNonSpare,
                    format!("join plan targets node {n}; spare nodes are {nodes}..{total}"),
                ));
            }
            None if spec.spare_nodes == 0 => {
                rep.diags.push(PlanDiag::error(
                    DiagCode::JoinNeedsSpare,
                    "join plan needs at least one spare node (ClusterSpec::spare_nodes)"
                        .to_string(),
                ));
            }
            _ => {}
        }
    }
    if let Some(f) = spec.mic_fraction {
        rep.diags.extend(fraction_diag(f));
    }
    if let Some(nb) = &spec.node_backends {
        if nb.len() != nodes && nb.len() != total {
            rep.diags.push(PlanDiag::error(
                DiagCode::NodeBackendsLengthMismatch,
                format!(
                    "node_backends has {} entries for {nodes} nodes (+{} spares)",
                    nb.len(),
                    spec.spare_nodes
                ),
            ));
        }
    }
    // Feasibility: run() snapshots at step 0 whenever checkpointing is on,
    // so with any Some(_) interval no kill step can precede the first
    // checkpoint. The infeasible plan is a kill with checkpointing off.
    if spec.checkpoint_every.is_none() {
        if let Some(k) = spec.faults.kills.iter().min_by_key(|k| k.step) {
            let sev = if strict { Severity::Error } else { Severity::Warning };
            rep.diags.push(PlanDiag {
                severity: sev,
                code: DiagCode::KillWithoutCheckpoint,
                message: format!(
                    "kill at step {} precedes the first checkpoint: checkpoint_every is \
                     unset, so the node failure will be unrecoverable (set \
                     ClusterSpec::checkpoint_every to snapshot at step 0 and every C steps)",
                    k.step
                ),
            });
        }
    } else if spec.checkpoint_every == Some(0) {
        rep.diags.push(PlanDiag::warning(
            DiagCode::CheckpointIntervalZero,
            "checkpoint_every is 0: only the step-0 snapshot is taken, so a late \
             failure rewinds the whole run"
                .to_string(),
        ));
    }
    rep
}

// ---------------------------------------------------------------------------
// block/plan structural checks
// ---------------------------------------------------------------------------

/// Structural audit of built blocks + exchange plan: ownership is
/// disjoint and exhaustive over `mesh_len` elements, route tables are
/// symmetric, and every copy's indices are in range. Pure invariants of
/// `build_local_blocks` — `launch` debug-asserts them as a preflight.
pub fn check_blocks(blocks: &[LocalBlock], plan: &ExchangePlan, mesh_len: usize) -> PlanReport {
    let mut rep = PlanReport::default();
    // ownership: exactly-one-owner per element
    let mut owner_of: Vec<Option<usize>> = vec![None; mesh_len];
    for blk in blocks {
        for &g in &blk.global_ids {
            if g >= mesh_len {
                rep.diags.push(PlanDiag::error(
                    DiagCode::ElementIdOutOfRange,
                    format!(
                        "owner {} claims element {g}, but the mesh has {mesh_len} elements",
                        blk.owner
                    ),
                ));
                continue;
            }
            match owner_of[g] {
                Some(prev) => rep.diags.push(PlanDiag::error(
                    DiagCode::OverlappingOwnership,
                    format!("element {g} owned by both owner {prev} and owner {}", blk.owner),
                )),
                None => owner_of[g] = Some(blk.owner),
            }
        }
    }
    let unowned = owner_of.iter().filter(|o| o.is_none()).count();
    if unowned > 0 {
        let first = owner_of.iter().position(|o| o.is_none()).unwrap();
        rep.diags.push(PlanDiag::error(
            DiagCode::UnownedElement,
            format!("{unowned} mesh element(s) have no owner (first: element {first})"),
        ));
    }

    // route ranges + per-ordered-pair face counts
    let mut pair_faces: std::collections::HashMap<(usize, usize), usize> =
        std::collections::HashMap::new();
    for (dst, copies) in plan.copies.iter().enumerate() {
        for &(src, se, sf, slot) in copies {
            *pair_faces.entry((src, dst)).or_insert(0) += 1;
            if src >= blocks.len() || dst >= blocks.len() {
                rep.diags.push(PlanDiag::error(
                    DiagCode::RouteOutOfRange,
                    format!("copy {src}->{dst} references an owner beyond {}", blocks.len()),
                ));
                continue;
            }
            if se >= blocks[src].len() || sf >= 6 {
                rep.diags.push(PlanDiag::error(
                    DiagCode::RouteOutOfRange,
                    format!(
                        "copy {src}->{dst} reads element {se} face {sf}, but owner {src} \
                         has {} element(s)",
                        blocks[src].len()
                    ),
                ));
            }
            if slot >= blocks[dst].halo_len {
                rep.diags.push(PlanDiag::error(
                    DiagCode::RouteOutOfRange,
                    format!(
                        "copy {src}->{dst} writes halo slot {slot}, but owner {dst} has \
                         {} slot(s)",
                        blocks[dst].halo_len
                    ),
                ));
            }
        }
    }
    // symmetry: a shared face produces one trace copy in each direction
    for (&(a, b), &n_ab) in &pair_faces {
        if a < b {
            let n_ba = pair_faces.get(&(b, a)).copied().unwrap_or(0);
            if n_ab != n_ba {
                rep.diags.push(PlanDiag::error(
                    DiagCode::AsymmetricRoute,
                    format!(
                        "route table asymmetric between owners {a} and {b}: \
                         {n_ab} face(s) {a}->{b} but {n_ba} face(s) {b}->{a}"
                    ),
                ));
            }
        } else if a > b && !pair_faces.contains_key(&(b, a)) {
            rep.diags.push(PlanDiag::error(
                DiagCode::AsymmetricRoute,
                format!(
                    "route table asymmetric between owners {b} and {a}: \
                     0 face(s) {b}->{a} but {n_ab} face(s) {a}->{b}"
                ),
            ));
        }
    }
    rep
}

/// The §5.5 accelerator-silence audit under the canonical nested owner
/// layout (`owner = node*2 + device`, device 1 = accelerator): no copy
/// may connect an accelerator owner to a *different node*. Kept separate
/// from [`check_blocks`] because a violating plan is a legal data
/// structure the runtime refuses at fabric-build time with this same
/// diagnostic — the launch preflight asserts only the structural
/// invariants and leaves §5.5 to the typed refusal.
pub fn check_silence(plan: &ExchangePlan) -> PlanReport {
    let mut rep = PlanReport::default();
    let mut mic_inter_node = 0usize;
    for (dst, copies) in plan.copies.iter().enumerate() {
        for &(src, _, _, _) in copies {
            let (src_node, dst_node) = (src / 2, dst / 2);
            if src_node != dst_node && (src % 2 == 1 || dst % 2 == 1) {
                mic_inter_node += 1;
            }
        }
    }
    if mic_inter_node > 0 {
        rep.diags.push(PlanDiag::error(
            DiagCode::AcceleratorOnInterNodeLane,
            format!(
                "{mic_inter_node} halo faces would route between an accelerator worker \
                 and another node; accelerators never touch the inter-node fabric \
                 (paper §5.5 interior-only constraint) — fix the nested partition"
            ),
        ));
    }
    rep
}

// ---------------------------------------------------------------------------
// whole-plan + serve checks
// ---------------------------------------------------------------------------

/// Full static check of a cluster plan: shape-check the spec, then mirror
/// the launch construction (level-1 splice → fraction solve → nested
/// level-2 split → blocks + exchange plan) and audit the result — without
/// spawning a worker or opening a fabric lane.
pub fn check_cluster(mesh: &Mesh, spec: &ClusterSpec, strict: bool) -> PlanReport {
    let mut rep = check_spec(mesh.len(), spec, strict);
    if rep.has_errors() {
        return rep; // the plan below would be built from refused inputs
    }
    let nodes = spec.nodes.max(1);
    let total = nodes + spec.spare_nodes;
    let node_part = Partition { assignment: splice(mesh, nodes).assignment, nparts: total };
    let k_node = (mesh.len() / nodes).max(1);
    let frac = spec.mic_fraction.unwrap_or_else(|| {
        let sol = solve_mic_fraction(&calib::stampede_node(), spec.order, k_node);
        sol.k_mic as f64 / k_node as f64
    });
    if let Some(d) = fraction_diag(frac) {
        rep.diags.push(d);
        return rep;
    }
    let fractions = vec![frac; total];
    let np = nested_partition_fractions(mesh, &node_part, &fractions);
    let owners = np.owners();
    let (lblocks, plan) = build_local_blocks(mesh, &owners, np.n_owners());
    rep.merge(check_blocks(&lblocks, &plan, mesh.len()));
    rep.merge(check_silence(&plan));
    rep
}

/// Slice-budget sanity for a serve spec: slices exist and have lanes,
/// the lane total fits the machine, and every job's mesh is at least as
/// large as its cluster node count (a smaller one fails at job launch).
pub fn check_serve(spec: &ServeSpec, _strict: bool) -> PlanReport {
    let mut rep = PlanReport::default();
    if spec.slices.is_empty() {
        rep.diags.push(PlanDiag::error(
            DiagCode::EmptySliceBudget,
            "serve spec has no slices — the scheduler needs at least one".to_string(),
        ));
    }
    for (i, &lanes) in spec.slices.iter().enumerate() {
        if lanes == 0 {
            rep.diags.push(PlanDiag::warning(
                DiagCode::EmptySliceBudget,
                format!("slice {i} has 0 lanes; the scheduler floors it to 1"),
            ));
        }
    }
    let total: usize = spec.slices.iter().map(|&l| l.max(1)).sum();
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if total > hw {
        rep.diags.push(PlanDiag::warning(
            DiagCode::SliceOversubscribed,
            format!("slices request {total} lanes on a {hw}-thread machine"),
        ));
    }
    for job in &spec.jobs {
        if job.nodes >= 2 && job.elems() < job.nodes {
            rep.diags.push(PlanDiag::error(
                DiagCode::JobMeshSmallerThanNodes,
                format!(
                    "job {:?}: mesh has {} element(s) but asks for {} cluster nodes",
                    job.name,
                    job.elems(),
                    job.nodes
                ),
            ));
        }
    }
    rep
}

// ---------------------------------------------------------------------------
// tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fault::{KillMode, KillSpec};
    use crate::coordinator::serve::JobSpec;
    use crate::mesh::unit_cube_geometry;

    fn built(nodes: usize) -> (Mesh, Vec<LocalBlock>, ExchangePlan) {
        let mesh = unit_cube_geometry(2); // 8 elements
        let node_part =
            Partition { assignment: splice(&mesh, nodes).assignment, nparts: nodes };
        let fractions = vec![0.5; nodes];
        let np = nested_partition_fractions(&mesh, &node_part, &fractions);
        let owners = np.owners();
        let (blocks, plan) = build_local_blocks(&mesh, &owners, np.n_owners());
        (mesh, blocks, plan)
    }

    #[test]
    fn clean_plan_passes() {
        let (mesh, blocks, plan) = built(2);
        let rep = check_blocks(&blocks, &plan, mesh.len());
        assert!(!rep.has_errors(), "{}", rep.render_errors());
        assert!(!check_silence(&plan).has_errors());
        let spec = ClusterSpec::new(2, 2);
        let rep = check_cluster(&mesh, &spec, true);
        assert!(!rep.has_errors(), "{}", rep.render_errors());
    }

    #[test]
    fn overlapping_ownership_is_rejected() {
        let (mesh, mut blocks, plan) = built(2);
        // duplicate one element into a second owner's block
        let stolen = blocks[1].global_ids[0];
        blocks[0].global_ids.push(stolen);
        let rep = check_blocks(&blocks, &plan, mesh.len());
        assert!(rep.has_errors());
        assert!(rep.find(DiagCode::OverlappingOwnership).is_some(), "{:?}", rep.diags);
    }

    #[test]
    fn unowned_element_is_rejected() {
        let (mesh, mut blocks, plan) = built(2);
        blocks[0].global_ids.pop();
        let rep = check_blocks(&blocks, &plan, mesh.len());
        assert!(rep.find(DiagCode::UnownedElement).is_some(), "{:?}", rep.diags);
    }

    #[test]
    fn out_of_range_id_is_rejected() {
        let (mesh, mut blocks, plan) = built(2);
        let huge = mesh.len() + 7;
        blocks[0].global_ids[0] = huge; // also leaves the real element unowned
        let rep = check_blocks(&blocks, &plan, mesh.len());
        assert!(rep.find(DiagCode::ElementIdOutOfRange).is_some(), "{:?}", rep.diags);
    }

    #[test]
    fn asymmetric_route_is_rejected() {
        let (mesh, blocks, mut plan) = built(2);
        // drop one direction of one exchanged pair
        let dst = plan
            .copies
            .iter()
            .position(|c| !c.is_empty())
            .expect("a 2-node plan exchanges faces");
        plan.copies[dst].pop();
        let rep = check_blocks(&blocks, &plan, mesh.len());
        assert!(rep.find(DiagCode::AsymmetricRoute).is_some(), "{:?}", rep.diags);
    }

    #[test]
    fn accelerator_on_inter_node_lane_is_rejected() {
        // owner 1 = node 0 accelerator, owner 2 = node 1 CPU: a copy
        // between them crosses nodes on an accelerator endpoint. Keep it
        // symmetric so only the §5.5 check can fire.
        let mut plan = ExchangePlan { copies: vec![Vec::new(); 4] };
        plan.copies[2].push((1, 0, 0, 0));
        plan.copies[1].push((2, 0, 0, 0));
        let rep = check_silence(&plan);
        let d = rep.find(DiagCode::AcceleratorOnInterNodeLane).expect("must be refused");
        assert_eq!(d.severity, Severity::Error);
        // the CLI/tests key on this substring — keep it stable
        assert!(d.message.contains("inter-node"), "{}", d.message);
    }

    #[test]
    fn kill_without_checkpoint_strictness() {
        let mut spec = ClusterSpec::new(2, 2);
        spec.faults.kills.push(KillSpec { node: 0, step: 3, mode: KillMode::Crash });
        // strict (repro check): rejected outright
        let rep = check_spec(64, &spec, true);
        let d = rep.find(DiagCode::KillWithoutCheckpoint).expect("diagnosed");
        assert_eq!(d.severity, Severity::Error);
        assert!(rep.has_errors());
        // launch mode: surfaced as a warning, still launchable (the
        // fault-injection tests rely on observing the typed failure live)
        let rep = check_spec(64, &spec, false);
        let d = rep.find(DiagCode::KillWithoutCheckpoint).expect("diagnosed");
        assert_eq!(d.severity, Severity::Warning);
        assert!(!rep.has_errors());
        // with checkpointing on, every kill step is recoverable
        spec.checkpoint_every = Some(2);
        let rep = check_spec(64, &spec, true);
        assert!(rep.find(DiagCode::KillWithoutCheckpoint).is_none());
    }

    #[test]
    fn spec_shape_diagnostics() {
        let mut spec = ClusterSpec::new(4, 2);
        spec.mic_fraction = Some(1.5);
        spec.faults.kills.push(KillSpec { node: 9, step: 1, mode: KillMode::Crash });
        spec.node_backends = Some(Vec::new());
        spec.checkpoint_every = Some(1);
        let rep = check_spec(2, &spec, false); // mesh of 2 < 4 nodes
        assert!(rep.find(DiagCode::MeshSmallerThanNodes).is_some());
        assert!(rep.find(DiagCode::KillTargetsUnknownNode).is_some());
        assert!(rep.find(DiagCode::MicFractionOutOfRange).is_some());
        assert!(rep.find(DiagCode::NodeBackendsLengthMismatch).is_some());
        assert!(rep.has_errors());
        let err = rep.into_result().unwrap_err();
        assert!(err.to_string().contains("fewer elements"), "{err}");
    }

    #[test]
    fn serve_budget_diagnostics() {
        let jobs = vec![JobSpec { name: "tiny".into(), n: 1, order: 2, steps: 1, nodes: 8 }];
        let mut spec = ServeSpec::new(jobs);
        spec.slices = vec![2, 0];
        let rep = check_serve(&spec, true);
        assert!(rep.find(DiagCode::JobMeshSmallerThanNodes).is_some(), "{:?}", rep.diags);
        assert!(rep.find(DiagCode::EmptySliceBudget).is_some());
        assert!(rep.has_errors());
    }

    #[test]
    fn diagnostics_render_as_json_lines() {
        let d = PlanDiag::error(DiagCode::OverlappingOwnership, "element 3 owned \"twice\"");
        let line = d.to_json_line();
        assert_eq!(
            line,
            "{\"severity\":\"error\",\"code\":\"overlapping-ownership\",\
             \"message\":\"element 3 owned \\\"twice\\\"\"}"
        );
    }
}
