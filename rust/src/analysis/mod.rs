//! Static analysis over cluster plans — checks that run *without*
//! launching anything.
//!
//! [`plan_check`] walks a [`ClusterSpec`](crate::coordinator::ClusterSpec)
//! (and the `ExchangePlan` the launch sequence would build from it) and
//! reports typed diagnostics: ownership disjointness/exhaustiveness,
//! route-table symmetry, the paper's §5.5 accelerator-silence constraint,
//! checkpoint-interval vs kill-step feasibility, and serve slice-budget
//! sanity. The same checks back three surfaces:
//!
//! * `repro check` — the CLI front end, machine-readable JSON-line output;
//! * [`ClusterRun::launch`](crate::coordinator::ClusterRun::launch) — its
//!   plan-shape refusals are these diagnostics rendered as errors, plus a
//!   debug-build deep preflight over the built blocks;
//! * unit tests pinning each rejection to a distinct [`plan_check::DiagCode`].
//!
//! CORRECTNESS.md describes how this layer fits next to the loom model
//! suite and the Miri/TSan CI lanes.

pub mod plan_check;

pub use plan_check::{DiagCode, PlanCheckError, PlanDiag, PlanReport, Severity};
