//! The seven kernels of the dgae timestep (paper §4) and their work counts.
//!
//! Work formulas follow directly from the DGSEM operation counts with
//! M = N + 1 nodes per direction:
//!
//! * `volume_loop`: "elemental tensor product application to each of the
//!   nine unknowns. For each unknown, three tensor applications [...] each
//!   amounts to M matrix multiplications of one MxM matrix by another" —
//!   9 fields x 3 axes x M x (2 M^3) flops, plus the pointwise stress.
//! * `int_flux` / `bound_flux` / `parallel_flux`: "various operations
//!   performed with vectors of length NFP" per face-node; ~220 flops per
//!   face node covers the Riemann solve (impedances, jumps, 9 outputs).
//! * `interp_q`: trace extraction, 6 faces x 9 fields x M^2 moves.
//! * `lift`: 6 faces x 9 fields x M^2 fused multiply-adds.
//! * `rk`: 2 axpy over 9 M^3 values per stage, 5 stages per step.
//!
//! All counts are per *timestep* (5 RK stages) per element or per face.

/// The kernels profiled in Fig 4.1 / compared in Fig 6.2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PaperKernel {
    VolumeLoop,
    IntFlux,
    InterpQ,
    Lift,
    Rk,
    BoundFlux,
    ParallelFlux,
}

pub const ALL_KERNELS: [PaperKernel; 7] = [
    PaperKernel::VolumeLoop,
    PaperKernel::IntFlux,
    PaperKernel::InterpQ,
    PaperKernel::Lift,
    PaperKernel::Rk,
    PaperKernel::BoundFlux,
    PaperKernel::ParallelFlux,
];

impl PaperKernel {
    pub fn name(&self) -> &'static str {
        match self {
            PaperKernel::VolumeLoop => "volume_loop",
            PaperKernel::IntFlux => "int_flux",
            PaperKernel::InterpQ => "interp_q",
            PaperKernel::Lift => "lift",
            PaperKernel::Rk => "rk",
            PaperKernel::BoundFlux => "bound_flux",
            PaperKernel::ParallelFlux => "parallel_flux",
        }
    }

    /// Is the kernel's work proportional to element count (vs face count)?
    pub fn is_volume_kernel(&self) -> bool {
        !matches!(self, PaperKernel::BoundFlux | PaperKernel::ParallelFlux)
    }
}

const RK_STAGES: f64 = 5.0;
/// Flops of one exact elastic-acoustic Riemann solve per face node.
const RIEMANN_FLOPS: f64 = 220.0;

/// Floating-point work (flops) of `kernel` for one element (volume kernels)
/// or one face (flux kernels) for a full 5-stage timestep at order `n`.
pub fn work_flops(kernel: PaperKernel, n: usize) -> f64 {
    let m = (n + 1) as f64;
    let per_stage = match kernel {
        // 9 unknowns x 3 tensor applications x 2 M^4 flops + stress (13 M^3)
        PaperKernel::VolumeLoop => 9.0 * 3.0 * 2.0 * m.powi(4) + 13.0 * m.powi(3),
        // interior faces: one Riemann solve per face node, both sides lifted
        // (per shared face, counted once)
        PaperKernel::IntFlux => 2.0 * RIEMANN_FLOPS * m * m,
        // trace extraction: 6 faces x 9 fields x M^2 copies (count as 1 flop)
        PaperKernel::InterpQ => 6.0 * 9.0 * m * m,
        // lift: 6 faces x 9 fields x M^2 fma = 2 flops
        PaperKernel::Lift => 2.0 * 6.0 * 9.0 * m * m,
        // low-storage RK: res = a res + dt rhs ; q += b res -> 4 flops/value
        PaperKernel::Rk => 4.0 * 9.0 * m.powi(3),
        // physical boundary: mirror + one-sided Riemann per face
        PaperKernel::BoundFlux => (RIEMANN_FLOPS + 18.0) * m * m,
        // off-node face: same Riemann + pack/unpack
        PaperKernel::ParallelFlux => (RIEMANN_FLOPS + 36.0) * m * m,
    };
    per_stage * RK_STAGES
}

/// Bytes moved from/to main memory by `kernel` per element (or face) per
/// timestep — used for roofline sanity checks of the calibration.
pub fn work_bytes(kernel: PaperKernel, n: usize) -> f64 {
    let m = (n + 1) as f64;
    let per_stage = match kernel {
        PaperKernel::VolumeLoop => 4.0 * (2.0 * 9.0 * m.powi(3) + 9.0 * m.powi(3)),
        PaperKernel::IntFlux => 4.0 * (4.0 * 9.0 * m * m),
        PaperKernel::InterpQ => 4.0 * (2.0 * 9.0 * 6.0 * m * m),
        PaperKernel::Lift => 4.0 * (3.0 * 9.0 * 6.0 * m * m),
        PaperKernel::Rk => 4.0 * (4.0 * 9.0 * m.powi(3)),
        PaperKernel::BoundFlux => 4.0 * (3.0 * 9.0 * m * m),
        PaperKernel::ParallelFlux => 4.0 * (4.0 * 9.0 * m * m),
    };
    per_stage * RK_STAGES
}

/// Bytes of one face trace (9 fields x M^2 nodes, f32) — the unit of halo,
/// PCI and MPI traffic.
pub fn face_trace_bytes(n: usize) -> usize {
    9 * (n + 1) * (n + 1) * 4
}

/// Bytes of one element's full state (9 fields x M^3, f32).
pub fn element_state_bytes(n: usize) -> usize {
    9 * (n + 1).pow(3) * 4
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn volume_dominates_at_high_order() {
        // at N=7 the volume kernel must dominate all others per element
        let n = 7;
        let vol = work_flops(PaperKernel::VolumeLoop, n);
        for k in [PaperKernel::IntFlux, PaperKernel::InterpQ, PaperKernel::Lift, PaperKernel::Rk] {
            assert!(vol > 3.0 * work_flops(k, n), "{k:?}");
        }
    }

    #[test]
    fn work_grows_with_order() {
        for k in ALL_KERNELS {
            assert!(work_flops(k, 7) > work_flops(k, 3));
            assert!(work_bytes(k, 7) > work_bytes(k, 3));
        }
    }

    #[test]
    fn trace_and_state_sizes() {
        assert_eq!(face_trace_bytes(7), 9 * 64 * 4);
        assert_eq!(element_state_bytes(7), 9 * 512 * 4);
        // the paper's O(K (N+1)^3) vs O(6 K^{2/3} (N+1)^2) traffic argument
        let k: f64 = 8192.0;
        // ratio = K^{1/3} (N+1) / 6 = 20.2 * 8 / 6 ~ 27 at the paper's size
        let task_offload = k * element_state_bytes(7) as f64;
        let nested = 6.0 * k.powf(2.0 / 3.0) * face_trace_bytes(7) as f64;
        assert!(task_offload > 20.0 * nested, "{}", task_offload / nested);
    }
}
