//! PCI-bus transfer model (paper §5.6, Fig 5.3).
//!
//! The paper measured host<->MIC transfers of 1..4096 MB and fit the load
//! balancer's PCI_time(K_MIC) term from them. The model here is the
//! standard latency + size/bandwidth affine form with (a) asymmetric
//! directions (KNC PCIe 2.0: ~6 GB/s to the device, ~5 GB/s back), (b) a
//! small-transfer penalty floor (offload invocation overhead), and (c) a
//! deterministic jitter hook reproducing Fig 5.3's error bars.

use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Direction {
    ToDevice,
    FromDevice,
}

#[derive(Debug, Clone)]
pub struct PciModel {
    /// Per-transfer latency (offload region setup), seconds.
    pub latency_s: f64,
    /// Sustained bandwidth host -> device, bytes/s.
    pub bw_to_device: f64,
    /// Sustained bandwidth device -> host, bytes/s.
    pub bw_from_device: f64,
    /// Relative std-dev of measured samples (Fig 5.3 error bars).
    pub jitter_rel: f64,
}

impl PciModel {
    /// Calibrate from a measured fabric link
    /// ([`crate::coordinator::transport::measure_fabric_links`]): the
    /// probe's latency and bandwidth stand in for the bus, symmetric in
    /// both directions (an in-memory lane has no PCIe up/down asymmetry)
    /// and jitter-free (the probe reports a single sustained figure).
    pub fn from_link(link: crate::coordinator::transport::LinkMeasurement) -> Self {
        PciModel {
            latency_s: link.latency_s,
            bw_to_device: link.bw_bytes_per_s,
            bw_from_device: link.bw_bytes_per_s,
            jitter_rel: 0.0,
        }
    }

    /// Mean transfer time for `bytes` in `dir`.
    pub fn transfer_time(&self, bytes: usize, dir: Direction) -> f64 {
        let bw = match dir {
            Direction::ToDevice => self.bw_to_device,
            Direction::FromDevice => self.bw_from_device,
        };
        self.latency_s + bytes as f64 / bw
    }

    /// One noisy sample (deterministic in `seed`) — used to regenerate the
    /// mean +/- sigma series of Fig 5.3.
    pub fn sample(&self, bytes: usize, dir: Direction, seed: u64) -> f64 {
        let mean = self.transfer_time(bytes, dir);
        let mut rng = Rng::seed_from_u64(seed ^ bytes as u64);
        // uniform +/- sqrt(3) sigma has std-dev sigma
        let u: f64 = rng.range(-1.0, 1.0);
        mean * (1.0 + self.jitter_rel * 3f64.sqrt() * u)
    }

    /// The per-timestep PCI cost of the nested scheme for `shared_faces`
    /// CPU<->MIC faces at order `n`: both directions, once per step
    /// (paper §5.5: "Synchronization is only required once per time step").
    pub fn step_exchange_time(&self, shared_faces: usize, n: usize) -> f64 {
        let bytes = shared_faces * super::kernels::face_trace_bytes(n);
        self.transfer_time(bytes, Direction::ToDevice)
            + self.transfer_time(bytes, Direction::FromDevice)
    }

    /// The per-timestep PCI cost of the task-offload strawman (paper §5.5):
    /// the whole element state crosses the bus both ways every step.
    pub fn step_task_offload_time(&self, k_elems: usize, n: usize) -> f64 {
        let bytes = k_elems * super::kernels::element_state_bytes(n);
        self.transfer_time(bytes, Direction::ToDevice)
            + self.transfer_time(bytes, Direction::FromDevice)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::calib::stampede_node;

    #[test]
    fn affine_in_size() {
        let pci = stampede_node().pci;
        let t1 = pci.transfer_time(1 << 20, Direction::ToDevice);
        let t2 = pci.transfer_time(2 << 20, Direction::ToDevice);
        let t4 = pci.transfer_time(4 << 20, Direction::ToDevice);
        // second differences vanish for affine
        assert!(((t4 - t2) - 2.0 * (t2 - t1)).abs() < 1e-12);
    }

    #[test]
    fn asymmetric_directions() {
        let pci = stampede_node().pci;
        let big = 1 << 30;
        assert!(
            pci.transfer_time(big, Direction::FromDevice)
                > pci.transfer_time(big, Direction::ToDevice)
        );
    }

    #[test]
    fn latency_floor_dominates_small() {
        let pci = stampede_node().pci;
        let t = pci.transfer_time(64, Direction::ToDevice);
        assert!(t > 0.9 * pci.latency_s);
    }

    #[test]
    fn samples_center_on_mean() {
        let pci = stampede_node().pci;
        let bytes = 64 << 20;
        let mean = pci.transfer_time(bytes, Direction::ToDevice);
        let n = 2000;
        let avg: f64 = (0..n)
            .map(|i| pci.sample(bytes, Direction::ToDevice, i))
            .sum::<f64>()
            / n as f64;
        assert!((avg / mean - 1.0).abs() < 0.02, "avg {avg} mean {mean}");
    }

    #[test]
    fn nested_traffic_far_below_task_offload() {
        let pci = stampede_node().pci;
        let k = 8192;
        let shared = 6 * (k as f64).powf(2.0 / 3.0) as usize;
        let nested = pci.step_exchange_time(shared, 7);
        let offload = pci.step_task_offload_time(k, 7);
        assert!(offload > 10.0 * nested, "nested {nested} offload {offload}");
    }
}
