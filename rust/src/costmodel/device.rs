//! Per-device kernel timing functions T_device(kernel, N, count).

use super::kernels::{work_flops, PaperKernel, ALL_KERNELS};

/// The three execution resources of a Stampede node (paper §5.2/§5.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceClass {
    /// Baseline: one scalar MPI rank per core, 8 per node.
    CpuScalar,
    /// Optimized host: 8 OpenMP threads + hand vectorization, one socket.
    CpuVector,
    /// Xeon Phi, 120 threads, 512-bit vectors.
    Mic,
}

/// Effective per-kernel throughput of one device *pool* (a whole socket or
/// the whole MIC): `time = count * work_flops(kernel, n) / rate`.
///
/// Rates are "effective" (achieved) flops — they absorb vectorization
/// efficiency, threading overhead and memory-bandwidth limits per kernel,
/// exactly like the paper's measured T(N, K) tables absorb them.
#[derive(Debug, Clone)]
pub struct DeviceModel {
    pub class: DeviceClass,
    pub name: &'static str,
    /// Aggregate peak of the pool, for roofline/utilization reporting.
    pub peak_gflops: f64,
    /// Effective rate in flops/s per kernel, indexed by ALL_KERNELS order.
    rates: [f64; 7],
}

fn kidx(k: PaperKernel) -> usize {
    ALL_KERNELS.iter().position(|&x| x == k).expect("kernel in ALL_KERNELS")
}

impl DeviceModel {
    pub fn new(
        class: DeviceClass,
        name: &'static str,
        peak_gflops: f64,
        rates_gflops: [(PaperKernel, f64); 7],
    ) -> Self {
        let mut rates = [0.0; 7];
        for (k, r) in rates_gflops {
            rates[kidx(k)] = r * 1e9;
        }
        assert!(rates.iter().all(|&r| r > 0.0), "every kernel needs a rate");
        DeviceModel { class, name, peak_gflops, rates }
    }

    /// Effective rate for a kernel (flops/s).
    pub fn rate(&self, kernel: PaperKernel) -> f64 {
        self.rates[kidx(kernel)]
    }

    /// Seconds to process `count` elements (volume kernels) or faces (flux
    /// kernels) for one full timestep at order `n`.
    pub fn time(&self, kernel: PaperKernel, n: usize, count: usize) -> f64 {
        count as f64 * work_flops(kernel, n) / self.rate(kernel)
    }

    /// Achieved fraction of peak for a kernel — the utilization number
    /// reported in EXPERIMENTS.md.
    pub fn utilization(&self, kernel: PaperKernel) -> f64 {
        self.rate(kernel) / (self.peak_gflops * 1e9)
    }

    /// Sum timestep time over the volume kernels for `k` elements plus the
    /// face kernels with explicit counts.
    pub fn step_time(
        &self,
        n: usize,
        k_elems: usize,
        int_faces: usize,
        bound_faces: usize,
        parallel_faces: usize,
    ) -> f64 {
        self.time(PaperKernel::VolumeLoop, n, k_elems)
            + self.time(PaperKernel::InterpQ, n, k_elems)
            + self.time(PaperKernel::Lift, n, k_elems)
            + self.time(PaperKernel::Rk, n, k_elems)
            + self.time(PaperKernel::IntFlux, n, int_faces)
            + self.time(PaperKernel::BoundFlux, n, bound_faces)
            + self.time(PaperKernel::ParallelFlux, n, parallel_faces)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::calib::stampede_node;

    #[test]
    fn time_linear_in_count() {
        let node = stampede_node();
        let t1 = node.mic.time(PaperKernel::VolumeLoop, 7, 1000);
        let t2 = node.mic.time(PaperKernel::VolumeLoop, 7, 2000);
        assert!((t2 / t1 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn utilization_below_one() {
        let node = stampede_node();
        for dev in [&node.cpu_scalar, &node.cpu_vec, &node.mic] {
            for k in ALL_KERNELS {
                let u = dev.utilization(k);
                assert!(u > 0.0 && u < 1.0, "{} {k:?} {u}", dev.name);
            }
        }
    }

    #[test]
    fn step_time_additive() {
        let node = stampede_node();
        let d = &node.cpu_vec;
        let full = d.step_time(7, 100, 300, 60, 20);
        let sum = d.time(PaperKernel::VolumeLoop, 7, 100)
            + d.time(PaperKernel::InterpQ, 7, 100)
            + d.time(PaperKernel::Lift, 7, 100)
            + d.time(PaperKernel::Rk, 7, 100)
            + d.time(PaperKernel::IntFlux, 7, 300)
            + d.time(PaperKernel::BoundFlux, 7, 60)
            + d.time(PaperKernel::ParallelFlux, 7, 20);
        assert!((full - sum).abs() < 1e-15);
    }
}
