//! Calibrated performance models for the Stampede-class node (paper §5.6).
//!
//! The paper builds per-kernel timing functions T_kernel(N, K) for the CPU
//! and the MIC from measured experiments, plus a PCI transfer model, and
//! solves T_MIC = T_CPU + T_PCI for the work split. With no Stampede
//! available, this module encodes the same *functional forms* with
//! constants calibrated to everything the paper reports (hardware specs in
//! §5.2, the baseline profile of Fig 4.1, the per-kernel speedups of
//! Fig 6.2, the transfer curve of Fig 5.3, and the end-to-end times of
//! Table 6.1) — see `calib.rs` for the fit and DESIGN.md for the
//! substitution rationale.

pub mod calib;
pub mod device;
pub mod kernels;
pub mod network;
pub mod pci;
pub mod placement;

pub use device::{DeviceClass, DeviceModel};
pub use kernels::PaperKernel;
pub use network::NetworkModel;
pub use pci::PciModel;
pub use placement::PlacementModel;

/// Everything the simulator / balancer needs about one compute node.
#[derive(Debug, Clone)]
pub struct NodeModel {
    /// Baseline per-core scalar CPU (one MPI rank per core).
    pub cpu_scalar: DeviceModel,
    /// Optimized CPU socket: vectorized + OpenMP across `cpu_cores`.
    pub cpu_vec: DeviceModel,
    /// The accelerator (61-core MIC, 120 threads).
    pub mic: DeviceModel,
    pub pci: PciModel,
    pub cores_per_socket: usize,
}

impl Default for NodeModel {
    fn default() -> Self {
        calib::stampede_node()
    }
}
