//! Placement scoring for the serving layer (jobs across pool slices).
//!
//! The level-1 splice places *elements across nodes* by measured
//! per-element rates; the job scheduler plays the same move one level up,
//! placing *jobs across pool slices*. [`PlacementModel`] prices a
//! candidate placement: predicted wall seconds for a (order, elements,
//! steps) job on a given lane count. Before anything ran, predictions
//! bootstrap from the calibrated Stampede CPU model
//! ([`calib::stampede_node`], or a node refit via
//! [`calib::measured_node_with_pci`] handed to
//! [`PlacementModel::with_node`]); every finished job then closes the
//! loop through [`PlacementModel::observe`], folding the realized
//! per-element·step·lane rate into an EWMA per order — the same
//! measured-over-calibrated progression the rebalancer uses.

use std::collections::HashMap;

use crate::costmodel::{calib, NodeModel};

/// Smoothing of the measured-rate update (0.5 = equal weight to the last
/// job and all history — jobs are whole runs, already well averaged).
const EWMA_ALPHA: f64 = 0.5;

/// Predicts job wall time per candidate slice; learns from finished jobs.
#[derive(Debug, Clone)]
pub struct PlacementModel {
    node: NodeModel,
    /// Measured seconds per element·step on one lane, EWMA per order.
    measured: HashMap<usize, f64>,
}

impl PlacementModel {
    /// Bootstrap from the calibrated Stampede node.
    pub fn new() -> PlacementModel {
        PlacementModel::with_node(calib::stampede_node())
    }

    /// Bootstrap from an explicit node model (e.g. one refit from live
    /// times via [`calib::measured_node_with_pci`]).
    pub fn with_node(node: NodeModel) -> PlacementModel {
        PlacementModel { node, measured: HashMap::new() }
    }

    /// Predicted wall seconds for a `k_elems`-element, order-`order` job
    /// of `steps` timesteps on `lanes` parallel lanes. Measured rates are
    /// lane-normalized at observation time, so imperfect scaling at the
    /// lane counts actually used is folded in; the calibrated bootstrap
    /// assumes ideal scaling and only has to rank candidates until the
    /// first job of that order lands.
    pub fn predict_wall_s(&self, order: usize, k_elems: usize, steps: usize, lanes: usize) -> f64 {
        let k = k_elems.max(1);
        let per_elem_step = match self.measured.get(&order) {
            Some(&rate) => rate,
            None => {
                // same face-count ansatz as calib::measured_device: ~3k
                // interior faces, ~6k^(2/3) on the chunk surface
                let int_faces = 3 * k;
                let bound_faces = (6.0 * (k as f64).powf(2.0 / 3.0)).ceil() as usize;
                self.node.cpu_vec.step_time(order, k, int_faces, bound_faces, 0) / k as f64
            }
        };
        k as f64 * steps as f64 * per_elem_step / lanes.max(1) as f64
    }

    /// Fold a finished job's realized rate back in (closing the loop the
    /// way the rebalancer's `measured_node` refit does).
    pub fn observe(&mut self, order: usize, k_elems: usize, steps: usize, lanes: usize, wall_s: f64) {
        if wall_s <= 0.0 || k_elems == 0 || steps == 0 {
            return;
        }
        let rate = wall_s * lanes.max(1) as f64 / (k_elems as f64 * steps as f64);
        self.measured
            .entry(order)
            .and_modify(|e| *e = (1.0 - EWMA_ALPHA) * *e + EWMA_ALPHA * rate)
            .or_insert(rate);
    }

    /// How many orders have measured (non-bootstrap) rates.
    pub fn measured_orders(&self) -> usize {
        self.measured.len()
    }
}

impl Default for PlacementModel {
    fn default() -> Self {
        PlacementModel::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bootstrap_ranks_big_jobs_slower_and_more_lanes_faster() {
        let m = PlacementModel::new();
        let small = m.predict_wall_s(2, 64, 10, 1);
        let big = m.predict_wall_s(2, 512, 10, 1);
        assert!(big > small, "{big} vs {small}");
        let wide = m.predict_wall_s(2, 512, 10, 4);
        assert!(wide < big, "{wide} vs {big}");
        assert!(small > 0.0);
    }

    #[test]
    fn observed_rates_replace_the_bootstrap() {
        let mut m = PlacementModel::new();
        assert_eq!(m.measured_orders(), 0);
        // a job that really took 2s: 100 elems x 10 steps on 2 lanes
        m.observe(3, 100, 10, 2, 2.0);
        assert_eq!(m.measured_orders(), 1);
        let p = m.predict_wall_s(3, 100, 10, 2);
        assert!((p - 2.0).abs() < 1e-12, "first observation is adopted verbatim: {p}");
        // a second, 2x slower observation moves the EWMA halfway
        m.observe(3, 100, 10, 2, 4.0);
        let p = m.predict_wall_s(3, 100, 10, 2);
        assert!((p - 3.0).abs() < 1e-12, "{p}");
        // degenerate observations are ignored
        m.observe(3, 0, 10, 2, 1.0);
        m.observe(3, 100, 10, 2, 0.0);
        assert!((m.predict_wall_s(3, 100, 10, 2) - 3.0).abs() < 1e-12);
    }
}
