//! Inter-node (InfiniBand) communication model.
//!
//! An alpha-beta model for the per-step neighbor exchange plus a
//! synchronization-jitter term: bulk-synchronous codes pay the *max* over
//! nodes each step, and the variance of per-node times grows with node
//! count. The jitter constants are fit so the Table 6.1 scale-up shape
//! holds (baseline 408 -> 413 s, optimized 65 -> 74 s from 1 to 64 nodes):
//! the optimized code synchronizes two devices per node and has ~6x less
//! compute to hide noise under, so it degrades more at scale — the paper
//! observes exactly this (6.3x -> 5.6x).

#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// Per-step message/sync overhead per node, seconds.
    pub alpha_s: f64,
    /// Sustained point-to-point bandwidth, bytes/s.
    pub beta_bytes_per_s: f64,
    /// Relative straggler overhead at 64 nodes for the baseline scheme.
    pub jitter_base: f64,
    /// Relative straggler overhead at 64 nodes for the heterogeneous
    /// (CPU+MIC) scheme — larger: two synchronized devices per node.
    pub jitter_hetero: f64,
}

impl NetworkModel {
    /// Calibrate from a measured fabric link
    /// ([`crate::coordinator::transport::measure_fabric_links`]): the
    /// probe's one-way latency becomes the per-step alpha, its sustained
    /// bandwidth the beta. The jitter terms are zero — a link measured on
    /// one machine carries no cross-node straggler statistics; the Table
    /// 6.1 jitter fit stays with
    /// [`crate::costmodel::calib::stampede_node_network`].
    pub fn from_link(link: crate::coordinator::transport::LinkMeasurement) -> Self {
        NetworkModel {
            alpha_s: link.latency_s,
            beta_bytes_per_s: link.bw_bytes_per_s,
            jitter_base: 0.0,
            jitter_hetero: 0.0,
        }
    }

    /// Time for one node to exchange `faces` traces with its neighbors.
    pub fn exchange_time(&self, faces: usize, n: usize) -> f64 {
        if faces == 0 {
            return 0.0;
        }
        let bytes = faces * super::kernels::face_trace_bytes(n);
        // traces flow both directions
        self.alpha_s + 2.0 * bytes as f64 / self.beta_bytes_per_s
    }

    /// Multiplicative straggler factor for a bulk-synchronous step across
    /// `nodes` nodes. Grows like log(P), normalized to the calibrated
    /// value at 64 nodes; 1.0 for a single node.
    pub fn straggler_factor(&self, nodes: usize, heterogeneous: bool) -> f64 {
        if nodes <= 1 {
            return 1.0;
        }
        let j64 = if heterogeneous { self.jitter_hetero } else { self.jitter_base };
        1.0 + j64 * (nodes as f64).ln() / 64f64.ln()
    }
}

#[cfg(test)]
mod tests {
    use crate::costmodel::calib::stampede_node_network;

    #[test]
    fn zero_faces_zero_time() {
        let net = stampede_node_network();
        assert_eq!(net.exchange_time(0, 7), 0.0);
    }

    #[test]
    fn straggler_monotone_in_nodes() {
        let net = stampede_node_network();
        let mut prev = net.straggler_factor(1, true);
        for p in [2, 4, 16, 64, 256] {
            let f = net.straggler_factor(p, true);
            assert!(f >= prev);
            prev = f;
        }
    }

    #[test]
    fn hetero_jitter_exceeds_baseline() {
        let net = stampede_node_network();
        assert!(net.straggler_factor(64, true) > net.straggler_factor(64, false));
    }

    #[test]
    fn bandwidth_term_scales() {
        let net = stampede_node_network();
        let t1 = net.exchange_time(1000, 7) - net.alpha_s;
        let t2 = net.exchange_time(2000, 7) - net.alpha_s;
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }
}
