//! Stampede calibration constants.
//!
//! Every number the paper reports participates in the fit:
//!
//! * §5.2 hardware: SNB socket 173 GF peak (8 cores x 2.7 GHz x 8 DP
//!   flops/cycle), MIC 1.0 TF peak, CPU memory BW 51.2 GB/s, MIC 320 GB/s.
//! * Fig 4.1 (baseline profile): volume_loop is the majority of runtime
//!   with int_flux second; we use the fractions
//!   {volume .55, int_flux .22, interp .05, lift .05, rk .06, bound .02,
//!   parallel .05} of the measured 3.458 s/step baseline node time
//!   (408 s / 118 steps, Table 6.1).
//! * Fig 6.2 (per-kernel speedups): optimized-CPU vs baseline 2x for
//!   volume_loop, 5x for int_flux; MIC above optimized-CPU for every
//!   kernel except parallel_flux.
//! * §6: the balanced split K_MIC/K_CPU = 1.6 at N=7, K=8192.
//! * Fig 5.3: PCI latency floor + ~6 GB/s saturation.
//!
//! Derivation of the baseline volume rate, as a worked example: the node
//! step budget is 3.458 s of which 55% = 1.902 s is volume_loop; the work
//! is 8192 elem x 1.139 Mflop/elem/step = 9.33 GF, giving 4.9 GF/s across
//! 8 scalar cores = 0.61 GF/s/core = 11% of scalar peak — a plausible
//! unvectorized -O3 figure, which is the consistency check that the
//! paper's numbers and our work formulas agree.

use super::device::{DeviceClass, DeviceModel};
use super::kernels::PaperKernel::*;
use super::kernels::{work_flops, PaperKernel};
use super::network::NetworkModel;
use super::pci::PciModel;
use super::NodeModel;
use crate::solver::reference::KernelTimes;

/// Theoretical peaks (paper §5.2), double precision.
pub const SNB_SOCKET_PEAK_GFLOPS: f64 = 173.0;
pub const MIC_PEAK_GFLOPS: f64 = 1000.0;
pub const NODE_PEAK_GFLOPS: f64 = 173.0 + 1000.0; // one socket + MIC (§6)

/// Paper Table 6.1 anchors.
pub const BASELINE_1NODE_S: f64 = 408.0;
pub const OPTIMIZED_1NODE_S: f64 = 65.0;
pub const BASELINE_64NODE_S: f64 = 413.0;
pub const OPTIMIZED_64NODE_S: f64 = 74.0;
pub const PAPER_STEPS: usize = 118;
pub const PAPER_ELEMS_PER_NODE: usize = 8192;
pub const PAPER_ORDER: usize = 7;
pub const PAPER_MIC_RATIO: f64 = 1.6; // K_MIC / K_CPU at the optimum

/// Fig 4.1 baseline time fractions (volume majority, int_flux second; the
/// remaining kernels "significant enough to merit vectorization").
pub const BASELINE_FRACTIONS: [(super::kernels::PaperKernel, f64); 7] = [
    (VolumeLoop, 0.55),
    (IntFlux, 0.22),
    (InterpQ, 0.05),
    (Lift, 0.05),
    (Rk, 0.06),
    (BoundFlux, 0.02),
    (ParallelFlux, 0.05),
];

/// Baseline: 8 scalar MPI ranks on one socket (node-aggregate rates).
pub fn cpu_scalar() -> DeviceModel {
    DeviceModel::new(
        DeviceClass::CpuScalar,
        "snb-8xscalar",
        SNB_SOCKET_PEAK_GFLOPS,
        [
            (VolumeLoop, 4.9),
            (IntFlux, 4.55),
            (InterpQ, 0.82),
            (Lift, 1.64),
            (Rk, 3.64),
            (BoundFlux, 2.70),
            (ParallelFlux, 1.10),
        ],
    )
}

/// Optimized host: vectorized kernels on 8 OpenMP threads.
/// volume 2x / int_flux 5x over baseline per Fig 6.2; the bandwidth-bound
/// kernels (interp, lift, rk) gain ~4x from threading alone.
pub fn cpu_vector() -> DeviceModel {
    DeviceModel::new(
        DeviceClass::CpuVector,
        "snb-omp8-avx",
        SNB_SOCKET_PEAK_GFLOPS,
        [
            (VolumeLoop, 9.3),
            (IntFlux, 22.8),
            (InterpQ, 3.3),
            (Lift, 6.6),
            (Rk, 14.6),
            (BoundFlux, 13.5),
            (ParallelFlux, 5.5),
        ],
    )
}

/// The MIC, 120 threads: above the optimized CPU on every kernel except
/// parallel_flux (Fig 6.2 — its PCI-adjacent faces bottleneck the cores).
pub fn mic() -> DeviceModel {
    DeviceModel::new(
        DeviceClass::Mic,
        "knc-120t",
        MIC_PEAK_GFLOPS,
        [
            (VolumeLoop, 15.9),
            (IntFlux, 34.0),
            (InterpQ, 6.6),
            (Lift, 13.2),
            (Rk, 36.5),
            (BoundFlux, 20.0),
            (ParallelFlux, 2.75),
        ],
    )
}

/// PCI model fit to Fig 5.3: ~0.1 ms invocation floor, 6 GB/s in,
/// 5 GB/s out, ~5% sample scatter.
pub fn stampede_pci() -> PciModel {
    PciModel {
        latency_s: 1.0e-4,
        bw_to_device: 6.0e9,
        bw_from_device: 5.0e9,
        jitter_rel: 0.05,
    }
}

/// Network fit to the Table 6.1 scale-up (see network.rs).
pub fn stampede_node_network() -> NetworkModel {
    NetworkModel {
        alpha_s: 2.0e-4,
        beta_bytes_per_s: 3.0e9,
        jitter_base: 0.008,
        jitter_hetero: 0.18,
    }
}

/// The in-process fabric's "PCI": halo traces cross an mpsc channel, not a
/// bus — near-zero latency at memory bandwidth. Used when the balance solve
/// runs against *measured* in-process times instead of the Stampede fit.
pub fn fabric_pci() -> PciModel {
    PciModel { latency_s: 2.0e-6, bw_to_device: 2.0e10, bw_from_device: 2.0e10, jitter_rel: 0.0 }
}

/// Zero-jitter in-process "network" for cross-checking live cluster runs
/// against the simulator (all virtual nodes share one address space).
pub fn fabric_network() -> NetworkModel {
    NetworkModel { alpha_s: 1.0e-6, beta_bytes_per_s: 5.0e10, jitter_base: 0.0, jitter_hetero: 0.0 }
}

/// Refit a [`DeviceModel`] from kernel wall times measured over `steps`
/// timesteps on a `k`-element block at order `n`. Per-kernel counts use the
/// same ansatz as the balance solve (volume kernels ~ K, int_flux ~ 3K,
/// the two surface kernels ~ 6 K^(2/3)); kernels that measured no time (or
/// have no work at this K) inherit the `fallback` model's rate, so the
/// refit degrades gracefully for idle devices.
pub fn measured_device(
    class: DeviceClass,
    name: &'static str,
    n: usize,
    k: usize,
    steps: f64,
    times: &KernelTimes,
    fallback: &DeviceModel,
) -> DeviceModel {
    let surface = 6.0 * (k as f64).powf(2.0 / 3.0);
    let count = |kern: PaperKernel| -> f64 {
        match kern {
            IntFlux => 3.0 * k as f64,
            BoundFlux | ParallelFlux => surface,
            _ => k as f64,
        }
    };
    let rate = |kern: PaperKernel, secs: f64| -> (PaperKernel, f64) {
        let c = count(kern);
        let gf = if secs > 1e-9 && c > 0.0 && steps > 0.0 {
            work_flops(kern, n) * c * steps / secs / 1e9
        } else {
            fallback.rate(kern) / 1e9
        };
        (kern, gf)
    };
    DeviceModel::new(
        class,
        name,
        fallback.peak_gflops,
        [
            rate(VolumeLoop, times.volume_loop),
            rate(IntFlux, times.int_flux),
            rate(InterpQ, times.interp_q),
            rate(Lift, times.lift),
            rate(Rk, times.rk),
            rate(BoundFlux, times.bound_flux),
            rate(ParallelFlux, times.parallel_flux),
        ],
    )
}

/// A [`NodeModel`] refitted from one live node's measured per-worker kernel
/// times — the closed loop of the adaptive rebalancer: live `KernelTimes`
/// flow back into [`crate::partition::solve_mic_fraction`] through this
/// model. An accelerator worker that has not run yet (K_mic = 0) bootstraps
/// with the CPU worker's measured rates: both workers are in-process CPU
/// threads, so equal speed is the right prior for a first split.
///
/// The measured times come from the workers' persistent stage pools
/// ([`crate::util::pool::WorkerPool`]); with `ClusterSpec::pin_cores` set,
/// each pool is pinned to a disjoint core range, so the rates fitted here
/// reflect the *budgeted* contention (each worker on its own cores) rather
/// than whatever placement the scheduler happened to pick that window —
/// which is what makes the node-count scaling series comparable across
/// runs.
pub fn measured_node(
    n: usize,
    k_cpu: usize,
    k_mic: usize,
    steps: f64,
    cpu_times: &KernelTimes,
    mic_times: &KernelTimes,
) -> NodeModel {
    measured_node_with_pci(n, k_cpu, k_mic, steps, cpu_times, mic_times, fabric_pci())
}

/// [`measured_node`] with an explicit intra-node transfer model —
/// [`crate::costmodel::pci::PciModel::from_link`] over a probed fabric
/// lane ([`crate::coordinator::transport::measure_fabric_links`]) closes
/// the loop on *measured* links: the balance solve then prices the
/// CPU<->MIC exchange at what the active transport actually costs
/// instead of the default in-process guess.
#[allow(clippy::too_many_arguments)]
pub fn measured_node_with_pci(
    n: usize,
    k_cpu: usize,
    k_mic: usize,
    steps: f64,
    cpu_times: &KernelTimes,
    mic_times: &KernelTimes,
    pci: PciModel,
) -> NodeModel {
    let base = stampede_node();
    let cpu =
        measured_device(DeviceClass::CpuVector, "measured-cpu", n, k_cpu, steps, cpu_times, &base.cpu_vec);
    let mic = if k_mic > 0 && mic_times.total() > 1e-9 {
        measured_device(DeviceClass::Mic, "measured-mic", n, k_mic, steps, mic_times, &cpu)
    } else {
        let mk = |kern: PaperKernel| (kern, cpu.rate(kern) / 1e9);
        DeviceModel::new(
            DeviceClass::Mic,
            "measured-mic-bootstrap",
            cpu.peak_gflops,
            [
                mk(VolumeLoop),
                mk(IntFlux),
                mk(InterpQ),
                mk(Lift),
                mk(Rk),
                mk(BoundFlux),
                mk(ParallelFlux),
            ],
        )
    };
    NodeModel {
        cpu_scalar: base.cpu_scalar,
        cpu_vec: cpu,
        mic,
        pci,
        cores_per_socket: base.cores_per_socket,
    }
}

/// Measured per-element rate: busy wall seconds per element per timestep —
/// the level-1 weight of the two-level rebalancer
/// ([`crate::coordinator::rebalance`]). A node's `busy_per_step` is the max
/// over its concurrently-running workers; divided by the node's element
/// count it becomes the cost every element of that node's chunk carries
/// into [`crate::partition::splice_weighted`]. `None` until something was
/// measured.
pub fn measured_elem_rate(busy_per_step_s: f64, k_elems: usize) -> Option<f64> {
    if k_elems == 0 || !busy_per_step_s.is_finite() || busy_per_step_s <= 0.0 {
        None
    } else {
        Some(busy_per_step_s / k_elems as f64)
    }
}

/// The full Stampede node model.
pub fn stampede_node() -> NodeModel {
    NodeModel {
        cpu_scalar: cpu_scalar(),
        cpu_vec: cpu_vector(),
        mic: mic(),
        pci: stampede_pci(),
        cores_per_socket: 8,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::kernels::{work_flops, PaperKernel, ALL_KERNELS};

    /// The baseline calibration must reproduce the Fig 4.1 step budget:
    /// summing the seven kernels at paper counts gives ~3.46 s/step.
    #[test]
    fn baseline_step_time_matches_table_6_1() {
        let dev = cpu_scalar();
        let n = PAPER_ORDER;
        let k = PAPER_ELEMS_PER_NODE;
        // face counts for a ~20^3 brick chunk, morton-spliced 8 ways
        let int_faces = 3 * k; // interior face approximation
        let bound_faces = (6.0 * (k as f64).powf(2.0 / 3.0)) as usize;
        let par_faces = 2500; // inter-rank faces inside the node (baseline)
        let t = dev.step_time(n, k, int_faces, bound_faces, par_faces);
        let target = BASELINE_1NODE_S / PAPER_STEPS as f64;
        assert!(
            (t - target).abs() / target < 0.30,
            "baseline step {t:.3} s vs paper {target:.3} s"
        );
    }

    /// Fig 6.2 anchors: volume 2x, int_flux 5x CPU-opt over baseline.
    #[test]
    fn fig62_cpu_speedups() {
        let b = cpu_scalar();
        let v = cpu_vector();
        let rv = v.rate(PaperKernel::VolumeLoop) / b.rate(PaperKernel::VolumeLoop);
        let rf = v.rate(PaperKernel::IntFlux) / b.rate(PaperKernel::IntFlux);
        // bar-chart read tolerance: the Table 6.1 wall-time anchor pulls
        // the fitted volume rate to 1.9x
        assert!((rv - 2.0).abs() < 0.15, "volume speedup {rv}");
        assert!((rf - 5.0).abs() < 0.15, "int_flux speedup {rf}");
    }

    /// Fig 6.2: MIC beats optimized CPU everywhere except parallel_flux.
    #[test]
    fn fig62_mic_relation() {
        let v = cpu_vector();
        let m = mic();
        for k in ALL_KERNELS {
            if k == PaperKernel::ParallelFlux {
                assert!(m.rate(k) < v.rate(k), "{k:?}");
            } else {
                assert!(m.rate(k) > v.rate(k), "{k:?}");
            }
        }
    }

    /// The worked example from the module docs: baseline volume work.
    #[test]
    fn volume_work_consistency() {
        let w = work_flops(PaperKernel::VolumeLoop, 7);
        assert!((w / 1.139e6 - 1.0).abs() < 0.01, "volume work {w}");
    }

    /// The measured-rate refit must reproduce the throughput it was fed and
    /// fall back to the reference model for kernels that measured nothing.
    #[test]
    fn measured_device_recovers_rates() {
        let times = KernelTimes {
            volume_loop: 1e-3,
            int_flux: 1e-3,
            interp_q: 1e-4,
            lift: 1e-4,
            rk: 1e-4,
            bound_flux: 0.0, // unmeasured
            parallel_flux: 1e-4,
        };
        let dev =
            measured_device(DeviceClass::CpuVector, "m", 2, 100, 1.0, &times, &cpu_vector());
        let expect = work_flops(PaperKernel::VolumeLoop, 2) * 100.0 / 1e-3;
        assert!((dev.rate(PaperKernel::VolumeLoop) / expect - 1.0).abs() < 1e-9);
        assert_eq!(
            dev.rate(PaperKernel::BoundFlux),
            cpu_vector().rate(PaperKernel::BoundFlux),
            "unmeasured kernel inherits the fallback rate"
        );
    }

    /// Two workers measured at identical rates solve to a near-even split
    /// (the in-process fabric's PCI term is nearly free).
    #[test]
    fn measured_node_balances_equal_workers() {
        let t = KernelTimes {
            volume_loop: 2e-3,
            int_flux: 1e-3,
            interp_q: 2e-4,
            lift: 2e-4,
            rk: 3e-4,
            bound_flux: 1e-4,
            parallel_flux: 1e-4,
        };
        let node = measured_node(2, 100, 100, 1.0, &t, &t);
        let sol = crate::partition::solve_mic_fraction(&node, 2, 200);
        assert!((80..=115).contains(&sol.k_mic), "k_mic {}", sol.k_mic);
        // an unmeasured accelerator bootstraps from the CPU rates
        let boot = measured_node(2, 200, 0, 1.0, &t, &KernelTimes::default());
        let sol2 = crate::partition::solve_mic_fraction(&boot, 2, 200);
        assert!(sol2.k_mic > 50, "bootstrap split k_mic {}", sol2.k_mic);
    }

    /// The level-1 rate helper: simple quotient with guarded degenerate
    /// inputs (nothing measured, empty worker, non-finite timer).
    #[test]
    fn measured_elem_rate_guards() {
        let r = measured_elem_rate(2.0e-3, 100).unwrap();
        assert!((r / 2.0e-5 - 1.0).abs() < 1e-12, "{r}");
        assert_eq!(measured_elem_rate(0.0, 100), None);
        assert_eq!(measured_elem_rate(1.0, 0), None);
        assert_eq!(measured_elem_rate(f64::NAN, 100), None);
        assert_eq!(measured_elem_rate(-1.0, 100), None);
    }

    /// Measured-link constructors flow probe numbers straight into the
    /// models, and the node refit accepts an explicit PCI model.
    #[test]
    fn measured_link_calibration() {
        use crate::coordinator::transport::LinkMeasurement;
        use crate::costmodel::network::NetworkModel;
        let link = LinkMeasurement { latency_s: 3.0e-6, bw_bytes_per_s: 8.0e9 };
        let net = NetworkModel::from_link(link);
        assert_eq!(net.alpha_s, 3.0e-6);
        assert_eq!(net.beta_bytes_per_s, 8.0e9);
        assert_eq!(net.straggler_factor(64, true), 1.0, "measured links carry no jitter fit");
        let pci = PciModel::from_link(link);
        assert_eq!(pci.bw_to_device, pci.bw_from_device, "in-memory lanes are symmetric");
        let t = KernelTimes { volume_loop: 1e-3, ..Default::default() };
        let node = measured_node_with_pci(2, 100, 100, 1.0, &t, &t, pci);
        assert_eq!(node.pci.latency_s, 3.0e-6);
    }

    /// Load balance: with these rates the equal-time split lands near the
    /// paper's K_MIC/K_CPU = 1.6 (the balance solver test asserts tighter).
    #[test]
    fn rough_mic_ratio() {
        let node = stampede_node();
        let n = PAPER_ORDER;
        // per-element step time on each device (volume kernels only,
        // faces scale along): crude ratio check
        let t_cpu = node.cpu_vec.step_time(n, 1000, 3000, 0, 0);
        let t_mic = node.mic.step_time(n, 1000, 3000, 0, 0);
        let ratio = t_cpu / t_mic;
        assert!(
            (1.3..2.1).contains(&ratio),
            "per-element MIC/CPU advantage {ratio}"
        );
    }
}
