//! Stampede calibration constants.
//!
//! Every number the paper reports participates in the fit:
//!
//! * §5.2 hardware: SNB socket 173 GF peak (8 cores x 2.7 GHz x 8 DP
//!   flops/cycle), MIC 1.0 TF peak, CPU memory BW 51.2 GB/s, MIC 320 GB/s.
//! * Fig 4.1 (baseline profile): volume_loop is the majority of runtime
//!   with int_flux second; we use the fractions
//!   {volume .55, int_flux .22, interp .05, lift .05, rk .06, bound .02,
//!   parallel .05} of the measured 3.458 s/step baseline node time
//!   (408 s / 118 steps, Table 6.1).
//! * Fig 6.2 (per-kernel speedups): optimized-CPU vs baseline 2x for
//!   volume_loop, 5x for int_flux; MIC above optimized-CPU for every
//!   kernel except parallel_flux.
//! * §6: the balanced split K_MIC/K_CPU = 1.6 at N=7, K=8192.
//! * Fig 5.3: PCI latency floor + ~6 GB/s saturation.
//!
//! Derivation of the baseline volume rate, as a worked example: the node
//! step budget is 3.458 s of which 55% = 1.902 s is volume_loop; the work
//! is 8192 elem x 1.139 Mflop/elem/step = 9.33 GF, giving 4.9 GF/s across
//! 8 scalar cores = 0.61 GF/s/core = 11% of scalar peak — a plausible
//! unvectorized -O3 figure, which is the consistency check that the
//! paper's numbers and our work formulas agree.

use super::device::{DeviceClass, DeviceModel};
use super::kernels::PaperKernel::*;
use super::network::NetworkModel;
use super::pci::PciModel;
use super::NodeModel;

/// Theoretical peaks (paper §5.2), double precision.
pub const SNB_SOCKET_PEAK_GFLOPS: f64 = 173.0;
pub const MIC_PEAK_GFLOPS: f64 = 1000.0;
pub const NODE_PEAK_GFLOPS: f64 = 173.0 + 1000.0; // one socket + MIC (§6)

/// Paper Table 6.1 anchors.
pub const BASELINE_1NODE_S: f64 = 408.0;
pub const OPTIMIZED_1NODE_S: f64 = 65.0;
pub const BASELINE_64NODE_S: f64 = 413.0;
pub const OPTIMIZED_64NODE_S: f64 = 74.0;
pub const PAPER_STEPS: usize = 118;
pub const PAPER_ELEMS_PER_NODE: usize = 8192;
pub const PAPER_ORDER: usize = 7;
pub const PAPER_MIC_RATIO: f64 = 1.6; // K_MIC / K_CPU at the optimum

/// Fig 4.1 baseline time fractions (volume majority, int_flux second; the
/// remaining kernels "significant enough to merit vectorization").
pub const BASELINE_FRACTIONS: [(super::kernels::PaperKernel, f64); 7] = [
    (VolumeLoop, 0.55),
    (IntFlux, 0.22),
    (InterpQ, 0.05),
    (Lift, 0.05),
    (Rk, 0.06),
    (BoundFlux, 0.02),
    (ParallelFlux, 0.05),
];

/// Baseline: 8 scalar MPI ranks on one socket (node-aggregate rates).
pub fn cpu_scalar() -> DeviceModel {
    DeviceModel::new(
        DeviceClass::CpuScalar,
        "snb-8xscalar",
        SNB_SOCKET_PEAK_GFLOPS,
        [
            (VolumeLoop, 4.9),
            (IntFlux, 4.55),
            (InterpQ, 0.82),
            (Lift, 1.64),
            (Rk, 3.64),
            (BoundFlux, 2.70),
            (ParallelFlux, 1.10),
        ],
    )
}

/// Optimized host: vectorized kernels on 8 OpenMP threads.
/// volume 2x / int_flux 5x over baseline per Fig 6.2; the bandwidth-bound
/// kernels (interp, lift, rk) gain ~4x from threading alone.
pub fn cpu_vector() -> DeviceModel {
    DeviceModel::new(
        DeviceClass::CpuVector,
        "snb-omp8-avx",
        SNB_SOCKET_PEAK_GFLOPS,
        [
            (VolumeLoop, 9.3),
            (IntFlux, 22.8),
            (InterpQ, 3.3),
            (Lift, 6.6),
            (Rk, 14.6),
            (BoundFlux, 13.5),
            (ParallelFlux, 5.5),
        ],
    )
}

/// The MIC, 120 threads: above the optimized CPU on every kernel except
/// parallel_flux (Fig 6.2 — its PCI-adjacent faces bottleneck the cores).
pub fn mic() -> DeviceModel {
    DeviceModel::new(
        DeviceClass::Mic,
        "knc-120t",
        MIC_PEAK_GFLOPS,
        [
            (VolumeLoop, 15.9),
            (IntFlux, 34.0),
            (InterpQ, 6.6),
            (Lift, 13.2),
            (Rk, 36.5),
            (BoundFlux, 20.0),
            (ParallelFlux, 2.75),
        ],
    )
}

/// PCI model fit to Fig 5.3: ~0.1 ms invocation floor, 6 GB/s in,
/// 5 GB/s out, ~5% sample scatter.
pub fn stampede_pci() -> PciModel {
    PciModel {
        latency_s: 1.0e-4,
        bw_to_device: 6.0e9,
        bw_from_device: 5.0e9,
        jitter_rel: 0.05,
    }
}

/// Network fit to the Table 6.1 scale-up (see network.rs).
pub fn stampede_node_network() -> NetworkModel {
    NetworkModel {
        alpha_s: 2.0e-4,
        beta_bytes_per_s: 3.0e9,
        jitter_base: 0.008,
        jitter_hetero: 0.18,
    }
}

/// The full Stampede node model.
pub fn stampede_node() -> NodeModel {
    NodeModel {
        cpu_scalar: cpu_scalar(),
        cpu_vec: cpu_vector(),
        mic: mic(),
        pci: stampede_pci(),
        cores_per_socket: 8,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::kernels::{work_flops, PaperKernel, ALL_KERNELS};

    /// The baseline calibration must reproduce the Fig 4.1 step budget:
    /// summing the seven kernels at paper counts gives ~3.46 s/step.
    #[test]
    fn baseline_step_time_matches_table_6_1() {
        let dev = cpu_scalar();
        let n = PAPER_ORDER;
        let k = PAPER_ELEMS_PER_NODE;
        // face counts for a ~20^3 brick chunk, morton-spliced 8 ways
        let int_faces = 3 * k; // interior face approximation
        let bound_faces = (6.0 * (k as f64).powf(2.0 / 3.0)) as usize;
        let par_faces = 2500; // inter-rank faces inside the node (baseline)
        let t = dev.step_time(n, k, int_faces, bound_faces, par_faces);
        let target = BASELINE_1NODE_S / PAPER_STEPS as f64;
        assert!(
            (t - target).abs() / target < 0.30,
            "baseline step {t:.3} s vs paper {target:.3} s"
        );
    }

    /// Fig 6.2 anchors: volume 2x, int_flux 5x CPU-opt over baseline.
    #[test]
    fn fig62_cpu_speedups() {
        let b = cpu_scalar();
        let v = cpu_vector();
        let rv = v.rate(PaperKernel::VolumeLoop) / b.rate(PaperKernel::VolumeLoop);
        let rf = v.rate(PaperKernel::IntFlux) / b.rate(PaperKernel::IntFlux);
        // bar-chart read tolerance: the Table 6.1 wall-time anchor pulls
        // the fitted volume rate to 1.9x
        assert!((rv - 2.0).abs() < 0.15, "volume speedup {rv}");
        assert!((rf - 5.0).abs() < 0.15, "int_flux speedup {rf}");
    }

    /// Fig 6.2: MIC beats optimized CPU everywhere except parallel_flux.
    #[test]
    fn fig62_mic_relation() {
        let v = cpu_vector();
        let m = mic();
        for k in ALL_KERNELS {
            if k == PaperKernel::ParallelFlux {
                assert!(m.rate(k) < v.rate(k), "{k:?}");
            } else {
                assert!(m.rate(k) > v.rate(k), "{k:?}");
            }
        }
    }

    /// The worked example from the module docs: baseline volume work.
    #[test]
    fn volume_work_consistency() {
        let w = work_flops(PaperKernel::VolumeLoop, 7);
        assert!((w / 1.139e6 - 1.0).abs() < 0.01, "volume work {w}");
    }

    /// Load balance: with these rates the equal-time split lands near the
    /// paper's K_MIC/K_CPU = 1.6 (the balance solver test asserts tighter).
    #[test]
    fn rough_mic_ratio() {
        let node = stampede_node();
        let n = PAPER_ORDER;
        // per-element step time on each device (volume kernels only,
        // faces scale along): crude ratio check
        let t_cpu = node.cpu_vec.step_time(n, 1000, 3000, 0, 0);
        let t_mic = node.mic.step_time(n, 1000, 3000, 0, 0);
        let ratio = t_cpu / t_mic;
        assert!(
            (1.3..2.1).contains(&ratio),
            "per-element MIC/CPU advantage {ratio}"
        );
    }
}
