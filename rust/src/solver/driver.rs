//! Multi-block time-stepping driver.
//!
//! Owns the per-owner [`BlockState`]s and an [`ExchangePlan`]; advances the
//! coupled system stage by stage: every block computes one LSRK stage
//! (through whatever [`StageBackend`] it was given — pure rust or a PJRT
//! executable), then halo traces are exchanged so the next stage sees
//! same-stage neighbor data. This is the numerically-exact schedule; the
//! *simulated* once-per-step PCI accounting of the paper lives in
//! [`crate::sim`], not here.

use std::collections::HashMap;

use super::basis::LglBasis;
use super::exchange::apply_exchange;
use super::reference::{stage as ref_stage, KernelTimes, RefScratch};
use super::rk::{LSRK_A, LSRK_B, N_STAGES};
use super::state::BlockState;
use crate::mesh::ExchangePlan;
use crate::Result;

/// Anything that can advance one block by one LSRK stage.
pub trait StageBackend {
    fn stage(&mut self, st: &mut BlockState, dt: f32, a: f32, b: f32) -> Result<KernelTimes>;
    fn name(&self) -> &'static str;
}

/// The pure-rust reference backend (scalar CPU kernels).
pub struct RustRefBackend {
    basis: LglBasis,
    scratch: HashMap<(usize, usize), RefScratch>,
}

impl RustRefBackend {
    pub fn new(order: usize) -> Self {
        RustRefBackend { basis: LglBasis::new(order), scratch: HashMap::new() }
    }
}

impl StageBackend for RustRefBackend {
    fn stage(&mut self, st: &mut BlockState, dt: f32, a: f32, b: f32) -> Result<KernelTimes> {
        let key = (st.k_pad, st.m);
        let scratch = self
            .scratch
            .entry(key)
            .or_insert_with(|| RefScratch::new(st));
        Ok(ref_stage(st, &self.basis, scratch, dt, a, b))
    }

    fn name(&self) -> &'static str {
        "rust-ref"
    }
}

/// The coupled multi-block system.
pub struct Driver {
    pub blocks: Vec<BlockState>,
    pub plan: ExchangePlan,
    pub backends: Vec<Box<dyn StageBackend>>,
    pub basis: LglBasis,
    /// Accumulated per-kernel wall times per block.
    pub times: Vec<KernelTimes>,
    pub steps_taken: usize,
}

impl Driver {
    /// One backend per block (blocks and backends are index-aligned).
    pub fn new(
        blocks: Vec<BlockState>,
        plan: ExchangePlan,
        backends: Vec<Box<dyn StageBackend>>,
        order: usize,
    ) -> Self {
        assert_eq!(blocks.len(), backends.len());
        let n = blocks.len();
        Driver {
            blocks,
            plan,
            backends,
            basis: LglBasis::new(order),
            times: vec![KernelTimes::default(); n],
            steps_taken: 0,
        }
    }

    /// Prime the halos from current traces (call once after ICs).
    pub fn prime(&mut self) {
        for b in self.blocks.iter_mut() {
            b.refresh_traces();
        }
        apply_exchange(&mut self.blocks, &self.plan);
    }

    /// Advance one full LSRK timestep.
    pub fn step(&mut self, dt: f64) -> Result<()> {
        for s in 0..N_STAGES {
            let (a, b) = (LSRK_A[s] as f32, LSRK_B[s] as f32);
            for (i, blk) in self.blocks.iter_mut().enumerate() {
                let t = self.backends[i].stage(blk, dt as f32, a, b)?;
                acc(&mut self.times[i], &t);
            }
            apply_exchange(&mut self.blocks, &self.plan);
        }
        self.steps_taken += 1;
        Ok(())
    }

    /// Advance `n` steps.
    pub fn run(&mut self, dt: f64, n: usize) -> Result<()> {
        for _ in 0..n {
            self.step(dt)?;
        }
        Ok(())
    }

    /// Total energy over all blocks.
    pub fn energy(&self) -> f64 {
        self.blocks.iter().map(|b| b.energy(&self.basis)).sum()
    }

    /// Global relative L2 error against an exact solution.
    pub fn rel_l2_error(&self, exact: impl Fn([f64; 3]) -> [f64; 9] + Copy) -> f64 {
        // combine per-block num/den via errors weighted by dof counts:
        // recompute directly for exactness
        let mut num = 0.0;
        let mut den = 0.0;
        for b in &self.blocks {
            let e = b.rel_l2_error(&self.basis, exact);
            // rel = sqrt(num/den); recover num, den via den from exact norm
            let d = block_exact_norm2(b, &self.basis, exact);
            num += e * e * d;
            den += d;
        }
        (num / den.max(1e-300)).sqrt()
    }

    /// Summed kernel-time breakdown across blocks.
    pub fn total_times(&self) -> KernelTimes {
        let mut out = KernelTimes::default();
        for t in &self.times {
            acc(&mut out, t);
        }
        out
    }
}

fn acc(into: &mut KernelTimes, from: &KernelTimes) {
    into.volume_loop += from.volume_loop;
    into.int_flux += from.int_flux;
    into.interp_q += from.interp_q;
    into.lift += from.lift;
    into.rk += from.rk;
    into.bound_flux += from.bound_flux;
    into.parallel_flux += from.parallel_flux;
}

fn block_exact_norm2(
    b: &BlockState,
    basis: &LglBasis,
    exact: impl Fn([f64; 3]) -> [f64; 9],
) -> f64 {
    let m = b.m;
    let vol = m * m * m;
    let mut den = 0.0;
    for e in 0..b.k_real {
        let coords = b.node_coords(e, basis);
        for &x in coords.iter().take(vol) {
            for v in exact(x) {
                den += v * v;
            }
        }
    }
    den
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::{build_local_blocks, geometry::unit_cube_geometry};
    use crate::solver::analytic::standing_wave;

    /// The decisive split-consistency test: a 2-block run must match the
    /// monolithic single-block run to f32 roundoff, which proves the halo
    /// plumbing end to end.
    #[test]
    fn split_matches_monolithic() {
        let order = 2;
        let n = 2;
        let w = std::f64::consts::PI * 3f64.sqrt();
        let dt = 2e-3;

        let run = |owners: Vec<usize>, n_owners: usize| -> Vec<f32> {
            let mesh = unit_cube_geometry(n);
            let (lblocks, plan) = build_local_blocks(&mesh, &owners, n_owners);
            let basis = LglBasis::new(order);
            let mut blocks: Vec<BlockState> = lblocks
                .iter()
                .map(|b| BlockState::from_local_block(b, order, b.len().max(1), b.halo_len.max(1)))
                .collect();
            for b in blocks.iter_mut() {
                b.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
            }
            let backends: Vec<Box<dyn StageBackend>> = (0..n_owners)
                .map(|_| Box::new(RustRefBackend::new(order)) as Box<dyn StageBackend>)
                .collect();
            let mut drv = Driver::new(blocks, plan, backends, order);
            drv.prime();
            drv.run(dt, 5).unwrap();
            // reassemble global q in owner-then-local order keyed by global id
            let mut out: Vec<(usize, Vec<f32>)> = Vec::new();
            for (bi, lb) in lblocks.iter().enumerate() {
                let st = &drv.blocks[bi];
                let vol = st.m * st.m * st.m;
                for (li, &g) in lb.global_ids.iter().enumerate() {
                    out.push((g, st.q[li * 9 * vol..(li + 1) * 9 * vol].to_vec()));
                }
            }
            out.sort_by_key(|x| x.0);
            out.into_iter().flat_map(|x| x.1).collect()
        };

        let mono = run(vec![0usize; 8], 1);
        let split = run((0..8).map(|e| e % 2).collect(), 2);
        assert_eq!(mono.len(), split.len());
        let max_diff = mono
            .iter()
            .zip(&split)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 5e-6, "split vs monolithic diff {max_diff}");
    }

    #[test]
    fn energy_decays_across_blocks() {
        let order = 2;
        let mesh = unit_cube_geometry(2);
        let owners: Vec<usize> = (0..8).map(|e| e / 4).collect();
        let (lblocks, plan) = build_local_blocks(&mesh, &owners, 2);
        let basis = LglBasis::new(order);
        let w = std::f64::consts::PI * 3f64.sqrt();
        let mut blocks: Vec<BlockState> = lblocks
            .iter()
            .map(|b| BlockState::from_local_block(b, order, b.len(), b.halo_len.max(1)))
            .collect();
        for b in blocks.iter_mut() {
            b.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
        }
        let backends: Vec<Box<dyn StageBackend>> = (0..2)
            .map(|_| Box::new(RustRefBackend::new(order)) as Box<dyn StageBackend>)
            .collect();
        let mut drv = Driver::new(blocks, plan, backends, order);
        drv.prime();
        let e0 = drv.energy();
        drv.run(1e-3, 20).unwrap();
        let e1 = drv.energy();
        assert!(e1 <= e0 * (1.0 + 1e-6), "{e0} -> {e1}");
        assert!(e1 > 0.9 * e0);
    }
}
