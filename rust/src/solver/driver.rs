//! Multi-block time-stepping driver.
//!
//! Owns the per-owner [`BlockState`]s and an [`ExchangePlan`]; advances the
//! coupled system stage by stage. Two schedules, both numerically exact:
//!
//! * **serial** (`overlap = false`, the seed behavior): every block
//!   computes one full LSRK stage, then halo traces are exchanged
//!   synchronously so the next stage sees same-stage neighbor data.
//! * **overlapped** (`overlap = true`): each block first advances only its
//!   *boundary* elements (the level-2 nested split of
//!   [`crate::partition::nested`], applied in-node), the outbound traces
//!   are gathered, and then the halo scatter runs on a persistent comm
//!   thread ([`crate::util::pool::TaskThread`], created once per driver)
//!   **concurrently** with the interior-element sweeps — the paper's
//!   compute/communication overlap (Fig 4.1) realized inside the CPU
//!   backend. Backends that don't implement the split
//!   ([`StageBackend::supports_overlap`] = false) degrade gracefully: they
//!   run their full stage in the boundary slot and a no-op interior phase.
//!
//! The *simulated* once-per-step PCI accounting of the paper lives in
//! [`crate::sim`], not here.

use std::collections::HashMap;

use super::basis::LglBasis;
use super::exchange::{apply_exchange, gather_exchange, scatter_exchange, ExchangeStaging};
use super::reference::{stage as ref_stage, KernelTimes, RefScratch};
use super::rk::{LSRK_A, LSRK_B, N_STAGES};
use super::state::{BlockState, InteriorView, NFIELDS};
use crate::mesh::ExchangePlan;
use crate::util::pool::TaskThread;
use crate::Result;

/// Anything that can advance one block by one LSRK stage.
///
/// The split-phase methods exist for the overlapped schedule; the default
/// implementations make any backend correct under it (full stage in the
/// boundary phase, no interior phase), so only backends that really split
/// — e.g. [`super::parallel::ParallelRefBackend`] — opt in via
/// [`StageBackend::supports_overlap`].
pub trait StageBackend {
    fn stage(&mut self, st: &mut BlockState, dt: f32, a: f32, b: f32) -> Result<KernelTimes>;
    fn name(&self) -> &'static str;

    /// Whether `stage_boundary`/`stage_interior` implement the real
    /// boundary/interior split. [`Driver::step`] consults this: with
    /// `overlap = true` it only pays for the gather/scatter staging when
    /// at least one backend actually splits (the default methods make the
    /// overlapped schedule *correct* for any backend either way).
    fn supports_overlap(&self) -> bool {
        false
    }

    /// Advance the boundary elements (everything that owns halo faces) so
    /// that afterwards every outbound trace of the exchange plan is final.
    /// Default: the whole stage.
    fn stage_boundary(
        &mut self,
        st: &mut BlockState,
        dt: f32,
        a: f32,
        b: f32,
    ) -> Result<KernelTimes> {
        self.stage(st, dt, a, b)
    }

    /// Advance the interior elements on a halo-less view while the halo is
    /// (possibly) being rewritten concurrently. Default: no-op.
    fn stage_interior(
        &mut self,
        v: &mut InteriorView<'_>,
        dt: f32,
        a: f32,
        b: f32,
    ) -> Result<KernelTimes> {
        let _ = (v, dt, a, b);
        Ok(KernelTimes::default())
    }

    /// Generation id of the backend's persistent worker pool
    /// ([`crate::util::pool::WorkerPool::generation`]); `None` for
    /// backends without one. The cluster runtime surfaces it so tests can
    /// assert that a rebalance keeping a worker's blocks also keeps its
    /// pool alive.
    fn pool_generation(&self) -> Option<u64> {
        None
    }

    /// How many times the backend computed its boundary/interior
    /// classification (memoizing backends stay flat once warm; backends
    /// without a classification report 0).
    fn classify_computes(&self) -> u64 {
        0
    }
}

/// The pure-rust reference backend (scalar CPU kernels).
pub struct RustRefBackend {
    basis: LglBasis,
    scratch: HashMap<(usize, usize), RefScratch>,
}

impl RustRefBackend {
    pub fn new(order: usize) -> Self {
        RustRefBackend { basis: LglBasis::new(order), scratch: HashMap::new() }
    }
}

impl StageBackend for RustRefBackend {
    fn stage(&mut self, st: &mut BlockState, dt: f32, a: f32, b: f32) -> Result<KernelTimes> {
        let key = (st.k_pad, st.m);
        let scratch = self
            .scratch
            .entry(key)
            .or_insert_with(|| RefScratch::new(st));
        Ok(ref_stage(st, &self.basis, scratch, dt, a, b))
    }

    fn name(&self) -> &'static str {
        "rust-ref"
    }
}

/// The coupled multi-block system.
pub struct Driver {
    pub blocks: Vec<BlockState>,
    pub plan: ExchangePlan,
    pub backends: Vec<Box<dyn StageBackend>>,
    pub basis: LglBasis,
    /// Accumulated per-kernel wall times per block.
    pub times: Vec<KernelTimes>,
    pub steps_taken: usize,
    /// Use the overlapped boundary/interior schedule (see module docs).
    pub overlap: bool,
    staging: ExchangeStaging,
    /// Persistent thread for the overlapped halo scatter, created on the
    /// first overlapped step — after that warmup no OS thread is ever
    /// created per stage (the backends' pools are equally persistent).
    comm: Option<TaskThread>,
}

impl Driver {
    /// One backend per block (blocks and backends are index-aligned).
    pub fn new(
        blocks: Vec<BlockState>,
        plan: ExchangePlan,
        backends: Vec<Box<dyn StageBackend>>,
        order: usize,
    ) -> Self {
        assert_eq!(blocks.len(), backends.len());
        let n = blocks.len();
        Driver {
            blocks,
            plan,
            backends,
            basis: LglBasis::new(order),
            times: vec![KernelTimes::default(); n],
            steps_taken: 0,
            overlap: false,
            staging: ExchangeStaging::default(),
            comm: None,
        }
    }

    /// Prime the halos from current traces (call once after ICs).
    pub fn prime(&mut self) {
        for b in self.blocks.iter_mut() {
            b.refresh_traces();
        }
        apply_exchange(&mut self.blocks, &self.plan);
    }

    /// Advance one full LSRK timestep. One shared stage loop serves both
    /// schedules: per stage, phase 1 advances every block (the full stage
    /// serially, or just its boundary elements when overlapping), then the
    /// halo exchange runs — synchronously after phase 1, or on the
    /// persistent comm thread *concurrently* with the interior sweeps. The
    /// overlap
    /// variant differs only in that gather/scatter step; all RK
    /// bookkeeping (stage coefficients, time accounting, step counting) is
    /// common.
    pub fn step(&mut self, dt: f64) -> Result<()> {
        let overlap = self.overlap && self.backends.iter().any(|b| b.supports_overlap());
        for s in 0..N_STAGES {
            let (a, b) = (LSRK_A[s] as f32, LSRK_B[s] as f32);
            // phase 1: full stage (serial) or boundary-only (overlapped);
            // either way every outbound trace is final afterwards
            for (i, blk) in self.blocks.iter_mut().enumerate() {
                let t = if overlap {
                    self.backends[i].stage_boundary(blk, dt as f32, a, b)?
                } else {
                    self.backends[i].stage(blk, dt as f32, a, b)?
                };
                self.times[i].accumulate(&t);
            }
            // phase 2: the exchange, overlapped with interior compute when
            // the backends support the split
            if overlap {
                self.exchange_overlapped(dt as f32, a, b)?;
            } else {
                apply_exchange(&mut self.blocks, &self.plan);
            }
        }
        self.steps_taken += 1;
        Ok(())
    }

    /// The overlapped exchange of one stage: gather outbound traces, then
    /// scatter them into neighbor halos on the persistent comm thread
    /// while the interior sweeps compute. The comm thread is created once
    /// (first overlapped stage) and reused — after that warmup no OS
    /// thread is spawned per stage anywhere on the hot path.
    fn exchange_overlapped(&mut self, dt: f32, a: f32, b: f32) -> Result<()> {
        let sz = NFIELDS * self.basis.m() * self.basis.m();
        gather_exchange(&self.blocks, &self.plan, &mut self.staging);
        if self.comm.is_none() {
            self.comm = Some(TaskThread::new("driver-comm"));
        }
        let mut halos: Vec<&mut [f32]> = Vec::new();
        let mut views: Vec<InteriorView<'_>> = Vec::new();
        for blk in self.blocks.iter_mut() {
            let (v, h) = blk.split_for_overlap();
            views.push(v);
            halos.push(h);
        }
        let staging = &self.staging;
        let backends = &mut self.backends;
        let times = &mut self.times;
        let comm = self.comm.as_mut().expect("created above");
        // SAFETY: the guard is joined below on this frame, before any of
        // the borrows the scatter task captures can end.
        let guard = unsafe { comm.run_scoped(move || scatter_exchange(&mut halos, sz, staging)) };
        let mut result = Ok(());
        for (i, v) in views.iter_mut().enumerate() {
            match backends[i].stage_interior(v, dt, a, b) {
                Ok(t) => times[i].accumulate(&t),
                Err(e) => {
                    result = Err(e);
                    break;
                }
            }
        }
        guard.join();
        result
    }

    /// Advance `n` steps.
    pub fn run(&mut self, dt: f64, n: usize) -> Result<()> {
        for _ in 0..n {
            self.step(dt)?;
        }
        Ok(())
    }

    /// Total energy over all blocks.
    pub fn energy(&self) -> f64 {
        self.blocks.iter().map(|b| b.energy(&self.basis)).sum()
    }

    /// Global relative L2 error against an exact solution.
    pub fn rel_l2_error(&self, exact: impl Fn([f64; 3]) -> [f64; 9] + Copy) -> f64 {
        // combine per-block num/den via errors weighted by dof counts:
        // recompute directly for exactness
        let mut num = 0.0;
        let mut den = 0.0;
        for b in &self.blocks {
            let e = b.rel_l2_error(&self.basis, exact);
            // rel = sqrt(num/den); recover num, den via den from exact norm
            let d = block_exact_norm2(b, &self.basis, exact);
            num += e * e * d;
            den += d;
        }
        (num / den.max(1e-300)).sqrt()
    }

    /// Summed kernel-time breakdown across blocks.
    pub fn total_times(&self) -> KernelTimes {
        let mut out = KernelTimes::default();
        for t in &self.times {
            out.accumulate(t);
        }
        out
    }
}

fn block_exact_norm2(
    b: &BlockState,
    basis: &LglBasis,
    exact: impl Fn([f64; 3]) -> [f64; 9],
) -> f64 {
    let m = b.m;
    let vol = m * m * m;
    let mut den = 0.0;
    for e in 0..b.k_real {
        let coords = b.node_coords(e, basis);
        for &x in coords.iter().take(vol) {
            for v in exact(x) {
                den += v * v;
            }
        }
    }
    den
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::{build_local_blocks, geometry::unit_cube_geometry};
    use crate::solver::analytic::standing_wave;
    use crate::solver::parallel::ParallelRefBackend;

    /// The decisive split-consistency test: a 2-block run must match the
    /// monolithic single-block run to f32 roundoff, which proves the halo
    /// plumbing end to end.
    #[test]
    fn split_matches_monolithic() {
        let order = 2;
        let n = 2;
        let w = std::f64::consts::PI * 3f64.sqrt();
        let dt = 2e-3;

        let run = |owners: Vec<usize>, n_owners: usize| -> Vec<f32> {
            let mesh = unit_cube_geometry(n);
            let (lblocks, plan) = build_local_blocks(&mesh, &owners, n_owners);
            let basis = LglBasis::new(order);
            let mut blocks: Vec<BlockState> = lblocks
                .iter()
                .map(|b| BlockState::from_local_block(b, order, b.len().max(1), b.halo_len.max(1)))
                .collect();
            for b in blocks.iter_mut() {
                b.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
            }
            let backends: Vec<Box<dyn StageBackend>> = (0..n_owners)
                .map(|_| Box::new(RustRefBackend::new(order)) as Box<dyn StageBackend>)
                .collect();
            let mut drv = Driver::new(blocks, plan, backends, order);
            drv.prime();
            drv.run(dt, 5).unwrap();
            // reassemble global q in owner-then-local order keyed by global id
            let mut out: Vec<(usize, Vec<f32>)> = Vec::new();
            for (bi, lb) in lblocks.iter().enumerate() {
                let st = &drv.blocks[bi];
                let vol = st.m * st.m * st.m;
                for (li, &g) in lb.global_ids.iter().enumerate() {
                    out.push((g, st.q[li * 9 * vol..(li + 1) * 9 * vol].to_vec()));
                }
            }
            out.sort_by_key(|x| x.0);
            out.into_iter().flat_map(|x| x.1).collect()
        };

        let mono = run(vec![0usize; 8], 1);
        let split = run((0..8).map(|e| e % 2).collect(), 2);
        assert_eq!(mono.len(), split.len());
        let max_diff = mono
            .iter()
            .zip(&split)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 5e-6, "split vs monolithic diff {max_diff}");
    }

    #[test]
    fn energy_decays_across_blocks() {
        let order = 2;
        let mesh = unit_cube_geometry(2);
        let owners: Vec<usize> = (0..8).map(|e| e / 4).collect();
        let basis = LglBasis::new(order);
        let w = std::f64::consts::PI * 3f64.sqrt();
        let (lblocks, plan) = build_local_blocks(&mesh, &owners, 2);
        let mut blocks: Vec<BlockState> = lblocks
            .iter()
            .map(|b| BlockState::from_local_block(b, order, b.len(), b.halo_len.max(1)))
            .collect();
        for b in blocks.iter_mut() {
            b.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
        }
        let backends: Vec<Box<dyn StageBackend>> = (0..2)
            .map(|_| Box::new(RustRefBackend::new(order)) as Box<dyn StageBackend>)
            .collect();
        let mut drv = Driver::new(blocks, plan, backends, order);
        drv.prime();
        let e0 = drv.energy();
        drv.run(1e-3, 20).unwrap();
        let e1 = drv.energy();
        assert!(e1 <= e0 * (1.0 + 1e-6), "{e0} -> {e1}");
        assert!(e1 > 0.9 * e0);
    }

    /// The overlapped schedule must be numerically identical to the serial
    /// one — both with the parallel backend (real split phases) and with
    /// the scalar backend (graceful degradation).
    #[test]
    fn overlapped_schedule_matches_serial() {
        let order = 2;
        let w = std::f64::consts::PI * 3f64.sqrt();
        let mesh = unit_cube_geometry(2);
        let owners: Vec<usize> = (0..8).map(|e| e / 4).collect();
        let run = |overlap: bool, parallel: bool| -> Vec<f32> {
            let (lblocks, plan) = build_local_blocks(&mesh, &owners, 2);
            let basis = LglBasis::new(order);
            let mut blocks: Vec<BlockState> = lblocks
                .iter()
                .map(|b| BlockState::from_local_block(b, order, b.len(), b.halo_len.max(1)))
                .collect();
            for b in blocks.iter_mut() {
                b.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
            }
            let backends: Vec<Box<dyn StageBackend>> = (0..2)
                .map(|_| -> Box<dyn StageBackend> {
                    if parallel {
                        Box::new(ParallelRefBackend::with_threads(order, 2))
                    } else {
                        Box::new(RustRefBackend::new(order))
                    }
                })
                .collect();
            let mut drv = Driver::new(blocks, plan, backends, order);
            drv.overlap = overlap;
            drv.prime();
            drv.run(1.5e-3, 4).unwrap();
            drv.blocks.iter().flat_map(|b| b.q.clone()).collect()
        };
        let serial_scalar = run(false, false);
        for (overlap, parallel) in [(true, false), (false, true), (true, true)] {
            let got = run(overlap, parallel);
            assert_eq!(
                serial_scalar, got,
                "overlap {overlap} parallel {parallel} must match the serial scalar schedule"
            );
        }
    }
}
