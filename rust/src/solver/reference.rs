//! Pure-rust reference implementation of the DGSEM stage.
//!
//! Math-identical to python/compile/model.py (same strong-form volume
//! term, exact Riemann fluxes, mirror BC, lift scaling and LSRK update).
//! Three roles:
//!
//! 1. end-to-end oracle for the PJRT artifact path (rust/tests),
//! 2. the "scalar CPU kernel" when profiling the paper's baseline on this
//!    machine (coordinator::profile) — its per-kernel timer split mirrors
//!    Fig 4.1's kernel taxonomy,
//! 3. a fallback backend when artifacts are absent.
//!
//! The hot path is factored per element ([`rhs_element`] over a borrowed
//! [`RhsCtx`]) so the multithreaded backend ([`super::parallel`]) can sweep
//! disjoint element sets from a thread pool while sharing these exact
//! kernels — scalar and parallel backends are bitwise-identical by
//! construction. The tensor-product derivative is restructured into
//! line-contiguous sweeps (axis 0/1 are contiguous axpy over face slabs /
//! rows) with monomorphized fast paths for m = 3, 4, 8, and the Riemann
//! face kernel is generic over the exterior-trace fetch so the mirror /
//! neighbor / halo cases are resolved outside the per-node loop instead of
//! materializing a copied trace.
//!
//! The innermost loops (axpy sweeps, axis-2 matvec, pointwise stress, RK
//! update, Riemann per-node math) dispatch through [`super::simd`]: explicit
//! AVX2/SSE2 vector bodies when the `simd` feature is on and the host
//! supports them, bitwise-identical scalar fallbacks otherwise. The lane
//! width rides along in [`RhsCtx`] so one read of the global dispatch
//! serves a whole sweep.

use std::time::Instant;

use super::basis::LglBasis;
use super::simd::{self, Lanes};
use super::state::{BlockState, NFIELDS};

/// Voigt order: E11 E22 E33 E23 E13 E12 | v1 v2 v3.
/// Stress column a (traction for normal e_a) as Voigt indices.
pub(crate) const S_COL: [[usize; 3]; 3] = [[0, 5, 4], [5, 1, 3], [4, 3, 2]];
/// Voigt slot of the symmetric pair {i, j}, i != j.
pub(crate) const VOIGT_PAIR: [[usize; 3]; 3] =
    [[usize::MAX, 5, 4], [5, usize::MAX, 3], [4, 3, usize::MAX]];

/// Wall-clock per paper kernel, accumulated across calls (Fig 4.1 taxonomy).
#[derive(Debug, Clone, Copy, Default)]
pub struct KernelTimes {
    pub volume_loop: f64,
    pub int_flux: f64,
    pub interp_q: f64,
    pub lift: f64,
    pub rk: f64,
    pub bound_flux: f64,
    pub parallel_flux: f64,
}

impl KernelTimes {
    pub fn total(&self) -> f64 {
        self.volume_loop + self.int_flux + self.interp_q + self.lift + self.rk
            + self.bound_flux + self.parallel_flux
    }

    pub fn rows(&self) -> [(&'static str, f64); 7] {
        [
            ("volume_loop", self.volume_loop),
            ("int_flux", self.int_flux),
            ("interp_q", self.interp_q),
            ("lift", self.lift),
            ("rk", self.rk),
            ("bound_flux", self.bound_flux),
            ("parallel_flux", self.parallel_flux),
        ]
    }

    /// Accumulate another sample (used by drivers and worker threads).
    pub fn accumulate(&mut self, from: &KernelTimes) {
        self.volume_loop += from.volume_loop;
        self.int_flux += from.int_flux;
        self.interp_q += from.interp_q;
        self.lift += from.lift;
        self.rk += from.rk;
        self.bound_flux += from.bound_flux;
        self.parallel_flux += from.parallel_flux;
    }

    /// Every timer multiplied by `factor`. Thread-parallel backends report
    /// thread-*summed* CPU seconds (which exceed wall time); the measured-
    /// rate refit rescales a profile by wall/total with this before fitting
    /// so heterogeneous backends are compared in the same unit.
    pub fn scaled(&self, factor: f64) -> KernelTimes {
        KernelTimes {
            volume_loop: self.volume_loop * factor,
            int_flux: self.int_flux * factor,
            interp_q: self.interp_q * factor,
            lift: self.lift * factor,
            rk: self.rk * factor,
            bound_flux: self.bound_flux * factor,
            parallel_flux: self.parallel_flux * factor,
        }
    }
}

/// Per-thread scratch for one element's face terms (no allocation on the
/// hot path; one per worker thread in the parallel backend).
pub(crate) struct ElemScratch {
    pub(crate) stress: Vec<f32>,
    pub(crate) flux: Vec<f32>,
}

impl ElemScratch {
    pub(crate) fn new(m: usize) -> Self {
        let vol = m * m * m;
        ElemScratch { stress: vec![0.0; 6 * vol], flux: vec![0.0; NFIELDS * m * m] }
    }
}

/// Scratch buffers reused across stages (no allocation on the hot path).
pub struct RefScratch {
    pub(crate) dq: Vec<f32>,
    pub(crate) elem: ElemScratch,
}

impl RefScratch {
    pub fn new(st: &BlockState) -> Self {
        let m = st.m;
        let vol = m * m * m;
        RefScratch {
            dq: vec![0.0; st.k_pad * NFIELDS * vol],
            elem: ElemScratch::new(m),
        }
    }
}

/// Borrowed view of the *shared* state the RHS reads: traces, halo and
/// the immutable block tables — everything except the element's own `q`,
/// which is passed per element so the fused pool sweep can hand each
/// worker exclusive `q`/`res` slices of its elements while all workers
/// share this one context. Safe to share across worker threads. The
/// interior sweep of the overlapped schedule passes `halo: &[]` —
/// interior elements never index the halo by construction.
#[derive(Clone, Copy)]
pub struct RhsCtx<'a> {
    pub m: usize,
    pub traces: &'a [f32],
    pub halo: &'a [f32],
    pub conn: &'a [i32],
    pub halo_idx: &'a [i32],
    pub mats: &'a [f32],
    pub halo_mats: &'a [f32],
    pub h: &'a [f32],
    /// SIMD lane width for this sweep (read once from the global dispatch).
    pub lanes: Lanes,
}

impl<'a> RhsCtx<'a> {
    pub fn of(st: &'a BlockState) -> Self {
        RhsCtx {
            m: st.m,
            traces: &st.traces,
            halo: &st.halo,
            conn: &st.conn,
            halo_idx: &st.halo_idx,
            mats: &st.mats,
            halo_mats: &st.halo_mats,
            h: &st.h,
            lanes: simd::active(),
        }
    }

    #[inline]
    fn trace_slice(&self, e: usize, f: usize) -> &'a [f32] {
        let sz = NFIELDS * self.m * self.m;
        let base = (e * 6 + f) * sz;
        &self.traces[base..base + sz]
    }
}

/// One LSRK stage: res <- a res + dt rhs(q); q <- q + b res; refresh traces.
/// Returns per-kernel wall times for this call.
pub fn stage(
    st: &mut BlockState,
    basis: &LglBasis,
    scratch: &mut RefScratch,
    dt: f32,
    a: f32,
    b: f32,
) -> KernelTimes {
    let mut times = KernelTimes::default();
    rhs(st, basis, scratch, &mut times);

    // ---- rk update (low-storage) ---------------------------------------
    let t0 = Instant::now();
    let m = st.m;
    let vol = m * m * m;
    let live = st.k_real * NFIELDS * vol;
    simd::rk_update(
        simd::active(),
        &mut st.q[..live],
        &mut st.res[..live],
        &scratch.dq[..live],
        dt,
        a,
        b,
    );
    times.rk += t0.elapsed().as_secs_f64();

    // ---- interp_q: refresh face traces of the updated state ------------
    let t0 = Instant::now();
    st.refresh_traces();
    times.interp_q += t0.elapsed().as_secs_f64();
    times
}

/// dq/dt into scratch.dq (real elements only; padding untouched).
fn rhs(st: &BlockState, basis: &LglBasis, scratch: &mut RefScratch, times: &mut KernelTimes) {
    let cx = RhsCtx::of(st);
    let vol = st.m * st.m * st.m;
    for e in 0..st.k_real {
        let qb = e * NFIELDS * vol;
        let q_e = &st.q[qb..qb + NFIELDS * vol];
        let dq = &mut scratch.dq[qb..qb + NFIELDS * vol];
        rhs_element(&cx, basis, e, q_e, dq, &mut scratch.elem, times);
    }
}

/// dq/dt of a single element into `dq` (a `NFIELDS * m^3` slice); `q_e`
/// is the element's own `(9, M, M, M)` block of q.
///
/// Reads only `q_e`, the face traces of the element's same-block
/// neighbors, and its halo slots — never the `q` of other elements — so
/// disjoint element sets can be swept concurrently against one shared
/// [`RhsCtx`], even while each worker updates its own elements' `q` in
/// place (the fused RHS+RK pass of [`super::parallel`]).
pub(crate) fn rhs_element(
    cx: &RhsCtx<'_>,
    basis: &LglBasis,
    e: usize,
    q_e: &[f32],
    dq: &mut [f32],
    scr: &mut ElemScratch,
    times: &mut KernelTimes,
) {
    let m = cx.m;
    let vol = m * m * m;
    let face = m * m;
    let lanes = cx.lanes;
    let w0 = basis.w0() as f32;

    let rho = cx.mats[e * 3];
    let lam = cx.mats[e * 3 + 1];
    let mu = cx.mats[e * 3 + 2];
    let he = [cx.h[e * 3], cx.h[e * 3 + 1], cx.h[e * 3 + 2]];
    dq.iter_mut().for_each(|v| *v = 0.0);

    // ---- volume_loop: stress + tensor-product derivatives --------------
    let t0 = Instant::now();
    let q = q_e;
    // pointwise stress (Voigt)
    simd::stress(lanes, q, &mut scr.stress, vol, lam, mu);
    let sc = [2.0 / he[0], 2.0 / he[1], 2.0 / he[2]];
    // strain eq: dE = sym(grad v); v fields are q[6..9]
    let (v1, v2, v3) = (&q[6 * vol..7 * vol], &q[7 * vol..8 * vol], &q[8 * vol..9 * vol]);
    let mut acc = |src: &[f32], axis: usize, dst: usize, scale: f32| {
        deriv_acc(basis, axis, src, &mut dq[dst * vol..(dst + 1) * vol], scale, lanes);
    };
    acc(v1, 0, 0, sc[0]); // E11 = d v1 / dx
    acc(v2, 1, 1, sc[1]); // E22
    acc(v3, 2, 2, sc[2]); // E33
    acc(v3, 1, 3, 0.5 * sc[1]); // E23 = (dv3/dy + dv2/dz)/2
    acc(v2, 2, 3, 0.5 * sc[2]);
    acc(v3, 0, 4, 0.5 * sc[0]); // E13
    acc(v1, 2, 4, 0.5 * sc[2]);
    acc(v2, 0, 5, 0.5 * sc[0]); // E12
    acc(v1, 1, 5, 0.5 * sc[1]);
    // velocity eq: rho dv_i = sum_a dS_ia/dx_a
    for i in 0..3 {
        for axis in 0..3 {
            let sv = S_COL[axis][i];
            let stress_f = &scr.stress[sv * vol..(sv + 1) * vol];
            deriv_acc(
                basis,
                axis,
                stress_f,
                &mut dq[(6 + i) * vol..(7 + i) * vol],
                sc[axis] / rho,
                lanes,
            );
        }
    }
    times.volume_loop += t0.elapsed().as_secs_f64();

    // ---- face terms -----------------------------------------------------
    for f in 0..6 {
        let axis = f / 2;
        let sign = if f % 2 == 0 { -1.0f32 } else { 1.0 };
        let cf = cx.conn[e * 6 + f];
        let tr_m = cx.trace_slice(e, f);
        let t0 = Instant::now();
        let timer: &mut f64 = match cf {
            c if c >= 0 => {
                let nb = c as usize;
                let tr_p = cx.trace_slice(nb, f ^ 1);
                let matp = [cx.mats[nb * 3], cx.mats[nb * 3 + 1], cx.mats[nb * 3 + 2]];
                let mm = [rho, lam, mu];
                riemann_face_l(lanes, tr_m, tr_p, mm, matp, axis, sign, face, &mut scr.flux);
                &mut times.int_flux
            }
            -1 => {
                let slot = cx.halo_idx[e * 6 + f] as usize;
                let sz = NFIELDS * face;
                let tr_p = &cx.halo[slot * sz..(slot + 1) * sz];
                let matp = [
                    cx.halo_mats[slot * 3],
                    cx.halo_mats[slot * 3 + 1],
                    cx.halo_mats[slot * 3 + 2],
                ];
                let mm = [rho, lam, mu];
                riemann_face_l(lanes, tr_m, tr_p, mm, matp, axis, sign, face, &mut scr.flux);
                &mut times.parallel_flux
            }
            _ => {
                // mirror BC: exterior trace is (-E, v) of the interior one
                riemann_face_mirror_l(lanes, tr_m, [rho, lam, mu], axis, sign, face, &mut scr.flux);
                &mut times.bound_flux
            }
        };
        *timer += t0.elapsed().as_secs_f64();

        // ---- lift: subtract at the face node layer ---------------------
        let t0 = Instant::now();
        let lift = 2.0 / (he[axis] * w0);
        let layer = if sign < 0.0 { 0 } else { m - 1 };
        for fld in 0..NFIELDS {
            let scale = if fld >= 6 { lift / rho } else { lift };
            for fa in 0..m {
                for fb in 0..m {
                    let n = node_on_face(axis, layer, fa, fb, m);
                    dq[fld * vol + n] -= scale * scr.flux[fld * face + fa * m + fb];
                }
            }
        }
        times.lift += t0.elapsed().as_secs_f64();
    }
}

/// `dst[n] += scale * Σ_t D[along(n), t] · src[line(n, t)]` along `axis`.
///
/// Line-contiguous sweeps: axis 0 is an axpy over whole contiguous face
/// slabs, axis 1 an axpy over contiguous rows, axis 2 a row-local small
/// matvec over contiguous data. `src` and `dst` must be distinct arrays
/// (they always are: q/stress vs dq).
#[inline(always)]
fn deriv_acc_kernel(
    d: &[f32],
    m: usize,
    axis: usize,
    src: &[f32],
    dst: &mut [f32],
    scale: f32,
    lanes: Lanes,
) {
    let face = m * m;
    match axis {
        0 => {
            // dst[i,:,:] += scale * Σ_t d[i,t] * src[t,:,:]
            for i in 0..m {
                let drow = &d[i * m..(i + 1) * m];
                let dst_i = &mut dst[i * face..(i + 1) * face];
                for (t, &dv) in drow.iter().enumerate() {
                    let c = scale * dv;
                    simd::axpy(lanes, dst_i, &src[t * face..(t + 1) * face], c);
                }
            }
        }
        1 => {
            // dst[i,j,:] += scale * Σ_t d[j,t] * src[i,t,:]
            for i in 0..m {
                let sbase = i * face;
                for j in 0..m {
                    let drow = &d[j * m..(j + 1) * m];
                    let dbase = i * face + j * m;
                    let dst_row = &mut dst[dbase..dbase + m];
                    for (t, &dv) in drow.iter().enumerate() {
                        let c = scale * dv;
                        simd::axpy(lanes, dst_row, &src[sbase + t * m..sbase + (t + 1) * m], c);
                    }
                }
            }
        }
        _ => {
            // dst[r, l] += scale * Σ_t d[l,t] * src[r, t], contiguous rows
            // (scalar path; the vector path is simd::matvec_rows, dispatched
            // by deriv_acc before reaching here)
            for r in 0..face {
                let row = &src[r * m..(r + 1) * m];
                let dst_row = &mut dst[r * m..(r + 1) * m];
                for (l, o) in dst_row.iter_mut().enumerate() {
                    let drow = &d[l * m..(l + 1) * m];
                    let mut acc = 0.0f32;
                    for (&dv, &v) in drow.iter().zip(row) {
                        acc += dv * v;
                    }
                    *o += scale * acc;
                }
            }
        }
    }
}

/// Dispatch to monomorphized fast paths for the common node counts
/// (orders 2, 3 and 7 — the paper's sweep); the constant `m` lets the
/// compiler fully unroll the innermost loops of the scalar paths, while
/// the axis-2 matvec goes through the transposed-padded operator
/// ([`LglBasis::d32t`]) when a vector path covers `(lanes, m)`.
pub(crate) fn deriv_acc(
    basis: &LglBasis,
    axis: usize,
    src: &[f32],
    dst: &mut [f32],
    scale: f32,
    lanes: Lanes,
) {
    let m = basis.m();
    if axis == 2 && simd::matvec_rows(lanes, &basis.d32t, m, src, dst, scale) {
        return;
    }
    let d = &basis.d32;
    match m {
        3 => deriv_acc_kernel(d, 3, axis, src, dst, scale, lanes),
        4 => deriv_acc_kernel(d, 4, axis, src, dst, scale, lanes),
        8 => deriv_acc_kernel(d, 8, axis, src, dst, scale, lanes),
        _ => deriv_acc_kernel(d, m, axis, src, dst, scale, lanes),
    }
}

/// Volume node index for face-layer coordinates: the face plane fixes
/// `axis` at `layer`; (a, b) run over the remaining axes in order.
#[inline]
fn node_on_face(axis: usize, layer: usize, a: usize, b: usize, m: usize) -> usize {
    match axis {
        0 => layer * m * m + a * m + b,
        1 => a * m * m + layer * m + b,
        _ => a * m * m + b * m + layer,
    }
}

/// The Riemann flux core, generic over the exterior-trace fetch so the
/// mirror / neighbor / halo cases monomorphize with the branch hoisted out
/// of the per-node loop. `n0` is the first node to process — the SIMD
/// prefix ([`simd::riemann_vec`]) covers `[0, n0)` and this kernel finishes
/// the unpadded tail.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn riemann_kernel<Q: Fn(usize, usize) -> f32>(
    tr_m: &[f32],
    q_ext: Q,
    matm: [f32; 3],
    matp: [f32; 3],
    axis: usize,
    sign: f32,
    face: usize,
    n0: usize,
    out: &mut [f32],
) {
    let (rho_m, lam_m, mu_m) = (matm[0], matm[1], matm[2]);
    let (rho_p, lam_p, mu_p) = (matp[0], matp[1], matp[2]);
    let cp_m = ((lam_m + 2.0 * mu_m) / rho_m).sqrt();
    let cs_m = (mu_m / rho_m).sqrt();
    let cp_p = ((lam_p + 2.0 * mu_p) / rho_p).sqrt();
    let cs_p = (mu_p / rho_p).sqrt();
    let (zp_m, zs_m) = (rho_m * cp_m, rho_m * cs_m);
    let (zp_p, zs_p) = (rho_p * cp_p, rho_p * cs_p);
    let k0 = 1.0 / (zp_m + zp_p);
    let zs_sum = zs_m + zs_p;
    let k1 = if mu_m > 0.0 && zs_sum > 0.0 { 1.0 / zs_sum } else { 0.0 };

    for n in n0..face {
        let q_m = |f: usize| tr_m[f * face + n];
        let q_p = |f: usize| q_ext(f, n);
        // tractions t_i = sign * S[i, axis]
        let tr_e_m = q_m(0) + q_m(1) + q_m(2);
        let tr_e_p = q_p(0) + q_p(1) + q_p(2);
        let s_m = |i: usize| {
            let sv = S_COL[axis][i];
            if sv < 3 {
                lam_m * tr_e_m + 2.0 * mu_m * q_m(sv)
            } else {
                2.0 * mu_m * q_m(sv)
            }
        };
        let s_p = |i: usize| {
            let sv = S_COL[axis][i];
            if sv < 3 {
                lam_p * tr_e_p + 2.0 * mu_p * q_p(sv)
            } else {
                2.0 * mu_p * q_p(sv)
            }
        };
        let t_jump = [
            sign * (s_m(0) - s_p(0)),
            sign * (s_m(1) - s_p(1)),
            sign * (s_m(2) - s_p(2)),
        ];
        let v_jump = [q_m(6) - q_p(6), q_m(7) - q_p(7), q_m(8) - q_p(8)];
        let tn = sign * t_jump[axis];
        let vn = sign * v_jump[axis];
        // tangential parts: a_tan = a - (n.a) n with n = sign * e_axis
        let mut t_tan = t_jump;
        let mut v_tan = v_jump;
        t_tan[axis] = t_jump[axis] - tn * sign;
        v_tan[axis] = v_jump[axis] - vn * sign;

        let phi_p = k0 * tn + k0 * zp_p * vn;

        // strain rows
        for fld in 0..6 {
            out[fld * face + n] = 0.0;
        }
        out[axis * face + n] = phi_p;
        for j in 0..3 {
            if j == axis {
                continue;
            }
            let tang = k1 * t_tan[j] + k1 * zs_p * v_tan[j];
            let vi = VOIGT_PAIR[axis][j];
            out[vi * face + n] += 0.5 * sign * tang;
        }
        // velocity rows
        for i in 0..3 {
            let mut v = zs_m * (k1 * t_tan[i] + k1 * zs_p * v_tan[i]);
            if i == axis {
                v += sign * phi_p * zp_m;
            }
            out[(6 + i) * face + n] = v;
        }
    }
}

/// Exact elastic-acoustic Riemann flux difference over one face
/// (math-identical to kernels/ref.py::riemann_ref; see its docstring for
/// the conventions). `out` rows 6..8 are NOT divided by rho^- (the lift
/// applies Q^{-1}).
#[allow(clippy::too_many_arguments)]
pub fn riemann_face(
    tr_m: &[f32],
    tr_p: &[f32],
    matm: [f32; 3],
    matp: [f32; 3],
    axis: usize,
    sign: f32,
    face: usize,
    out: &mut [f32],
) {
    riemann_face_l(simd::active(), tr_m, tr_p, matm, matp, axis, sign, face, out);
}

/// [`riemann_face`] with the lane width supplied by the caller (the per-
/// element sweep reads it once per stage instead of per face call): SIMD
/// prefix over whole vectors, scalar kernel over the unpadded tail.
#[allow(clippy::too_many_arguments)]
pub(crate) fn riemann_face_l(
    lanes: Lanes,
    tr_m: &[f32],
    tr_p: &[f32],
    matm: [f32; 3],
    matp: [f32; 3],
    axis: usize,
    sign: f32,
    face: usize,
    out: &mut [f32],
) {
    let n0 = simd::riemann_vec(lanes, tr_m, tr_p, false, matm, matp, axis, sign, face, out);
    if n0 < face {
        riemann_kernel(tr_m, |f, n| tr_p[f * face + n], matm, matp, axis, sign, face, n0, out);
    }
}

/// [`riemann_face`] against the mirror boundary state `(-E, v)` of the
/// interior trace, same material both sides — no exterior trace is
/// materialized.
pub fn riemann_face_mirror(
    tr_m: &[f32],
    mat: [f32; 3],
    axis: usize,
    sign: f32,
    face: usize,
    out: &mut [f32],
) {
    riemann_face_mirror_l(simd::active(), tr_m, mat, axis, sign, face, out);
}

/// [`riemann_face_mirror`] with a caller-supplied lane width; the SIMD
/// prefix folds the `(-E, v)` fetch into a sign-bit XOR on the loaded
/// strain rows.
pub(crate) fn riemann_face_mirror_l(
    lanes: Lanes,
    tr_m: &[f32],
    mat: [f32; 3],
    axis: usize,
    sign: f32,
    face: usize,
    out: &mut [f32],
) {
    let n0 = simd::riemann_vec(lanes, tr_m, tr_m, true, mat, mat, axis, sign, face, out);
    if n0 < face {
        riemann_kernel(
            tr_m,
            |f, n| {
                let v = tr_m[f * face + n];
                if f < 6 {
                    -v
                } else {
                    v
                }
            },
            mat,
            mat,
            axis,
            sign,
            face,
            n0,
            out,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::{build_local_blocks, geometry::unit_cube_geometry};
    use crate::solver::rk::{LSRK_A, LSRK_B, N_STAGES};

    fn state(order: usize, n: usize) -> BlockState {
        let mesh = unit_cube_geometry(n);
        let owners = vec![0usize; mesh.len()];
        let (blocks, _) = build_local_blocks(&mesh, &owners, 1);
        let k = blocks[0].len();
        BlockState::from_local_block(&blocks[0], order, k, 8)
    }

    #[test]
    fn zero_state_stays_zero() {
        let basis = LglBasis::new(2);
        let mut st = state(2, 2);
        let mut scratch = RefScratch::new(&st);
        stage(&mut st, &basis, &mut scratch, 1e-3, 0.0, 1.0);
        assert!(st.q.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn riemann_zero_jump_zero_flux() {
        let face = 9;
        let tr: Vec<f32> = (0..9 * face).map(|i| (i as f32) * 0.1).collect();
        let mut out = vec![0.0f32; 9 * face];
        riemann_face(&tr, &tr, [1.0, 2.0, 0.5], [1.0, 2.0, 0.5], 1, -1.0, face, &mut out);
        assert!(out.iter().all(|&v| v.abs() < 1e-6));
    }

    #[test]
    fn riemann_1d_acoustic_characteristic() {
        // same scenario as python test_kernels.py::test_riemann_1d_...
        let face = 4;
        let mut tr_m = vec![0.0f32; 9 * face];
        let tr_p = vec![0.0f32; 9 * face];
        for n in 0..face {
            tr_m[n] = 1.0; // E11 = 1
            tr_m[6 * face + n] = 0.5; // v1 = 0.5
        }
        let mats = [1.0, 1.0, 0.0];
        let mut out = vec![0.0f32; 9 * face];
        riemann_face(&tr_m, &tr_p, mats, mats, 0, 1.0, face, &mut out);
        let phi = (1.0 + 0.5) / 2.0;
        for n in 0..face {
            assert!((out[n] - phi).abs() < 1e-6); // E11 row
            assert!((out[6 * face + n] - phi).abs() < 1e-6); // v1 row
            assert!(out[face + n].abs() < 1e-7); // E22 row untouched
        }
    }

    #[test]
    fn mirror_specialization_matches_materialized_trace() {
        // riemann_face_mirror must equal riemann_face against an explicit
        // (-E, v) exterior trace, for every axis/sign and both materials
        let face = 9;
        let tr_m: Vec<f32> = (0..9 * face).map(|i| ((i * 37 % 23) as f32 - 11.0) * 0.07).collect();
        let mut tr_p = vec![0.0f32; 9 * face];
        for fld in 0..9 {
            for n in 0..face {
                let v = tr_m[fld * face + n];
                tr_p[fld * face + n] = if fld < 6 { -v } else { v };
            }
        }
        for mat in [[1.0, 1.0, 0.0f32], [1.2, 3.0, 0.8]] {
            for axis in 0..3 {
                for sign in [-1.0f32, 1.0] {
                    let mut a = vec![0.0f32; 9 * face];
                    let mut b = vec![0.0f32; 9 * face];
                    riemann_face(&tr_m, &tr_p, mat, mat, axis, sign, face, &mut a);
                    riemann_face_mirror(&tr_m, mat, axis, sign, face, &mut b);
                    for (x, y) in a.iter().zip(&b) {
                        assert_eq!(x, y, "axis {axis} sign {sign}");
                    }
                }
            }
        }
    }

    #[test]
    fn deriv_acc_matches_naive() {
        // blocked sweeps vs the straightforward triple loop, all axes,
        // generic and specialized node counts, every lane width the host
        // supports (vector paths must agree with the naive loop too)
        let cap = crate::solver::simd::detect();
        for m in [3usize, 4, 5, 8] {
            let basis = LglBasis::new(m - 1);
            let vol = m * m * m;
            let face = m * m;
            let src: Vec<f32> = (0..vol).map(|i| ((i * 13 % 17) as f32 - 8.0) * 0.3).collect();
            let stride = [face, m, 1usize];
            for axis in 0..3 {
                let scale = 0.37f32;
                let mut want = vec![0.5f32; vol];
                let sa = stride[axis];
                for i in 0..m {
                    for j in 0..m {
                        for l in 0..m {
                            let idx = [i, j, l];
                            let n = i * face + j * m + l;
                            let along = idx[axis];
                            let base = n - along * sa;
                            let mut acc = 0.0f32;
                            for t in 0..m {
                                acc += basis.d32[along * m + t] * src[base + t * sa];
                            }
                            want[n] += scale * acc;
                        }
                    }
                }
                for lanes in [Lanes::Scalar, Lanes::W4, Lanes::W8] {
                    if lanes.width() > cap.width() {
                        continue;
                    }
                    let mut got = vec![0.5f32; vol];
                    deriv_acc(&basis, axis, &src, &mut got, scale, lanes);
                    for (g, w) in got.iter().zip(&want) {
                        // different (valid) summation associations: relative
                        // bound, not bitwise
                        assert!(
                            (g - w).abs() < 2e-4 * (1.0 + w.abs()),
                            "m {m} axis {axis} lanes {lanes:?}: {g} vs {w}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn deriv_acc_lane_widths_agree_exactly() {
        // across lane widths the kernels must agree bitwise (up to the sign
        // of zero, which f32 equality ignores) — the contract that keeps the
        // exact cross-backend tests valid with SIMD on
        let cap = crate::solver::simd::detect();
        for m in [3usize, 4, 8] {
            let basis = LglBasis::new(m - 1);
            let vol = m * m * m;
            let src: Vec<f32> = (0..vol).map(|i| ((i * 13 % 17) as f32 - 8.0) * 0.3).collect();
            for axis in 0..3 {
                let mut base = vec![0.5f32; vol];
                deriv_acc(&basis, axis, &src, &mut base, 0.37, Lanes::Scalar);
                for lanes in [Lanes::W4, Lanes::W8] {
                    if lanes.width() > cap.width() {
                        continue;
                    }
                    let mut got = vec![0.5f32; vol];
                    deriv_acc(&basis, axis, &src, &mut got, 0.37, lanes);
                    assert_eq!(got, base, "m {m} axis {axis} lanes {lanes:?}");
                }
            }
        }
    }

    #[test]
    fn standing_wave_energy_decays_slowly() {
        let order = 3;
        let basis = LglBasis::new(order);
        let mut st = state(order, 2);
        let pi = std::f64::consts::PI;
        let w = pi * 3f64.sqrt();
        st.set_initial_condition(&basis, |x| {
            crate::solver::analytic::standing_wave(x, 0.0, 1.0, 1.0, w)
        });
        let mut scratch = RefScratch::new(&st);
        let e0 = st.energy(&basis);
        let dt = 1e-3f32;
        for _ in 0..50 {
            for s in 0..N_STAGES {
                stage(&mut st, &basis, &mut scratch, dt, LSRK_A[s] as f32, LSRK_B[s] as f32);
            }
        }
        let e1 = st.energy(&basis);
        assert!(e1 <= e0 * (1.0 + 1e-5), "energy must not grow: {e0} -> {e1}");
        assert!(e1 >= 0.995 * e0, "resolved mode barely dissipates: {e0} -> {e1}");
    }

    #[test]
    fn standing_wave_converges_with_order() {
        let mut errs = Vec::new();
        for order in [2usize, 4] {
            let basis = LglBasis::new(order);
            let mut st = state(order, 2);
            let pi = std::f64::consts::PI;
            let w = pi * 3f64.sqrt();
            st.set_initial_condition(&basis, |x| {
                crate::solver::analytic::standing_wave(x, 0.0, 1.0, 1.0, w)
            });
            let mut scratch = RefScratch::new(&st);
            let t_end = 0.2f64;
            let dt = 0.25 * 0.5 / (1.0 * (order * order + 1) as f64);
            let steps = (t_end / dt).ceil() as usize;
            let dt = (t_end / steps as f64) as f32;
            for _ in 0..steps {
                for s in 0..N_STAGES {
                    stage(&mut st, &basis, &mut scratch, dt, LSRK_A[s] as f32, LSRK_B[s] as f32);
                }
            }
            let err = st.rel_l2_error(&basis, |x| {
                crate::solver::analytic::standing_wave(x, t_end, 1.0, 1.0, w)
            });
            errs.push(err);
        }
        assert!(errs[1] < 0.15 * errs[0], "spectral convergence: {errs:?}");
        assert!(errs[1] < 5e-3, "{errs:?}");
    }

    #[test]
    fn kernel_times_accumulate() {
        let basis = LglBasis::new(2);
        let mut st = state(2, 2);
        st.set_initial_condition(&basis, |x| [x[0], 0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
        let mut scratch = RefScratch::new(&st);
        let t = stage(&mut st, &basis, &mut scratch, 1e-3, 0.0, 1.0);
        assert!(t.volume_loop > 0.0);
        assert!(t.bound_flux > 0.0);
        assert!(t.total() > 0.0);
    }
}
