//! The DGSEM elastic-acoustic solver substrate on the rust side.
//!
//! The production compute path executes the AOT-compiled L2 stage artifact
//! through PJRT ([`crate::runtime`]); this module provides everything
//! around it — block state in the artifact's exact memory layout, the LGL
//! basis (independent implementation, cross-checked against python in
//! tests), halo exchange, analytic solutions and energy/error norms — plus
//! a pure-rust **reference backend** implementing the same stage math,
//! used (a) to validate the PJRT path end to end, (b) as the
//! scalar-CPU-kernel stand-in when profiling the paper's baseline on this
//! machine — plus a **multithreaded backend** ([`parallel`]) that applies
//! the paper's level-2 boundary/interior split inside a block and overlaps
//! halo exchange with interior compute ([`driver`] `overlap = true`) —
//! and a [`simd`] lane-dispatch layer giving the hot per-element kernels
//! AVX2/SSE2 vector paths that reproduce the scalar results bitwise
//! (`simd` cargo feature, on by default; runtime CPU detection with a
//! portable scalar fallback).

pub mod analytic;
pub mod basis;
pub mod driver;
pub mod exchange;
pub mod parallel;
pub mod reference;
pub mod rk;
pub mod simd;
pub mod state;

pub use basis::LglBasis;
pub use driver::{Driver, StageBackend};
pub use parallel::ParallelRefBackend;
pub use rk::{LSRK_A, LSRK_B, N_STAGES};
pub use state::{BlockState, InteriorView};
