//! Explicit SIMD paths for the per-element hot kernels (x86_64 AVX2 and
//! SSE2 via `core::arch`), with a portable scalar fallback that compiles
//! everywhere. Gated by the `simd` cargo feature (default on); the lane
//! width is picked at runtime from CPUID (`is_x86_feature_detected!`),
//! never at compile time, so one binary runs correctly on any x86_64 host
//! and on other architectures falls back to scalar.
//!
//! ## Bitwise equivalence contract
//!
//! Every vector kernel here reproduces the scalar kernel's floating-point
//! result exactly (up to the sign of zero, which `f32::eq` ignores): the
//! vector code uses separate multiply and add (never FMA), keeps the
//! scalar code's operand association (`lam*tr + (2*mu)*q`, ascending-`t`
//! accumulation in the axis-2 matvec), and hoists only per-face *scalar*
//! constants (impedances, `k0`, `k1`) that both paths compute identically.
//! The existing `assert_eq!`-exact backend tests therefore stay valid with
//! SIMD on, and `tests/simd_kernels.rs` sweeps lane widths explicitly.
//!
//! ## Lane forcing
//!
//! [`set_forced`] pins the active width (clamped to what the host
//! supports) so tests and benches can price the SIMD delta
//! (`simd_over_scalar_*` scalars in BENCH_rhs.json) and assert
//! scalar-vs-vector equality on the same machine.
//!
//! ## Opt-in FMA (`simd-fma` feature)
//!
//! With the `simd-fma` cargo feature the W8/AVX2 kernels get
//! `_mm256_fmadd_ps`-contracted twins, dispatched at runtime when the
//! host reports FMA (and [`set_fma`] hasn't pinned it off). Contraction
//! skips the intermediate rounding of each multiply, so **the bitwise
//! contract above is deliberately traded away on exactly that leg**:
//! tests widen their gate from `assert_eq!` to a 1e-6 relative tolerance
//! precisely where [`fma_possible`] says contraction may happen, and
//! nowhere else (SSE2 and the scalar path stay bitwise). The
//! `fma_over_nofma_*` scalars in BENCH_rhs.json price what the fused ops
//! buy, toggled via [`set_fma`] on the same build.
#![allow(clippy::needless_range_loop)]

use std::sync::atomic::{AtomicU8, Ordering};

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
use super::reference::{S_COL, VOIGT_PAIR};

/// Active f32 lane count of the kernel dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lanes {
    /// Portable scalar kernels (also the non-x86_64 / feature-off path).
    Scalar,
    /// 128-bit SSE2 (x86_64 baseline — always available there).
    W4,
    /// 256-bit AVX2.
    W8,
}

impl Lanes {
    /// f32 elements per vector register (1 for scalar).
    pub fn width(self) -> usize {
        match self {
            Lanes::Scalar => 1,
            Lanes::W4 => 4,
            Lanes::W8 => 8,
        }
    }

    fn code(self) -> u8 {
        match self {
            Lanes::Scalar => 1,
            Lanes::W4 => 2,
            Lanes::W8 => 3,
        }
    }

    fn from_code(c: u8) -> Lanes {
        match c {
            2 => Lanes::W4,
            3 => Lanes::W8,
            _ => Lanes::Scalar,
        }
    }
}

/// 0 = unset; otherwise a `Lanes::code`.
static DETECTED: AtomicU8 = AtomicU8::new(0);
/// 0 = auto (use detection); otherwise a forced `Lanes::code`.
static FORCED: AtomicU8 = AtomicU8::new(0);

fn detect_uncached() -> Lanes {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    fn inner() -> Lanes {
        if std::arch::is_x86_feature_detected!("avx2") {
            Lanes::W8
        } else {
            // SSE2 is part of the x86_64 baseline: no check needed.
            Lanes::W4
        }
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    fn inner() -> Lanes {
        Lanes::Scalar
    }
    inner()
}

/// Widest lane count this host supports (cached after the first call).
pub fn detect() -> Lanes {
    match DETECTED.load(Ordering::Relaxed) {
        0 => {
            let l = detect_uncached();
            DETECTED.store(l.code(), Ordering::Relaxed);
            l
        }
        c => Lanes::from_code(c),
    }
}

/// Force the dispatch width (tests / benches); `None` restores
/// auto-detection. The request is clamped to the host capability — the
/// *effective* width is returned, so callers can skip sweep points the
/// machine can't run instead of faulting on unsupported instructions.
pub fn set_forced(lanes: Option<Lanes>) -> Lanes {
    match lanes {
        None => {
            FORCED.store(0, Ordering::SeqCst);
            detect()
        }
        Some(l) => {
            let cap = detect();
            let eff = if l.width() > cap.width() { cap } else { l };
            FORCED.store(eff.code(), Ordering::SeqCst);
            eff
        }
    }
}

/// The lane count kernels should dispatch on right now (forced or
/// detected). Read once per stage/context, not per inner loop.
#[inline]
pub fn active() -> Lanes {
    match FORCED.load(Ordering::Relaxed) {
        0 => detect(),
        c => Lanes::from_code(c),
    }
}

#[inline]
fn check_lanes(lanes: Lanes) {
    // Callers must pass a width obtained from active()/set_forced(), which
    // are clamped to the host capability; dispatching wider would fault.
    debug_assert!(lanes.width() <= detect().width(), "lane width beyond host capability");
}

// ---------------------------------------------------------------------------
// FMA contraction state (simd-fma feature)
// ---------------------------------------------------------------------------

/// 0 = auto (on if available), 1 = pinned off, 2 = pinned on (still
/// clamped to availability).
static FMA_MODE: AtomicU8 = AtomicU8::new(0);

/// Whether this build + host can execute the FMA-contracted W8 kernels at
/// all: `simd-fma` compiled in and CPUID reports FMA.
pub fn fma_available() -> bool {
    #[cfg(all(feature = "simd-fma", target_arch = "x86_64"))]
    {
        std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(all(feature = "simd-fma", target_arch = "x86_64")))]
    {
        false
    }
}

/// Pin FMA contraction on/off (benches price the delta by toggling on one
/// build); `None` restores auto (on when available). Returns the
/// *effective* state — always clamped to [`fma_available`], so pinning
/// "on" on a host or build without FMA is a no-op reported as `false`.
pub fn set_fma(on: Option<bool>) -> bool {
    let code = match on {
        None => 0,
        Some(false) => 1,
        Some(true) => 2,
    };
    FMA_MODE.store(code, Ordering::SeqCst);
    fma_active()
}

/// Whether the next W8 dispatch will use the contracted kernels.
#[inline]
pub fn fma_active() -> bool {
    match FMA_MODE.load(Ordering::Relaxed) {
        1 => false,
        _ => fma_available(),
    }
}

/// Whether kernels at `lanes` use FMA right now (W8 only; SSE2 and scalar
/// never contract).
#[inline]
pub fn fma_contracts(lanes: Lanes) -> bool {
    lanes == Lanes::W8 && fma_active()
}

/// Whether kernels at `lanes` *may* contract in this build on this host,
/// regardless of the runtime toggle. Equality tests key their gate on
/// this (bitwise vs 1e-6) so they stay race-free against a concurrent
/// [`set_fma`] — the toggle changes which result appears, not whether it
/// is within the widened gate.
#[inline]
pub fn fma_possible(lanes: Lanes) -> bool {
    lanes == Lanes::W8 && fma_available()
}

// ---------------------------------------------------------------------------
// multiply-accumulate selection
// ---------------------------------------------------------------------------
//
// Every AVX2 kernel body below is written against a `$madd` macro with the
// uniform shape `madd(a, b, c) = c + a*b`. The `nofma` expansion keeps the
// separate multiply and add in exactly the operand order the scalar
// kernels use (the addend `c` first), so the non-contracted twins stay
// bitwise identical to the code they replaced; the `fma` expansion
// (`simd-fma` builds only) is a single `_mm256_fmadd_ps`.

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
macro_rules! madd256_nofma {
    ($a:expr, $b:expr, $c:expr) => {
        _mm256_add_ps($c, _mm256_mul_ps($a, $b))
    };
}

#[cfg(all(feature = "simd-fma", target_arch = "x86_64"))]
macro_rules! madd256_fma {
    ($a:expr, $b:expr, $c:expr) => {
        _mm256_fmadd_ps($a, $b, $c)
    };
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
macro_rules! madd128_nofma {
    ($a:expr, $b:expr, $c:expr) => {
        _mm_add_ps($c, _mm_mul_ps($a, $b))
    };
}

// ---------------------------------------------------------------------------
// axpy: dst[i] += c * src[i]   (axis-0/1 derivative sweeps)
// ---------------------------------------------------------------------------

#[inline]
pub(crate) fn axpy(lanes: Lanes, dst: &mut [f32], src: &[f32], c: f32) {
    debug_assert_eq!(dst.len(), src.len());
    check_lanes(lanes);
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        match lanes {
            Lanes::W8 => {
                #[cfg(feature = "simd-fma")]
                {
                    if fma_active() {
                        // SAFETY: `fma_active` is only true when CPUID
                        // reported FMA (and W8 implies AVX2, see
                        // `check_lanes`), satisfying the kernel's
                        // target_feature contract; lengths are checked
                        // above and the kernel handles any tail.
                        return unsafe { axpy_avx2_fma(dst, src, c) };
                    }
                }
                // SAFETY: Lanes::W8 is only produced by `detect`/
                // `set_forced` when AVX2 is present (`check_lanes`
                // debug-asserts it), satisfying the target_feature
                // contract; unaligned loads, tail handled in-kernel.
                return unsafe { axpy_avx2(dst, src, c) };
            }
            // SAFETY: Lanes::W4 requires SSE2, unconditionally present
            // on x86_64 (and re-checked by `check_lanes`).
            Lanes::W4 => return unsafe { axpy_sse2(dst, src, c) },
            Lanes::Scalar => {}
        }
    }
    let _ = lanes;
    for (o, &v) in dst.iter_mut().zip(src) {
        *o += c * v;
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
macro_rules! axpy256_body {
    ($dst:ident, $src:ident, $c:ident, $madd:ident) => {{
        use core::arch::x86_64::*;
        let n = $dst.len();
        let cv = _mm256_set1_ps($c);
        let dp = $dst.as_mut_ptr();
        let sp = $src.as_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dp.add(i));
            let s = _mm256_loadu_ps(sp.add(i));
            _mm256_storeu_ps(dp.add(i), $madd!(cv, s, d));
            i += 8;
        }
        while i < n {
            *dp.add(i) += $c * *sp.add(i);
            i += 1;
        }
    }};
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(dst: &mut [f32], src: &[f32], c: f32) {
    axpy256_body!(dst, src, c, madd256_nofma)
}

#[cfg(all(feature = "simd-fma", target_arch = "x86_64"))]
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy_avx2_fma(dst: &mut [f32], src: &[f32], c: f32) {
    axpy256_body!(dst, src, c, madd256_fma)
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "sse2")]
unsafe fn axpy_sse2(dst: &mut [f32], src: &[f32], c: f32) {
    use core::arch::x86_64::*;
    let n = dst.len();
    let cv = _mm_set1_ps(c);
    let dp = dst.as_mut_ptr();
    let sp = src.as_ptr();
    let mut i = 0usize;
    while i + 4 <= n {
        let d = _mm_loadu_ps(dp.add(i));
        let s = _mm_loadu_ps(sp.add(i));
        _mm_storeu_ps(dp.add(i), _mm_add_ps(d, _mm_mul_ps(cv, s)));
        i += 4;
    }
    while i < n {
        *dp.add(i) += c * *sp.add(i);
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// matvec_rows: the axis-2 derivative (row-local small matvec)
// ---------------------------------------------------------------------------

/// `dst[r, l] += scale * Σ_t src[r, t] * dT[t, l]` over every contiguous
/// `m`-length row, with `dt_pad` the transposed differentiation matrix
/// padded to 8-wide rows ([`crate::solver::basis::LglBasis::d32t`]): one
/// broadcast of `src[r, t]` times one padded row per multiply-accumulate,
/// ascending `t` exactly like the scalar kernel. Returns `false` when no
/// vector path covers `(lanes, m)` — the caller falls back to scalar.
pub(crate) fn matvec_rows(
    lanes: Lanes,
    dt_pad: &[f32],
    m: usize,
    src: &[f32],
    dst: &mut [f32],
    scale: f32,
) -> bool {
    debug_assert_eq!(dst.len(), src.len());
    debug_assert!(m > 8 || dt_pad.len() >= m * 8);
    check_lanes(lanes);
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        match (lanes, m) {
            (Lanes::W8, 8) => {
                #[cfg(feature = "simd-fma")]
                {
                    if fma_active() {
                        // SAFETY: `fma_active` is only true with CPUID
                        // FMA (W8 implies AVX2 via `check_lanes`); the
                        // m == 8 arm and the dt_pad length debug-assert
                        // match the kernel's 8x8 layout.
                        unsafe { matvec8_avx2_fma(dt_pad, src, dst, scale) };
                        return true;
                    }
                }
                // SAFETY: Lanes::W8 is only produced when AVX2 is
                // present (`check_lanes`); m == 8 and the dt_pad
                // debug-assert match the kernel's 8x8 layout.
                unsafe { matvec8_avx2(dt_pad, src, dst, scale) };
                return true;
            }
            (Lanes::W4, 8) => {
                // SAFETY: SSE2 is unconditionally present on x86_64;
                // m == 8 matches the kernel's layout expectations.
                unsafe { matvec8_sse2(dt_pad, src, dst, scale) };
                return true;
            }
            (Lanes::W8, 4) | (Lanes::W4, 4) => {
                // SAFETY: SSE2 is unconditionally present on x86_64;
                // m == 4 matches the kernel's layout expectations.
                unsafe { matvec4_sse2(dt_pad, src, dst, scale) };
                return true;
            }
            _ => {}
        }
    }
    let _ = (lanes, dt_pad, m, src, dst, scale);
    false
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
macro_rules! matvec8x256_body {
    ($dt:ident, $src:ident, $dst:ident, $scale:ident, $madd:ident) => {{
        use core::arch::x86_64::*;
        let n = $dst.len();
        debug_assert_eq!(n % 8, 0);
        let mut d = [_mm256_setzero_ps(); 8];
        for (t, dv) in d.iter_mut().enumerate() {
            *dv = _mm256_loadu_ps($dt.as_ptr().add(t * 8));
        }
        let vs = _mm256_set1_ps($scale);
        let sp = $src.as_ptr();
        let dp = $dst.as_mut_ptr();
        let mut r = 0usize;
        while r < n {
            let mut acc = _mm256_mul_ps(_mm256_set1_ps(*sp.add(r)), d[0]);
            for t in 1..8 {
                acc = $madd!(_mm256_set1_ps(*sp.add(r + t)), d[t], acc);
            }
            let prev = _mm256_loadu_ps(dp.add(r));
            _mm256_storeu_ps(dp.add(r), $madd!(vs, acc, prev));
            r += 8;
        }
    }};
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn matvec8_avx2(dt: &[f32], src: &[f32], dst: &mut [f32], scale: f32) {
    matvec8x256_body!(dt, src, dst, scale, madd256_nofma)
}

#[cfg(all(feature = "simd-fma", target_arch = "x86_64"))]
#[target_feature(enable = "avx2,fma")]
unsafe fn matvec8_avx2_fma(dt: &[f32], src: &[f32], dst: &mut [f32], scale: f32) {
    matvec8x256_body!(dt, src, dst, scale, madd256_fma)
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "sse2")]
unsafe fn matvec8_sse2(dt: &[f32], src: &[f32], dst: &mut [f32], scale: f32) {
    use core::arch::x86_64::*;
    let n = dst.len();
    debug_assert_eq!(n % 8, 0);
    let mut dlo = [_mm_setzero_ps(); 8];
    let mut dhi = [_mm_setzero_ps(); 8];
    for t in 0..8 {
        dlo[t] = _mm_loadu_ps(dt.as_ptr().add(t * 8));
        dhi[t] = _mm_loadu_ps(dt.as_ptr().add(t * 8 + 4));
    }
    let vs = _mm_set1_ps(scale);
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let mut r = 0usize;
    while r < n {
        let b0 = _mm_set1_ps(*sp.add(r));
        let mut lo = _mm_mul_ps(b0, dlo[0]);
        let mut hi = _mm_mul_ps(b0, dhi[0]);
        for t in 1..8 {
            let b = _mm_set1_ps(*sp.add(r + t));
            lo = _mm_add_ps(lo, _mm_mul_ps(b, dlo[t]));
            hi = _mm_add_ps(hi, _mm_mul_ps(b, dhi[t]));
        }
        let plo = _mm_loadu_ps(dp.add(r));
        let phi = _mm_loadu_ps(dp.add(r + 4));
        _mm_storeu_ps(dp.add(r), _mm_add_ps(plo, _mm_mul_ps(vs, lo)));
        _mm_storeu_ps(dp.add(r + 4), _mm_add_ps(phi, _mm_mul_ps(vs, hi)));
        r += 8;
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "sse2")]
unsafe fn matvec4_sse2(dt: &[f32], src: &[f32], dst: &mut [f32], scale: f32) {
    use core::arch::x86_64::*;
    let n = dst.len();
    debug_assert_eq!(n % 4, 0);
    let mut d = [_mm_setzero_ps(); 4];
    for (t, dv) in d.iter_mut().enumerate() {
        // rows of d32t are padded to 8; only the first 4 columns are live
        *dv = _mm_loadu_ps(dt.as_ptr().add(t * 8));
    }
    let vs = _mm_set1_ps(scale);
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let mut r = 0usize;
    while r < n {
        let mut acc = _mm_mul_ps(_mm_set1_ps(*sp.add(r)), d[0]);
        for t in 1..4 {
            acc = _mm_add_ps(acc, _mm_mul_ps(_mm_set1_ps(*sp.add(r + t)), d[t]));
        }
        let prev = _mm_loadu_ps(dp.add(r));
        _mm_storeu_ps(dp.add(r), _mm_add_ps(prev, _mm_mul_ps(vs, acc)));
        r += 4;
    }
}

// ---------------------------------------------------------------------------
// stress: pointwise Voigt stress from strain (volume_loop prologue)
// ---------------------------------------------------------------------------

/// `out[fld, n]` for the 6 stress rows from `q`'s 6 strain rows (both
/// `vol`-strided field-major): diagonal rows `lam*tr + (2*mu)*q`, shear
/// rows `(2*mu)*q`, with `tr = (q0 + q1) + q2`.
pub(crate) fn stress(lanes: Lanes, q: &[f32], out: &mut [f32], vol: usize, lam: f32, mu: f32) {
    debug_assert!(q.len() >= 6 * vol && out.len() >= 6 * vol);
    check_lanes(lanes);
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        match lanes {
            Lanes::W8 => {
                #[cfg(feature = "simd-fma")]
                {
                    if fma_active() {
                        // SAFETY: `fma_active` is only true with CPUID
                        // FMA (W8 implies AVX2 via `check_lanes`);
                        // slice lengths are checked by the caller and
                        // the kernel's tail loop.
                        return unsafe { stress_avx2_fma(q, out, vol, lam, mu) };
                    }
                }
                // SAFETY: Lanes::W8 is only produced when AVX2 is
                // present (`check_lanes` debug-asserts it); unaligned
                // loads, tail handled in-kernel.
                return unsafe { stress_avx2(q, out, vol, lam, mu) };
            }
            // SAFETY: SSE2 is unconditionally present on x86_64.
            Lanes::W4 => return unsafe { stress_sse2(q, out, vol, lam, mu) },
            Lanes::Scalar => {}
        }
    }
    let _ = lanes;
    stress_scalar(q, out, 0, vol, vol, lam, mu);
}

/// Scalar body, shared by the portable path and the vector tails.
#[inline(always)]
fn stress_scalar(q: &[f32], out: &mut [f32], n0: usize, n1: usize, vol: usize, lam: f32, mu: f32) {
    let two_mu = 2.0 * mu;
    for n in n0..n1 {
        let tr = q[n] + q[vol + n] + q[2 * vol + n];
        out[n] = lam * tr + two_mu * q[n];
        out[vol + n] = lam * tr + two_mu * q[vol + n];
        out[2 * vol + n] = lam * tr + two_mu * q[2 * vol + n];
        out[3 * vol + n] = two_mu * q[3 * vol + n];
        out[4 * vol + n] = two_mu * q[4 * vol + n];
        out[5 * vol + n] = two_mu * q[5 * vol + n];
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
macro_rules! stress256_body {
    ($q:ident, $out:ident, $vol:ident, $lam:ident, $mu:ident, $madd:ident) => {{
        use core::arch::x86_64::*;
        let vl = _mm256_set1_ps($lam);
        let v2m = _mm256_set1_ps(2.0 * $mu);
        let qp = $q.as_ptr();
        let op = $out.as_mut_ptr();
        let mut n = 0usize;
        while n + 8 <= $vol {
            let q0 = _mm256_loadu_ps(qp.add(n));
            let q1 = _mm256_loadu_ps(qp.add($vol + n));
            let q2 = _mm256_loadu_ps(qp.add(2 * $vol + n));
            let tr = _mm256_add_ps(_mm256_add_ps(q0, q1), q2);
            let lt = _mm256_mul_ps(vl, tr);
            _mm256_storeu_ps(op.add(n), $madd!(v2m, q0, lt));
            _mm256_storeu_ps(op.add($vol + n), $madd!(v2m, q1, lt));
            _mm256_storeu_ps(op.add(2 * $vol + n), $madd!(v2m, q2, lt));
            for f in 3..6 {
                let qf = _mm256_loadu_ps(qp.add(f * $vol + n));
                _mm256_storeu_ps(op.add(f * $vol + n), _mm256_mul_ps(v2m, qf));
            }
            n += 8;
        }
        stress_scalar($q, $out, n, $vol, $vol, $lam, $mu);
    }};
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn stress_avx2(q: &[f32], out: &mut [f32], vol: usize, lam: f32, mu: f32) {
    stress256_body!(q, out, vol, lam, mu, madd256_nofma)
}

#[cfg(all(feature = "simd-fma", target_arch = "x86_64"))]
#[target_feature(enable = "avx2,fma")]
unsafe fn stress_avx2_fma(q: &[f32], out: &mut [f32], vol: usize, lam: f32, mu: f32) {
    stress256_body!(q, out, vol, lam, mu, madd256_fma)
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "sse2")]
unsafe fn stress_sse2(q: &[f32], out: &mut [f32], vol: usize, lam: f32, mu: f32) {
    use core::arch::x86_64::*;
    let vl = _mm_set1_ps(lam);
    let v2m = _mm_set1_ps(2.0 * mu);
    let qp = q.as_ptr();
    let op = out.as_mut_ptr();
    let mut n = 0usize;
    while n + 4 <= vol {
        let q0 = _mm_loadu_ps(qp.add(n));
        let q1 = _mm_loadu_ps(qp.add(vol + n));
        let q2 = _mm_loadu_ps(qp.add(2 * vol + n));
        let tr = _mm_add_ps(_mm_add_ps(q0, q1), q2);
        let lt = _mm_mul_ps(vl, tr);
        _mm_storeu_ps(op.add(n), _mm_add_ps(lt, _mm_mul_ps(v2m, q0)));
        _mm_storeu_ps(op.add(vol + n), _mm_add_ps(lt, _mm_mul_ps(v2m, q1)));
        _mm_storeu_ps(op.add(2 * vol + n), _mm_add_ps(lt, _mm_mul_ps(v2m, q2)));
        for f in 3..6 {
            let qf = _mm_loadu_ps(qp.add(f * vol + n));
            _mm_storeu_ps(op.add(f * vol + n), _mm_mul_ps(v2m, qf));
        }
        n += 4;
    }
    stress_scalar(q, out, n, vol, vol, lam, mu);
}

// ---------------------------------------------------------------------------
// rk_update: res <- a*res + dt*dq; q <- q + b*res   (low-storage stage)
// ---------------------------------------------------------------------------

pub(crate) fn rk_update(
    lanes: Lanes,
    q: &mut [f32],
    res: &mut [f32],
    dq: &[f32],
    dt: f32,
    a: f32,
    b: f32,
) {
    debug_assert!(q.len() == res.len() && res.len() == dq.len());
    check_lanes(lanes);
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        match lanes {
            Lanes::W8 => {
                #[cfg(feature = "simd-fma")]
                {
                    if fma_active() {
                        // SAFETY: `fma_active` is only true with CPUID
                        // FMA (W8 implies AVX2 via `check_lanes`);
                        // equal lengths checked above, tail in-kernel.
                        return unsafe { rk_avx2_fma(q, res, dq, dt, a, b) };
                    }
                }
                // SAFETY: Lanes::W8 is only produced when AVX2 is
                // present (`check_lanes` debug-asserts it); unaligned
                // loads, tail handled in-kernel.
                return unsafe { rk_avx2(q, res, dq, dt, a, b) };
            }
            // SAFETY: SSE2 is unconditionally present on x86_64.
            Lanes::W4 => return unsafe { rk_sse2(q, res, dq, dt, a, b) },
            Lanes::Scalar => {}
        }
    }
    let _ = lanes;
    for (r, d) in res.iter_mut().zip(dq) {
        *r = a * *r + dt * *d;
    }
    for (qv, r) in q.iter_mut().zip(res.iter()) {
        *qv += b * *r;
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
macro_rules! rk256_body {
    ($q:ident, $res:ident, $dq:ident, $dt:ident, $a:ident, $b:ident, $madd:ident) => {{
        use core::arch::x86_64::*;
        let n = $q.len();
        let va = _mm256_set1_ps($a);
        let vdt = _mm256_set1_ps($dt);
        let vb = _mm256_set1_ps($b);
        let qp = $q.as_mut_ptr();
        let rp = $res.as_mut_ptr();
        let dp = $dq.as_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let r = _mm256_loadu_ps(rp.add(i));
            let d = _mm256_loadu_ps(dp.add(i));
            let rn = $madd!(vdt, d, _mm256_mul_ps(va, r));
            _mm256_storeu_ps(rp.add(i), rn);
            let qv = _mm256_loadu_ps(qp.add(i));
            _mm256_storeu_ps(qp.add(i), $madd!(vb, rn, qv));
            i += 8;
        }
        while i < n {
            let rn = $a * *rp.add(i) + $dt * *dp.add(i);
            *rp.add(i) = rn;
            *qp.add(i) += $b * rn;
            i += 1;
        }
    }};
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn rk_avx2(q: &mut [f32], res: &mut [f32], dq: &[f32], dt: f32, a: f32, b: f32) {
    rk256_body!(q, res, dq, dt, a, b, madd256_nofma)
}

#[cfg(all(feature = "simd-fma", target_arch = "x86_64"))]
#[target_feature(enable = "avx2,fma")]
unsafe fn rk_avx2_fma(q: &mut [f32], res: &mut [f32], dq: &[f32], dt: f32, a: f32, b: f32) {
    rk256_body!(q, res, dq, dt, a, b, madd256_fma)
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "sse2")]
unsafe fn rk_sse2(q: &mut [f32], res: &mut [f32], dq: &[f32], dt: f32, a: f32, b: f32) {
    use core::arch::x86_64::*;
    let n = q.len();
    let va = _mm_set1_ps(a);
    let vdt = _mm_set1_ps(dt);
    let vb = _mm_set1_ps(b);
    let qp = q.as_mut_ptr();
    let rp = res.as_mut_ptr();
    let dp = dq.as_ptr();
    let mut i = 0usize;
    while i + 4 <= n {
        let r = _mm_loadu_ps(rp.add(i));
        let d = _mm_loadu_ps(dp.add(i));
        let rn = _mm_add_ps(_mm_mul_ps(va, r), _mm_mul_ps(vdt, d));
        _mm_storeu_ps(rp.add(i), rn);
        let qv = _mm_loadu_ps(qp.add(i));
        _mm_storeu_ps(qp.add(i), _mm_add_ps(qv, _mm_mul_ps(vb, rn)));
        i += 4;
    }
    while i < n {
        let rn = a * *rp.add(i) + dt * *dp.add(i);
        *rp.add(i) = rn;
        *qp.add(i) += b * rn;
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// riemann_vec: the exact Riemann face flux, W nodes per iteration
// ---------------------------------------------------------------------------

/// Vector prefix of the Riemann face flux: processes `face / W * W` nodes
/// and returns that count; the caller runs the scalar kernel on the tail
/// (`riemann_kernel` with a start offset). `mirror` folds the `(-E, v)`
/// boundary-state fetch into the trace load, so `tr_p` is `tr_m` itself
/// there. Returns 0 when no vector path applies (scalar lanes, tiny face,
/// feature off) — the caller then does the whole face scalar.
#[allow(clippy::too_many_arguments)]
pub(crate) fn riemann_vec(
    lanes: Lanes,
    tr_m: &[f32],
    tr_p: &[f32],
    mirror: bool,
    matm: [f32; 3],
    matp: [f32; 3],
    axis: usize,
    sign: f32,
    face: usize,
    out: &mut [f32],
) -> usize {
    check_lanes(lanes);
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        match lanes {
            Lanes::W8 if face >= 8 => {
                #[cfg(feature = "simd-fma")]
                {
                    if fma_active() {
                        // SAFETY: `fma_active` is only true with CPUID
                        // FMA (W8 implies AVX2 via `check_lanes`);
                        // face >= 8 gives the kernel a full first
                        // vector, the tail is handled in-kernel.
                        return unsafe {
                            riemann_avx2_fma(tr_m, tr_p, mirror, matm, matp, axis, sign, face, out)
                        };
                    }
                }
                // SAFETY: Lanes::W8 is only produced when AVX2 is
                // present (`check_lanes` debug-asserts it); face >= 8
                // gives a full first vector, tail handled in-kernel.
                return unsafe {
                    riemann_avx2(tr_m, tr_p, mirror, matm, matp, axis, sign, face, out)
                };
            }
            Lanes::W4 | Lanes::W8 if face >= 4 => {
                // SAFETY: SSE2 is unconditionally present on x86_64;
                // face >= 4 gives a full first vector, tail in-kernel.
                return unsafe {
                    riemann_sse2(tr_m, tr_p, mirror, matm, matp, axis, sign, face, out)
                };
            }
            _ => {}
        }
    }
    let _ = (lanes, tr_m, tr_p, mirror, matm, matp, axis, sign, face, out);
    0
}

/// One macro body, two instantiations (AVX2 / SSE2): the per-node math is
/// identical to `reference::riemann_kernel` with the per-face scalar
/// constants (`k0`, `k0*zp_p`, `k1`, `k1*zs_p`, `0.5*sign`) hoisted and
/// broadcast; mirror negation of the 6 strain rows is a sign-bit XOR.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
macro_rules! riemann_body {
    ($tr_m:ident, $tr_p:ident, $mirror:ident, $matm:ident, $matp:ident,
     $axis:ident, $sign:ident, $face:ident, $out:ident,
     $w:expr, $set1:ident, $load:ident, $store:ident,
     $add:ident, $sub:ident, $mul:ident, $xor:ident, $madd:ident) => {{
        use core::arch::x86_64::*;
        let (rho_m, lam_m, mu_m) = ($matm[0], $matm[1], $matm[2]);
        let (rho_p, lam_p, mu_p) = ($matp[0], $matp[1], $matp[2]);
        let cp_m = ((lam_m + 2.0 * mu_m) / rho_m).sqrt();
        let cs_m = (mu_m / rho_m).sqrt();
        let cp_p = ((lam_p + 2.0 * mu_p) / rho_p).sqrt();
        let cs_p = (mu_p / rho_p).sqrt();
        let (zp_m, zs_m) = (rho_m * cp_m, rho_m * cs_m);
        let (zp_p, zs_p) = (rho_p * cp_p, rho_p * cs_p);
        let k0 = 1.0 / (zp_m + zp_p);
        let zs_sum = zs_m + zs_p;
        let k1 = if mu_m > 0.0 && zs_sum > 0.0 { 1.0 / zs_sum } else { 0.0 };

        let vlam_m = $set1(lam_m);
        let vlam_p = $set1(lam_p);
        let v2mu_m = $set1(2.0 * mu_m);
        let v2mu_p = $set1(2.0 * mu_p);
        let vsign = $set1($sign);
        let vk0 = $set1(k0);
        let vk0zpp = $set1(k0 * zp_p);
        let vk1 = $set1(k1);
        let vk1zsp = $set1(k1 * zs_p);
        let vhalf = $set1(0.5 * $sign);
        let vzs_m = $set1(zs_m);
        let vzp_m = $set1(zp_m);
        let vzero = $set1(0.0);
        let signbit = $set1(-0.0f32);

        let mp = $tr_m.as_ptr();
        let pp = $tr_p.as_ptr();
        let op = $out.as_mut_ptr();
        let done = $face / $w * $w;
        let mut n = 0usize;
        while n < done {
            let mut qm = [vzero; 9];
            let mut qp = [vzero; 9];
            for f in 0..9 {
                qm[f] = $load(mp.add(f * $face + n));
                let raw = $load(pp.add(f * $face + n));
                qp[f] = if $mirror && f < 6 { $xor(raw, signbit) } else { raw };
            }
            let tre_m = $add($add(qm[0], qm[1]), qm[2]);
            let tre_p = $add($add(qp[0], qp[1]), qp[2]);
            let mut tjump = [vzero; 3];
            let mut vjump = [vzero; 3];
            for i in 0..3 {
                let sv = S_COL[$axis][i];
                let s_m = if sv < 3 {
                    $madd!(v2mu_m, qm[sv], $mul(vlam_m, tre_m))
                } else {
                    $mul(v2mu_m, qm[sv])
                };
                let s_p = if sv < 3 {
                    $madd!(v2mu_p, qp[sv], $mul(vlam_p, tre_p))
                } else {
                    $mul(v2mu_p, qp[sv])
                };
                tjump[i] = $mul(vsign, $sub(s_m, s_p));
                vjump[i] = $sub(qm[6 + i], qp[6 + i]);
            }
            let tn = $mul(vsign, tjump[$axis]);
            let vn = $mul(vsign, vjump[$axis]);
            let mut t_tan = tjump;
            let mut v_tan = vjump;
            t_tan[$axis] = $sub(tjump[$axis], $mul(tn, vsign));
            v_tan[$axis] = $sub(vjump[$axis], $mul(vn, vsign));
            let phi = $madd!(vk0zpp, vn, $mul(vk0, tn));
            // tangential flux, shared by the strain and velocity rows (the
            // scalar kernel computes the same expression in both loops)
            let mut tang = [vzero; 3];
            for j in 0..3 {
                tang[j] = $madd!(vk1zsp, v_tan[j], $mul(vk1, t_tan[j]));
            }
            // strain rows: zeroed, normal row = phi, symmetric pairs
            let mut rows = [vzero; 6];
            rows[$axis] = phi;
            for j in 0..3 {
                if j != $axis {
                    let vi = VOIGT_PAIR[$axis][j];
                    rows[vi] = $madd!(vhalf, tang[j], rows[vi]);
                }
            }
            for (fld, row) in rows.iter().enumerate() {
                $store(op.add(fld * $face + n), *row);
            }
            // velocity rows
            for i in 0..3 {
                let mut v = $mul(vzs_m, tang[i]);
                if i == $axis {
                    v = $madd!($mul(vsign, phi), vzp_m, v);
                }
                $store(op.add((6 + i) * $face + n), v);
            }
            n += $w;
        }
        done
    }};
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn riemann_avx2(
    tr_m: &[f32],
    tr_p: &[f32],
    mirror: bool,
    matm: [f32; 3],
    matp: [f32; 3],
    axis: usize,
    sign: f32,
    face: usize,
    out: &mut [f32],
) -> usize {
    riemann_body!(
        tr_m, tr_p, mirror, matm, matp, axis, sign, face, out, 8, _mm256_set1_ps,
        _mm256_loadu_ps, _mm256_storeu_ps, _mm256_add_ps, _mm256_sub_ps, _mm256_mul_ps,
        _mm256_xor_ps, madd256_nofma
    )
}

#[cfg(all(feature = "simd-fma", target_arch = "x86_64"))]
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::too_many_arguments)]
unsafe fn riemann_avx2_fma(
    tr_m: &[f32],
    tr_p: &[f32],
    mirror: bool,
    matm: [f32; 3],
    matp: [f32; 3],
    axis: usize,
    sign: f32,
    face: usize,
    out: &mut [f32],
) -> usize {
    riemann_body!(
        tr_m, tr_p, mirror, matm, matp, axis, sign, face, out, 8, _mm256_set1_ps,
        _mm256_loadu_ps, _mm256_storeu_ps, _mm256_add_ps, _mm256_sub_ps, _mm256_mul_ps,
        _mm256_xor_ps, madd256_fma
    )
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "sse2")]
#[allow(clippy::too_many_arguments)]
unsafe fn riemann_sse2(
    tr_m: &[f32],
    tr_p: &[f32],
    mirror: bool,
    matm: [f32; 3],
    matp: [f32; 3],
    axis: usize,
    sign: f32,
    face: usize,
    out: &mut [f32],
) -> usize {
    riemann_body!(
        tr_m, tr_p, mirror, matm, matp, axis, sign, face, out, 4, _mm_set1_ps, _mm_loadu_ps,
        _mm_storeu_ps, _mm_add_ps, _mm_sub_ps, _mm_mul_ps, _mm_xor_ps, madd128_nofma
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Bitwise unless `lanes` may FMA-contract in this build/host, then
    /// a 1e-6 relative gate (see the module docs).
    fn assert_lane_eq(got: &[f32], want: &[f32], lanes: Lanes, ctx: &str) {
        assert_eq!(got.len(), want.len(), "{ctx}");
        if fma_possible(lanes) {
            for (i, (g, w)) in got.iter().zip(want).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-6 * w.abs().max(1.0),
                    "{ctx}: [{i}] {g} vs {w}"
                );
            }
        } else {
            assert!(got == want, "{ctx}");
        }
    }

    #[test]
    fn detection_is_sane_and_cached() {
        let a = detect();
        let b = detect();
        assert_eq!(a, b);
        assert!(a.width() == 1 || a.width() == 4 || a.width() == 8);
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        assert!(a.width() >= 4, "SSE2 is the x86_64 baseline");
    }

    #[test]
    fn forcing_clamps_to_capability() {
        let cap = detect();
        for want in [Lanes::Scalar, Lanes::W4, Lanes::W8] {
            let eff = set_forced(Some(want));
            assert!(eff.width() <= cap.width());
            assert!(eff.width() <= want.width());
            assert_eq!(active(), eff);
        }
        assert_eq!(set_forced(None), cap);
        assert_eq!(active(), cap);
    }

    #[test]
    fn axpy_matches_scalar_with_tails() {
        for len in [1usize, 3, 4, 7, 8, 9, 27, 64, 65] {
            let src: Vec<f32> = (0..len).map(|i| ((i * 7 % 13) as f32 - 6.0) * 0.3).collect();
            let mut want: Vec<f32> = (0..len).map(|i| (i as f32) * 0.1).collect();
            let c = 0.37f32;
            for (o, &v) in want.iter_mut().zip(&src) {
                *o += c * v;
            }
            for lanes in [Lanes::Scalar, Lanes::W4, Lanes::W8] {
                if lanes.width() > detect().width() {
                    continue;
                }
                let mut got: Vec<f32> = (0..len).map(|i| (i as f32) * 0.1).collect();
                axpy(lanes, &mut got, &src, c);
                assert_lane_eq(&got, &want, lanes, &format!("len {len} lanes {lanes:?}"));
            }
        }
    }

    #[test]
    fn rk_and_stress_match_scalar() {
        let vol = 27usize; // odd chunk: exercises the vector tail
        let q0: Vec<f32> = (0..9 * vol).map(|i| ((i * 11 % 19) as f32 - 9.0) * 0.21).collect();
        let r0: Vec<f32> = (0..9 * vol).map(|i| ((i * 5 % 23) as f32 - 11.0) * 0.13).collect();
        let dq: Vec<f32> = (0..9 * vol).map(|i| ((i * 3 % 29) as f32 - 14.0) * 0.09).collect();
        let (mut qs, mut rs) = (q0.clone(), r0.clone());
        rk_update(Lanes::Scalar, &mut qs, &mut rs, &dq, 1e-3, -0.4, 0.7);
        let mut ss = vec![0.0f32; 6 * vol];
        stress(Lanes::Scalar, &q0, &mut ss, vol, 2.0, 0.8);
        for lanes in [Lanes::W4, Lanes::W8] {
            if lanes.width() > detect().width() {
                continue;
            }
            let (mut qv, mut rv) = (q0.clone(), r0.clone());
            rk_update(lanes, &mut qv, &mut rv, &dq, 1e-3, -0.4, 0.7);
            assert_lane_eq(&qv, &qs, lanes, &format!("{lanes:?} q"));
            assert_lane_eq(&rv, &rs, lanes, &format!("{lanes:?} res"));
            let mut sv = vec![0.0f32; 6 * vol];
            stress(lanes, &q0, &mut sv, vol, 2.0, 0.8);
            assert_lane_eq(&sv, &ss, lanes, &format!("{lanes:?} stress"));
        }
    }

    #[test]
    fn fma_toggle_is_clamped_and_off_means_bitwise() {
        // default (auto): active iff available; pinning mirrors that clamp
        assert_eq!(fma_active(), fma_available());
        assert!(!set_fma(Some(false)));
        assert!(!fma_active());
        assert!(!fma_contracts(Lanes::W8));
        // with contraction pinned off, W8 must be bitwise-equal to scalar
        // even on simd-fma builds
        if detect() == Lanes::W8 {
            let len = 64usize;
            let src: Vec<f32> = (0..len).map(|i| ((i * 7 % 13) as f32 - 6.0) * 0.3).collect();
            let mut want: Vec<f32> = (0..len).map(|i| (i as f32) * 0.1).collect();
            let mut got = want.clone();
            axpy(Lanes::Scalar, &mut want, &src, 0.37);
            axpy(Lanes::W8, &mut got, &src, 0.37);
            assert!(got == want, "pinned-off FMA must not contract");
        }
        assert_eq!(set_fma(Some(true)), fma_available(), "pin-on clamps to capability");
        assert_eq!(set_fma(None), fma_available());
        // scalar and W4 never contract regardless of the toggle
        assert!(!fma_contracts(Lanes::Scalar));
        assert!(!fma_contracts(Lanes::W4));
        assert!(!fma_possible(Lanes::W4));
    }
}
