//! Multithreaded CPU stage backend with the paper's level-2 nested split
//! applied *inside* a block.
//!
//! [`ParallelRefBackend`] advances the same DGSEM stage math as the scalar
//! reference backend (it shares `reference::rhs_element`, so results are
//! bitwise identical), but sweeps elements from a scoped thread pool with
//! per-thread scratch, in two phases mirroring Fig 4.1's CPU/accelerator
//! concurrency:
//!
//! 1. **boundary phase** — elements with at least one halo face (the
//!    communication-owning elements, `partition::nested::split_block_elements`)
//!    are advanced first: RHS, RK update, and a refresh of exactly their
//!    halo-facing face traces. After this phase every outbound trace of the
//!    exchange plan is final.
//! 2. **interior phase** — the remaining elements (which never touch the
//!    halo) are advanced while the driver concurrently scatters the
//!    gathered boundary traces into neighbor halos
//!    ([`crate::solver::driver::Driver`] with `overlap = true`, or the
//!    [`crate::coordinator::node`] workers, which ship traces between the
//!    phases).
//!
//! Phase ordering is exact, not approximate: all RHS evaluations read the
//! pre-stage traces (the boundary phase refreshes only halo-facing faces,
//! which same-block elements never read), and element updates are
//! per-element independent.
//!
//! Reported [`KernelTimes`] sum the per-thread RHS kernel timers (CPU
//! seconds, so they can exceed wall time) and attribute rk/interp_q by
//! phase wall time.

use std::collections::HashMap;
use std::time::Instant;

use super::basis::LglBasis;
use super::driver::StageBackend;
use super::reference::{rhs_element, ElemScratch, KernelTimes, RhsCtx};
use super::state::{refresh_elem_face, refresh_elem_traces, BlockState, InteriorView, NFIELDS};
use crate::mesh::halo::LOCAL_HALO;
use crate::partition::nested::split_block_elements;
use crate::Result;

/// Boundary/interior element split of one block, plus the halo-facing
/// (element, face) pairs whose traces feed the exchange plan.
#[derive(Debug, Clone, Default)]
pub struct BlockSplit {
    pub boundary: Vec<usize>,
    pub interior: Vec<usize>,
    pub halo_faces: Vec<(usize, usize)>,
}

/// Classify a block's real elements from its local connectivity.
pub fn classify_elements(conn: &[i32], k_real: usize) -> BlockSplit {
    let (boundary, interior) = split_block_elements(conn, k_real);
    let mut halo_faces = Vec::new();
    for &e in &boundary {
        for f in 0..6 {
            if conn[e * 6 + f] == LOCAL_HALO {
                halo_faces.push((e, f));
            }
        }
    }
    BlockSplit { boundary, interior, halo_faces }
}

/// The multithreaded reference backend (see module docs).
pub struct ParallelRefBackend {
    basis: LglBasis,
    threads: usize,
    /// dq accumulator keyed by (k_pad, m), reused across stages.
    dq: HashMap<(usize, usize), Vec<f32>>,
    /// One element-scratch per worker thread.
    pool: Vec<ElemScratch>,
    /// Split computed by the boundary phase, consumed by the interior one.
    pending: Option<BlockSplit>,
    /// Identity element list 0..k_real, grown on demand (avoids a per-stage
    /// allocation in the full trace refresh).
    all_elems: Vec<usize>,
}

impl ParallelRefBackend {
    /// Backend with one worker per available hardware thread.
    pub fn new(order: usize) -> Self {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::with_threads(order, threads)
    }

    /// Backend with an explicit worker count (>= 1).
    pub fn with_threads(order: usize, threads: usize) -> Self {
        ParallelRefBackend {
            basis: LglBasis::new(order),
            threads: threads.max(1),
            dq: HashMap::new(),
            pool: Vec::new(),
            pending: None,
            all_elems: Vec::new(),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    fn ensure_pool(&mut self, m: usize) {
        // scratch is sized by m; the basis fixes m for every block this
        // backend can legally stage
        debug_assert_eq!(m, self.basis.m());
        while self.pool.len() < self.threads {
            self.pool.push(ElemScratch::new(m));
        }
    }

    /// Boundary phase on a full state (RHS + RK + halo-face trace refresh
    /// for boundary elements). Returns the computed split for reuse.
    fn phase_boundary(
        &mut self,
        st: &mut BlockState,
        split: &BlockSplit,
        dt: f32,
        a: f32,
        b: f32,
    ) -> KernelTimes {
        let m = st.m;
        let vol = m * m * m;
        let esz = NFIELDS * vol;
        self.ensure_pool(m);
        let dq = self
            .dq
            .entry((st.k_pad, m))
            .or_insert_with(|| vec![0.0; st.k_pad * esz]);
        let cx = RhsCtx::of(st);
        let mut times =
            par_rhs(&self.basis, self.threads, &mut self.pool, dq, &cx, &split.boundary);
        let t0 = Instant::now();
        par_update(self.threads, &mut st.q, &mut st.res, dq, &split.boundary, esz, dt, a, b);
        times.rk += t0.elapsed().as_secs_f64();
        // refresh exactly the halo-facing traces: same-block elements never
        // read these faces, so the pre-stage trace invariant holds for the
        // interior sweep while the exchange plan sees final data
        let t0 = Instant::now();
        let tsz = 6 * NFIELDS * m * m;
        for &(e, f) in &split.halo_faces {
            let q_e = &st.q[e * esz..(e + 1) * esz];
            let tr_e = &mut st.traces[e * tsz..(e + 1) * tsz];
            refresh_elem_face(m, q_e, tr_e, f);
        }
        times.interp_q += t0.elapsed().as_secs_f64();
        times
    }

    /// Interior phase on a split view (RHS + RK for interior elements,
    /// then a full trace refresh of every real element).
    fn phase_interior(
        &mut self,
        v: &mut InteriorView<'_>,
        split: &BlockSplit,
        dt: f32,
        a: f32,
        b: f32,
    ) -> KernelTimes {
        let m = v.m;
        let vol = m * m * m;
        let esz = NFIELDS * vol;
        self.ensure_pool(m);
        let dq = self
            .dq
            .entry((v.k_pad, m))
            .or_insert_with(|| vec![0.0; v.k_pad * esz]);
        let cx = RhsCtx {
            m,
            q: &*v.q,
            traces: &*v.traces,
            // interior elements have no halo faces by construction
            halo: &[],
            conn: v.conn,
            halo_idx: v.halo_idx,
            mats: v.mats,
            halo_mats: v.halo_mats,
            h: v.h,
        };
        let mut times =
            par_rhs(&self.basis, self.threads, &mut self.pool, dq, &cx, &split.interior);
        let t0 = Instant::now();
        par_update(self.threads, v.q, v.res, dq, &split.interior, esz, dt, a, b);
        times.rk += t0.elapsed().as_secs_f64();
        // full refresh of every real element: interior faces get their
        // post-update traces; boundary halo faces are rewritten with the
        // values the boundary phase already published (idempotent)
        let t0 = Instant::now();
        while self.all_elems.len() < v.k_real {
            self.all_elems.push(self.all_elems.len());
        }
        par_refresh(self.threads, m, v.q, v.traces, &self.all_elems[..v.k_real]);
        times.interp_q += t0.elapsed().as_secs_f64();
        times
    }
}

impl StageBackend for ParallelRefBackend {
    fn stage(&mut self, st: &mut BlockState, dt: f32, a: f32, b: f32) -> Result<KernelTimes> {
        self.pending = None;
        let split = classify_elements(&st.conn, st.k_real);
        let mut times = self.phase_boundary(st, &split, dt, a, b);
        let (mut view, _halo) = st.split_for_overlap();
        times.accumulate(&self.phase_interior(&mut view, &split, dt, a, b));
        Ok(times)
    }

    fn name(&self) -> &'static str {
        "rust-parallel"
    }

    fn supports_overlap(&self) -> bool {
        true
    }

    fn stage_boundary(
        &mut self,
        st: &mut BlockState,
        dt: f32,
        a: f32,
        b: f32,
    ) -> Result<KernelTimes> {
        let split = classify_elements(&st.conn, st.k_real);
        let times = self.phase_boundary(st, &split, dt, a, b);
        self.pending = Some(split);
        Ok(times)
    }

    fn stage_interior(
        &mut self,
        v: &mut InteriorView<'_>,
        dt: f32,
        a: f32,
        b: f32,
    ) -> Result<KernelTimes> {
        let split = match self.pending.take() {
            Some(s) => s,
            None => classify_elements(v.conn, v.k_real),
        };
        Ok(self.phase_interior(v, &split, dt, a, b))
    }
}

/// RHS sweep over an element subset from up to `threads` scoped workers.
/// Each worker owns one [`ElemScratch`] and a disjoint set of per-element
/// `dq` slices (handed out through a take-once slot table, so no unsafe
/// aliasing anywhere). Returns the per-thread kernel timers summed.
fn par_rhs(
    basis: &LglBasis,
    threads: usize,
    pool: &mut [ElemScratch],
    dq: &mut [f32],
    cx: &RhsCtx<'_>,
    elems: &[usize],
) -> KernelTimes {
    let mut total = KernelTimes::default();
    if elems.is_empty() {
        return total;
    }
    let esz = NFIELDS * cx.m * cx.m * cx.m;
    let nt = threads.min(elems.len()).max(1);
    if nt == 1 {
        let scr = &mut pool[0];
        for &e in elems {
            rhs_element(cx, basis, e, &mut dq[e * esz..(e + 1) * esz], scr, &mut total);
        }
        return total;
    }
    let mut slots: Vec<Option<&mut [f32]>> = dq.chunks_mut(esz).map(Some).collect();
    let chunk = elems.len().div_euclid(nt) + usize::from(elems.len() % nt != 0);
    let mut jobs: Vec<(Vec<(usize, &mut [f32])>, &mut ElemScratch)> = Vec::new();
    let mut pool_iter = pool.iter_mut();
    for ids in elems.chunks(chunk) {
        let items: Vec<(usize, &mut [f32])> = ids
            .iter()
            .map(|&e| (e, slots[e].take().expect("element listed twice")))
            .collect();
        jobs.push((items, pool_iter.next().expect("scratch pool smaller than thread count")));
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = jobs
            .into_iter()
            .map(|(items, scr)| {
                let cx = *cx;
                s.spawn(move || {
                    let mut t = KernelTimes::default();
                    for (e, dq_e) in items {
                        rhs_element(&cx, basis, e, dq_e, scr, &mut t);
                    }
                    t
                })
            })
            .collect();
        for h in handles {
            total.accumulate(&h.join().expect("rhs worker panicked"));
        }
    });
    total
}

/// Low-storage RK update of an element subset, threaded the same way.
#[allow(clippy::too_many_arguments)]
fn par_update(
    threads: usize,
    q: &mut [f32],
    res: &mut [f32],
    dq: &[f32],
    elems: &[usize],
    esz: usize,
    dt: f32,
    a: f32,
    b: f32,
) {
    if elems.is_empty() {
        return;
    }
    let nt = threads.min(elems.len()).max(1);
    if nt == 1 {
        for &e in elems {
            update_elem(
                &mut q[e * esz..(e + 1) * esz],
                &mut res[e * esz..(e + 1) * esz],
                &dq[e * esz..(e + 1) * esz],
                dt,
                a,
                b,
            );
        }
        return;
    }
    let mut q_slots: Vec<Option<&mut [f32]>> = q.chunks_mut(esz).map(Some).collect();
    let mut r_slots: Vec<Option<&mut [f32]>> = res.chunks_mut(esz).map(Some).collect();
    let chunk = elems.len().div_euclid(nt) + usize::from(elems.len() % nt != 0);
    std::thread::scope(|s| {
        for ids in elems.chunks(chunk) {
            let items: Vec<(&mut [f32], &mut [f32], &[f32])> = ids
                .iter()
                .map(|&e| {
                    (
                        q_slots[e].take().expect("element listed twice"),
                        r_slots[e].take().expect("element listed twice"),
                        &dq[e * esz..(e + 1) * esz],
                    )
                })
                .collect();
            s.spawn(move || {
                for (q_e, r_e, dq_e) in items {
                    update_elem(q_e, r_e, dq_e, dt, a, b);
                }
            });
        }
    });
}

#[inline]
fn update_elem(q_e: &mut [f32], r_e: &mut [f32], dq_e: &[f32], dt: f32, a: f32, b: f32) {
    for (r, d) in r_e.iter_mut().zip(dq_e) {
        *r = a * *r + dt * *d;
    }
    for (qv, r) in q_e.iter_mut().zip(r_e.iter()) {
        *qv += b * *r;
    }
}

/// Threaded trace refresh of an element subset.
fn par_refresh(threads: usize, m: usize, q: &[f32], traces: &mut [f32], elems: &[usize]) {
    if elems.is_empty() {
        return;
    }
    let esz = NFIELDS * m * m * m;
    let tsz = 6 * NFIELDS * m * m;
    let nt = threads.min(elems.len()).max(1);
    if nt == 1 {
        for &e in elems {
            refresh_elem_traces(m, &q[e * esz..(e + 1) * esz], &mut traces[e * tsz..(e + 1) * tsz]);
        }
        return;
    }
    let mut t_slots: Vec<Option<&mut [f32]>> = traces.chunks_mut(tsz).map(Some).collect();
    let chunk = elems.len().div_euclid(nt) + usize::from(elems.len() % nt != 0);
    std::thread::scope(|s| {
        for ids in elems.chunks(chunk) {
            let items: Vec<(&[f32], &mut [f32])> = ids
                .iter()
                .map(|&e| {
                    (
                        &q[e * esz..(e + 1) * esz],
                        t_slots[e].take().expect("element listed twice"),
                    )
                })
                .collect();
            s.spawn(move || {
                for (q_e, tr_e) in items {
                    refresh_elem_traces(m, q_e, tr_e);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::{build_local_blocks, geometry::unit_cube_geometry};
    use crate::solver::reference::{stage as ref_stage, RefScratch};
    use crate::solver::rk::{LSRK_A, LSRK_B, N_STAGES};

    fn state(order: usize, n: usize) -> BlockState {
        let mesh = unit_cube_geometry(n);
        let owners = vec![0usize; mesh.len()];
        let (blocks, _) = build_local_blocks(&mesh, &owners, 1);
        let k = blocks[0].len();
        BlockState::from_local_block(&blocks[0], order, k, 8)
    }

    #[test]
    fn classify_single_block_is_all_interior() {
        let st = state(2, 2);
        let split = classify_elements(&st.conn, st.k_real);
        assert!(split.boundary.is_empty());
        assert_eq!(split.interior.len(), st.k_real);
        assert!(split.halo_faces.is_empty());
    }

    #[test]
    fn classify_two_owner_split() {
        let mesh = unit_cube_geometry(2);
        let owners: Vec<usize> = (0..8).map(|e| e % 2).collect();
        let (blocks, _) = build_local_blocks(&mesh, &owners, 2);
        for lb in &blocks {
            let st = BlockState::from_local_block(lb, 1, lb.len(), lb.halo_len.max(1));
            let split = classify_elements(&st.conn, st.k_real);
            // the pathological parity split makes every element a halo owner
            assert_eq!(split.boundary.len(), st.k_real);
            assert!(split.interior.is_empty());
            assert_eq!(split.halo_faces.len(), lb.halo_len);
        }
    }

    #[test]
    fn parallel_stage_matches_scalar_bitwise() {
        for (order, threads) in [(2usize, 1usize), (2, 4), (3, 2), (3, 4)] {
            let basis = LglBasis::new(order);
            let w = std::f64::consts::PI * 3f64.sqrt();
            let ic =
                |x: [f64; 3]| crate::solver::analytic::standing_wave(x, 0.0, 1.0, 1.0, w);
            let mut st_s = state(order, 2);
            st_s.set_initial_condition(&basis, ic);
            let mut st_p = st_s.clone();
            let mut scratch = RefScratch::new(&st_s);
            let mut par = ParallelRefBackend::with_threads(order, threads);
            for step in 0..3 {
                for s in 0..N_STAGES {
                    let (a, b) = (LSRK_A[s] as f32, LSRK_B[s] as f32);
                    ref_stage(&mut st_s, &basis, &mut scratch, 1e-3, a, b);
                    par.stage(&mut st_p, 1e-3, a, b).unwrap();
                }
                assert_eq!(st_s.q, st_p.q, "order {order} threads {threads} step {step}");
                assert_eq!(st_s.res, st_p.res);
                let live = st_s.k_real * 6 * NFIELDS * st_s.m * st_s.m;
                assert_eq!(st_s.traces[..live], st_p.traces[..live]);
            }
        }
    }

    #[test]
    fn split_stage_equals_fused_stage() {
        // stage_boundary + scatter-free stage_interior == stage()
        let order = 2;
        let mesh = unit_cube_geometry(2);
        let owners: Vec<usize> = (0..8).map(|e| usize::from(e >= 4)).collect();
        let (blocks, _) = build_local_blocks(&mesh, &owners, 2);
        let basis = LglBasis::new(order);
        let w = std::f64::consts::PI * 3f64.sqrt();
        let mut a_state =
            BlockState::from_local_block(&blocks[0], order, blocks[0].len(), blocks[0].halo_len);
        a_state.set_initial_condition(&basis, |x| {
            crate::solver::analytic::standing_wave(x, 0.0, 1.0, 1.0, w)
        });
        let mut b_state = a_state.clone();
        let mut fused = ParallelRefBackend::with_threads(order, 2);
        let mut split = ParallelRefBackend::with_threads(order, 2);
        fused.stage(&mut a_state, 1e-3, -0.3, 0.7).unwrap();
        split.stage_boundary(&mut b_state, 1e-3, -0.3, 0.7).unwrap();
        let (mut view, _halo) = b_state.split_for_overlap();
        split.stage_interior(&mut view, 1e-3, -0.3, 0.7).unwrap();
        assert_eq!(a_state.q, b_state.q);
        assert_eq!(a_state.traces, b_state.traces);
    }

    #[test]
    fn zero_state_stays_zero_parallel() {
        let mut st = state(2, 2);
        let mut par = ParallelRefBackend::with_threads(2, 3);
        par.stage(&mut st, 1e-3, 0.0, 1.0).unwrap();
        assert!(st.q.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn kernel_times_reported() {
        let basis = LglBasis::new(2);
        let mut st = state(2, 2);
        st.set_initial_condition(&basis, |x| [x[0], 0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
        let mut par = ParallelRefBackend::with_threads(2, 2);
        let t = par.stage(&mut st, 1e-3, 0.0, 1.0).unwrap();
        assert!(t.volume_loop > 0.0);
        assert!(t.total() > 0.0);
    }
}
