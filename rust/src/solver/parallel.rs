//! Multithreaded CPU stage backend with the paper's level-2 nested split
//! applied *inside* a block, running on a **persistent worker pool**.
//!
//! [`ParallelRefBackend`] advances the same DGSEM stage math as the scalar
//! reference backend (it shares `reference::rhs_element`, so results are
//! bitwise identical), in two phases mirroring Fig 4.1's CPU/accelerator
//! concurrency:
//!
//! 1. **boundary phase** — elements with at least one halo face (the
//!    communication-owning elements, `partition::nested::split_block_elements`)
//!    are advanced first: RHS, RK update, and a refresh of exactly their
//!    halo-facing face traces. After this phase every outbound trace of the
//!    exchange plan is final.
//! 2. **interior phase** — the remaining elements (which never touch the
//!    halo) are advanced while the driver concurrently scatters the
//!    gathered boundary traces into neighbor halos
//!    ([`crate::solver::driver::Driver`] with `overlap = true`, or the
//!    [`crate::coordinator::node`] workers, which ship traces between the
//!    phases).
//!
//! Three properties distinguish this from the original scoped-thread
//! implementation (kept as [`ParallelRefBackend::legacy_scoped`] so the
//! benches can price the difference):
//!
//! * **Persistent pool.** Worker threads are created once per backend (or
//!   shared across a cluster worker's backends) and live in a
//!   [`crate::util::pool::WorkerPool`]; a stage costs pool *rendezvous*
//!   (condvar wake + barrier), not thread spawn/join sweeps.
//! * **Fused pipeline.** RHS and the RK update ride in one per-element
//!   pass: each pool worker owns a disjoint element slice and, per
//!   element, evaluates the RHS then updates `q`/`res` in place. This is
//!   exact because [`rhs_element`] reads only the element's own `q` plus
//!   *traces* of neighbors — never neighbor `q` — and no trace is written
//!   during the pass. The full trace refresh (interior phase) runs as a
//!   second, pool-internal barrier phase of the *same* rendezvous. Six
//!   spawn/join barriers per stage become two rendezvous (one per phase).
//! * **Memoized classification.** The boundary/interior split depends
//!   only on the block's immutable connectivity, so it is computed once
//!   and cached, keyed on the block's process-unique identity
//!   ([`BlockState::uid`]; [`ParallelRefBackend::classify_computes`]
//!   exposes the counter). A cluster rebalance that keeps a worker's
//!   blocks keeps the cache; a rebuild starts fresh.
//!
//! Phase ordering is exact, not approximate: all RHS evaluations read the
//! pre-stage traces (the boundary phase refreshes only halo-facing faces,
//! which same-block elements never read, and the refresh happens after
//! the fused pass), and element updates are per-element independent.
//!
//! Reported [`KernelTimes`] sum the per-thread kernel timers (CPU
//! seconds, so they can exceed wall time).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::basis::LglBasis;
use super::driver::StageBackend;
use super::reference::{rhs_element, ElemScratch, KernelTimes, RhsCtx};
use super::simd;
use super::state::{
    refresh_elem_face, refresh_elem_faces_masked, refresh_elem_traces, BlockState, InteriorView,
    NFIELDS,
};
use crate::mesh::halo::LOCAL_HALO;
use crate::partition::nested::split_block_elements;
use crate::util::pool::{PoolSlice, WorkerPool};
use crate::Result;

/// Boundary/interior element split of one block, plus the halo-facing
/// (element, face) pairs whose traces feed the exchange plan.
#[derive(Debug, Clone, Default)]
pub struct BlockSplit {
    pub boundary: Vec<usize>,
    pub interior: Vec<usize>,
    pub halo_faces: Vec<(usize, usize)>,
    /// Per-element face-dirty bitmap for the interior phase's trace
    /// refresh (bit `f` set = face `f` still needs refreshing then).
    /// Boundary elements drop exactly their halo-facing bits — those
    /// faces were already refreshed by the boundary phase and `q` hasn't
    /// changed since — so the two phases' refreshes union to exactly one
    /// write per face per stage.
    pub interior_refresh: Vec<u8>,
}

/// Classify a block's real elements from its local connectivity.
pub fn classify_elements(conn: &[i32], k_real: usize) -> BlockSplit {
    let (boundary, interior) = split_block_elements(conn, k_real);
    let mut halo_faces = Vec::new();
    let mut interior_refresh = vec![0x3Fu8; k_real];
    for &e in &boundary {
        for f in 0..6 {
            if conn[e * 6 + f] == LOCAL_HALO {
                halo_faces.push((e, f));
                interior_refresh[e] &= !(1u8 << f);
            }
        }
    }
    BlockSplit { boundary, interior, halo_faces, interior_refresh }
}

/// Identity of one block's classification inputs: the block's
/// process-unique [`BlockState::uid`] (clones share it — identical
/// connectivity — while a migrated/rebuilt block gets a fresh one, so a
/// stale split can never alias the way a pointer key could) plus the real
/// element count as a belt-and-braces check.
type SplitKey = (u64, usize);

struct SplitCache {
    key: SplitKey,
    split: BlockSplit,
}

/// The multithreaded reference backend (see module docs).
pub struct ParallelRefBackend {
    basis: LglBasis,
    threads: usize,
    /// The persistent pool slice this backend dispatches onto; possibly
    /// shared with the other backends of one cluster worker
    /// ([`ParallelRefBackend::with_pool`]) or carved out of a bigger
    /// serving pool ([`ParallelRefBackend::with_slice`]).
    pool: PoolSlice,
    /// One element-scratch per pool worker (locked once per dispatch —
    /// each worker touches exactly its own slot).
    scratch: Vec<Mutex<ElemScratch>>,
    /// Per-worker kernel-time accumulators owned by the backend: a fused
    /// sweep drains (and zeroes) them after the rendezvous instead of
    /// allocating a fresh `Vec<Mutex<KernelTimes>>` per dispatch.
    worker_times: Vec<Mutex<KernelTimes>>,
    /// dq accumulator keyed by (k_pad, m), reused across stages.
    dq: HashMap<(usize, usize), Vec<f32>>,
    /// Memoized boundary/interior classification (see module docs).
    cache: Option<SplitCache>,
    /// Times the classification was actually computed (stays flat across
    /// stages once warm; the cluster tests assert survival).
    classify_computes: u64,
    /// Identity element list 0..k_real, grown on demand (avoids a
    /// per-stage allocation in the fused full-stage sweep).
    all_elems: Vec<usize>,
    /// Run the pre-pool scoped-thread pipeline (benches only).
    legacy: bool,
    /// Scratch for the legacy path (one per scoped worker).
    legacy_scratch: Vec<ElemScratch>,
    /// Split computed by a legacy boundary phase, consumed by the legacy
    /// interior phase.
    legacy_pending: Option<BlockSplit>,
}

impl ParallelRefBackend {
    /// Backend with one worker per available hardware thread.
    pub fn new(order: usize) -> Self {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::with_threads(order, threads)
    }

    /// Backend with an explicit worker count (>= 1); the pool (and its
    /// `threads - 1` OS threads) is created here and lives as long as the
    /// backend.
    pub fn with_threads(order: usize, threads: usize) -> Self {
        Self::with_pool(order, Arc::new(WorkerPool::new(threads.max(1), None)))
    }

    /// Backend on an existing (possibly shared) pool — the cluster's
    /// worker factory builds one pool per worker and hands it to every
    /// block backend of that worker.
    pub fn with_pool(order: usize, pool: Arc<WorkerPool>) -> Self {
        Self::with_slice(order, PoolSlice::full(pool))
    }

    /// Backend on a [`PoolSlice`] — the serving layer gives each
    /// co-scheduled job a disjoint slice of one shared pool, so the jobs'
    /// stage dispatches proceed concurrently.
    pub fn with_slice(order: usize, pool: PoolSlice) -> Self {
        let basis = LglBasis::new(order);
        let m = basis.m();
        let threads = pool.threads();
        let scratch = (0..threads).map(|_| Mutex::new(ElemScratch::new(m))).collect();
        let worker_times = (0..threads).map(|_| Mutex::new(KernelTimes::default())).collect();
        ParallelRefBackend {
            basis,
            threads,
            pool,
            scratch,
            worker_times,
            dq: HashMap::new(),
            cache: None,
            classify_computes: 0,
            all_elems: Vec::new(),
            legacy: false,
            legacy_scratch: Vec::new(),
            legacy_pending: None,
        }
    }

    /// The pre-pool implementation: per-stage scoped-thread sweeps for
    /// RHS, RK and trace refresh (three spawn/join barriers per phase)
    /// with per-stage classification. Kept so `benches/rhs_reference.rs`
    /// can price the fused pipeline against it (`stage_spawn_overhead`);
    /// not intended for production use.
    pub fn legacy_scoped(order: usize, threads: usize) -> Self {
        let mut b = Self::with_pool(order, Arc::new(WorkerPool::new(1, None)));
        b.threads = threads.max(1);
        b.legacy = true;
        b
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Generation id of the backend's persistent pool (see
    /// [`WorkerPool::generation`]).
    pub fn pool_generation(&self) -> u64 {
        self.pool.generation()
    }

    /// How many times the boundary/interior classification was computed
    /// (memoized: flat once warm; legacy mode recomputes per stage).
    pub fn classify_computes(&self) -> u64 {
        self.classify_computes
    }

    /// Memoize the classification for this block's connectivity.
    fn memoize_split(&mut self, uid: u64, conn: &[i32], k_real: usize) {
        let key = (uid, k_real);
        if !self.cache.as_ref().is_some_and(|c| c.key == key) {
            self.cache = Some(SplitCache { key, split: classify_elements(conn, k_real) });
            self.classify_computes += 1;
        }
    }

    // -- legacy scoped-thread pipeline (benches only) ---------------------

    fn ensure_legacy_scratch(&mut self, m: usize) {
        debug_assert_eq!(m, self.basis.m());
        while self.legacy_scratch.len() < self.threads {
            self.legacy_scratch.push(ElemScratch::new(m));
        }
    }

    /// Legacy boundary phase on a full state (scoped-thread RHS + RK +
    /// halo-face trace refresh for boundary elements).
    fn legacy_phase_boundary(
        &mut self,
        st: &mut BlockState,
        split: &BlockSplit,
        dt: f32,
        a: f32,
        b: f32,
    ) -> KernelTimes {
        let m = st.m;
        let vol = m * m * m;
        let esz = NFIELDS * vol;
        self.ensure_legacy_scratch(m);
        let dq = self
            .dq
            .entry((st.k_pad, m))
            .or_insert_with(|| vec![0.0; st.k_pad * esz]);
        let cx = RhsCtx::of(st);
        let mut times = par_rhs(
            &self.basis,
            self.threads,
            &mut self.legacy_scratch,
            dq,
            &cx,
            &st.q,
            &split.boundary,
        );
        let t0 = Instant::now();
        par_update(self.threads, &mut st.q, &mut st.res, dq, &split.boundary, esz, dt, a, b);
        times.rk += t0.elapsed().as_secs_f64();
        // refresh exactly the halo-facing traces: same-block elements never
        // read these faces, so the pre-stage trace invariant holds for the
        // interior sweep while the exchange plan sees final data
        let t0 = Instant::now();
        let tsz = 6 * NFIELDS * m * m;
        for &(e, f) in &split.halo_faces {
            let q_e = &st.q[e * esz..(e + 1) * esz];
            let tr_e = &mut st.traces[e * tsz..(e + 1) * tsz];
            refresh_elem_face(m, q_e, tr_e, f);
        }
        times.interp_q += t0.elapsed().as_secs_f64();
        times
    }

    /// Legacy interior phase on a split view (scoped-thread RHS + RK for
    /// interior elements, then a full trace refresh of every real
    /// element).
    fn legacy_phase_interior(
        &mut self,
        v: &mut InteriorView<'_>,
        split: &BlockSplit,
        dt: f32,
        a: f32,
        b: f32,
    ) -> KernelTimes {
        let m = v.m;
        let vol = m * m * m;
        let esz = NFIELDS * vol;
        self.ensure_legacy_scratch(m);
        let dq = self
            .dq
            .entry((v.k_pad, m))
            .or_insert_with(|| vec![0.0; v.k_pad * esz]);
        let cx = RhsCtx {
            m,
            traces: &*v.traces,
            // interior elements have no halo faces by construction
            halo: &[],
            conn: v.conn,
            halo_idx: v.halo_idx,
            mats: v.mats,
            halo_mats: v.halo_mats,
            h: v.h,
            lanes: simd::active(),
        };
        let mut times = par_rhs(
            &self.basis,
            self.threads,
            &mut self.legacy_scratch,
            dq,
            &cx,
            v.q,
            &split.interior,
        );
        let t0 = Instant::now();
        par_update(self.threads, v.q, v.res, dq, &split.interior, esz, dt, a, b);
        times.rk += t0.elapsed().as_secs_f64();
        // full refresh of every real element: interior faces get their
        // post-update traces; boundary halo faces are rewritten with the
        // values the boundary phase already published (idempotent)
        let t0 = Instant::now();
        while self.all_elems.len() < v.k_real {
            self.all_elems.push(self.all_elems.len());
        }
        par_refresh(self.threads, m, v.q, v.traces, &self.all_elems[..v.k_real]);
        times.interp_q += t0.elapsed().as_secs_f64();
        times
    }

    // -- fused pool pipeline (the default) --------------------------------

    /// Fused boundary phase: one pool rendezvous sweeping the boundary
    /// elements (RHS + RK per element), then the serial halo-face trace
    /// refresh (surface-sized; same placement as the legacy path).
    fn fused_boundary(&mut self, st: &mut BlockState, dt: f32, a: f32, b: f32) -> KernelTimes {
        self.memoize_split(st.uid, &st.conn, st.k_real);
        let m = st.m;
        let esz = NFIELDS * m * m * m;
        let tsz = 6 * NFIELDS * m * m;
        let ParallelRefBackend { basis, pool, scratch, worker_times, dq, cache, .. } = self;
        let split = &cache.as_ref().expect("memoized above").split;
        let dqv = dq
            .entry((st.k_pad, m))
            .or_insert_with(|| vec![0.0; st.k_pad * esz]);
        let mut times = fused_sweep(
            basis,
            pool,
            scratch,
            worker_times,
            &split.boundary,
            None,
            None,
            FusedShared {
                m,
                conn: &st.conn,
                halo: &st.halo,
                halo_idx: &st.halo_idx,
                mats: &st.mats,
                halo_mats: &st.halo_mats,
                h: &st.h,
            },
            RawMut::new(&mut st.q),
            RawMut::new(&mut st.res),
            RawMut::new(dqv),
            RawMut::new(&mut st.traces),
            dt,
            a,
            b,
        );
        let t0 = Instant::now();
        for &(e, f) in &split.halo_faces {
            let q_e = &st.q[e * esz..(e + 1) * esz];
            let tr_e = &mut st.traces[e * tsz..(e + 1) * tsz];
            refresh_elem_face(m, q_e, tr_e, f);
        }
        times.interp_q += t0.elapsed().as_secs_f64();
        times
    }

    /// Fused interior phase: one pool rendezvous — RHS + RK over the
    /// interior elements, then (behind the pool-internal barrier) the
    /// full trace refresh of every real element.
    fn fused_interior(&mut self, v: &mut InteriorView<'_>, dt: f32, a: f32, b: f32) -> KernelTimes {
        self.memoize_split(v.uid, v.conn, v.k_real);
        let m = v.m;
        let esz = NFIELDS * m * m * m;
        let ParallelRefBackend { basis, pool, scratch, worker_times, dq, cache, .. } = self;
        let split = &cache.as_ref().expect("memoized above").split;
        let dqv = dq
            .entry((v.k_pad, m))
            .or_insert_with(|| vec![0.0; v.k_pad * esz]);
        fused_sweep(
            basis,
            pool,
            scratch,
            worker_times,
            &split.interior,
            Some(v.k_real),
            // the boundary phase already refreshed the halo-facing traces
            // (and q hasn't changed since), so skip exactly those faces
            Some(&split.interior_refresh),
            FusedShared {
                m,
                conn: v.conn,
                // interior elements have no halo faces by construction —
                // and the halo is being rewritten concurrently by the
                // overlap scatter, so it must not be read here
                halo: &[],
                halo_idx: v.halo_idx,
                mats: v.mats,
                halo_mats: v.halo_mats,
                h: v.h,
            },
            RawMut::new(v.q),
            RawMut::new(v.res),
            RawMut::new(dqv),
            RawMut::new(v.traces),
            dt,
            a,
            b,
        )
    }

    /// Fused full stage (serial schedule): every real element in one
    /// rendezvous (RHS + RK), full trace refresh behind the barrier. No
    /// classification needed — boundary and interior elements take the
    /// same path when there is no overlap to schedule around.
    fn fused_stage(&mut self, st: &mut BlockState, dt: f32, a: f32, b: f32) -> KernelTimes {
        let m = st.m;
        let esz = NFIELDS * m * m * m;
        while self.all_elems.len() < st.k_real {
            self.all_elems.push(self.all_elems.len());
        }
        let ParallelRefBackend { basis, pool, scratch, worker_times, dq, all_elems, .. } = self;
        let dqv = dq
            .entry((st.k_pad, m))
            .or_insert_with(|| vec![0.0; st.k_pad * esz]);
        fused_sweep(
            basis,
            pool,
            scratch,
            worker_times,
            &all_elems[..st.k_real],
            Some(st.k_real),
            // serial schedule: no boundary phase ran, refresh every face
            None,
            FusedShared {
                m,
                conn: &st.conn,
                halo: &st.halo,
                halo_idx: &st.halo_idx,
                mats: &st.mats,
                halo_mats: &st.halo_mats,
                h: &st.h,
            },
            RawMut::new(&mut st.q),
            RawMut::new(&mut st.res),
            RawMut::new(dqv),
            RawMut::new(&mut st.traces),
            dt,
            a,
            b,
        )
    }
}

impl StageBackend for ParallelRefBackend {
    fn stage(&mut self, st: &mut BlockState, dt: f32, a: f32, b: f32) -> Result<KernelTimes> {
        if self.legacy {
            self.legacy_pending = None;
            let split = classify_elements(&st.conn, st.k_real);
            self.classify_computes += 1;
            let mut times = self.legacy_phase_boundary(st, &split, dt, a, b);
            let (mut view, _halo) = st.split_for_overlap();
            times.accumulate(&self.legacy_phase_interior(&mut view, &split, dt, a, b));
            return Ok(times);
        }
        Ok(self.fused_stage(st, dt, a, b))
    }

    fn name(&self) -> &'static str {
        "rust-parallel"
    }

    fn supports_overlap(&self) -> bool {
        true
    }

    fn stage_boundary(
        &mut self,
        st: &mut BlockState,
        dt: f32,
        a: f32,
        b: f32,
    ) -> Result<KernelTimes> {
        if self.legacy {
            let split = classify_elements(&st.conn, st.k_real);
            self.classify_computes += 1;
            let times = self.legacy_phase_boundary(st, &split, dt, a, b);
            self.legacy_pending = Some(split);
            return Ok(times);
        }
        Ok(self.fused_boundary(st, dt, a, b))
    }

    fn stage_interior(
        &mut self,
        v: &mut InteriorView<'_>,
        dt: f32,
        a: f32,
        b: f32,
    ) -> Result<KernelTimes> {
        if self.legacy {
            let split = match self.legacy_pending.take() {
                Some(s) => s,
                None => {
                    self.classify_computes += 1;
                    classify_elements(v.conn, v.k_real)
                }
            };
            return Ok(self.legacy_phase_interior(v, &split, dt, a, b));
        }
        Ok(self.fused_interior(v, dt, a, b))
    }

    fn pool_generation(&self) -> Option<u64> {
        Some(self.pool.generation())
    }

    fn classify_computes(&self) -> u64 {
        self.classify_computes
    }
}

// ---------------------------------------------------------------------------
// the fused pool sweep
// ---------------------------------------------------------------------------

/// Raw shared-mutable array view handed to pool workers, so disjoint
/// per-element slices can be carved out concurrently from one shared
/// closure.
///
/// Safety contract, upheld by [`fused_sweep`]:
/// * concurrent `slice_mut` calls use disjoint index ranges — the element
///   lists are duplicate-free and chunked disjointly across workers;
/// * `slice` (shared) reads only happen in dispatch phases where no
///   worker `slice_mut`s the same array — phases are separated by the
///   pool barrier, which provides the happens-before edges.
#[derive(Clone, Copy)]
struct RawMut {
    ptr: *mut f32,
    len: usize,
}

// SAFETY: the pointer is only dereferenced through `slice`/`slice_mut`,
// whose disjointness discipline is documented on the type and argued at
// each use; sending the pointer value itself is unrestricted.
unsafe impl Send for RawMut {}
// SAFETY: concurrent `&RawMut` use is exactly the documented access
// discipline (disjoint ranges per worker, phases barrier-separated);
// every dereference stays `unsafe` and re-argues it.
unsafe impl Sync for RawMut {}

impl RawMut {
    fn new(s: &mut [f32]) -> Self {
        RawMut { ptr: s.as_mut_ptr(), len: s.len() }
    }

    /// # Safety
    /// The range must be in bounds and disjoint from every concurrent
    /// `slice_mut`/`slice` range of this array.
    #[allow(clippy::mut_from_ref)]
    unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [f32] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }

    /// # Safety
    /// No concurrent `slice_mut` may overlap the range for the lifetime
    /// of the returned slice.
    unsafe fn slice(&self, start: usize, len: usize) -> &[f32] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts(self.ptr.add(start), len)
    }
}

/// The read-only block tables shared by every worker of a fused sweep.
struct FusedShared<'a> {
    m: usize,
    conn: &'a [i32],
    halo: &'a [f32],
    halo_idx: &'a [i32],
    mats: &'a [f32],
    halo_mats: &'a [f32],
    h: &'a [f32],
}

/// Worker `w`'s slice of `0..len` split into `nw` contiguous chunks.
fn chunk_range(w: usize, len: usize, nw: usize) -> std::ops::Range<usize> {
    let nw = nw.max(1);
    let chunk = len.div_euclid(nw) + usize::from(len % nw != 0);
    let start = (w * chunk).min(len);
    let end = (start + chunk).min(len);
    start..end
}

/// Blocks at or below this many nodes (`elements x m^3`) run the whole
/// sweep inline on the caller — the rendezvous wake-ups would cost more
/// than the work (order 2: <= 18 elements; order 7: a single element).
const INLINE_NODES: usize = 512;

/// One fused pool rendezvous (see module docs):
///
/// * phase 0 — each worker sweeps its disjoint chunk of `elems`, fusing
///   per element: RHS into `dq`, then the low-storage RK update of
///   `q`/`res` in place. Sound because the RHS reads only the element's
///   own `q` (passed explicitly) plus *traces*, and no trace is written
///   in this phase.
/// * phase 1 (when `refresh_all = Some(k_real)`) — behind the pool
///   barrier, the trace refresh of elements `0..k_real`, chunked the
///   same way (each worker writes only its own elements' traces and reads
///   only their `q`, which no one writes anymore). With `refresh_masks`,
///   element `e` refreshes only the faces set in `masks[e]` (the interior
///   phase skipping the halo faces the boundary phase already wrote).
///
/// Only `min(threads, work-chunks)` workers are woken per rendezvous
/// ([`WorkerPool::run_phased_limit`]); tiny blocks (see [`INLINE_NODES`])
/// skip the rendezvous entirely. Kernel timers accumulate into the
/// backend-owned `worker_times` slots, drained (and zeroed) here after
/// the dispatch — no per-sweep allocation.
#[allow(clippy::too_many_arguments)]
fn fused_sweep(
    basis: &LglBasis,
    pool: &PoolSlice,
    scratch: &[Mutex<ElemScratch>],
    worker_times: &[Mutex<KernelTimes>],
    elems: &[usize],
    refresh_all: Option<usize>,
    refresh_masks: Option<&[u8]>,
    sh: FusedShared<'_>,
    q: RawMut,
    res: RawMut,
    dq: RawMut,
    traces: RawMut,
    dt: f32,
    a: f32,
    b: f32,
) -> KernelTimes {
    let m = sh.m;
    let vol = m * m * m;
    let esz = NFIELDS * vol;
    let tsz = 6 * NFIELDS * m * m;
    if elems.is_empty() && refresh_all.is_none() {
        // e.g. the boundary phase of a halo-less single block
        return KernelTimes::default();
    }
    let work = elems.len().max(refresh_all.unwrap_or(0));
    let nw = if work * vol <= INLINE_NODES { 1 } else { pool.threads().min(work).max(1) };
    debug_assert!(scratch.len() >= nw && worker_times.len() >= nw);
    let lanes = simd::active();
    let phases = 1 + usize::from(refresh_all.is_some());
    pool.run_phased_limit(nw, phases, |w, phase| {
        if phase == 0 {
            let r = chunk_range(w, elems.len(), nw);
            if r.is_empty() {
                return;
            }
            let mut t = KernelTimes::default();
            // scratch/timer locks are uncontended (one worker per slot);
            // tolerate poisoning from an earlier panicked dispatch — the
            // scratch holds no cross-stage invariants
            let mut scr = scratch[w].lock().unwrap_or_else(|e| e.into_inner());
            // SAFETY: no worker writes `traces` in phase 0, so a shared
            // view of the whole array is sound.
            let tr_view: &[f32] = unsafe { traces.slice(0, traces.len) };
            let cx = RhsCtx {
                m,
                traces: tr_view,
                halo: sh.halo,
                conn: sh.conn,
                halo_idx: sh.halo_idx,
                mats: sh.mats,
                halo_mats: sh.halo_mats,
                h: sh.h,
                lanes,
            };
            for &e in &elems[r] {
                // SAFETY: element lists are duplicate-free and chunks are
                // disjoint across workers, so these per-element ranges
                // never overlap between concurrent workers.
                let (q_e, res_e, dq_e) = unsafe {
                    (
                        q.slice_mut(e * esz, esz),
                        res.slice_mut(e * esz, esz),
                        dq.slice_mut(e * esz, esz),
                    )
                };
                rhs_element(&cx, basis, e, q_e, dq_e, &mut scr, &mut t);
                let t0 = Instant::now();
                update_elem(q_e, res_e, dq_e, dt, a, b, lanes);
                t.rk += t0.elapsed().as_secs_f64();
            }
            worker_times[w].lock().unwrap_or_else(|e| e.into_inner()).accumulate(&t);
        } else {
            let k_real = refresh_all.expect("phase 1 only scheduled with refresh_all");
            let r = chunk_range(w, k_real, nw);
            if r.is_empty() {
                return;
            }
            let t0 = Instant::now();
            for e in r {
                // SAFETY: per-element ranges, disjoint across workers; no
                // worker writes `q` in this phase (RK finished behind the
                // pool barrier), so the shared read of `q_e` is sound.
                let (q_e, tr_e) =
                    unsafe { (q.slice(e * esz, esz), traces.slice_mut(e * tsz, tsz)) };
                match refresh_masks {
                    Some(masks) => refresh_elem_faces_masked(m, q_e, tr_e, masks[e]),
                    None => refresh_elem_traces(m, q_e, tr_e),
                }
            }
            let mut wt = worker_times[w].lock().unwrap_or_else(|e| e.into_inner());
            wt.interp_q += t0.elapsed().as_secs_f64();
        }
    });
    let mut total = KernelTimes::default();
    for wt in &worker_times[..nw] {
        let mut t = wt.lock().unwrap_or_else(|e| e.into_inner());
        total.accumulate(&t);
        *t = KernelTimes::default();
    }
    total
}

// ---------------------------------------------------------------------------
// legacy scoped-thread sweeps (benches only; the pre-pool implementation)
// ---------------------------------------------------------------------------

/// RHS sweep over an element subset from up to `threads` scoped workers.
/// Each worker owns one [`ElemScratch`] and a disjoint set of per-element
/// `dq` slices (handed out through a take-once slot table). Returns the
/// per-thread kernel timers summed.
fn par_rhs(
    basis: &LglBasis,
    threads: usize,
    pool: &mut [ElemScratch],
    dq: &mut [f32],
    cx: &RhsCtx<'_>,
    q: &[f32],
    elems: &[usize],
) -> KernelTimes {
    let mut total = KernelTimes::default();
    if elems.is_empty() {
        return total;
    }
    let esz = NFIELDS * cx.m * cx.m * cx.m;
    let nt = threads.min(elems.len()).max(1);
    if nt == 1 {
        let scr = &mut pool[0];
        for &e in elems {
            rhs_element(
                cx,
                basis,
                e,
                &q[e * esz..(e + 1) * esz],
                &mut dq[e * esz..(e + 1) * esz],
                scr,
                &mut total,
            );
        }
        return total;
    }
    let mut slots: Vec<Option<&mut [f32]>> = dq.chunks_mut(esz).map(Some).collect();
    let chunk = elems.len().div_euclid(nt) + usize::from(elems.len() % nt != 0);
    let mut jobs: Vec<(Vec<(usize, &mut [f32])>, &mut ElemScratch)> = Vec::new();
    let mut pool_iter = pool.iter_mut();
    for ids in elems.chunks(chunk) {
        let items: Vec<(usize, &mut [f32])> = ids
            .iter()
            .map(|&e| (e, slots[e].take().expect("element listed twice")))
            .collect();
        jobs.push((items, pool_iter.next().expect("scratch pool smaller than thread count")));
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = jobs
            .into_iter()
            .map(|(items, scr)| {
                let cx = *cx;
                s.spawn(move || {
                    let mut t = KernelTimes::default();
                    for (e, dq_e) in items {
                        rhs_element(&cx, basis, e, &q[e * esz..(e + 1) * esz], dq_e, scr, &mut t);
                    }
                    t
                })
            })
            .collect();
        for h in handles {
            total.accumulate(&h.join().expect("rhs worker panicked"));
        }
    });
    total
}

/// Low-storage RK update of an element subset, threaded the same way.
#[allow(clippy::too_many_arguments)]
fn par_update(
    threads: usize,
    q: &mut [f32],
    res: &mut [f32],
    dq: &[f32],
    elems: &[usize],
    esz: usize,
    dt: f32,
    a: f32,
    b: f32,
) {
    if elems.is_empty() {
        return;
    }
    let lanes = simd::active();
    let nt = threads.min(elems.len()).max(1);
    if nt == 1 {
        for &e in elems {
            update_elem(
                &mut q[e * esz..(e + 1) * esz],
                &mut res[e * esz..(e + 1) * esz],
                &dq[e * esz..(e + 1) * esz],
                dt,
                a,
                b,
                lanes,
            );
        }
        return;
    }
    let mut q_slots: Vec<Option<&mut [f32]>> = q.chunks_mut(esz).map(Some).collect();
    let mut r_slots: Vec<Option<&mut [f32]>> = res.chunks_mut(esz).map(Some).collect();
    let chunk = elems.len().div_euclid(nt) + usize::from(elems.len() % nt != 0);
    std::thread::scope(|s| {
        for ids in elems.chunks(chunk) {
            let items: Vec<(&mut [f32], &mut [f32], &[f32])> = ids
                .iter()
                .map(|&e| {
                    (
                        q_slots[e].take().expect("element listed twice"),
                        r_slots[e].take().expect("element listed twice"),
                        &dq[e * esz..(e + 1) * esz],
                    )
                })
                .collect();
            s.spawn(move || {
                for (q_e, r_e, dq_e) in items {
                    update_elem(q_e, r_e, dq_e, dt, a, b, lanes);
                }
            });
        }
    });
}

/// Low-storage RK update of one element: `res = a*res + dt*dq` then
/// `q += b*res`, via the lane-dispatched kernel (per-index independent,
/// so the vector path is bitwise identical to the scalar loops).
#[inline]
fn update_elem(
    q_e: &mut [f32],
    r_e: &mut [f32],
    dq_e: &[f32],
    dt: f32,
    a: f32,
    b: f32,
    lanes: simd::Lanes,
) {
    simd::rk_update(lanes, q_e, r_e, dq_e, dt, a, b);
}

/// Threaded trace refresh of an element subset (legacy path).
fn par_refresh(threads: usize, m: usize, q: &[f32], traces: &mut [f32], elems: &[usize]) {
    if elems.is_empty() {
        return;
    }
    let esz = NFIELDS * m * m * m;
    let tsz = 6 * NFIELDS * m * m;
    let nt = threads.min(elems.len()).max(1);
    if nt == 1 {
        for &e in elems {
            refresh_elem_traces(m, &q[e * esz..(e + 1) * esz], &mut traces[e * tsz..(e + 1) * tsz]);
        }
        return;
    }
    let mut t_slots: Vec<Option<&mut [f32]>> = traces.chunks_mut(tsz).map(Some).collect();
    let chunk = elems.len().div_euclid(nt) + usize::from(elems.len() % nt != 0);
    std::thread::scope(|s| {
        for ids in elems.chunks(chunk) {
            let items: Vec<(&[f32], &mut [f32])> = ids
                .iter()
                .map(|&e| {
                    (
                        &q[e * esz..(e + 1) * esz],
                        t_slots[e].take().expect("element listed twice"),
                    )
                })
                .collect();
            s.spawn(move || {
                for (q_e, tr_e) in items {
                    refresh_elem_traces(m, q_e, tr_e);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::{build_local_blocks, geometry::unit_cube_geometry};
    use crate::solver::reference::{stage as ref_stage, RefScratch};
    use crate::solver::rk::{LSRK_A, LSRK_B, N_STAGES};

    fn state(order: usize, n: usize) -> BlockState {
        let mesh = unit_cube_geometry(n);
        let owners = vec![0usize; mesh.len()];
        let (blocks, _) = build_local_blocks(&mesh, &owners, 1);
        let k = blocks[0].len();
        BlockState::from_local_block(&blocks[0], order, k, 8)
    }

    #[test]
    fn classify_single_block_is_all_interior() {
        let st = state(2, 2);
        let split = classify_elements(&st.conn, st.k_real);
        assert!(split.boundary.is_empty());
        assert_eq!(split.interior.len(), st.k_real);
        assert!(split.halo_faces.is_empty());
    }

    #[test]
    fn classify_two_owner_split() {
        let mesh = unit_cube_geometry(2);
        let owners: Vec<usize> = (0..8).map(|e| e % 2).collect();
        let (blocks, _) = build_local_blocks(&mesh, &owners, 2);
        for lb in &blocks {
            let st = BlockState::from_local_block(lb, 1, lb.len(), lb.halo_len.max(1));
            let split = classify_elements(&st.conn, st.k_real);
            // the pathological parity split makes every element a halo owner
            assert_eq!(split.boundary.len(), st.k_real);
            assert!(split.interior.is_empty());
            assert_eq!(split.halo_faces.len(), lb.halo_len);
        }
    }

    #[test]
    fn interior_refresh_mask_complements_halo_faces() {
        // interior elements keep all 6 faces; boundary elements drop
        // exactly their halo-facing bits — the two phases' refreshes
        // union to every face, each written once
        let mesh = unit_cube_geometry(2);
        let owners: Vec<usize> = (0..8).map(|e| usize::from(e >= 4)).collect();
        let (blocks, _) = build_local_blocks(&mesh, &owners, 2);
        for lb in &blocks {
            let st = BlockState::from_local_block(lb, 1, lb.len(), lb.halo_len.max(1));
            let split = classify_elements(&st.conn, st.k_real);
            assert_eq!(split.interior_refresh.len(), st.k_real);
            let mut expect = vec![0x3Fu8; st.k_real];
            for &(e, f) in &split.halo_faces {
                expect[e] &= !(1u8 << f);
            }
            assert_eq!(split.interior_refresh, expect);
            for &e in &split.interior {
                assert_eq!(split.interior_refresh[e], 0x3F, "interior element {e}");
            }
            for &e in &split.boundary {
                assert_ne!(split.interior_refresh[e], 0x3F, "boundary element {e} has halo faces");
            }
        }
    }

    #[test]
    fn parallel_stage_matches_scalar_bitwise() {
        for (order, threads) in [(2usize, 1usize), (2, 4), (3, 2), (3, 4)] {
            let basis = LglBasis::new(order);
            let w = std::f64::consts::PI * 3f64.sqrt();
            let ic =
                |x: [f64; 3]| crate::solver::analytic::standing_wave(x, 0.0, 1.0, 1.0, w);
            let mut st_s = state(order, 2);
            st_s.set_initial_condition(&basis, ic);
            let mut st_p = st_s.clone();
            let mut scratch = RefScratch::new(&st_s);
            let mut par = ParallelRefBackend::with_threads(order, threads);
            for step in 0..3 {
                for s in 0..N_STAGES {
                    let (a, b) = (LSRK_A[s] as f32, LSRK_B[s] as f32);
                    ref_stage(&mut st_s, &basis, &mut scratch, 1e-3, a, b);
                    par.stage(&mut st_p, 1e-3, a, b).unwrap();
                }
                assert_eq!(st_s.q, st_p.q, "order {order} threads {threads} step {step}");
                assert_eq!(st_s.res, st_p.res);
                let live = st_s.k_real * 6 * NFIELDS * st_s.m * st_s.m;
                assert_eq!(st_s.traces[..live], st_p.traces[..live]);
            }
        }
    }

    #[test]
    fn legacy_scoped_matches_fused_bitwise() {
        // the retained pre-pool pipeline and the fused pool pipeline must
        // agree exactly, under both the full stage and the split phases
        let order = 2;
        let mesh = unit_cube_geometry(2);
        let owners: Vec<usize> = (0..8).map(|e| usize::from(e >= 4)).collect();
        let (blocks, _) = build_local_blocks(&mesh, &owners, 2);
        let basis = LglBasis::new(order);
        let w = std::f64::consts::PI * 3f64.sqrt();
        let mut fused_st =
            BlockState::from_local_block(&blocks[0], order, blocks[0].len(), blocks[0].halo_len);
        fused_st.set_initial_condition(&basis, |x| {
            crate::solver::analytic::standing_wave(x, 0.0, 1.0, 1.0, w)
        });
        let mut legacy_st = fused_st.clone();
        let mut fused = ParallelRefBackend::with_threads(order, 2);
        let mut legacy = ParallelRefBackend::legacy_scoped(order, 2);
        for s in 0..N_STAGES {
            let (a, b) = (LSRK_A[s] as f32, LSRK_B[s] as f32);
            fused.stage(&mut fused_st, 1e-3, a, b).unwrap();
            legacy.stage(&mut legacy_st, 1e-3, a, b).unwrap();
        }
        assert_eq!(fused_st.q, legacy_st.q);
        assert_eq!(fused_st.res, legacy_st.res);
        assert_eq!(fused_st.traces, legacy_st.traces);
        // split phases too
        fused.stage_boundary(&mut fused_st, 1e-3, -0.3, 0.7).unwrap();
        legacy.stage_boundary(&mut legacy_st, 1e-3, -0.3, 0.7).unwrap();
        {
            let (mut fv, _) = fused_st.split_for_overlap();
            fused.stage_interior(&mut fv, 1e-3, -0.3, 0.7).unwrap();
            let (mut lv, _) = legacy_st.split_for_overlap();
            legacy.stage_interior(&mut lv, 1e-3, -0.3, 0.7).unwrap();
        }
        assert_eq!(fused_st.q, legacy_st.q);
        assert_eq!(fused_st.traces, legacy_st.traces);
    }

    #[test]
    fn split_stage_equals_fused_stage() {
        // stage_boundary + scatter-free stage_interior == stage()
        let order = 2;
        let mesh = unit_cube_geometry(2);
        let owners: Vec<usize> = (0..8).map(|e| usize::from(e >= 4)).collect();
        let (blocks, _) = build_local_blocks(&mesh, &owners, 2);
        let basis = LglBasis::new(order);
        let w = std::f64::consts::PI * 3f64.sqrt();
        let mut a_state =
            BlockState::from_local_block(&blocks[0], order, blocks[0].len(), blocks[0].halo_len);
        a_state.set_initial_condition(&basis, |x| {
            crate::solver::analytic::standing_wave(x, 0.0, 1.0, 1.0, w)
        });
        let mut b_state = a_state.clone();
        let mut fused = ParallelRefBackend::with_threads(order, 2);
        let mut split = ParallelRefBackend::with_threads(order, 2);
        fused.stage(&mut a_state, 1e-3, -0.3, 0.7).unwrap();
        split.stage_boundary(&mut b_state, 1e-3, -0.3, 0.7).unwrap();
        let (mut view, _halo) = b_state.split_for_overlap();
        split.stage_interior(&mut view, 1e-3, -0.3, 0.7).unwrap();
        assert_eq!(a_state.q, b_state.q);
        assert_eq!(a_state.traces, b_state.traces);
    }

    #[test]
    fn classification_is_memoized_across_stages() {
        let order = 2;
        let mesh = unit_cube_geometry(2);
        let owners: Vec<usize> = (0..8).map(|e| usize::from(e >= 4)).collect();
        let (blocks, _) = build_local_blocks(&mesh, &owners, 2);
        let basis = LglBasis::new(order);
        let w = std::f64::consts::PI * 3f64.sqrt();
        let mut st =
            BlockState::from_local_block(&blocks[0], order, blocks[0].len(), blocks[0].halo_len);
        st.set_initial_condition(&basis, |x| {
            crate::solver::analytic::standing_wave(x, 0.0, 1.0, 1.0, w)
        });
        let mut par = ParallelRefBackend::with_threads(order, 2);
        assert_eq!(par.classify_computes(), 0);
        for _ in 0..5 {
            par.stage_boundary(&mut st, 1e-3, -0.3, 0.7).unwrap();
            let (mut view, _halo) = st.split_for_overlap();
            par.stage_interior(&mut view, 1e-3, -0.3, 0.7).unwrap();
        }
        assert_eq!(
            par.classify_computes(),
            1,
            "split phases over one block must classify exactly once"
        );
        // the fused full stage never needs the classification
        let mut par2 = ParallelRefBackend::with_threads(order, 2);
        par2.stage(&mut st, 1e-3, -0.3, 0.7).unwrap();
        assert_eq!(par2.classify_computes(), 0);
        // a different block (fresh uid) invalidates the cache
        let mut st2 =
            BlockState::from_local_block(&blocks[1], order, blocks[1].len(), blocks[1].halo_len);
        st2.set_initial_condition(&basis, |x| {
            crate::solver::analytic::standing_wave(x, 0.0, 1.0, 1.0, w)
        });
        par.stage_boundary(&mut st2, 1e-3, -0.3, 0.7).unwrap();
        assert_eq!(par.classify_computes(), 2, "new block identity reclassifies");
    }

    #[test]
    fn pool_generation_is_stable_and_shared() {
        let a = ParallelRefBackend::with_threads(2, 2);
        let b = ParallelRefBackend::with_threads(2, 2);
        assert_ne!(a.pool_generation(), 0);
        assert_ne!(a.pool_generation(), b.pool_generation());
        // backends sharing one pool report the same generation
        let pool = Arc::new(WorkerPool::new(2, None));
        let c = ParallelRefBackend::with_pool(2, pool.clone());
        let d = ParallelRefBackend::with_pool(2, pool);
        assert_eq!(c.pool_generation(), d.pool_generation());
    }

    #[test]
    fn zero_state_stays_zero_parallel() {
        let mut st = state(2, 2);
        let mut par = ParallelRefBackend::with_threads(2, 3);
        par.stage(&mut st, 1e-3, 0.0, 1.0).unwrap();
        assert!(st.q.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn kernel_times_reported() {
        let basis = LglBasis::new(2);
        let mut st = state(2, 2);
        st.set_initial_condition(&basis, |x| [x[0], 0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
        let mut par = ParallelRefBackend::with_threads(2, 2);
        let t = par.stage(&mut st, 1e-3, 0.0, 1.0).unwrap();
        assert!(t.volume_loop > 0.0);
        assert!(t.total() > 0.0);
    }
}
