//! Element-block state in the exact memory layout of the AOT artifact.
//!
//! Arrays are row-major, matching the jax defaults the artifact was
//! lowered with:
//!   q, res    (K, 9, M, M, M) f32
//!   traces    (K, 6, 9, M, M) f32   (face order -x,+x,-y,+y,-z,+z)
//!   halo      (H, 9, M, M)    f32
//!   conn      (K, 6)          i32   local idx | -1 halo | -2 boundary
//!   halo_idx  (K, 6)          i32
//!   mats      (K, 3)          f32   (rho, lambda, mu)
//!   halo_mats (H, 3)          f32
//!   h         (K, 3)          f32
//!
//! Blocks are padded from their real element count up to the artifact's
//! bucket size; padding elements are fully mirror-bounded and inert
//! (python/tests/test_model.py::test_padding_elements_do_not_affect_real_ones
//! proves non-interference).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::mesh::LocalBlock;
use crate::solver::basis::LglBasis;

/// Number of solution fields (Voigt strain 6 + velocity 3).
pub const NFIELDS: usize = 9;

/// Source of process-unique block identities (see [`BlockState::uid`]).
static NEXT_BLOCK_UID: AtomicU64 = AtomicU64::new(1);

#[derive(Debug, Clone)]
pub struct BlockState {
    /// Process-unique identity of this block's *connectivity* (assigned at
    /// construction; clones share it, which is correct — a clone has
    /// identical connectivity). The parallel backend keys its memoized
    /// boundary/interior classification on this, so a freed-and-reallocated
    /// block can never alias a stale cache entry the way a raw pointer key
    /// could. Use [`BlockState::fresh_uid`] when building a state by hand.
    pub uid: u64,
    pub order: usize,
    pub m: usize,
    /// Real / padded element counts.
    pub k_real: usize,
    pub k_pad: usize,
    /// Real / padded halo slot counts.
    pub halo_real: usize,
    pub halo_pad: usize,
    pub q: Vec<f32>,
    pub res: Vec<f32>,
    pub traces: Vec<f32>,
    pub halo: Vec<f32>,
    pub conn: Vec<i32>,
    pub halo_idx: Vec<i32>,
    pub mats: Vec<f32>,
    pub halo_mats: Vec<f32>,
    pub h: Vec<f32>,
    /// Element centers (real elements only), for ICs and error norms.
    pub centers: Vec<[f64; 3]>,
}

impl BlockState {
    /// Build a padded state from a [`LocalBlock`]; `k_bucket`/`h_bucket`
    /// must be at least the real counts (artifact shape bucket).
    pub fn from_local_block(
        blk: &LocalBlock,
        order: usize,
        k_bucket: usize,
        h_bucket: usize,
    ) -> Self {
        let k_real = blk.len();
        let halo_real = blk.halo_len;
        assert!(k_bucket >= k_real, "bucket {k_bucket} < block {k_real}");
        assert!(h_bucket >= halo_real, "halo bucket {h_bucket} < {halo_real}");
        let m = order + 1;
        let vol = m * m * m;
        let face = m * m;
        let mut conn = vec![-2i32; k_bucket * 6];
        let mut halo_idx = vec![0i32; k_bucket * 6];
        let mut mats = vec![0f32; k_bucket * 3];
        let mut hvec = vec![1f32; k_bucket * 3];
        for e in 0..k_real {
            conn[e * 6..e * 6 + 6].copy_from_slice(&blk.conn[e]);
            halo_idx[e * 6..e * 6 + 6].copy_from_slice(&blk.halo_idx[e]);
            mats[e * 3..e * 3 + 3].copy_from_slice(&blk.mats[e]);
            hvec[e * 3..e * 3 + 3].copy_from_slice(&blk.h[e]);
        }
        // inert padding material (rho=1, lambda=1, mu=0)
        for e in k_real..k_bucket {
            mats[e * 3] = 1.0;
            mats[e * 3 + 1] = 1.0;
        }
        let mut halo_mats = vec![1f32; h_bucket * 3];
        for s in 0..halo_real {
            halo_mats[s * 3..s * 3 + 3].copy_from_slice(&blk.halo_mats[s]);
        }
        BlockState {
            uid: Self::fresh_uid(),
            order,
            m,
            k_real,
            k_pad: k_bucket,
            halo_real,
            halo_pad: h_bucket,
            q: vec![0.0; k_bucket * NFIELDS * vol],
            res: vec![0.0; k_bucket * NFIELDS * vol],
            traces: vec![0.0; k_bucket * 6 * NFIELDS * face],
            halo: vec![0.0; h_bucket * NFIELDS * face],
            conn,
            halo_idx,
            mats,
            halo_mats,
            h: hvec,
            centers: blk.centers.clone(),
        }
    }

    /// A fresh process-unique block identity, for callers that build a
    /// [`BlockState`] by hand instead of via [`BlockState::from_local_block`].
    pub fn fresh_uid() -> u64 {
        NEXT_BLOCK_UID.fetch_add(1, Ordering::Relaxed)
    }

    /// Physical coordinates of every LGL node of real element `e`.
    pub fn node_coords(&self, e: usize, basis: &LglBasis) -> Vec<[f64; 3]> {
        let m = self.m;
        let c = self.centers[e];
        let hx = [
            self.h[e * 3] as f64,
            self.h[e * 3 + 1] as f64,
            self.h[e * 3 + 2] as f64,
        ];
        let mut out = Vec::with_capacity(m * m * m);
        for i in 0..m {
            for j in 0..m {
                for l in 0..m {
                    out.push([
                        c[0] + 0.5 * hx[0] * basis.nodes[i],
                        c[1] + 0.5 * hx[1] * basis.nodes[j],
                        c[2] + 0.5 * hx[2] * basis.nodes[l],
                    ]);
                }
            }
        }
        out
    }

    /// Initialize q from a function of physical position returning the 9
    /// fields; also zeroes res and refreshes traces.
    pub fn set_initial_condition(
        &mut self,
        basis: &LglBasis,
        f: impl Fn([f64; 3]) -> [f64; NFIELDS],
    ) {
        let m = self.m;
        let vol = m * m * m;
        for e in 0..self.k_real {
            let coords = self.node_coords(e, basis);
            for (n, &x) in coords.iter().enumerate() {
                let vals = f(x);
                for fld in 0..NFIELDS {
                    self.q[(e * NFIELDS + fld) * vol + n] = vals[fld] as f32;
                }
            }
        }
        self.res.iter_mut().for_each(|v| *v = 0.0);
        self.refresh_traces();
    }

    /// Recompute `traces` from `q` (slices at the face node layers) —
    /// same as the artifact's traces output, used before the first stage.
    pub fn refresh_traces(&mut self) {
        let m = self.m;
        let vol = m * m * m;
        let face = m * m;
        for e in 0..self.k_pad {
            for fld in 0..NFIELDS {
                let qb = (e * NFIELDS + fld) * vol;
                for a in 0..m {
                    for b in 0..m {
                        let fb = ((e * 6) * NFIELDS + fld) * face;
                        // face 0 (-x): q[0, a, b]; face 1 (+x): q[m-1, a, b]
                        self.traces[fb + a * m + b] = self.q[qb + a * m + b];
                        self.traces[fb + (NFIELDS * face) + a * m + b] =
                            self.q[qb + (m - 1) * face + a * m + b];
                        // face 2 (-y): q[a, 0, b]; face 3 (+y): q[a, m-1, b]
                        self.traces[fb + 2 * (NFIELDS * face) + a * m + b] =
                            self.q[qb + a * face + b];
                        self.traces[fb + 3 * (NFIELDS * face) + a * m + b] =
                            self.q[qb + a * face + (m - 1) * m + b];
                        // face 4 (-z): q[a, b, 0]; face 5 (+z): q[a, b, m-1]
                        self.traces[fb + 4 * (NFIELDS * face) + a * m + b] =
                            self.q[qb + a * face + b * m];
                        self.traces[fb + 5 * (NFIELDS * face) + a * m + b] =
                            self.q[qb + a * face + b * m + (m - 1)];
                    }
                }
            }
        }
    }

    /// Split the mutable state into the part the interior sweep touches
    /// (everything but the halo) and the halo storage, so the overlapped
    /// schedule can scatter incoming halo traces from one thread while the
    /// interior elements — which never read the halo — are advanced on
    /// others.
    pub fn split_for_overlap(&mut self) -> (InteriorView<'_>, &mut [f32]) {
        let BlockState {
            uid,
            order,
            m,
            k_real,
            k_pad,
            q,
            res,
            traces,
            halo,
            conn,
            halo_idx,
            mats,
            halo_mats,
            h,
            ..
        } = self;
        (
            InteriorView {
                uid: *uid,
                order: *order,
                m: *m,
                k_real: *k_real,
                k_pad: *k_pad,
                q: q.as_mut_slice(),
                res: res.as_mut_slice(),
                traces: traces.as_mut_slice(),
                conn: conn.as_slice(),
                halo_idx: halo_idx.as_slice(),
                mats: mats.as_slice(),
                halo_mats: halo_mats.as_slice(),
                h: h.as_slice(),
            },
            halo.as_mut_slice(),
        )
    }

    /// Immutable view of one face trace (9 x M x M values) of an element.
    pub fn trace_slice(&self, e: usize, f: usize) -> &[f32] {
        let m = self.m;
        let sz = NFIELDS * m * m;
        let base = (e * 6 + f) * sz;
        &self.traces[base..base + sz]
    }

    /// Write one halo slot from a trace slice.
    pub fn set_halo_slot(&mut self, slot: usize, trace: &[f32]) {
        let m = self.m;
        let sz = NFIELDS * m * m;
        debug_assert_eq!(trace.len(), sz);
        self.halo[slot * sz..(slot + 1) * sz].copy_from_slice(trace);
    }

    /// Discrete block energy (real elements only):
    /// 1/2 sum J w_lmn (rho |v|^2 + lam tr(E)^2 + 2 mu E:E).
    pub fn energy(&self, basis: &LglBasis) -> f64 {
        let m = self.m;
        let vol = m * m * m;
        let mut total = 0.0f64;
        for e in 0..self.k_real {
            let rho = self.mats[e * 3] as f64;
            let lam = self.mats[e * 3 + 1] as f64;
            let mu = self.mats[e * 3 + 2] as f64;
            let jac = (self.h[e * 3] as f64) * (self.h[e * 3 + 1] as f64)
                * (self.h[e * 3 + 2] as f64)
                / 8.0;
            let qb = e * NFIELDS * vol;
            let mut n = 0;
            for i in 0..m {
                for j in 0..m {
                    for l in 0..m {
                        let w = basis.weights[i] * basis.weights[j] * basis.weights[l];
                        let fld = |f: usize| self.q[qb + f * vol + n] as f64;
                        let tr = fld(0) + fld(1) + fld(2);
                        let ee = fld(0) * fld(0)
                            + fld(1) * fld(1)
                            + fld(2) * fld(2)
                            + 2.0 * (fld(3) * fld(3) + fld(4) * fld(4) + fld(5) * fld(5));
                        let v2 = fld(6) * fld(6) + fld(7) * fld(7) + fld(8) * fld(8);
                        total += 0.5 * jac * w * (rho * v2 + lam * tr * tr + 2.0 * mu * ee);
                        n += 1;
                    }
                }
            }
        }
        total
    }

    /// Relative L2 error of q against an exact solution (real elements).
    pub fn rel_l2_error(
        &self,
        basis: &LglBasis,
        exact: impl Fn([f64; 3]) -> [f64; NFIELDS],
    ) -> f64 {
        let m = self.m;
        let vol = m * m * m;
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for e in 0..self.k_real {
            let coords = self.node_coords(e, basis);
            for (n, &x) in coords.iter().enumerate() {
                let ex = exact(x);
                for fld in 0..NFIELDS {
                    let got = self.q[(e * NFIELDS + fld) * vol + n] as f64;
                    num += (got - ex[fld]).powi(2);
                    den += ex[fld].powi(2);
                }
            }
        }
        (num / den.max(1e-300)).sqrt()
    }
}

/// Mutable view of a [`BlockState`] minus its halo storage (see
/// [`BlockState::split_for_overlap`]). This is what
/// [`crate::solver::StageBackend::stage_interior`] receives: interior
/// elements have no halo faces, so the halo can be rewritten concurrently.
pub struct InteriorView<'a> {
    /// The underlying block's identity (see [`BlockState::uid`]).
    pub uid: u64,
    pub order: usize,
    pub m: usize,
    pub k_real: usize,
    pub k_pad: usize,
    pub q: &'a mut [f32],
    pub res: &'a mut [f32],
    pub traces: &'a mut [f32],
    pub conn: &'a [i32],
    pub halo_idx: &'a [i32],
    pub mats: &'a [f32],
    pub halo_mats: &'a [f32],
    pub h: &'a [f32],
}

/// Refresh one face trace of one element from its volume values. Free
/// function over the element-local slices (`q_e`: the `(9, M, M, M)`
/// block, `tr_e`: the `(6, 9, M, M)` block) so sweeps can run on split
/// borrows from worker threads.
pub(crate) fn refresh_elem_face(m: usize, q_e: &[f32], tr_e: &mut [f32], f: usize) {
    let vol = m * m * m;
    let face = m * m;
    let axis = f / 2;
    let layer = if f % 2 == 0 { 0 } else { m - 1 };
    for fld in 0..NFIELDS {
        let qb = fld * vol;
        let tb = (f * NFIELDS + fld) * face;
        for a in 0..m {
            for b in 0..m {
                let n = match axis {
                    0 => layer * face + a * m + b,
                    1 => a * face + layer * m + b,
                    _ => a * face + b * m + layer,
                };
                tr_e[tb + a * m + b] = q_e[qb + n];
            }
        }
    }
}

/// Refresh all six face traces of one element (see [`refresh_elem_face`]).
pub(crate) fn refresh_elem_traces(m: usize, q_e: &[f32], tr_e: &mut [f32]) {
    for f in 0..6 {
        refresh_elem_face(m, q_e, tr_e, f);
    }
}

/// Refresh only the faces whose bit is set in `mask` (bit `f` = face `f`).
/// The face-dirty path of the fused interior sweep: faces already
/// refreshed by the boundary phase (the halo-facing ones) are skipped
/// instead of being recomputed idempotently.
pub(crate) fn refresh_elem_faces_masked(m: usize, q_e: &[f32], tr_e: &mut [f32], mask: u8) {
    for f in 0..6 {
        if mask & (1 << f) != 0 {
            refresh_elem_face(m, q_e, tr_e, f);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::{build_local_blocks, geometry::unit_cube_geometry};

    fn block(order: usize) -> BlockState {
        let mesh = unit_cube_geometry(2);
        let owners = vec![0usize; mesh.len()];
        let (blocks, _) = build_local_blocks(&mesh, &owners, 1);
        BlockState::from_local_block(&blocks[0], order, 8, 8)
    }

    #[test]
    fn shapes_and_padding() {
        let st = block(2);
        assert_eq!(st.k_real, 8);
        assert_eq!(st.k_pad, 8);
        assert_eq!(st.q.len(), 8 * 9 * 27);
        assert_eq!(st.traces.len(), 8 * 6 * 9 * 9);
    }

    #[test]
    fn padding_is_mirror_bounded() {
        let mesh = unit_cube_geometry(2);
        let owners = vec![0usize; mesh.len()];
        let (blocks, _) = build_local_blocks(&mesh, &owners, 1);
        let st = BlockState::from_local_block(&blocks[0], 2, 16, 8);
        for e in 8..16 {
            for f in 0..6 {
                assert_eq!(st.conn[e * 6 + f], -2);
            }
            assert_eq!(st.mats[e * 3], 1.0);
        }
    }

    #[test]
    fn traces_match_q_slices() {
        let mut st = block(1); // m=2 keeps indexing easy to verify
        for (i, v) in st.q.iter_mut().enumerate() {
            *v = i as f32;
        }
        st.refresh_traces();
        let m = st.m;
        let vol = m * m * m;
        let face = m * m;
        // face 1 (+x) of element 0, field 0: q[0,0,{m-1},a,b]
        for a in 0..m {
            for b in 0..m {
                let want = st.q[(m - 1) * face + a * m + b];
                let got = st.trace_slice(0, 1)[a * m + b];
                assert_eq!(got, want);
            }
        }
        // face 4 (-z), field 2: q[2*vol + a*face + b*m + 0]
        for a in 0..m {
            for b in 0..m {
                let want = st.q[2 * vol + a * face + b * m];
                let got = st.trace_slice(0, 4)[2 * face + a * m + b];
                assert_eq!(got, want);
            }
        }
    }

    #[test]
    fn elemwise_refresh_matches_bulk() {
        // refresh_elem_traces must reproduce refresh_traces exactly
        for order in [1usize, 2, 3] {
            let mut st = block(order);
            for (i, v) in st.q.iter_mut().enumerate() {
                *v = ((i * 31) % 101) as f32 * 0.13 - 5.0;
            }
            st.refresh_traces();
            let want = st.traces.clone();
            let m = st.m;
            let vol = m * m * m;
            let tsz = 6 * NFIELDS * m * m;
            let mut got = vec![-1.0f32; st.traces.len()];
            for e in 0..st.k_pad {
                let q_e = &st.q[e * NFIELDS * vol..(e + 1) * NFIELDS * vol];
                refresh_elem_traces(m, q_e, &mut got[e * tsz..(e + 1) * tsz]);
            }
            assert_eq!(got, want, "order {order}");
        }
    }

    #[test]
    fn split_for_overlap_partitions_state() {
        let mut st = block(2);
        let halo_len = st.halo.len();
        let q_len = st.q.len();
        let (mut view, halo) = st.split_for_overlap();
        assert_eq!(view.q.len(), q_len);
        assert_eq!(halo.len(), halo_len);
        assert_eq!(view.k_real, 8);
        // mutating through the view and the halo concurrently type-checks
        view.q[0] = 7.0;
        if !halo.is_empty() {
            halo[0] = 3.0;
        }
        drop(view);
        assert_eq!(st.q[0], 7.0);
    }

    #[test]
    fn energy_quadratic_scaling() {
        let basis = LglBasis::new(2);
        let mut st = block(2);
        st.set_initial_condition(&basis, |x| {
            let s = (x[0] * 3.0).sin();
            [s, 0.0, 0.0, 0.0, 0.0, 0.0, s * 0.5, 0.0, 0.0]
        });
        let e1 = st.energy(&basis);
        assert!(e1 > 0.0);
        for v in st.q.iter_mut() {
            *v *= 2.0;
        }
        let e2 = st.energy(&basis);
        assert!((e2 / e1 - 4.0).abs() < 1e-5);
    }

    #[test]
    fn ic_then_error_is_zero() {
        let basis = LglBasis::new(3);
        let mut st = block(3);
        let f = |x: [f64; 3]| {
            [
                x[0], x[1], x[2], 0.1, 0.2, 0.3,
                x[0] * x[1], 0.0, 1.0,
            ]
        };
        st.set_initial_condition(&basis, f);
        assert!(st.rel_l2_error(&basis, f) < 1e-6);
    }

    #[test]
    fn halo_slot_roundtrip() {
        let mut st = block(2);
        let sz = 9 * st.m * st.m;
        let data: Vec<f32> = (0..sz).map(|i| i as f32).collect();
        st.set_halo_slot(3, &data);
        assert_eq!(&st.halo[3 * sz..4 * sz], &data[..]);
    }
}
