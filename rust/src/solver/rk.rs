//! Low-storage 5-stage 4th-order Runge-Kutta (Carpenter & Kennedy 1994),
//! the integrator used by dgae. Must match python/compile/model.py (the
//! tableau also ships in artifacts/manifest.json; the runtime asserts
//! agreement at load time).

pub const N_STAGES: usize = 5;

pub const LSRK_A: [f64; N_STAGES] = [
    0.0,
    -567301805773.0 / 1357537059087.0,
    -2404267990393.0 / 2016746695238.0,
    -3550918686646.0 / 2091501179385.0,
    -1275806237668.0 / 842570457699.0,
];

pub const LSRK_B: [f64; N_STAGES] = [
    1432997174477.0 / 9575080441755.0,
    5161836677717.0 / 13612068292357.0,
    1720146321549.0 / 2090206949498.0,
    3134564353537.0 / 4481467310338.0,
    2277821191437.0 / 14882151754819.0,
];

/// CFL-limited timestep for order `n`, mesh size `h_min`, max wave speed.
pub fn stable_dt(cfl: f64, h_min: f64, c_max: f64, order: usize) -> f64 {
    cfl * h_min / (c_max * (order * order + 1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_order_consistency() {
        // integrating dq/dt = 1 over dt = 1 must give exactly 1
        let (mut q, mut r) = (0.0f64, 0.0f64);
        for s in 0..N_STAGES {
            r = LSRK_A[s] * r + 1.0;
            q += LSRK_B[s] * r;
        }
        assert!((q - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fourth_order_on_linear_ode() {
        // dq/dt = l q: one step error ~ (l dt)^5 / 5!-ish
        let l = 1.0f64;
        for &dt in &[0.1f64, 0.05] {
            let (mut q, mut r) = (1.0f64, 0.0f64);
            for s in 0..N_STAGES {
                r = LSRK_A[s] * r + dt * l * q;
                q += LSRK_B[s] * r;
            }
            let err = (q - (l * dt).exp()).abs();
            assert!(err < (l * dt).powi(5), "dt {dt} err {err}");
        }
    }

    #[test]
    fn stable_dt_decreases_with_order() {
        assert!(stable_dt(0.5, 0.1, 1.0, 7) < stable_dt(0.5, 0.1, 1.0, 2));
    }
}
