//! Analytic solutions for validation (mirrors python/compile/blocks.py).

/// Acoustic standing wave on the unit cube with traction-free walls:
/// p(x, t) = -amp cos(w t) S(x), S = sin(pi x) sin(pi y) sin(pi z),
/// w = pi sqrt(3) c. Returns the 9 fields at (x, t) for material
/// (rho, lam) with c^2 = lam / rho; pass `w` = pi sqrt(3) c.
pub fn standing_wave(x: [f64; 3], t: f64, rho: f64, amp: f64, w: f64) -> [f64; 9] {
    let pi = std::f64::consts::PI;
    let (sx, cx) = ((pi * x[0]).sin(), (pi * x[0]).cos());
    let (sy, cy) = ((pi * x[1]).sin(), (pi * x[1]).cos());
    let (sz, cz) = ((pi * x[2]).sin(), (pi * x[2]).cos());
    let b = amp / (rho * w * w);
    let (ct, st) = ((w * t).cos(), (w * t).sin());
    let pi2 = pi * pi;
    // E = b cos(wt) Hess(S)
    let e_diag = -pi2 * sx * sy * sz;
    let e23 = pi2 * sx * cy * cz;
    let e13 = pi2 * cx * sy * cz;
    let e12 = pi2 * cx * cy * sz;
    // v = -(amp / (rho w)) sin(wt) grad S
    let gv = amp / (rho * w);
    [
        b * ct * e_diag,
        b * ct * e_diag,
        b * ct * e_diag,
        b * ct * e23,
        b * ct * e13,
        b * ct * e12,
        -gv * st * pi * cx * sy * sz,
        -gv * st * pi * sx * cy * sz,
        -gv * st * pi * sx * sy * cz,
    ]
}

/// A smooth localized pressure pulse (gaussian), acoustic initial state at
/// rest — the generic "interesting" IC for demos on arbitrary geometry.
pub fn gaussian_pulse(x: [f64; 3], center: [f64; 3], width: f64, amp: f64, lam: f64) -> [f64; 9] {
    let r2 = (x[0] - center[0]).powi(2) + (x[1] - center[1]).powi(2) + (x[2] - center[2]).powi(2);
    let p = amp * (-r2 / (2.0 * width * width)).exp();
    // isotropic strain with tr(E) = p / lam (pressure p = lam tr E)
    let e = p / (3.0 * lam);
    [e, e, e, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standing_wave_zero_velocity_at_t0() {
        let w = std::f64::consts::PI * 3f64.sqrt();
        let q = standing_wave([0.3, 0.4, 0.6], 0.0, 1.0, 1.0, w);
        assert_eq!(q[6], 0.0);
        assert_eq!(q[7], 0.0);
        assert_eq!(q[8], 0.0);
    }

    #[test]
    fn standing_wave_periodicity() {
        let w = std::f64::consts::PI * 3f64.sqrt();
        let t_period = 2.0 * std::f64::consts::PI / w;
        let x = [0.23, 0.71, 0.52];
        let q0 = standing_wave(x, 0.0, 1.0, 1.0, w);
        let q1 = standing_wave(x, t_period, 1.0, 1.0, w);
        for (a, b) in q0.iter().zip(&q1) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn pulse_is_centered() {
        let q_c = gaussian_pulse([0.5; 3], [0.5; 3], 0.1, 2.0, 1.0);
        let q_o = gaussian_pulse([0.9; 3], [0.5; 3], 0.1, 2.0, 1.0);
        assert!(q_c[0] > q_o[0]);
        assert!((q_c[0] - 2.0 / 3.0).abs() < 1e-12);
    }
}
