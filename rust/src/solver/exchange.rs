//! Halo exchange between blocks: the parallel_flux data motion.
//!
//! In production this copy crosses MPI (inter-node) or PCI (CPU<->MIC);
//! here it is an in-process copy whose *bytes* are identical — the
//! simulator charges modeled time for them (DESIGN.md substitution table).

use crate::mesh::ExchangePlan;
use crate::solver::state::BlockState;

/// Apply every copy of the plan: for each destination block, fill its halo
/// slots from the source blocks' current traces. Also refreshes the halo
/// materials once (they are static, set at block build).
pub fn apply_exchange(blocks: &mut [BlockState], plan: &ExchangePlan) {
    // staging buffer reused across copies
    let mut staging: Vec<f32> = Vec::new();
    for dst in 0..blocks.len() {
        if plan.copies.len() <= dst {
            continue;
        }
        // copies are grouped by source to amortize borrows
        for &(src_owner, src_elem, src_face, slot) in &plan.copies[dst] {
            let sz = {
                let s = blocks[src_owner].trace_slice(src_elem, src_face);
                staging.resize(s.len(), 0.0);
                staging.copy_from_slice(s);
                s.len()
            };
            debug_assert_eq!(sz, staging.len());
            blocks[dst].set_halo_slot(slot, &staging);
        }
    }
}

/// Staging area for the overlapped schedule: outbound halo traces are
/// gathered (copied out) right after the boundary phase, then scattered
/// into destination halos *while* the interior sweep runs — the in-process
/// stand-in for posting sends as soon as boundary data is ready (paper
/// §5.5). Buffers are reused across stages.
#[derive(Debug, Default)]
pub struct ExchangeStaging {
    /// Per destination owner: the halo slots to fill and the packed trace
    /// data, one `9*M*M` span per slot, in the same order.
    pub per_dst: Vec<(Vec<usize>, Vec<f32>)>,
}

/// Copy every outbound trace of the plan into `staging`. After this call
/// the source blocks' traces may be rewritten freely.
pub fn gather_exchange(blocks: &[BlockState], plan: &ExchangePlan, staging: &mut ExchangeStaging) {
    staging.per_dst.resize_with(plan.copies.len(), Default::default);
    for (dst, copies) in plan.copies.iter().enumerate() {
        let (slots, data) = &mut staging.per_dst[dst];
        slots.clear();
        data.clear();
        if dst >= blocks.len() {
            continue;
        }
        for &(src_owner, src_elem, src_face, slot) in copies {
            slots.push(slot);
            data.extend_from_slice(blocks[src_owner].trace_slice(src_elem, src_face));
        }
    }
}

/// Scatter previously gathered traces into per-destination halo storage.
/// `halos[dst]` is destination block `dst`'s halo array, `sz` the face
/// trace size (`9*M*M`). Safe to run concurrently with interior compute:
/// nothing in the interior sweep reads or writes the halo.
pub fn scatter_exchange(halos: &mut [&mut [f32]], sz: usize, staging: &ExchangeStaging) {
    for (dst, (slots, data)) in staging.per_dst.iter().enumerate() {
        if dst >= halos.len() {
            continue;
        }
        let halo = &mut *halos[dst];
        for (i, &slot) in slots.iter().enumerate() {
            halo[slot * sz..(slot + 1) * sz].copy_from_slice(&data[i * sz..(i + 1) * sz]);
        }
    }
}

/// Total bytes moved by one application of the plan (for traffic accounting).
pub fn exchange_bytes(blocks: &[BlockState], plan: &ExchangePlan) -> usize {
    let mut total = 0;
    for (dst, copies) in plan.copies.iter().enumerate() {
        if dst < blocks.len() {
            let m = blocks[dst].m;
            total += copies.len() * 9 * m * m * 4;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::{build_local_blocks, geometry::unit_cube_geometry};

    #[test]
    fn exchange_moves_neighbor_traces() {
        let mesh = unit_cube_geometry(2);
        let owners: Vec<usize> = (0..8).map(|e| e % 2).collect();
        let (lblocks, plan) = build_local_blocks(&mesh, &owners, 2);
        let mut blocks: Vec<BlockState> = lblocks
            .iter()
            .map(|b| BlockState::from_local_block(b, 1, b.len(), b.halo_len.max(1)))
            .collect();
        // distinctive q per block
        for (i, b) in blocks.iter_mut().enumerate() {
            for v in b.q.iter_mut() {
                *v = (i + 1) as f32;
            }
            b.refresh_traces();
        }
        apply_exchange(&mut blocks, &plan);
        // every halo value of block 0 came from block 1 (all values = 2)
        let live = blocks[0].halo_real * 9 * blocks[0].m * blocks[0].m;
        assert!(blocks[0].halo[..live].iter().all(|&v| v == 2.0));
        let live1 = blocks[1].halo_real * 9 * blocks[1].m * blocks[1].m;
        assert!(blocks[1].halo[..live1].iter().all(|&v| v == 1.0));
    }

    #[test]
    fn gather_scatter_equals_apply() {
        let mesh = unit_cube_geometry(2);
        let owners: Vec<usize> = (0..8).map(|e| e % 2).collect();
        let (lblocks, plan) = build_local_blocks(&mesh, &owners, 2);
        let mk = || -> Vec<BlockState> {
            let mut blocks: Vec<BlockState> = lblocks
                .iter()
                .map(|b| BlockState::from_local_block(b, 2, b.len(), b.halo_len.max(1)))
                .collect();
            for (i, b) in blocks.iter_mut().enumerate() {
                for (j, v) in b.q.iter_mut().enumerate() {
                    *v = (i * 1000 + j % 97) as f32 * 0.01;
                }
                b.refresh_traces();
            }
            blocks
        };
        let mut direct = mk();
        apply_exchange(&mut direct, &plan);

        let mut staged = mk();
        let mut staging = ExchangeStaging::default();
        gather_exchange(&staged, &plan, &mut staging);
        let sz = 9 * staged[0].m * staged[0].m;
        let mut halos: Vec<&mut [f32]> = staged.iter_mut().map(|b| b.halo.as_mut_slice()).collect();
        scatter_exchange(&mut halos, sz, &staging);
        for (a, b) in direct.iter().zip(&staged) {
            assert_eq!(a.halo, b.halo);
        }
    }

    #[test]
    fn bytes_accounting() {
        let mesh = unit_cube_geometry(2);
        let owners: Vec<usize> = (0..8).map(|e| e % 2).collect();
        let (lblocks, plan) = build_local_blocks(&mesh, &owners, 2);
        let blocks: Vec<BlockState> = lblocks
            .iter()
            .map(|b| BlockState::from_local_block(b, 1, b.len(), b.halo_len.max(1)))
            .collect();
        let bytes = exchange_bytes(&blocks, &plan);
        assert_eq!(bytes, plan.total_faces() * 9 * 4 * 4);
    }
}
