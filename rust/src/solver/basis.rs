//! Legendre-Gauss-Lobatto basis, independent of the python implementation
//! (python/compile/basis.py); the two are cross-checked in tests via
//! hard-coded reference values and identities.

/// Evaluate P_n(x) and P'_n(x) by the three-term recurrence.
fn legendre_and_deriv(n: usize, x: f64) -> (f64, f64) {
    if n == 0 {
        return (1.0, 0.0);
    }
    let (mut p0, mut p1) = (1.0, x);
    for k in 1..n {
        let kf = k as f64;
        let p2 = ((2.0 * kf + 1.0) * x * p1 - kf * p0) / (kf + 1.0);
        p0 = p1;
        p1 = p2;
    }
    let dp = if (x * x - 1.0).abs() < 1e-14 {
        // endpoint limit: P'_N(+-1) = (+-1)^{N-1} N(N+1)/2
        let s = if x > 0.0 { 1.0 } else { (-1.0f64).powi(n as i32 - 1) };
        s * (n * (n + 1)) as f64 / 2.0
    } else {
        n as f64 * (x * p1 - p0) / (x * x - 1.0)
    };
    (p1, dp)
}

/// The LGL collocation basis of a given polynomial order.
#[derive(Debug, Clone)]
pub struct LglBasis {
    pub order: usize,
    /// Nodes on [-1, 1], ascending.
    pub nodes: Vec<f64>,
    /// Quadrature weights.
    pub weights: Vec<f64>,
    /// Differentiation matrix, row-major (M x M): D[i][j] = l'_j(x_i).
    pub d: Vec<f64>,
    /// `d` pre-cast to f32 once — the reference kernels work in f32 and
    /// used to pay an f64->f32 convert in the innermost derivative loop.
    pub d32: Vec<f32>,
    /// Lane-padded transpose of `d32`: `d32t[t * 8 + l] = d[l * m + t]`,
    /// rows padded with zeros to the widest f32 lane count (8). The SIMD
    /// axis-2 row matvec ([`crate::solver::simd::matvec_rows`]) loads one
    /// padded row per broadcast multiply-accumulate. Empty when m > 8
    /// (no vector path; the scalar kernel doesn't read it).
    pub d32t: Vec<f32>,
}

impl LglBasis {
    pub fn new(order: usize) -> Self {
        assert!(order >= 1, "LGL needs order >= 1");
        let n = order;
        let m = n + 1;
        // Newton from Chebyshev-Gauss-Lobatto guesses on the interior roots
        // of P'_N; endpoints fixed at +-1.
        let mut nodes = vec![0.0; m];
        nodes[0] = -1.0;
        nodes[n] = 1.0;
        for i in 1..n {
            let mut x = -(std::f64::consts::PI * i as f64 / n as f64).cos();
            for _ in 0..100 {
                let (p, dp) = legendre_and_deriv(n, x);
                // Newton on g = P'_N with g' from the Legendre ODE
                let d2p = (2.0 * x * dp - (n * (n + 1)) as f64 * p) / (1.0 - x * x);
                let dx = dp / d2p;
                x -= dx;
                if dx.abs() < 1e-15 {
                    break;
                }
            }
            nodes[i] = x;
        }
        let weights: Vec<f64> = nodes
            .iter()
            .map(|&x| {
                let (p, _) = legendre_and_deriv(n, x);
                2.0 / ((n * (n + 1)) as f64 * p * p)
            })
            .collect();
        // barycentric differentiation matrix
        let mut c = vec![1.0f64; m];
        for j in 0..m {
            for k in 0..m {
                if k != j {
                    c[j] *= nodes[j] - nodes[k];
                }
            }
        }
        let mut d = vec![0.0f64; m * m];
        for i in 0..m {
            let mut rowsum = 0.0;
            for j in 0..m {
                if i != j {
                    let v = (c[i] / c[j]) / (nodes[i] - nodes[j]);
                    d[i * m + j] = v;
                    rowsum += v;
                }
            }
            d[i * m + i] = -rowsum; // negative-sum trick
        }
        let d32: Vec<f32> = d.iter().map(|&v| v as f32).collect();
        let d32t = if m <= 8 {
            let mut t32 = vec![0.0f32; m * 8];
            for l in 0..m {
                for t in 0..m {
                    // same f64 -> f32 cast as d32 so both views agree bitwise
                    t32[t * 8 + l] = d[l * m + t] as f32;
                }
            }
            t32
        } else {
            Vec::new()
        };
        LglBasis { order, nodes, weights, d, d32, d32t }
    }

    pub fn m(&self) -> usize {
        self.order + 1
    }

    /// Endpoint weight w_0 (= w_N), the lift denominator.
    pub fn w0(&self) -> f64 {
        self.weights[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_sum_to_two() {
        for order in 1..=9 {
            let b = LglBasis::new(order);
            let s: f64 = b.weights.iter().sum();
            assert!((s - 2.0).abs() < 1e-12, "order {order}: {s}");
        }
    }

    #[test]
    fn diff_exact_on_monomials() {
        for order in 1..=7 {
            let b = LglBasis::new(order);
            let m = b.m();
            for p in 0..=order {
                for i in 0..m {
                    let mut du = 0.0;
                    for j in 0..m {
                        du += b.d[i * m + j] * b.nodes[j].powi(p as i32);
                    }
                    let exact = if p == 0 {
                        0.0
                    } else {
                        p as f64 * b.nodes[i].powi(p as i32 - 1)
                    };
                    assert!((du - exact).abs() < 1e-8, "order {order} p {p} i {i}");
                }
            }
        }
    }

    #[test]
    fn known_order2_values() {
        let b = LglBasis::new(2);
        assert!((b.nodes[1]).abs() < 1e-14);
        assert!((b.weights[0] - 1.0 / 3.0).abs() < 1e-14);
        assert!((b.weights[1] - 4.0 / 3.0).abs() < 1e-14);
    }

    #[test]
    fn known_order3_interior_nodes() {
        let b = LglBasis::new(3);
        let x = (1.0f64 / 5.0).sqrt();
        assert!((b.nodes[1] + x).abs() < 1e-12);
        assert!((b.nodes[2] - x).abs() < 1e-12);
    }

    #[test]
    fn d32_mirrors_d() {
        for order in [2usize, 3, 7] {
            let b = LglBasis::new(order);
            assert_eq!(b.d32.len(), b.d.len());
            for (lo, hi) in b.d32.iter().zip(&b.d) {
                assert_eq!(*lo, *hi as f32);
            }
        }
    }

    #[test]
    fn d32t_is_padded_transpose_of_d32() {
        for order in [2usize, 3, 7] {
            let b = LglBasis::new(order);
            let m = b.m();
            assert_eq!(b.d32t.len(), m * 8);
            for t in 0..m {
                for l in 0..8 {
                    let want = if l < m { b.d32[l * m + t] } else { 0.0 };
                    assert_eq!(b.d32t[t * 8 + l], want, "order {order} t {t} l {l}");
                }
            }
        }
        assert!(LglBasis::new(9).d32t.is_empty(), "no padded transpose past m = 8");
    }

    #[test]
    fn matches_python_basis_order7_w0() {
        // python: lgl_weights(7)[0] = 2/(7*8*P7(-1)^2) = 2/56
        let b = LglBasis::new(7);
        assert!((b.w0() - 2.0 / 56.0).abs() < 1e-13);
    }
}
