//! Explore the nested partitioning scheme: sweep node counts and MIC
//! fractions, print per-node statistics, the Fig 5.4-style slice, and how
//! the onion-peeled MIC surface compares to the ideal-cube lower bound.
//!
//! ```bash
//! cargo run --release --example partition_explorer
//! ```

use repro::costmodel::calib;
use repro::mesh::geometry::discontinuous_brick;
use repro::partition::{
    balance::mic_surface_faces, nested_partition, partition_stats, solve_mic_fraction, splice,
    DeviceKind,
};

fn main() -> repro::Result<()> {
    let n = 16;
    let mesh = discontinuous_brick([n, n, n], [1.0, 1.0, 1.0]);
    println!("mesh: {}^3 = {} elements\n", n, mesh.len());

    // ---- sweep node counts at the balanced fraction ----------------------
    println!("nodes  k/node  mic-frac  pci/node  ideal-cube  mpi/node(max)");
    for nodes in [1usize, 2, 4, 8] {
        let node_part = splice(&mesh, nodes);
        let k_node = mesh.len() / nodes;
        let sol = solve_mic_fraction(&calib::stampede_node(), 7, k_node);
        let frac = sol.k_mic as f64 / k_node as f64;
        let np = nested_partition(&mesh, &node_part, frac);
        let st = partition_stats(&mesh, &np);
        let pci_avg: f64 =
            st.per_node.iter().map(|s| s.pci_faces as f64).sum::<f64>() / nodes as f64;
        let mic_avg: f64 =
            st.per_node.iter().map(|s| s.k_mic as f64).sum::<f64>() / nodes as f64;
        println!(
            "{nodes:>5}  {k_node:>6}  {frac:>8.3}  {pci_avg:>8.0}  {:>10.0}  {:>13}",
            mic_surface_faces(mic_avg),
            st.max_mpi_faces(),
        );
    }

    // ---- sweep fractions on 4 nodes --------------------------------------
    println!("\nfraction sweep (4 nodes): realized mic share + pci surface");
    let node_part = splice(&mesh, 4);
    println!("requested  realized  pci_total  interior_clipped");
    for f in [0.1, 0.3, 0.5, 0.7, 0.9] {
        let np = nested_partition(&mesh, &node_part, f);
        let st = partition_stats(&mesh, &np);
        let mic: usize = np.node_counts.iter().map(|c| c.1).sum();
        let realized = mic as f64 / mesh.len() as f64;
        println!(
            "{f:>9.2}  {realized:>8.3}  {:>9}  {}",
            st.total_pci_faces(),
            if realized + 1e-9 < f { "yes" } else { "no" }
        );
    }

    // ---- Fig 5.4 slice ----------------------------------------------------
    println!("\nFig 5.4 mid-plane: digits = owning node (CPU), '*' = MIC interior");
    let sol = solve_mic_fraction(&calib::stampede_node(), 7, mesh.len() / 4);
    let np = nested_partition(&mesh, &node_part, sol.k_mic as f64 / (mesh.len() / 4) as f64);
    let mut grid = vec![vec![' '; n]; n];
    for (e, elem) in mesh.elements.iter().enumerate() {
        let ix = (elem.center[0] * n as f64).floor() as usize;
        let iy = (elem.center[1] * n as f64).floor() as usize;
        let iz = (elem.center[2] * n as f64).floor() as usize;
        if iz == n / 2 {
            grid[iy][ix] = if np.device[e] == DeviceKind::Mic {
                '*'
            } else {
                char::from_digit((np.node.assignment[e] % 10) as u32, 10).unwrap()
            };
        }
    }
    for row in grid.iter().rev() {
        println!("{}", row.iter().collect::<String>());
    }
    println!("\npartition_explorer OK");
    Ok(())
}
