//! Quickstart: build a mesh, nested-partition it, and run the wave solver
//! end to end through the public API (PJRT backend if artifacts exist,
//! rust-ref otherwise).
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use repro::coordinator::{node::WorkerBackend, HeteroRun};
use repro::costmodel::calib;
use repro::mesh::{build_local_blocks, geometry::unit_cube_geometry};
use repro::partition::{nested_partition, solve_mic_fraction, splice, DeviceKind};
use repro::runtime::ArtifactManifest;
use repro::solver::analytic::standing_wave;
use repro::solver::rk::stable_dt;
use repro::solver::{BlockState, LglBasis};

fn main() -> repro::Result<()> {
    let order = 2;
    let mesh = unit_cube_geometry(4); // 64 elements

    // level 1: one subdomain per (simulated) node — here a single node
    let node_part = splice(&mesh, 1);
    // level 2: CPU boundary / MIC interior, ratio from the balance solve
    let sol = solve_mic_fraction(&calib::stampede_node(), order, mesh.len());
    let np = nested_partition(&mesh, &node_part, sol.k_mic as f64 / mesh.len() as f64);
    println!(
        "partition: {} CPU + {} MIC elements (paper ratio ~1.6 at N=7)",
        np.node_counts[0].0, np.node_counts[0].1
    );

    let owners = np.owners();
    let (lblocks, plan) = build_local_blocks(&mesh, &owners, np.n_owners());

    // backend: PJRT artifacts when built, pure-rust reference otherwise
    let artifacts = ArtifactManifest::default_dir();
    let (backend, manifest) = if artifacts.join("manifest.json").exists() {
        (
            WorkerBackend::Pjrt { artifact_dir: artifacts.clone() },
            Some(ArtifactManifest::load(&artifacts)?),
        )
    } else {
        println!("(artifacts not built; falling back to the rust reference backend)");
        (WorkerBackend::RustRef, None)
    };

    let basis = LglBasis::new(order);
    let w = std::f64::consts::PI * 3f64.sqrt();
    let mut states = Vec::new();
    let mut devices = Vec::new();
    for lb in &lblocks {
        let (kb, hb) = match &manifest {
            Some(m) => {
                let meta = m.pick_stage(order, lb.len().max(1), lb.halo_len.max(1))?;
                (meta.k, meta.halo)
            }
            None => (lb.len().max(1), lb.halo_len.max(1)),
        };
        let mut st = BlockState::from_local_block(lb, order, kb, hb);
        st.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
        states.push(st);
        devices.push(if lb.owner % 2 == 0 { DeviceKind::Cpu } else { DeviceKind::Mic });
    }

    let dt = stable_dt(0.3, 0.25, 1.0, order);
    let mut run = HeteroRun::launch(&lblocks, states, plan, &devices, backend, order)?;
    let e0 = run.energy()?;
    run.run(dt, 25)?;
    let e1 = run.energy()?;
    println!("25 steps: energy {e0:.6} -> {e1:.6} (upwind DG dissipates slightly)");
    assert!(e1 <= e0 * 1.000001 && e1 > 0.9 * e0);
    println!("quickstart OK");
    Ok(())
}
