//! End-to-end driver (the EXPERIMENTS.md validation run): the full system
//! on a real small workload, proving all layers compose.
//!
//!  * real compute — the paper's DGSEM on the Table 6.1 workload (brick
//!    with a centered material discontinuity), AOT JAX+Pallas kernels
//!    executed through PJRT by the rust coordinator, CPU and MIC worker
//!    threads running concurrently with per-stage trace exchange;
//!  * real partitioning — Morton level-1 splice across 4 simulated nodes,
//!    level-2 interior/boundary split from the §5.6 balance solve;
//!  * modeled time — the same partition fed to the calibrated cluster
//!    simulator reports the paper's headline metric (baseline vs nested
//!    speedup) next to the measured physics and wall time.
//!
//! ```bash
//! make artifacts && cargo run --release --example heterogeneous_cluster
//! ```

use repro::coordinator::{node::WorkerBackend, HeteroRun};
use repro::costmodel::calib;
use repro::mesh::{build_local_blocks, geometry::discontinuous_brick};
use repro::partition::{nested_partition, partition_stats, solve_mic_fraction, splice, DeviceKind};
use repro::runtime::ArtifactManifest;
use repro::sim::{simulate, Cluster, Scheme};
use repro::solver::analytic::gaussian_pulse;
use repro::solver::rk::stable_dt;
use repro::solver::{BlockState, LglBasis};

fn main() -> repro::Result<()> {
    let order = 2;
    let nodes = 4;
    let mesh = discontinuous_brick([8, 8, 8], [2.0, 1.0, 1.0]);
    println!(
        "workload: {} elements, order {order}, {} simulated nodes (Table 6.1 geometry)",
        mesh.len(),
        nodes
    );

    // ---- the nested partitioning scheme ---------------------------------
    let node_part = splice(&mesh, nodes);
    let k_node = mesh.len() / nodes;
    let sol = solve_mic_fraction(&calib::stampede_node(), order, k_node);
    let frac = sol.k_mic as f64 / k_node as f64;
    let np = nested_partition(&mesh, &node_part, frac);
    let stats = partition_stats(&mesh, &np);
    println!("\nlevel-2 split (balance solve requested K_MIC/K_CPU = {:.2}):", sol.ratio);
    for (nd, s) in stats.per_node.iter().enumerate() {
        println!(
            "  node {nd}: cpu {} mic {} | pci faces {} mpi faces {}",
            s.k_cpu, s.k_mic, s.pci_faces, s.mpi_faces
        );
    }

    // ---- real execution through PJRT ------------------------------------
    let owners = np.owners();
    let (lblocks, plan) = build_local_blocks(&mesh, &owners, np.n_owners());
    let artifacts = ArtifactManifest::default_dir();
    anyhow::ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts`"
    );
    let manifest = ArtifactManifest::load(&artifacts)?;
    let basis = LglBasis::new(order);
    let mut states = Vec::new();
    let mut devices = Vec::new();
    for lb in &lblocks {
        let meta = manifest.pick_stage(order, lb.len().max(1), lb.halo_len.max(1))?;
        let mut st = BlockState::from_local_block(lb, order, meta.k, meta.halo);
        st.set_initial_condition(&basis, |x| {
            gaussian_pulse(x, [0.6, 0.5, 0.5], 0.15, 1.0, 1.0)
        });
        states.push(st);
        devices.push(if lb.owner % 2 == 0 { DeviceKind::Cpu } else { DeviceKind::Mic });
    }
    let dt = stable_dt(0.3, 2.0 / 8.0, 3.0, order);
    let steps = 200;
    let mut run = HeteroRun::launch(
        &lblocks,
        states,
        plan,
        &devices,
        WorkerBackend::Pjrt { artifact_dir: artifacts },
        order,
    )?;
    let e0 = run.energy()?;
    let t0 = std::time::Instant::now();
    run.run(dt, steps)?;
    let wall = t0.elapsed().as_secs_f64();
    let e1 = run.energy()?;
    println!("\nreal execution (PJRT, cpu+mic worker threads):");
    println!(
        "  {steps} steps x 5 stages in {wall:.2} s ({:.1} ms/step); \
         {:.0} elem-steps/s",
        wall * 1e3 / steps as f64,
        (mesh.len() * steps) as f64 / wall
    );
    println!(
        "  energy {e0:.6} -> {e1:.6} (ratio {:.6}, upwind-dissipative as required)",
        e1 / e0
    );
    anyhow::ensure!(e1.is_finite() && e1 <= e0 * 1.000001 && e1 > 0.5 * e0);

    // ---- modeled cluster time (the paper's headline) ---------------------
    println!("\nsimulated Stampede timing for this partition (cost models, DESIGN.md):");
    let cluster = Cluster::stampede(nodes);
    let paper_mesh = repro::coordinator::experiments::paper_mesh(nodes, 8192);
    let base = simulate(&cluster, &paper_mesh, 7, 20, Scheme::BaselineMpi { ranks_per_node: 8 });
    let nest = simulate(&cluster, &paper_mesh, 7, 20, Scheme::Nested { mic_fraction: None });
    let off = simulate(&cluster, &paper_mesh, 7, 20, Scheme::TaskOffload);
    println!(
        "  at paper scale (8192 elem/node, N=7): baseline {:.2} s/step, nested {:.2} s/step, \
         task-offload {:.2} s/step",
        base.wall_s / 20.0,
        nest.wall_s / 20.0,
        off.wall_s / 20.0
    );
    println!(
        "  nested speedup {:.1}x (paper: 6.3x at 1 node, 5.6x at 64); \
         cpu busy {:.0}%, mic busy {:.0}%",
        base.wall_s / nest.wall_s,
        nest.cpu_busy_frac * 100.0,
        nest.mic_busy_frac * 100.0
    );
    println!("\nheterogeneous_cluster OK");
    Ok(())
}
