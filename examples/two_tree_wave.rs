//! The paper's Fig 6.1 workload: coupled elastic-acoustic wave propagation
//! across two glued trees — acoustic (c_p = 1, c_s = 0) | elastic
//! (c_p = 3, c_s = 2) — with a material discontinuity at the interface.
//!
//! A pressure pulse launched in the acoustic tree partially transmits into
//! the elastic tree; the example tracks per-tree energy to show the
//! transmission, running the full nested-partition + PJRT stack.
//!
//! ```bash
//! make artifacts && cargo run --release --example two_tree_wave
//! ```

use repro::coordinator::{node::WorkerBackend, HeteroRun};
use repro::costmodel::calib;
use repro::mesh::{build_local_blocks, geometry::two_tree_geometry};
use repro::partition::{nested_partition, solve_mic_fraction, splice, DeviceKind};
use repro::runtime::ArtifactManifest;
use repro::solver::analytic::gaussian_pulse;
use repro::solver::rk::stable_dt;
use repro::solver::{BlockState, LglBasis};

/// Per-tree (acoustic | elastic) energy split.
fn tree_energy(run: &HeteroRun, order: usize) -> repro::Result<(f64, f64)> {
    let basis = LglBasis::new(order);
    let (mut ac, mut el) = (0.0, 0.0);
    for &o in &run.owners() {
        let st = run.read_block(o)?;
        let m = st.m;
        let vol = 9 * m * m * m;
        for e in 0..st.k_real {
            let mut one = st.clone();
            one.k_real = 1;
            one.q = st.q[e * vol..(e + 1) * vol].to_vec();
            one.mats = st.mats[e * 3..e * 3 + 3].to_vec();
            one.h = st.h[e * 3..e * 3 + 3].to_vec();
            one.centers = vec![st.centers[e]];
            let en = one.energy(&basis);
            if st.centers[e][0] < 1.0 {
                ac += en;
            } else {
                el += en;
            }
        }
    }
    Ok((ac, el))
}

fn main() -> repro::Result<()> {
    let order = 3;
    let n = 4; // 4^3 elements per tree
    let mesh = two_tree_geometry(n);
    println!(
        "two-tree geometry: {} elements (acoustic cp=1 | elastic cp=3, cs=2)",
        mesh.len()
    );

    // nested partition: one node, CPU boundary / MIC interior
    let node_part = splice(&mesh, 1);
    let sol = solve_mic_fraction(&calib::stampede_node(), order, mesh.len());
    let np = nested_partition(&mesh, &node_part, sol.k_mic as f64 / mesh.len() as f64);
    println!(
        "nested partition: {} CPU (boundary) + {} MIC (interior) elements",
        np.node_counts[0].0, np.node_counts[0].1
    );
    let owners = np.owners();
    let (lblocks, plan) = build_local_blocks(&mesh, &owners, np.n_owners());

    let artifacts = ArtifactManifest::default_dir();
    let (backend, manifest) = if artifacts.join("manifest.json").exists() {
        (
            WorkerBackend::Pjrt { artifact_dir: artifacts.clone() },
            Some(ArtifactManifest::load(&artifacts)?),
        )
    } else {
        println!("(no artifacts; using the rust reference backend)");
        (WorkerBackend::RustRef, None)
    };

    let basis = LglBasis::new(order);
    let mut states = Vec::new();
    let mut devices = Vec::new();
    for lb in &lblocks {
        let (kb, hb) = match &manifest {
            Some(m) => {
                let meta = m.pick_stage(order, lb.len().max(1), lb.halo_len.max(1))?;
                (meta.k, meta.halo)
            }
            None => (lb.len().max(1), lb.halo_len.max(1)),
        };
        let mut st = BlockState::from_local_block(lb, order, kb, hb);
        // pulse centered in the acoustic tree
        st.set_initial_condition(&basis, |x| gaussian_pulse(x, [0.5, 0.5, 0.5], 0.12, 1.0, 1.0));
        states.push(st);
        devices.push(if lb.owner % 2 == 0 { DeviceKind::Cpu } else { DeviceKind::Mic });
    }

    let dt = stable_dt(0.3, 1.0 / n as f64, 3.0, order);
    let steps = (0.6 / dt).ceil() as usize; // pulse reaches + crosses interface
    let mut run = HeteroRun::launch(&lblocks, states, plan, &devices, backend, order)?;

    let (a0, e0) = tree_energy(&run, order)?;
    println!("t=0.00: acoustic-tree energy {a0:.5}, elastic-tree energy {e0:.5}");
    let t0 = std::time::Instant::now();
    let half = steps / 2;
    run.run(dt, half)?;
    let (a1, e1) = tree_energy(&run, order)?;
    println!(
        "t={:.2}: acoustic {a1:.5}, elastic {e1:.5} (transmitted {:.1}%)",
        half as f64 * dt,
        100.0 * e1 / (a1 + e1)
    );
    run.run(dt, steps - half)?;
    let (a2, e2) = tree_energy(&run, order)?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "t={:.2}: acoustic {a2:.5}, elastic {e2:.5} (transmitted {:.1}%)",
        steps as f64 * dt,
        100.0 * e2 / (a2 + e2)
    );
    println!("{steps} steps in {wall:.2} s ({:.1} ms/step)", wall * 1e3 / steps as f64);

    let total0 = a0 + e0;
    let total2 = a2 + e2;
    assert!(total2 <= total0 * 1.000001, "energy must not grow");
    assert!(e2 > e0, "energy must transmit into the elastic tree");
    println!("two_tree_wave OK: wave crossed the material interface, energy non-increasing");
    Ok(())
}
