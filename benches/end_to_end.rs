//! Bench: Table 6.1 end to end — the three execution schemes at paper
//! scale through the simulator, the real multi-block driver scalar vs
//! parallel-with-overlap (the in-node nested split), plus the *real*
//! coordinator step (PJRT) on a reduced workload.
//! `cargo bench --offline --bench end_to_end`

use repro::coordinator::experiments::paper_mesh;
use repro::coordinator::node::WorkerBackend;
use repro::coordinator::{HeteroRun, ProfileReport};
use repro::mesh::{build_local_blocks, geometry::unit_cube_geometry};
use repro::partition::{nested_partition, splice, DeviceKind};
use repro::runtime::ArtifactManifest;
use repro::sim::{simulate, Cluster, Scheme};
use repro::solver::analytic::standing_wave;
use repro::solver::driver::{Driver, RustRefBackend, StageBackend};
use repro::solver::{BlockState, LglBasis, ParallelRefBackend};
use repro::util::bench::Bench;

/// Two-owner coupled driver over a unit cube, one backend per block.
fn coupled_driver(order: usize, n: usize, parallel: bool, overlap: bool) -> Driver {
    let mesh = unit_cube_geometry(n);
    let owners: Vec<usize> = (0..mesh.len()).map(|e| usize::from(e >= mesh.len() / 2)).collect();
    let (lblocks, plan) = build_local_blocks(&mesh, &owners, 2);
    let basis = LglBasis::new(order);
    let w = std::f64::consts::PI * 3f64.sqrt();
    let mut blocks: Vec<BlockState> = lblocks
        .iter()
        .map(|lb| BlockState::from_local_block(lb, order, lb.len().max(1), lb.halo_len.max(1)))
        .collect();
    for blk in blocks.iter_mut() {
        blk.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
    }
    let backends: Vec<Box<dyn StageBackend>> = (0..2)
        .map(|_| -> Box<dyn StageBackend> {
            if parallel {
                Box::new(ParallelRefBackend::new(order))
            } else {
                Box::new(RustRefBackend::new(order))
            }
        })
        .collect();
    let mut drv = Driver::new(blocks, plan, backends, order);
    drv.overlap = overlap;
    drv.prime();
    drv
}

fn main() {
    let b = Bench::new(1, 5);

    // ---- real multi-block driver: scalar vs parallel+overlap -----------
    for order in [3usize, 7] {
        let n = if order >= 7 { 4 } else { 6 };
        let k = n * n * n;
        let mut scalar_mean = None;
        let mut scalar_profile = None;
        for (label, parallel, overlap) in [
            ("scalar", false, false),
            ("parallel", true, false),
            ("parallel+overlap", true, true),
        ] {
            let mut drv = coupled_driver(order, n, parallel, overlap);
            let r = b.run(&format!("driver_step_{label}_n{order}_k{k}"), || {
                drv.step(1e-4).unwrap();
            });
            r.report_throughput(k * 5, "elem-stages");
            let profile = ProfileReport::from_kernel_times(&drv.total_times());
            match (&scalar_mean, &scalar_profile) {
                (None, _) => {
                    scalar_mean = Some(r.mean());
                    scalar_profile = Some(profile);
                }
                (Some(s), Some(base)) => println!(
                    "  {label}: {:.2}x wall vs scalar ({:.2}x by kernel CPU time)",
                    s / r.mean(),
                    profile.speedup_over(base),
                ),
                _ => unreachable!(),
            }
        }
    }

    // ---- simulated Table 6.1 at 1 and 64 nodes --------------------------
    for nodes in [1usize, 64] {
        let mesh = paper_mesh(nodes, 8192);
        let cluster = Cluster::stampede(nodes);
        let mut walls = (0.0, 0.0, 0.0);
        let r = b.run(&format!("table6_1_sim_{nodes}nodes"), || {
            let base = simulate(&cluster, &mesh, 7, 118, Scheme::BaselineMpi { ranks_per_node: 8 });
            let nest = simulate(&cluster, &mesh, 7, 118, Scheme::Nested { mic_fraction: None });
            let off = simulate(&cluster, &mesh, 7, 118, Scheme::TaskOffload);
            walls = (base.wall_s, nest.wall_s, off.wall_s);
        });
        r.report();
        println!(
            "  {nodes} node(s): baseline {:.0} s | nested {:.0} s ({:.1}x) | task-offload {:.0} s",
            walls.0,
            walls.1,
            walls.0 / walls.1,
            walls.2
        );
    }

    // ---- real coordinator step (PJRT) ------------------------------------
    if !cfg!(feature = "pjrt") {
        println!("SKIP real-step bench: built without the `pjrt` feature");
        return;
    }
    let dir = ArtifactManifest::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP real-step bench: artifacts not built");
        return;
    }
    let order = 3;
    let mesh = unit_cube_geometry(4);
    let node_part = splice(&mesh, 1);
    let np = nested_partition(&mesh, &node_part, 0.12);
    let owners = np.owners();
    let (lblocks, plan) = build_local_blocks(&mesh, &owners, np.n_owners());
    let manifest = ArtifactManifest::load(&dir).unwrap();
    let basis = LglBasis::new(order);
    let w = std::f64::consts::PI * 3f64.sqrt();
    let mut states = Vec::new();
    let mut devices = Vec::new();
    for lb in &lblocks {
        let meta = manifest.pick_stage(order, lb.len().max(1), lb.halo_len.max(1)).unwrap();
        let mut st = BlockState::from_local_block(lb, order, meta.k, meta.halo);
        st.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
        states.push(st);
        devices.push(if lb.owner % 2 == 0 { DeviceKind::Cpu } else { DeviceKind::Mic });
    }
    let mut run = HeteroRun::launch(
        &lblocks, states, plan, &devices,
        WorkerBackend::Pjrt { artifact_dir: dir }, order,
    )
    .unwrap();
    let r = b.run("hetero_step_pjrt_n3_64elems", || {
        run.step(1e-4).unwrap();
    });
    r.report_throughput(mesh.len() * 5, "elem-stages");
    println!(
        "  stage wall {:.3} s, exchange wall {:.3} s over {} steps",
        run.stage_wall_s, run.exchange_wall_s, run.steps_taken
    );
}
