//! Bench: Table 6.1 end to end — the three execution schemes at paper
//! scale through the simulator, the real multi-block driver scalar vs
//! parallel-with-overlap (the in-node nested split), the N-node cluster
//! runtime (node-count scaling + static-vs-adaptive rebalancing, emitted
//! to `BENCH_cluster.json`), plus the *real* coordinator step (PJRT) on a
//! reduced workload.
//!
//! `cargo bench --offline --bench end_to_end` — pass `-- --smoke` for the
//! CI mode: tiny meshes, 2 steps, still exercising the full cluster path.

use repro::coordinator::cluster::{ClusterRun, ClusterSpec};
use repro::coordinator::experiments::{cross_check, paper_mesh};
use repro::coordinator::node::WorkerBackend;
use repro::coordinator::profile::{busy_imbalance, node_busy_imbalance};
use repro::coordinator::rebalance::RebalanceTotals;
use repro::coordinator::{FaultPlan, HeteroRun, KillMode, KillSpec, ProfileReport, TransportKind};
use repro::mesh::{build_local_blocks, geometry::unit_cube_geometry};
use repro::partition::{nested_partition, splice, DeviceKind};
use repro::runtime::ArtifactManifest;
use repro::sim::{simulate, Cluster, Scheme};
use repro::solver::analytic::standing_wave;
use repro::solver::driver::{Driver, RustRefBackend, StageBackend};
use repro::solver::{BlockState, LglBasis, ParallelRefBackend};
use repro::util::bench::{Bench, JsonSink};

/// Two-owner coupled driver over a unit cube, one backend per block.
fn coupled_driver(order: usize, n: usize, parallel: bool, overlap: bool) -> Driver {
    let mesh = unit_cube_geometry(n);
    let owners: Vec<usize> = (0..mesh.len()).map(|e| usize::from(e >= mesh.len() / 2)).collect();
    let (lblocks, plan) = build_local_blocks(&mesh, &owners, 2);
    let basis = LglBasis::new(order);
    let w = std::f64::consts::PI * 3f64.sqrt();
    let mut blocks: Vec<BlockState> = lblocks
        .iter()
        .map(|lb| BlockState::from_local_block(lb, order, lb.len().max(1), lb.halo_len.max(1)))
        .collect();
    for blk in blocks.iter_mut() {
        blk.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
    }
    let backends: Vec<Box<dyn StageBackend>> = (0..2)
        .map(|_| -> Box<dyn StageBackend> {
            if parallel {
                Box::new(ParallelRefBackend::new(order))
            } else {
                Box::new(RustRefBackend::new(order))
            }
        })
        .collect();
    let mut drv = Driver::new(blocks, plan, backends, order);
    drv.overlap = overlap;
    drv.prime();
    drv
}

/// The N-node cluster runtime: node-count scaling over one global mesh
/// crossed with the transport matrix (inproc / shm / socket), plus the
/// rebalancer's imbalance win, written to `BENCH_cluster.json`.
fn cluster_bench(b: &Bench, smoke: bool) {
    let mut sink = JsonSink::new();
    let order = 2;
    let n = if smoke { 4 } else { 8 };
    let steps_per_iter = if smoke { 1 } else { 2 };
    let mesh = unit_cube_geometry(n);
    let w = std::f64::consts::PI * 3f64.sqrt();
    let ic = move |x: [f64; 3]| standing_wave(x, 0.0, 1.0, 1.0, w);
    let dt = 1e-4;

    // ---- node-count scaling x transport matrix --------------------------
    // same global mesh, P virtual nodes, stepped over all three message
    // fabrics; shm/socket cost relative to the in-process baseline lands
    // in BENCH_cluster.json as the transport_overhead_* scalars
    let ps: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };
    let p_max = *ps.last().unwrap();
    let mut t1 = None;
    for &p in ps {
        let mut inproc_mean = None;
        for kind in [TransportKind::InProc, TransportKind::Shm, TransportKind::Socket] {
            // the transports only diverge once an inter-node lane class
            // exists; at P=1 the socket fabric degenerates to the rings
            if p == 1 && kind != TransportKind::InProc {
                continue;
            }
            let mut spec = ClusterSpec::new(p, order);
            spec.mic_fraction = Some(0.25);
            spec.transport = kind;
            let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
            let items = mesh.len() * 5 * steps_per_iter;
            let tag = match kind {
                TransportKind::InProc => String::new(),
                other => format!("{}_", other.label()),
            };
            let r = b.run(&format!("cluster_step_p{p}_{tag}n{order}_k{}", mesh.len()), || {
                run.run(dt, steps_per_iter).unwrap();
            });
            r.report_throughput(items, "elem-stages");
            sink.push(&r, Some((items, "elem-stages")));
            // §5.5 refusal is transport-independent: classification comes
            // from the routing tables, not the mechanism
            assert_eq!(
                run.fabric().mic_inter_node_faces,
                0,
                "accelerators must stay off the inter-node fabric ({kind})"
            );
            if kind == TransportKind::InProc {
                inproc_mean = Some(r.mean());
                if p == p_max {
                    let f = run.fabric();
                    let (lb_self, lb_intra, lb_inter) = f.lane_bytes_per_stage(order);
                    sink.push_scalar("fabric_lane_self_bytes", lb_self as f64, "B_per_stage");
                    sink.push_scalar("fabric_lane_intra_bytes", lb_intra as f64, "B_per_stage");
                    sink.push_scalar("fabric_lane_inter_bytes", lb_inter as f64, "B_per_stage");
                    let msgs_i = f.intra_node_msgs as f64;
                    let msgs_x = f.inter_node_msgs as f64;
                    sink.push_scalar("fabric_lane_intra_msgs", msgs_i, "msgs_per_stage");
                    sink.push_scalar("fabric_lane_inter_msgs", msgs_x, "msgs_per_stage");
                }
                match t1 {
                    None => t1 = Some(r.mean()),
                    Some(base) => {
                        let eff = base / r.mean();
                        println!(
                            "  P={p}: parallel efficiency {eff:.2} vs P=1 \
                             (virtual nodes share this machine's cores)"
                        );
                        sink.push_scalar(
                            &format!("cluster_parallel_efficiency_p{p}"),
                            eff,
                            "t1_over_tp",
                        );
                    }
                }
            } else {
                let over = r.mean() / inproc_mean.expect("inproc benched first");
                println!("  P={p} {kind}: {over:.2}x the in-process fabric");
                if p == p_max {
                    sink.push_scalar(
                        &format!("transport_overhead_{}_over_inproc", kind.label()),
                        over,
                        "t_over_t_inproc",
                    );
                }
            }
        }
    }

    // ---- static vs adaptive: per-step worker busy imbalance -------------
    let steps_measure = if smoke { 2 } else { 6 };
    let imbalance_of = |rebalance: bool| -> f64 {
        let mut spec = ClusterSpec::new(1, order);
        spec.mic_fraction = Some(0.05); // deliberately bad static split
        if rebalance {
            spec.rebalance_every = Some(2);
        }
        let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
        // warm up (and let the rebalancer act), then measure steady state
        run.run(dt, if rebalance { 4 } else { 2 }).unwrap();
        run.rebalance_every = None;
        let _ = run.take_worker_times().unwrap();
        run.run(dt, steps_measure).unwrap();
        busy_imbalance(&run.take_worker_times().unwrap())
    };
    let imb_static = imbalance_of(false);
    let imb_adaptive = imbalance_of(true);
    println!(
        "  worker busy imbalance (max/mean): static {imb_static:.2} -> adaptive {imb_adaptive:.2}"
    );
    sink.push_scalar("cluster_imbalance_static", imb_static, "max_over_mean");
    sink.push_scalar("cluster_imbalance_adaptive", imb_adaptive, "max_over_mean");

    // ---- two-level: skewed cluster (one throttled node), static vs ------
    // adaptive level-1+2 rebalancing, node-level busy imbalance
    let spin = if smoke { 10 } else { 20 };
    let two_level = |adaptive: bool| -> (f64, RebalanceTotals) {
        let mut spec = ClusterSpec::new(2, order);
        spec.mic_fraction = Some(0.25);
        spec.node_backends = Some(vec![
            (WorkerBackend::RustRef, WorkerBackend::RustRef),
            (
                WorkerBackend::Throttled { spin_us_per_elem: spin },
                WorkerBackend::Throttled { spin_us_per_elem: spin },
            ),
        ]);
        if adaptive {
            spec.rebalance_every = Some(2);
        }
        let mut run = ClusterRun::launch(&mesh, &spec, ic).unwrap();
        // warm up (letting the two-level rebalancer converge), then freeze
        // and measure the steady state
        run.run(dt, if adaptive { 6 } else { 2 }).unwrap();
        run.rebalance_every = None;
        let _ = run.take_worker_times().unwrap();
        run.run(dt, steps_measure).unwrap();
        let imb = node_busy_imbalance(&run.take_worker_times().unwrap());
        (imb, RebalanceTotals::of(&run.rebalance_history))
    };
    let (tl_static, _) = two_level(false);
    let (tl_adaptive, t) = two_level(true);
    println!(
        "  two-level node imbalance on a skewed cluster: static {tl_static:.2} -> \
         adaptive {tl_adaptive:.2} (level-1 moved {}, level-2 moved {}, \
         rebuilt {} backends in {:.1} ms)",
        t.level1_migrated,
        t.level2_migrated,
        t.rebuilt_workers,
        t.wall_s * 1e3
    );
    sink.push_scalar("cluster_two_level_imbalance_static", tl_static, "max_over_mean");
    sink.push_scalar("cluster_two_level_imbalance_adaptive", tl_adaptive, "max_over_mean");
    sink.push_scalar("cluster_rebalance_level1_elems", t.level1_migrated as f64, "elems");
    sink.push_scalar("cluster_rebalance_level2_elems", t.level2_migrated as f64, "elems");
    sink.push_scalar("cluster_rebalance_rebuilt_workers", t.rebuilt_workers as f64, "workers");
    sink.push_scalar("cluster_rebalance_wall_s", t.wall_s, "s");

    // ---- fault tolerance: kill one node mid-run, recover, keep going ----
    // detection + checkpoint rewind + resplice onto the survivor, priced
    // as the recovery_wall_s / replayed_steps scalars
    let ft_steps = if smoke { 6 } else { 8 };
    let mut ft_spec = ClusterSpec::new(2, order);
    ft_spec.mic_fraction = Some(0.25);
    ft_spec.checkpoint_every = Some(2);
    ft_spec.faults = FaultPlan {
        seed: 7,
        kills: vec![KillSpec { node: 1, step: 3, mode: KillMode::Crash }],
        ..FaultPlan::default()
    };
    let mut ft_run = ClusterRun::launch(&mesh, &ft_spec, ic).unwrap();
    ft_run.run(dt, ft_steps).unwrap();
    assert!(ft_run.last_error().is_none(), "recovery must leave the run healthy");
    let ft = RebalanceTotals::of(&ft_run.rebalance_history);
    assert_eq!(ft.recoveries, 1, "the injected kill must trigger exactly one recovery");
    println!(
        "  fault tolerance: killed node 1 at step 3, recovered in {:.1} ms \
         replaying {} step(s)",
        ft.recovery_wall_s * 1e3,
        ft.replayed_steps
    );
    sink.push_scalar("recovery_wall_s", ft.recovery_wall_s, "s");
    sink.push_scalar("replayed_steps", ft.replayed_steps as f64, "steps");
    drop(ft_run);

    // ---- live-vs-sim drift per kernel (two-level cross-check) -----------
    let ck = cross_check(
        2,
        if smoke { 4 } else { 6 },
        order,
        if smoke { 2 } else { 4 },
        Some(2),
        TransportKind::InProc,
        None,
        Some(&mut sink),
    )
    .expect("cross-check");
    println!("{ck}");

    sink.write("BENCH_cluster.json").expect("writing BENCH_cluster.json");
    println!("  wrote BENCH_cluster.json");
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    if smoke {
        // CI mode: tiny mesh, 2 steps, one sample — exercises the cluster
        // path (launch, fabric, rebalance, JSON emission) plus the
        // overlapped driver on every push.
        println!("== smoke mode ==");
        let b = Bench::new(0, 1);
        cluster_bench(&b, true);
        let mut drv = coupled_driver(2, 2, true, true);
        drv.run(1e-4, 2).unwrap();
        println!("smoke: coupled overlapped driver ok, energy {:.6}", drv.energy());
        return;
    }
    let b = Bench::new(1, 5);

    // ---- real multi-block driver: scalar vs parallel+overlap -----------
    for order in [3usize, 7] {
        let n = if order >= 7 { 4 } else { 6 };
        let k = n * n * n;
        let mut scalar_mean = None;
        let mut scalar_profile = None;
        for (label, parallel, overlap) in [
            ("scalar", false, false),
            ("parallel", true, false),
            ("parallel+overlap", true, true),
        ] {
            let mut drv = coupled_driver(order, n, parallel, overlap);
            let r = b.run(&format!("driver_step_{label}_n{order}_k{k}"), || {
                drv.step(1e-4).unwrap();
            });
            r.report_throughput(k * 5, "elem-stages");
            let profile = ProfileReport::from_kernel_times(&drv.total_times());
            match (&scalar_mean, &scalar_profile) {
                (None, _) => {
                    scalar_mean = Some(r.mean());
                    scalar_profile = Some(profile);
                }
                (Some(s), Some(base)) => println!(
                    "  {label}: {:.2}x wall vs scalar ({:.2}x by kernel CPU time)",
                    s / r.mean(),
                    profile.speedup_over(base),
                ),
                _ => unreachable!(),
            }
        }
    }

    // ---- simulated Table 6.1 at 1 and 64 nodes --------------------------
    for nodes in [1usize, 64] {
        let mesh = paper_mesh(nodes, 8192);
        let cluster = Cluster::stampede(nodes);
        let mut walls = (0.0, 0.0, 0.0);
        let r = b.run(&format!("table6_1_sim_{nodes}nodes"), || {
            let base = simulate(&cluster, &mesh, 7, 118, Scheme::BaselineMpi { ranks_per_node: 8 });
            let nest = simulate(&cluster, &mesh, 7, 118, Scheme::Nested { mic_fraction: None });
            let off = simulate(&cluster, &mesh, 7, 118, Scheme::TaskOffload);
            walls = (base.wall_s, nest.wall_s, off.wall_s);
        });
        r.report();
        println!(
            "  {nodes} node(s): baseline {:.0} s | nested {:.0} s ({:.1}x) | task-offload {:.0} s",
            walls.0,
            walls.1,
            walls.0 / walls.1,
            walls.2
        );
    }

    // ---- N-node cluster runtime -----------------------------------------
    cluster_bench(&b, false);

    // ---- real coordinator step (PJRT) ------------------------------------
    if !cfg!(feature = "pjrt") {
        println!("SKIP real-step bench: built without the `pjrt` feature");
        return;
    }
    let dir = ArtifactManifest::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP real-step bench: artifacts not built");
        return;
    }
    let order = 3;
    let mesh = unit_cube_geometry(4);
    let node_part = splice(&mesh, 1);
    let np = nested_partition(&mesh, &node_part, 0.12);
    let owners = np.owners();
    let (lblocks, plan) = build_local_blocks(&mesh, &owners, np.n_owners());
    let manifest = ArtifactManifest::load(&dir).unwrap();
    let basis = LglBasis::new(order);
    let w = std::f64::consts::PI * 3f64.sqrt();
    let mut states = Vec::new();
    let mut devices = Vec::new();
    for lb in &lblocks {
        let meta = manifest.pick_stage(order, lb.len().max(1), lb.halo_len.max(1)).unwrap();
        let mut st = BlockState::from_local_block(lb, order, meta.k, meta.halo);
        st.set_initial_condition(&basis, |x| standing_wave(x, 0.0, 1.0, 1.0, w));
        states.push(st);
        devices.push(if lb.owner % 2 == 0 { DeviceKind::Cpu } else { DeviceKind::Mic });
    }
    let mut run = HeteroRun::launch(
        &lblocks, states, plan, &devices,
        WorkerBackend::Pjrt { artifact_dir: dir }, order,
    )
    .unwrap();
    let r = b.run("hetero_step_pjrt_n3_64elems", || {
        run.step(1e-4).unwrap();
    });
    r.report_throughput(mesh.len() * 5, "elem-stages");
    println!(
        "  stage wall {:.3} s, exchange wall {:.3} s over {} steps",
        run.stage_wall_s, run.exchange_wall_s, run.steps_taken
    );
}
