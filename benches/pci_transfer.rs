//! Bench: Fig 5.3's measured analogue — real in-process buffer copies
//! (the halo fabric) timed across sizes next to the calibrated PCI model,
//! plus the measured message-fabric links (mpsc hop / shm ring / Unix
//! socket) folded through `costmodel::{pci,network}::from_link` — the
//! same path `coordinator::transport::measure_fabric_links` feeds — so
//! the hand-fit `calib::fabric_pci` / `calib::fabric_network` defaults
//! can be checked against this machine.
//! `cargo bench --offline --bench pci_transfer`

use repro::coordinator::transport::{measure_fabric_links, TransportKind};
use repro::costmodel::calib::{fabric_network, fabric_pci, stampede_pci};
use repro::costmodel::network::NetworkModel;
use repro::costmodel::pci::{Direction, PciModel};
use repro::util::bench::Bench;

fn main() {
    let pci = stampede_pci();
    let b = Bench::new(2, 10);
    println!("real in-process copies (this machine) vs modeled Stampede PCI:");
    let mut mb = 1usize;
    while mb <= 1024 {
        let bytes = mb << 20;
        let src = vec![1.3f32; bytes / 4];
        let mut dst = vec![0f32; bytes / 4];
        let r = b.run(&format!("memcpy_{mb}MB"), || {
            dst.copy_from_slice(&src);
            std::hint::black_box(dst[0]);
        });
        let model_to = pci.transfer_time(bytes, Direction::ToDevice);
        let model_from = pci.transfer_time(bytes, Direction::FromDevice);
        println!(
            "  model: to_mic {:.3} ms, from_mic {:.3} ms ({:.1} GB/s measured here)",
            model_to * 1e3,
            model_from * 1e3,
            bytes as f64 / r.mean() / 1e9
        );
        mb *= 4;
    }

    // ---- measured fabric links -> costmodel calibration -----------------
    // probe what each transport actually puts on the two lane classes and
    // price a representative 4 MiB transfer with the from_link models next
    // to the hand-fit in-process defaults
    println!("\nmeasured fabric links vs calib::fabric_pci / calib::fabric_network defaults:");
    let probe_bytes = 4usize << 20;
    let def_pci = fabric_pci().transfer_time(probe_bytes, Direction::ToDevice);
    let def_net = fabric_network().exchange_time(probe_bytes / face_bytes(), paper_order());
    for kind in [TransportKind::InProc, TransportKind::Shm, TransportKind::Socket] {
        let links = match measure_fabric_links(kind) {
            Ok(l) => l,
            Err(e) => {
                println!("  {kind}: probe failed ({e}); skipping");
                continue;
            }
        };
        let mpci = PciModel::from_link(links.pci);
        let mnet = NetworkModel::from_link(links.net);
        println!(
            "  {kind}: pci lane {:.1} us / {:.1} GB/s, net lane {:.1} us / {:.1} GB/s",
            links.pci.latency_s * 1e6,
            links.pci.bw_bytes_per_s / 1e9,
            links.net.latency_s * 1e6,
            links.net.bw_bytes_per_s / 1e9
        );
        println!(
            "    4 MiB priced: pci {:.3} ms (default {:.3} ms), \
             net exchange {:.3} ms (default {:.3} ms)",
            mpci.transfer_time(probe_bytes, Direction::ToDevice) * 1e3,
            def_pci * 1e3,
            mnet.exchange_time(probe_bytes / face_bytes(), paper_order()) * 1e3,
            def_net * 1e3
        );
    }
}

/// Bytes of one face trace at the paper's order, so the network pricing
/// above can express 4 MiB as a face count.
fn face_bytes() -> usize {
    repro::costmodel::kernels::face_trace_bytes(paper_order())
}

fn paper_order() -> usize {
    repro::costmodel::calib::PAPER_ORDER
}
