//! Bench: Fig 5.3's measured analogue — real in-process buffer copies
//! (the halo fabric) timed across sizes, next to the calibrated PCI model.
//! `cargo bench --offline --bench pci_transfer`

use repro::costmodel::calib::stampede_pci;
use repro::costmodel::pci::Direction;
use repro::util::bench::Bench;

fn main() {
    let pci = stampede_pci();
    let b = Bench::new(2, 10);
    println!("real in-process copies (this machine) vs modeled Stampede PCI:");
    let mut mb = 1usize;
    while mb <= 1024 {
        let bytes = mb << 20;
        let src = vec![1.3f32; bytes / 4];
        let mut dst = vec![0f32; bytes / 4];
        let r = b.run(&format!("memcpy_{mb}MB"), || {
            dst.copy_from_slice(&src);
            std::hint::black_box(dst[0]);
        });
        let model_to = pci.transfer_time(bytes, Direction::ToDevice);
        let model_from = pci.transfer_time(bytes, Direction::FromDevice);
        println!(
            "  model: to_mic {:.3} ms, from_mic {:.3} ms ({:.1} GB/s measured here)",
            model_to * 1e3,
            model_from * 1e3,
            bytes as f64 / r.mean() / 1e9
        );
        mb *= 4;
    }
}
