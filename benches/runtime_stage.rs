//! Bench: one AOT stage through PJRT per (order, bucket) — the L3 hot
//! path's compute call. The before/after rows in EXPERIMENTS.md §Perf
//! come from here. `cargo bench --offline --bench runtime_stage`

use repro::mesh::{build_local_blocks, geometry::unit_cube_geometry};
use repro::runtime::{ArtifactManifest, PjrtRuntime};
use repro::solver::basis::LglBasis;
use repro::solver::state::BlockState;
use repro::solver::StageBackend;
use repro::util::bench::Bench;

fn main() {
    let dir = ArtifactManifest::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP: artifacts not built (make artifacts)");
        return;
    }
    let mut rt = PjrtRuntime::new(&dir).unwrap();
    let b = Bench::new(2, 10);
    for (order, n_side) in [(2usize, 4usize), (3, 4), (7, 4)] {
        let mesh = unit_cube_geometry(n_side);
        let owners = vec![0usize; mesh.len()];
        let (lblocks, _) = build_local_blocks(&mesh, &owners, 1);
        let Ok(meta) = rt.manifest.pick_stage(order, mesh.len(), 1) else {
            println!("skip order {order}: no artifact bucket");
            continue;
        };
        let (kb, hb) = (meta.k, meta.halo);
        let basis = LglBasis::new(order);
        let mut st = BlockState::from_local_block(&lblocks[0], order, kb, hb);
        st.set_initial_condition(&basis, |x| {
            [x[0].sin(), 0.0, 0.0, 0.0, 0.0, 0.0, x[1].cos(), 0.0, 0.0]
        });
        let mut backend = rt.stage_backend(&st).unwrap();
        let r = b.run(&format!("pjrt_stage_n{order}_k{kb}"), || {
            backend.stage(&mut st, 1e-4, -0.5, 0.3).unwrap();
        });
        r.report_throughput(mesh.len(), "elem-stages");
    }
}
