//! Bench: the pure-rust reference stage, scalar vs the multithreaded
//! boundary/interior backend, per order — the numerator of the paper's
//! baseline column plus the speedup this repo's level-2 in-node split
//! buys. Two parallel pipelines are priced against each other:
//!
//! * **fused** (the default) — persistent worker pool, RHS+RK fused per
//!   element, memoized classification (`ref_stage_parallel_*` entries);
//! * **legacy** — the pre-pool scoped-thread pipeline, three spawn/join
//!   sweeps per phase (`ref_stage_legacy_*` entries).
//!
//! The small-block order-2 series (K <= 64) is where PERF.md predicts the
//! spawn/classify overhead dominates; `stage_spawn_overhead_ns_*` scalars
//! record legacy-minus-fused per stage there and at order 7 (where both
//! must be compute-bound), and `fused_over_legacy_*` the ratio.
//!
//! Each scalar run is additionally repeated with the lane dispatch pinned
//! to the portable fallback (`ref_stage_nosimd_*`); the
//! `simd_over_scalar_nN_kK` scalars are nosimd-mean / simd-mean on one
//! thread — the vector kernels' own speedup, fused/threading excluded.
//!
//! On `simd-fma` builds whose host reports FMA, the stage is priced a
//! third way with the contraction toggled off/on on the same build
//! (`ref_stage_nofma_*` / `ref_stage_fma_*`); `fma_over_nofma_nN_kK` is
//! nofma-mean / fma-mean — what `_mm256_fmadd_ps` alone buys.
//!
//! Writes `BENCH_rhs.json` (see PERF.md for the schema).
//! `cargo bench --offline --bench rhs_reference` — pass `-- --smoke` for
//! the CI-sized run (fewer warmup/sample iterations, same series, so the
//! archived scalars exist for every commit at a fraction of the wall
//! time; read trends, not single noisy runs).

use repro::mesh::{build_local_blocks, geometry::unit_cube_geometry};
use repro::solver::basis::LglBasis;
use repro::solver::reference::{stage, RefScratch};
use repro::solver::simd::{self, Lanes};
use repro::solver::state::BlockState;
use repro::solver::{ParallelRefBackend, StageBackend};
use repro::util::bench::{Bench, JsonSink};

fn block_state(order: usize, n: usize) -> BlockState {
    let mesh = unit_cube_geometry(n);
    let owners = vec![0usize; mesh.len()];
    let (lblocks, _) = build_local_blocks(&mesh, &owners, 1);
    let basis = LglBasis::new(order);
    let ic = |x: [f64; 3]| [x[0].sin(), 0.0, 0.0, 0.0, 0.0, 0.0, x[1].cos(), 0.0, 0.0];
    let mut st = BlockState::from_local_block(&lblocks[0], order, mesh.len(), 8);
    st.set_initial_condition(&basis, ic);
    st
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let b = if smoke { Bench::new(1, 3) } else { Bench::new(2, 8) };
    let mut sink = JsonSink::new();
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let lanes = simd::detect();
    println!("host parallelism: {hw} threads{}", if smoke { " (smoke mode)" } else { "" });
    println!("simd lanes: {lanes:?} ({} f32/op)", lanes.width());

    // (order, n per axis): the established series plus the small-block
    // order-2 regime (27 and 64 elements) where barrier removal shows
    for (order, n) in [(2usize, 3usize), (2, 4), (2, 6), (3, 6), (7, 4)] {
        let k = n * n * n;
        let basis = LglBasis::new(order);

        // ---- scalar reference ------------------------------------------
        let mut st = block_state(order, n);
        let mut scratch = RefScratch::new(&st);
        let scalar = b.run(&format!("ref_stage_scalar_n{order}_k{k}"), || {
            stage(&mut st, &basis, &mut scratch, 1e-4, -0.5, 0.3);
        });
        scalar.report_throughput(k, "elem-stages");
        sink.push(&scalar, Some((k, "elem-stages")));

        // ---- same stage with the vector paths forced off ---------------
        // (the `simd_over_scalar_*` scalars price the SIMD kernels alone:
        // same code, same thread, lane dispatch pinned to the portable
        // fallback; a no-op when the host has no vector unit)
        if lanes != Lanes::Scalar {
            let mut st = block_state(order, n);
            let mut scratch = RefScratch::new(&st);
            simd::set_forced(Some(Lanes::Scalar));
            let nosimd = b.run(&format!("ref_stage_nosimd_n{order}_k{k}"), || {
                stage(&mut st, &basis, &mut scratch, 1e-4, -0.5, 0.3);
            });
            simd::set_forced(None);
            nosimd.report_throughput(k, "elem-stages");
            sink.push(&nosimd, Some((k, "elem-stages")));
            let speedup = nosimd.mean() / scalar.mean();
            println!("  order {order}, k {k}: simd {speedup:.2}x over scalar lanes");
            sink.push_scalar(&format!("simd_over_scalar_n{order}_k{k}"), speedup, "speedup");
        }

        // ---- FMA-contracted W8 kernels vs the bitwise-exact ones -------
        // (simd-fma builds on FMA hosts only; both legs run on this same
        // build via the runtime toggle, so the delta prices the fused
        // multiply-adds alone)
        if lanes == Lanes::W8 && simd::fma_available() {
            let mut st = block_state(order, n);
            let mut scratch = RefScratch::new(&st);
            simd::set_fma(Some(false));
            let nofma = b.run(&format!("ref_stage_nofma_n{order}_k{k}"), || {
                stage(&mut st, &basis, &mut scratch, 1e-4, -0.5, 0.3);
            });
            let mut st = block_state(order, n);
            let mut scratch = RefScratch::new(&st);
            simd::set_fma(Some(true));
            let fma = b.run(&format!("ref_stage_fma_n{order}_k{k}"), || {
                stage(&mut st, &basis, &mut scratch, 1e-4, -0.5, 0.3);
            });
            simd::set_fma(None);
            nofma.report_throughput(k, "elem-stages");
            fma.report_throughput(k, "elem-stages");
            sink.push(&nofma, Some((k, "elem-stages")));
            sink.push(&fma, Some((k, "elem-stages")));
            let speedup = nofma.mean() / fma.mean();
            println!("  order {order}, k {k}: fma {speedup:.2}x over separate mul+add");
            sink.push_scalar(&format!("fma_over_nofma_n{order}_k{k}"), speedup, "speedup");
        }

        // ---- fused pool backend, thread sweep --------------------------
        let mut counts = vec![1usize, 2, 4, hw];
        counts.sort_unstable();
        counts.dedup();
        let mut best: Option<f64> = None;
        let mut fused_at_hw: Option<f64> = None;
        for &threads in &counts {
            let mut st = block_state(order, n);
            let mut backend = ParallelRefBackend::with_threads(order, threads);
            let par = b.run(&format!("ref_stage_parallel_n{order}_k{k}_t{threads}"), || {
                backend.stage(&mut st, 1e-4, -0.5, 0.3).unwrap();
            });
            par.report_throughput(k, "elem-stages");
            sink.push(&par, Some((k, "elem-stages")));
            let speedup = scalar.mean() / par.mean();
            println!("  order {order}, k {k}, {threads} thread(s): {speedup:.2}x vs scalar");
            best = Some(best.map_or(speedup, |s: f64| s.max(speedup)));
            if threads == hw {
                fused_at_hw = Some(par.mean());
            }
        }
        if let Some(s) = best {
            println!("order {order}, k {k}: best fused speedup {s:.2}x over scalar");
        }

        // ---- legacy scoped-thread backend at the full budget -----------
        // (the pre-PR pipeline: per-stage spawn/join sweeps + per-stage
        // classification; kept to price what the pool removed)
        let mut st = block_state(order, n);
        let mut legacy = ParallelRefBackend::legacy_scoped(order, hw);
        let leg = b.run(&format!("ref_stage_legacy_n{order}_k{k}_t{hw}"), || {
            legacy.stage(&mut st, 1e-4, -0.5, 0.3).unwrap();
        });
        leg.report_throughput(k, "elem-stages");
        sink.push(&leg, Some((k, "elem-stages")));
        if let Some(fused) = fused_at_hw {
            let overhead_ns = (leg.mean() - fused) * 1e9;
            let ratio = leg.mean() / fused;
            println!(
                "  order {order}, k {k}: fused {ratio:.2}x over legacy \
                 (spawn overhead {overhead_ns:.0} ns/stage)"
            );
            sink.push_scalar(
                &format!("stage_spawn_overhead_ns_n{order}_k{k}"),
                overhead_ns,
                "ns_per_stage",
            );
            sink.push_scalar(
                &format!("fused_over_legacy_n{order}_k{k}"),
                ratio,
                "speedup",
            );
        }
    }

    match sink.write("BENCH_rhs.json") {
        Ok(()) => println!("wrote BENCH_rhs.json"),
        Err(e) => eprintln!("could not write BENCH_rhs.json: {e}"),
    }
}
