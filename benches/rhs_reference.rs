//! Bench: the pure-rust reference stage (the scalar-CPU kernel path).
//! Reports element throughput per order — the numerator of the paper's
//! baseline column. `cargo bench --offline --bench rhs_reference`

use repro::mesh::{build_local_blocks, geometry::unit_cube_geometry};
use repro::solver::basis::LglBasis;
use repro::solver::reference::{stage, RefScratch};
use repro::solver::state::BlockState;
use repro::util::bench::Bench;

fn main() {
    let b = Bench::new(2, 8);
    for order in [2usize, 3, 7] {
        let n = if order >= 7 { 4 } else { 6 };
        let mesh = unit_cube_geometry(n);
        let owners = vec![0usize; mesh.len()];
        let (lblocks, _) = build_local_blocks(&mesh, &owners, 1);
        let basis = LglBasis::new(order);
        let mut st = BlockState::from_local_block(&lblocks[0], order, mesh.len(), 8);
        st.set_initial_condition(&basis, |x| {
            [x[0].sin(), 0.0, 0.0, 0.0, 0.0, 0.0, x[1].cos(), 0.0, 0.0]
        });
        let mut scratch = RefScratch::new(&st);
        let r = b.run(&format!("ref_stage_n{order}_k{}", mesh.len()), || {
            stage(&mut st, &basis, &mut scratch, 1e-4, -0.5, 0.3);
        });
        r.report_throughput(mesh.len(), "elem-stages");
    }
}
