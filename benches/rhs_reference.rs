//! Bench: the pure-rust reference stage, scalar vs the multithreaded
//! boundary/interior backend, per order — the numerator of the paper's
//! baseline column plus the speedup this repo's level-2 in-node split
//! buys. Writes `BENCH_rhs.json` (see PERF.md for the schema).
//! `cargo bench --offline --bench rhs_reference`

use repro::mesh::{build_local_blocks, geometry::unit_cube_geometry};
use repro::solver::basis::LglBasis;
use repro::solver::reference::{stage, RefScratch};
use repro::solver::state::BlockState;
use repro::solver::{ParallelRefBackend, StageBackend};
use repro::util::bench::{Bench, JsonSink};

fn main() {
    let b = Bench::new(2, 8);
    let mut sink = JsonSink::new();
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("host parallelism: {hw} threads");

    for order in [2usize, 3, 7] {
        let n = if order >= 7 { 4 } else { 6 };
        let mesh = unit_cube_geometry(n);
        let owners = vec![0usize; mesh.len()];
        let (lblocks, _) = build_local_blocks(&mesh, &owners, 1);
        let basis = LglBasis::new(order);
        let ic = |x: [f64; 3]| [x[0].sin(), 0.0, 0.0, 0.0, 0.0, 0.0, x[1].cos(), 0.0, 0.0];

        // ---- scalar reference ------------------------------------------
        let mut st = BlockState::from_local_block(&lblocks[0], order, mesh.len(), 8);
        st.set_initial_condition(&basis, ic);
        let mut scratch = RefScratch::new(&st);
        let scalar = b.run(&format!("ref_stage_scalar_n{order}_k{}", mesh.len()), || {
            stage(&mut st, &basis, &mut scratch, 1e-4, -0.5, 0.3);
        });
        scalar.report_throughput(mesh.len(), "elem-stages");
        sink.push(&scalar, Some((mesh.len(), "elem-stages")));

        // ---- parallel backend, thread sweep ----------------------------
        let mut counts = vec![1usize, 2, 4, hw];
        counts.sort_unstable();
        counts.dedup();
        let mut best: Option<f64> = None;
        for threads in counts {
            let mut st = BlockState::from_local_block(&lblocks[0], order, mesh.len(), 8);
            st.set_initial_condition(&basis, ic);
            let mut backend = ParallelRefBackend::with_threads(order, threads);
            let par = b.run(
                &format!("ref_stage_parallel_n{order}_k{}_t{threads}", mesh.len()),
                || {
                    backend.stage(&mut st, 1e-4, -0.5, 0.3).unwrap();
                },
            );
            par.report_throughput(mesh.len(), "elem-stages");
            sink.push(&par, Some((mesh.len(), "elem-stages")));
            let speedup = scalar.mean() / par.mean();
            println!("  order {order}, {threads} thread(s): {speedup:.2}x vs scalar");
            best = Some(best.map_or(speedup, |s: f64| s.max(speedup)));
        }
        if let Some(s) = best {
            println!("order {order}: best parallel speedup {s:.2}x over scalar");
        }
    }

    match sink.write("BENCH_rhs.json") {
        Ok(()) => println!("wrote BENCH_rhs.json"),
        Err(e) => eprintln!("could not write BENCH_rhs.json: {e}"),
    }
}
