//! Bench: regenerate Fig 4.1 (baseline kernel breakdown) and time the
//! simulator itself. `cargo bench --offline --bench profile_breakdown`

use repro::coordinator::experiments::paper_mesh;
use repro::coordinator::ProfileReport;
use repro::sim::{simulate, Cluster, Scheme};
use repro::util::bench::Bench;

fn main() {
    let b = Bench::new(1, 5);
    for nodes in [1usize, 8, 64] {
        let mesh = paper_mesh(nodes, 8192);
        let cluster = Cluster::stampede(nodes);
        let mut last = None;
        let r = b.run(&format!("simulate_fig4_1_{nodes}nodes"), || {
            let rep = simulate(
                &cluster, &mesh, 7, 118, Scheme::BaselineMpi { ranks_per_node: 8 },
            );
            last = Some(rep);
        });
        r.report_throughput(118 * nodes, "node-steps");
        let rep = last.unwrap();
        println!(
            "{}",
            ProfileReport::from_breakdown(&rep.breakdown)
                .render(&format!("Fig 4.1 breakdown, {nodes} node(s), wall {:.0} s", rep.wall_s))
        );
    }
}
