//! Bench: the partitioning pipeline at paper scale — Morton splice,
//! onion-peeling nested split, stats, local-block extraction.
//! `cargo bench --offline --bench partitioner`

use repro::mesh::build_local_blocks;
use repro::mesh::geometry::sweep_dims;
use repro::partition::{nested_partition, partition_stats, splice};
use repro::util::bench::Bench;

fn main() {
    let b = Bench::new(1, 5);
    for nodes in [1usize, 8, 64] {
        let (dims, extent) = sweep_dims(nodes, 8192);
        let mesh = repro::mesh::geometry::discontinuous_brick(dims, extent);
        let k = mesh.len();
        let r = b.run(&format!("splice_{nodes}n_{k}elems"), || {
            let p = splice(&mesh, nodes);
            std::hint::black_box(p.sizes());
        });
        r.report_throughput(k, "elems");
        let node_part = splice(&mesh, nodes);
        let r = b.run(&format!("nested_{nodes}n_{k}elems"), || {
            let np = nested_partition(&mesh, &node_part, 0.62);
            std::hint::black_box(np.node_counts.len());
        });
        r.report_throughput(k, "elems");
        let np = nested_partition(&mesh, &node_part, 0.62);
        let r = b.run(&format!("stats_{nodes}n_{k}elems"), || {
            std::hint::black_box(partition_stats(&mesh, &np).total_pci_faces());
        });
        r.report_throughput(k, "elems");
        if nodes <= 8 {
            let owners = np.owners();
            let r = b.run(&format!("blocks_{nodes}n_{k}elems"), || {
                let (blocks, plan) = build_local_blocks(&mesh, &owners, np.n_owners());
                std::hint::black_box((blocks.len(), plan.total_faces()));
            });
            r.report_throughput(k, "elems");
        }
    }
}
